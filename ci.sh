#!/usr/bin/env bash
# Tier-1 verification gate: build, test, and (when available) check
# formatting and lints. Run before every merge; CI runs exactly this
# script.
#
#   ./ci.sh               # release build + tests + fmt + clippy gates
#   SKIP_FMT=1 ./ci.sh    # skip the formatting gate
#   SKIP_CLIPPY=1 ./ci.sh # skip the lint gate
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release"
cargo build --release

echo "== cargo check --all-targets"
cargo check --all-targets --quiet

echo "== cargo bench --no-run"
cargo bench --no-run --quiet        # benches must keep building end-to-end

echo "== cargo test -q"
cargo test -q

echo "== fault-scenario smoke run"
# One end-to-end pass of the ops subsystem: faults, drains, the
# admission queue and preemption on the quick workload, plus the
# availability sweep axes. Catches CLI/reporting regressions the unit
# tests can't see.
cargo run --release --quiet -- simulate --quick --policy grmu \
    --mtbf 400 --drain-rate 1 --queue-cap 16 --queue-ttl 12 \
    --preempt --priority-frac 0.1 --arrival-process bursty >/dev/null
cargo run --release --quiet -- sweep --quick --mtbf-axis 0,400 --drain-axis 0,2 >/dev/null

echo "== sharded-engine smoke run"
# The sharded router end-to-end: 4 shards with auto worker threads,
# cross-shard rebalance, and a correlated-failure (blast radius) pass.
cargo run --release --quiet -- simulate --quick --policy grmu \
    --shards 4 --shard-rebalance 12 >/dev/null
cargo run --release --quiet -- simulate --quick --policy grmu \
    --shards 2 --host-mtbf 500 --blast-radius 0.5 >/dev/null

echo "== ILP repair + optimality-gap smoke run"
# The rolling ILP repair planner composed through the registry, and the
# gap reporter's sweep column end-to-end.
cargo run --release --quiet -- simulate --quick --policy mcc+ilp-repair \
    --ilp-window 8 --ilp-nodes 5000 --ilp-period 12 >/dev/null
cargo run --release --quiet -- sweep --quick --gap-every 48 \
    | grep -q "Optimality gap" || { echo "sweep produced no gap samples"; exit 1; }

echo "== cluster-index-v2 smoke run"
# The hierarchical bitset index vs its brute-force scan oracle through
# the real CLI: a small mixed-model sweep must report byte-identical
# rows (modulo the wall-clock column) across --use-index modes.
IDX_A="$(mktemp)"; IDX_B="$(mktemp)"
cargo run --release --quiet -- sweep --quick --seeds 42 --policies ff,grmu \
    --gpu-models a30:0.5,a100-40:0.5 --use-index true \
    | awk '{$NF=""; print}' > "$IDX_A"
cargo run --release --quiet -- sweep --quick --seeds 42 --policies ff,grmu \
    --gpu-models a30:0.5,a100-40:0.5 --use-index false \
    | awk '{$NF=""; print}' > "$IDX_B"
grep -q "acceptance" "$IDX_A" || { echo "index smoke produced no sweep table"; exit 1; }
diff "$IDX_A" "$IDX_B" \
    || { echo "indexed and scan sweeps diverged"; exit 1; }
rm -f "$IDX_A" "$IDX_B"

echo "== crash-recovery smoke run"
# Checkpoint a quick run, kill it on disk (drop the newest snapshot and
# tear the next one), resume, and require the resumed run to print the
# same headline metrics. Exercises the snapshot store, journal
# cross-check and torn-write fallback through the real CLI.
CKPT_DIR="$(mktemp -d)"
trap 'rm -rf "$CKPT_DIR"' EXIT
cargo run --release --quiet -- simulate --quick --policy grmu \
    --checkpoint-every 24 --checkpoint-dir "$CKPT_DIR" \
    | grep '^policy=' > "$CKPT_DIR/full.out"
SNAPS=("$CKPT_DIR"/snap-*.grmu)
[ "${#SNAPS[@]}" -ge 2 ] || { echo "expected >=2 snapshots, got ${#SNAPS[@]}"; exit 1; }
# Kill: the newest image vanishes, the next-newest is torn mid-write.
rm "${SNAPS[-1]}"
truncate -s 100 "${SNAPS[-2]}"
cargo run --release --quiet -- simulate --quick --policy grmu \
    --resume "$CKPT_DIR" \
    | grep '^policy=' > "$CKPT_DIR/resumed.out"
# wall= differs by definition; everything else must match exactly.
sed 's/ wall=.*//' "$CKPT_DIR/full.out" > "$CKPT_DIR/full.cmp"
sed 's/ wall=.*//' "$CKPT_DIR/resumed.out" > "$CKPT_DIR/resumed.cmp"
diff "$CKPT_DIR/full.cmp" "$CKPT_DIR/resumed.cmp" \
    || { echo "resumed run diverged from the checkpointed run"; exit 1; }
# Graceful-degradation flag parses and runs end to end.
cargo run --release --quiet -- simulate --quick --policy grmu \
    --on-corruption rebuild >/dev/null

echo "== cargo doc --no-deps (warnings are errors)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

if [ "${SKIP_FMT:-0}" != "1" ]; then
    if cargo fmt --version >/dev/null 2>&1; then
        echo "== cargo fmt --check"
        cargo fmt --check
    else
        echo "== cargo fmt unavailable (rustfmt not installed); skipping"
    fi
fi

if [ "${SKIP_CLIPPY:-0}" != "1" ]; then
    if cargo clippy --version >/dev/null 2>&1; then
        echo "== cargo clippy --all-targets -- -D warnings"
        cargo clippy --all-targets --quiet -- -D warnings
    else
        echo "== cargo clippy unavailable; skipping"
    fi
fi

echo "CI OK"
