//! The three-layer composition check: Rust coordinator scoring through
//! the AOT-compiled Pallas/JAX artifact via PJRT.
//!
//! 1. Loads `artifacts/cc_scorer.hlo.txt` (Pallas kernel → JAX graph →
//!    HLO text, built once by `make artifacts`; python is NOT running
//!    now).
//! 2. Verifies bit-identical CC + per-profile capacities against the
//!    native table for all 256 occupancy masks.
//! 3. Runs the same MCC placement decisions with both scoring backends
//!    and asserts identical placements.
//! 4. Reports scorer throughput (native vs XLA) — the L1/L3 perf numbers
//!    recorded in EXPERIMENTS.md §Perf.
//!
//! Run: `make artifacts && cargo run --release --example xla_scorer`

use grmu::cluster::DataCenter;
use grmu::mig::gpu::{cc, profile_capacity};
use grmu::mig::GpuModel;
use grmu::policies::mcc::Mcc;
use grmu::policies::{CcScorer, NativeScorer, Policy, PolicyCtx};
use grmu::runtime::XlaScorer;
use grmu::trace::{TraceConfig, Workload};
use std::path::Path;
use std::time::Instant;

fn main() {
    let artifact = Path::new("artifacts/cc_scorer.hlo.txt");
    if !artifact.exists() {
        eprintln!("artifacts/cc_scorer.hlo.txt missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let mut scorer = XlaScorer::load(artifact).expect("loading artifact");
    println!("loaded {} (batch {})", artifact.display(), scorer.batch());

    // (2) bit-identical scoring across the whole occupancy space.
    let masks: Vec<u8> = (0..=255).collect();
    let (ccs, caps) = scorer.score_full(&masks).unwrap();
    for (i, &m) in masks.iter().enumerate() {
        assert_eq!(ccs[i], cc(m), "CC mismatch at {m:08b}");
        assert_eq!(caps[i], profile_capacity(m), "capacity mismatch at {m:08b}");
    }
    println!("scorer parity: all 256 occupancy masks bit-identical to the native table");

    // (3) identical MCC decisions under both backends.
    let workload = Workload::generate(TraceConfig::small(7));
    let run = |scorer: Box<dyn CcScorer>| {
        let mut dc = DataCenter::new(workload.hosts.clone());
        let mut policy = Mcc::new();
        let mut ctx = PolicyCtx::with_scorer(0, scorer);
        let decisions = policy.place_batch(&mut dc, &workload.vms, &mut ctx);
        let placements: Vec<_> =
            workload.vms.iter().map(|vm| dc.locate(vm.id)).collect();
        (decisions, placements)
    };
    let native = run(Box::new(NativeScorer));
    let xla = run(Box::new(XlaScorer::load(artifact).unwrap()));
    assert_eq!(native.0, xla.0, "acceptance decisions diverge");
    assert_eq!(native.1, xla.1, "placements diverge");
    println!(
        "MCC decision parity: {} VMs placed identically under native and XLA scoring",
        native.0.iter().filter(|d| d.is_placed()).count()
    );

    // (4) throughput comparison.
    let batch: Vec<u8> = (0..scorer.batch()).map(|i| (i % 256) as u8).collect();
    let mut native_scorer = NativeScorer;
    let t0 = Instant::now();
    let mut sink = 0u64;
    let native_iters = 2_000;
    for _ in 0..native_iters {
        sink += native_scorer.score(GpuModel::A100_40, &batch).iter().map(|&x| x as u64).sum::<u64>();
    }
    let native_dt = t0.elapsed();
    let t0 = Instant::now();
    let xla_iters = 50;
    for _ in 0..xla_iters {
        sink += scorer.score(GpuModel::A100_40, &batch).iter().map(|&x| x as u64).sum::<u64>();
    }
    let xla_dt = t0.elapsed();
    let native_rate = (native_iters * batch.len()) as f64 / native_dt.as_secs_f64();
    let xla_rate = (xla_iters * batch.len()) as f64 / xla_dt.as_secs_f64();
    println!("\nscorer throughput ({}-config batches):", batch.len());
    println!("  native table lookup: {native_rate:.2e} configs/s");
    println!("  XLA (PJRT CPU):      {xla_rate:.2e} configs/s");
    println!(
        "  ratio: native is {:.0}x faster on CPU — the artifact exists for TPU\n\
         deployment where the MXU batches thousands of GPUs per step; on this\n\
         testbed the native table is the production backend (see DESIGN.md §Perf).",
        native_rate / xla_rate
    );
    std::hint::black_box(sink);
}
