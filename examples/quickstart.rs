//! Quickstart: the GRMU public API in ~60 lines.
//!
//! Builds a 3-host data center, routes a handful of MIG-enabled VM
//! requests through GRMU, prints each typed placement decision — the
//! chosen GPU or the [`RejectReason`] — with the GPU block maps
//! (Fig. 2-style), and shows the CC metric and defragmentation in action.
//!
//! Run: `cargo run --release --example quickstart`

use grmu::cluster::{DataCenter, Host, VmSpec};
use grmu::mig::Profile;
use grmu::policies::grmu::{Grmu, GrmuConfig};
use grmu::policies::{Decision, Policy, PolicyCtx, RejectReason};

fn vm(id: u64, profile: Profile) -> VmSpec {
    VmSpec { id, profile, cpus: 4, ram_gb: 16, arrival: 0, departure: 3_600_000, weight: 1.0 }
}

fn print_cluster(dc: &DataCenter) {
    for host in dc.hosts() {
        for (g, gpu) in host.gpus().iter().enumerate() {
            println!(
                "  host {} gpu {}: [{}] CC={:<2} frag={:.2}",
                host.id,
                g,
                gpu.block_map(),
                gpu.cc(),
                grmu::mig::fragmentation::gpu_fragmentation(gpu),
            );
        }
    }
}

fn main() {
    // A small data center: 3 hosts × 2 A100s.
    let mut dc = DataCenter::new((0..3).map(|i| Host::new(i, 64, 256, 2)).collect());

    // GRMU with a 33% heavy-basket quota (2 of 6 GPUs may serve 7g.40gb).
    let mut policy = Grmu::new(GrmuConfig {
        heavy_capacity_frac: 0.34,
        consolidation_interval_hours: Some(1),
        ..GrmuConfig::default()
    });
    let mut ctx = PolicyCtx::new(0);

    // A mixed batch: two whole-GPU requests plus assorted slices.
    let batch = vec![
        vm(1, Profile::P7g40gb),
        vm(2, Profile::P7g40gb),
        vm(3, Profile::P7g40gb), // exceeds the heavy quota -> QuotaDenied
        vm(4, Profile::P3g20gb),
        vm(5, Profile::P2g10gb),
        vm(6, Profile::P1g5gb),
        vm(7, Profile::P1g5gb),
    ];
    let decisions = policy.place_batch(&mut dc, &batch, &mut ctx);
    println!("placement decisions:");
    for (vm, decision) in batch.iter().zip(&decisions) {
        match decision {
            Decision::Placed { gpu, placement } => println!(
                "  VM {} ({:<8}) -> host {} gpu {} start {}",
                vm.id,
                vm.profile.name(),
                gpu.host,
                gpu.gpu,
                placement.start
            ),
            Decision::Rejected(reason) => {
                println!("  VM {} ({:<8}) -> REJECTED ({reason})", vm.id, vm.profile.name())
            }
        }
    }
    assert_eq!(decisions[2], Decision::Rejected(RejectReason::QuotaDenied));
    println!("\ncluster state (block maps; digit = compute engines of the instance):");
    print_cluster(&dc);

    // Departures free capacity that later requests reuse.
    dc.remove(5);
    dc.remove(7);
    println!("\nafter VMs 5 and 7 depart:");
    print_cluster(&dc);
    let retry = vec![vm(8, Profile::P4g20gb), vm(9, Profile::P4g20gb)];
    ctx.now = 3_600;
    let decisions = policy.place_batch(&mut dc, &retry, &mut ctx);
    println!(
        "\nretry batch accepted: {:?}",
        decisions.iter().map(|d| d.is_placed()).collect::<Vec<_>>()
    );
    print_cluster(&dc);

    let (active, total) = dc.active_hardware();
    println!("\nactive hardware (strict rule): {active}/{total} units");
    dc.check_integrity().expect("datacenter consistent");
    println!("integrity check: OK");

    // --- §7.1's defragmentation worked example, in isolation ---------
    // Two 1g.5gb instances land on blocks 6 and 4 (Algorithm 1). When
    // the block-6 tenant departs, the survivor is stranded at block 4 —
    // a suboptimal arrangement. The migration-planner layer plans an
    // atomic re-pack (applied transactionally via `apply_plan`) that
    // moves it back to 6, reported as a first-class MigrationEvent with
    // a block-weighted cost.
    use grmu::cluster::GpuRef;
    use grmu::mig::placement::assign;
    use grmu::migrate::{defrag, PlanScope};
    use std::collections::BTreeSet;

    println!("\n§7.1 defragmentation example:");
    let mut dc2 = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
    let r = GpuRef { host: 0, gpu: 0 };
    for id in [100u64, 101] {
        let spec = vm(id, Profile::P1g5gb);
        let placement = {
            let mut probe = dc2.gpu(r).clone();
            assign(&mut probe, id, Profile::P1g5gb).unwrap()
        };
        dc2.place(&spec, r, placement);
    }
    dc2.remove(100); // the block-6 tenant departs
    println!("  before: [{}] CC={}", dc2.gpu(r).block_map(), dc2.gpu(r).cc());
    let basket: BTreeSet<GpuRef> = [r].into_iter().collect();
    let moves = defrag::defragment(&mut dc2, PlanScope::Set(&basket), true);
    println!(
        "  after:  [{}] CC={}  ({} intra-GPU migration, cost {}: {:?})",
        dc2.gpu(r).block_map(),
        dc2.gpu(r).cc(),
        moves.len(),
        moves.iter().map(|m| m.cost()).sum::<u64>(),
        moves
    );
    assert_eq!(dc2.locate(101).unwrap().placement.start, 6);
}
