//! §5.1 reproduction: exhaustive MIG configuration-space analysis.
//!
//! Regenerates every statistic of the paper's §5.1 (723 configurations,
//! 78 maximal, 482/67% suboptimal arrangements, default-policy
//! reachability, the per-profile-capacity "improvable" analyses, and the
//! 261,726-pair two-GPU sweep), plus Fig. 3 / Table 3: a same-CC pair of
//! arrangements with different per-profile capacities.
//!
//! Run: `cargo run --release --example config_space_analysis`

use grmu::mig::config_space::{
    analyze, enumerate_all, group_by_multiset, occupancy_of, TieBreak,
};
use grmu::mig::gpu::{cc, profile_capacity};
use grmu::mig::profiles::ALL_PROFILES;

fn main() {
    let t0 = std::time::Instant::now();
    let stats = analyze(true);
    println!("§5.1 configuration-space analysis ({:.2}s)\n", t0.elapsed().as_secs_f64());
    let pct = |a: usize, b: usize| 100.0 * a as f64 / b.max(1) as f64;

    println!("{:<44} {:>9} {:>9}", "statistic", "paper", "measured");
    let rows: Vec<(&str, String, String)> = vec![
        ("unique single-GPU configurations", "723".into(), stats.total.to_string()),
        ("maximal (terminal) configurations", "78".into(), stats.maximal.to_string()),
        (
            "suboptimal arrangements",
            "482 (67%)".into(),
            format!("{} ({:.0}%)", stats.suboptimal, pct(stats.suboptimal, stats.total)),
        ),
        (
            "default-policy reachable (first tie)",
            "248".into(),
            stats.default_reachable.to_string(),
        ),
        (
            "  of which suboptimal",
            "172 (69%)".into(),
            format!(
                "{} ({:.0}%)",
                stats.default_reachable_suboptimal,
                pct(stats.default_reachable_suboptimal, stats.default_reachable)
            ),
        ),
        (
            "default-policy reachable (all CC ties)",
            "—".into(),
            stats.default_reachable_all_ties.to_string(),
        ),
        (
            "improvable single-GPU configurations",
            "138 (19%)".into(),
            format!("{} ({:.0}%)", stats.improvable, pct(stats.improvable, stats.total)),
        ),
        ("two-GPU configurations", "261,726".into(), stats.two_gpu_total.to_string()),
        (
            "improvable two-GPU configurations",
            "205,575 (79%)".into(),
            format!(
                "{} ({:.0}%)",
                stats.two_gpu_improvable,
                pct(stats.two_gpu_improvable, stats.two_gpu_total)
            ),
        ),
    ];
    for (name, paper, measured) in rows {
        println!("{name:<44} {paper:>9} {measured:>9}");
    }
    println!(
        "\nnote: the 248/172 reachability claim does not reproduce under any\n\
         Algorithm 1 tie-breaking we tried (first/last/all-maximal give\n\
         179/179/297); every other §5.1 statistic matches exactly.\n"
    );

    // Fig. 3 / Table 3: find a same-profile same-CC pair of arrangements
    // with different per-profile capacity.
    let configs = enumerate_all();
    let groups = group_by_multiset(&configs);
    'outer: for members in groups.values() {
        for (i, &a) in members.iter().enumerate() {
            for &b in &members[i + 1..] {
                let (oa, ob) = (occupancy_of(a), occupancy_of(b));
                if cc(oa) == cc(ob) && profile_capacity(oa) != profile_capacity(ob) {
                    println!("Fig. 3 / Table 3 — same CC, different per-profile capacity:");
                    println!("  occupancy A: {oa:08b}  occupancy B: {ob:08b}  CC = {}", cc(oa));
                    println!("  {:<10} {:>10} {:>12}", "profile", "original", "alternative");
                    let (ca, cb) = (profile_capacity(oa), profile_capacity(ob));
                    for (p, prof) in ALL_PROFILES.iter().enumerate() {
                        println!("  {:<10} {:>10} {:>12}", prof.name(), ca[p], cb[p]);
                    }
                    break 'outer;
                }
            }
        }
    }

    // Reachability under each tie-break, for the record.
    for tie in [TieBreak::First, TieBreak::Last, TieBreak::AllMaximal] {
        let n = grmu::mig::config_space::default_policy_reachable(tie).len();
        println!("reachable under {tie:?}: {n}");
    }
}
