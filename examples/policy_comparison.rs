//! End-to-end driver: the paper's full §8.3 evaluation on one workload.
//!
//! Generates the Alibaba-2023-like trace (1,213 hosts / ~8,100 MIG VMs at
//! full scale), replays it through all five policies, regenerates
//! Figs. 10–12 + Table 6 + the §8.3.3 migration summary, and checks the
//! paper's headline claims directionally:
//!
//! * GRMU has the highest overall acceptance; MCC is second.
//! * GRMU activates the least hardware (lowest Table 6 AUC).
//! * Only GRMU migrates, and for only ~1% of accepted VMs.
//!
//! Run: `cargo run --release --example policy_comparison [-- --quick]`
//! Results are recorded in EXPERIMENTS.md.

use grmu::report::{experiments, tables};
use grmu::trace::Workload;
use grmu::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = if args.flag("quick") {
        experiments::ExperimentConfig::quick(args.num_or("seed", 42))
    } else {
        let mut c = experiments::ExperimentConfig::default();
        c.trace.seed = args.num_or("seed", 42);
        c
    };
    let workload = Workload::generate(cfg.trace.clone());
    println!(
        "workload: {} hosts / {} GPUs / {} VMs (seed {})\n",
        workload.hosts.len(),
        workload.num_gpus(),
        workload.vms.len(),
        cfg.trace.seed
    );
    println!("{}", tables::fig5(&workload.report.profile_counts));

    let t0 = std::time::Instant::now();
    let results = experiments::policy_comparison(&workload, &cfg);
    println!("simulated 5 policies in {:.2}s\n", t0.elapsed().as_secs_f64());

    println!("{}", tables::fig10(&results));
    println!("{}", tables::fig11(&results));
    println!("{}", tables::fig12(&results));
    println!("{}", tables::table6(&results));
    println!("{}", tables::migrations_summary(&results));

    // Headline checks (directional, not absolute — synthetic trace).
    let by_name = |n: &str| results.iter().find(|r| r.policy == n).unwrap();
    let (ff, mcc, grmu) = (by_name("FF"), by_name("MCC"), by_name("GRMU"));

    println!("headline claims (paper → measured):");
    println!(
        "  GRMU vs MCC acceptance:   +22%  → {:+.1}%",
        100.0 * (grmu.overall_acceptance() / mcc.overall_acceptance() - 1.0)
    );
    println!(
        "  GRMU vs FF  acceptance:   +39%  → {:+.1}%",
        100.0 * (grmu.overall_acceptance() / ff.overall_acceptance() - 1.0)
    );
    println!(
        "  GRMU vs FF  active hw:    -17%  → {:+.1}%  (Table 6 AUC)",
        100.0 * (grmu.active_auc() / ff.active_auc() - 1.0)
    );
    println!(
        "  GRMU migration share:      ~1%  → {:.2}%",
        100.0 * grmu.migration_share()
    );

    let mut ok = true;
    let mut check = |name: &str, cond: bool| {
        println!("  [{}] {}", if cond { "PASS" } else { "FAIL" }, name);
        ok &= cond;
    };
    println!("\ndirectional assertions:");
    check("GRMU beats every baseline on overall acceptance", {
        results.iter().all(|r| r.policy == "GRMU" || r.overall_acceptance() < grmu.overall_acceptance())
    });
    check("MCC is the best baseline", {
        results
            .iter()
            .filter(|r| r.policy != "GRMU" && r.policy != "MCC")
            .all(|r| r.overall_acceptance() <= mcc.overall_acceptance())
    });
    check("GRMU activates the least hardware (min AUC)", {
        results.iter().all(|r| r.policy == "GRMU" || grmu.active_auc() < r.active_auc())
    });
    check("only GRMU migrates", {
        results.iter().all(|r| r.policy == "GRMU" || r.migrations() == 0)
    });
    check("GRMU migration share below 2%", grmu.migration_share() < 0.02);
    check("GRMU loses to MCC on 7g.40gb (quota effect)", {
        let h = grmu::mig::Profile::P7g40gb.index();
        grmu.per_profile_acceptance()[h] < mcc.per_profile_acceptance()[h]
    });
    std::process::exit(if ok { 0 } else { 1 });
}
