//! Validate the heuristics against the exact ILP (Eq. 3–26) on small
//! instances.
//!
//! The paper argues the ILP is intractable at data-center scale and never
//! solves it; on *small* instances our branch-and-bound solver is exact,
//! so we can measure how far GRMU and FF fall from the true optimum —
//! and confirm the invariant that no heuristic ever beats the ILP bound.
//!
//! Run: `cargo run --release --example ilp_validation`

use grmu::cluster::{DataCenter, Host, VmSpec};
use grmu::ilp::model::{IlpHost, PlacementInstance};
use grmu::ilp::IlpSolver;
use grmu::mig::profiles::ALL_PROFILES;
use grmu::policies::{Policy, PolicyConfig, PolicyCtx, PolicyRegistry};
use grmu::util::rng::Rng;
use std::collections::HashMap;

fn random_instance(rng: &mut Rng, hosts: usize, gpus: usize, vms: usize) -> PlacementInstance {
    let host = IlpHost { cpus: 64, ram_gb: 256, num_gpus: gpus, weight: 1.0 };
    let vms = (0..vms)
        .map(|i| VmSpec {
            id: i as u64 + 1,
            profile: *rng.pick(&ALL_PROFILES),
            cpus: rng.range_inclusive(1, 8) as u32,
            ram_gb: rng.range_inclusive(4, 32) as u32,
            arrival: 0,
            departure: 1_000,
            weight: 1.0,
        })
        .collect();
    PlacementInstance { hosts: vec![host; hosts], vms, prior: HashMap::new() }
}

fn heuristic_accepted(name: &str, inst: &PlacementInstance) -> u64 {
    let hosts: Vec<Host> = inst
        .hosts
        .iter()
        .enumerate()
        .map(|(i, h)| Host::new(i as u32, h.cpus, h.ram_gb, h.num_gpus))
        .collect();
    let mut dc = DataCenter::new(hosts);
    let mut policy = PolicyRegistry::standard()
        .build(name, &PolicyConfig::new().heavy_frac(0.34))
        .unwrap();
    let mut ctx = PolicyCtx::default();
    policy.place_batch(&mut dc, &inst.vms, &mut ctx).iter().filter(|d| d.is_placed()).count()
        as u64
}

fn main() {
    let mut rng = Rng::new(2026);
    let trials = 20;
    let mut ilp_total = 0.0;
    let mut grmu_total = 0u64;
    let mut ff_total = 0u64;
    let mut grmu_optimal = 0usize;
    let mut ff_optimal = 0usize;
    let mut nodes_total = 0usize;

    println!(
        "{:>5} {:>6} {:>6} {:>6} {:>8} {:>8}",
        "trial", "VMs", "ILP", "GRMU", "FF", "B&B nodes"
    );
    for trial in 0..trials {
        // ≥2 GPUs so GRMU's dual-basket split is non-degenerate; ≤4 VMs
        // keeps each exact solve in the sub-second-to-seconds range.
        let hosts = 1 + (trial % 2);
        let gpus = 2;
        let n_vms = 3 + (trial % 2);
        let inst = random_instance(&mut rng, hosts, gpus, n_vms);
        let solution = IlpSolver::new(inst.clone()).solve().expect("feasible (empty is)");
        let ilp = solution.acceptance;
        let grmu_acc = heuristic_accepted("grmu", &inst);
        let ff_acc = heuristic_accepted("ff", &inst);
        nodes_total += solution.nodes;
        println!(
            "{:>5} {:>6} {:>6.0} {:>6} {:>8} {:>8}",
            trial, n_vms, ilp, grmu_acc, ff_acc, solution.nodes
        );
        assert!(
            grmu_acc as f64 <= ilp + 1e-6,
            "heuristic exceeded the exact optimum — model bug"
        );
        assert!(ff_acc as f64 <= ilp + 1e-6);
        ilp_total += ilp;
        grmu_total += grmu_acc;
        ff_total += ff_acc;
        if (grmu_acc as f64 - ilp).abs() < 1e-6 {
            grmu_optimal += 1;
        }
        if (ff_acc as f64 - ilp).abs() < 1e-6 {
            ff_optimal += 1;
        }
    }
    println!("\nacross {trials} random small instances:");
    println!("  ILP optimal acceptance total: {ilp_total:.0}");
    println!(
        "  GRMU: {grmu_total} ({:.1}% of optimal), optimal in {grmu_optimal}/{trials} instances",
        100.0 * grmu_total as f64 / ilp_total
    );
    println!(
        "  FF:   {ff_total} ({:.1}% of optimal), optimal in {ff_optimal}/{trials} instances",
        100.0 * ff_total as f64 / ilp_total
    );
    println!("  branch-and-bound nodes total: {nodes_total}");
}
