"""AOT export: lower the L2 scoring graph to HLO **text** for the Rust
runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under ``artifacts/``):

* ``cc_scorer.hlo.txt``   — ``score(occ: f32[B, 8]) -> (f32[B], f32[B, 6])``
  lowered with ``return_tuple=True`` (the Rust side unwraps the tuple).
* ``cc_scorer.meta.json`` — the batch size and output names the Rust
  loader validates against.

Usage: ``python -m compile.aot --out ../artifacts/cc_scorer.hlo.txt``
(from ``python/``; the Makefile drives this).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

DEFAULT_BATCH = 1024


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides the 18×8 placement-mask and 18×6 grouping constants as
    ``{...}``, which the Rust-side parser silently reads as zeros — every
    placement then looks feasible (CC = 18 everywhere) and capacities
    collapse to zero.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export(out_path: str, batch: int = DEFAULT_BATCH, tile: int | None = None) -> dict:
    spec = jax.ShapeDtypeStruct((batch, 8), jnp.float32)
    if tile is None:
        fn = model.score
    else:
        from compile.kernels.cc_kernel import score_configs

        def fn(occ):
            return score_configs(occ, tile=tile)

    lowered = jax.jit(fn).lower(spec)
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        f.write(text)
    meta = {
        "batch": batch,
        "inputs": [{"name": "occ", "shape": [batch, 8], "dtype": "f32"}],
        "outputs": [
            {"name": "cc", "shape": [batch], "dtype": "f32"},
            {"name": "capacity", "shape": [batch, 6], "dtype": "f32"},
        ],
    }
    meta_path = os.path.splitext(out_path)[0]
    meta_path = meta_path[: -len(".hlo")] if meta_path.endswith(".hlo") else meta_path
    meta_path += ".meta.json"
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    return {"hlo": out_path, "meta": meta_path, "chars": len(text)}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts/cc_scorer.hlo.txt")
    parser.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    parser.add_argument(
        "--tile",
        type=int,
        default=None,
        help="VMEM tile (default: auto, capped at 256). --tile == --batch "
        "collapses the Pallas grid to one step — measurably faster on the "
        "CPU PJRT backend (see EXPERIMENTS.md §Perf).",
    )
    args = parser.parse_args()
    info = export(args.out, args.batch, args.tile)
    print(f"wrote {info['chars']} chars to {info['hlo']} (+ {info['meta']})")


if __name__ == "__main__":
    main()
