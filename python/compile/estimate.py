"""Analytic TPU resource estimate for the L1 CC-scorer kernel.

``interpret=True`` timings are CPU-numpy and say nothing about TPU
performance, so the §Perf methodology is analytic: compute the VMEM
footprint and MXU utilization of the kernel per BlockSpec tile and find
the tile size where the kernel stops being launch-bound without
spilling VMEM.

Model (per grid step, dtype f32 unless noted):

* inputs resident in VMEM: occupancy tile ``(T, 8)``, placement matrix
  ``(18, 8)``, grouping matrix ``(18, 6)``;
* intermediates: overlap/feasible ``(T, 18)``;
* outputs: cc ``(T,)``, capacity ``(T, 6)``;
* FLOPs: the two matmuls — ``2·T·8·18`` and ``2·T·18·6``;
* MXU: a 128×128 systolic array at ``MXU_FLOPS`` peak; the contraction
  dims (8 and 18) underfill the array, so effective peak is scaled by
  ``min(K,128)/128`` per matmul — the kernel is *bandwidth-bound* by
  design and the target is HBM-roofline share, not MXU share.

Usage: ``python -m compile.estimate [--tiles 64,256,1024,4096]``
"""

from __future__ import annotations

import argparse
from dataclasses import dataclass

VMEM_BYTES = 16 * 2**20  # v4-class core VMEM
HBM_GBPS = 1_200.0  # v4-class HBM bandwidth
MXU_TFLOPS = 275.0  # bf16 peak; f32 ≈ half


@dataclass
class TileEstimate:
    tile: int
    vmem_bytes: int
    vmem_frac: float
    flops: int
    hbm_bytes: int
    arithmetic_intensity: float
    mxu_util: float
    roofline_time_us: float
    configs_per_sec: float


def estimate(tile: int, dtype_bytes: int = 4) -> TileEstimate:
    t = tile
    # Resident buffers per grid step.
    occ = t * 8 * dtype_bytes
    placements = 18 * 8 * dtype_bytes
    groups = 18 * 6 * dtype_bytes
    feasible = t * 18 * dtype_bytes
    cc = t * dtype_bytes
    cap = t * 6 * dtype_bytes
    vmem = occ + placements + groups + feasible + cc + cap

    flops = 2 * t * 8 * 18 + 2 * t * 18 * 6
    # HBM traffic: stream occ in, cc+cap out (P/G pinned across steps).
    hbm = occ + cc + cap
    intensity = flops / hbm

    # MXU effective peak limited by the contraction dim (K=8 then K=18).
    peak = MXU_TFLOPS * 1e12 / 2  # f32
    eff_peak = peak * ((8 / 128) * 0.5 + (18 / 128) * 0.5)
    compute_time = flops / eff_peak
    memory_time = hbm / (HBM_GBPS * 1e9)
    time = max(compute_time, memory_time)
    mxu_util = flops / (time * peak)

    return TileEstimate(
        tile=tile,
        vmem_bytes=vmem,
        vmem_frac=vmem / VMEM_BYTES,
        flops=flops,
        hbm_bytes=hbm,
        arithmetic_intensity=intensity,
        mxu_util=mxu_util,
        roofline_time_us=time * 1e6,
        configs_per_sec=tile / time,
    )


def report(tiles: list[int]) -> str:
    lines = [
        f"{'tile':>6} {'VMEM':>10} {'VMEM%':>7} {'AI (fl/B)':>10} "
        f"{'MXU util':>9} {'roofline µs':>12} {'configs/s':>12}"
    ]
    for t in tiles:
        e = estimate(t)
        lines.append(
            f"{e.tile:>6} {e.vmem_bytes:>10} {100 * e.vmem_frac:>6.2f}% "
            f"{e.arithmetic_intensity:>10.2f} {100 * e.mxu_util:>8.3f}% "
            f"{e.roofline_time_us:>12.3f} {e.configs_per_sec:>12.3e}"
        )
    return "\n".join(lines)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--tiles", default="64,256,1024,4096,16384")
    args = parser.parse_args()
    tiles = [int(x) for x in args.tiles.split(",")]
    print(report(tiles))
    best = max((estimate(t) for t in tiles), key=lambda e: e.configs_per_sec)
    print(
        f"\nkernel is memory-bound (AI ≈ {best.arithmetic_intensity:.1f} FLOP/B "
        f"< MXU knee); VMEM permits tiles up to "
        f"~{int(VMEM_BYTES / (estimate(1024).vmem_bytes / 1024))} rows."
    )


if __name__ == "__main__":
    main()
