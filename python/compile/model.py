"""Layer 2 — the JAX scoring graph exported to the Rust coordinator.

Composes the L1 Pallas kernel into the functions the coordinator needs on
its decision path:

* :func:`score` — batched ``(cc, capacity)`` of occupancy vectors; this is
  what MCC consumes (Algorithm 6's ``GetCC`` over every candidate GPU).
* :func:`score_ecc` — capacity contracted with profile probabilities
  (Algorithm 7's ``GetECC``) for MECC.
* :func:`assign_best_start` — Algorithm 1 in tensor form: for a requested
  profile, feasibility-test every start, score each resulting occupancy
  and pick the CC-maximizing start (first maximal start on ties, matching
  the driver behaviour and the Rust implementation bit-for-bit).

Only :func:`score` is AOT-exported (``aot.py``): ECC is a dot product the
coordinator does natively from ``capacity``, and the argmax of
``assign_best_start`` is cheaper in Rust than a second artifact. The
function is still part of the build-time test surface because it documents
the exact tensor semantics of the native hot path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.cc_kernel import NUM_BLOCKS, PROFILES, auto_tile, score_configs


def score(occ: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(cc, capacity) for a (B, 8) occupancy batch — the AOT entry point."""
    return score_configs(occ, tile=auto_tile(occ.shape[0]))


def score_ecc(occ: jax.Array, probs: jax.Array) -> jax.Array:
    """Algorithm 7: expected CC under per-profile probabilities (B,)."""
    _, cap = score_configs(occ, tile=auto_tile(occ.shape[0]))
    return cap @ probs.astype(cap.dtype)


def _profile_start_table() -> tuple[jnp.ndarray, jnp.ndarray]:
    """(6, 7) legal-start flags and (6, 7, 8) candidate placement masks.

    Row p lists up to 7 candidate starts for profile p (padded with
    zeros); ``legal[p, s_idx]`` marks real entries.
    """
    import numpy as np

    legal = np.zeros((len(PROFILES), 7), dtype=np.float32)
    masks = np.zeros((len(PROFILES), 7, NUM_BLOCKS), dtype=np.float32)
    for p, (_, size, starts) in enumerate(PROFILES):
        for s_idx, start in enumerate(starts):
            legal[p, s_idx] = 1.0
            masks[p, s_idx, start : start + size] = 1.0
    return jnp.asarray(legal), jnp.asarray(masks)


def assign_best_start(occ: jax.Array, profile_index: int) -> tuple[jax.Array, jax.Array]:
    """Algorithm 1 in tensor form over a (B, 8) batch.

    Returns ``(start_idx, feasible)``: per row, the index into the
    profile's start list maximizing post-allocation CC (first max on
    ties), and whether any start fits.
    """
    legal, masks = _profile_start_table()
    cand_masks = masks[profile_index]  # (7, 8)
    cand_legal = legal[profile_index]  # (7,)
    batch = occ.shape[0]
    # Candidate occupancies: (B, 7, 8); infeasible where blocks overlap.
    overlap = jnp.einsum("bk,sk->bs", occ, cand_masks)
    fits = (overlap == 0.0) & (cand_legal > 0.0)  # (B, 7)
    new_occ = jnp.clip(occ[:, None, :] + cand_masks[None, :, :], 0.0, 1.0)
    # tile=7 always divides the flattened batch*7 candidate rows.
    cc, _ = score_configs(new_occ.reshape(batch * 7, NUM_BLOCKS), tile=7)
    cc = cc.reshape(batch, 7)
    cc = jnp.where(fits, cc, -1.0)
    # First maximal start: argmax returns the first index on ties.
    start_idx = jnp.argmax(cc, axis=1)
    feasible = jnp.any(fits, axis=1)
    return start_idx, feasible
