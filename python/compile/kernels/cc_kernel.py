"""Layer 1 — the batched Configuration-Capability scorer as a Pallas kernel.

The placement hot-spot of the paper is scoring GPU block configurations:
MCC/MECC evaluate the post-allocation CC (Eq. 1) of *every* GPU in a
1,213-host data center for every request. On TPU hardware that scoring
maps naturally onto the MXU: a configuration is an 8-lane occupancy
vector, the 18 legal ``(profile, start)`` placements form a static
``18x8`` 0/1 mask matrix ``P``, and a placement fits iff its mask shares
no block with the occupancy — i.e. iff ``(occ @ P.T) == 0``. One batched
matmul feasibility-tests all 18 placements for a whole tile of GPUs;
grouped reductions then give CC and the per-profile capacities.

VMEM/BlockSpec plan (DESIGN.md "Hardware adaptation"): the batch dimension
is tiled into ``TILE``-row blocks resident in VMEM; ``P`` (18x8) and the
placement-to-profile matrix ``G`` (18x6) are tiny and pinned in VMEM for
every grid step. All arithmetic is exact in float32 **and** bfloat16
(counts <= 18), so the kernel can feed the MXU in its native dtype.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; the interpreted kernel lowers to plain HLO and is what the
AOT artifact ships. Real-TPU performance is estimated analytically in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# The six profiles in Algorithm 1 table order: (size_blocks, start_blocks).
PROFILES = (
    ("1g.5gb", 1, (0, 1, 2, 3, 4, 5, 6)),
    ("1g.10gb", 2, (0, 2, 4, 6)),
    ("2g.10gb", 2, (0, 2, 4)),
    ("3g.20gb", 4, (0, 4)),
    ("4g.20gb", 4, (0,)),
    ("7g.40gb", 8, (0,)),
)

NUM_BLOCKS = 8
NUM_PROFILES = len(PROFILES)


def placement_tables() -> tuple[np.ndarray, np.ndarray]:
    """The static (18, 8) placement-mask matrix ``P`` and the (18, 6)
    placement-to-profile one-hot matrix ``G``."""
    masks, groups = [], []
    for p_idx, (_, size, starts) in enumerate(PROFILES):
        for start in starts:
            row = np.zeros(NUM_BLOCKS, dtype=np.float32)
            row[start : start + size] = 1.0
            masks.append(row)
            onehot = np.zeros(NUM_PROFILES, dtype=np.float32)
            onehot[p_idx] = 1.0
            groups.append(onehot)
    P = np.stack(masks)  # noqa: N806
    G = np.stack(groups)  # noqa: N806
    assert P.shape == (18, NUM_BLOCKS)
    assert G.shape == (18, NUM_PROFILES)
    return P, G


def _cc_kernel(occ_ref, p_ref, g_ref, cc_ref, cap_ref):
    """One grid step: score a (TILE, 8) occupancy block.

    occ is 0/1 with 1 = block occupied. A placement is feasible iff the
    overlap count ``occ · mask`` is exactly zero.
    """
    occ = occ_ref[...]
    placements = p_ref[...]
    overlap = jnp.dot(occ, placements.T, preferred_element_type=jnp.float32)
    feasible = (overlap == 0.0).astype(jnp.float32)  # (TILE, 18)
    cc_ref[...] = jnp.sum(feasible, axis=-1)
    cap_ref[...] = jnp.dot(feasible, g_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile",))
def score_configs(occ: jax.Array, tile: int = 256) -> tuple[jax.Array, jax.Array]:
    """Batched CC + per-profile capacity of occupancy vectors.

    Args:
      occ: (B, 8) array, 1.0 where the memory block is occupied. B must be
        a multiple of ``tile`` (the AOT wrapper pads).
      tile: batch tile held in VMEM per grid step.

    Returns:
      ``(cc, cap)``: (B,) CC values and (B, 6) per-profile feasible-start
      counts, both float32.
    """
    batch = occ.shape[0]
    if batch % tile != 0:
        raise ValueError(f"batch {batch} not a multiple of tile {tile}")
    p_np, g_np = placement_tables()
    p = jnp.asarray(p_np, dtype=occ.dtype)
    g = jnp.asarray(g_np, dtype=occ.dtype)
    grid = (batch // tile,)
    cc, cap = pl.pallas_call(
        _cc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, NUM_BLOCKS), lambda i: (i, 0)),
            pl.BlockSpec((18, NUM_BLOCKS), lambda i: (0, 0)),
            pl.BlockSpec((18, NUM_PROFILES), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile, NUM_PROFILES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch,), jnp.float32),
            jax.ShapeDtypeStruct((batch, NUM_PROFILES), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(occ, p, g)
    return cc, cap


def auto_tile(batch: int, cap: int = 256) -> int:
    """Largest divisor of ``batch`` not exceeding ``cap`` (VMEM budget)."""
    best = 1
    d = 1
    while d * d <= batch:
        if batch % d == 0:
            for cand in (d, batch // d):
                if cand <= cap and cand > best:
                    best = cand
        d += 1
    return best


def masks_to_batch(masks, dtype=jnp.float32) -> jax.Array:
    """Convert an iterable of 8-bit occupancy masks to the (B, 8) input."""
    arr = np.zeros((len(masks), NUM_BLOCKS), dtype=np.float32)
    for i, m in enumerate(masks):
        for b in range(NUM_BLOCKS):
            if m & (1 << b):
                arr[i, b] = 1.0
    return jnp.asarray(arr, dtype=dtype)
