"""Pure-jnp (and pure-Python) correctness oracles for the CC scorer.

Two independent references:

* :func:`score_configs_ref` — the same linear-algebra formulation as the
  kernel, in plain ``jnp`` (catches Pallas-specific bugs: BlockSpec
  indexing, tiling, dtype handling).
* :func:`cc_scalar` / :func:`capacity_scalar` — a from-first-principles
  bit-twiddling implementation of Eq. 1 (catches shared formulation bugs
  in the mask matrices).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .cc_kernel import NUM_BLOCKS, PROFILES, placement_tables


def score_configs_ref(occ: jax.Array) -> tuple[jax.Array, jax.Array]:
    """CC + per-profile capacity via plain jnp (no Pallas)."""
    p_np, g_np = placement_tables()
    placements = jnp.asarray(p_np, dtype=occ.dtype)
    groups = jnp.asarray(g_np, dtype=jnp.float32)
    overlap = occ @ placements.T
    feasible = (overlap == 0.0).astype(jnp.float32)
    return jnp.sum(feasible, axis=-1), feasible @ groups


def _placement_bitmasks() -> list[tuple[int, int]]:
    """(profile_index, bitmask) for all 18 legal placements."""
    out = []
    for p_idx, (_, size, starts) in enumerate(PROFILES):
        for start in starts:
            mask = 0
            for i in range(size):
                mask |= 1 << (start + i)
            out.append((p_idx, mask))
    return out


_BITMASKS = _placement_bitmasks()


def cc_scalar(occ_mask: int) -> int:
    """Eq. 1 from first principles on an 8-bit occupancy mask."""
    return sum(1 for _, m in _BITMASKS if occ_mask & m == 0)


def capacity_scalar(occ_mask: int) -> list[int]:
    """Per-profile feasible-start counts on an 8-bit occupancy mask."""
    caps = [0] * len(PROFILES)
    for p_idx, m in _BITMASKS:
        if occ_mask & m == 0:
            caps[p_idx] += 1
    return caps


def batch_to_masks(occ) -> list[int]:
    """Inverse of ``cc_kernel.masks_to_batch``."""
    out = []
    for row in occ:
        mask = 0
        for b in range(NUM_BLOCKS):
            if float(row[b]) != 0.0:
                mask |= 1 << b
        out.append(mask)
    return out
