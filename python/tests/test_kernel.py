"""L1 correctness: the Pallas kernel vs the pure-jnp and scalar oracles.

Hypothesis sweeps occupancy masks, batch shapes and dtypes; fixed tests
pin the paper's worked examples.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.cc_kernel import (
    NUM_BLOCKS,
    PROFILES,
    masks_to_batch,
    placement_tables,
    score_configs,
)
from compile.kernels.ref import capacity_scalar, cc_scalar, score_configs_ref


def run_kernel(masks, tile=None, dtype=jnp.float32):
    occ = masks_to_batch(masks, dtype=dtype)
    if tile is None:
        tile = occ.shape[0]
    cc, cap = score_configs(occ, tile=tile)
    return np.asarray(cc), np.asarray(cap)


class TestStaticTables:
    def test_eighteen_placements(self):
        P, G = placement_tables()
        assert P.shape == (18, 8)
        assert G.shape == (18, 6)
        # Each placement maps to exactly one profile.
        assert np.array_equal(G.sum(axis=1), np.ones(18))
        # Mask row sums equal the profile sizes.
        sizes = G @ np.array([s for _, s, _ in PROFILES], dtype=np.float32)
        assert np.array_equal(P.sum(axis=1), sizes)

    def test_instance_counts_match_table1(self):
        _, G = placement_tables()
        per_profile = G.sum(axis=0)
        assert list(per_profile) == [7, 4, 3, 2, 1, 1]


class TestPaperExamples:
    def test_empty_gpu_cc_18(self):
        cc, cap = run_kernel([0x00])
        assert cc[0] == 18.0
        assert list(cap[0]) == [7, 4, 3, 2, 1, 1]

    def test_full_gpu_cc_0(self):
        cc, cap = run_kernel([0xFF])
        assert cc[0] == 0.0
        assert cap[0].sum() == 0.0

    def test_section5_worked_example_cc_9(self):
        # Blocks 0 and 3 occupied -> CC = 9 (5, 2, 1, 1, 0, 0).
        cc, cap = run_kernel([0b0000_1001])
        assert cc[0] == 9.0
        assert list(cap[0]) == [5, 2, 1, 1, 0, 0]

    def test_fig2a_checkerboard(self):
        # Blocks 1,3,5,7 occupied: no 2-block profile fits.
        cc, cap = run_kernel([0b1010_1010])
        assert cap[0][1] == 0  # 1g.10gb
        assert cap[0][2] == 0  # 2g.10gb
        assert cap[0][0] == 4  # 1g.5gb at 0,2,4,6


class TestKernelVsReferences:
    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=255), min_size=1, max_size=64))
    def test_matches_scalar_oracle(self, masks):
        cc, cap = run_kernel(masks)
        for i, m in enumerate(masks):
            assert cc[i] == cc_scalar(m), f"mask {m:08b}"
            assert list(cap[i]) == capacity_scalar(m), f"mask {m:08b}"

    @settings(max_examples=50, deadline=None)
    @given(
        st.lists(st.integers(min_value=0, max_value=255), min_size=1, max_size=48),
        st.sampled_from([jnp.float32, jnp.bfloat16]),
    )
    def test_matches_jnp_reference_across_dtypes(self, masks, dtype):
        occ = masks_to_batch(masks, dtype=dtype)
        cc_k, cap_k = score_configs(occ, tile=occ.shape[0])
        cc_r, cap_r = score_configs_ref(occ)
        np.testing.assert_allclose(np.asarray(cc_k), np.asarray(cc_r), rtol=0, atol=0)
        np.testing.assert_allclose(np.asarray(cap_k), np.asarray(cap_r), rtol=0, atol=0)

    def test_exhaustive_all_256_masks(self):
        masks = list(range(256))
        cc, cap = run_kernel(masks, tile=64)
        for m in masks:
            assert cc[m] == cc_scalar(m)
            assert list(cap[m]) == capacity_scalar(m)


class TestTiling:
    @pytest.mark.parametrize("batch,tile", [(8, 8), (64, 16), (256, 256), (512, 128)])
    def test_tilings_agree(self, batch, tile):
        rng = np.random.default_rng(batch * 1000 + tile)
        masks = rng.integers(0, 256, size=batch).tolist()
        cc_a, cap_a = run_kernel(masks, tile=tile)
        cc_b, cap_b = run_kernel(masks, tile=batch)
        np.testing.assert_array_equal(cc_a, cc_b)
        np.testing.assert_array_equal(cap_a, cap_b)

    def test_non_dividing_tile_rejected(self):
        with pytest.raises(ValueError):
            score_configs(jnp.zeros((10, NUM_BLOCKS), jnp.float32), tile=4)


class TestMonotonicity:
    @settings(max_examples=100, deadline=None)
    @given(
        st.integers(min_value=0, max_value=255),
        st.integers(min_value=0, max_value=7),
    )
    def test_occupying_a_block_never_raises_cc(self, mask, block):
        cc, _ = run_kernel([mask, mask | (1 << block)])
        assert cc[1] <= cc[0]
