"""L2 correctness: the scoring graph's composite functions and the AOT
export surface (shapes, determinism, tuple structure)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels.cc_kernel import masks_to_batch
from compile.kernels.ref import capacity_scalar, cc_scalar


class TestScore:
    def test_shapes(self):
        occ = jnp.zeros((32, 8), jnp.float32)
        cc, cap = model.score(occ)
        assert cc.shape == (32,)
        assert cap.shape == (32, 6)

    def test_deterministic(self):
        occ = masks_to_batch(list(range(64)))
        a = model.score(occ)
        b = model.score(occ)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


class TestEcc:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(min_value=0, max_value=255), min_size=4, max_size=4))
    def test_ecc_is_prob_weighted_capacity(self, masks):
        occ = masks_to_batch(masks)
        probs = jnp.asarray([0.1, 0.05, 0.2, 0.15, 0.1, 0.4], jnp.float32)
        ecc = np.asarray(model.score_ecc(occ, probs))
        for i, m in enumerate(masks):
            expected = float(np.dot(np.asarray(probs), capacity_scalar(m)))
            assert abs(ecc[i] - expected) < 1e-5

    def test_uniform_probs_give_cc_over_6(self):
        occ = masks_to_batch([0b0000_1001])
        probs = jnp.full((6,), 1.0 / 6.0, jnp.float32)
        ecc = float(model.score_ecc(occ, probs)[0])
        assert abs(ecc - cc_scalar(0b0000_1001) / 6.0) < 1e-5


class TestAssignBestStart:
    def _best_start_scalar(self, mask: int, profile_index: int):
        """Algorithm 1 reference: first CC-maximizing start."""
        from compile.kernels.cc_kernel import PROFILES

        _, size, starts = PROFILES[profile_index]
        best = None
        for s_idx, start in enumerate(starts):
            pmask = 0
            for i in range(size):
                pmask |= 1 << (start + i)
            if mask & pmask:
                continue
            cc_val = cc_scalar(mask | pmask)
            if best is None or cc_val > best[1]:
                best = (s_idx, cc_val)
        return best

    def test_first_1g_goes_to_block_6(self):
        # §5.1: the first 1g.5gb on an empty GPU lands on block 6 —
        # start index 6 in the profile's start list (0..6).
        occ = masks_to_batch([0])
        idx, feasible = model.assign_best_start(occ, 0)
        assert bool(feasible[0])
        assert int(idx[0]) == 6

    @settings(max_examples=60, deadline=None)
    @given(
        st.integers(min_value=0, max_value=255),
        st.integers(min_value=0, max_value=5),
    )
    def test_matches_scalar_algorithm1(self, mask, profile_index):
        occ = masks_to_batch([mask])
        idx, feasible = model.assign_best_start(occ, profile_index)
        expected = self._best_start_scalar(mask, profile_index)
        if expected is None:
            assert not bool(feasible[0])
        else:
            assert bool(feasible[0])
            assert int(idx[0]) == expected[0], f"mask {mask:08b} profile {profile_index}"


class TestAotExport:
    def test_export_writes_hlo_text_and_meta(self, tmp_path):
        from compile import aot

        out = tmp_path / "scorer.hlo.txt"
        info = aot.export(str(out), batch=64)
        text = out.read_text()
        assert "HloModule" in text
        assert info["chars"] == len(text)
        import json

        meta = json.loads((tmp_path / "scorer.meta.json").read_text())
        assert meta["batch"] == 64
        assert meta["outputs"][0]["name"] == "cc"

    def test_exported_hlo_mentions_parameter_shape(self, tmp_path):
        from compile import aot

        out = tmp_path / "scorer.hlo.txt"
        aot.export(str(out), batch=32)
        text = out.read_text()
        assert "f32[32,8]" in text.replace(" ", "")

    def test_no_elided_constants(self, tmp_path):
        # Regression guard: the default HLO printer elides the placement
        # matrices as "{...}", which the Rust-side parser reads as zeros.
        from compile import aot

        out = tmp_path / "scorer.hlo.txt"
        aot.export(str(out), batch=32)
        text = out.read_text()
        assert "{...}" not in text
        # The 18x8 placement matrix starts with the 1g.5gb@0 row.
        assert "f32[8,18]" in text.replace(" ", "") or "f32[18,8]" in text.replace(" ", "")
