"""Sanity checks on the analytic TPU resource model (§Perf methodology)."""

from compile.estimate import VMEM_BYTES, estimate, report


def test_vmem_scales_linearly_with_tile():
    a = estimate(256)
    b = estimate(512)
    # Fixed matrices aside, doubling the tile ~doubles VMEM.
    assert 1.8 < b.vmem_bytes / a.vmem_bytes < 2.1


def test_reasonable_tiles_fit_vmem():
    for tile in (64, 256, 1024, 4096):
        e = estimate(tile)
        assert e.vmem_bytes < VMEM_BYTES, f"tile {tile} spills VMEM"
        assert 0 < e.vmem_frac < 1


def test_kernel_is_memory_bound():
    # Arithmetic intensity is far below any MXU roofline knee (~100s
    # FLOP/B): the kernel streams configs and must be judged against the
    # HBM roofline, which is the documented §Perf target.
    e = estimate(1024)
    assert e.arithmetic_intensity < 50
    # Throughput is enormous regardless: > 1e9 configs/s at roofline.
    assert e.configs_per_sec > 1e9


def test_mxu_utilization_low_by_design():
    # K = 8/18 underfills the 128-wide systolic array.
    e = estimate(4096)
    assert e.mxu_util < 0.2


def test_report_renders_all_tiles():
    text = report([64, 256])
    assert "64" in text and "256" in text
    assert "VMEM" in text
