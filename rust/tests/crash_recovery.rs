//! Crash-injection harness for the checkpoint/journal subsystem.
//!
//! The contract under test: a run that is killed at an interval boundary
//! and resumed from its checkpoint directory produces **exactly** the
//! outcome of an uninterrupted twin — same acceptance counters, same
//! per-interval samples, same migration log, same queue and availability
//! books — across policies × shard counts × ops schedules × kill points.
//!
//! A "kill" is simulated by cloning a completed run's checkpoint
//! directory and deleting every snapshot newer than the kill point: the
//! on-disk state is then precisely what a crash at that boundary leaves
//! behind (an older full image plus journal records running past it).
//! Torn writes are simulated by truncating or corrupting snapshot files
//! in place; recovery must fall back to the previous valid image and
//! still converge.

use grmu::cluster::DataCenter;
use grmu::ops::{OpsConfig, QueueConfig};
use grmu::policies::{Policy, PolicyConfig, PolicyRegistry};
use grmu::recover::SnapshotStore;
use grmu::sim::{
    ShardOptions, ShardedSimulation, SimResult, Simulation, SimulationOptions,
};
use grmu::trace::{TraceConfig, Workload};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

fn scratch(tag: &str) -> PathBuf {
    let n = DIR_SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("grmu-crash-{}-{tag}-{n}", std::process::id()))
}

/// Clone a checkpoint directory file-for-file.
fn clone_dir(src: &Path, tag: &str) -> PathBuf {
    let dst = scratch(tag);
    std::fs::create_dir_all(&dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
    dst
}

/// Simulate a crash at interval boundary `kill_hour`: clone the
/// completed run's checkpoint directory and delete every snapshot past
/// the kill point. The journal keeps running past it, as it would after
/// a real crash (records are appended every interval, images only on
/// the cadence).
fn killed_at(full: &Path, kill_hour: u64, tag: &str) -> PathBuf {
    let dir = clone_dir(full, tag);
    let store = SnapshotStore::open(&dir).unwrap();
    for hour in store.hours() {
        if hour > kill_hour {
            std::fs::remove_file(store.path_for(hour)).unwrap();
        }
    }
    assert_eq!(store.hours().last(), Some(&kill_hour), "kill point must survive");
    dir
}

fn small_workload(seed: u64) -> Workload {
    Workload::generate(TraceConfig {
        num_hosts: 16,
        num_pods: 200,
        horizon_hours: 48,
        ..TraceConfig::small(seed)
    })
}

fn build_policy(name: &str) -> Box<dyn Policy> {
    PolicyRegistry::standard()
        .build(name, &PolicyConfig::new().heavy_frac(0.25))
        .unwrap()
}

fn cell_options(ops_on: bool) -> SimulationOptions {
    let (ops, queue) = if ops_on {
        (
            OpsConfig { drain_rate: 1.0, seed: 9, ..OpsConfig::default().with_gpu_mtbf(300.0) },
            QueueConfig { capacity: 8, ..QueueConfig::default() },
        )
    } else {
        (OpsConfig::default(), QueueConfig::default())
    };
    SimulationOptions {
        integrity_every: 4,
        drain_cap_hours: 24,
        ops,
        queue,
        checkpoint_every_hours: 8,
        ..SimulationOptions::default()
    }
}

/// Run one grid cell: `shards == 1` drives the classic single-core
/// engine (`SnapshotKind::Core` images), anything larger the sharded
/// engine (`SnapshotKind::Sharded`).
fn run_cell(
    workload: &Workload,
    policy: &str,
    shards: usize,
    options: SimulationOptions,
) -> SimResult {
    if shards == 1 {
        let mut sim = Simulation::new(
            DataCenter::new(workload.hosts.clone()),
            build_policy(policy),
            &workload.vms,
        );
        sim.options = options;
        sim.run()
    } else {
        let policies: Vec<Box<dyn Policy>> = (0..shards).map(|_| build_policy(policy)).collect();
        let mut sim = ShardedSimulation::new(&workload.hosts, policies, &workload.vms);
        sim.options = options;
        sim.shard_options = ShardOptions { shards, threads: 2, ..ShardOptions::default() };
        sim.run()
    }
}

/// The tentpole lock: every (policy × shard count × ops × kill point)
/// cell resumes to the exact outcome of its uninterrupted twin.
#[test]
fn resume_is_exact_across_policies_shards_ops_and_kill_points() {
    let workload = small_workload(5);
    for policy in ["ff", "mcc", "grmu"] {
        for shards in [1usize, 4] {
            for ops_on in [false, true] {
                let label = format!("{policy}-s{shards}-ops{}", u8::from(ops_on));
                let dir_full = scratch(&label);
                let mut options = cell_options(ops_on);
                options.checkpoint_dir = Some(dir_full.clone());
                let reference = run_cell(&workload, policy, shards, options);

                let hours = SnapshotStore::open(&dir_full).unwrap().hours();
                assert!(hours.len() >= 3, "{label}: too few snapshots: {hours:?}");
                // Early and mid-run kill points exercise both a long and
                // a short re-drive window.
                for kill in [hours[0], hours[hours.len() / 2]] {
                    let crashed = killed_at(&dir_full, kill, &format!("{label}-k{kill}"));
                    let mut options = cell_options(ops_on);
                    options.resume_from = Some(crashed.clone());
                    let resumed = run_cell(&workload, policy, shards, options);
                    assert!(
                        resumed.same_outcome(&reference),
                        "{label}: resume from hour {kill} diverged from the \
                         uninterrupted run"
                    );
                    std::fs::remove_dir_all(&crashed).unwrap();
                }
                std::fs::remove_dir_all(&dir_full).unwrap();
            }
        }
    }
}

/// A torn newest snapshot (truncated mid-write, as a crash without the
/// atomic rename would leave it) is skipped by checksum: recovery falls
/// back to the previous valid image and still converges exactly.
#[test]
fn torn_newest_snapshot_falls_back_to_previous_and_converges() {
    let workload = small_workload(7);
    let dir_full = scratch("torn-full");
    let mut options = cell_options(true);
    options.checkpoint_dir = Some(dir_full.clone());
    let reference = run_cell(&workload, "grmu", 1, options);

    let hours = SnapshotStore::open(&dir_full).unwrap().hours();
    assert!(hours.len() >= 2, "need a fallback image: {hours:?}");
    let crashed = clone_dir(&dir_full, "torn-crashed");
    let store = SnapshotStore::open(&crashed).unwrap();
    let newest = *hours.last().unwrap();
    let bytes = std::fs::read(store.path_for(newest)).unwrap();
    std::fs::write(store.path_for(newest), &bytes[..bytes.len() / 2]).unwrap();

    // The torn file is present but unreadable; the previous image wins.
    let (fallback_hour, _, _) = store.latest_valid().unwrap();
    assert_eq!(fallback_hour, hours[hours.len() - 2], "torn newest must be skipped");

    let mut options = cell_options(true);
    options.resume_from = Some(crashed.clone());
    let resumed = run_cell(&workload, "grmu", 1, options);
    assert!(
        resumed.same_outcome(&reference),
        "resume from the fallback snapshot diverged"
    );
    std::fs::remove_dir_all(&dir_full).unwrap();
    std::fs::remove_dir_all(&crashed).unwrap();
}

/// Bit-flip corruption (not just truncation) in the newest image is
/// also caught by the checksum and recovery degrades one image back.
#[test]
fn corrupt_newest_snapshot_is_skipped_by_checksum() {
    let workload = small_workload(11);
    let dir_full = scratch("flip-full");
    let mut options = cell_options(false);
    options.checkpoint_dir = Some(dir_full.clone());
    let reference = run_cell(&workload, "bf", 1, options);

    let hours = SnapshotStore::open(&dir_full).unwrap().hours();
    assert!(hours.len() >= 2, "need a fallback image: {hours:?}");
    let crashed = clone_dir(&dir_full, "flip-crashed");
    let store = SnapshotStore::open(&crashed).unwrap();
    let newest = *hours.last().unwrap();
    let path = store.path_for(newest);
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    assert_eq!(store.latest_valid().unwrap().0, hours[hours.len() - 2]);

    let mut options = cell_options(false);
    options.resume_from = Some(crashed.clone());
    let resumed = run_cell(&workload, "bf", 1, options);
    assert!(resumed.same_outcome(&reference), "checksum fallback diverged");
    std::fs::remove_dir_all(&dir_full).unwrap();
    std::fs::remove_dir_all(&crashed).unwrap();
}

/// With every image torn there is nothing to resume from; the engine
/// refuses loudly instead of silently starting a fresh run that would
/// double-count the trace.
#[test]
#[should_panic(expected = "no valid snapshot")]
fn resume_with_no_valid_snapshot_aborts() {
    let workload = small_workload(13);
    let dir = scratch("allgone");
    let mut options = cell_options(false);
    options.checkpoint_dir = Some(dir.clone());
    run_cell(&workload, "ff", 1, options);
    let store = SnapshotStore::open(&dir).unwrap();
    for hour in store.hours() {
        let path = store.path_for(hour);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..4]).unwrap();
    }
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut options = cell_options(false);
        options.resume_from = Some(dir.clone());
        run_cell(&workload, "ff", 1, options)
    }));
    std::fs::remove_dir_all(&dir).unwrap();
    match result {
        Ok(_) => panic!("resume from all-torn directory was accepted"),
        // Re-raise the original payload after cleanup so the
        // `should_panic(expected)` filter still sees the message.
        Err(e) => std::panic::resume_unwind(e),
    }
}

/// Resuming under a different policy than the crashed run is a
/// configuration error, not a silent divergence: the image carries the
/// policy name and restore refuses a mismatch.
#[test]
#[should_panic(expected = "resume failed")]
fn resume_with_wrong_policy_aborts() {
    let workload = small_workload(17);
    let dir = scratch("wrongpolicy");
    let mut options = cell_options(false);
    options.checkpoint_dir = Some(dir.clone());
    run_cell(&workload, "ff", 1, options);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut options = cell_options(false);
        options.resume_from = Some(dir.clone());
        run_cell(&workload, "mcc", 1, options)
    }));
    std::fs::remove_dir_all(&dir).unwrap();
    match result {
        Ok(_) => panic!("policy mismatch was accepted"),
        Err(e) => std::panic::resume_unwind(e),
    }
}

/// A single-core image cannot seed a sharded run (and vice versa): the
/// frame's kind tag is checked before any payload decoding.
#[test]
#[should_panic(expected = "but this run needs")]
fn resume_rejects_engine_kind_mismatch() {
    let workload = small_workload(19);
    let dir = scratch("kind");
    let mut options = cell_options(false);
    options.checkpoint_dir = Some(dir.clone());
    run_cell(&workload, "ff", 1, options);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut options = cell_options(false);
        options.resume_from = Some(dir.clone());
        run_cell(&workload, "ff", 4, options)
    }));
    std::fs::remove_dir_all(&dir).unwrap();
    match result {
        Ok(_) => panic!("kind mismatch was accepted"),
        Err(e) => std::panic::resume_unwind(e),
    }
}
