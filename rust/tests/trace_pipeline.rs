//! Integration tests: the §8.1 workload pipeline end to end — synthesis
//! → CSV export → loader → mapping — and its statistical properties.

use grmu::trace::loader::{load_trace, parse_pods_csv};
use grmu::trace::mapping::{map_pods_to_profiles, nearest_profile, normalized_profile_values};
use grmu::trace::{TraceConfig, Workload};
use grmu::util::stats::{iqr_filter, mean};

const FIXTURE: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/fixtures/alibaba_mini.csv");

#[test]
fn csv_roundtrip_preserves_vm_stream() {
    let workload = Workload::generate(TraceConfig::small(42));
    // Export the mapped VMs in pod format (as `repro trace` does).
    let mut csv = String::from("arrival,duration,num_gpus,gpu_frac,cpus,ram_gb\n");
    for vm in &workload.vms {
        csv.push_str(&format!(
            "{},{},1,{:.6},{},{}\n",
            vm.arrival,
            vm.departure - vm.arrival,
            vm.profile.combined_value(),
            vm.cpus,
            vm.ram_gb
        ));
    }
    let pods = parse_pods_csv(&csv).unwrap();
    let (vms, report) = map_pods_to_profiles(&pods);
    assert_eq!(vms.len(), workload.vms.len());
    assert_eq!(report.outliers_removed, 0, "round-trip must not re-filter");
    // Profiles survive the round trip exactly: each exported frac is the
    // profile's own normalized value.
    for (a, b) in vms.iter().zip(&workload.vms) {
        assert_eq!(a.profile, b.profile);
        assert_eq!(a.arrival, b.arrival);
    }
}

/// Satellite lock: a committed miniature Alibaba-format trace flows
/// through loader → cleaning → mapping → event core end to end. The
/// fixture plants one multi-GPU pod and one extreme arrival so both
/// cleaning stages visibly fire on file-loaded (not synthesized) data.
#[test]
fn committed_fixture_runs_end_to_end() {
    use grmu::cluster::{DataCenter, Host};
    use grmu::ops::{OpsConfig, QueueConfig};
    use grmu::policies::{PolicyConfig, PolicyRegistry};
    use grmu::sim::{Simulation, SimulationOptions};

    let (vms, report) = load_trace(std::path::Path::new(FIXTURE)).unwrap();
    assert_eq!(report.multi_gpu_removed, 1, "the 2-GPU pod must be dropped");
    assert!(report.outliers_removed >= 1, "the planted arrival outlier must go");
    assert_eq!(vms.len(), 30);
    assert!(vms.windows(2).all(|p| p[0].arrival <= p[1].arrival));

    let hosts: Vec<Host> = (0..3).map(|i| Host::new(i, 64, 256, 2)).collect();
    let run = |ops: OpsConfig, queue: QueueConfig| {
        let policy = PolicyRegistry::standard()
            .build("grmu", &PolicyConfig::new().heavy_frac(0.3))
            .unwrap();
        let mut sim = Simulation::new(DataCenter::new(hosts.clone()), policy, &vms);
        sim.options = SimulationOptions {
            integrity_every: 1,
            drain_cap_hours: 0,
            ops,
            queue,
            ..SimulationOptions::default()
        };
        sim.run()
    };
    let clean = run(OpsConfig::default(), QueueConfig::default());
    assert_eq!(clean.requested, 30);
    assert!(clean.accepted > 0);
    assert_eq!(clean.rejections.iter().sum::<u64>(), clean.requested - clean.accepted);
    assert_eq!(clean.availability, 1.0);
    // Deterministic replay: the file path is as reproducible as synthesis.
    let again = run(OpsConfig::default(), QueueConfig::default());
    assert_eq!(clean.samples, again.samples);
    assert_eq!(clean.rejections, again.rejections);

    // The same fixture under the fault/queue model keeps the books.
    let ops = OpsConfig {
        drain_rate: 2.0,
        seed: 9,
        ..OpsConfig::default().with_gpu_mtbf(150.0)
    };
    let faulty = run(ops.clone(), QueueConfig { capacity: 8, ..QueueConfig::default() });
    assert_eq!(faulty.requested, 30);
    assert_eq!(faulty.rejections.iter().sum::<u64>(), faulty.requested - faulty.accepted);
    assert!(faulty.availability <= 1.0);
    let faulty_again = run(ops, QueueConfig { capacity: 8, ..QueueConfig::default() });
    assert_eq!(faulty.samples, faulty_again.samples);
    assert_eq!(faulty.interrupted, faulty_again.interrupted);
}

/// Satellite lock: a checkpointed run over the committed fixture can be
/// killed mid-trace and resumed to the exact outcome of an uninterrupted
/// run — and the re-driven tail reproduces the crashed run's snapshot
/// files byte for byte.
#[test]
fn checkpointed_fixture_resumes_byte_identical() {
    use grmu::cluster::{DataCenter, Host};
    use grmu::policies::{PolicyConfig, PolicyRegistry};
    use grmu::recover::SnapshotStore;
    use grmu::sim::{Simulation, SimulationOptions};
    use std::sync::atomic::{AtomicU64, Ordering};

    static DIR_SEQ: AtomicU64 = AtomicU64::new(0);
    let scratch = |tag: &str| {
        let n = DIR_SEQ.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("grmu-trace-cp-{}-{tag}-{n}", std::process::id()))
    };

    let (vms, _) = load_trace(std::path::Path::new(FIXTURE)).unwrap();
    let hosts: Vec<Host> = (0..3).map(|i| Host::new(i, 64, 256, 2)).collect();
    let run = |options: SimulationOptions| {
        let policy = PolicyRegistry::standard()
            .build("grmu", &PolicyConfig::new().heavy_frac(0.3))
            .unwrap();
        let mut sim = Simulation::new(DataCenter::new(hosts.clone()), policy, &vms);
        sim.options = options;
        sim.run()
    };

    // Baseline: the same run with checkpointing off.
    let baseline = run(SimulationOptions { integrity_every: 1, ..SimulationOptions::default() });

    // Checkpointed run: a full snapshot every 24 simulated hours.
    let dir_full = scratch("full");
    let checkpointed = run(SimulationOptions {
        integrity_every: 1,
        checkpoint_every_hours: 24,
        checkpoint_dir: Some(dir_full.clone()),
        ..SimulationOptions::default()
    });
    assert!(
        checkpointed.same_outcome(&baseline),
        "checkpointing must not change any observable outcome"
    );

    // Simulate a kill: clone the checkpoint directory, then delete the
    // newest snapshot so the resume starts from an earlier interval and
    // has to re-drive the tail (cross-checking the journal suffix).
    let hours = SnapshotStore::open(&dir_full).unwrap().hours();
    assert!(hours.len() >= 2, "fixture run produced only {hours:?}");
    let newest = *hours.last().unwrap();
    let dir_crash = scratch("crashed");
    std::fs::create_dir_all(&dir_crash).unwrap();
    for entry in std::fs::read_dir(&dir_full).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dir_crash.join(entry.file_name())).unwrap();
    }
    let crash_store = SnapshotStore::open(&dir_crash).unwrap();
    std::fs::remove_file(crash_store.path_for(newest)).unwrap();

    let resumed = run(SimulationOptions {
        integrity_every: 1,
        checkpoint_every_hours: 24,
        resume_from: Some(dir_crash.clone()),
        ..SimulationOptions::default()
    });
    assert!(
        resumed.same_outcome(&baseline),
        "resumed run must reproduce the uninterrupted run exactly"
    );

    // The re-driven tail rewrote the deleted snapshot byte for byte.
    let full_store = SnapshotStore::open(&dir_full).unwrap();
    let original = std::fs::read(full_store.path_for(newest)).unwrap();
    let recovered = std::fs::read(crash_store.path_for(newest)).unwrap();
    assert_eq!(original, recovered, "snapshot at hour {newest} must be byte-identical");

    std::fs::remove_dir_all(&dir_full).unwrap();
    std::fs::remove_dir_all(&dir_crash).unwrap();
}

#[test]
fn iqr_filter_matches_report() {
    let config = TraceConfig::small(3);
    let workload = Workload::generate(config.clone());
    // The generator plants ~outlier_frac extreme arrivals.
    let expected = (config.num_pods as f64 * config.outlier_frac) as f64;
    let removed = workload.report.outliers_removed as f64;
    assert!(
        removed > 0.0 && removed < 4.0 * expected.max(1.0),
        "removed {removed} vs expected ≈ {expected}"
    );
}

#[test]
fn profile_mapping_covers_all_profiles() {
    let values = normalized_profile_values();
    for (i, v) in values.iter().enumerate() {
        // The profile's own normalized value maps back to itself.
        assert_eq!(nearest_profile(*v).index(), i);
    }
}

#[test]
fn mapping_boundaries_are_midpoints() {
    let values = normalized_profile_values();
    for w in values.windows(2) {
        let mid = (w[0] + w[1]) / 2.0;
        let below = nearest_profile(mid - 1e-9);
        let above = nearest_profile(mid + 1e-9);
        assert_ne!(below, above, "midpoint {mid} must separate profiles");
    }
}

#[test]
fn workload_statistics_sane_at_paper_scale() {
    let workload = Workload::generate(TraceConfig::default());
    assert_eq!(workload.hosts.len(), 1_213);
    // VM count lands near the paper's 8,063 (±5%).
    let n = workload.vms.len() as f64;
    assert!((7_660.0..=8_470.0).contains(&n), "VM count {n}");
    // 7g.40gb is the single most common profile (paper Fig. 5).
    let dist = workload.profile_distribution();
    let max_idx = (0..6).max_by(|&a, &b| dist[a].partial_cmp(&dist[b]).unwrap()).unwrap();
    assert_eq!(max_idx, grmu::mig::Profile::P7g40gb.index());
    // Durations are heavy-tailed: mean far above median.
    let durations: Vec<f64> = workload.vms.iter().map(|v| v.duration() as f64).collect();
    let mut sorted = durations.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = sorted[sorted.len() / 2];
    // Long-lived services: the median VM outlives most of the 30-day
    // horizon (this scarcity is what produces the paper's ~40% regime),
    // while a short-lived head still exists (churn for defrag to act on).
    assert!(median as u64 > 14 * 24 * 3_600, "median duration too short: {median}");
    let p10 = sorted[sorted.len() / 10];
    assert!(median > 3.0 * p10, "no dynamic range in durations");
    assert!(mean(&durations) > 0.0);
}

#[test]
fn arrivals_uniformish_after_filter() {
    // Post-IQR arrivals span the horizon with no huge gaps.
    let config = TraceConfig::small(8);
    let workload = Workload::generate(config.clone());
    let arrivals: Vec<f64> = workload.vms.iter().map(|v| v.arrival as f64).collect();
    let kept = iqr_filter(&arrivals);
    assert_eq!(kept.len(), arrivals.len(), "pipeline output must already be IQR-clean");
    let horizon = (config.horizon_hours * 3_600) as f64;
    let spread = arrivals.last().unwrap() - arrivals.first().unwrap();
    assert!(spread > 0.5 * horizon, "arrivals bunched: spread {spread} of {horizon}");
}

#[test]
fn cpu_ram_demands_scale_with_profile() {
    let workload = Workload::generate(TraceConfig::small(12));
    let avg = |p: grmu::mig::Profile| -> f64 {
        let xs: Vec<f64> = workload
            .vms
            .iter()
            .filter(|v| v.profile == p)
            .map(|v| v.cpus as f64)
            .collect();
        if xs.is_empty() {
            f64::NAN
        } else {
            mean(&xs)
        }
    };
    let small = avg(grmu::mig::Profile::P1g5gb);
    let large = avg(grmu::mig::Profile::P7g40gb);
    if small.is_finite() && large.is_finite() {
        assert!(large > small, "7g VMs should demand more CPU than 1g VMs");
    }
}
