//! Property-style integration tests for the ops subsystem: random
//! fault/repair/drain sequences — including conflicting and redundant
//! ones (repairs without failures, double drains, events on already-down
//! hosts) — interleaved with placements, queueing and preemption must
//! keep the cluster, the index and the accounting coherent at every
//! interval. The per-interval `check_integrity` inside the event core is
//! the oracle; these tests only have to survive it.

use grmu::cluster::vm::{Time, HOUR};
use grmu::cluster::{DataCenter, GpuRef, Host};
use grmu::ops::{FaultInjector, OpsEvent, QueueConfig};
use grmu::policies::{PolicyConfig, PolicyCtx, PolicyRegistry};
use grmu::sim::EventCore;
use grmu::trace::{TraceConfig, Workload};
use grmu::util::rng::Rng;

/// An adversarial schedule: uniformly random events over random targets,
/// with no care for pairing fails with repairs or drains with ends.
fn random_schedule(rng: &mut Rng, hosts: &[Host], horizon: Time) -> Vec<(Time, OpsEvent)> {
    let mut out = Vec::new();
    let n = 60 + (rng.f64() * 80.0) as usize;
    for _ in 0..n {
        let t = (rng.f64() * horizon as f64) as Time;
        let hi = ((rng.f64() * hosts.len() as f64) as usize).min(hosts.len() - 1);
        let h = hosts[hi].id;
        let gpus = hosts[hi].gpus().len();
        let g = ((rng.f64() * gpus as f64) as usize).min(gpus - 1) as u8;
        let gpu = GpuRef { host: h, gpu: g };
        let until = t + HOUR + (rng.f64() * 12.0 * HOUR as f64) as Time;
        let ev = match ((rng.f64() * 6.0) as u32).min(5) {
            0 => OpsEvent::GpuFail { gpu, until },
            1 => OpsEvent::GpuRepair { gpu },
            2 => OpsEvent::HostFail { host: h, until },
            3 => OpsEvent::HostRepair { host: h },
            4 => OpsEvent::DrainStart { host: h, until },
            _ => OpsEvent::DrainDone { host: h },
        };
        out.push((t, ev));
    }
    out.sort_by_key(|&(t, _)| t);
    out
}

#[test]
fn random_ops_sequences_keep_integrity_green() {
    let mut any_interrupted = false;
    for seed in [1u64, 2, 3, 4, 5] {
        // priority_frac > 0 gives the preemption path real High-tier
        // arrivals to act on.
        let workload =
            Workload::generate(TraceConfig { priority_frac: 0.25, ..TraceConfig::small(seed) });
        let vms = &workload.vms;
        let horizon = (workload.config.horizon_hours + 24) * HOUR;
        let mut rng = Rng::new(seed ^ 0xB0B);
        let schedule = random_schedule(&mut rng, &workload.hosts, horizon);
        assert!(!schedule.is_empty());
        for name in ["ff", "grmu"] {
            let policy = PolicyRegistry::standard()
                .build(name, &PolicyConfig::new().heavy_frac(0.25))
                .unwrap();
            let mut core = EventCore::new(
                DataCenter::new(workload.hosts.clone()),
                policy,
                PolicyCtx::new(seed),
            );
            // ban_after 2: repeated random failures on the same GPU
            // exercise the blocklist transition too.
            core.set_fault_schedule(FaultInjector::new(schedule.clone(), 2));
            core.set_admission_queue(QueueConfig {
                capacity: 8,
                ttl_hours: 6,
                preemption: true,
            });
            core.set_integrity_every(1);
            let last_arrival = vms.last().map(|v| v.arrival).unwrap_or(0);
            let mut next = 0usize;
            loop {
                let t_end = core.interval_end();
                let start = next;
                while next < vms.len() && vms[next].arrival <= t_end {
                    next += 1;
                }
                core.step(&vms[start..next]);
                let drained = next >= vms.len() && core.pending_departures() == 0;
                let capped = core.hour() * HOUR > last_arrival + 3 * 24 * HOUR;
                if drained || capped {
                    break;
                }
            }
            let res = core.into_result(0.0);
            assert_eq!(
                res.rejections.iter().sum::<u64>(),
                res.requested - res.accepted,
                "seed {seed} {name}: queue/preemption accounting leaked"
            );
            assert!((0.0..=1.0).contains(&res.availability), "seed {seed} {name}");
            assert!(res.queue_delay_p99() >= res.queue_delay_p50(), "seed {seed} {name}");
            any_interrupted |= res.interrupted > 0;
        }
    }
    assert!(any_interrupted, "no random schedule ever hit a resident — vacuous run");
}

/// Health contract of the online ILP's instance extraction: whatever
/// adversarial fault/repair/drain sequence is in flight, the
/// fragmented-window ranking surfaces *exactly* the schedulable GPUs
/// (device and host `Healthy`) — never failed, banned or draining
/// capacity — and no resident of an unschedulable device ever enters an
/// extracted instance as a prior. Checked at every interval of a live
/// run, against `gpu_available` as the oracle.
#[test]
fn ilp_extraction_never_sees_unschedulable_capacity() {
    use grmu::ilp::online::{build_instance, fragmented_window, MAX_INSTANCE_VMS, REPAIR_WEIGHT};
    use grmu::mig::GpuModel;
    use grmu::migrate::PlanScope;
    use std::collections::BTreeSet;
    let workload = Workload::generate(TraceConfig::small(6));
    let vms = &workload.vms;
    let horizon = (workload.config.horizon_hours + 24) * HOUR;
    let mut rng = Rng::new(0xFACE);
    let schedule = random_schedule(&mut rng, &workload.hosts, horizon);
    let policy = PolicyRegistry::standard().build("ff", &PolicyConfig::new()).unwrap();
    let mut core =
        EventCore::new(DataCenter::new(workload.hosts.clone()), policy, PolicyCtx::new(6));
    core.set_fault_schedule(FaultInjector::new(schedule, 1));
    core.set_integrity_every(4);
    let last_arrival = vms.last().map(|v| v.arrival).unwrap_or(0);
    let mut saw_unavailable = false;
    let mut next = 0usize;
    loop {
        let t_end = core.interval_end();
        let start = next;
        while next < vms.len() && vms[next].arrival <= t_end {
            next += 1;
        }
        core.step(&vms[start..next]);
        let dc = &core.dc;
        let all = dc.gpu_refs();
        let schedulable: BTreeSet<GpuRef> =
            all.iter().copied().filter(|&r| dc.gpu_available(r)).collect();
        saw_unavailable |= schedulable.len() < all.len();
        let window = fragmented_window(dc, PlanScope::Cluster, GpuModel::A100_40, all.len());
        let in_window: BTreeSet<GpuRef> = window.iter().copied().collect();
        assert_eq!(in_window.len(), window.len(), "the window must not repeat a GPU");
        assert_eq!(
            in_window, schedulable,
            "hour {}: window != schedulable capacity",
            core.hour()
        );
        let ex = build_instance(dc, &window, &[], MAX_INSTANCE_VMS, &|_| REPAIR_WEIGHT);
        for &vm in ex.inst.prior.keys() {
            let loc = dc.locate(vm).expect("instance priors are resident");
            assert!(
                dc.gpu_available(loc.gpu),
                "hour {}: resident of unschedulable {:?} leaked into the instance",
                core.hour(),
                loc.gpu
            );
        }
        let drained = next >= vms.len() && core.pending_departures() == 0;
        let capped = core.hour() * HOUR > last_arrival + 3 * 24 * HOUR;
        if drained || capped {
            break;
        }
    }
    assert!(saw_unavailable, "no fault ever removed capacity — the health lock is vacuous");
}

/// The injector itself is order-safe under replay: popping the same
/// schedule through cores with different interval grids applies every
/// event exactly once and ends in a coherent state (integrity checked
/// each interval on both grids).
#[test]
fn schedules_replay_coherently_on_any_interval_grid() {
    let workload = Workload::generate(TraceConfig::small(8));
    let vms = &workload.vms;
    let horizon = (workload.config.horizon_hours + 24) * HOUR;
    let mut rng = Rng::new(0xD1CE);
    let schedule = random_schedule(&mut rng, &workload.hosts, horizon);
    let last_arrival = vms.last().map(|v| v.arrival).unwrap_or(0);
    let mut totals = Vec::new();
    for interval in [HOUR, HOUR / 2, 3 * HOUR] {
        let policy = PolicyRegistry::standard()
            .build("ff", &PolicyConfig::new())
            .unwrap();
        let mut core = EventCore::with_interval(
            DataCenter::new(workload.hosts.clone()),
            policy,
            PolicyCtx::new(8),
            interval,
        );
        core.set_fault_schedule(FaultInjector::new(schedule.clone(), 0));
        core.set_integrity_every(1);
        let mut next = 0usize;
        loop {
            let t_end = core.interval_end();
            let start = next;
            while next < vms.len() && vms[next].arrival <= t_end {
                next += 1;
            }
            core.step(&vms[start..next]);
            let drained = next >= vms.len() && core.pending_departures() == 0;
            let capped = core.hour() * interval > last_arrival + 3 * 24 * HOUR;
            if drained || capped {
                break;
            }
        }
        let res = core.into_result(0.0);
        assert_eq!(
            res.rejections.iter().sum::<u64>(),
            res.requested - res.accepted,
            "interval {interval}"
        );
        totals.push((res.requested, res.accepted + res.interrupted));
    }
    // Same request stream on every grid.
    assert!(totals.windows(2).all(|w| w[0].0 == w[1].0));
}
