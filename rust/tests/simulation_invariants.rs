//! Integration tests: whole-simulation invariants across all policies.
//!
//! These run the real engine over generated workloads and check the
//! properties every correct placement system must satisfy, independent
//! of policy quality: conservation (no VM lost or duplicated), capacity
//! safety (CPU/RAM/blocks never oversubscribed), determinism, identical
//! request streams across policies, and a rejection breakdown that
//! accounts for every refusal.

use grmu::cluster::{DataCenter, Host};
use grmu::mig::gpu::consistent;
use grmu::policies::{Policy, PolicyConfig, PolicyCtx, PolicyRegistry};
use grmu::sim::{Simulation, SimulationOptions};
use grmu::trace::{TraceConfig, Workload};

fn build(policy: &str, heavy: f64, consolidation: Option<u64>) -> Box<dyn grmu::policies::Policy> {
    PolicyRegistry::standard()
        .build(
            policy,
            &PolicyConfig::new().heavy_frac(heavy).consolidation_hours(consolidation),
        )
        .unwrap()
}

fn run(policy: &str, seed: u64, heavy: f64, consolidation: Option<u64>) -> grmu::sim::SimResult {
    let workload = Workload::generate(TraceConfig::small(seed));
    let dc = DataCenter::new(workload.hosts.clone());
    let p = build(policy, heavy, consolidation);
    let mut sim = Simulation::new(dc, p, &workload.vms);
    sim.ctx = PolicyCtx::new(seed);
    sim.options = SimulationOptions {
        integrity_every: 13,
        drain_cap_hours: 10 * 24,
        ..Default::default()
    };
    sim.run()
}

fn all_names() -> Vec<String> {
    // Includes the composed base+planner migration variants, so every
    // invariant below also covers the planner layer end-to-end.
    PolicyRegistry::standard().names()
}

#[test]
fn all_policies_complete_with_integrity_checks_on() {
    for policy in all_names() {
        for seed in [1u64, 2, 3] {
            let r = run(&policy, seed, 0.3, Some(24));
            assert!(r.requested > 0);
            assert!(r.accepted <= r.requested, "{policy} seed {seed}");
            // The typed breakdown accounts for every refusal.
            assert_eq!(
                r.rejections.iter().sum::<u64>(),
                r.requested - r.accepted,
                "{policy} seed {seed}: rejection breakdown mismatch"
            );
        }
    }
}

#[test]
fn identical_request_streams_across_policies() {
    let results: Vec<_> =
        PolicyRegistry::COMPARISON.iter().map(|p| run(p, 7, 0.3, None)).collect();
    for r in &results[1..] {
        assert_eq!(r.requested, results[0].requested);
        for i in 0..r.per_profile.len() {
            assert_eq!(
                r.per_profile[i].0, results[0].per_profile[i].0,
                "policy {} sees a different stream",
                r.policy
            );
        }
    }
}

#[test]
fn determinism_same_seed_same_result() {
    for policy in all_names() {
        let a = run(&policy, 11, 0.3, Some(12));
        let b = run(&policy, 11, 0.3, Some(12));
        assert_eq!(a.accepted, b.accepted, "{policy}");
        assert_eq!(a.rejections, b.rejections, "{policy}");
        assert_eq!(a.migration_events, b.migration_events, "{policy}");
        assert_eq!(a.samples.len(), b.samples.len(), "{policy}");
        for (sa, sb) in a.samples.iter().zip(&b.samples) {
            assert_eq!(sa, sb, "{policy}");
        }
    }
}

#[test]
fn different_seeds_differ() {
    let a = run("ff", 1, 0.3, None);
    let b = run("ff", 2, 0.3, None);
    assert_ne!(
        (a.accepted, a.requested),
        (b.accepted, b.requested),
        "two seeds produced identical workload outcomes — suspicious"
    );
}

#[test]
fn cluster_fully_drains_after_last_departure() {
    for policy in all_names() {
        let workload = Workload::generate(TraceConfig {
            num_hosts: 10,
            num_pods: 60,
            horizon_hours: 48,
            duration_mu: 2.0, // short-lived: everything departs
            ..TraceConfig::default()
        });
        let dc = DataCenter::new(workload.hosts.clone());
        let p = build(&policy, 0.3, Some(6));
        let mut sim = Simulation::new(dc, p, &workload.vms);
        sim.options.integrity_every = 1;
        let r = sim.run();
        let last = r.samples.last().unwrap();
        assert_eq!(last.resident, 0, "{policy}: residents remain after drain");
        assert!(last.active_rate < 1e-9, "{policy}: hardware active after drain");
    }
}

#[test]
fn acceptance_rate_monotone_niceness_of_capacity() {
    // Doubling every host's GPU count can only help (same stream).
    let base = TraceConfig::small(5);
    let workload = Workload::generate(base.clone());
    let small_dc = DataCenter::new(workload.hosts.clone());
    let big_hosts: Vec<Host> = workload
        .hosts
        .iter()
        .map(|h| Host::new(h.id, h.cpus * 2, h.ram_gb * 2, h.gpus().len() * 2))
        .collect();
    let big_dc = DataCenter::new(big_hosts);
    for policy in ["ff", "bf", "grmu"] {
        let mut p1 = build(&policy, 0.3, None);
        let mut small = small_dc.clone();
        let mut ctx1 = PolicyCtx::default();
        let acc_small: usize = p1
            .place_batch(&mut small, &workload.vms, &mut ctx1)
            .iter()
            .filter(|d| d.is_placed())
            .count();
        let mut p2 = build(&policy, 0.3, None);
        let mut big = big_dc.clone();
        let mut ctx2 = PolicyCtx::default();
        let acc_big: usize = p2
            .place_batch(&mut big, &workload.vms, &mut ctx2)
            .iter()
            .filter(|d| d.is_placed())
            .count();
        assert!(
            acc_big >= acc_small,
            "{policy}: more capacity lowered acceptance ({acc_big} < {acc_small})"
        );
    }
}

#[test]
fn no_gpu_ever_oversubscribed() {
    // Deep check on a dense single-batch placement.
    let workload = Workload::generate(TraceConfig::small(21));
    for policy in all_names() {
        let mut dc = DataCenter::new(workload.hosts.clone());
        let mut p = build(&policy, 0.3, None);
        let mut ctx = PolicyCtx::default();
        let decisions = p.place_batch(&mut dc, &workload.vms, &mut ctx);
        dc.check_integrity().unwrap();
        // Every accepted decision's address matches the location index.
        for (vm, d) in workload.vms.iter().zip(&decisions) {
            assert_eq!(d.gpu(), dc.locate(vm.id).map(|loc| loc.gpu), "{policy}: VM {}", vm.id);
        }
        for host in dc.hosts() {
            assert!(host.free_cpus() <= host.cpus);
            assert!(host.free_ram() <= host.ram_gb);
            for gpu in host.gpus() {
                assert!(consistent(gpu), "{policy}: inconsistent GPU");
                // No profile exceeds its Table 1 instance limit (per the
                // GPU's own model).
                let counts = gpu.profile_counts();
                for i in 0..gpu.model().num_profiles() {
                    let max = gpu.model().profile(i).max_instances();
                    assert!(counts[i] <= max, "{policy}: {} instances of profile {i}", counts[i]);
                }
            }
        }
    }
}

#[test]
fn grmu_components_toggle_cleanly() {
    // DB-only vs defrag vs defrag+consolidation: migrations appear only
    // with the responsible component enabled.
    let workload = Workload::generate(TraceConfig::small(9));
    let run_grmu = |defrag: bool, consolidation: Option<u64>| {
        let dc = DataCenter::new(workload.hosts.clone());
        let policy = Box::new(grmu::policies::grmu::Grmu::new(grmu::policies::grmu::GrmuConfig {
            heavy_capacity_frac: 0.3,
            consolidation_interval_hours: consolidation,
            defrag_enabled: defrag,
            ..Default::default()
        }));
        let mut sim = Simulation::new(dc, policy, &workload.vms);
        sim.options.integrity_every = 7;
        sim.run()
    };
    let db_only = run_grmu(false, None);
    assert_eq!(db_only.intra_migrations(), 0);
    assert_eq!(db_only.inter_migrations(), 0);
    let defrag = run_grmu(true, None);
    assert_eq!(defrag.inter_migrations(), 0);
    let full = run_grmu(true, Some(6));
    // Consolidation may or may not find candidates on a small trace, but
    // it must never *reduce* intra-migrations bookkeeping.
    assert!(full.intra_migrations() + full.inter_migrations() >= defrag.intra_migrations());
}

#[test]
fn weighted_metrics_consistent() {
    let r = run("grmu", 3, 0.3, None);
    // Per-profile accepted sums to total accepted.
    let sum: u64 = r.per_profile.iter().map(|(_, a)| a).sum();
    assert_eq!(sum, r.accepted);
    let req: u64 = r.per_profile.iter().map(|(q, _)| q).sum();
    assert_eq!(req, r.requested);
    // Acceptance-rate samples are monotone results of cumulative counts.
    for s in &r.samples {
        assert!((0.0..=1.0).contains(&s.acceptance_rate));
        assert!((0.0..=1.0).contains(&s.active_rate));
    }
}
