//! Integration tests for the typed placement-decision API:
//!
//! * the [`RejectReason`] taxonomy — one engine-level test per reason,
//!   asserting the reason surfaces in [`SimResult`];
//! * the simulator-vs-coordinator equivalence — both drive the shared
//!   `EventCore`, so the same seeded trace must produce identical
//!   acceptance counts, per-reason rejections, migration events and
//!   sample prefixes (the regression lock for the core extraction);
//! * the indexed-vs-scan equivalence — every policy built with the
//!   cluster index (`PolicyConfig::use_index(true)`, the default) must
//!   produce the exact `Decision` sequence and `SimResult` of its
//!   brute-force full-scan variant (the regression lock for the
//!   `ClusterIndex` maintenance), on single-model *and* mixed fleets;
//! * the catalog equivalence — A100-only fleets decide byte-identically
//!   whether built through the legacy constructors or explicitly through
//!   the `GpuModel` catalog (the golden lock for the heterogeneous-fleet
//!   redesign).

use grmu::cluster::vm::HOUR;
use grmu::cluster::{DataCenter, Host, VmSpec};
use grmu::coordinator::{Coordinator, CoordinatorConfig, Request};
use grmu::mig::Profile;
use grmu::policies::{Decision, Policy, PolicyConfig, PolicyCtx, PolicyRegistry, RejectReason};
use grmu::sim::{
    EventCore, ShardedCore, ShardedSimulation, SimResult, Simulation, SimulationOptions,
};
use grmu::trace::{TraceConfig, Workload};

fn vm(id: u64, profile: Profile, cpus: u32, ram_gb: u32, arrival_h: u64, dur_h: u64) -> VmSpec {
    VmSpec {
        id,
        profile,
        cpus,
        ram_gb,
        arrival: arrival_h * HOUR + 60,
        departure: (arrival_h + dur_h) * HOUR + 60,
        weight: 1.0,
    }
}

fn run_ff(dc: DataCenter, vms: &[VmSpec]) -> SimResult {
    let policy = PolicyRegistry::standard().build("ff", &PolicyConfig::new()).unwrap();
    let mut sim = Simulation::new(dc, policy, vms);
    sim.options.integrity_every = 1;
    sim.run()
}

// ---------------------------------------------------------------- taxonomy

#[test]
fn cpu_exhaustion_surfaces_in_result() {
    // 3 CPUs, ample RAM and GPU blocks: the second 2-CPU VM starves.
    let dc = DataCenter::new(vec![Host::new(0, 3, 256, 1)]);
    let vms =
        vec![vm(1, Profile::P1g5gb, 2, 4, 0, 9), vm(2, Profile::P1g5gb, 2, 4, 0, 9)];
    let res = run_ff(dc, &vms);
    assert_eq!(res.accepted, 1);
    assert_eq!(res.rejected(RejectReason::CpuExhausted), 1);
    assert_eq!(res.rejections.iter().sum::<u64>(), 1);
}

#[test]
fn ram_exhaustion_surfaces_in_result() {
    // 10 GB RAM, ample CPU: the second 8 GB VM starves on RAM.
    let dc = DataCenter::new(vec![Host::new(0, 64, 10, 1)]);
    let vms =
        vec![vm(1, Profile::P1g5gb, 2, 8, 0, 9), vm(2, Profile::P1g5gb, 2, 8, 0, 9)];
    let res = run_ff(dc, &vms);
    assert_eq!(res.accepted, 1);
    assert_eq!(res.rejected(RejectReason::RamExhausted), 1);
}

#[test]
fn fragmentation_no_gpu_fit_surfaces_in_result() {
    // Host resources are plentiful; the single GPU is fully occupied by a
    // 7g.40gb, so a 1g.5gb has no fitting GI — the fragmentation bucket.
    let dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
    let vms =
        vec![vm(1, Profile::P7g40gb, 2, 4, 0, 9), vm(2, Profile::P1g5gb, 2, 4, 0, 9)];
    let res = run_ff(dc, &vms);
    assert_eq!(res.accepted, 1);
    assert_eq!(res.rejected(RejectReason::NoGpuFit), 1);
}

#[test]
fn grmu_quota_denial_surfaces_in_result() {
    // 10 single-GPU hosts, heavy quota 30% → 3 GPUs. Five 7g.40gb
    // requests: three accepted, two rejected by the basket quota even
    // though the pool still holds empty GPUs.
    let dc = DataCenter::new((0..10).map(|i| Host::new(i, 64, 256, 1)).collect());
    let vms: Vec<VmSpec> = (1..=5).map(|i| vm(i, Profile::P7g40gb, 2, 4, 0, 9)).collect();
    let policy = PolicyRegistry::standard()
        .build("grmu", &PolicyConfig::new().heavy_frac(0.3))
        .unwrap();
    let mut sim = Simulation::new(dc, policy, &vms);
    sim.options.integrity_every = 1;
    let res = sim.run();
    assert_eq!(res.accepted, 3);
    assert_eq!(res.rejected(RejectReason::QuotaDenied), 2);
    assert_eq!(res.rejections.iter().sum::<u64>(), 2);
}

#[test]
fn grmu_reports_fragmentation_when_pool_is_spent() {
    // 2 GPUs (1 heavy + 1 light, empty pool): once the light GPU is full,
    // a light request is a fragmentation rejection, not a quota denial.
    let dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
    let vms = vec![
        vm(1, Profile::P4g20gb, 2, 4, 0, 9),
        vm(2, Profile::P3g20gb, 2, 4, 0, 9),
        vm(3, Profile::P3g20gb, 2, 4, 0, 9),
    ];
    let policy = PolicyRegistry::standard()
        .build("grmu", &PolicyConfig::new().heavy_frac(0.5))
        .unwrap();
    let res = Simulation::new(dc, policy, &vms).run();
    assert_eq!(res.accepted, 2);
    assert_eq!(res.rejected(RejectReason::NoGpuFit), 1);
    assert_eq!(res.rejected(RejectReason::QuotaDenied), 0);
}

#[test]
fn breakdown_accounts_for_every_refusal_on_generated_traces() {
    // Acceptance criterion: per-reason breakdown for FF and GRMU on a
    // generated trace.
    let workload = Workload::generate(TraceConfig::small(33));
    for name in ["ff", "grmu"] {
        let policy = PolicyRegistry::standard()
            .build(name, &PolicyConfig::new().heavy_frac(0.2))
            .unwrap();
        let dc = DataCenter::new(workload.hosts.clone());
        let mut sim = Simulation::new(dc, policy, &workload.vms);
        sim.options.drain_cap_hours = 10 * 24;
        let res = sim.run();
        assert_eq!(
            res.rejections.iter().sum::<u64>(),
            res.requested - res.accepted,
            "{name}: breakdown must sum to refusals"
        );
    }
}

// ------------------------------------------------------------- equivalence

/// Replay the trace through the coordinator, batched on the simulator's
/// absolute interval grid, and return the shared result type.
fn coordinator_replay(name: &str, heavy: f64, workload: &Workload, seed: u64) -> SimResult {
    let policy = PolicyRegistry::standard()
        .build(name, &PolicyConfig::new().heavy_frac(heavy))
        .unwrap();
    let mut coord = Coordinator::with_ctx(
        DataCenter::new(workload.hosts.clone()),
        policy,
        CoordinatorConfig { max_batch: usize::MAX, interval: HOUR },
        PolicyCtx::new(seed),
    );
    let vms = &workload.vms;
    let mut i = 0usize;
    while i < vms.len() {
        let w = coord.window_of(vms[i].arrival);
        let mut j = i;
        while j < vms.len() && coord.window_of(vms[j].arrival) == w {
            j += 1;
        }
        let batch: Vec<Request> = vms[i..j].iter().map(|&vm| Request { vm }).collect();
        let responses = coord.decide_batch(&batch);
        assert_eq!(responses.len(), batch.len());
        i = j;
    }
    coord.close_interval();
    coord.into_result()
}

fn simulator_replay(name: &str, heavy: f64, workload: &Workload, seed: u64) -> SimResult {
    let policy = PolicyRegistry::standard()
        .build(name, &PolicyConfig::new().heavy_frac(heavy))
        .unwrap();
    let dc = DataCenter::new(workload.hosts.clone());
    let mut sim = Simulation::new(dc, policy, &workload.vms);
    sim.ctx = PolicyCtx::new(seed);
    sim.options =
        SimulationOptions { integrity_every: 0, drain_cap_hours: 5 * 24, ..Default::default() };
    sim.run()
}

#[test]
fn simulator_and_coordinator_agree_on_the_same_trace() {
    let workload = Workload::generate(TraceConfig::small(42));
    // FF (no migrations) and GRMU with defragmentation (batch-triggered
    // intra migrations); consolidation stays off so no migration can
    // happen outside a request batch.
    for name in ["ff", "grmu"] {
        let sim = simulator_replay(name, 0.25, &workload, 42);
        let coord = coordinator_replay(name, 0.25, &workload, 42);
        assert_eq!(coord.requested, sim.requested, "{name}: requested diverged");
        assert_eq!(coord.accepted, sim.accepted, "{name}: accepted diverged");
        assert_eq!(coord.per_profile, sim.per_profile, "{name}: per-profile diverged");
        assert_eq!(coord.rejections, sim.rejections, "{name}: rejections diverged");
        assert_eq!(
            coord.migration_events, sim.migration_events,
            "{name}: migration events diverged"
        );
        // The coordinator's closed intervals sample identically to the
        // simulator's (the simulator continues into the drain phase).
        assert!(
            coord.samples.len() <= sim.samples.len(),
            "{name}: coordinator sampled past the simulator"
        );
        for (h, (cs, ss)) in coord.samples.iter().zip(&sim.samples).enumerate() {
            assert_eq!(cs, ss, "{name}: sample {h} diverged");
        }
    }
}

#[test]
fn equivalence_holds_across_seeds() {
    for seed in [7u64, 19] {
        let workload = Workload::generate(TraceConfig::small(seed));
        let sim = simulator_replay("grmu", 0.3, &workload, seed);
        let coord = coordinator_replay("grmu", 0.3, &workload, seed);
        assert_eq!((coord.requested, coord.accepted), (sim.requested, sim.accepted));
        assert_eq!(coord.migrations(), sim.migrations(), "seed {seed}");
    }
}

/// Satellite lock for the O(1) activity counters: the simulator-vs-
/// coordinator equivalence also holds on a *non-hour* interval clock
/// (`EventCore::with_interval`), so the counter-backed sampling path is
/// exercised on a grid the hourly tests never touch. The "simulator"
/// side drives the shared core directly on a 30-minute grid; the
/// coordinator batches on the same grid.
#[test]
fn non_hour_interval_sim_and_coordinator_agree() {
    let workload = Workload::generate(TraceConfig::small(23));
    let interval = HOUR / 2;
    let vms = &workload.vms;
    let last_arrival = vms.last().map(|v| v.arrival).unwrap_or(0);
    for name in ["ff", "grmu"] {
        let build = || {
            PolicyRegistry::standard()
                .build(name, &PolicyConfig::new().heavy_frac(0.25))
                .unwrap()
        };
        let mut core = EventCore::with_interval(
            DataCenter::new(workload.hosts.clone()),
            build(),
            PolicyCtx::new(23),
            interval,
        );
        core.set_integrity_every(16);
        let mut next = 0usize;
        loop {
            let t_end = core.interval_end();
            let start = next;
            while next < vms.len() && vms[next].arrival <= t_end {
                next += 1;
            }
            core.step_buffered(&vms[start..next]);
            let drained = next >= vms.len() && core.pending_departures() == 0;
            let capped = core.hour() * interval > last_arrival + 5 * 24 * HOUR;
            if drained || capped {
                break;
            }
        }
        let sim = core.into_result(0.0);

        let mut coord = Coordinator::with_ctx(
            DataCenter::new(workload.hosts.clone()),
            build(),
            CoordinatorConfig { max_batch: usize::MAX, interval },
            PolicyCtx::new(23),
        );
        let mut i = 0usize;
        while i < vms.len() {
            let w = coord.window_of(vms[i].arrival);
            let mut j = i;
            while j < vms.len() && coord.window_of(vms[j].arrival) == w {
                j += 1;
            }
            let batch: Vec<Request> = vms[i..j].iter().map(|&vm| Request { vm }).collect();
            coord.decide_batch(&batch);
            i = j;
        }
        coord.close_interval();
        let coord = coord.into_result();

        assert_eq!(coord.requested, sim.requested, "{name}: requested diverged");
        assert_eq!(coord.accepted, sim.accepted, "{name}: accepted diverged");
        assert_eq!(coord.per_profile, sim.per_profile, "{name}: per-profile diverged");
        assert_eq!(coord.rejections, sim.rejections, "{name}: rejections diverged");
        assert_eq!(
            coord.migration_events, sim.migration_events,
            "{name}: migration events diverged"
        );
        assert!(coord.samples.len() <= sim.samples.len(), "{name}");
        for (h, (cs, ss)) in coord.samples.iter().zip(&sim.samples).enumerate() {
            assert_eq!(cs, ss, "{name}: sample {h} diverged on the 30-minute grid");
        }
    }
}

// ------------------------------------------------------ index equivalence

/// Drive one policy over the workload exactly like `Simulation::run`
/// does, recording every `Decision` the policy emits. The periodic
/// integrity check also re-validates the incrementally maintained
/// cluster index against a brute-force rebuild.
fn replay_decisions(
    name: &str,
    cfg: &PolicyConfig,
    workload: &Workload,
    seed: u64,
) -> (Vec<Decision>, SimResult) {
    let policy = PolicyRegistry::standard().build(name, cfg).unwrap();
    replay_policy(policy, workload, seed)
}

/// [`replay_decisions`] over an explicitly constructed policy (used by
/// the thin-composition lock below).
fn replay_policy(
    policy: Box<dyn grmu::policies::Policy>,
    workload: &Workload,
    seed: u64,
) -> (Vec<Decision>, SimResult) {
    let mut core = EventCore::new(
        DataCenter::new(workload.hosts.clone()),
        policy,
        PolicyCtx::new(seed),
    );
    core.set_integrity_every(8);
    let vms = &workload.vms;
    let last_arrival = vms.last().map(|v| v.arrival).unwrap_or(0);
    let mut decisions = Vec::new();
    let mut next = 0usize;
    loop {
        let t_end = core.interval_end();
        let start = next;
        while next < vms.len() && vms[next].arrival <= t_end {
            next += 1;
        }
        decisions.extend(core.step(&vms[start..next]));
        let drained = next >= vms.len() && core.pending_departures() == 0;
        let capped = core.hour() * HOUR > last_arrival + 5 * 24 * HOUR;
        if drained || capped {
            break;
        }
    }
    (decisions, core.into_result(0.0))
}

fn assert_equivalent(name: &str, cfg: &PolicyConfig, workload: &Workload, seed: u64) {
    let indexed = replay_decisions(name, &cfg.clone().use_index(true), workload, seed);
    let scanned = replay_decisions(name, &cfg.clone().use_index(false), workload, seed);
    assert_eq!(indexed.0, scanned.0, "{name}: decision sequences diverged");
    let (ri, rs) = (indexed.1, scanned.1);
    assert_eq!(ri.requested, rs.requested, "{name}: requested diverged");
    assert_eq!(ri.accepted, rs.accepted, "{name}: accepted diverged");
    assert_eq!(ri.per_profile, rs.per_profile, "{name}: per-profile diverged");
    assert_eq!(ri.rejections, rs.rejections, "{name}: rejections diverged");
    assert_eq!(
        ri.migration_events, rs.migration_events,
        "{name}: migration events diverged"
    );
    assert_eq!(ri.samples, rs.samples, "{name}: samples diverged");
}

/// Acceptance criterion: all five §8.3 policies plus the `grmu-db`
/// ablation decide byte-identically with and without the index on the
/// quick workload.
#[test]
fn indexed_and_scan_policies_decide_identically() {
    let workload = Workload::generate(TraceConfig::small(42));
    let cfg = PolicyConfig::new().heavy_frac(0.25);
    for name in ["ff", "bf", "mcc", "mecc", "grmu", "grmu-db"] {
        assert_equivalent(name, &cfg, &workload, 42);
    }
}

/// Same lock with GRMU's consolidation clock running, so inter-GPU
/// migrations (and the index updates they trigger) are covered too.
#[test]
fn index_equivalence_survives_consolidation() {
    let workload = Workload::generate(TraceConfig::small(19));
    let cfg = PolicyConfig::new().heavy_frac(0.2).consolidation_hours(Some(12));
    assert_equivalent("grmu", &cfg, &workload, 19);
}

// ---------------------------------------------------- catalog equivalence

/// Golden lock for the GpuModel-catalog redesign: an A100-only fleet
/// built through the legacy constructors (`Host::new`, implicit A100-40
/// everywhere) and the same fleet built explicitly through the catalog
/// (`Host::with_models(&[GpuModel::A100_40; n])`, a single-entry
/// `gpu_models` trace mix) must produce byte-identical `Decision`
/// sequences and `SimResult`s for every policy — the catalog is a pure
/// generalization, not a behavior change.
#[test]
fn a100_only_catalog_fleet_is_byte_identical_to_legacy() {
    use grmu::mig::GpuModel;
    let legacy = Workload::generate(TraceConfig::small(42));
    let catalog_cfg = TraceConfig {
        gpu_models: vec![(GpuModel::A100_40, 1.0)],
        ..TraceConfig::small(42)
    };
    let catalog = Workload::generate(catalog_cfg);
    // The trace pipeline itself must not shift: same VM stream.
    assert_eq!(legacy.vms, catalog.vms, "single-model fleets must not consume extra RNG");
    // Rebuild the catalog fleet explicitly through Host::with_models.
    let rebuilt: Vec<Host> = legacy
        .hosts
        .iter()
        .map(|h| {
            Host::with_models(
                h.id,
                h.cpus,
                h.ram_gb,
                &vec![GpuModel::A100_40; h.gpus().len()],
            )
        })
        .collect();
    let explicit = Workload { hosts: rebuilt, ..legacy.clone() };
    let cfg = PolicyConfig::new().heavy_frac(0.25);
    for name in ["ff", "bf", "mcc", "mecc", "grmu", "grmu-db"] {
        let a = replay_decisions(name, &cfg, &legacy, 42);
        let b = replay_decisions(name, &cfg, &explicit, 42);
        assert_eq!(a.0, b.0, "{name}: decision sequences diverged");
        assert_eq!(a.1.per_profile, b.1.per_profile, "{name}");
        assert_eq!(a.1.rejections, b.1.rejections, "{name}");
        assert_eq!(a.1.samples, b.1.samples, "{name}");
        assert_eq!(a.1.migration_events, b.1.migration_events, "{name}");
        // A100-only runs keep the historical per-profile layout: the
        // first six dense slots carry everything, the tail stays zero.
        assert!(a.1.per_profile[6..].iter().all(|&(r, _)| r == 0), "{name}");
        assert_eq!(
            a.1.per_profile.iter().map(|(r, _)| r).sum::<u64>(),
            a.1.requested,
            "{name}"
        );
    }
}

/// The indexed-vs-scan lock on a *heterogeneous* fleet: every policy
/// must decide byte-identically with and without the cluster index when
/// A30s, A100-40s and H100-80s share the cluster.
#[test]
fn mixed_fleet_indexed_and_scan_policies_decide_identically() {
    use grmu::mig::GpuModel;
    let workload = Workload::generate(TraceConfig {
        gpu_models: vec![
            (GpuModel::A30, 0.3),
            (GpuModel::A100_40, 0.4),
            (GpuModel::H100_80, 0.3),
        ],
        ..TraceConfig::small(42)
    });
    let cfg = PolicyConfig::new().heavy_frac(0.25);
    for name in ["ff", "bf", "mcc", "mecc", "grmu", "grmu-db"] {
        assert_equivalent(name, &cfg, &workload, 42);
    }
}

/// Mixed-fleet GRMU with consolidation: inter-GPU moves must respect
/// model compatibility and keep the index coherent (the periodic
/// integrity checks inside `replay_decisions` verify both).
#[test]
fn mixed_fleet_index_equivalence_survives_consolidation() {
    use grmu::mig::GpuModel;
    let workload = Workload::generate(TraceConfig {
        gpu_models: vec![(GpuModel::A30, 0.5), (GpuModel::A100_40, 0.5)],
        ..TraceConfig::small(19)
    });
    let cfg = PolicyConfig::new().heavy_frac(0.2).consolidation_hours(Some(12));
    assert_equivalent("grmu", &cfg, &workload, 19);
}

// ------------------------------------------------ migration-planner layer

/// GRMU's dual baskets composed with the *extracted* defrag planner
/// through the public `migrate` API — the reference reconstruction of
/// the pre-extraction inline flow (grmu-db placement + defragment on
/// rejection over the light basket).
struct BasketsPlusPlanners {
    inner: grmu::policies::grmu::Grmu,
    stack: grmu::migrate::PlannerStack,
    events: Vec<grmu::policies::MigrationEvent>,
}

impl grmu::policies::Policy for BasketsPlusPlanners {
    fn name(&self) -> &str {
        "GRMU"
    }

    fn place_batch_into(
        &mut self,
        dc: &mut DataCenter,
        vms: &[VmSpec],
        ctx: &mut PolicyCtx,
    ) {
        use grmu::migrate::{PlanScope, PlanTrigger};
        self.inner.place_batch_into(dc, vms, ctx);
        if ctx.decisions.iter().any(|d| !d.is_placed()) {
            self.stack.run(
                dc,
                ctx.now,
                PlanTrigger::Rejection,
                PlanScope::Set(self.inner.light_basket()),
                &mut self.events,
            );
        }
    }

    fn drain_migrations_into(&mut self, out: &mut Vec<grmu::policies::MigrationEvent>) {
        self.inner.drain_migrations_into(out);
        out.append(&mut self.events);
    }
}

/// Acceptance criterion (tentpole determinism contract): default-config
/// GRMU — whose migration machinery now routes through
/// `MigrationPlan`/`apply_plan`/`PlannerStack` — produces **byte-identical**
/// Decision and MigrationEvent sequences to the reference reconstruction
/// of the pre-refactor inline flow above. Together with the unchanged
/// pre-refactor unit expectations (exact relocation targets, pool
/// returns) and the sim-vs-coordinator / indexed-vs-scan locks, this
/// pins the extraction as a pure refactor.
#[test]
fn grmu_is_a_thin_composition_of_extracted_planners() {
    use grmu::migrate::{DefragOnReject, MigrationBudget, PlannerStack};
    use grmu::policies::grmu::{Grmu, GrmuConfig};
    let mut migrated_somewhere = false;
    for seed in [42u64, 19, 7] {
        let workload = Workload::generate(TraceConfig::small(seed));
        let cfg = PolicyConfig::new().heavy_frac(0.25);
        let (dec_a, res_a) = replay_decisions("grmu", &cfg, &workload, seed);
        let composed = BasketsPlusPlanners {
            inner: Grmu::new(GrmuConfig {
                heavy_capacity_frac: 0.25,
                consolidation_interval_hours: None,
                defrag_enabled: false,
                ..GrmuConfig::default()
            }),
            stack: PlannerStack::new(MigrationBudget::unlimited())
                .with(Box::new(DefragOnReject::new(true))),
            events: Vec::new(),
        };
        let (dec_b, res_b) = replay_policy(Box::new(composed), &workload, seed);
        assert_eq!(dec_a, dec_b, "seed {seed}: decision sequences diverged");
        assert_eq!(
            res_a.migration_events, res_b.migration_events,
            "seed {seed}: migration events diverged"
        );
        assert_eq!(res_a.per_profile, res_b.per_profile, "seed {seed}");
        assert_eq!(res_a.rejections, res_b.rejections, "seed {seed}");
        assert_eq!(res_a.samples, res_b.samples, "seed {seed}");
        migrated_somewhere |= res_a.migrations() > 0;
    }
    assert!(migrated_somewhere, "the lock is vacuous if no seed migrates");
}

/// Acceptance criterion: composed `base+planner` registry variants
/// decide byte-identically with and without the cluster index — the
/// same determinism contract every base policy honors extends through
/// the planner layer (defrag's fragmentation fast path, consolidation,
/// the frag-gradient drain).
#[test]
fn composed_policies_decide_identically_indexed_vs_scan() {
    let workload = Workload::generate(TraceConfig::small(42));
    let cfg = PolicyConfig::new()
        .heavy_frac(0.25)
        .consolidation_hours(Some(12))
        .frag_threshold(0.5);
    for name in ["ff+defrag", "mcc+defrag", "bf+consolidate", "ff+frag-gradient"] {
        assert_equivalent(name, &cfg, &workload, 42);
    }
}

/// The index-vs-scan contract extends through the rolling ILP repair
/// planner: `mcc+ilp-repair` — whose rejection bursts trigger bounded
/// exact solves and transactional plan applies — decides byte-identically
/// with and without the cluster index. (The node budget is tightened so
/// the test stays quick; determinism is per-budget, so both sides see
/// the same truncation.)
#[test]
fn ilp_repair_composition_decides_identically_indexed_vs_scan() {
    let workload = Workload::generate(TraceConfig::small(42));
    let cfg = PolicyConfig::new().heavy_frac(0.25).ilp_nodes(2_000).ilp_period_hours(24);
    assert_equivalent("mcc+ilp-repair", &cfg, &workload, 42);
}

/// A zero migration budget starves every planner, so budgeted GRMU
/// decides exactly like the dual-basket-only ablation — and a budgeted
/// composed policy exactly like its plain base.
#[test]
fn zero_migration_budget_reduces_to_the_migration_free_variant() {
    use grmu::migrate::MigrationBudget;
    let workload = Workload::generate(TraceConfig::small(42));
    let base = PolicyConfig::new().heavy_frac(0.25);
    let starved = base.clone().migration_budget(MigrationBudget::unlimited().per_interval(0));
    let (dec_a, res_a) = replay_decisions("grmu", &starved, &workload, 42);
    let (dec_b, res_b) = replay_decisions("grmu-db", &base, &workload, 42);
    assert_eq!(dec_a, dec_b, "budget-0 grmu must decide like grmu-db");
    assert_eq!(res_a.migrations(), 0);
    assert_eq!(res_b.migrations(), 0);
    let (dec_c, res_c) = replay_decisions("mcc+defrag", &starved, &workload, 42);
    let (dec_d, _) = replay_decisions("mcc", &base, &workload, 42);
    assert_eq!(dec_c, dec_d, "budget-0 mcc+defrag must decide like mcc");
    assert_eq!(res_c.migrations(), 0);
}

/// Satellite lock for the rolling ILP repair planner: a zero extraction
/// window — and, separately, a zero branch-and-bound node budget —
/// disables the planner entirely, so `mcc+ilp-repair` is byte-identical
/// to bare `mcc` (decisions, samples, rejections, events). The composed
/// variant must be inert until *both* knobs are positive.
#[test]
fn disabled_ilp_planner_reduces_to_the_planner_free_variant() {
    let workload = Workload::generate(TraceConfig::small(42));
    let base = PolicyConfig::new().heavy_frac(0.25);
    let (dec_plain, res_plain) = replay_decisions("mcc", &base, &workload, 42);
    for (label, cfg) in
        [("window 0", base.clone().ilp_window(0)), ("nodes 0", base.clone().ilp_nodes(0))]
    {
        let (dec, res) = replay_decisions("mcc+ilp-repair", &cfg, &workload, 42);
        assert_eq!(dec, dec_plain, "{label}: mcc+ilp-repair must decide like mcc");
        assert_eq!(res.migrations(), 0, "{label}: a disabled planner must never move a VM");
        assert_eq!(res.samples, res_plain.samples, "{label}: samples diverged");
        assert_eq!(res.rejections, res_plain.rejections, "{label}: rejections diverged");
        assert_eq!(
            res.migration_events, res_plain.migration_events,
            "{label}: migration events diverged"
        );
    }
}

// --------------------------------------------------------- ops equivalence

/// Tentpole lock: the simulator-vs-coordinator equivalence extends to
/// runs with GPU/host faults, maintenance drains and an admission
/// queue. Both sides install the same deterministic schedule and are
/// driven to the same interval count, so every metric — including the
/// new ops counters — must match exactly.
#[test]
fn ops_runs_agree_between_simulator_and_coordinator() {
    use grmu::ops::{FaultInjector, OpsConfig, QueueConfig};
    let workload = Workload::generate(TraceConfig::small(42));
    let vms = &workload.vms;
    let last_arrival = vms.last().unwrap().arrival;
    let ops = OpsConfig {
        drain_rate: 1.0,
        host_mtbf_hours: 2_000.0,
        horizon_hours: workload.config.horizon_hours + 48,
        ..OpsConfig::default().with_gpu_mtbf(400.0)
    };
    let qcfg = QueueConfig { capacity: 16, ttl_hours: 12, preemption: false };
    for name in ["ff", "grmu"] {
        let build = || {
            PolicyRegistry::standard()
                .build(name, &PolicyConfig::new().heavy_frac(0.25))
                .unwrap()
        };
        // Simulator side: the shared core on the hourly grid.
        let mut core = EventCore::new(
            DataCenter::new(workload.hosts.clone()),
            build(),
            PolicyCtx::new(42),
        );
        core.set_fault_schedule(FaultInjector::from_config(&ops, &workload.hosts));
        core.set_admission_queue(qcfg);
        core.set_integrity_every(16);
        let mut next = 0usize;
        loop {
            let t_end = core.interval_end();
            let start = next;
            while next < vms.len() && vms[next].arrival <= t_end {
                next += 1;
            }
            core.step(&vms[start..next]);
            let drained = next >= vms.len() && core.pending_departures() == 0;
            let capped = core.hour() * HOUR > last_arrival + 3 * 24 * HOUR;
            if drained || capped {
                break;
            }
        }
        let sim = core.into_result(0.0);
        // The fault model must actually have fired, or the lock is vacuous.
        assert!(sim.interrupted > 0, "{name}: no failure landed on a resident VM");
        assert!(sim.availability < 1.0, "{name}: faults cost no GPU-hours?");
        assert!(
            sim.served_from_queue() + sim.rejected(RejectReason::Expired) > 0,
            "{name}: the queue never engaged"
        );

        // Coordinator side: same schedule and queue, batched per window,
        // then stepped to the simulator's exact interval count.
        let mut coord = Coordinator::with_ctx(
            DataCenter::new(workload.hosts.clone()),
            build(),
            CoordinatorConfig { max_batch: usize::MAX, interval: HOUR },
            PolicyCtx::new(42),
        );
        coord.set_fault_schedule(FaultInjector::from_config(&ops, &workload.hosts));
        coord.set_admission_queue(qcfg);
        let mut i = 0usize;
        while i < vms.len() {
            let w = coord.window_of(vms[i].arrival);
            let mut j = i;
            while j < vms.len() && coord.window_of(vms[j].arrival) == w {
                j += 1;
            }
            let batch: Vec<Request> = vms[i..j].iter().map(|&vm| Request { vm }).collect();
            coord.decide_batch(&batch);
            i = j;
        }
        let closed = coord.window_of(last_arrival) as usize;
        for _ in closed..sim.samples.len() {
            coord.close_interval();
        }
        let coord = coord.into_result();

        assert_eq!(coord.requested, sim.requested, "{name}: requested diverged");
        assert_eq!(coord.accepted, sim.accepted, "{name}: accepted diverged");
        assert_eq!(coord.per_profile, sim.per_profile, "{name}: per-profile diverged");
        assert_eq!(coord.rejections, sim.rejections, "{name}: rejections diverged");
        assert_eq!(
            coord.migration_events, sim.migration_events,
            "{name}: migration events diverged"
        );
        assert_eq!(coord.samples, sim.samples, "{name}: samples diverged");
        assert_eq!(coord.interrupted, sim.interrupted, "{name}: interrupted diverged");
        assert_eq!(coord.preempted, sim.preempted, "{name}: preempted diverged");
        assert_eq!(coord.queue_delays, sim.queue_delays, "{name}: queue delays diverged");
        assert_eq!(coord.availability, sim.availability, "{name}: availability diverged");
    }
}

/// Strictly-additive lock: installing a zero-rate fault schedule and a
/// zero-capacity queue must not perturb a single decision, sample or
/// rejection — the ops hooks are inert until configured.
#[test]
fn disabled_ops_hooks_do_not_perturb_decisions() {
    use grmu::ops::{FaultInjector, OpsConfig, QueueConfig};
    let workload = Workload::generate(TraceConfig::small(42));
    let cfg = PolicyConfig::new().heavy_frac(0.25);
    let (dec_plain, res_plain) = replay_decisions("grmu", &cfg, &workload, 42);

    let policy = PolicyRegistry::standard().build("grmu", &cfg).unwrap();
    let mut core = EventCore::new(
        DataCenter::new(workload.hosts.clone()),
        policy,
        PolicyCtx::new(42),
    );
    core.set_fault_schedule(FaultInjector::from_config(
        &OpsConfig { horizon_hours: 300, ..OpsConfig::default() },
        &workload.hosts,
    ));
    core.set_admission_queue(QueueConfig { capacity: 0, ..QueueConfig::default() });
    core.set_integrity_every(8);
    let vms = &workload.vms;
    let last_arrival = vms.last().map(|v| v.arrival).unwrap_or(0);
    let mut decisions = Vec::new();
    let mut next = 0usize;
    loop {
        let t_end = core.interval_end();
        let start = next;
        while next < vms.len() && vms[next].arrival <= t_end {
            next += 1;
        }
        decisions.extend(core.step(&vms[start..next]));
        let drained = next >= vms.len() && core.pending_departures() == 0;
        let capped = core.hour() * HOUR > last_arrival + 5 * 24 * HOUR;
        if drained || capped {
            break;
        }
    }
    let res = core.into_result(0.0);
    assert_eq!(decisions, dec_plain, "inert ops hooks changed a decision");
    assert_eq!(res.samples, res_plain.samples);
    assert_eq!(res.rejections, res_plain.rejections);
    assert_eq!(res.per_profile, res_plain.per_profile);
    assert_eq!(res.migration_events, res_plain.migration_events);
    assert_eq!(res.interrupted, 0);
    assert_eq!(res.availability, 1.0);
}

// ------------------------------------------------------ sharded engine

/// One identically configured policy instance per shard, the way the
/// experiment layer builds them.
fn shard_policies(name: &str, heavy: f64, n: usize) -> Vec<Box<dyn Policy>> {
    (0..n)
        .map(|_| {
            PolicyRegistry::standard()
                .build(name, &PolicyConfig::new().heavy_frac(heavy))
                .unwrap()
        })
        .collect()
}

/// Tentpole lock #1: `--shards 1` is **byte-identical** to the unsharded
/// engine — every field of the result, plain and with the full ops
/// stack (faults + drains + admission queue) enabled. The router at one
/// shard must be a pure pass-through.
#[test]
fn one_shard_router_is_byte_identical_to_the_engine() {
    use grmu::ops::{OpsConfig, QueueConfig};
    let workload = Workload::generate(TraceConfig::small(42));
    let ops = OpsConfig {
        drain_rate: 1.0,
        host_mtbf_hours: 2_000.0,
        horizon_hours: workload.config.horizon_hours + 48,
        ..OpsConfig::default().with_gpu_mtbf(400.0)
    };
    let qcfg = QueueConfig { capacity: 16, ttl_hours: 12, preemption: false };
    for (label, with_ops) in [("plain", false), ("ops+queue", true)] {
        let mut sim = Simulation::new(
            DataCenter::new(workload.hosts.clone()),
            PolicyRegistry::standard()
                .build("grmu", &PolicyConfig::new().heavy_frac(0.25))
                .unwrap(),
            &workload.vms,
        );
        sim.ctx = PolicyCtx::new(42);
        sim.options =
            SimulationOptions { integrity_every: 8, drain_cap_hours: 5 * 24, ..Default::default() };
        if with_ops {
            sim.options.ops = ops.clone();
            sim.options.queue = qcfg;
        }
        let a = sim.run();

        let mut sharded =
            ShardedSimulation::new(&workload.hosts, shard_policies("grmu", 0.25, 1), &workload.vms);
        sharded.options =
            SimulationOptions { integrity_every: 8, drain_cap_hours: 5 * 24, ..Default::default() };
        if with_ops {
            sharded.options.ops = ops.clone();
            sharded.options.queue = qcfg;
        }
        sharded.shard_options.seed = 42;
        sharded.shard_options.threads = 8; // thread count must be irrelevant
        let b = sharded.run();

        assert_eq!(a.policy, b.policy, "{label}");
        assert_eq!(a.samples, b.samples, "{label}: samples diverged");
        assert_eq!(a.requested, b.requested, "{label}");
        assert_eq!(a.accepted, b.accepted, "{label}");
        assert_eq!(a.per_profile, b.per_profile, "{label}");
        assert_eq!(a.rejections, b.rejections, "{label}");
        assert_eq!(a.migration_events, b.migration_events, "{label}");
        assert_eq!(a.gpus_by_model, b.gpus_by_model, "{label}");
        assert_eq!(a.gpu_activity, b.gpu_activity, "{label}");
        assert_eq!(a.interrupted, b.interrupted, "{label}");
        assert_eq!(a.preempted, b.preempted, "{label}");
        assert_eq!(a.queue_delays, b.queue_delays, "{label}");
        assert_eq!(a.availability, b.availability, "{label}: availability diverged");
        assert!(a.accepted > 0, "{label}: vacuous run");
        if with_ops {
            assert!(a.interrupted > 0, "{label}: the fault model never fired (vacuous lock)");
        }
    }
}

/// Tentpole lock #2: at `shards > 1` the result is a pure function of
/// the trace and the shard count — the fan-out worker count must not
/// change a single byte, with the full ops stack enabled.
#[test]
fn sharded_results_are_thread_count_independent() {
    use grmu::ops::{OpsConfig, QueueConfig};
    let workload = Workload::generate(TraceConfig::small(7));
    let ops = OpsConfig {
        drain_rate: 1.0,
        host_mtbf_hours: 2_000.0,
        horizon_hours: workload.config.horizon_hours + 48,
        ..OpsConfig::default().with_gpu_mtbf(400.0)
    };
    let qcfg = QueueConfig { capacity: 16, ttl_hours: 12, preemption: false };
    let run = |threads: usize| {
        let mut sim =
            ShardedSimulation::new(&workload.hosts, shard_policies("grmu", 0.25, 4), &workload.vms);
        sim.options = SimulationOptions {
            integrity_every: 8,
            drain_cap_hours: 5 * 24,
            ops: ops.clone(),
            queue: qcfg,
            ..Default::default()
        };
        sim.shard_options.shards = 4;
        sim.shard_options.threads = threads;
        sim.shard_options.seed = 7;
        sim.run()
    };
    let base = run(1);
    assert!(base.accepted > 0);
    assert_eq!(base.rejections.iter().sum::<u64>(), base.requested - base.accepted);
    for threads in [2usize, 8] {
        let r = run(threads);
        assert_eq!(base.samples, r.samples, "threads={threads}: samples diverged");
        assert_eq!(base.requested, r.requested, "threads={threads}");
        assert_eq!(base.accepted, r.accepted, "threads={threads}");
        assert_eq!(base.per_profile, r.per_profile, "threads={threads}");
        assert_eq!(base.rejections, r.rejections, "threads={threads}");
        assert_eq!(base.migration_events, r.migration_events, "threads={threads}");
        assert_eq!(base.interrupted, r.interrupted, "threads={threads}");
        assert_eq!(base.preempted, r.preempted, "threads={threads}");
        assert_eq!(base.queue_delays, r.queue_delays, "threads={threads}");
        assert_eq!(base.availability, r.availability, "threads={threads}");
    }
}

/// The indexed-vs-scan lock through the *sharded* engine at
/// `--shards 4`: per-shard policies querying the hierarchical bitset
/// index must produce a `SimResult` byte-identical to per-shard
/// policies brute-force scanning their shard. (The engine's own
/// rebalance scans always run over the per-shard index — `use_index`
/// only toggles the policy-side candidate iteration, which is exactly
/// the equivalence being locked.)
#[test]
fn sharded_indexed_and_scan_policies_decide_identically() {
    let workload = Workload::generate(TraceConfig::small(42));
    let run = |name: &str, use_index: bool| {
        let policies: Vec<Box<dyn Policy>> = (0..4)
            .map(|_| {
                PolicyRegistry::standard()
                    .build(name, &PolicyConfig::new().heavy_frac(0.25).use_index(use_index))
                    .unwrap()
            })
            .collect();
        let mut sim = ShardedSimulation::new(&workload.hosts, policies, &workload.vms);
        sim.options =
            SimulationOptions { integrity_every: 8, drain_cap_hours: 5 * 24, ..Default::default() };
        sim.shard_options.shards = 4;
        sim.shard_options.threads = 4;
        sim.shard_options.seed = 42;
        sim.run()
    };
    for name in ["grmu", "mcc"] {
        let a = run(name, true);
        let b = run(name, false);
        assert!(a.accepted > 0, "{name}: vacuous run");
        assert_eq!(a.samples, b.samples, "{name}: samples diverged");
        assert_eq!(a.requested, b.requested, "{name}");
        assert_eq!(a.accepted, b.accepted, "{name}");
        assert_eq!(a.per_profile, b.per_profile, "{name}");
        assert_eq!(a.rejections, b.rejections, "{name}");
        assert_eq!(a.migration_events, b.migration_events, "{name}");
        assert_eq!(a.gpu_activity, b.gpu_activity, "{name}");
        assert_eq!(a.availability, b.availability, "{name}");
    }
}

/// The sim-vs-coordinator equivalence, sharded: driving the
/// [`ShardedCore`] window by window (`run_until` + `step_buffered`, the
/// coordinator-style surface) produces the same result as
/// [`ShardedSimulation::run`]'s trace loop.
#[test]
fn sharded_sim_and_window_driven_core_agree() {
    let workload = Workload::generate(TraceConfig::small(42));
    let vms = &workload.vms;
    let last_arrival = vms.last().unwrap().arrival;

    let mut sim = ShardedSimulation::new(&workload.hosts, shard_policies("grmu", 0.25, 3), vms);
    sim.options =
        SimulationOptions { integrity_every: 8, drain_cap_hours: 5 * 24, ..Default::default() };
    sim.shard_options.shards = 3;
    sim.shard_options.threads = 2;
    sim.shard_options.seed = 42;
    let a = sim.run();

    let mut core = ShardedCore::new(&workload.hosts, shard_policies("grmu", 0.25, 3), 42, 3, 2);
    core.set_integrity_every(8);
    let mut i = 0usize;
    while i < vms.len() {
        let w = core.window_of(vms[i].arrival);
        let mut j = i;
        while j < vms.len() && core.window_of(vms[j].arrival) == w {
            j += 1;
        }
        core.run_until(w);
        core.step_buffered(&vms[i..j]);
        i = j;
    }
    // Drain with the engine's exact stop conditions.
    while core.pending_departures() > 0 && core.hour() * HOUR <= last_arrival + 5 * 24 * HOUR {
        core.step_buffered(&[]);
    }
    let b = core.into_result(0.0);

    assert_eq!(a.requested, b.requested, "requested diverged");
    assert_eq!(a.accepted, b.accepted, "accepted diverged");
    assert_eq!(a.per_profile, b.per_profile, "per-profile diverged");
    assert_eq!(a.rejections, b.rejections, "rejections diverged");
    assert_eq!(a.migration_events, b.migration_events, "migration events diverged");
    assert_eq!(a.samples, b.samples, "samples diverged");
    assert_eq!(a.availability, b.availability);
}

/// Satellite lock: correlated-failure escalation. A zero blast radius
/// leaves the schedule byte-identical; `p = 1` escalates every host
/// failure across its domain; and a sharded run under blast faults is
/// deterministic with a consistent rejection breakdown.
#[test]
fn blast_radius_amplifies_the_fault_schedule_deterministically() {
    use grmu::ops::{FaultInjector, OpsConfig, OpsEvent};
    let workload = Workload::generate(TraceConfig::small(11));
    let base_ops = OpsConfig {
        host_mtbf_hours: 200.0,
        horizon_hours: workload.config.horizon_hours + 48,
        seed: 11,
        ..OpsConfig::default()
    };
    let host_fails = |ops: &OpsConfig| {
        let (schedule, _) = FaultInjector::from_config(ops, &workload.hosts).into_parts();
        schedule.iter().filter(|(_, e)| matches!(e, OpsEvent::HostFail { .. })).count()
    };
    let base = host_fails(&base_ops);
    assert!(base > 0, "200 h host MTBF must draw failures over the horizon");
    let zero = OpsConfig { blast_radius: 0.0, blast_hosts: 4, ..base_ops.clone() };
    assert_eq!(host_fails(&zero), base, "zero blast radius must not change the schedule");
    let full = OpsConfig { blast_radius: 1.0, blast_hosts: 4, ..base_ops.clone() };
    assert!(host_fails(&full) > base, "p=1 blast must escalate failures across domains");

    let run = || {
        let mut sim =
            ShardedSimulation::new(&workload.hosts, shard_policies("ff", 0.25, 2), &workload.vms);
        sim.options = SimulationOptions {
            integrity_every: 4,
            drain_cap_hours: 3 * 24,
            ops: full.clone(),
            ..Default::default()
        };
        sim.shard_options.shards = 2;
        sim.shard_options.seed = 11;
        sim.run()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.samples, b.samples, "blast runs must be deterministic");
    assert_eq!(a.interrupted, b.interrupted);
    assert_eq!(a.rejections, b.rejections);
    assert_eq!(a.rejections.iter().sum::<u64>(), a.requested - a.accepted);
    assert!(a.availability < 1.0, "domain-wide outages must cost GPU-hours");
}

/// Migration-cost accounting is consistent across layers: the
/// `SimResult` aggregates equal a straight fold over the event log, the
/// migrated-VM share is bounded by the event share, and every event
/// carries the block size of its profile.
#[test]
fn migration_cost_accounting_is_consistent() {
    use grmu::policies::MigrationKind;
    // Find a seed that actually migrates (defrag fires on rejections, so
    // in practice the first one does; the loop keeps the test robust).
    let mut picked = None;
    for seed in [42u64, 19, 7, 23] {
        let workload = Workload::generate(TraceConfig::small(seed));
        let cfg = PolicyConfig::new().heavy_frac(0.2).consolidation_hours(Some(12));
        let (_, res) = replay_decisions("grmu", &cfg, &workload, seed);
        if res.migrations() > 0 {
            picked = Some(res);
            break;
        }
    }
    let res = picked.expect("no seed produced migrations to check accounting on");
    let intra: u64 = res
        .migration_events
        .iter()
        .filter(|e| e.kind == MigrationKind::Intra)
        .map(|e| e.blocks as u64)
        .sum();
    assert_eq!(res.migration_cost(MigrationKind::Intra), intra * MigrationKind::Intra.weight());
    assert_eq!(
        res.total_migration_cost(),
        res.migration_cost(MigrationKind::Intra) + res.migration_cost(MigrationKind::Inter)
    );
    assert!(res.migrated_vm_share() <= res.migration_share());
    assert!(res.migrated_vms() <= res.migrations());
    for e in &res.migration_events {
        assert!(e.blocks > 0 && e.cost() >= e.blocks as u64);
        assert_eq!(e.kind == MigrationKind::Intra, e.from == e.to);
    }
}
