//! Integration tests: the AOT-compiled XLA scorer against the native
//! implementation, end to end. Compiled only with `--features xla`.
//!
//! Gated on `artifacts/cc_scorer.hlo.txt` (built by `make artifacts`);
//! each test skips with a message when the artifact is absent so
//! `cargo test` stays green in a fresh checkout.

use grmu::cluster::DataCenter;
use grmu::mig::gpu::{cc, profile_capacity};
use grmu::policies::{mcc::Mcc, CcScorer, NativeScorer, Policy, PolicyCtx};
use grmu::runtime::XlaScorer;
use grmu::trace::{TraceConfig, Workload};
use std::path::PathBuf;

fn artifact() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/cc_scorer.hlo.txt");
    if p.exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn all_256_masks_bit_identical() {
    let Some(path) = artifact() else { return };
    let mut scorer = XlaScorer::load(&path).unwrap();
    let masks: Vec<u8> = (0..=255).collect();
    let (ccs, caps) = scorer.score_full(&masks).unwrap();
    for (i, &m) in masks.iter().enumerate() {
        assert_eq!(ccs[i], cc(m));
        assert_eq!(caps[i], profile_capacity(m));
    }
}

#[test]
fn whole_trace_decision_parity() {
    let Some(path) = artifact() else { return };
    let workload = Workload::generate(TraceConfig::small(13));
    let run = |scorer: Box<dyn CcScorer>| {
        let mut dc = DataCenter::new(workload.hosts.clone());
        let mut policy = Mcc::new();
        let mut ctx = PolicyCtx::with_scorer(0, scorer);
        let decisions = policy.place_batch(&mut dc, &workload.vms, &mut ctx);
        let locs: Vec<_> = workload.vms.iter().map(|v| dc.locate(v.id)).collect();
        (decisions, locs)
    };
    let native = run(Box::new(NativeScorer));
    let xla = run(Box::new(XlaScorer::load(&path).unwrap()));
    assert_eq!(native.0, xla.0, "decisions diverge");
    assert_eq!(native.1, xla.1, "placements diverge");
}

#[test]
fn odd_batch_sizes_and_remainders() {
    let Some(path) = artifact() else { return };
    let mut scorer = XlaScorer::load(&path).unwrap();
    for n in [1usize, 7, 255, 1024, 1025, 2048 + 13] {
        let masks: Vec<u8> = (0..n).map(|i| ((i * 37) % 256) as u8).collect();
        let (ccs, _) = scorer.score_full(&masks).unwrap();
        assert_eq!(ccs.len(), n);
        for (i, &m) in masks.iter().enumerate() {
            assert_eq!(ccs[i], cc(m), "n={n} i={i}");
        }
    }
}

#[test]
fn scorer_accounting_tracks_calls() {
    let Some(path) = artifact() else { return };
    let mut scorer = XlaScorer::load(&path).unwrap();
    let batch = scorer.batch();
    scorer.score_full(&vec![0u8; batch * 2 + 1]).unwrap();
    assert_eq!(scorer.calls, 3);
    assert_eq!(scorer.configs_scored, (batch * 2 + 1) as u64);
}

#[test]
fn coordinator_serves_through_xla_scorer() {
    let Some(path) = artifact() else { return };
    use grmu::coordinator::{Coordinator, CoordinatorConfig, Request};
    use std::sync::mpsc;
    let workload = Workload::generate(TraceConfig::small(17));
    let ctx = PolicyCtx::with_scorer(17, Box::new(XlaScorer::load(&path).unwrap()));
    let coordinator = Coordinator::with_ctx(
        DataCenter::new(workload.hosts.clone()),
        Box::new(Mcc::new()),
        CoordinatorConfig::default(),
        ctx,
    );
    let (req_tx, req_rx) = mpsc::channel();
    let (resp_tx, resp_rx) = mpsc::channel();
    let handle = std::thread::spawn(move || coordinator.serve(req_rx, resp_tx));
    for vmspec in workload.vms.iter().take(100) {
        req_tx.send(Request { vm: *vmspec }).unwrap();
    }
    drop(req_tx);
    let responses: Vec<_> = resp_rx.iter().collect();
    let stats = handle.join().unwrap();
    assert_eq!(responses.len(), 100);
    assert_eq!(stats.requests, 100);
    assert!(stats.accepted > 0);
    assert!(stats.throughput() > 0.0);
}
