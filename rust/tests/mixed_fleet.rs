//! Integration tests for heterogeneous (multi-model) fleets: end-to-end
//! simulation over mixed A30 / A100-40 / H100-80 clusters, model-routing
//! invariants (a GI only ever lands on a GPU of its own model), per-model
//! accounting, and a property test of `check_integrity` under random
//! place/remove/migrate/relocate on mixed clusters.

use grmu::cluster::{DataCenter, GpuRef, Host, VmSpec};
use grmu::mig::placement::mock_assign;
use grmu::mig::{GpuModel, Placement};
use grmu::policies::{PolicyConfig, PolicyCtx, PolicyRegistry};
use grmu::sim::{Simulation, SimulationOptions};
use grmu::trace::{TraceConfig, Workload};
use grmu::util::prop::forall;
use grmu::util::rng::Rng;

fn mixed_workload(seed: u64) -> Workload {
    Workload::generate(TraceConfig {
        gpu_models: vec![
            (GpuModel::A30, 0.3),
            (GpuModel::A100_40, 0.4),
            (GpuModel::H100_80, 0.3),
        ],
        ..TraceConfig::small(seed)
    })
}

#[test]
fn all_policies_run_mixed_fleets_end_to_end() {
    // The acceptance-criterion scenario: a30:0.3,a100-40:0.4,h100-80:0.3
    // runs through every policy with integrity checks on, and the typed
    // rejection breakdown stays exact.
    let workload = mixed_workload(42);
    // names() includes the composed base+planner migration variants, so
    // this also drives the planner layer end-to-end on a mixed fleet.
    for name in PolicyRegistry::standard().names() {
        let policy = PolicyRegistry::standard()
            .build(&name, &PolicyConfig::new().heavy_frac(0.3).consolidation_hours(Some(24)))
            .unwrap();
        let dc = DataCenter::new(workload.hosts.clone());
        let mut sim = Simulation::new(dc, policy, &workload.vms);
        sim.ctx = PolicyCtx::new(42);
        sim.options =
            SimulationOptions { integrity_every: 13, drain_cap_hours: 10 * 24, ..Default::default() };
        let r = sim.run();
        assert!(r.requested > 0);
        assert!(r.accepted > 0, "{name}: accepted nothing on a mixed fleet");
        assert_eq!(
            r.rejections.iter().sum::<u64>(),
            r.requested - r.accepted,
            "{name}: rejection breakdown mismatch"
        );
        // Per-model rollup partitions the totals.
        let by_model = r.per_model_requests();
        assert_eq!(by_model.iter().map(|(q, _)| q).sum::<u64>(), r.requested, "{name}");
        assert_eq!(by_model.iter().map(|(_, a)| a).sum::<u64>(), r.accepted, "{name}");
        // Every fleet model saw requests; the absent model saw none.
        assert_eq!(by_model[GpuModel::A100_80 as usize], (0, 0), "{name}");
        for m in [GpuModel::A30, GpuModel::A100_40, GpuModel::H100_80] {
            assert!(by_model[m as usize].0 > 0, "{name}: no {m} requests");
            assert!(r.gpus_by_model[m as usize] > 0, "{name}: no {m} GPUs");
        }
    }
}

#[test]
fn placements_always_respect_model_compatibility() {
    let workload = mixed_workload(7);
    for name in ["ff", "bf", "mcc", "mecc", "grmu"] {
        let policy = PolicyRegistry::standard()
            .build(name, &PolicyConfig::new().heavy_frac(0.3))
            .unwrap();
        let mut dc = DataCenter::new(workload.hosts.clone());
        let mut p = policy;
        let mut ctx = PolicyCtx::default();
        let decisions = p.place_batch(&mut dc, &workload.vms, &mut ctx);
        for (vm, d) in workload.vms.iter().zip(&decisions) {
            if let Some(r) = d.gpu() {
                assert_eq!(
                    dc.gpu(r).model(),
                    vm.profile.model(),
                    "{name}: VM {} landed cross-model",
                    vm.id
                );
            }
        }
        dc.check_integrity().unwrap();
    }
}

#[test]
fn grmu_heavy_basket_serves_every_models_whole_gpu_profile() {
    // One host per model; whole-GPU requests of each model route through
    // the heavy basket (is_heavy generalizes beyond 7g.40gb).
    let hosts = vec![
        Host::with_models(0, 256, 1024, &[GpuModel::A30, GpuModel::A30]),
        Host::with_models(1, 256, 1024, &[GpuModel::A100_40, GpuModel::A100_40]),
        Host::with_models(2, 256, 1024, &[GpuModel::H100_80, GpuModel::H100_80]),
    ];
    let mut dc = DataCenter::new(hosts);
    let mut policy = PolicyRegistry::standard()
        .build("grmu", &PolicyConfig::new().heavy_frac(0.5))
        .unwrap();
    let heavy = |m: GpuModel| m.profile(m.num_profiles() - 1);
    assert!(heavy(GpuModel::A30).is_heavy());
    let vms: Vec<VmSpec> = [GpuModel::A30, GpuModel::A100_40, GpuModel::H100_80]
        .iter()
        .enumerate()
        .map(|(i, &m)| VmSpec {
            id: i as u64 + 1,
            profile: heavy(m),
            cpus: 2,
            ram_gb: 4,
            arrival: 0,
            departure: 1_000_000,
            weight: 1.0,
        })
        .collect();
    let mut ctx = PolicyCtx::default();
    let out = policy.place_batch(&mut dc, &vms, &mut ctx);
    // Heavy capacity is 3 of 6 GPUs; each request needs its own model,
    // and the heavy basket grows from the pool per model as needed.
    assert!(out.iter().all(|d| d.is_placed()), "heavy per-model requests should all place");
    for (vm, d) in vms.iter().zip(&out) {
        let r = d.gpu().unwrap();
        assert_eq!(dc.gpu(r).model(), vm.profile.model());
        assert_eq!(dc.gpu(r).free_blocks(), 0, "whole-GPU profile fills the part");
    }
    dc.check_integrity().unwrap();
}

#[test]
fn migration_events_stay_model_coherent_on_mixed_fleets() {
    // Every migration a policy performs on a mixed fleet — GRMU's
    // basket-scoped planners and the cluster-scoped composed stacks
    // alike — records source and destination GPUs of the event's own
    // model, and intra events never change GPUs.
    use grmu::policies::MigrationKind;
    let workload = mixed_workload(42);
    for name in ["grmu", "mcc+defrag", "ff+consolidate", "ff+defrag+frag-gradient"] {
        let policy = PolicyRegistry::standard()
            .build(
                name,
                &PolicyConfig::new()
                    .heavy_frac(0.2)
                    .consolidation_hours(Some(12))
                    .frag_threshold(0.5),
            )
            .unwrap();
        let dc = DataCenter::new(workload.hosts.clone());
        let mut sim = Simulation::new(dc, policy, &workload.vms);
        sim.ctx = PolicyCtx::new(42);
        sim.options =
            SimulationOptions { integrity_every: 17, drain_cap_hours: 10 * 24, ..Default::default() };
        let r = sim.run();
        // Rebuild a fleet map to resolve each event's GPUs.
        let fleet = DataCenter::new(workload.hosts.clone());
        for ev in &r.migration_events {
            assert_eq!(fleet.gpu(ev.from).model(), ev.model, "{name}: {ev:?}");
            assert_eq!(fleet.gpu(ev.to).model(), ev.model, "{name}: {ev:?}");
            assert_eq!(ev.kind == MigrationKind::Intra, ev.from == ev.to, "{name}: {ev:?}");
            assert!(ev.blocks > 0, "{name}: {ev:?}");
        }
        assert_eq!(
            r.total_migration_cost(),
            r.migration_events.iter().map(|e| e.cost()).sum::<u64>(),
            "{name}"
        );
    }
}

#[test]
fn mixed_fleet_simulation_is_deterministic() {
    let workload = mixed_workload(11);
    let run = || {
        let policy = PolicyRegistry::standard()
            .build("grmu", &PolicyConfig::new().heavy_frac(0.2).consolidation_hours(Some(12)))
            .unwrap();
        let mut sim =
            Simulation::new(DataCenter::new(workload.hosts.clone()), policy, &workload.vms);
        sim.ctx = PolicyCtx::new(11);
        sim.options.drain_cap_hours = 7 * 24;
        sim.run()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.accepted, b.accepted);
    assert_eq!(a.per_profile, b.per_profile);
    assert_eq!(a.migration_events, b.migration_events);
    assert_eq!(a.gpu_activity, b.gpu_activity);
    assert_eq!(a.samples, b.samples);
}

/// Satellite acceptance: `check_integrity` holds on mixed A30/A100/H100
/// clusters under random place/remove/migrate/relocate sequences (the
/// integration-level twin of the `cluster::index` property test, driven
/// through the public DataCenter API on a larger mixed topology).
#[test]
fn prop_mixed_cluster_integrity_under_random_ops() {
    let models = [GpuModel::A30, GpuModel::A100_40, GpuModel::H100_80, GpuModel::A100_80];
    forall(
        "mixed-cluster-integrity",
        |r: &mut Rng| {
            // 3-6 hosts, each with 1-3 GPUs of random models.
            let hosts: Vec<Host> = (0..3 + r.below(4))
                .map(|i| {
                    let gpus: Vec<GpuModel> = (0..1 + r.below(3))
                        .map(|_| models[r.below(models.len() as u64) as usize])
                        .collect();
                    Host::with_models(i as u32, 8 + r.below(16) as u32, 32 + r.below(64) as u32, &gpus)
                })
                .collect();
            let mut dc = DataCenter::new(hosts);
            let refs: Vec<GpuRef> = dc.gpu_refs();
            let mut next_vm = 1u64;
            let mut resident: Vec<u64> = Vec::new();
            for _ in 0..64 {
                match r.below(4) {
                    0 | 1 => {
                        let gr = refs[r.below(refs.len() as u64) as usize];
                        let model = dc.gpu(gr).model();
                        let profile =
                            model.profile(r.below(model.num_profiles() as u64) as usize);
                        let vm = VmSpec {
                            id: next_vm,
                            profile,
                            cpus: 1 + r.below(3) as u32,
                            ram_gb: 1 + r.below(4) as u32,
                            arrival: 0,
                            departure: 1_000,
                            weight: 1.0,
                        };
                        if dc.host(gr.host).fits_resources(vm.cpus, vm.ram_gb) {
                            if let Some((pl, _)) = mock_assign(dc.gpu(gr).occupancy(), profile) {
                                dc.place(&vm, gr, pl);
                                resident.push(next_vm);
                                next_vm += 1;
                            }
                        }
                    }
                    2 => {
                        if !resident.is_empty() {
                            let i = r.below(resident.len() as u64) as usize;
                            dc.remove(resident.swap_remove(i));
                        }
                    }
                    _ => {
                        if resident.is_empty() {
                            continue;
                        }
                        let vm = resident[r.below(resident.len() as u64) as usize];
                        let loc = dc.locate(vm).unwrap();
                        if r.chance(0.5) {
                            // Relocate within the same GPU.
                            let occ = dc.gpu(loc.gpu).occupancy() & !loc.placement.mask();
                            let starts: Vec<u8> = loc
                                .placement
                                .profile
                                .start_blocks()
                                .iter()
                                .copied()
                                .filter(|&s| {
                                    let m = Placement {
                                        profile: loc.placement.profile,
                                        start: s,
                                    }
                                    .mask();
                                    occ & m == 0
                                })
                                .collect();
                            let s = starts[r.below(starts.len() as u64) as usize];
                            dc.relocate_within_gpu(
                                vm,
                                Placement { profile: loc.placement.profile, start: s },
                            );
                        } else {
                            // Migrate to a model-compatible GPU.
                            let dst = refs[r.below(refs.len() as u64) as usize];
                            if dst == loc.gpu
                                || dc.gpu(dst).model() != loc.placement.profile.model()
                            {
                                continue;
                            }
                            let (cpus, ram) = dc.vm_demands(vm).unwrap();
                            if dst.host != loc.gpu.host
                                && !dc.host(dst.host).fits_resources(cpus, ram)
                            {
                                continue;
                            }
                            if let Some((pl, _)) =
                                mock_assign(dc.gpu(dst).occupancy(), loc.placement.profile)
                            {
                                dc.migrate(vm, dst, pl);
                            }
                        }
                    }
                }
            }
            dc
        },
        |dc| dc.check_integrity().map_err(|e| format!("integrity: {e}")),
    );
}

#[test]
fn foreign_profile_requests_reject_not_crash() {
    // An A100-80 request against a fleet with no A100-80s must reject
    // cleanly (fragmentation/no-fit taxonomy), never place cross-model.
    let hosts = vec![Host::with_models(0, 64, 256, &[GpuModel::A100_40, GpuModel::A30])];
    let workload_vm = VmSpec {
        id: 1,
        profile: GpuModel::A100_80.profile(0),
        cpus: 2,
        ram_gb: 4,
        arrival: 0,
        departure: 100,
        weight: 1.0,
    };
    for name in PolicyRegistry::standard().names() {
        let mut dc = DataCenter::new(hosts.clone());
        let mut policy = PolicyRegistry::standard()
            .build(&name, &PolicyConfig::new())
            .unwrap();
        let mut ctx = PolicyCtx::default();
        let out = policy.place_batch(&mut dc, &[workload_vm], &mut ctx);
        assert!(!out[0].is_placed(), "{name}: placed a foreign-model GI");
        assert!(out[0].reject_reason().is_some(), "{name}");
        dc.check_integrity().unwrap();
    }
}

#[test]
fn a100_profile_stream_never_uses_foreign_keys() {
    // Cross-check with the trace layer: an A100-only workload keeps all
    // accounting inside the first six dense slots.
    let w = Workload::generate(TraceConfig::small(5));
    assert!(w.vms.iter().all(|v| v.profile.model() == GpuModel::A100_40));
    assert!(w.report.profile_counts[6..].iter().all(|&c| c == 0));
}
