//! Integration tests: the paper's evaluation *shapes* on a reduced-scale
//! workload — the directional claims of §8.2–8.3 that must survive any
//! reasonable re-synthesis of the trace.
//!
//! (The full-scale run lives in `examples/policy_comparison.rs` and
//! EXPERIMENTS.md; these tests keep the shapes from regressing.)

use grmu::mig::Profile;
use grmu::report::experiments::{
    consolidation_sweep, heavy_capacity_sweep, policy_comparison, ExperimentConfig,
};
use grmu::trace::{TraceConfig, Workload};

/// A mid-scale workload with the full-scale scarcity profile (more pods
/// per GPU than `TraceConfig::small`, which is over-provisioned).
fn scarce_workload(seed: u64) -> (Workload, ExperimentConfig) {
    let trace = TraceConfig {
        seed,
        num_hosts: 150,
        num_pods: 1_100,
        horizon_hours: 21 * 24,
        ..TraceConfig::default()
    };
    let cfg = ExperimentConfig {
        trace: trace.clone(),
        heavy_frac: 0.15,
        consolidation_hours: None,
        drain_cap_hours: 14 * 24,
    };
    (Workload::generate(trace), cfg)
}

#[test]
fn fig10_grmu_wins_overall_acceptance() {
    let (w, cfg) = scarce_workload(42);
    let results = policy_comparison(&w, &cfg);
    let get = |n: &str| results.iter().find(|r| r.policy == n).unwrap();
    let grmu = get("GRMU");
    for r in &results {
        if r.policy != "GRMU" {
            assert!(
                grmu.overall_acceptance() > r.overall_acceptance(),
                "GRMU {:.4} not above {} {:.4}",
                grmu.overall_acceptance(),
                r.policy,
                r.overall_acceptance()
            );
        }
    }
    // MCC is the strongest baseline (paper: GRMU +22% over second-best MCC).
    let mcc = get("MCC");
    for r in &results {
        if r.policy != "GRMU" && r.policy != "MCC" {
            assert!(mcc.overall_acceptance() >= r.overall_acceptance());
        }
    }
}

#[test]
fn fig11_profile_crossover_shape() {
    let (w, cfg) = scarce_workload(42);
    let results = policy_comparison(&w, &cfg);
    let get = |n: &str| results.iter().find(|r| r.policy == n).unwrap();
    let grmu = get("GRMU").per_profile_acceptance();
    let mcc = get("MCC").per_profile_acceptance();
    // GRMU sacrifices 7g.40gb (quota) ...
    let h = Profile::P7g40gb.index();
    assert!(grmu[h] < mcc[h], "GRMU should lose 7g.40gb: {} vs {}", grmu[h], mcc[h]);
    // ... and wins the mid profiles (3g/4g — the paper's 1.43x / 2.29x).
    for p in [Profile::P3g20gb, Profile::P4g20gb] {
        assert!(
            grmu[p.index()] > mcc[p.index()],
            "GRMU should win {p}: {} vs {}",
            grmu[p.index()],
            mcc[p.index()]
        );
    }
}

#[test]
fn fig12_table6_active_hardware_ordering() {
    let (w, cfg) = scarce_workload(42);
    let results = policy_comparison(&w, &cfg);
    let auc = |n: &str| results.iter().find(|r| r.policy == n).unwrap().active_auc();
    // GRMU least active hardware; MCC/MECC the most (paper Table 6).
    assert!(auc("GRMU") < auc("FF"));
    assert!(auc("GRMU") < auc("BF"));
    assert!(auc("FF") < auc("MCC"));
    assert!(auc("BF") < auc("MCC"));
    assert!((auc("MECC") - auc("MCC")).abs() / auc("MCC") < 0.05);
}

#[test]
fn migrations_only_grmu_and_small() {
    let (w, cfg) = scarce_workload(42);
    let results = policy_comparison(&w, &cfg);
    for r in &results {
        if r.policy == "GRMU" {
            assert!(
                r.migration_share() < 0.05,
                "GRMU migration share too high: {:.3}",
                r.migration_share()
            );
        } else {
            assert_eq!(r.migrations(), 0, "{} migrated", r.policy);
        }
    }
}

#[test]
fn fig7_heavy_capacity_tradeoff() {
    let (w, cfg) = scarce_workload(42);
    let sweep = heavy_capacity_sweep(&w, &[0.1, 0.5], &cfg);
    let h = Profile::P7g40gb.index();
    let lo = &sweep[0].1;
    let hi = &sweep[1].1;
    // 7g.40gb acceptance rises with capacity; light profiles fall.
    assert!(hi.per_profile_acceptance()[h] > lo.per_profile_acceptance()[h]);
    let light_lo: f64 = (0..5).map(|p| lo.per_profile_acceptance()[p]).sum();
    let light_hi: f64 = (0..5).map(|p| hi.per_profile_acceptance()[p]).sum();
    assert!(light_hi < light_lo, "light profiles should pay for heavy capacity");
    // Active hardware rises with heavy capacity (Fig. 6).
    assert!(hi.average_active_rate() >= lo.average_active_rate() - 0.01);
}

#[test]
fn fig9_consolidation_tradeoff() {
    let (w, cfg) = scarce_workload(42);
    let sweep = consolidation_sweep(&w, &[6, 96], &cfg);
    let get = |label: &str| sweep.iter().find(|(l, _)| l == label).unwrap();
    let db = &get("DB").1;
    let disabled = &get("Disabled").1;
    let fast = &get("6h").1;
    let slow = &get("96h").1;
    // DB performs zero migrations; consolidation variants migrate more
    // the shorter the interval.
    assert_eq!(db.migrations(), 0);
    assert!(fast.inter_migrations() >= slow.inter_migrations());
    // Consolidation cannot hurt acceptance on the same stream.
    assert!(fast.overall_acceptance() >= disabled.overall_acceptance() - 0.02);
    // And it reduces (or equals) active hardware vs Disabled.
    assert!(fast.average_active_rate() <= disabled.average_active_rate() + 0.005);
}

#[test]
fn shapes_hold_across_seeds() {
    // The headline ordering is not a seed artifact.
    for seed in [7u64, 99] {
        let (w, cfg) = scarce_workload(seed);
        let results = policy_comparison(&w, &cfg);
        let get = |n: &str| results.iter().find(|r| r.policy == n).unwrap();
        assert!(
            get("GRMU").overall_acceptance() > get("FF").overall_acceptance(),
            "seed {seed}: GRMU ≤ FF"
        );
        assert!(
            get("GRMU").active_auc() < get("MCC").active_auc(),
            "seed {seed}: GRMU hardware ≥ MCC"
        );
    }
}
