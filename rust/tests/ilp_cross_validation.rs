//! Integration tests: the exact ILP (Eq. 3–26) against an independent
//! brute-force enumerator and against the heuristics.
//!
//! The brute-force enumerator shares *no code* with the MILP: it searches
//! over explicit (GPU, start-block) assignments using the placement
//! bitmasks, so agreement pins both the model and the solver.

use grmu::cluster::VmSpec;
use grmu::ilp::model::{IlpHost, PlacementInstance};
use grmu::ilp::{IlpSolver, NodeBudget};
use grmu::mig::profiles::{Placement, ALL_PROFILES};
use grmu::mig::Profile;
use grmu::util::rng::Rng;
use std::collections::HashMap;

/// Exhaustive optimum: maximize accepted weight, then minimize active
/// hardware among weight-optimal solutions. Exponential — tiny inputs only.
fn brute_force(inst: &PlacementInstance) -> (f64, f64) {
    struct State {
        gpu_occ: Vec<u8>,
        host_cpu: Vec<u32>,
        host_ram: Vec<u32>,
    }
    fn gpu_host(inst: &PlacementInstance, gpu: usize) -> usize {
        let mut g = gpu;
        for (j, h) in inst.hosts.iter().enumerate() {
            if g < h.num_gpus {
                return j;
            }
            g -= h.num_gpus;
        }
        unreachable!()
    }
    fn active_hw(inst: &PlacementInstance, placed: &[Option<(usize, u8)>], vms: &[VmSpec]) -> f64 {
        let total_gpus: usize = inst.hosts.iter().map(|h| h.num_gpus).sum();
        let mut host_active = vec![false; inst.hosts.len()];
        let mut gpu_active = vec![false; total_gpus];
        for (i, p) in placed.iter().enumerate() {
            let _ = &vms[i];
            if let Some((gpu, _)) = p {
                host_active[gpu_host(inst, *gpu)] = true;
                gpu_active[*gpu] = true;
            }
        }
        let mut units = 0.0;
        for (j, h) in inst.hosts.iter().enumerate() {
            if host_active[j] {
                units += h.weight;
            }
        }
        for (g, active) in gpu_active.iter().enumerate() {
            if *active {
                units += inst.hosts[gpu_host(inst, g)].weight;
            }
        }
        units
    }
    fn recurse(
        inst: &PlacementInstance,
        vms: &[VmSpec],
        i: usize,
        state: &mut State,
        placed: &mut Vec<Option<(usize, u8)>>,
        best: &mut (f64, f64),
    ) {
        if i == vms.len() {
            let weight: f64 = placed
                .iter()
                .zip(vms)
                .filter(|(p, _)| p.is_some())
                .map(|(_, vm)| vm.weight)
                .sum();
            let hw = active_hw(inst, placed, vms);
            if weight > best.0 + 1e-9 || (weight > best.0 - 1e-9 && hw < best.1 - 1e-9) {
                *best = (weight, hw);
            }
            return;
        }
        // Option 1: reject VM i.
        placed.push(None);
        recurse(inst, vms, i + 1, state, placed, best);
        placed.pop();
        // Option 2: every legal (gpu, start).
        let vm = &vms[i];
        for gpu in 0..state.gpu_occ.len() {
            let host = gpu_host(inst, gpu);
            if state.host_cpu[host] < vm.cpus || state.host_ram[host] < vm.ram_gb {
                continue;
            }
            for &start in vm.profile.start_blocks() {
                let mask = Placement { profile: vm.profile, start }.mask();
                if state.gpu_occ[gpu] & mask != 0 {
                    continue;
                }
                state.gpu_occ[gpu] |= mask;
                state.host_cpu[host] -= vm.cpus;
                state.host_ram[host] -= vm.ram_gb;
                placed.push(Some((gpu, start)));
                recurse(inst, vms, i + 1, state, placed, best);
                placed.pop();
                state.host_cpu[host] += vm.cpus;
                state.host_ram[host] += vm.ram_gb;
                state.gpu_occ[gpu] &= !mask;
            }
        }
    }
    let total_gpus: usize = inst.hosts.iter().map(|h| h.num_gpus).sum();
    let mut state = State {
        gpu_occ: vec![0; total_gpus],
        host_cpu: inst.hosts.iter().map(|h| h.cpus).collect(),
        host_ram: inst.hosts.iter().map(|h| h.ram_gb).collect(),
    };
    let mut best = (0.0, f64::INFINITY);
    recurse(inst, &inst.vms, 0, &mut state, &mut Vec::new(), &mut best);
    if best.1.is_infinite() {
        best.1 = 0.0;
    }
    best
}

fn vm(id: u64, profile: Profile, weight: f64) -> VmSpec {
    VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 10, weight }
}

#[test]
fn ilp_matches_brute_force_on_fixed_cases() {
    let cases: Vec<PlacementInstance> = vec![
        // One GPU, competing pair.
        PlacementInstance {
            hosts: vec![IlpHost { cpus: 16, ram_gb: 64, num_gpus: 1, weight: 1.0 }],
            vms: vec![vm(1, Profile::P7g40gb, 1.0), vm(2, Profile::P3g20gb, 1.0)],
            prior: HashMap::new(),
        },
        // Two GPUs on one host, mixed profiles.
        PlacementInstance {
            hosts: vec![IlpHost { cpus: 32, ram_gb: 128, num_gpus: 2, weight: 1.0 }],
            vms: vec![
                vm(1, Profile::P4g20gb, 1.0),
                vm(2, Profile::P4g20gb, 1.0),
                vm(3, Profile::P3g20gb, 1.0),
            ],
            prior: HashMap::new(),
        },
        // Weighted: big VM worth more than two smalls.
        PlacementInstance {
            hosts: vec![IlpHost { cpus: 16, ram_gb: 64, num_gpus: 1, weight: 1.0 }],
            vms: vec![
                vm(1, Profile::P7g40gb, 5.0),
                vm(2, Profile::P2g10gb, 1.0),
                vm(3, Profile::P2g10gb, 1.0),
            ],
            prior: HashMap::new(),
        },
        // CPU-bound host.
        PlacementInstance {
            hosts: vec![IlpHost { cpus: 3, ram_gb: 64, num_gpus: 2, weight: 1.0 }],
            vms: vec![vm(1, Profile::P1g5gb, 1.0), vm(2, Profile::P1g5gb, 1.0)],
            prior: HashMap::new(),
        },
    ];
    for (idx, inst) in cases.iter().enumerate() {
        let (bf_weight, bf_hw) = brute_force(inst);
        let sol = IlpSolver::new(inst.clone()).solve().expect("feasible");
        assert!(
            (sol.acceptance - bf_weight).abs() < 1e-6,
            "case {idx}: ILP acceptance {} vs brute force {bf_weight}",
            sol.acceptance
        );
        assert!(
            (sol.active_hardware - bf_hw).abs() < 1e-6,
            "case {idx}: ILP hardware {} vs brute force {bf_hw}",
            sol.active_hardware
        );
    }
}

#[test]
fn ilp_matches_brute_force_on_random_cases() {
    let mut rng = Rng::new(777);
    for case in 0..8 {
        let n_vms = 3;
        let vms: Vec<VmSpec> = (0..n_vms)
            .map(|i| {
                vm(
                    i as u64 + 1,
                    *rng.pick(&ALL_PROFILES),
                    rng.range_inclusive(1, 3) as f64,
                )
            })
            .collect();
        let inst = PlacementInstance {
            hosts: vec![IlpHost { cpus: 32, ram_gb: 128, num_gpus: 2, weight: 1.0 }],
            vms,
            prior: HashMap::new(),
        };
        let (bf_weight, bf_hw) = brute_force(&inst);
        let sol = IlpSolver::new(inst).solve().expect("feasible");
        assert!(
            (sol.acceptance - bf_weight).abs() < 1e-6,
            "case {case}: {} vs {bf_weight}",
            sol.acceptance
        );
        assert!(
            (sol.active_hardware - bf_hw).abs() < 1e-6,
            "case {case}: hw {} vs {bf_hw}",
            sol.active_hardware
        );
    }
}

#[test]
fn heuristics_never_beat_the_ilp_bound() {
    use grmu::cluster::{DataCenter, Host};
    use grmu::policies::{Policy, PolicyConfig, PolicyCtx, PolicyRegistry};
    let mut rng = Rng::new(31337);
    let registry = PolicyRegistry::standard();
    let cfg = PolicyConfig::new().heavy_frac(0.5);
    for _ in 0..6 {
        let vms: Vec<VmSpec> =
            (0..4).map(|i| vm(i as u64 + 1, *rng.pick(&ALL_PROFILES), 1.0)).collect();
        let inst = PlacementInstance {
            hosts: vec![IlpHost { cpus: 64, ram_gb: 256, num_gpus: 2, weight: 1.0 }],
            vms: vms.clone(),
            prior: HashMap::new(),
        };
        let sol = IlpSolver::new(inst).solve().unwrap();
        for policy in registry.names() {
            let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
            let mut p = registry.build(&policy, &cfg).unwrap();
            let mut ctx = PolicyCtx::default();
            let accepted = p
                .place_batch(&mut dc, &vms, &mut ctx)
                .iter()
                .filter(|d| d.is_placed())
                .count() as f64;
            assert!(
                accepted <= sol.acceptance + 1e-6,
                "{policy} beat the exact optimum: {accepted} > {}",
                sol.acceptance
            );
        }
    }
}

// ------------------------------------------------- online ILP repair

/// Online extraction cross-validates against the enumerator: a bounded
/// instance carved out of a *live* cluster (residents as priors, pending
/// rejects as demand) must reach the same acceptance weight and active
/// hardware under the unlimited offline solve, under the node-limited
/// online solve, and under brute force. Small clusters leave the node
/// budget no room to truncate, so all three must agree exactly.
#[test]
fn online_extraction_matches_the_offline_optimum_on_small_clusters() {
    use grmu::cluster::{DataCenter, GpuRef, Host};
    use grmu::ilp::online::{build_instance, fragmented_window, MAX_INSTANCE_VMS};
    use grmu::mig::GpuModel;
    use grmu::migrate::PlanScope;
    let mut rng = Rng::new(4242);
    let one_g_starts = [0u8, 1, 2, 3, 4, 5, 6];
    let two_g_starts = [0u8, 2, 4];
    for case in 0..6 {
        // One host, two GPUs; one resident per GPU at a random legal
        // start, plus one or two pending rejects.
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let s0 = *rng.pick(&one_g_starts);
        let s1 = *rng.pick(&two_g_starts);
        dc.place(
            &vm(1, Profile::P1g5gb, 1.0),
            GpuRef { host: 0, gpu: 0 },
            Placement { profile: Profile::P1g5gb, start: s0 },
        );
        dc.place(
            &vm(2, Profile::P2g10gb, 1.0),
            GpuRef { host: 0, gpu: 1 },
            Placement { profile: Profile::P2g10gb, start: s1 },
        );
        let pending: Vec<VmSpec> = (0..rng.range_inclusive(1, 2))
            .map(|i| vm(10 + i, *rng.pick(&ALL_PROFILES), rng.range_inclusive(1, 3) as f64))
            .collect();
        let window = fragmented_window(&dc, PlanScope::Cluster, GpuModel::A100_40, 8);
        assert_eq!(window.len(), 2, "case {case}: both healthy GPUs must enter the window");
        let ex = build_instance(&dc, &window, &pending, MAX_INSTANCE_VMS, &|_| 1.0);
        let (bf_weight, bf_hw) = brute_force(&ex.inst);
        let offline = IlpSolver::new(ex.inst.clone()).solve().expect("feasible");
        let online =
            IlpSolver::new(ex.inst.clone()).solve_budgeted(NodeBudget::Nodes(200_000)).expect("feasible");
        for (label, sol) in [("offline", &offline), ("online", &online)] {
            assert!(
                (sol.acceptance - bf_weight).abs() < 1e-6,
                "case {case} {label}: acceptance {} vs brute force {bf_weight}",
                sol.acceptance
            );
            assert!(
                (sol.active_hardware - bf_hw).abs() < 1e-6,
                "case {case} {label}: hardware {} vs brute force {bf_hw}",
                sol.active_hardware
            );
        }
    }
}

/// Per-GPU state summary for the rollback assertions below: occupancy
/// masks plus the sorted resident set, per host.
fn fingerprint(dc: &grmu::cluster::DataCenter) -> Vec<Vec<(u8, Vec<u64>)>> {
    dc.hosts()
        .iter()
        .map(|h| {
            h.gpus()
                .iter()
                .map(|g| {
                    let mut vms: Vec<u64> = g.instances().iter().map(|i| i.vm).collect();
                    vms.sort_unstable();
                    (g.occupancy(), vms)
                })
                .collect()
        })
        .collect()
}

/// Transactionality under adversarial staleness: a plan the rolling ILP
/// produced against a snapshot is applied *after* the cluster mutated
/// under it (interlopers now occupy every block the repack could
/// target). `apply_plan` must refuse the stale plan wholesale — no
/// half-applied state, fingerprint unchanged, integrity green — while
/// the identical plan still applies cleanly to the un-mutated snapshot.
#[test]
fn stale_ilp_plans_roll_back_without_corrupting_the_cluster() {
    use grmu::cluster::{DataCenter, GpuRef, Host};
    use grmu::ilp::RollingIlp;
    use grmu::migrate::{MigrationPlan, MigrationPlanner, PlanCtx, PlanScope, PlanTrigger};
    let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
    let g0 = GpuRef { host: 0, gpu: 0 };
    // Strays at blocks 2 and 4: the stray at 2 blocks a pending
    // 4g.20gb (sole legal start 0), so the repair must relocate it
    // into the upper half (block 5 or 6 — 4 is taken).
    let place = |dc: &mut DataCenter, id: u64, start: u8| {
        dc.place(
            &vm(id, Profile::P1g5gb, 1.0),
            g0,
            Placement { profile: Profile::P1g5gb, start },
        );
    };
    place(&mut dc, 1, 2);
    place(&mut dc, 2, 4);
    let pending = [vm(10, Profile::P4g20gb, 1.0)];
    let mut planner = RollingIlp::new(8, 50_000, 24);
    let mut plan = MigrationPlan::new();
    let ctx = PlanCtx {
        now: 0,
        trigger: PlanTrigger::Rejection,
        scope: PlanScope::Cluster,
        pending: &pending,
    };
    planner.plan(&dc, &ctx, &mut plan);
    assert!(!plan.is_empty(), "the stray 1g must be planned out of blocks 0..4");

    // The plan applies cleanly to the state it was planned against.
    let mut fresh = dc.clone();
    fresh.apply_plan(&plan).expect("plan must fit its own snapshot");
    fresh.check_integrity().unwrap();
    assert_eq!(fresh.gpu(g0).occupancy() & 0b0000_1111, 0, "blocks 0..4 must be vacated");

    // Adversary: fill both remaining upper-half starts before applying.
    place(&mut dc, 8, 5);
    place(&mut dc, 9, 6);
    let before = fingerprint(&dc);
    let err = dc.apply_plan(&plan);
    assert!(err.is_err(), "every relocation target is occupied — the plan must be refused");
    assert_eq!(fingerprint(&dc), before, "a refused plan must leave no trace");
    dc.check_integrity().unwrap();
}

/// Multi-step rollback: a hand-built plan whose *second* step collides
/// (both migrations target the same destination blocks) must undo the
/// first step too — apply is all-or-nothing, never a prefix.
#[test]
fn partially_feasible_plans_roll_back_the_applied_prefix() {
    use grmu::cluster::{DataCenter, GpuRef, Host};
    use grmu::migrate::MigrationPlan;
    let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
    let g0 = GpuRef { host: 0, gpu: 0 };
    let g1 = GpuRef { host: 0, gpu: 1 };
    let p = |start: u8| Placement { profile: Profile::P1g5gb, start };
    dc.place(&vm(1, Profile::P1g5gb, 1.0), g0, p(0));
    dc.place(&vm(2, Profile::P1g5gb, 1.0), g0, p(1));
    let mut plan = MigrationPlan::new();
    plan.push_migrate(1, g0, g1, p(0));
    plan.push_migrate(2, g0, g1, p(0)); // collides with step 1's landing
    let before = fingerprint(&dc);
    assert!(dc.apply_plan(&plan).is_err(), "the second landing is occupied by the first");
    assert_eq!(fingerprint(&dc), before, "step 1 must have been rolled back");
    dc.check_integrity().unwrap();
}

#[test]
fn ilp_start_blocks_always_legal() {
    let mut rng = Rng::new(99);
    for _ in 0..5 {
        let vms: Vec<VmSpec> =
            (0..3).map(|i| vm(i as u64 + 1, *rng.pick(&ALL_PROFILES), 1.0)).collect();
        let inst = PlacementInstance {
            hosts: vec![IlpHost { cpus: 64, ram_gb: 256, num_gpus: 2, weight: 1.0 }],
            vms: vms.clone(),
            prior: HashMap::new(),
        };
        let sol = IlpSolver::new(inst).solve().unwrap();
        for (&id, &(_, _, start)) in &sol.assignment {
            let profile = vms.iter().find(|v| v.id == id).unwrap().profile;
            assert!(
                profile.start_blocks().contains(&start),
                "{profile} assigned illegal start {start}"
            );
        }
    }
}
