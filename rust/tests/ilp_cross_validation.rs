//! Integration tests: the exact ILP (Eq. 3–26) against an independent
//! brute-force enumerator and against the heuristics.
//!
//! The brute-force enumerator shares *no code* with the MILP: it searches
//! over explicit (GPU, start-block) assignments using the placement
//! bitmasks, so agreement pins both the model and the solver.

use grmu::cluster::VmSpec;
use grmu::ilp::model::{IlpHost, PlacementInstance};
use grmu::ilp::IlpSolver;
use grmu::mig::profiles::{Placement, ALL_PROFILES};
use grmu::mig::Profile;
use grmu::util::rng::Rng;
use std::collections::HashMap;

/// Exhaustive optimum: maximize accepted weight, then minimize active
/// hardware among weight-optimal solutions. Exponential — tiny inputs only.
fn brute_force(inst: &PlacementInstance) -> (f64, f64) {
    struct State {
        gpu_occ: Vec<u8>,
        host_cpu: Vec<u32>,
        host_ram: Vec<u32>,
    }
    fn gpu_host(inst: &PlacementInstance, gpu: usize) -> usize {
        let mut g = gpu;
        for (j, h) in inst.hosts.iter().enumerate() {
            if g < h.num_gpus {
                return j;
            }
            g -= h.num_gpus;
        }
        unreachable!()
    }
    fn active_hw(inst: &PlacementInstance, placed: &[Option<(usize, u8)>], vms: &[VmSpec]) -> f64 {
        let total_gpus: usize = inst.hosts.iter().map(|h| h.num_gpus).sum();
        let mut host_active = vec![false; inst.hosts.len()];
        let mut gpu_active = vec![false; total_gpus];
        for (i, p) in placed.iter().enumerate() {
            let _ = &vms[i];
            if let Some((gpu, _)) = p {
                host_active[gpu_host(inst, *gpu)] = true;
                gpu_active[*gpu] = true;
            }
        }
        let mut units = 0.0;
        for (j, h) in inst.hosts.iter().enumerate() {
            if host_active[j] {
                units += h.weight;
            }
        }
        for (g, active) in gpu_active.iter().enumerate() {
            if *active {
                units += inst.hosts[gpu_host(inst, g)].weight;
            }
        }
        units
    }
    fn recurse(
        inst: &PlacementInstance,
        vms: &[VmSpec],
        i: usize,
        state: &mut State,
        placed: &mut Vec<Option<(usize, u8)>>,
        best: &mut (f64, f64),
    ) {
        if i == vms.len() {
            let weight: f64 = placed
                .iter()
                .zip(vms)
                .filter(|(p, _)| p.is_some())
                .map(|(_, vm)| vm.weight)
                .sum();
            let hw = active_hw(inst, placed, vms);
            if weight > best.0 + 1e-9 || (weight > best.0 - 1e-9 && hw < best.1 - 1e-9) {
                *best = (weight, hw);
            }
            return;
        }
        // Option 1: reject VM i.
        placed.push(None);
        recurse(inst, vms, i + 1, state, placed, best);
        placed.pop();
        // Option 2: every legal (gpu, start).
        let vm = &vms[i];
        for gpu in 0..state.gpu_occ.len() {
            let host = gpu_host(inst, gpu);
            if state.host_cpu[host] < vm.cpus || state.host_ram[host] < vm.ram_gb {
                continue;
            }
            for &start in vm.profile.start_blocks() {
                let mask = Placement { profile: vm.profile, start }.mask();
                if state.gpu_occ[gpu] & mask != 0 {
                    continue;
                }
                state.gpu_occ[gpu] |= mask;
                state.host_cpu[host] -= vm.cpus;
                state.host_ram[host] -= vm.ram_gb;
                placed.push(Some((gpu, start)));
                recurse(inst, vms, i + 1, state, placed, best);
                placed.pop();
                state.host_cpu[host] += vm.cpus;
                state.host_ram[host] += vm.ram_gb;
                state.gpu_occ[gpu] &= !mask;
            }
        }
    }
    let total_gpus: usize = inst.hosts.iter().map(|h| h.num_gpus).sum();
    let mut state = State {
        gpu_occ: vec![0; total_gpus],
        host_cpu: inst.hosts.iter().map(|h| h.cpus).collect(),
        host_ram: inst.hosts.iter().map(|h| h.ram_gb).collect(),
    };
    let mut best = (0.0, f64::INFINITY);
    recurse(inst, &inst.vms, 0, &mut state, &mut Vec::new(), &mut best);
    if best.1.is_infinite() {
        best.1 = 0.0;
    }
    best
}

fn vm(id: u64, profile: Profile, weight: f64) -> VmSpec {
    VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 10, weight }
}

#[test]
fn ilp_matches_brute_force_on_fixed_cases() {
    let cases: Vec<PlacementInstance> = vec![
        // One GPU, competing pair.
        PlacementInstance {
            hosts: vec![IlpHost { cpus: 16, ram_gb: 64, num_gpus: 1, weight: 1.0 }],
            vms: vec![vm(1, Profile::P7g40gb, 1.0), vm(2, Profile::P3g20gb, 1.0)],
            prior: HashMap::new(),
        },
        // Two GPUs on one host, mixed profiles.
        PlacementInstance {
            hosts: vec![IlpHost { cpus: 32, ram_gb: 128, num_gpus: 2, weight: 1.0 }],
            vms: vec![
                vm(1, Profile::P4g20gb, 1.0),
                vm(2, Profile::P4g20gb, 1.0),
                vm(3, Profile::P3g20gb, 1.0),
            ],
            prior: HashMap::new(),
        },
        // Weighted: big VM worth more than two smalls.
        PlacementInstance {
            hosts: vec![IlpHost { cpus: 16, ram_gb: 64, num_gpus: 1, weight: 1.0 }],
            vms: vec![
                vm(1, Profile::P7g40gb, 5.0),
                vm(2, Profile::P2g10gb, 1.0),
                vm(3, Profile::P2g10gb, 1.0),
            ],
            prior: HashMap::new(),
        },
        // CPU-bound host.
        PlacementInstance {
            hosts: vec![IlpHost { cpus: 3, ram_gb: 64, num_gpus: 2, weight: 1.0 }],
            vms: vec![vm(1, Profile::P1g5gb, 1.0), vm(2, Profile::P1g5gb, 1.0)],
            prior: HashMap::new(),
        },
    ];
    for (idx, inst) in cases.iter().enumerate() {
        let (bf_weight, bf_hw) = brute_force(inst);
        let sol = IlpSolver::new(inst.clone()).solve().expect("feasible");
        assert!(
            (sol.acceptance - bf_weight).abs() < 1e-6,
            "case {idx}: ILP acceptance {} vs brute force {bf_weight}",
            sol.acceptance
        );
        assert!(
            (sol.active_hardware - bf_hw).abs() < 1e-6,
            "case {idx}: ILP hardware {} vs brute force {bf_hw}",
            sol.active_hardware
        );
    }
}

#[test]
fn ilp_matches_brute_force_on_random_cases() {
    let mut rng = Rng::new(777);
    for case in 0..8 {
        let n_vms = 3;
        let vms: Vec<VmSpec> = (0..n_vms)
            .map(|i| {
                vm(
                    i as u64 + 1,
                    *rng.pick(&ALL_PROFILES),
                    rng.range_inclusive(1, 3) as f64,
                )
            })
            .collect();
        let inst = PlacementInstance {
            hosts: vec![IlpHost { cpus: 32, ram_gb: 128, num_gpus: 2, weight: 1.0 }],
            vms,
            prior: HashMap::new(),
        };
        let (bf_weight, bf_hw) = brute_force(&inst);
        let sol = IlpSolver::new(inst).solve().expect("feasible");
        assert!(
            (sol.acceptance - bf_weight).abs() < 1e-6,
            "case {case}: {} vs {bf_weight}",
            sol.acceptance
        );
        assert!(
            (sol.active_hardware - bf_hw).abs() < 1e-6,
            "case {case}: hw {} vs {bf_hw}",
            sol.active_hardware
        );
    }
}

#[test]
fn heuristics_never_beat_the_ilp_bound() {
    use grmu::cluster::{DataCenter, Host};
    use grmu::policies::{Policy, PolicyConfig, PolicyCtx, PolicyRegistry};
    let mut rng = Rng::new(31337);
    let registry = PolicyRegistry::standard();
    let cfg = PolicyConfig::new().heavy_frac(0.5);
    for _ in 0..6 {
        let vms: Vec<VmSpec> =
            (0..4).map(|i| vm(i as u64 + 1, *rng.pick(&ALL_PROFILES), 1.0)).collect();
        let inst = PlacementInstance {
            hosts: vec![IlpHost { cpus: 64, ram_gb: 256, num_gpus: 2, weight: 1.0 }],
            vms: vms.clone(),
            prior: HashMap::new(),
        };
        let sol = IlpSolver::new(inst).solve().unwrap();
        for policy in registry.names() {
            let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
            let mut p = registry.build(&policy, &cfg).unwrap();
            let mut ctx = PolicyCtx::default();
            let accepted = p
                .place_batch(&mut dc, &vms, &mut ctx)
                .iter()
                .filter(|d| d.is_placed())
                .count() as f64;
            assert!(
                accepted <= sol.acceptance + 1e-6,
                "{policy} beat the exact optimum: {accepted} > {}",
                sol.acceptance
            );
        }
    }
}

#[test]
fn ilp_start_blocks_always_legal() {
    let mut rng = Rng::new(99);
    for _ in 0..5 {
        let vms: Vec<VmSpec> =
            (0..3).map(|i| vm(i as u64 + 1, *rng.pick(&ALL_PROFILES), 1.0)).collect();
        let inst = PlacementInstance {
            hosts: vec![IlpHost { cpus: 64, ram_gb: 256, num_gpus: 2, weight: 1.0 }],
            vms: vms.clone(),
            prior: HashMap::new(),
        };
        let sol = IlpSolver::new(inst).solve().unwrap();
        for (&id, &(_, _, start)) in &sol.assignment {
            let profile = vms.iter().find(|v| v.id == id).unwrap().profile;
            assert!(
                profile.start_blocks().contains(&start),
                "{profile} assigned illegal start {start}"
            );
        }
    }
}
