//! Property tests for the sharded fleet engine: whatever the shard
//! count, worker count, fault schedule, admission queue or cross-shard
//! rebalance period, the union of the per-shard data centers must be a
//! *valid partition* of one coherent global cluster.
//!
//! For a grid of configurations this drives a [`ShardedCore`] over a
//! generated workload with per-interval integrity checks, then — before
//! collecting the result — verifies:
//!
//! 1. every shard's `DataCenter::check_integrity` holds (index, counter
//!    and health coherence inside each shard);
//! 2. a brute-force **global rebuild** — replaying every resident
//!    instance of every shard into one fresh `DataCenter` over the
//!    original (un-renumbered) fleet via the `ShardMap` translation —
//!    also passes `check_integrity`, i.e. no two shards claim the same
//!    VM or GPU and every local reference maps back into its owner's
//!    global range;
//! 3. the merged counters (`resident`, `active_hardware`,
//!    `gpus_by_model`) equal the rebuilt cluster's — the sharded sums
//!    are exactly the global quantities, not approximations;
//! 4. the router's merged accounting stays consistent:
//!    `sum(rejections) == requested − accepted`, cluster-level.

use grmu::cluster::vm::{VmId, VmSpec, HOUR};
use grmu::cluster::{DataCenter, GpuRef};
use grmu::migrate::MigrationBudget;
use grmu::ops::{FaultInjector, OpsConfig, QueueConfig};
use grmu::policies::{Policy, PolicyConfig, PolicyRegistry};
use grmu::sim::ShardedCore;
use grmu::trace::{TraceConfig, Workload};
use std::collections::HashMap;

fn policies(name: &str, n: usize) -> Vec<Box<dyn Policy>> {
    (0..n)
        .map(|_| {
            PolicyRegistry::standard()
                .build(name, &PolicyConfig::new().heavy_frac(0.25))
                .unwrap()
        })
        .collect()
}

/// Rebuild one global `DataCenter` from the per-shard residents and
/// check it is coherent; compare its aggregates to the shard sums.
fn verify_partition(core: &ShardedCore, specs: &HashMap<VmId, VmSpec>, label: &str) {
    let map = core.map();
    // (1) Each shard is internally coherent.
    for (s, shard) in core.shards().iter().enumerate() {
        shard
            .dc
            .check_integrity()
            .unwrap_or_else(|e| panic!("{label}: shard {s} integrity: {e}"));
    }
    // (2) The union re-places cleanly into a fresh global cluster. The
    // rebuilt fleet comes from the shard dcs themselves (translated
    // back), so a shard mutating a host it does not own would surface
    // as a duplicate VM, an out-of-range reference or a capacity
    // violation here.
    let mut hosts = Vec::with_capacity(map.num_hosts());
    for (s, shard) in core.shards().iter().enumerate() {
        for h in shard.dc.hosts() {
            let global_id = map.to_global(s, GpuRef { host: h.id, gpu: 0 }).host;
            // Pristine copy: residents are replayed through `place` below.
            hosts.push(grmu::cluster::Host::with_models(
                global_id,
                h.cpus,
                h.ram_gb,
                &h.gpus().iter().map(|g| g.model()).collect::<Vec<_>>(),
            ));
        }
    }
    hosts.sort_by_key(|h| h.id);
    let mut rebuilt = DataCenter::new(hosts);
    let mut resident_sum = 0usize;
    for (s, shard) in core.shards().iter().enumerate() {
        resident_sum += shard.dc.resident_count();
        for h in shard.dc.hosts() {
            for (g, gpu) in h.gpus().iter().enumerate() {
                for inst in gpu.instances() {
                    let global = map.to_global(s, GpuRef { host: h.id, gpu: g as u8 });
                    let spec = specs
                        .get(&inst.vm)
                        .unwrap_or_else(|| panic!("{label}: unknown resident vm {}", inst.vm));
                    assert!(
                        rebuilt.vm_demands(inst.vm).is_none(),
                        "{label}: vm {} resident on two shards",
                        inst.vm
                    );
                    rebuilt.place(spec, global, inst.placement);
                }
            }
        }
    }
    rebuilt
        .check_integrity()
        .unwrap_or_else(|e| panic!("{label}: rebuilt global integrity: {e}"));
    // (3) Shard sums are the global aggregates.
    assert_eq!(rebuilt.resident_count(), resident_sum, "{label}: resident count");
    let (mut active, mut total) = (0usize, 0usize);
    let mut by_model = [0usize; grmu::mig::NUM_MODELS];
    for shard in core.shards() {
        let (a, t) = shard.dc.active_hardware();
        active += a;
        total += t;
        for (acc, x) in by_model.iter_mut().zip(shard.dc.gpus_by_model()) {
            *acc += x;
        }
    }
    assert_eq!(rebuilt.active_hardware(), (active, total), "{label}: active hardware");
    assert_eq!(rebuilt.gpus_by_model(), by_model, "{label}: fleet composition");
    // (4) Router accounting: one entry per request, cluster-level.
    assert_eq!(
        core.rejections().iter().sum::<u64>(),
        core.requested() - core.accepted(),
        "{label}: merged rejections must sum to refusals"
    );
}

/// Drive the core through the engine's trace loop, verifying the
/// partition at a mid-run point and again after the drain.
fn drive_and_verify(seed: u64, shards: usize, threads: usize, ops: bool, queue: bool, rebalance: bool) {
    let label = format!(
        "seed={seed} shards={shards} threads={threads} ops={ops} queue={queue} rebalance={rebalance}"
    );
    let workload = Workload::generate(TraceConfig::small(seed));
    let vms = &workload.vms;
    let specs: HashMap<VmId, VmSpec> = vms.iter().map(|v| (v.id, *v)).collect();
    let last_arrival = vms.last().unwrap().arrival;

    let mut core = ShardedCore::new(&workload.hosts, policies("grmu", shards), seed, shards, threads);
    core.set_integrity_every(1);
    if ops {
        let cfg = OpsConfig {
            drain_rate: 1.0,
            host_mtbf_hours: 2_000.0,
            blast_radius: 0.5,
            blast_hosts: 4,
            horizon_hours: workload.config.horizon_hours + 48,
            seed,
            ..OpsConfig::default().with_gpu_mtbf(500.0)
        };
        core.set_fault_schedule(FaultInjector::from_config(&cfg, &workload.hosts));
    }
    if queue {
        core.set_admission_queue(QueueConfig { capacity: 16, ttl_hours: 8, preemption: false });
    }
    if rebalance {
        core.set_rebalance(6, MigrationBudget { max_moves_per_interval: 4, max_moves_per_vm: 2 });
    }
    let mut next = 0usize;
    let mut checked_midrun = false;
    loop {
        let t_end = core.interval_end();
        let start = next;
        while next < vms.len() && vms[next].arrival <= t_end {
            next += 1;
        }
        core.step_buffered(&vms[start..next]);
        if !checked_midrun && next >= vms.len() / 2 {
            // Once mid-trace: the partition must hold while loaded, not
            // just after the drain.
            verify_partition(&core, &specs, &format!("{label} (mid-run)"));
            checked_midrun = true;
        }
        let drained = next >= vms.len() && core.pending_departures() == 0;
        let capped = core.hour() * HOUR > last_arrival + 3 * 24 * HOUR;
        if drained || capped {
            break;
        }
    }
    verify_partition(&core, &specs, &format!("{label} (final)"));
    let result = core.into_result(0.0);
    assert_eq!(
        result.rejections.iter().sum::<u64>(),
        result.requested - result.accepted,
        "{label}: result breakdown must sum after the queue flush"
    );
    assert!(result.accepted > 0, "{label}: vacuous run");
}

#[test]
fn partition_holds_without_ops() {
    drive_and_verify(42, 1, 1, false, false, false);
    drive_and_verify(42, 3, 2, false, false, false);
    drive_and_verify(19, 4, 8, false, false, false);
}

#[test]
fn partition_holds_under_faults_and_queueing() {
    drive_and_verify(42, 4, 2, true, true, false);
    drive_and_verify(7, 2, 4, true, false, false);
}

#[test]
fn partition_holds_under_cross_shard_rebalance() {
    drive_and_verify(42, 3, 2, false, false, true);
    drive_and_verify(19, 4, 4, true, true, true);
}
