//! # GRMU — Multi-Objective MIG-Enabled VM Placement
//!
//! A from-scratch reproduction of *"A Multi-Objective Framework for
//! Optimizing GPU-Enabled VM Placement in Cloud Data Centers with
//! Multi-Instance GPU Technology"* (Siavashi & Momtazpour, 2025).
//!
//! The crate is organised bottom-up:
//!
//! * [`util`] — in-tree substrates for the offline build environment:
//!   seeded RNG and distributions, JSON, CLI parsing, a bench harness and
//!   a property-testing helper.
//! * [`mig`] — the NVIDIA Multi-Instance GPU substrate: profiles and
//!   placement rules (Table 1 / Fig. 1), the Configuration-Capability
//!   metric (Eq. 1–2), the default driver placement policy (Alg. 1), the
//!   723-node configuration space (§5.1) and the fragmentation metric
//!   (Alg. 4).
//! * [`trace`] — Alibaba-2023-like workload synthesis with the paper's
//!   IQR outlier filter and Eq. 27–30 GPU-fraction→profile mapping.
//! * [`cluster`] — physical machines (CPU/RAM/GPUs), VMs and the
//!   data-center state.
//! * [`sim`] — the discrete-event simulation engine and metric sampling
//!   (replaces the paper's "Cloudy" simulator).
//! * [`policies`] — the five placement policies evaluated in §8:
//!   First-Fit, Best-Fit, MCC, MECC and GRMU (dual-basket pooling,
//!   defragmentation, consolidation — Alg. 2–7).
//! * [`ilp`] — the paper's multi-objective ILP (Eq. 3–26) plus an exact
//!   in-house MILP solver (dense simplex + branch & bound) used to
//!   validate the heuristics on small instances.
//! * [`runtime`] — the PJRT/XLA runtime that loads the AOT-compiled
//!   batched configuration scorer (`artifacts/cc_scorer.hlo.txt`).
//! * [`coordinator`] — the online placement service: request loop,
//!   admission, migration ticks and metrics export.
//! * [`report`] — renderers that regenerate every table and figure of the
//!   paper's evaluation section.

pub mod cluster;
pub mod coordinator;
pub mod ilp;
pub mod mig;
pub mod policies;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod util;
