//! # GRMU — Multi-Objective MIG-Enabled VM Placement
//!
//! A from-scratch reproduction of *"A Multi-Objective Framework for
//! Optimizing GPU-Enabled VM Placement in Cloud Data Centers with
//! Multi-Instance GPU Technology"* (Siavashi & Momtazpour, 2025).
//!
//! The crate is organised bottom-up:
//!
//! * [`util`] — in-tree substrates for the offline build environment:
//!   seeded RNG and distributions, JSON, CLI parsing, a bench harness and
//!   a property-testing helper.
//! * [`mig`] — the NVIDIA Multi-Instance GPU substrate, parameterized
//!   over the [`mig::GpuModel`] catalog (A100-40 / A30 / A100-80 /
//!   H100-80): per-model profiles and placement rules (Table 1 /
//!   Fig. 1), the Configuration-Capability metric (Eq. 1–2), the default
//!   driver placement policy (Alg. 1), the A100-40's 723-node
//!   configuration space (§5.1) and the fragmentation metric (Alg. 4).
//! * [`trace`] — Alibaba-2023-like workload synthesis with the paper's
//!   IQR outlier filter and Eq. 27–30 GPU-fraction→profile mapping.
//! * [`cluster`] — physical machines (CPU/RAM/GPUs), VMs and the
//!   data-center state, plus the [`cluster::ClusterIndex`]: per-profile
//!   GPU feasibility buckets stored as two-level hierarchical bitsets
//!   (read through [`cluster::GpuSetView`], intersectable word-wise
//!   against external [`cluster::GpuBits`] masks), per-model
//!   schedulable sets, and flat host-headroom histograms with cached
//!   extremes — all maintained incrementally (O(1) per mutation) by
//!   every `DataCenter` operation. The determinism contract — buckets
//!   iterate in ascending [`cluster::GpuRef`] order, the paper's
//!   `globalIndex` — holds by construction: a bitset walk in ascending
//!   slot order *is* the ascending-`GpuRef` walk, which is what makes
//!   indexed policy decisions byte-identical to full scans.
//! * [`migrate`] — the policy-agnostic migration-planner layer (the
//!   paper's third objective as a mechanism): [`migrate::MigrationPlanner`]s
//!   produce explicit [`migrate::MigrationPlan`]s — Algorithm 4 re-packs
//!   ([`migrate::DefragOnReject`]), Algorithm 5 pairwise consolidation
//!   ([`migrate::PairwiseConsolidate`]) and the threshold-triggered
//!   [`migrate::FragGradient`] drain — applied **transactionally** by
//!   `DataCenter::apply_plan` (all-or-nothing, index/counter-coherent)
//!   and composed via [`migrate::PlannerStack`]s with per-interval /
//!   per-VM [`migrate::MigrationBudget`]s. Performed moves surface as
//!   [`migrate::MigrationEvent`]s with block-weighted per-kind costs.
//! * [`policies`] — the typed placement-decision API and the five §8
//!   policies (First-Fit, Best-Fit, MCC, MECC, GRMU). A policy answers
//!   each request with a [`policies::Decision`] — `Placed` with the
//!   chosen GPU and block placement, or `Rejected` with a
//!   [`policies::RejectReason`] (CPU/RAM exhaustion, fragmentation,
//!   basket-quota denial) — and reports defragmentation/consolidation
//!   moves as [`policies::MigrationEvent`] records. Policies are built
//!   through the [`policies::PolicyRegistry`] and run against a
//!   [`policies::PolicyCtx`] (virtual clock, seeded RNG, pluggable CC
//!   scorer). Registry names compose with planner suffixes
//!   (`mcc+defrag`, `bf+consolidate`, ...) via [`policies::Planned`],
//!   so every policy can migrate — GRMU itself is a thin composition of
//!   its dual baskets and a light-basket-scoped planner stack.
//!   Placement candidates come from the cluster index;
//!   `PolicyConfig::use_index(false)` rebuilds the brute-force
//!   full-scan variants used by the equivalence tests and benches.
//! * [`ops`] — the deterministic operational model: GPU/host
//!   [`cluster::HealthState`] transitions (fail / repair / drain / ban)
//!   drawn by a seeded [`ops::FaultInjector`] with per-model MTBF/MTTR,
//!   all-or-nothing drain evacuation through the planner layer
//!   ([`ops::plan_evacuation`]), and a bounded FIFO
//!   [`ops::AdmissionQueue`] with TTLs and priority-tier preemption.
//!   The `ClusterIndex` covers schedulable capacity only;
//!   `check_integrity` verifies the health/index contract. With every
//!   rate at zero (the default) the whole layer is byte-invisible.
//! * [`sim`] — the shared [`sim::EventCore`] (departure heap, interval
//!   batching, maintenance ticks, fault replay, admission-queue
//!   processing, metric sampling) plus the offline trace-replay
//!   [`sim::Simulation`] built on it. Results carry per-reason
//!   rejection breakdowns, full migration-event logs, interruption /
//!   preemption counts, queue-delay samples and fleet availability.
//!   For fleets past the single-core ceiling, [`sim::ShardedCore`] /
//!   [`sim::ShardedSimulation`] partition the hosts into shards (each
//!   its own `EventCore`) behind a deterministic router: per-interval
//!   batches fan out to scoped worker threads, rejected requests retry
//!   on sibling shards in fixed order, and merged results are
//!   byte-identical at `--shards 1` and independent of the worker
//!   thread count at any shard count.
//! * [`ilp`] — the paper's multi-objective ILP (Eq. 3–26) plus an exact
//!   in-house MILP solver (dense simplex + branch & bound) used to
//!   validate the heuristics on small instances. [`ilp::online`] takes
//!   the formalism online: [`ilp::RollingIlp`] is a `MigrationPlanner`
//!   that on a cadence (and on rejection bursts) extracts the most
//!   fragmented K GPUs per model plus the interval's pending rejects as
//!   a bounded instance, solves it under a deterministic
//!   branch-and-bound node budget, and emits a transactional repair
//!   plan (registry name `ilp-repair`, so `mcc+ilp-repair` composes);
//!   [`ilp::GapMeter`] reuses the extraction to report each policy's
//!   optimality gap against the bounded ILP bound.
//! * [`runtime`] *(feature `xla`)* — the PJRT/XLA runtime that loads the
//!   AOT-compiled batched configuration scorer
//!   (`artifacts/cc_scorer.hlo.txt`) behind the [`policies::CcScorer`]
//!   trait.
//! * [`coordinator`] — the online placement service: the same
//!   [`sim::EventCore`] driven by a request channel, with serving
//!   metrics (latency percentiles, throughput) on top. Coordinator runs
//!   report the simulator's [`sim::SimResult`].
//! * [`recover`] — crash-consistent persistence: versioned, checksummed
//!   engine snapshots written atomically (temp file + fsync + rename),
//!   an append-only interval journal for cross-checking resumed runs,
//!   and the [`recover::OnCorruption`] graceful-degradation policy for
//!   integrity violations (abort / quarantine / rebuild). CLI
//!   `simulate --checkpoint-every H --checkpoint-dir D` checkpoints a
//!   run; `--resume D` restores the newest valid snapshot and continues
//!   byte-identically to an uninterrupted run.
//! * [`report`] — renderers that regenerate every table and figure of the
//!   paper's evaluation section, plus the parallel multi-seed ×
//!   multi-policy sweep runner behind the `sweep` CLI subcommand
//!   (scoped threads, deterministic seed-major output).
//!
//! ## Migration note (GpuModel catalog / ProfileKey)
//!
//! The MIG layer used to hardcode one part — the A100-40GB (8 blocks,
//! a closed six-variant `Profile` enum, `[_; 6]` accounting arrays).
//! It is now parameterized over the [`mig::GpuModel`] catalog. Code
//! written against the old surface maps as follows:
//!
//! * `Profile` is now an alias for [`mig::ProfileKey`] — a
//!   `(model, per-model index)` pair. The A100-40 constants
//!   (`Profile::P1g5gb` .. `Profile::P7g40gb`), `ALL_PROFILES`,
//!   `NUM_BLOCKS`, `PLACEMENTS` and `Profile::parse("2g.10gb")` keep
//!   their historical meanings.
//! * `Profile::index()` remains the *per-model* index (per-GPU capacity
//!   arrays); cluster-wide accounting (`SimResult::per_profile`, MECC
//!   windows, `ClusterIndex` buckets) is keyed by the new dense
//!   cross-model [`mig::ProfileKey::dense`] index
//!   (`0..mig::NUM_PROFILE_KEYS`). The A100-40's dense indices equal its
//!   historical 0..6, so A100-only layouts are unchanged with a zero
//!   tail.
//! * Model-less table lookups grew `_for` variants: `cc(occ)` →
//!   [`mig::cc_for`]`(model, occ)` (the bare names remain as A100-40
//!   shorthands); `fragmentation_value(occ)` →
//!   `fragmentation_value(model, occ)`;
//!   [`policies::CcScorer::score`] takes the candidates' model.
//! * [`mig::GpuState`], [`cluster::Host`] (via `Host::with_models`) and
//!   the trace generator (`TraceConfig::gpu_models`, CLI
//!   `--gpu-models a30:0.3,a100-40:0.7`) carry per-GPU models; requests
//!   only ever place on GPUs of their profile's model (Eq. 17–18).
//!   Single-model defaults are byte-identical to the pre-catalog
//!   behaviour (locked in `rust/tests/decision_api.rs`).
//!
//! ## Migration note (decision API)
//!
//! Earlier revisions had `Policy::place_batch(..) -> Vec<bool>` with two
//! cumulative migration counters and duplicated event loops in
//! `sim::engine` and `coordinator::service`. Code written against that
//! contract maps as follows:
//!
//! * `Vec<bool>` → `Vec<Decision>`; use `Decision::is_placed()` for the
//!   old boolean, `Decision::gpu()` for the placement address,
//!   `Decision::reject_reason()` for the new diagnostics.
//! * `policy.intra_migrations()` / `policy.inter_migrations()` →
//!   `policy.take_migrations()` (drained by the event core);
//!   `SimResult::{intra_migrations, inter_migrations}` fields →
//!   methods over `SimResult::migration_events`.
//! * `policies::by_name(..)` / `POLICY_NAMES` →
//!   [`policies::PolicyRegistry::standard`] with
//!   [`policies::PolicyConfig`] builders; unknown names now report the
//!   accepted list (which includes `grmu-db`).
//! * `place_batch(dc, vms, now)` → `place_batch(dc, vms, &mut ctx)` with
//!   the time on `ctx.now`.
//!
//! ## Migration note (zero-allocation hot path, §Perf iteration 6)
//!
//! The steady-state simulate/coordinate loop is allocation-free and
//! scan-free. Code written against the earlier surface maps as follows:
//!
//! * The required policy entry point is
//!   [`policies::Policy::place_batch_into`], which writes one `Decision`
//!   per VM into the [`policies::PolicyCtx`]'s reusable
//!   [`policies::DecisionBuffer`]; the `Vec`-returning `place_batch`
//!   survives as a provided compat wrapper (implementors of the old
//!   signature move their body into `place_batch_into` and push into
//!   `ctx.decisions`). Likewise
//!   [`sim::EventCore::step_buffered`]/[`sim::EventCore::place_buffered`]
//!   are the engine's hot path ([`sim::EventCore::decisions`] reads the
//!   latest batch) and `step`/`place` stay as `Vec` wrappers.
//! * [`policies::CcScorer::score_into`] appends scores to a reusable
//!   buffer; `score` remains for backends without an append path.
//! * [`policies::Policy::drain_migrations_into`] drains migration events
//!   while retaining the policy-side buffer's capacity;
//!   `take_migrations` remains.
//! * `DataCenter::active_hardware`, `active_gpus_by_model`,
//!   `gpus_by_model` and `resident_count` are O(1) counter reads
//!   maintained incrementally by every mutation; the old fleet scans
//!   survive as `active_hardware_scan`/`active_gpus_by_model_scan`
//!   (`check_integrity` compares the two). Counters are observers only —
//!   indexed-vs-scan decision equivalence is untouched.
//! * [`sim::EventCore::reserve_for_trace`] pre-sizes the departure heap,
//!   sample vector and migration log from trace metadata; the sweep
//!   runner shares each seed's generated trace across its cells via
//!   `Arc<[Host]>`/`Arc<[VmSpec]>`
//!   ([`report::experiments::run_trace`]).
//!
//! ## Migration note (ops: health, faults, admission queue)
//!
//! The cluster used to be implicitly always-healthy. Capacity now
//! carries an operational [`cluster::HealthState`]; code written
//! against the pristine-fleet surface maps as follows:
//!
//! * `ClusterIndex::build(&hosts)` (and every incremental update) skips
//!   capacity whose health forbids placement — buckets, headroom
//!   histograms and `hosts_with_model` describe *schedulable* capacity.
//!   The scan-mode reference paths (`visit_candidates`,
//!   `classify_rejection*`, the planners' candidate walks) gained
//!   matching `gpu_available`/`host_available` checks, so
//!   indexed-vs-scan byte-identity is preserved; on an all-healthy
//!   fleet every check is vacuous and decision streams are unchanged.
//! * [`policies::RejectReason`] grew `Queued` and `Expired`;
//!   `RejectCounts` is `[u64; 6]`. `sum(rejections) == requested -
//!   accepted` still holds at every instant — a queued request counts
//!   under `Queued` until it is placed (moving to `accepted`) or
//!   expires (moving to `Expired`).
//! * Evictions from failures surface as `SimResult::interrupted`,
//!   queue preemptions as `SimResult::preempted`; neither is a
//!   rejection. `SimResult::availability` is the mean per-interval
//!   fraction of schedulable GPUs.
//! * Mutating health directly on a `Host` is not possible; go through
//!   `DataCenter::set_gpu_health` / `set_host_health`, which keep the
//!   index and the offline-GPU counter coherent (residents must be
//!   evicted *before* a transition to failed/banned —
//!   `check_integrity` enforces the resulting emptiness).
//!
//! ## Migration note (migration-planner layer)
//!
//! Defragmentation and consolidation used to be private helpers inside
//! `policies/grmu/{defrag,consolidation}.rs`, mutating the data center
//! directly. They are now policy-agnostic planners under [`migrate`].
//! Code written against the old surface maps as follows:
//!
//! * `policies::grmu::defrag::{most_fragmented, repack_plan}` →
//!   [`migrate::defrag`] (same algorithms; `most_fragmented` takes any
//!   GPU iterator plus a `use_index` flag for the occupancy fast path /
//!   fragmentation table, with the full recomputation as the
//!   `use_index(false)` reference).
//! * `defrag::defragment_light_basket(dc, basket)` →
//!   [`migrate::defrag::defragment`]`(dc, PlanScope::Set(basket), true)`,
//!   or compose [`migrate::DefragOnReject`] into a stack.
//! * `consolidation::consolidate_light_basket(dc, light, events)` →
//!   [`migrate::PairwiseConsolidate`] (plan) + `DataCenter::apply_plan`;
//!   GRMU returns emptied sources to its pool by inspecting the applied
//!   `Inter` events.
//! * Mutating the cluster from a migration routine → build a
//!   [`migrate::MigrationPlan`] and call `DataCenter::apply_plan`: steps
//!   are validated against the live state and an infeasible plan rolls
//!   back atomically (`check_integrity`-clean either way).
//! * [`policies::MigrationEvent`]/[`policies::MigrationKind`] moved to
//!   [`migrate`] (the `policies` re-exports remain) and events gained
//!   `model` + `blocks` fields: [`migrate::MigrationEvent::cost`] is the
//!   block-weighted per-kind cost (Table 2) that `SimResult` aggregates.
//! * `Policy::take_migrations` is now the compat wrapper and the
//!   buffered [`policies::Policy::drain_migrations_into`] the required
//!   drain shape (default: allocation-free no-op).
//! * Registry names compose: `mcc+defrag`, `bf+consolidate`,
//!   `ff+defrag+frag-gradient`; CLI `--planners`/`--migration-budget`
//!   on `simulate`/`sweep` reach the same machinery.
//!
//! ## Migration note (online ILP repair + optimality gap)
//!
//! The ILP layer used to be offline-only (small-shape validation).
//! Code written against that surface maps as follows:
//!
//! * `IlpSolver::solve()` remains the unlimited offline reference;
//!   `IlpSolver::solve_budgeted(`[`ilp::NodeBudget`]`)` is the
//!   node-budgeted online entry point (`solve_limited(n)` survives as a
//!   sentinel-decoding wrapper). The historical **zero divergence** —
//!   `Milp::solve(0)` meant
//!   *unlimited* while a zero `--ilp-nodes`/`--ilp-window` disables
//!   [`ilp::RollingIlp`] entirely (an online planner must never run
//!   unbounded) — is now resolved at the type level: the solver's
//!   canonical entry point is `Milp::solve_with(`[`ilp::NodeBudget`]`)`
//!   (`Unlimited` / `Nodes(n)`), `Milp::solve(usize)` survives only as
//!   a deprecated shim mapping `0 → Unlimited`, and the planner layer
//!   still guards its own zero (= off) before constructing a budget.
//! * The planner registry gained `ilp-repair`
//!   (`policies::planned::planner_from_name`); CLI knobs `--ilp-window
//!   K --ilp-nodes N --ilp-period HOURS` ride on
//!   [`policies::PolicyConfig`] / `report::experiments::ExperimentConfig`.
//!   The sharded router's rebalance pass can swap its sole-tenant scan
//!   for any registry planner via `--shard-rebalance-planner NAME`
//!   (`sim::ShardedCore::set_rebalance_planner`).
//! * `--gap-every HOURS` wraps every policy in an [`ilp::GapMeter`]:
//!   pre-batch bounded ILP bound vs achieved weighted acceptance,
//!   surfaced as `SimResult::gap_samples` / `gap_mean()` / `gap_max()`,
//!   the `gap%` column of `repro sweep`, and
//!   `report::tables::optimality_gap`. With the meter off (default) and
//!   the planner disabled, streams are byte-identical to the
//!   pre-online-ILP crate (locked in `rust/tests/decision_api.rs`).
//!
//! ## Migration note (sharded fleet)
//!
//! The engine used to be one `EventCore` owning the whole fleet. Very
//! large fleets now run through the sharding layer; code written
//! against the single-core surface maps as follows:
//!
//! * One global `DataCenter`/`ClusterIndex` → a [`cluster::ShardMap`]
//!   partitioning hosts into contiguous shards, each shard a full
//!   `EventCore` (own index, activity counters, health state, policy
//!   instance seeded per shard). `ShardMap::to_local`/`to_global`
//!   translate [`cluster::GpuRef`]s; requests route to
//!   `home_shard(vm.id)`.
//! * `Simulation` → [`sim::ShardedSimulation`] with
//!   [`sim::ShardOptions`] (`shards`, `threads`, `rebalance_every`,
//!   budget); CLI `simulate --shards N [--shard-threads N]
//!   [--shard-rebalance HOURS]`. `--shards 1` is byte-identical to the
//!   classic engine; results at any shard count are independent of the
//!   worker thread count (workers only run pre-routed per-shard
//!   batches; all merging, retries and rebalance run serially on the
//!   router thread). Both locks live in `rust/tests/decision_api.rs`.
//! * A request rejected for a retryable reason by its home shard
//!   retries on sibling shards in fixed order before becoming a
//!   cluster-level rejection; the router uncounts duplicate bookkeeping
//!   so `sum(rejections) == requested - accepted` holds cluster-wide.
//! * Fault schedules are drawn over the *unsplit* fleet and then split
//!   per owning shard, so the operational timeline is identical at
//!   every shard count; `--blast-radius p` escalates host failures to
//!   correlated domain outages (default domain = one shard).
//! * Cross-shard consolidation is the opt-in router-level rebalance
//!   pass (sole-tenant GIs onto sibling shards' non-empty GPUs under
//!   the [`migrate::MigrationBudget`]), surfacing as ordinary `Inter`
//!   [`migrate::MigrationEvent`]s.
//!
//! ## Migration note (crash-safe persistence)
//!
//! The engine used to be run-to-completion and in-memory only; an
//! integrity violation panicked the process. Runs can now checkpoint,
//! resume and degrade gracefully. Code written against the old surface
//! maps as follows:
//!
//! * Snapshot format: one frame per checkpoint (`GRMU` magic,
//!   `recover::SNAPSHOT_VERSION`, kind tag, length, payload, FNV-1a
//!   checksum). The version is bumped on **any** payload field-sequence
//!   change and readers refuse unknown versions — there is no in-place
//!   format migration; an old snapshot simply cannot seed a new build,
//!   and recovery falls back to re-running the trace.
//! * What is serialized: ground truth and run state only — hosts with
//!   per-GPU models, health and resident instances, per-VM demand
//!   entries, the departure heap, admission-queue contents, RNG
//!   cursors (`util::rng::Rng::state_parts`), the fault-schedule
//!   cursor, cumulative counters, samples/migration logs and per-policy
//!   opaque state via [`policies::Policy::snapshot_state`] /
//!   `restore_state` (planners mirror this via
//!   [`migrate::MigrationPlanner::snapshot_state`]). What is *rebuilt*:
//!   `ClusterIndex`, activity counters, VM locations and the offline-GPU
//!   counter are re-derived on load by replaying placements onto fresh
//!   hosts, then cross-checked with `check_integrity` — derived state
//!   can therefore never be restored stale.
//! * `DataCenter::check_integrity` (panic on violation via the caller's
//!   `expect`) gained a non-panicking sibling
//!   `try_check_integrity() -> Result<(), IntegrityReport>`; the engine
//!   dispatches on [`recover::OnCorruption`] (`abort` keeps the
//!   historical panic; `quarantine` bans the offending host after a
//!   derived-state rebuild; `rebuild` just rebuilds). Repairs are
//!   logged as [`ops::OpsEvent::StateRepair`] entries
//!   (`sim::EventCore::state_repairs`) — never part of generated fault
//!   schedules.
//! * With checkpointing off (the default: `checkpoint_every_hours: 0`,
//!   no `--checkpoint-dir`) the engine takes the exact pre-persistence
//!   code path: no files, no extra state, byte-identical streams.

pub mod cluster;
pub mod coordinator;
pub mod ilp;
pub mod mig;
pub mod migrate;
pub mod ops;
pub mod policies;
pub mod recover;
pub mod report;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod util;
