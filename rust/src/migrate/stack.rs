//! Composable planner stacks with migration budgets.
//!
//! A [`PlannerStack`] owns an ordered list of
//! [`MigrationPlanner`](super::MigrationPlanner)s and drives one
//! plan→budget→apply round per trigger: each planner (in stack order)
//! builds its [`MigrationPlan`] against the then-current cluster state,
//! the plan is truncated to the remaining [`MigrationBudget`] (whole
//! steps, prefix-only — deterministic), applied transactionally via
//! [`DataCenter::apply_plan`](crate::cluster::DataCenter::apply_plan),
//! and the performed moves are appended to the caller's event log.
//!
//! GRMU runs a stack over its light basket; the `Planned` wrapper
//! (`policies::planned`) runs one over the whole cluster for any base
//! policy (`mcc+defrag`, `ff+consolidate`, ...). With the default
//! unlimited budget the stack adds no behavior of its own — default
//! GRMU is byte-identical to the pre-extraction inline implementation.

use super::{
    MigrationBudget, MigrationEvent, MigrationPlan, MigrationPlanner, PlanCtx, PlanScope,
    PlanTrigger,
};
use crate::cluster::vm::{Time, VmId};
use crate::cluster::DataCenter;
use std::collections::HashMap;

/// An ordered, budgeted composition of migration planners.
pub struct PlannerStack {
    planners: Vec<Box<dyn MigrationPlanner>>,
    budget: MigrationBudget,
    /// Lifetime move counts per VM (the per-VM budget axis). Only
    /// maintained when the budget is finite.
    vm_moves: HashMap<VmId, u32>,
    /// `now` of the last round, for per-interval budget resets.
    interval: Time,
    interval_moves: u32,
    /// Reusable plan scratch (cleared per planner per round).
    plan: MigrationPlan,
}

impl PlannerStack {
    pub fn new(budget: MigrationBudget) -> PlannerStack {
        PlannerStack {
            planners: Vec::new(),
            budget,
            vm_moves: HashMap::new(),
            interval: 0,
            interval_moves: 0,
            plan: MigrationPlan::new(),
        }
    }

    /// Append a planner (runs after the ones already in the stack).
    pub fn push(&mut self, planner: Box<dyn MigrationPlanner>) {
        self.planners.push(planner);
    }

    /// Builder-style [`PlannerStack::push`].
    pub fn with(mut self, planner: Box<dyn MigrationPlanner>) -> PlannerStack {
        self.push(planner);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.planners.is_empty()
    }

    pub fn budget(&self) -> MigrationBudget {
        self.budget
    }

    /// Planner names in stack order (for composed policy names).
    pub fn names(&self) -> Vec<&'static str> {
        self.planners.iter().map(|p| p.name()).collect()
    }

    /// One planning round: let every planner plan against the current
    /// state, truncate to the remaining budget, apply atomically, append
    /// the performed [`MigrationEvent`]s to `events`. Returns the number
    /// of moves applied.
    ///
    /// A plan the transactional apply refuses is dropped whole (the
    /// rollback already restored the cluster) — planners validating
    /// against a `PlanView` never hit this path; the `debug_assert`
    /// flags one that does.
    pub fn run(
        &mut self,
        dc: &mut DataCenter,
        now: Time,
        trigger: PlanTrigger,
        scope: PlanScope,
        events: &mut Vec<MigrationEvent>,
    ) -> u32 {
        self.run_with_pending(dc, now, trigger, scope, &[], events)
    }

    /// [`PlannerStack::run`] with the triggering batch's unplaced VMs
    /// threaded through as [`PlanCtx::pending`] demand hints. `run` is
    /// this with an empty slice.
    pub fn run_with_pending(
        &mut self,
        dc: &mut DataCenter,
        now: Time,
        trigger: PlanTrigger,
        scope: PlanScope,
        pending: &[crate::cluster::VmSpec],
        events: &mut Vec<MigrationEvent>,
    ) -> u32 {
        if self.planners.is_empty() {
            return 0;
        }
        if now != self.interval {
            self.interval = now;
            self.interval_moves = 0;
        }
        let limited = !self.budget.is_unlimited();
        let mut applied = 0u32;
        for planner in &mut self.planners {
            if limited && self.interval_moves >= self.budget.max_moves_per_interval {
                // The interval budget is spent: no plan could keep any
                // step, so skip the (possibly O(cluster)) planning work.
                break;
            }
            self.plan.clear();
            let ctx = PlanCtx { now, trigger, scope, pending };
            planner.plan(dc, &ctx, &mut self.plan);
            if limited {
                self.plan.truncate_to_budget(&self.budget, self.interval_moves, &self.vm_moves);
            }
            if self.plan.is_empty() {
                continue;
            }
            match dc.apply_plan(&self.plan) {
                Ok(()) => {
                    let start = events.len();
                    self.plan.push_events_into(events);
                    for ev in &events[start..] {
                        if limited {
                            *self.vm_moves.entry(ev.vm).or_insert(0) += 1;
                        }
                        self.interval_moves += 1;
                        applied += 1;
                    }
                }
                Err(e) => {
                    debug_assert!(false, "{} planned an infeasible plan: {e}", planner.name());
                }
            }
        }
        applied
    }

    /// Serialize the stack's mutable state (budget counters plus each
    /// planner's own state, in stack order) for crash-safe snapshots.
    /// The planner list and budget themselves are configuration: the
    /// restoring side rebuilds an identically-shaped stack first and
    /// then calls [`PlannerStack::restore_state`].
    pub fn snapshot_state(&self, out: &mut Vec<u8>) {
        let mut e = crate::util::codec::Enc::new();
        let mut vm_moves: Vec<(VmId, u32)> = self.vm_moves.iter().map(|(&k, &v)| (k, v)).collect();
        vm_moves.sort_by_key(|&(k, _)| k);
        e.usize(vm_moves.len());
        for (vm, n) in vm_moves {
            e.u64(vm);
            e.u32(n);
        }
        e.u64(self.interval);
        e.u32(self.interval_moves);
        e.usize(self.planners.len());
        for planner in &self.planners {
            let mut state = Vec::new();
            planner.snapshot_state(&mut state);
            e.blob(&state);
        }
        out.extend_from_slice(e.bytes());
    }

    /// Inverse of [`PlannerStack::snapshot_state`]. Fails when the
    /// snapshot's planner count disagrees with this stack's shape.
    pub fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut d = crate::util::codec::Dec::new(bytes);
        let n = d.count(12)?;
        self.vm_moves = HashMap::with_capacity(n);
        for _ in 0..n {
            let vm = d.u64()?;
            let moves = d.u32()?;
            self.vm_moves.insert(vm, moves);
        }
        self.interval = d.u64()?;
        self.interval_moves = d.u32()?;
        let n = d.count(8)?;
        if n != self.planners.len() {
            return Err(format!(
                "snapshot has {n} planner states but the stack holds {}",
                self.planners.len()
            ));
        }
        for planner in &mut self.planners {
            let state = d.blob()?.to_vec();
            planner.restore_state(&state)?;
        }
        if !d.is_empty() {
            return Err("trailing bytes in planner-stack state".into());
        }
        Ok(())
    }
}

impl std::fmt::Debug for PlannerStack {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlannerStack")
            .field("planners", &self.names())
            .field("budget", &self.budget)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::vm::HOUR;
    use crate::cluster::{GpuRef, Host, VmSpec};
    use crate::mig::{Placement, Profile};
    use crate::migrate::MigrationKind;

    /// Test stub: plans one inter-GPU move per listed (vm, from, to)
    /// tuple, reading the live placement for validity.
    struct MoveAll;

    impl MigrationPlanner for MoveAll {
        fn name(&self) -> &'static str {
            "move-all"
        }

        fn plan(&mut self, dc: &DataCenter, ctx: &PlanCtx, plan: &mut MigrationPlan) {
            use crate::mig::placement::mock_assign;
            let mut view = crate::migrate::PlanView::new(dc);
            // Move every resident VM one GPU to the right, when it fits.
            let refs: Vec<GpuRef> = ctx.scope.gpus(dc).collect();
            for (i, &r) in refs.iter().enumerate() {
                let Some(&next) = refs.get(i + 1) else { break };
                for inst in dc.gpu(r).instances() {
                    if dc.gpu(next).model() != inst.placement.profile.model() {
                        continue;
                    }
                    let (cpus, ram) = dc.vm_demands(inst.vm).unwrap_or((0, 0));
                    if r.host != next.host && !view.host_fits(next.host, cpus, ram) {
                        continue;
                    }
                    if let Some((pl, _)) =
                        mock_assign(view.occupancy(next), inst.placement.profile)
                    {
                        view.note_move(r, inst.placement, next, pl, cpus, ram);
                        plan.push_migrate(inst.vm, r, next, pl);
                    }
                }
            }
        }
    }

    fn dc_with_vms(n: u64) -> DataCenter {
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 4)]);
        for id in 1..=n {
            let vm = VmSpec {
                id,
                profile: Profile::P1g5gb,
                cpus: 1,
                ram_gb: 1,
                arrival: 0,
                departure: 100,
                weight: 1.0,
            };
            dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement {
                profile: Profile::P1g5gb,
                start: (id - 1) as u8,
            });
        }
        dc
    }

    #[test]
    fn unlimited_stack_applies_everything() {
        let mut dc = dc_with_vms(3);
        let mut stack = PlannerStack::new(MigrationBudget::unlimited()).with(Box::new(MoveAll));
        let mut events = Vec::new();
        let n = stack.run(&mut dc, HOUR, PlanTrigger::Tick, PlanScope::Cluster, &mut events);
        assert_eq!(n, 3);
        assert_eq!(events.len(), 3);
        assert!(events.iter().all(|e| e.kind == MigrationKind::Inter));
        assert!(dc.gpu(GpuRef { host: 0, gpu: 0 }).is_empty());
        dc.check_integrity().unwrap();
    }

    #[test]
    fn interval_budget_caps_moves_and_resets_next_interval() {
        let mut dc = dc_with_vms(3);
        let budget = MigrationBudget::unlimited().per_interval(2);
        let mut stack = PlannerStack::new(budget).with(Box::new(MoveAll));
        let mut events = Vec::new();
        let n = stack.run(&mut dc, HOUR, PlanTrigger::Tick, PlanScope::Cluster, &mut events);
        assert_eq!(n, 2, "third move exceeds the interval budget");
        // Same interval, second trigger: budget already spent.
        let n = stack.run(&mut dc, HOUR, PlanTrigger::Tick, PlanScope::Cluster, &mut events);
        assert_eq!(n, 0);
        // Next interval: the counter resets.
        let n = stack.run(&mut dc, 2 * HOUR, PlanTrigger::Tick, PlanScope::Cluster, &mut events);
        assert!(n > 0);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn per_vm_budget_is_lifetime() {
        let mut dc = dc_with_vms(1);
        let budget = MigrationBudget::unlimited().per_vm(1);
        let mut stack = PlannerStack::new(budget).with(Box::new(MoveAll));
        let mut events = Vec::new();
        assert_eq!(stack.run(&mut dc, HOUR, PlanTrigger::Tick, PlanScope::Cluster, &mut events), 1);
        // VM 1 has spent its lifetime budget — later intervals move nothing.
        assert_eq!(
            stack.run(&mut dc, 2 * HOUR, PlanTrigger::Tick, PlanScope::Cluster, &mut events),
            0
        );
        assert_eq!(events.len(), 1);
    }

    #[test]
    fn empty_stack_is_free() {
        let mut dc = dc_with_vms(1);
        let mut stack = PlannerStack::new(MigrationBudget::unlimited());
        assert!(stack.is_empty());
        let mut events = Vec::new();
        assert_eq!(stack.run(&mut dc, HOUR, PlanTrigger::Tick, PlanScope::Cluster, &mut events), 0);
        assert!(events.is_empty());
    }

    #[test]
    fn stack_names_in_order() {
        let stack = PlannerStack::new(MigrationBudget::unlimited())
            .with(Box::new(crate::migrate::DefragOnReject::new(true)))
            .with(Box::new(crate::migrate::PairwiseConsolidate::every(24)));
        assert_eq!(stack.names(), vec!["defrag", "consolidate"]);
        assert!(format!("{stack:?}").contains("defrag"));
    }
}
