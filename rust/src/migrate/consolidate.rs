//! Pairwise consolidation via inter-GPU migration (Algorithm 5), as a
//! policy-agnostic [`MigrationPlanner`].
//!
//! Periodically, half-full single-profile GPUs in scope — GPUs holding
//! exactly one instance that occupies one half of the device (one
//! 3g.20gb or 4g.20gb on the A100-40) — are merged pairwise: the guest
//! of the source moves into the free half of the target and the source
//! empties. Every move is a [`super::MigrationKind::Inter`] event; GRMU
//! returns emptied sources from its light basket to the pool.
//!
//! Placement-rule subtlety the pseudocode glosses over: a 4g.20gb can
//! only start at block 0, so two 4g.20gb-bearing GPUs can never merge —
//! the fit check below (via the default placement) rejects such pairs.
//! Likewise, on a mixed fleet only GPUs of the *same model* pair up
//! (Eq. 17–18): a half-full A30 can never receive an A100-40 instance.
//!
//! This used to live in `policies/grmu/consolidation.rs` and mutated the
//! data center as it paired. The planner reproduces the exact greedy
//! pairing — same candidate order (ascending `globalIndex`), same
//! restart-from-the-top after every merge — against a [`PlanView`]
//! overlay, so the emitted [`MigrationPlan`] applies through the
//! transactional `apply_plan` with byte-identical moves (locked in
//! `rust/tests/decision_api.rs`).

use super::{MigrationPlan, MigrationPlanner, PlanCtx, PlanTrigger, PlanView};
use crate::cluster::vm::{Time, HOUR};
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::placement::mock_assign;
use crate::mig::Placement;

/// Algorithm 5 as a planner, fired on the maintenance tick every
/// `period_hours`.
#[derive(Debug, Clone)]
pub struct PairwiseConsolidate {
    period_hours: u64,
    last: Time,
}

impl PairwiseConsolidate {
    /// Consolidate every `hours` simulation hours (Fig. 9's x-axis).
    pub fn every(hours: u64) -> PairwiseConsolidate {
        PairwiseConsolidate { period_hours: hours, last: 0 }
    }
}

impl MigrationPlanner for PairwiseConsolidate {
    fn name(&self) -> &'static str {
        "consolidate"
    }

    fn plan(&mut self, dc: &DataCenter, ctx: &PlanCtx, plan: &mut MigrationPlan) {
        if ctx.trigger != PlanTrigger::Tick {
            return;
        }
        // Same clock as the pre-extraction GRMU: due whenever a full
        // period elapsed since the last *due* tick, due or not fruitful.
        if ctx.now.saturating_sub(self.last) < self.period_hours * HOUR {
            return;
        }
        self.last = ctx.now;
        plan_consolidation(dc, ctx, plan);
    }

    fn snapshot_state(&self, out: &mut Vec<u8>) {
        let mut e = crate::util::codec::Enc::new();
        e.u64(self.last);
        out.extend_from_slice(e.bytes());
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut d = crate::util::codec::Dec::new(bytes);
        self.last = d.u64()?;
        if !d.is_empty() {
            return Err("trailing bytes in consolidate state".into());
        }
        Ok(())
    }
}

/// One consolidation round (Algorithm 5), appended to `plan`.
///
/// Greedy pairing: take each candidate source in ascending `globalIndex`
/// order, find the first compatible target among the remaining
/// candidates; on a merge both leave the candidate list and the scan
/// restarts from the top. Feasibility is checked against the
/// [`PlanView`] overlay, which tracks the host CPU/RAM that earlier
/// planned moves already shifted — the same state the sequential
/// application will walk through.
pub fn plan_consolidation(dc: &DataCenter, ctx: &PlanCtx, plan: &mut MigrationPlan) {
    // Candidates: available, half-full, single-profile GPUs (Algorithm 5
    // line 1). Unavailable capacity (failed/draining — see
    // [`crate::ops`]) is excluded in both roles: a draining host must
    // not *receive* guests, and its evacuation is the drain planner's
    // job, not consolidation's.
    let mut candidates: Vec<GpuRef> = ctx
        .scope
        .gpus(dc)
        .filter(|&r| {
            let g = dc.gpu(r);
            dc.gpu_available(r) && g.half_full() && g.single_profile()
        })
        .collect();

    let mut view = PlanView::new(dc);
    let mut i = 0;
    while i < candidates.len() {
        let source = candidates[i];
        let Some(inst) = dc.gpu(source).instances().first().copied() else {
            i += 1;
            continue;
        };
        let (cpus, ram) = dc.vm_demands(inst.vm).unwrap_or((0, 0));
        // Find a target whose free half accepts the source's profile.
        // (Feasibility is a single `mock_assign` table lookup per target,
        // so this path deliberately stays index-free: it behaves the same
        // under both candidate-iteration modes of the policies.)
        let mut chosen: Option<(usize, Placement)> = None;
        for (j, &target) in candidates.iter().enumerate() {
            if j == i {
                continue;
            }
            // Only GPUs of the instance's model can receive it
            // (Eq. 17–18): a mixed scope pairs per model.
            if dc.gpu(target).model() != inst.placement.profile.model() {
                continue;
            }
            // CPU/RAM must also follow the VM when hosts differ; the
            // paper's model migrates the whole VM.
            if source.host != target.host && !view.host_fits(target.host, cpus, ram) {
                continue;
            }
            if let Some((placement, _)) =
                mock_assign(view.occupancy(target), inst.placement.profile)
            {
                chosen = Some((j, placement));
                break;
            }
        }
        if let Some((j, placement)) = chosen {
            let target = candidates[j];
            view.note_move(source, inst.placement, target, placement, cpus, ram);
            plan.push_migrate(inst.vm, source, target, placement);
            // Source leaves the candidate list; target is now full and
            // leaves as well.
            let (lo, hi) = if i < j { (i, j) } else { (j, i) };
            candidates.remove(hi);
            candidates.remove(lo);
            // Restart scan from the beginning of the shrunk list.
            i = 0;
        } else {
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Host, VmSpec};
    use crate::mig::{GpuModel, Profile};
    use crate::migrate::{MigrationEvent, MigrationKind, PlanScope};
    use std::collections::BTreeSet;

    fn place(dc: &mut DataCenter, id: u64, profile: Profile, r: GpuRef, start: u8) {
        let vm = VmSpec {
            id,
            profile,
            cpus: 4,
            ram_gb: 8,
            arrival: 0,
            departure: 10,
            weight: 1.0,
        };
        dc.place(&vm, r, Placement { profile, start });
    }

    fn refs(n: u8) -> Vec<GpuRef> {
        (0..n).map(|g| GpuRef { host: 0, gpu: g }).collect()
    }

    /// Plan + apply one round over the given scope set; returns the
    /// performed events.
    fn consolidate(dc: &mut DataCenter, scope: &BTreeSet<GpuRef>) -> Vec<MigrationEvent> {
        let mut plan = MigrationPlan::new();
        let ctx = PlanCtx {
            now: 0,
            trigger: PlanTrigger::Tick,
            scope: PlanScope::Set(scope),
            pending: &[],
        };
        plan_consolidation(dc, &ctx, &mut plan);
        dc.apply_plan(&plan).expect("planned consolidation must apply");
        let mut events = Vec::new();
        plan.push_events_into(&mut events);
        events
    }

    #[test]
    fn merges_two_half_full_3g_gpus() {
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        place(&mut dc, 1, Profile::P3g20gb, refs(2)[0], 0);
        place(&mut dc, 2, Profile::P3g20gb, refs(2)[1], 0);
        let light: BTreeSet<GpuRef> = refs(2).into_iter().collect();
        let events = consolidate(&mut dc, &light);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, MigrationKind::Inter);
        assert_ne!(events[0].from, events[0].to);
        assert_eq!(events[0].blocks, 4);
        // One GPU holds both instances, the other is empty.
        assert_eq!(dc.gpu(events[0].to).instances().len(), 2);
        assert_eq!(dc.gpu(events[0].from).instances().len(), 0);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn two_4g_gpus_cannot_merge() {
        // Satellite edge case: 4g.20gb must start at block 0 — both GPUs
        // have block 0 taken, so the pair is never merged.
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        place(&mut dc, 1, Profile::P4g20gb, refs(2)[0], 0);
        place(&mut dc, 2, Profile::P4g20gb, refs(2)[1], 0);
        let light: BTreeSet<GpuRef> = refs(2).into_iter().collect();
        let events = consolidate(&mut dc, &light);
        assert!(events.is_empty());
        assert_eq!(dc.gpu(refs(2)[0]).instances().len(), 1);
        assert_eq!(dc.gpu(refs(2)[1]).instances().len(), 1);
    }

    #[test]
    fn cross_model_pairs_never_merge() {
        // Satellite edge case: a half-full A100-40 (3g.20gb) and a
        // half-full A30 (2g.12gb) are both candidates, but Eq. 17–18
        // forbids the merge in either direction.
        let mut dc = DataCenter::new(vec![Host::with_models(
            0,
            256,
            1024,
            &[GpuModel::A100_40, GpuModel::A30],
        )]);
        let (a100, a30) = (GpuRef { host: 0, gpu: 0 }, GpuRef { host: 0, gpu: 1 });
        place(&mut dc, 1, Profile::P3g20gb, a100, 0);
        let k2g = GpuModel::A30.profile(1); // 2g.12gb: half of the A30
        place(&mut dc, 2, k2g, a30, 0);
        assert!(dc.gpu(a100).half_full() && dc.gpu(a30).half_full());
        let light: BTreeSet<GpuRef> = [a100, a30].into_iter().collect();
        let events = consolidate(&mut dc, &light);
        assert!(events.is_empty(), "cross-model merge planned: {events:?}");
        assert_eq!(dc.locate(1).unwrap().gpu, a100);
        assert_eq!(dc.locate(2).unwrap().gpu, a30);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn same_model_pairs_still_merge_on_mixed_fleets() {
        // Two half-full A30s merge even with a half-full A100 in scope.
        let mut dc = DataCenter::new(vec![Host::with_models(
            0,
            256,
            1024,
            &[GpuModel::A100_40, GpuModel::A30, GpuModel::A30],
        )]);
        let k2g = GpuModel::A30.profile(1);
        place(&mut dc, 1, Profile::P3g20gb, GpuRef { host: 0, gpu: 0 }, 0);
        place(&mut dc, 2, k2g, GpuRef { host: 0, gpu: 1 }, 0);
        place(&mut dc, 3, k2g, GpuRef { host: 0, gpu: 2 }, 0);
        let light: BTreeSet<GpuRef> = refs(3).into_iter().collect();
        let events = consolidate(&mut dc, &light);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].model, GpuModel::A30);
        // The A100 instance did not move.
        assert_eq!(dc.locate(1).unwrap().gpu, GpuRef { host: 0, gpu: 0 });
        dc.check_integrity().unwrap();
    }

    #[test]
    fn mixed_3g_4g_merge_in_the_feasible_direction() {
        // 4g@0 on GPU 0, 3g@0 on GPU 1: only the 3g can move (to start 4
        // of GPU 0) — the 4g cannot start at 4.
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        place(&mut dc, 1, Profile::P4g20gb, refs(2)[0], 0);
        place(&mut dc, 2, Profile::P3g20gb, refs(2)[1], 0);
        let light: BTreeSet<GpuRef> = refs(2).into_iter().collect();
        let events = consolidate(&mut dc, &light);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].vm, 2);
        assert_eq!(events[0].from, GpuRef { host: 0, gpu: 1 });
        let loc = dc.locate(2).unwrap();
        assert_eq!(loc.gpu, GpuRef { host: 0, gpu: 0 });
        assert_eq!(loc.placement.start, 4);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn unavailable_gpus_are_not_candidates() {
        use crate::cluster::HealthState;
        // Two mergeable half-full GPUs on a draining host: consolidation
        // must leave them alone (the drain evacuation owns that host).
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        place(&mut dc, 1, Profile::P3g20gb, refs(2)[0], 0);
        place(&mut dc, 2, Profile::P3g20gb, refs(2)[1], 0);
        dc.set_host_health(0, HealthState::Draining);
        let light: BTreeSet<GpuRef> = refs(2).into_iter().collect();
        assert!(consolidate(&mut dc, &light).is_empty());
        dc.set_host_health(0, HealthState::Healthy);
        assert_eq!(consolidate(&mut dc, &light).len(), 1);
    }

    #[test]
    fn multi_instance_gpus_not_candidates() {
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        // Half-full but with two instances (2×2g) — not single-profile.
        place(&mut dc, 1, Profile::P2g10gb, refs(2)[0], 0);
        place(&mut dc, 2, Profile::P2g10gb, refs(2)[0], 2);
        place(&mut dc, 3, Profile::P3g20gb, refs(2)[1], 0);
        let light: BTreeSet<GpuRef> = refs(2).into_iter().collect();
        assert!(consolidate(&mut dc, &light).is_empty());
    }

    #[test]
    fn cross_host_migration_checks_resources() {
        // Target host has no CPU headroom → no migration that way.
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 1), Host::new(1, 4, 8, 1)]);
        place(&mut dc, 1, Profile::P3g20gb, GpuRef { host: 0, gpu: 0 }, 0);
        // Fill host 1's CPU with its own VM.
        place(&mut dc, 2, Profile::P3g20gb, GpuRef { host: 1, gpu: 0 }, 0);
        // Migrating VM 1 → host 1 impossible (CPU), VM 2 → host 0 fine.
        let light: BTreeSet<GpuRef> =
            [GpuRef { host: 0, gpu: 0 }, GpuRef { host: 1, gpu: 0 }].into_iter().collect();
        let events = consolidate(&mut dc, &light);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].from, GpuRef { host: 1, gpu: 0 });
        assert_eq!(dc.locate(2).unwrap().gpu.host, 0);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn four_gpus_pair_into_two_merges() {
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 4)]);
        for (i, r) in refs(4).into_iter().enumerate() {
            place(&mut dc, i as u64 + 1, Profile::P3g20gb, r, 0);
        }
        let light: BTreeSet<GpuRef> = refs(4).into_iter().collect();
        let events = consolidate(&mut dc, &light);
        assert_eq!(events.len(), 2);
        // Two GPUs full, two empty.
        let empty = refs(4).iter().filter(|&&r| dc.gpu(r).is_empty()).count();
        assert_eq!(empty, 2);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn period_gating_matches_the_grmu_clock() {
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        place(&mut dc, 1, Profile::P3g20gb, refs(2)[0], 0);
        place(&mut dc, 2, Profile::P3g20gb, refs(2)[1], 0);
        let mut planner = PairwiseConsolidate::every(24);
        let scope: BTreeSet<GpuRef> = refs(2).into_iter().collect();
        let mut plan = MigrationPlan::new();
        // Hour 1 tick: 1 HOUR < 24 — not due yet.
        planner.plan(
            &dc,
            &PlanCtx {
                now: HOUR,
                trigger: PlanTrigger::Tick,
                scope: PlanScope::Set(&scope),
                pending: &[],
            },
            &mut plan,
        );
        assert!(plan.is_empty());
        // Hour 24 tick: due.
        planner.plan(
            &dc,
            &PlanCtx {
                now: 24 * HOUR,
                trigger: PlanTrigger::Tick,
                scope: PlanScope::Set(&scope),
                pending: &[],
            },
            &mut plan,
        );
        assert_eq!(plan.num_moves(), 1);
        // A Rejection trigger never consolidates.
        let mut plan = MigrationPlan::new();
        planner.plan(
            &dc,
            &PlanCtx {
                now: 72 * HOUR,
                trigger: PlanTrigger::Rejection,
                scope: PlanScope::Set(&scope),
                pending: &[],
            },
            &mut plan,
        );
        assert!(plan.is_empty());
    }
}
