//! Explicit migration plans and their transactional application.
//!
//! A [`MigrationPlan`] is an ordered list of [`PlanStep`]s. Two step
//! shapes cover both of the paper's migration flavors:
//!
//! * [`PlanStep::Repack`] — an *atomic* intra-GPU re-pack: every listed
//!   instance moves to its new placement simultaneously (instances may
//!   swap blocks, so sequential application could transiently overlap;
//!   the step routes through
//!   [`DataCenter::repack_gpu`](crate::cluster::DataCenter::repack_gpu),
//!   which removes all movers before re-placing them).
//! * [`PlanStep::Migrate`] — one inter-GPU move, routed through
//!   [`DataCenter::migrate`](crate::cluster::DataCenter::migrate) so host
//!   CPU/RAM travel with the VM.
//!
//! [`DataCenter::apply_plan`] is the only way a plan touches the
//! cluster: each step is validated against the live state immediately
//! before it is applied, and if any step turns out infeasible the
//! already-applied prefix is rolled back in reverse order — the call is
//! all-or-nothing. Because both step shapes route through the existing
//! checked mutators, the `ClusterIndex` and activity counters stay
//! coherent throughout (including across a rollback), which
//! `check_integrity` verifies in the property tests below.

use super::{MigrationBudget, MigrationEvent, MigrationKind};
use crate::cluster::vm::VmId;
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::{BlockMask, Instance, Placement};
use std::collections::HashMap;
use std::fmt;

/// One step of a [`MigrationPlan`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanStep {
    /// Atomic intra-GPU re-pack (Algorithm 4): every listed instance
    /// moves from its current placement to the paired new one.
    Repack { gpu: GpuRef, moves: Vec<(Instance, Placement)> },
    /// One inter-GPU migration (Algorithm 5 / FragGradient).
    Migrate { vm: VmId, from: GpuRef, to: GpuRef, placement: Placement },
}

impl PlanStep {
    /// Individual VM moves in this step (the budget unit).
    pub fn num_moves(&self) -> usize {
        match self {
            PlanStep::Repack { moves, .. } => moves.len(),
            PlanStep::Migrate { .. } => 1,
        }
    }

    fn for_each_vm(&self, mut f: impl FnMut(VmId)) {
        match self {
            PlanStep::Repack { moves, .. } => {
                for (inst, _) in moves {
                    f(inst.vm);
                }
            }
            PlanStep::Migrate { vm, .. } => f(*vm),
        }
    }

    fn push_events_into(&self, out: &mut Vec<MigrationEvent>) {
        match self {
            PlanStep::Repack { gpu, moves } => {
                for (inst, _) in moves {
                    out.push(MigrationEvent {
                        vm: inst.vm,
                        from: *gpu,
                        to: *gpu,
                        kind: MigrationKind::Intra,
                        model: inst.placement.profile.model(),
                        blocks: inst.placement.profile.size(),
                    });
                }
            }
            PlanStep::Migrate { vm, from, to, placement } => out.push(MigrationEvent {
                vm: *vm,
                from: *from,
                to: *to,
                kind: MigrationKind::Inter,
                model: placement.profile.model(),
                blocks: placement.profile.size(),
            }),
        }
    }
}

/// An ordered, explicit migration plan. Built by
/// [`MigrationPlanner`](super::MigrationPlanner)s, budget-truncated by
/// the [`PlannerStack`](super::PlannerStack), applied atomically by
/// [`DataCenter::apply_plan`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MigrationPlan {
    steps: Vec<PlanStep>,
}

impl MigrationPlan {
    pub fn new() -> MigrationPlan {
        MigrationPlan::default()
    }

    /// Drop all steps (the stack reuses one plan across rounds).
    pub fn clear(&mut self) {
        self.steps.clear();
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn steps(&self) -> &[PlanStep] {
        &self.steps
    }

    /// Total individual VM moves across all steps (the budget unit).
    pub fn num_moves(&self) -> usize {
        self.steps.iter().map(|s| s.num_moves()).sum()
    }

    /// Block-weighted cost of the whole plan (sum of
    /// [`MigrationEvent::cost`] over the moves it would perform).
    pub fn cost(&self) -> u64 {
        let mut events = Vec::with_capacity(self.num_moves());
        self.push_events_into(&mut events);
        events.iter().map(|e| e.cost()).sum()
    }

    /// Append an atomic re-pack step; empty move lists are dropped.
    pub fn push_repack(&mut self, gpu: GpuRef, moves: Vec<(Instance, Placement)>) {
        if !moves.is_empty() {
            self.steps.push(PlanStep::Repack { gpu, moves });
        }
    }

    /// Append one inter-GPU move.
    pub fn push_migrate(&mut self, vm: VmId, from: GpuRef, to: GpuRef, placement: Placement) {
        self.steps.push(PlanStep::Migrate { vm, from, to, placement });
    }

    /// The [`MigrationEvent`]s this plan performs when applied, in order.
    pub fn push_events_into(&self, out: &mut Vec<MigrationEvent>) {
        for step in &self.steps {
            step.push_events_into(out);
        }
    }

    /// Keep the longest step prefix that fits both budget axes given
    /// `interval_moves` already spent this interval and the lifetime
    /// per-VM move counts in `vm_moves`. Truncation is prefix-only
    /// (steps stay whole and ordered), so budgeted plans remain
    /// deterministic.
    pub(crate) fn truncate_to_budget(
        &mut self,
        budget: &MigrationBudget,
        interval_moves: u32,
        vm_moves: &HashMap<VmId, u32>,
    ) {
        if budget.is_unlimited() {
            return;
        }
        let mut used = interval_moves;
        let mut local: HashMap<VmId, u32> = HashMap::new();
        let mut keep = 0usize;
        for step in &self.steps {
            let n = step.num_moves() as u32;
            if used.saturating_add(n) > budget.max_moves_per_interval {
                break;
            }
            let mut over_vm_budget = false;
            step.for_each_vm(|vm| {
                let lifetime =
                    vm_moves.get(&vm).copied().unwrap_or(0) + local.get(&vm).copied().unwrap_or(0);
                if lifetime + 1 > budget.max_moves_per_vm {
                    over_vm_budget = true;
                }
            });
            if over_vm_budget {
                break;
            }
            step.for_each_vm(|vm| *local.entry(vm).or_insert(0) += 1);
            used += n;
            keep += 1;
        }
        self.steps.truncate(keep);
    }
}

/// Why [`DataCenter::apply_plan`] refused a plan. The cluster is exactly
/// as it was before the call (the applied prefix was rolled back).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanError {
    /// Index of the infeasible step.
    pub step: usize,
    /// Human-readable cause.
    pub reason: String,
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "migration plan step {} infeasible: {}", self.step, self.reason)
    }
}

impl std::error::Error for PlanError {}

/// Undo record for one applied step (rollback runs these in reverse).
enum Undo {
    Repack { gpu: GpuRef, moves: Vec<(Instance, Placement)> },
    Migrate { vm: VmId, back_to: GpuRef, placement: Placement },
}

impl DataCenter {
    /// Validate and apply a [`MigrationPlan`] **atomically**. Steps are
    /// applied in order through the checked mutators
    /// ([`DataCenter::repack_gpu`], [`DataCenter::migrate`]), so the
    /// `ClusterIndex` and activity counters stay coherent. If any step
    /// is infeasible against the then-current state, every already
    /// applied step is rolled back in reverse order and the error names
    /// the offending step — the cluster is left exactly as before the
    /// call (all-or-nothing).
    pub fn apply_plan(&mut self, plan: &MigrationPlan) -> Result<(), PlanError> {
        let mut undo: Vec<Undo> = Vec::with_capacity(plan.steps().len());
        for (i, step) in plan.steps().iter().enumerate() {
            let applied = match step {
                PlanStep::Repack { gpu, moves } => self
                    .try_repack_step(*gpu, moves)
                    .map(|inverse| Undo::Repack { gpu: *gpu, moves: inverse }),
                PlanStep::Migrate { vm, from, to, placement } => self
                    .try_migrate_step(*vm, *from, *to, *placement)
                    .map(|(back_to, old)| Undo::Migrate { vm: *vm, back_to, placement: old }),
            };
            match applied {
                Ok(u) => undo.push(u),
                Err(reason) => {
                    // Roll back in reverse: each undo returns the cluster
                    // to the exact pre-step state, so every inverse
                    // operation is feasible by construction.
                    for u in undo.into_iter().rev() {
                        match u {
                            Undo::Repack { gpu, moves } => self.repack_gpu(gpu, &moves),
                            Undo::Migrate { vm, back_to, placement } => {
                                self.migrate(vm, back_to, placement)
                            }
                        }
                    }
                    return Err(PlanError { step: i, reason });
                }
            }
        }
        Ok(())
    }

    /// Validate + apply one re-pack step; returns the inverse move list.
    fn try_repack_step(
        &mut self,
        gpu_ref: GpuRef,
        moves: &[(Instance, Placement)],
    ) -> Result<Vec<(Instance, Placement)>, String> {
        if gpu_ref.host as usize >= self.hosts().len()
            || gpu_ref.gpu as usize >= self.host(gpu_ref.host).gpus().len()
        {
            return Err(format!("no such GPU {gpu_ref:?}"));
        }
        let gpu = self.gpu(gpu_ref);
        let mut freed: BlockMask = 0;
        for (k, (inst, new_pl)) in moves.iter().enumerate() {
            if moves[..k].iter().any(|(other, _)| other.vm == inst.vm) {
                return Err(format!("VM {} moved twice in one re-pack", inst.vm));
            }
            match gpu.find_vm(inst.vm) {
                Some(live) if live == *inst => {}
                Some(_) => return Err(format!("VM {} placement stale in plan", inst.vm)),
                None => return Err(format!("VM {} not on {gpu_ref:?}", inst.vm)),
            }
            if new_pl.profile != inst.placement.profile {
                return Err(format!("VM {} re-pack changes its profile", inst.vm));
            }
            if !new_pl.profile.start_blocks().contains(&new_pl.start) {
                return Err(format!("illegal start block {} for {}", new_pl.start, new_pl.profile));
            }
            freed |= inst.placement.mask();
        }
        // The movers' old blocks free up simultaneously; the new
        // placements must tile into the remainder without overlap.
        let mut occ = gpu.occupancy() & !freed;
        for (_, new_pl) in moves {
            if occ & new_pl.mask() != 0 {
                return Err(format!("re-pack placement {new_pl} overlaps on {gpu_ref:?}"));
            }
            occ |= new_pl.mask();
        }
        let inverse = moves
            .iter()
            .map(|(inst, new_pl)| (Instance { vm: inst.vm, placement: *new_pl }, inst.placement))
            .collect();
        self.repack_gpu(gpu_ref, moves);
        Ok(inverse)
    }

    /// Validate + apply one inter-GPU move; returns `(source GPU, old
    /// placement)` for rollback.
    fn try_migrate_step(
        &mut self,
        vm: VmId,
        from: GpuRef,
        to: GpuRef,
        placement: Placement,
    ) -> Result<(GpuRef, Placement), String> {
        let loc = self.locate(vm).ok_or_else(|| format!("VM {vm} not resident"))?;
        if loc.gpu != from {
            return Err(format!("VM {vm} is on {:?}, not {from:?}", loc.gpu));
        }
        if from == to {
            return Err(format!("VM {vm}: inter-GPU move with identical source/destination"));
        }
        if to.host as usize >= self.hosts().len()
            || to.gpu as usize >= self.host(to.host).gpus().len()
        {
            return Err(format!("no such GPU {to:?}"));
        }
        if !self.gpu_available(to) {
            return Err(format!("destination {to:?} is unavailable (failed/draining)"));
        }
        if placement.profile != loc.placement.profile {
            return Err(format!("VM {vm} migration changes its profile"));
        }
        let dst = self.gpu(to);
        if dst.model() != placement.profile.model() {
            return Err(format!("destination {to:?} is a {} part", dst.model()));
        }
        if !placement.profile.start_blocks().contains(&placement.start) {
            return Err(format!("illegal start block {} for {}", placement.start, placement.profile));
        }
        if dst.occupancy() & placement.mask() != 0 {
            return Err(format!("destination blocks occupied on {to:?}"));
        }
        if from.host != to.host {
            let (cpus, ram) = self.vm_demands(vm).unwrap_or((0, 0));
            if !self.host(to.host).fits_resources(cpus, ram) {
                return Err(format!("host {} lacks CPU/RAM for VM {vm}", to.host));
            }
        }
        self.migrate(vm, to, placement);
        Ok((from, loc.placement))
    }
}

/// A planner's virtual view of host headroom and GPU occupancy on top of
/// an immutable [`DataCenter`]: planners validate multi-move plans
/// against it without touching the cluster, then record each planned
/// move so later moves in the same plan see the intermediate state —
/// exactly the state [`DataCenter::apply_plan`] will walk through.
///
/// One VM may be moved at most once per plan (all shipped planners
/// satisfy this; `apply_plan` re-validates regardless).
pub struct PlanView<'a> {
    dc: &'a DataCenter,
    /// Overridden occupancy of touched GPUs (absolute masks).
    occ: HashMap<GpuRef, BlockMask>,
    /// Free CPU/RAM deltas of touched hosts.
    host_delta: HashMap<u32, (i64, i64)>,
}

impl<'a> PlanView<'a> {
    pub fn new(dc: &'a DataCenter) -> PlanView<'a> {
        PlanView { dc, occ: HashMap::new(), host_delta: HashMap::new() }
    }

    /// Occupancy of `r` after the moves recorded so far.
    pub fn occupancy(&self, r: GpuRef) -> BlockMask {
        self.occ.get(&r).copied().unwrap_or_else(|| self.dc.gpu(r).occupancy())
    }

    /// Would `host` still fit a `cpus`/`ram_gb` reservation after the
    /// moves recorded so far?
    pub fn host_fits(&self, host: u32, cpus: u32, ram_gb: u32) -> bool {
        let h = self.dc.host(host);
        let (dc_cpu, dc_ram) = self.host_delta.get(&host).copied().unwrap_or((0, 0));
        h.free_cpus() as i64 + dc_cpu >= cpus as i64 && h.free_ram() as i64 + dc_ram >= ram_gb as i64
    }

    /// Record a planned move of a `cpus`/`ram_gb` VM from `(from, old)`
    /// to `(to, new)`; subsequent queries see the post-move state.
    pub fn note_move(
        &mut self,
        from: GpuRef,
        old: Placement,
        to: GpuRef,
        new: Placement,
        cpus: u32,
        ram_gb: u32,
    ) {
        let from_occ = self.occupancy(from) & !old.mask();
        self.occ.insert(from, from_occ);
        let to_occ = self.occupancy(to) | new.mask();
        self.occ.insert(to, to_occ);
        if from.host != to.host {
            let e = self.host_delta.entry(from.host).or_insert((0, 0));
            e.0 += cpus as i64;
            e.1 += ram_gb as i64;
            let e = self.host_delta.entry(to.host).or_insert((0, 0));
            e.0 -= cpus as i64;
            e.1 -= ram_gb as i64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Host, VmSpec};
    use crate::mig::placement::mock_assign;
    use crate::mig::{GpuModel, Profile, ALL_MODELS};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn spec(id: VmId, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 4, ram_gb: 8, arrival: 0, departure: 1_000, weight: 1.0 }
    }

    fn place(dc: &mut DataCenter, id: VmId, profile: Profile, r: GpuRef, start: u8) {
        dc.place(&spec(id, profile), r, Placement { profile, start });
    }

    /// Structural fingerprint of the cluster for before/after comparison:
    /// every GPU's occupancy + sorted instances, every host's free
    /// CPU/RAM.
    type HostPrint = (u32, u32, Vec<(BlockMask, Vec<Instance>)>);

    fn fingerprint(dc: &DataCenter) -> Vec<HostPrint> {
        dc.hosts()
            .iter()
            .map(|h| {
                let gpus = h
                    .gpus()
                    .iter()
                    .map(|g| {
                        let mut insts = g.instances().to_vec();
                        insts.sort_by_key(|i| i.vm);
                        (g.occupancy(), insts)
                    })
                    .collect();
                (h.free_cpus(), h.free_ram(), gpus)
            })
            .collect()
    }

    #[test]
    fn applies_a_repack_and_a_migrate() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let (g0, g1) = (GpuRef { host: 0, gpu: 0 }, GpuRef { host: 0, gpu: 1 });
        place(&mut dc, 1, Profile::P1g5gb, g0, 4);
        place(&mut dc, 2, Profile::P3g20gb, g1, 0);
        let inst = dc.gpu(g0).find_vm(1).unwrap();
        let mut plan = MigrationPlan::new();
        plan.push_repack(g0, vec![(inst, Placement { profile: Profile::P1g5gb, start: 6 })]);
        plan.push_migrate(2, g1, g0, Placement { profile: Profile::P3g20gb, start: 0 });
        assert_eq!(plan.num_moves(), 2);
        // 1 block intra (×1) + 4 blocks inter (×2).
        assert_eq!(plan.cost(), 1 + 8);
        dc.apply_plan(&plan).unwrap();
        assert_eq!(dc.locate(1).unwrap().placement.start, 6);
        assert_eq!(dc.locate(2).unwrap().gpu, g0);
        assert!(dc.gpu(g1).is_empty());
        let mut events = Vec::new();
        plan.push_events_into(&mut events);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, MigrationKind::Intra);
        assert_eq!(events[1].kind, MigrationKind::Inter);
        assert_eq!(events[1].blocks, 4);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn infeasible_mid_plan_step_rolls_back_everything() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let (g0, g1) = (GpuRef { host: 0, gpu: 0 }, GpuRef { host: 0, gpu: 1 });
        place(&mut dc, 1, Profile::P1g5gb, g0, 4);
        place(&mut dc, 2, Profile::P3g20gb, g1, 0);
        let before = fingerprint(&dc);
        let inst = dc.gpu(g0).find_vm(1).unwrap();
        let mut plan = MigrationPlan::new();
        // Step 0 is fine; step 1 targets occupied blocks on g1.
        plan.push_repack(g0, vec![(inst, Placement { profile: Profile::P1g5gb, start: 6 })]);
        plan.push_migrate(1, g0, g1, Placement { profile: Profile::P1g5gb, start: 0 });
        let err = dc.apply_plan(&plan).unwrap_err();
        assert_eq!(err.step, 1);
        assert_eq!(fingerprint(&dc), before, "rollback must restore the exact state");
        dc.check_integrity().unwrap();
        // The stale-placement path: the repack above was rolled back, so a
        // plan recorded against the *applied* state is now stale.
        let stale = Instance { vm: 1, placement: Placement { profile: Profile::P1g5gb, start: 6 } };
        let mut plan = MigrationPlan::new();
        plan.push_repack(g0, vec![(stale, Placement { profile: Profile::P1g5gb, start: 5 })]);
        assert!(dc.apply_plan(&plan).is_err());
        assert_eq!(fingerprint(&dc), before);
    }

    #[test]
    fn cross_host_rollback_restores_resources() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1), Host::new(1, 64, 256, 1)]);
        let (g0, g1) = (GpuRef { host: 0, gpu: 0 }, GpuRef { host: 1, gpu: 0 });
        place(&mut dc, 1, Profile::P3g20gb, g0, 0);
        let before = fingerprint(&dc);
        let mut plan = MigrationPlan::new();
        plan.push_migrate(1, g0, g1, Placement { profile: Profile::P3g20gb, start: 0 });
        // Second step is nonsense: VM 99 does not exist.
        plan.push_migrate(99, g0, g1, Placement { profile: Profile::P3g20gb, start: 4 });
        let err = dc.apply_plan(&plan).unwrap_err();
        assert_eq!(err.step, 1);
        assert_eq!(fingerprint(&dc), before);
        assert_eq!(dc.host(0).free_cpus(), 60);
        assert_eq!(dc.host(1).free_cpus(), 64);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn rejects_model_and_profile_changes() {
        let mut dc = DataCenter::new(vec![Host::with_models(
            0,
            64,
            256,
            &[GpuModel::A100_40, GpuModel::A30],
        )]);
        let (g0, g1) = (GpuRef { host: 0, gpu: 0 }, GpuRef { host: 0, gpu: 1 });
        place(&mut dc, 1, Profile::P1g5gb, g0, 6);
        // Cross-model migration is never legal (Eq. 17–18).
        let mut plan = MigrationPlan::new();
        plan.push_migrate(1, g0, g1, Placement { profile: Profile::P1g5gb, start: 0 });
        assert!(dc.apply_plan(&plan).is_err());
        // Profile swaps are not migrations.
        let inst = dc.gpu(g0).find_vm(1).unwrap();
        let mut plan = MigrationPlan::new();
        plan.push_repack(g0, vec![(inst, Placement { profile: Profile::P2g10gb, start: 0 })]);
        assert!(dc.apply_plan(&plan).is_err());
        dc.check_integrity().unwrap();
    }

    #[test]
    fn rejects_unavailable_destinations() {
        use crate::cluster::HealthState;
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let (g0, g1) = (GpuRef { host: 0, gpu: 0 }, GpuRef { host: 0, gpu: 1 });
        place(&mut dc, 1, Profile::P1g5gb, g0, 4);
        dc.set_gpu_health(g1, HealthState::Failed { until: 50 });
        let mut plan = MigrationPlan::new();
        plan.push_migrate(1, g0, g1, Placement { profile: Profile::P1g5gb, start: 0 });
        assert!(dc.apply_plan(&plan).is_err());
        dc.check_integrity().unwrap();
        // Repair the device and the same plan applies.
        dc.set_gpu_health(g1, HealthState::Healthy);
        dc.apply_plan(&plan).unwrap();
        assert_eq!(dc.locate(1).unwrap().gpu, g1);
    }

    #[test]
    fn budget_truncation_keeps_step_prefix() {
        let g0 = GpuRef { host: 0, gpu: 0 };
        let g1 = GpuRef { host: 0, gpu: 1 };
        let pl = |start| Placement { profile: Profile::P1g5gb, start };
        let mut plan = MigrationPlan::new();
        plan.push_migrate(1, g0, g1, pl(0));
        plan.push_migrate(2, g0, g1, pl(1));
        plan.push_migrate(1, g1, g0, pl(4));
        // Interval budget of 2 keeps the first two steps.
        let mut p = plan.clone();
        p.truncate_to_budget(&MigrationBudget::unlimited().per_interval(2), 0, &HashMap::new());
        assert_eq!(p.num_moves(), 2);
        // ... minus what the interval already spent.
        let mut p = plan.clone();
        p.truncate_to_budget(&MigrationBudget::unlimited().per_interval(2), 1, &HashMap::new());
        assert_eq!(p.num_moves(), 1);
        // Per-VM budget of 1: the third step moves VM 1 again — dropped.
        let mut p = plan.clone();
        p.truncate_to_budget(&MigrationBudget::unlimited().per_vm(1), 0, &HashMap::new());
        assert_eq!(p.num_moves(), 2);
        // Lifetime counts from earlier intervals count too.
        let mut moved = HashMap::new();
        moved.insert(1u64, 1u32);
        let mut p = plan.clone();
        p.truncate_to_budget(&MigrationBudget::unlimited().per_vm(1), 0, &moved);
        assert_eq!(p.num_moves(), 0);
        // Unlimited is a no-op.
        let mut p = plan.clone();
        p.truncate_to_budget(&MigrationBudget::unlimited(), 1_000, &moved);
        assert_eq!(p.num_moves(), 3);
    }

    /// Acceptance criterion: `apply_plan` is atomic — a plan with an
    /// infeasible step (at a random position, after a random feasible
    /// prefix, on random single- or mixed-model clusters) leaves the
    /// cluster, `ClusterIndex` and activity counters exactly unchanged
    /// per `check_integrity` and a full structural fingerprint.
    #[test]
    fn prop_infeasible_plans_leave_cluster_unchanged() {
        forall(
            "apply-plan-rollback",
            |r: &mut Rng| {
                let hosts: Vec<Host> = (0..2 + r.below(3))
                    .map(|i| {
                        let models: Vec<GpuModel> = (0..1 + r.below(3))
                            .map(|_| ALL_MODELS[r.below(ALL_MODELS.len() as u64) as usize])
                            .collect();
                        Host::with_models(i as u32, 24, 96, &models)
                    })
                    .collect();
                let mut dc = DataCenter::new(hosts);
                let refs = dc.gpu_refs();
                let mut next_vm: u64 = 1;
                for _ in 0..24 {
                    let gr = refs[r.below(refs.len() as u64) as usize];
                    let model = dc.gpu(gr).model();
                    let profile = model.profile(r.below(model.num_profiles() as u64) as usize);
                    let vm = spec(next_vm, profile);
                    if dc.host(gr.host).fits_resources(vm.cpus, vm.ram_gb) {
                        if let Some((pl, _)) = mock_assign(dc.gpu(gr).occupancy(), profile) {
                            dc.place(&vm, gr, pl);
                            next_vm += 1;
                        }
                    }
                }
                // A feasible prefix: up to two real inter-GPU moves,
                // planned against a PlanView overlay.
                let mut plan = MigrationPlan::new();
                let mut view = PlanView::new(&dc);
                let mut moved: Vec<u64> = Vec::new();
                for _ in 0..r.below(3) {
                    let candidates: Vec<(u64, GpuRef, Placement)> = dc
                        .hosts()
                        .iter()
                        .flat_map(|h| h.gpus().iter().enumerate().map(move |(g, gpu)| {
                            (GpuRef { host: h.id, gpu: g as u8 }, gpu)
                        }))
                        .flat_map(|(gr, gpu)| {
                            gpu.instances().iter().map(move |i| (i.vm, gr, i.placement))
                        })
                        .filter(|(vm, _, _)| !moved.contains(vm))
                        .collect();
                    if candidates.is_empty() {
                        break;
                    }
                    let (vm, from, old) =
                        candidates[r.below(candidates.len() as u64) as usize];
                    let (cpus, ram) = dc.vm_demands(vm).unwrap();
                    let dest = refs.iter().copied().find(|&to| {
                        to != from
                            && dc.gpu(to).model() == old.profile.model()
                            && (to.host == from.host || view.host_fits(to.host, cpus, ram))
                            && mock_assign(view.occupancy(to), old.profile).is_some()
                    });
                    if let Some(to) = dest {
                        let (pl, _) = mock_assign(view.occupancy(to), old.profile).unwrap();
                        view.note_move(from, old, to, pl, cpus, ram);
                        plan.push_migrate(vm, from, to, pl);
                        moved.push(vm);
                    }
                }
                // Poison the tail with one of several infeasible shapes.
                let poison = r.below(3);
                (dc, plan, poison)
            },
            |(dc, plan, poison)| {
                let mut dc = dc.clone();
                let mut plan = plan.clone();
                let g0 = dc.gpu_refs()[0];
                match *poison {
                    // A VM that does not exist.
                    0 => plan.push_migrate(9_999, g0, g0, Placement {
                        profile: dc.gpu(g0).model().profile(0),
                        start: dc.gpu(g0).model().profile(0).start_blocks()[0],
                    }),
                    // A stale repack (instance not on the GPU).
                    1 => {
                        let k = dc.gpu(g0).model().profile(0);
                        let fake = Instance {
                            vm: 9_999,
                            placement: Placement { profile: k, start: k.start_blocks()[0] },
                        };
                        plan.push_repack(g0, vec![(
                            fake,
                            Placement { profile: k, start: k.start_blocks()[0] },
                        )]);
                    }
                    // An out-of-range destination GPU.
                    _ => {
                        let resident: Option<(u64, GpuRef)> = dc
                            .hosts()
                            .iter()
                            .flat_map(|h| {
                                h.gpus().iter().enumerate().flat_map(move |(g, gpu)| {
                                    gpu.instances()
                                        .iter()
                                        .map(move |i| (i.vm, GpuRef { host: h.id, gpu: g as u8 }))
                                })
                            })
                            .next();
                        match resident {
                            Some((vm, from)) => {
                                let k = dc.locate(vm).unwrap().placement.profile;
                                plan.push_migrate(vm, from, GpuRef { host: 999, gpu: 0 }, Placement {
                                    profile: k,
                                    start: k.start_blocks()[0],
                                });
                            }
                            // Empty cluster case: poison with a ghost VM.
                            None => plan.push_migrate(9_999, g0, GpuRef { host: 999, gpu: 0 },
                                Placement {
                                    profile: dc.gpu(g0).model().profile(0),
                                    start: dc.gpu(g0).model().profile(0).start_blocks()[0],
                                }),
                        }
                    }
                }
                let before = fingerprint(&dc);
                if dc.apply_plan(&plan).is_ok() {
                    return Err("poisoned plan applied".into());
                }
                if fingerprint(&dc) != before {
                    return Err("rollback did not restore the cluster".into());
                }
                dc.check_integrity().map_err(|e| format!("integrity after rollback: {e}"))
            },
        );
    }
}
