//! The policy-agnostic migration-planner layer.
//!
//! The paper's third objective — minimizing migration overhead (§4,
//! Table 2's `IntraMigrate`/`InterMigrate` costs, the "~1% of MIG-enabled
//! VMs migrated" headline of §8.3.3) — used to live as private helpers
//! inside the GRMU policy, so no other policy could defragment or
//! consolidate and migration cost was never first-class in results. This
//! module extracts the mechanism behind a scheduler-independent contract,
//! the way fragmentation-aware MIG schedulers treat migration as a
//! mechanism any placement policy can drive:
//!
//! * A [`MigrationPlanner`] inspects the cluster (read-only) and produces
//!   an explicit [`MigrationPlan`]: ordered [`PlanStep`]s — atomic
//!   intra-GPU re-packs (Algorithm 4) and single inter-GPU moves
//!   (Algorithm 5) — each carrying the exact destination placements.
//! * [`DataCenter::apply_plan`](crate::cluster::DataCenter::apply_plan)
//!   validates and applies a plan **transactionally**: every step is
//!   checked against the live state and routed through
//!   `repack_gpu`/`migrate` (so the `ClusterIndex` and activity counters
//!   stay coherent), and an infeasible mid-plan step rolls the already
//!   applied prefix back — all-or-nothing, verified by `check_integrity`.
//! * Applied moves surface as [`MigrationEvent`]s with a block-weighted
//!   [`MigrationEvent::cost`] (GI size in blocks × the
//!   [`MigrationKind::weight`] cost ratio of Table 2), so results can
//!   account migration overhead per kind and per model.
//! * A [`PlannerStack`] composes planners with per-interval / per-VM
//!   migration [`MigrationBudget`]s; planners run in stack order and each
//!   plan is budget-truncated before it is applied.
//!
//! The shipped planners:
//!
//! * [`defrag::DefragOnReject`] — Algorithm 4: on a rejected batch,
//!   re-pack the most fragmented in-scope GPU (intra-GPU moves only).
//! * [`consolidate::PairwiseConsolidate`] — Algorithm 5: periodically
//!   merge half-full single-profile GPU pairs (inter-GPU moves).
//! * [`frag_gradient::FragGradient`] — new here: when the mean
//!   fragmentation of occupied in-scope GPUs crosses a threshold, drain
//!   the most fragmented GPUs onto less fragmented ones, à la the online
//!   fragmentation-aware MIG schedulers.
//!
//! ## Scope and determinism
//!
//! Planners see the cluster through a [`PlanScope`]: either the whole
//! fleet or an explicit GPU set (GRMU hands its light basket). Every
//! scope iterates in ascending [`GpuRef`] — the paper's `globalIndex` —
//! so plans are deterministic and byte-identical across runs; the same
//! contract that makes indexed policy decisions identical to full scans.
//! GRMU's default configuration routes through this layer and produces
//! byte-identical Decision/MigrationEvent sequences to the pre-extraction
//! inline implementation (locked by `rust/tests/decision_api.rs`).

pub mod consolidate;
pub mod defrag;
pub mod frag_gradient;
pub mod plan;
pub mod stack;

pub use consolidate::PairwiseConsolidate;
pub use defrag::DefragOnReject;
pub use frag_gradient::FragGradient;
pub use plan::{MigrationPlan, PlanError, PlanStep, PlanView};
pub use stack::PlannerStack;

use crate::cluster::vm::{Time, VmId};
use crate::cluster::{DataCenter, GpuRef, VmSpec};
use crate::mig::GpuModel;
use std::collections::BTreeSet;
use std::fmt;

/// Migration flavor (Table 2): intra-GPU relocation vs inter-GPU move.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MigrationKind {
    /// Defragmentation relocation within one GPU (Alg. 4, `ω_ijk` only).
    Intra,
    /// Move to a different GPU (Alg. 5 consolidation, FragGradient).
    Inter,
}

impl MigrationKind {
    /// Both kinds, in [`MigrationKind::index`] order.
    pub const ALL: [MigrationKind; 2] = [MigrationKind::Intra, MigrationKind::Inter];

    /// Dense index for per-kind accounting arrays.
    #[inline]
    pub fn index(self) -> usize {
        match self {
            MigrationKind::Intra => 0,
            MigrationKind::Inter => 1,
        }
    }

    /// Stable name used in reports and JSON exports.
    pub fn name(self) -> &'static str {
        match self {
            MigrationKind::Intra => "intra",
            MigrationKind::Inter => "inter",
        }
    }

    /// Relative cost weight per moved block (Table 2): an inter-GPU move
    /// copies instance state across devices (and possibly hosts), an
    /// intra-GPU relocation stays on-part — the model charges inter
    /// migration twice the per-block rate.
    #[inline]
    pub fn weight(self) -> u64 {
        match self {
            MigrationKind::Intra => 1,
            MigrationKind::Inter => 2,
        }
    }
}

impl fmt::Display for MigrationKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One applied migration. For [`MigrationKind::Intra`] events
/// `from == to` (the GI moved between blocks of the same GPU). Carries
/// the moved GI's model and size so migration overhead can be accounted
/// per kind and per model without re-resolving the (possibly departed)
/// VM.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MigrationEvent {
    pub vm: VmId,
    pub from: GpuRef,
    pub to: GpuRef,
    pub kind: MigrationKind,
    /// Model of the GPU(s) involved (source and destination always
    /// share it, Eq. 17–18).
    pub model: GpuModel,
    /// GI size in memory blocks — the block-weighted cost basis.
    pub blocks: u8,
}

impl MigrationEvent {
    /// Block-weighted migration cost (Eq. 24–25's overhead term):
    /// blocks moved × the kind's per-block weight.
    #[inline]
    pub fn cost(&self) -> u64 {
        self.blocks as u64 * self.kind.weight()
    }

    /// Serialize for crash-safe snapshots ([`crate::recover`]).
    pub(crate) fn encode(&self, e: &mut crate::util::codec::Enc) {
        e.u64(self.vm);
        e.u32(self.from.host);
        e.u8(self.from.gpu);
        e.u32(self.to.host);
        e.u8(self.to.gpu);
        e.u8(self.kind.index() as u8);
        e.u8(self.model as u8);
        e.u8(self.blocks);
    }

    /// Inverse of [`MigrationEvent::encode`].
    pub(crate) fn decode(d: &mut crate::util::codec::Dec) -> Result<MigrationEvent, String> {
        let vm = d.u64()?;
        let from = GpuRef { host: d.u32()?, gpu: d.u8()? };
        let to = GpuRef { host: d.u32()?, gpu: d.u8()? };
        let kind = match d.u8()? {
            0 => MigrationKind::Intra,
            1 => MigrationKind::Inter,
            k => return Err(format!("malformed migration kind {k}")),
        };
        let model_idx = d.u8()? as usize;
        let model = *crate::mig::ALL_MODELS
            .get(model_idx)
            .ok_or_else(|| format!("malformed GPU model index {model_idx}"))?;
        let blocks = d.u8()?;
        Ok(MigrationEvent { vm, from, to, kind, model, blocks })
    }
}

/// What fired a planning round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanTrigger {
    /// The just-decided batch rejected at least one VM (Algorithm 4's
    /// defragmentation trigger).
    Rejection,
    /// The periodic maintenance tick at the end of an interval
    /// (Algorithm 5's consolidation clock).
    Tick,
}

/// The GPUs a planner may touch. Iteration is always ascending
/// [`GpuRef`] — the `globalIndex` determinism contract.
#[derive(Clone, Copy)]
pub enum PlanScope<'a> {
    /// Every GPU in the cluster.
    Cluster,
    /// Only the listed GPUs (e.g. GRMU's light basket).
    Set(&'a BTreeSet<GpuRef>),
}

impl<'a> PlanScope<'a> {
    /// The in-scope GPUs, ascending `globalIndex`.
    pub fn gpus<'d>(&self, dc: &'d DataCenter) -> ScopeIter<'a, 'd> {
        match self {
            PlanScope::Cluster => ScopeIter::Cluster { dc, host: 0, gpu: 0 },
            PlanScope::Set(set) => ScopeIter::Set(set.iter()),
        }
    }
}

impl fmt::Debug for PlanScope<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanScope::Cluster => f.write_str("Cluster"),
            PlanScope::Set(s) => write!(f, "Set({} GPUs)", s.len()),
        }
    }
}

/// Iterator behind [`PlanScope::gpus`]. The scope-set borrow (`'s`) and
/// the data-center borrow (`'d`) are independent, so a long-lived scope
/// can be walked against a short-lived cluster reference.
pub enum ScopeIter<'s, 'd> {
    Cluster { dc: &'d DataCenter, host: usize, gpu: usize },
    Set(std::collections::btree_set::Iter<'s, GpuRef>),
}

impl Iterator for ScopeIter<'_, '_> {
    type Item = GpuRef;

    fn next(&mut self) -> Option<GpuRef> {
        match self {
            ScopeIter::Set(it) => it.next().copied(),
            ScopeIter::Cluster { dc, host, gpu } => {
                let hosts = dc.hosts();
                while *host < hosts.len() {
                    let h = &hosts[*host];
                    if *gpu < h.gpus().len() {
                        let r = GpuRef { host: h.id, gpu: *gpu as u8 };
                        *gpu += 1;
                        return Some(r);
                    }
                    *host += 1;
                    *gpu = 0;
                }
                None
            }
        }
    }
}

/// Per-round planning context handed to every planner.
#[derive(Debug, Clone, Copy)]
pub struct PlanCtx<'a> {
    /// Virtual time of the round (end of the current interval).
    pub now: Time,
    /// What fired the round.
    pub trigger: PlanTrigger,
    /// The GPUs the planner may touch.
    pub scope: PlanScope<'a>,
    /// VMs the triggering batch failed to place (empty on
    /// [`PlanTrigger::Tick`] rounds and for callers that don't track
    /// rejects). Plans can only move *resident* VMs — pending specs are
    /// demand hints: a repair planner (`ilp::online::RollingIlp`) folds
    /// them into its objective so the repair frees contiguous space the
    /// rejects (or future arrivals like them) can use.
    pub pending: &'a [VmSpec],
}

/// A migration planner: inspects the cluster read-only and appends
/// [`PlanStep`]s to the round's [`MigrationPlan`]. Planners must only
/// propose moves that are feasible against the state they were shown
/// plus their own earlier steps (track virtual state with a
/// [`PlanView`]); the transactional
/// [`apply_plan`](crate::cluster::DataCenter::apply_plan) rolls back any
/// plan that turns out infeasible. `Send` so planner stacks can ride
/// inside policies on the coordinator's service thread.
pub trait MigrationPlanner: Send {
    /// Short name used in registry suffixes and reports ("defrag", ...).
    fn name(&self) -> &'static str;

    /// Append this round's proposed steps to `plan`. A planner that does
    /// not respond to `ctx.trigger` (or whose own gating — period,
    /// threshold — says "not now") appends nothing.
    fn plan(&mut self, dc: &DataCenter, ctx: &PlanCtx, plan: &mut MigrationPlan);

    /// Serialize decision-relevant planner state for the crash-safe
    /// snapshot layer (see `crate::policies::Policy::snapshot_state` —
    /// same contract). Stateless planners keep the default no-op;
    /// cadence-gated planners must at least persist their "last ran"
    /// clock so a resumed run keeps the cadence phase.
    fn snapshot_state(&self, _out: &mut Vec<u8>) {}

    /// Restore state captured by [`MigrationPlanner::snapshot_state`]
    /// into a freshly built planner of the same name and configuration.
    /// The default accepts only an empty state.
    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        if bytes.is_empty() {
            Ok(())
        } else {
            Err(format!("planner {} carries no restorable state", self.name()))
        }
    }
}

/// Migration budgets bounding how much a [`PlannerStack`] may move:
/// moves per interval (across all planners in the stack) and lifetime
/// moves per VM. The default is unlimited on both axes — the paper's
/// GRMU configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationBudget {
    /// Max moves per interval across the stack (`u32::MAX` = unlimited).
    pub max_moves_per_interval: u32,
    /// Max times any one VM may be moved over a run (`u32::MAX` =
    /// unlimited).
    pub max_moves_per_vm: u32,
}

impl Default for MigrationBudget {
    fn default() -> Self {
        MigrationBudget::unlimited()
    }
}

impl MigrationBudget {
    /// No limits (the default).
    pub const fn unlimited() -> MigrationBudget {
        MigrationBudget { max_moves_per_interval: u32::MAX, max_moves_per_vm: u32::MAX }
    }

    #[inline]
    pub fn is_unlimited(&self) -> bool {
        self.max_moves_per_interval == u32::MAX && self.max_moves_per_vm == u32::MAX
    }

    pub fn per_interval(mut self, n: u32) -> MigrationBudget {
        self.max_moves_per_interval = n;
        self
    }

    pub fn per_vm(mut self, n: u32) -> MigrationBudget {
        self.max_moves_per_vm = n;
        self
    }

    /// Parse the CLI syntax: `"8"` (moves per interval) or `"8:2"`
    /// (moves per interval : lifetime moves per VM).
    pub fn parse(s: &str) -> Result<MigrationBudget, String> {
        let mut budget = MigrationBudget::unlimited();
        let mut parts = s.split(':');
        let interval = parts.next().unwrap_or("");
        budget.max_moves_per_interval = interval
            .trim()
            .parse()
            .map_err(|e| format!("bad per-interval budget {interval:?}: {e}"))?;
        if let Some(per_vm) = parts.next() {
            budget.max_moves_per_vm = per_vm
                .trim()
                .parse()
                .map_err(|e| format!("bad per-VM budget {per_vm:?}: {e}"))?;
        }
        if parts.next().is_some() {
            return Err(format!("budget {s:?} has too many ':' fields (want N or N:M)"));
        }
        Ok(budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;

    #[test]
    fn kind_indices_and_weights() {
        for (i, k) in MigrationKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
        }
        assert_eq!(MigrationKind::Intra.weight(), 1);
        assert_eq!(MigrationKind::Inter.weight(), 2);
        let ev = MigrationEvent {
            vm: 1,
            from: GpuRef { host: 0, gpu: 0 },
            to: GpuRef { host: 0, gpu: 1 },
            kind: MigrationKind::Inter,
            model: GpuModel::A100_40,
            blocks: 4,
        };
        assert_eq!(ev.cost(), 8);
    }

    #[test]
    fn cluster_scope_iterates_global_index_order() {
        let dc = DataCenter::new(vec![Host::new(0, 8, 8, 2), Host::new(1, 8, 8, 1)]);
        let walked: Vec<GpuRef> = PlanScope::Cluster.gpus(&dc).collect();
        assert_eq!(walked, dc.gpu_refs());
        let set: BTreeSet<GpuRef> = dc.gpu_refs().into_iter().collect();
        let from_set: Vec<GpuRef> = PlanScope::Set(&set).gpus(&dc).collect();
        assert_eq!(from_set, walked);
    }

    #[test]
    fn budget_parse_forms() {
        assert_eq!(
            MigrationBudget::parse("8").unwrap(),
            MigrationBudget::unlimited().per_interval(8)
        );
        assert_eq!(
            MigrationBudget::parse("8:2").unwrap(),
            MigrationBudget::unlimited().per_interval(8).per_vm(2)
        );
        assert!(MigrationBudget::parse("x").is_err());
        assert!(MigrationBudget::parse("1:2:3").is_err());
        assert!(MigrationBudget::unlimited().is_unlimited());
        assert!(!MigrationBudget::unlimited().per_vm(1).is_unlimited());
    }
}
