//! `FragGradient` — threshold-triggered fragmentation drain, new in this
//! layer (not in the source paper).
//!
//! The online fragmentation-aware MIG schedulers (Zambianco et al.,
//! Ting et al.) treat migration as a background mechanism that fires
//! when *cluster-wide* fragmentation degrades, not only when a request
//! already bounced. `FragGradient` brings that shape here: whenever the
//! mean fragmentation of the occupied in-scope GPUs crosses a threshold,
//! the most fragmented GPUs are drained — each of their instances is
//! moved (inter-GPU) to the first less-fragmented GPU of the same model
//! that accepts it under the default placement, descending the
//! fragmentation gradient. Draining a badly shaped GPU both empties a
//! device (it can idle or serve a whole-part request) and packs its
//! fragments into existing holes elsewhere.
//!
//! Determinism: scope iteration is ascending `globalIndex`; sources are
//! ordered by descending fragmentation with `GpuRef` tie-breaks;
//! instances drain smallest-profile-first (then by start block); and the
//! destination walk is a plain ascending first-fit. Planned moves are
//! validated against a [`PlanView`] overlay so the emitted plan applies
//! cleanly through the transactional `apply_plan`.

use super::{MigrationPlan, MigrationPlanner, PlanCtx, PlanView};
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::fragmentation::{fragmentation_cached, fragmentation_value};
use crate::mig::placement::mock_assign;
use crate::mig::{BlockMask, GpuModel, Instance};

/// Threshold-triggered fragmentation drain.
#[derive(Debug, Clone)]
pub struct FragGradient {
    /// Mean-fragmentation trigger over the occupied in-scope GPUs.
    threshold: f64,
    /// Max source GPUs drained per planning round.
    max_gpus: usize,
    /// Read fragmentation from the precomputed per-model table; `false`
    /// recomputes per query (the brute-force reference — identical
    /// values, see [`fragmentation_cached`]).
    use_index: bool,
}

impl FragGradient {
    /// Drain when mean fragmentation exceeds `threshold` (the crate
    /// default used by the registry is 1.0 — roughly "one stranded
    /// profile-slot per occupied GPU on average").
    pub fn new(threshold: f64, use_index: bool) -> FragGradient {
        FragGradient { threshold, max_gpus: 1, use_index }
    }

    /// Drain up to `n` source GPUs per round (default 1).
    pub fn max_gpus(mut self, n: usize) -> FragGradient {
        self.max_gpus = n.max(1);
        self
    }

    fn frag(&self, model: GpuModel, occ: BlockMask) -> f64 {
        if self.use_index {
            fragmentation_cached(model, occ)
        } else {
            fragmentation_value(model, occ)
        }
    }
}

impl MigrationPlanner for FragGradient {
    fn name(&self) -> &'static str {
        "frag-gradient"
    }

    /// Fires on both triggers (rejections and ticks): the threshold gate
    /// is the throttle, not the trigger kind.
    fn plan(&mut self, dc: &DataCenter, ctx: &PlanCtx, plan: &mut MigrationPlan) {
        // Score the scope: mean fragmentation over occupied GPUs.
        let mut scored: Vec<(f64, GpuRef)> = Vec::new();
        let mut total = 0.0;
        let mut occupied = 0usize;
        for r in ctx.scope.gpus(dc) {
            // Unavailable capacity (failed/draining) neither counts
            // toward the trigger nor drains here: the ops layer owns its
            // evacuation, and planning against it would be rejected by
            // `apply_plan` anyway.
            if !dc.gpu_available(r) {
                continue;
            }
            let g = dc.gpu(r);
            let occ = g.occupancy();
            if occ == 0 {
                continue;
            }
            let f = self.frag(g.model(), occ);
            occupied += 1;
            total += f;
            if f > 0.0 {
                scored.push((f, r));
            }
        }
        if occupied == 0 || total / occupied as f64 <= self.threshold {
            return;
        }
        // Most fragmented first; ties ascending globalIndex.
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
        scored.truncate(self.max_gpus);
        let sources = scored;

        let mut view = PlanView::new(dc);
        for &(src_frag, src) in &sources {
            // Drain smallest instances first (they fit the most holes),
            // then by start block for determinism.
            let mut insts: Vec<Instance> = dc.gpu(src).instances().to_vec();
            insts.sort_by_key(|i| (i.placement.profile.size(), i.placement.start));
            for inst in insts {
                let (cpus, ram) = dc.vm_demands(inst.vm).unwrap_or((0, 0));
                let mut dest = None;
                for r in ctx.scope.gpus(dc) {
                    if r == src || sources.iter().any(|&(_, s)| s == r) {
                        continue;
                    }
                    if !dc.gpu_available(r) {
                        continue; // never migrate onto unavailable capacity
                    }
                    let g = dc.gpu(r);
                    if g.model() != inst.placement.profile.model() {
                        continue;
                    }
                    let occ = view.occupancy(r);
                    // Descend the gradient: only strictly less fragmented
                    // destinations receive instances, so a round can
                    // never ping-pong fragments between equally bad GPUs.
                    if self.frag(g.model(), occ) >= src_frag {
                        continue;
                    }
                    if src.host != r.host && !view.host_fits(r.host, cpus, ram) {
                        continue;
                    }
                    if let Some((pl, _)) = mock_assign(occ, inst.placement.profile) {
                        dest = Some((r, pl));
                        break;
                    }
                }
                if let Some((to, pl)) = dest {
                    view.note_move(src, inst.placement, to, pl, cpus, ram);
                    plan.push_migrate(inst.vm, src, to, pl);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Host, VmSpec};
    use crate::mig::{Placement, Profile};
    use crate::migrate::{MigrationKind, PlanScope, PlanTrigger};

    fn place(dc: &mut DataCenter, id: u64, profile: Profile, r: GpuRef, start: u8) {
        let vm =
            VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 10, weight: 1.0 };
        dc.place(&vm, r, Placement { profile, start });
    }

    fn ctx(trigger: PlanTrigger) -> PlanCtx<'static> {
        PlanCtx { now: 0, trigger, scope: PlanScope::Cluster, pending: &[] }
    }

    /// Checkerboard GPU 0 (1g at 1, 3, 5) + nearly free GPU 1: the drain
    /// moves the fragments off the worst GPU.
    fn fragmented_pair() -> DataCenter {
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        let g0 = GpuRef { host: 0, gpu: 0 };
        place(&mut dc, 1, Profile::P1g5gb, g0, 1);
        place(&mut dc, 2, Profile::P1g5gb, g0, 3);
        place(&mut dc, 3, Profile::P1g5gb, g0, 5);
        dc
    }

    #[test]
    fn drains_the_most_fragmented_gpu_over_threshold() {
        let mut dc = fragmented_pair();
        let mut planner = FragGradient::new(0.5, true);
        let mut plan = MigrationPlan::new();
        planner.plan(&dc, &ctx(PlanTrigger::Tick), &mut plan);
        assert!(!plan.is_empty(), "checkerboard must trip a 0.5 threshold");
        dc.apply_plan(&plan).unwrap();
        let mut events = Vec::new();
        plan.push_events_into(&mut events);
        // Everything moved off GPU 0, inter-kind, onto GPU 1.
        assert_eq!(events.len(), 3);
        let g0 = GpuRef { host: 0, gpu: 0 };
        let g1 = GpuRef { host: 0, gpu: 1 };
        for ev in &events {
            assert_eq!(ev.kind, MigrationKind::Inter);
            assert_eq!(ev.from, g0);
            assert_eq!(ev.to, g1);
        }
        assert!(dc.gpu(g0).is_empty());
        assert_eq!(dc.gpu(g1).instances().len(), 3);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn below_threshold_is_a_no_op() {
        let dc = fragmented_pair();
        let mut planner = FragGradient::new(1e9, true);
        let mut plan = MigrationPlan::new();
        planner.plan(&dc, &ctx(PlanTrigger::Tick), &mut plan);
        assert!(plan.is_empty());
    }

    #[test]
    fn fires_on_both_triggers() {
        let dc = fragmented_pair();
        for trigger in [PlanTrigger::Tick, PlanTrigger::Rejection] {
            let mut planner = FragGradient::new(0.5, true);
            let mut plan = MigrationPlan::new();
            planner.plan(&dc, &ctx(trigger), &mut plan);
            assert!(!plan.is_empty(), "{trigger:?}");
        }
    }

    #[test]
    fn never_moves_onto_an_equally_fragmented_gpu() {
        // Two identical checkerboards: both are "most fragmented", and the
        // gradient rule (strictly less fragmented destinations only)
        // forbids shuffling between them when both are drained.
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        for (gpu, base) in [(0u8, 0u64), (1u8, 10u64)] {
            let r = GpuRef { host: 0, gpu };
            place(&mut dc, base + 1, Profile::P1g5gb, r, 1);
            place(&mut dc, base + 2, Profile::P1g5gb, r, 3);
        }
        let mut planner = FragGradient::new(0.1, true).max_gpus(2);
        let mut plan = MigrationPlan::new();
        planner.plan(&dc, &ctx(PlanTrigger::Tick), &mut plan);
        assert!(plan.is_empty(), "no downhill destination exists: {plan:?}");
    }

    #[test]
    fn unavailable_destinations_are_never_chosen() {
        use crate::cluster::HealthState;
        // The only viable destination GPU is failed: the drain stalls.
        let mut dc = fragmented_pair();
        let g1 = GpuRef { host: 0, gpu: 1 };
        dc.set_gpu_health(g1, HealthState::Failed { until: 99 });
        let mut planner = FragGradient::new(0.5, true);
        let mut plan = MigrationPlan::new();
        planner.plan(&dc, &ctx(PlanTrigger::Tick), &mut plan);
        assert!(plan.is_empty(), "{plan:?}");
        // Repaired, the same round drains the checkerboard.
        dc.set_gpu_health(g1, HealthState::Healthy);
        let mut plan = MigrationPlan::new();
        planner.plan(&dc, &ctx(PlanTrigger::Tick), &mut plan);
        assert_eq!(plan.num_moves(), 3);
    }

    #[test]
    fn respects_model_compatibility_and_host_resources() {
        use crate::mig::GpuModel;
        // Fragmented A30 on host 0; the only other A30 sits on a host
        // with no CPU headroom → nothing can move. The roomy A100 on
        // host 2 is model-incompatible.
        let mut dc = DataCenter::new(vec![
            Host::with_models(0, 256, 1024, &[GpuModel::A30]),
            Host::with_models(1, 1, 1024, &[GpuModel::A30]),
            Host::with_models(2, 256, 1024, &[GpuModel::A100_40]),
        ]);
        let k1g = GpuModel::A30.profile(0);
        place(&mut dc, 1, k1g, GpuRef { host: 0, gpu: 0 }, 1);
        let mut planner = FragGradient::new(0.0, true);
        let mut plan = MigrationPlan::new();
        planner.plan(&dc, &ctx(PlanTrigger::Tick), &mut plan);
        assert!(plan.is_empty(), "{plan:?}");
        // Give host 1 headroom and the drain goes through.
        let mut dc2 = DataCenter::new(vec![
            Host::with_models(0, 256, 1024, &[GpuModel::A30]),
            Host::with_models(1, 64, 1024, &[GpuModel::A30]),
        ]);
        place(&mut dc2, 1, k1g, GpuRef { host: 0, gpu: 0 }, 1);
        let mut plan = MigrationPlan::new();
        planner.plan(&dc2, &ctx(PlanTrigger::Tick), &mut plan);
        assert_eq!(plan.num_moves(), 1);
        dc2.apply_plan(&plan).unwrap();
        assert_eq!(dc2.locate(1).unwrap().gpu.host, 1);
        dc2.check_integrity().unwrap();
    }

    #[test]
    fn planned_drain_applies_cleanly_with_partial_destinations() {
        // GPU 1 can absorb only one block (7 taken… build: 4g@0 + 2g@4 +
        // free 6,7 → 1g fits at 6); GPU 2 absorbs the rest.
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 3)]);
        let g0 = GpuRef { host: 0, gpu: 0 };
        let g1 = GpuRef { host: 0, gpu: 1 };
        place(&mut dc, 1, Profile::P1g5gb, g0, 1);
        place(&mut dc, 2, Profile::P1g5gb, g0, 3);
        place(&mut dc, 3, Profile::P1g5gb, g0, 5);
        place(&mut dc, 10, Profile::P4g20gb, g1, 0);
        place(&mut dc, 11, Profile::P2g10gb, g1, 4);
        let mut planner = FragGradient::new(0.1, true);
        let mut plan = MigrationPlan::new();
        planner.plan(&dc, &ctx(PlanTrigger::Tick), &mut plan);
        assert_eq!(plan.num_moves(), 3, "{plan:?}");
        dc.apply_plan(&plan).unwrap();
        assert!(dc.gpu(g0).is_empty());
        dc.check_integrity().unwrap();
    }
}
