//! Defragmentation via intra-GPU migration (Algorithm 4), as a
//! policy-agnostic [`MigrationPlanner`].
//!
//! When an allocation round rejects any VM, the planner selects the most
//! fragmented in-scope GPU and re-packs it: the GPU's current instances
//! are replayed onto an empty *mock* GPU using the default NVIDIA
//! placement (largest profiles first, so the replay reproduces a
//! fresh-arrival packing), and every instance whose mock position differs
//! from its live position is relocated (`Relocated` + `IntraMigrate` of
//! Table 2). The replay is simulation-only — the plan mutates nothing;
//! application happens through the transactional
//! [`DataCenter::apply_plan`](crate::cluster::DataCenter::apply_plan) as
//! one atomic [`super::PlanStep::Repack`]. Every relocation surfaces as a
//! [`MigrationEvent`] of kind [`super::MigrationKind::Intra`].
//!
//! This used to live in `policies/grmu/defrag.rs`, hard-wired to GRMU's
//! light basket; the extraction makes the scope a parameter
//! ([`super::PlanScope`]), so any policy can defragment — GRMU passes its
//! light basket, composed policies (`ff+defrag`, `mcc+defrag`, ...) the
//! whole cluster. Default-config GRMU decisions and events are
//! byte-identical to the pre-extraction implementation (locked in
//! `rust/tests/decision_api.rs`).

use super::{MigrationEvent, MigrationPlan, MigrationPlanner, PlanCtx, PlanScope, PlanTrigger};
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::fragmentation::{fragmentation_cached, fragmentation_value};
use crate::mig::placement::mock_assign;
use crate::mig::{GpuState, Instance, Placement};

/// Pick the most fragmented GPU (Algorithm 4's `Max(lightBasket,
/// Fragmentation)`) among `gpus`; ties resolve to the lowest global
/// index (the iteration order). GPUs with zero fragmentation are skipped
/// entirely.
///
/// With `use_index` the scan takes the occupancy fast path: empty and
/// completely full devices — the two states every feasibility bucket
/// query classifies in O(1), and by far the most common states on a
/// loaded fleet — are skipped on a mask compare before any fragmentation
/// math, and the remaining GPUs read the precomputed per-model
/// fragmentation table ([`fragmentation_cached`], one load) instead of
/// re-walking every profile's start blocks. `use_index = false` keeps
/// the original full recomputation as the brute-force reference; both
/// modes are decision-identical (empty/full GPUs score exactly 0.0,
/// which the `> 0` filter already dropped, and the table holds the same
/// values the direct computation produces).
pub fn most_fragmented(
    dc: &DataCenter,
    gpus: impl IntoIterator<Item = GpuRef>,
    use_index: bool,
) -> Option<GpuRef> {
    let mut best: Option<(f64, GpuRef)> = None;
    for r in gpus {
        // Unavailable (failed/draining) capacity is never re-packed:
        // failed devices are empty anyway, and a draining GPU's
        // residents belong to the drain evacuation, not to defrag.
        if !dc.gpu_available(r) {
            continue;
        }
        let gpu = dc.gpu(r);
        let frag = if use_index {
            let occ = gpu.occupancy();
            if occ == 0 || occ == gpu.model().full_mask() {
                continue;
            }
            fragmentation_cached(gpu.model(), occ)
        } else {
            fragmentation_value(gpu.model(), gpu.occupancy())
        };
        if frag <= 0.0 {
            continue;
        }
        if best.map(|(b, _)| frag > b).unwrap_or(true) {
            best = Some((frag, r));
        }
    }
    best.map(|(_, r)| r)
}

/// Compute the re-pack plan for one GPU: replay instances onto a mock GPU
/// with the default placement and return the instances that move, paired
/// with their new placements. Returns `None` if the replay cannot fit
/// every instance (the greedy default policy is not guaranteed to re-pack
/// arbitrary multisets) — in that case no migration is planned.
pub fn repack_plan(gpu: &GpuState) -> Option<Vec<(Instance, Placement)>> {
    let mut instances: Vec<Instance> = gpu.instances().to_vec();
    // Replay order: largest profile first, then current start — a
    // fresh-arrival order that the default policy packs tightly.
    instances.sort_by_key(|inst| {
        (std::cmp::Reverse(inst.placement.profile.size()), inst.placement.start)
    });
    let mut mock: u8 = 0;
    let mut moves = Vec::new();
    for inst in &instances {
        let (placement, new_occ) = mock_assign(mock, inst.placement.profile)?;
        mock = new_occ;
        if placement != inst.placement {
            moves.push((*inst, placement));
        }
    }
    // Migrations are costly (Eq. 5): only relocate when the re-pack
    // *strictly improves* the configuration's CC — a same-CC shuffle
    // would burn migrations for nothing.
    if crate::mig::gpu::cc_for(gpu.model(), mock) <= gpu.cc() {
        return Some(Vec::new());
    }
    Some(moves)
}

/// Algorithm 4 as a planner: on a rejection round, plan one atomic
/// re-pack of the most fragmented in-scope GPU.
#[derive(Debug, Clone)]
pub struct DefragOnReject {
    /// Occupancy fast path + fragmentation table (see
    /// [`most_fragmented`]); `false` keeps the brute-force scan.
    use_index: bool,
}

impl DefragOnReject {
    pub fn new(use_index: bool) -> DefragOnReject {
        DefragOnReject { use_index }
    }
}

impl MigrationPlanner for DefragOnReject {
    fn name(&self) -> &'static str {
        "defrag"
    }

    fn plan(&mut self, dc: &DataCenter, ctx: &PlanCtx, plan: &mut MigrationPlan) {
        if ctx.trigger != PlanTrigger::Rejection {
            return;
        }
        let Some(target) = most_fragmented(dc, ctx.scope.gpus(dc), self.use_index) else {
            return;
        };
        let Some(moves) = repack_plan(dc.gpu(target)) else {
            return;
        };
        plan.push_repack(target, moves);
    }
}

/// Convenience for tests and examples: plan one defragmentation round
/// over `scope` and apply it, returning the performed migrations.
pub fn defragment(dc: &mut DataCenter, scope: PlanScope, use_index: bool) -> Vec<MigrationEvent> {
    let mut planner = DefragOnReject::new(use_index);
    let mut plan = MigrationPlan::new();
    let ctx = PlanCtx { now: 0, trigger: PlanTrigger::Rejection, scope, pending: &[] };
    planner.plan(dc, &ctx, &mut plan);
    let mut events = Vec::new();
    if dc.apply_plan(&plan).is_ok() {
        plan.push_events_into(&mut events);
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Host, VmSpec};
    use crate::mig::{GpuModel, Profile, ALL_MODELS};
    use crate::migrate::MigrationKind;
    use std::collections::BTreeSet;

    fn dc_one_gpu() -> DataCenter {
        DataCenter::new(vec![Host::new(0, 256, 1024, 1)])
    }

    fn place(dc: &mut DataCenter, id: u64, profile: Profile, start: u8) {
        let vm = VmSpec { id, profile, cpus: 1, ram_gb: 1, arrival: 0, departure: 10, weight: 1.0 };
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile, start });
    }

    fn basket(refs: &[GpuRef]) -> BTreeSet<GpuRef> {
        refs.iter().copied().collect()
    }

    #[test]
    fn paper_stray_1g_relocated_to_block_6() {
        // §7.1: a 1g.5gb left at block 4 after its block-6 neighbour
        // departed should move to block 6.
        let mut dc = dc_one_gpu();
        place(&mut dc, 1, Profile::P1g5gb, 4);
        let r = GpuRef { host: 0, gpu: 0 };
        let b = basket(&[r]);
        let events = defragment(&mut dc, PlanScope::Set(&b), true);
        assert_eq!(events.len(), 1);
        assert_eq!(
            events[0],
            MigrationEvent {
                vm: 1,
                from: r,
                to: r,
                kind: MigrationKind::Intra,
                model: GpuModel::A100_40,
                blocks: 1,
            }
        );
        assert_eq!(dc.gpu(r).instances()[0].placement.start, 6);
        assert_eq!(dc.locate(1).unwrap().placement.start, 6);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn repack_improves_or_preserves_cc() {
        let mut dc = dc_one_gpu();
        // Fragmented layout: 1g.5gb at 0 and 3 (the CC=9 example).
        place(&mut dc, 1, Profile::P1g5gb, 0);
        place(&mut dc, 2, Profile::P1g5gb, 3);
        let r = GpuRef { host: 0, gpu: 0 };
        let cc_before = dc.gpu(r).cc();
        let b = basket(&[r]);
        defragment(&mut dc, PlanScope::Set(&b), true);
        assert!(dc.gpu(r).cc() > cc_before);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn already_optimal_gpu_untouched() {
        let mut dc = dc_one_gpu();
        place(&mut dc, 1, Profile::P1g5gb, 6); // where the default puts it
        let r = GpuRef { host: 0, gpu: 0 };
        let b = basket(&[r]);
        // Fragmentation of this state may be zero or the replay may be a
        // no-op; either way no migration happens.
        let events = defragment(&mut dc, PlanScope::Set(&b), true);
        assert!(events.is_empty());
        assert_eq!(dc.gpu(r).instances()[0].placement.start, 6);
    }

    #[test]
    fn empty_scope_no_op() {
        let mut dc = dc_one_gpu();
        let empty = BTreeSet::new();
        assert!(defragment(&mut dc, PlanScope::Set(&empty), true).is_empty());
    }

    #[test]
    fn most_fragmented_picks_worst_in_both_modes() {
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 3)]);
        // GPU 0: tight (3g at 0). GPU 1: stray 1g at 4. GPU 2: empty
        // (exercises the fast-path skip).
        let a = VmSpec {
            id: 1,
            profile: Profile::P3g20gb,
            cpus: 1,
            ram_gb: 1,
            arrival: 0,
            departure: 10,
            weight: 1.0,
        };
        dc.place(&a, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P3g20gb, start: 0 });
        let b = VmSpec { id: 2, profile: Profile::P1g5gb, ..a };
        dc.place(&b, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P1g5gb, start: 4 });
        let set = basket(&dc.gpu_refs());
        for use_index in [true, false] {
            let worst = most_fragmented(&dc, PlanScope::Set(&set).gpus(&dc), use_index).unwrap();
            assert_eq!(worst, GpuRef { host: 0, gpu: 1 }, "use_index={use_index}");
        }
    }

    /// Satellite lock: the fast path (empty/full skip + fragmentation
    /// table) picks exactly the GPU the full recomputation picks, for
    /// every model and random occupancy mixes.
    #[test]
    fn prop_fast_path_most_fragmented_matches_scan() {
        use crate::util::prop::forall;
        use crate::util::rng::Rng;
        forall(
            "most-fragmented-index-vs-scan",
            |r: &mut Rng| {
                let model = ALL_MODELS[r.below(ALL_MODELS.len() as u64) as usize];
                let n = 1 + r.below(6) as usize;
                let hosts =
                    vec![Host::with_models(0, 256, 1024, &vec![model; n])];
                let mut dc = DataCenter::new(hosts);
                let mut id = 1u64;
                for g in 0..n {
                    // Random layout: place random profiles at their first
                    // feasible start until a coin flip stops.
                    while r.chance(0.6) {
                        let gr = GpuRef { host: 0, gpu: g as u8 };
                        let k = model.profile(r.below(model.num_profiles() as u64) as usize);
                        if let Some((pl, _)) = mock_assign(dc.gpu(gr).occupancy(), k) {
                            let vm = VmSpec {
                                id,
                                profile: k,
                                cpus: 1,
                                ram_gb: 1,
                                arrival: 0,
                                departure: 10,
                                weight: 1.0,
                            };
                            dc.place(&vm, gr, pl);
                            id += 1;
                        } else {
                            break;
                        }
                    }
                }
                dc
            },
            |dc| {
                let set: BTreeSet<GpuRef> = dc.gpu_refs().into_iter().collect();
                let fast = most_fragmented(dc, PlanScope::Set(&set).gpus(dc), true);
                let scan = most_fragmented(dc, PlanScope::Set(&set).gpus(dc), false);
                if fast == scan {
                    Ok(())
                } else {
                    Err(format!("fast={fast:?} scan={scan:?}"))
                }
            },
        );
    }

    #[test]
    fn unavailable_gpus_never_selected() {
        use crate::cluster::HealthState;
        let mut dc = dc_one_gpu();
        place(&mut dc, 1, Profile::P1g5gb, 4); // fragmented layout
        dc.set_host_health(0, HealthState::Draining);
        let r = GpuRef { host: 0, gpu: 0 };
        let b = basket(&[r]);
        for use_index in [true, false] {
            assert!(most_fragmented(&dc, PlanScope::Set(&b).gpus(&dc), use_index).is_none());
        }
        dc.set_host_health(0, HealthState::Healthy);
        assert_eq!(most_fragmented(&dc, PlanScope::Set(&b).gpus(&dc), true), Some(r));
    }

    #[test]
    fn repack_plan_handles_full_multiset() {
        // 7 × 1g.5gb: replay fills blocks 0..=6 — all must fit.
        let mut g = GpuState::new();
        for (i, s) in [0u8, 1, 2, 3, 4, 5, 6].iter().enumerate() {
            g.place(i as u64, Placement { profile: Profile::P1g5gb, start: *s });
        }
        let plan = repack_plan(&g).expect("full multiset re-packs");
        // Already at every legal start; the plan may shuffle but count ≤ 7.
        assert!(plan.len() <= 7);
    }

    #[test]
    fn planner_ignores_tick_trigger() {
        let mut dc = dc_one_gpu();
        place(&mut dc, 1, Profile::P1g5gb, 4);
        let mut planner = DefragOnReject::new(true);
        let mut plan = MigrationPlan::new();
        planner.plan(
            &dc,
            &PlanCtx {
                now: 0,
                trigger: PlanTrigger::Tick,
                scope: PlanScope::Cluster,
                pending: &[],
            },
            &mut plan,
        );
        assert!(plan.is_empty());
        planner.plan(
            &dc,
            &PlanCtx {
                now: 0,
                trigger: PlanTrigger::Rejection,
                scope: PlanScope::Cluster,
                pending: &[],
            },
            &mut plan,
        );
        assert_eq!(plan.num_moves(), 1);
    }
}
