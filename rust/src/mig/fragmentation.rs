//! The GRMU fragmentation metric (Algorithm 4's `Fragmentation`),
//! parameterized over the [`GpuModel`] catalog.
//!
//! For each profile of the GPU's model that could fit in the remaining
//! free blocks, the metric greedily packs as many instances of the
//! profile as possible and adds the ratio of *still-free* blocks to the
//! profile size — i.e. how much space remains unusable at that
//! granularity. High values indicate GPUs whose free blocks are poorly
//! shaped for future requests; GRMU defragments the GPU with the maximal
//! value.
//!
//! The pseudocode iterates `{p ∈ Profiles | Size(p) ≤ |gpu'|}` without
//! fixing an order; we iterate profiles from largest to smallest so that
//! the packing at each granularity measures the space *large* profiles
//! cannot use before small profiles consume everything (iterating
//! smallest-first would immediately pack the 1-block profile into every
//! free block and collapse the metric to "is the last block stranded").
//! The choice is documented here and exercised by the unit tests.

use super::gpu::BlockMask;
use super::model::{GpuModel, ALL_MODELS, NUM_MODELS};
use super::profiles::Placement;
use std::sync::OnceLock;

/// Fragmentation value of an occupancy mask of `model` (Algorithm 4,
/// lines 8–17).
pub fn fragmentation_value(model: GpuModel, occ: BlockMask) -> f64 {
    let num_blocks = model.num_blocks() as u32;
    let mut frag = 0.0;
    let mut work = occ;
    // Largest-to-smallest profile order (see module docs).
    for idx in (0..model.num_profiles()).rev() {
        let profile = model.profile(idx);
        let free = num_blocks - work.count_ones();
        if profile.size() as u32 > free {
            continue;
        }
        // Greedily pack this profile at its start blocks.
        for &start in profile.start_blocks() {
            let mask = Placement { profile, start }.mask();
            if work & mask == 0 {
                work |= mask;
            }
        }
        let remaining = num_blocks - work.count_ones();
        frag += remaining as f64 / profile.size() as f64;
    }
    frag
}

fn frag_tables() -> &'static [Vec<f64>; NUM_MODELS] {
    static TABLES: OnceLock<[Vec<f64>; NUM_MODELS]> = OnceLock::new();
    TABLES.get_or_init(|| {
        ALL_MODELS.map(|model| {
            (0..model.num_masks()).map(|occ| fragmentation_value(model, occ as u8)).collect()
        })
    })
}

/// Table-backed [`fragmentation_value`]: the metric is a pure function
/// of the `(model, mask)` pair, so all ≤ 256 values per model are
/// precomputed at first use (like the CC tables of `mig::gpu`) and a
/// query is one load. Values are identical to the direct computation by
/// construction — the defragmentation fast path reads this table, the
/// direct recomputation survives as its brute-force reference.
#[inline]
pub fn fragmentation_cached(model: GpuModel, occ: BlockMask) -> f64 {
    frag_tables()[model as usize][occ as usize]
}

/// Convenience: fragmentation of a [`super::gpu::GpuState`].
pub fn gpu_fragmentation(gpu: &super::gpu::GpuState) -> f64 {
    fragmentation_value(gpu.model(), gpu.occupancy())
}

/// A fragmentation-free reference point: a GPU that packs perfectly at
/// every granularity (e.g. fully occupied) scores zero.
pub fn is_fragmentation_free(model: GpuModel, occ: BlockMask) -> bool {
    fragmentation_value(model, occ) == 0.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::{cc, FULL_GPU};
    use crate::mig::model::ALL_MODELS;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    const A100: GpuModel = GpuModel::A100_40;

    #[test]
    fn full_gpu_not_fragmented() {
        for m in ALL_MODELS {
            assert_eq!(fragmentation_value(m, m.full_mask()), 0.0, "{m}");
        }
        assert_eq!(fragmentation_value(A100, FULL_GPU), 0.0);
    }

    #[test]
    fn empty_gpu_not_fragmented() {
        // An empty GPU packs perfectly at every granularity: the heavy
        // profile consumes all blocks immediately.
        for m in ALL_MODELS {
            assert_eq!(fragmentation_value(m, 0), 0.0, "{m}");
        }
    }

    #[test]
    fn checkerboard_highly_fragmented() {
        // Blocks 1,3,5,7 occupied: free blocks exist but no 2-block or
        // larger profile fits, and block 7's neighbour situation strands
        // space at every granularity above 1g.5gb.
        let occ: BlockMask = 0b1010_1010;
        let frag = fragmentation_value(A100, occ);
        assert!(frag > 0.0, "checkerboard should be fragmented, got {frag}");
        // Same shape on the A30's 4 blocks.
        let a30 = fragmentation_value(GpuModel::A30, 0b1010);
        assert!(a30 > 0.0, "A30 checkerboard should be fragmented, got {a30}");
    }

    #[test]
    fn contiguous_half_less_fragmented_than_checkerboard() {
        // 4 occupied blocks in one half vs 4 scattered.
        let contiguous = fragmentation_value(A100, 0b0000_1111);
        let scattered = fragmentation_value(A100, 0b1010_1010);
        assert!(
            contiguous < scattered,
            "contiguous={contiguous} scattered={scattered}"
        );
    }

    #[test]
    fn stranded_block7_detected() {
        // Blocks 0..=6 occupied; block 7 free but unusable by most
        // profiles (only 1g.10gb@6 would need 6 and 7).
        let occ: BlockMask = 0b0111_1111;
        assert!(fragmentation_value(A100, occ) > 0.0);
        assert_eq!(cc(occ), 0); // nothing fits at all
    }

    #[test]
    fn defrag_target_ranking_matches_intuition() {
        // The paper's §7.1 example: 1g.5gb stranded at block 4 (suboptimal
        // after a departure) vs the same instance at block 6.
        let at_4: BlockMask = 0b0001_0000;
        let at_6: BlockMask = 0b0100_0000;
        assert!(
            fragmentation_value(A100, at_4) >= fragmentation_value(A100, at_6),
            "block-4 arrangement should be at least as fragmented"
        );
        // And CC agrees it is strictly worse.
        assert!(cc(at_4) < cc(at_6));
    }

    #[test]
    fn prop_fragmentation_nonnegative_and_bounded() {
        forall(
            "frag-bounds",
            |r: &mut Rng| {
                let model = ALL_MODELS[r.below(ALL_MODELS.len() as u64) as usize];
                (model, r.below(model.num_masks() as u64) as u8)
            },
            |&(model, occ)| {
                let f = fragmentation_value(model, occ);
                // Loose bound: (blocks-1) free at granularity 1 plus
                // padding at larger granularities stays under 2×blocks.
                let bound = 2.0 * model.num_blocks() as f64;
                if (0.0..bound).contains(&f) {
                    Ok(())
                } else {
                    Err(format!("{model}: frag({occ:08b}) = {f} out of bounds"))
                }
            },
        );
    }

    #[test]
    fn cached_table_matches_direct_computation_exhaustively() {
        for model in ALL_MODELS {
            for occ in 0..model.num_masks() {
                let occ = occ as u8;
                assert_eq!(
                    fragmentation_cached(model, occ),
                    fragmentation_value(model, occ),
                    "{model} occ={occ:08b}"
                );
            }
        }
    }

    #[test]
    fn prop_zero_free_blocks_means_zero_fragmentation() {
        forall(
            "frag-full-zero",
            |r: &mut Rng| {
                let model = ALL_MODELS[r.below(ALL_MODELS.len() as u64) as usize];
                (model, r.below(model.num_masks() as u64) as u8)
            },
            |&(model, occ)| {
                if occ == model.full_mask() && fragmentation_value(model, occ) != 0.0 {
                    Err("full GPU must have zero fragmentation".into())
                } else {
                    Ok(())
                }
            },
        );
    }
}
