//! The NVIDIA Multi-Instance GPU (MIG) substrate.
//!
//! Models every catalog GPU as up to 8 memory blocks with the placement
//! rules of §3 (Table 1 / Fig. 1 / Table 5): per-model GPU-instance
//! profiles, each with a fixed size in blocks and a fixed set of legal
//! starting blocks. On top of that this module provides:
//!
//! * [`model`] — the [`model::GpuModel`] catalog (A100-40 / A30 /
//!   A100-80 / H100-80) and the cross-model [`model::ProfileKey`]
//!   replacing the closed A100-only profile enum.
//! * [`profiles`] — the historical A100-40 surface: `Profile` (now an
//!   alias for `ProfileKey`), `ALL_PROFILES`, and the 18 legal
//!   `(profile, start)` placements of Fig. 1, plus
//!   [`profiles::placements_for`] generating any model's table.
//! * [`gpu`] — occupancy bitmasks, the Configuration Capability metric
//!   (Eq. 1) via precomputed per-model tables, per-profile capacities and
//!   the [`gpu::GpuState`] carrying a model tag and live instances.
//! * [`placement`] — the default NVIDIA driver placement policy
//!   (Algorithm 1): place a profile at the start block that maximizes the
//!   post-allocation CC, per model.
//! * [`config_space`] — exhaustive enumeration of the A100-40's
//!   723-configuration space and the §5.1 optimality analyses.
//! * [`fragmentation`] — the GRMU fragmentation metric (Algorithm 4),
//!   per model.

pub mod config_space;
pub mod fragmentation;
pub mod gpu;
pub mod model;
pub mod placement;
pub mod profiles;

pub use fragmentation::fragmentation_value;
pub use gpu::{
    cc, cc_for, profile_capacity, profile_capacity_for, BlockMask, GpuState, Instance, FULL_GPU,
    NUM_BLOCKS,
};
pub use model::{
    parse_fleet_mix, GpuModel, ProfileKey, ALL_MODELS, MAX_MODEL_PROFILES, NUM_MODELS,
    NUM_PROFILE_KEYS,
};
pub use placement::{assign, mock_assign, unassign_vm};
pub use profiles::{placements_for, Placement, Profile, PLACEMENTS};
