//! The NVIDIA Multi-Instance GPU (MIG) substrate.
//!
//! Models an A100 as 8 memory blocks with the placement rules of §3
//! (Table 1 / Fig. 1 / Table 5): six GPU-instance profiles, each with a
//! fixed size in blocks and a fixed set of legal starting blocks. On top
//! of that this module provides:
//!
//! * [`profiles`] — the profile table and the 18 legal `(profile, start)`
//!   placements.
//! * [`gpu`] — occupancy bitmasks, the Configuration Capability metric
//!   (Eq. 1) via a precomputed 256-entry table, per-profile capacities and
//!   the [`gpu::GpuState`] carrying live instances.
//! * [`placement`] — the default NVIDIA driver placement policy
//!   (Algorithm 1): place a profile at the start block that maximizes the
//!   post-allocation CC.
//! * [`config_space`] — exhaustive enumeration of the 723-configuration
//!   space and the §5.1 optimality analyses.
//! * [`fragmentation`] — the GRMU fragmentation metric (Algorithm 4).

pub mod config_space;
pub mod fragmentation;
pub mod gpu;
pub mod placement;
pub mod profiles;

pub use fragmentation::fragmentation_value;
pub use gpu::{cc, profile_capacity, BlockMask, GpuState, Instance, FULL_GPU, NUM_BLOCKS};
pub use placement::{assign, mock_assign, unassign_vm};
pub use profiles::{Placement, Profile, PLACEMENTS};
