//! The default NVIDIA driver placement policy (Algorithm 1).
//!
//! Observed with driver 530.30.02: a GI profile is placed at the starting
//! block whose resulting configuration maximizes the Configuration
//! Capability (Eq. 2). Ties resolve to the first maximizing start in
//! `startBlocks` order — this reproduces the paper's documented behaviour
//! (on an empty A100 the first 1g.5gb lands on block 6, the second on
//! block 4).
//!
//! NVIDIA does not allow overriding this intra-GPU policy, so every
//! placement policy in [`crate::policies`] funnels through [`assign`].
//! The decision is a pure function of `(model, occupancy, profile)`, so
//! one lookup table per catalog model is precomputed at first use — the
//! single hottest lookup in every policy's scan (EXPERIMENTS.md §Perf).

use super::gpu::{cc_for, BlockMask, GpuState, VmId};
use super::model::{ALL_MODELS, MAX_MODEL_PROFILES, NUM_MODELS};
use super::profiles::{Placement, Profile};
use std::sync::OnceLock;

/// Reference implementation of Algorithm 1's start selection — used to
/// build the lookup tables and kept for the property tests.
fn mock_assign_uncached(occ: BlockMask, profile: Profile) -> Option<(Placement, BlockMask)> {
    let model = profile.model();
    let mut best: Option<(u32, Placement, BlockMask)> = None;
    for &start in profile.start_blocks() {
        let pl = Placement { profile, start };
        let mask = pl.mask();
        if occ & mask != 0 {
            continue;
        }
        let new_occ = occ | mask;
        let score = cc_for(model, new_occ);
        match best {
            Some((best_score, _, _)) if score <= best_score => {}
            _ => best = Some((score, pl, new_occ)),
        }
    }
    best.map(|(_, pl, new_occ)| (pl, new_occ))
}

/// Precomputed Algorithm 1 decisions per model: `(start + 1, new_occ)`
/// per (occupancy, per-model profile index), 0 = no fit.
fn assign_tables() -> &'static [Vec<[(u8, u8); MAX_MODEL_PROFILES]>; NUM_MODELS] {
    static TABLES: OnceLock<[Vec<[(u8, u8); MAX_MODEL_PROFILES]>; NUM_MODELS]> = OnceLock::new();
    TABLES.get_or_init(|| {
        ALL_MODELS.map(|model| {
            let mut table = vec![[(0u8, 0u8); MAX_MODEL_PROFILES]; model.num_masks()];
            for (occ, row) in table.iter_mut().enumerate() {
                for profile in model.profile_keys() {
                    if let Some((pl, new_occ)) = mock_assign_uncached(occ as u8, profile) {
                        row[profile.index()] = (pl.start + 1, new_occ);
                    }
                }
            }
            table
        })
    })
}

/// Pick the start block for `profile` under occupancy `occ` per
/// Algorithm 1 (maximize post-allocation CC; first max wins ties).
/// `occ` must come from a GPU of the profile's model. Returns the chosen
/// placement and the new occupancy.
#[inline]
pub fn mock_assign(occ: BlockMask, profile: Profile) -> Option<(Placement, BlockMask)> {
    let (start_plus_1, new_occ) =
        assign_tables()[profile.model() as usize][occ as usize][profile.index()];
    if start_plus_1 == 0 {
        None
    } else {
        Some((Placement { profile, start: start_plus_1 - 1 }, new_occ))
    }
}

/// Algorithm 1's `Assign`: place `profile` for `vm` on `gpu`, choosing the
/// CC-maximizing start. Returns the placement, or `None` if it doesn't
/// fit (or the profile belongs to a different model).
pub fn assign(gpu: &mut GpuState, vm: VmId, profile: Profile) -> Option<Placement> {
    if profile.model() != gpu.model() {
        return None;
    }
    let (pl, _) = mock_assign(gpu.occupancy(), profile)?;
    gpu.place(vm, pl);
    Some(pl)
}

/// Reverse of [`assign`] (Algorithm 6's `UnAssign`).
pub fn unassign_vm(gpu: &mut GpuState, vm: VmId) -> Option<Placement> {
    gpu.remove_vm(vm)
}

/// Would `profile` fit at all under `occ` (an occupancy of the profile's
/// model)? Cheaper than `mock_assign` when the chosen start is
/// irrelevant.
#[inline]
pub fn fits(occ: BlockMask, profile: Profile) -> bool {
    super::gpu::profile_capacity_for(profile.model(), occ)[profile.index()] > 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::{cc, consistent};
    use crate::mig::model::GpuModel;
    use crate::mig::profiles::ALL_PROFILES;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    /// §5.1: "a 1g.5gb profile is placed on block 6. The second 1g.5gb
    /// profile is positioned on block 4."
    #[test]
    fn paper_documented_behaviour_1g5gb() {
        let mut g = GpuState::new();
        let p1 = assign(&mut g, 1, Profile::P1g5gb).unwrap();
        assert_eq!(p1.start, 6);
        let p2 = assign(&mut g, 2, Profile::P1g5gb).unwrap();
        assert_eq!(p2.start, 4);
    }

    /// §7.1 defragmentation rationale: with 1g.5gb at 4 and 6, removing
    /// the one at 6 leaves a suboptimal configuration; re-placing the
    /// remaining profile at 6 restores the maximum CC.
    #[test]
    fn defrag_motivating_example() {
        let mut g = GpuState::new();
        assign(&mut g, 1, Profile::P1g5gb).unwrap(); // block 6
        assign(&mut g, 2, Profile::P1g5gb).unwrap(); // block 4
        g.remove_vm(1);
        let cc_suboptimal = g.cc(); // 1g.5gb stranded on block 4
        let mut fresh = GpuState::new();
        assign(&mut fresh, 2, Profile::P1g5gb).unwrap(); // block 6
        assert!(fresh.cc() > cc_suboptimal);
    }

    #[test]
    fn full_gpu_rejects() {
        let mut g = GpuState::new();
        assert!(assign(&mut g, 1, Profile::P7g40gb).is_some());
        for p in ALL_PROFILES {
            assert!(assign(&mut g, 2, p).is_none(), "{p} placed on a full GPU");
        }
    }

    #[test]
    fn seven_small_instances_fit() {
        let mut g = GpuState::new();
        for vm in 0..7 {
            assert!(assign(&mut g, vm, Profile::P1g5gb).is_some(), "vm {vm}");
        }
        assert!(assign(&mut g, 7, Profile::P1g5gb).is_none());
        // Block 7 is never usable by 1g.5gb.
        assert_eq!(g.free_blocks(), 1);
        assert!(consistent(&g));
    }

    #[test]
    fn max_instances_reachable_for_all_profiles_on_every_model() {
        for model in ALL_MODELS {
            for p in model.profile_keys() {
                let mut g = GpuState::with_model(model);
                let mut placed = 0;
                while assign(&mut g, placed as u64, p).is_some() {
                    placed += 1;
                }
                assert_eq!(placed, p.max_instances(), "{p}");
            }
        }
    }

    #[test]
    fn foreign_model_profile_never_assigns() {
        let mut a30 = GpuState::with_model(GpuModel::A30);
        assert!(assign(&mut a30, 1, Profile::P1g5gb).is_none());
        assert!(a30.is_empty());
        let h100_heavy = GpuModel::H100_80.profile(5);
        let mut a100 = GpuState::new();
        assert!(assign(&mut a100, 1, h100_heavy).is_none());
    }

    #[test]
    fn mock_assign_matches_assign() {
        let mut g = GpuState::new();
        for (vm, p) in [Profile::P2g10gb, Profile::P1g10gb, Profile::P3g20gb]
            .into_iter()
            .enumerate()
        {
            let (expected, _) = mock_assign(g.occupancy(), p).unwrap();
            let actual = assign(&mut g, vm as u64, p).unwrap();
            assert_eq!(expected, actual);
        }
    }

    #[test]
    fn unassign_restores_occupancy() {
        let mut g = GpuState::new();
        let before = g.occupancy();
        assign(&mut g, 1, Profile::P4g20gb).unwrap();
        unassign_vm(&mut g, 1).unwrap();
        assert_eq!(g.occupancy(), before);
    }

    #[test]
    fn prop_assign_always_chooses_cc_maximal_start() {
        forall(
            "assign-cc-maximal",
            |r: &mut Rng| {
                // Random model, random reachable occupancy, random profile.
                let model = ALL_MODELS[r.below(ALL_MODELS.len() as u64) as usize];
                let keys: Vec<Profile> = model.profile_keys().collect();
                let mut g = GpuState::with_model(model);
                for vm in 0..r.below(6) {
                    let p = keys[r.below(keys.len() as u64) as usize];
                    let _ = assign(&mut g, vm, p);
                }
                (g.occupancy(), keys[r.below(keys.len() as u64) as usize])
            },
            |&(occ, profile)| {
                let model = profile.model();
                let Some((chosen, new_occ)) = mock_assign(occ, profile) else {
                    return Ok(());
                };
                // No alternative start yields a strictly higher CC.
                for &s in profile.start_blocks() {
                    let pl = Placement { profile, start: s };
                    if occ & pl.mask() == 0 && cc_for(model, occ | pl.mask()) > cc_for(model, new_occ)
                    {
                        return Err(format!(
                            "start {s} beats chosen {} under occ={occ:08b}",
                            chosen.start
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn table_matches_uncached_reference_exhaustively() {
        for model in ALL_MODELS {
            for occ in 0..model.num_masks() {
                for profile in model.profile_keys() {
                    assert_eq!(
                        mock_assign(occ as u8, profile),
                        mock_assign_uncached(occ as u8, profile),
                        "occ={occ:08b} profile={profile}"
                    );
                }
            }
        }
    }

    #[test]
    fn a30_default_policy_mirrors_a100_shape() {
        // On an empty A30 the first 1g.6gb lands on the *last* block the
        // CC-maximizing rule prefers — the same end-of-part bias the
        // paper documents on the A100.
        let mut g = GpuState::with_model(GpuModel::A30);
        let k1g = GpuModel::A30.profile(0);
        let p1 = assign(&mut g, 1, k1g).unwrap();
        let p2 = assign(&mut g, 2, k1g).unwrap();
        assert!(p1.start > p2.start, "first lands high ({p1}), second below ({p2})");
        // cc comparison confirms the choice was maximal.
        assert_eq!(cc(0), 18); // A100 table untouched by A30 queries
    }

    #[test]
    fn prop_fits_iff_mock_assign_some() {
        forall(
            "fits-consistent",
            |r: &mut Rng| {
                let model = ALL_MODELS[r.below(ALL_MODELS.len() as u64) as usize];
                let keys: Vec<Profile> = model.profile_keys().collect();
                (
                    r.below(model.num_masks() as u64) as u8,
                    keys[r.below(keys.len() as u64) as usize],
                )
            },
            |&(occ, p)| {
                if fits(occ, p) == mock_assign(occ, p).is_some() {
                    Ok(())
                } else {
                    Err(format!("fits disagrees at occ={occ:08b} profile={p}"))
                }
            },
        );
    }
}
