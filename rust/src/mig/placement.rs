//! The default NVIDIA driver placement policy (Algorithm 1).
//!
//! Observed with driver 530.30.02: a GI profile is placed at the starting
//! block whose resulting configuration maximizes the Configuration
//! Capability (Eq. 2). Ties resolve to the first maximizing start in
//! `startBlocks` order — this reproduces the paper's documented behaviour
//! (on an empty GPU the first 1g.5gb lands on block 6, the second on
//! block 4).
//!
//! NVIDIA does not allow overriding this intra-GPU policy, so every
//! placement policy in [`crate::policies`] funnels through [`assign`].

use super::gpu::{cc, BlockMask, GpuState, VmId};
use super::profiles::{Placement, Profile, ALL_PROFILES};
use std::sync::OnceLock;

/// Reference implementation of Algorithm 1's start selection — used to
/// build the lookup table and kept for the property tests.
fn mock_assign_uncached(occ: BlockMask, profile: Profile) -> Option<(Placement, BlockMask)> {
    let mut best: Option<(u32, Placement, BlockMask)> = None;
    for &start in profile.start_blocks() {
        let pl = Placement { profile, start };
        let mask = pl.mask();
        if occ & mask != 0 {
            continue;
        }
        let new_occ = occ | mask;
        let score = cc(new_occ);
        match best {
            Some((best_score, _, _)) if score <= best_score => {}
            _ => best = Some((score, pl, new_occ)),
        }
    }
    best.map(|(_, pl, new_occ)| (pl, new_occ))
}

/// Precomputed Algorithm 1 decisions: `(start + 1, new_occ)` per
/// (occupancy, profile), 0 = no fit. The decision is a pure function of
/// an 8-bit mask and one of six profiles, so the full table is 1.5 K
/// entries — this is the single hottest lookup in every policy's scan
/// (see EXPERIMENTS.md §Perf).
fn assign_table() -> &'static [[(u8, u8); 6]; 256] {
    static TABLE: OnceLock<[[(u8, u8); 6]; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut table = [[(0u8, 0u8); 6]; 256];
        for occ in 0usize..256 {
            for profile in ALL_PROFILES {
                if let Some((pl, new_occ)) = mock_assign_uncached(occ as u8, profile) {
                    table[occ][profile.index()] = (pl.start + 1, new_occ);
                }
            }
        }
        table
    })
}

/// Pick the start block for `profile` under occupancy `occ` per
/// Algorithm 1 (maximize post-allocation CC; first max wins ties).
/// Returns the chosen placement and the new occupancy.
#[inline]
pub fn mock_assign(occ: BlockMask, profile: Profile) -> Option<(Placement, BlockMask)> {
    let (start_plus_1, new_occ) = assign_table()[occ as usize][profile.index()];
    if start_plus_1 == 0 {
        None
    } else {
        Some((Placement { profile, start: start_plus_1 - 1 }, new_occ))
    }
}

/// Algorithm 1's `Assign`: place `profile` for `vm` on `gpu`, choosing the
/// CC-maximizing start. Returns the placement, or `None` if it doesn't fit.
pub fn assign(gpu: &mut GpuState, vm: VmId, profile: Profile) -> Option<Placement> {
    let (pl, _) = mock_assign(gpu.occupancy(), profile)?;
    gpu.place(vm, pl);
    Some(pl)
}

/// Reverse of [`assign`] (Algorithm 6's `UnAssign`).
pub fn unassign_vm(gpu: &mut GpuState, vm: VmId) -> Option<Placement> {
    gpu.remove_vm(vm)
}

/// Would `profile` fit at all under `occ`? (Cheaper than `mock_assign`
/// when the chosen start is irrelevant.)
#[inline]
pub fn fits(occ: BlockMask, profile: Profile) -> bool {
    super::gpu::profile_capacity(occ)[profile.index()] > 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::consistent;
    use crate::mig::profiles::ALL_PROFILES;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    /// §5.1: "a 1g.5gb profile is placed on block 6. The second 1g.5gb
    /// profile is positioned on block 4."
    #[test]
    fn paper_documented_behaviour_1g5gb() {
        let mut g = GpuState::new();
        let p1 = assign(&mut g, 1, Profile::P1g5gb).unwrap();
        assert_eq!(p1.start, 6);
        let p2 = assign(&mut g, 2, Profile::P1g5gb).unwrap();
        assert_eq!(p2.start, 4);
    }

    /// §7.1 defragmentation rationale: with 1g.5gb at 4 and 6, removing
    /// the one at 6 leaves a suboptimal configuration; re-placing the
    /// remaining profile at 6 restores the maximum CC.
    #[test]
    fn defrag_motivating_example() {
        let mut g = GpuState::new();
        assign(&mut g, 1, Profile::P1g5gb).unwrap(); // block 6
        assign(&mut g, 2, Profile::P1g5gb).unwrap(); // block 4
        g.remove_vm(1);
        let cc_suboptimal = g.cc(); // 1g.5gb stranded on block 4
        let mut fresh = GpuState::new();
        assign(&mut fresh, 2, Profile::P1g5gb).unwrap(); // block 6
        assert!(fresh.cc() > cc_suboptimal);
    }

    #[test]
    fn full_gpu_rejects() {
        let mut g = GpuState::new();
        assert!(assign(&mut g, 1, Profile::P7g40gb).is_some());
        for p in ALL_PROFILES {
            assert!(assign(&mut g, 2, p).is_none(), "{p} placed on a full GPU");
        }
    }

    #[test]
    fn seven_small_instances_fit() {
        let mut g = GpuState::new();
        for vm in 0..7 {
            assert!(assign(&mut g, vm, Profile::P1g5gb).is_some(), "vm {vm}");
        }
        assert!(assign(&mut g, 7, Profile::P1g5gb).is_none());
        // Block 7 is never usable by 1g.5gb.
        assert_eq!(g.free_blocks(), 1);
        assert!(consistent(&g));
    }

    #[test]
    fn max_instances_reachable_for_all_profiles() {
        for p in ALL_PROFILES {
            let mut g = GpuState::new();
            let mut placed = 0;
            while assign(&mut g, placed as u64, p).is_some() {
                placed += 1;
            }
            assert_eq!(placed, p.max_instances(), "{p}");
        }
    }

    #[test]
    fn mock_assign_matches_assign() {
        let mut g = GpuState::new();
        for (vm, p) in [Profile::P2g10gb, Profile::P1g10gb, Profile::P3g20gb]
            .into_iter()
            .enumerate()
        {
            let (expected, _) = mock_assign(g.occupancy(), p).unwrap();
            let actual = assign(&mut g, vm as u64, p).unwrap();
            assert_eq!(expected, actual);
        }
    }

    #[test]
    fn unassign_restores_occupancy() {
        let mut g = GpuState::new();
        let before = g.occupancy();
        assign(&mut g, 1, Profile::P4g20gb).unwrap();
        unassign_vm(&mut g, 1).unwrap();
        assert_eq!(g.occupancy(), before);
    }

    #[test]
    fn prop_assign_always_chooses_cc_maximal_start() {
        forall(
            "assign-cc-maximal",
            |r: &mut Rng| {
                // Random reachable occupancy + random profile.
                let mut g = GpuState::new();
                for vm in 0..r.below(6) {
                    let p = ALL_PROFILES[r.below(6) as usize];
                    let _ = assign(&mut g, vm, p);
                }
                (g.occupancy(), ALL_PROFILES[r.below(6) as usize])
            },
            |&(occ, profile)| {
                let Some((chosen, new_occ)) = mock_assign(occ, profile) else {
                    return Ok(());
                };
                // No alternative start yields a strictly higher CC.
                for &s in profile.start_blocks() {
                    let pl = Placement { profile, start: s };
                    if occ & pl.mask() == 0 && cc(occ | pl.mask()) > cc(new_occ) {
                        return Err(format!(
                            "start {s} beats chosen {} under occ={occ:08b}",
                            chosen.start
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn table_matches_uncached_reference_exhaustively() {
        for occ in 0u16..256 {
            for profile in ALL_PROFILES {
                assert_eq!(
                    mock_assign(occ as u8, profile),
                    mock_assign_uncached(occ as u8, profile),
                    "occ={occ:08b} profile={profile}"
                );
            }
        }
    }

    #[test]
    fn prop_fits_iff_mock_assign_some() {
        forall(
            "fits-consistent",
            |r: &mut Rng| (r.below(256) as u8, ALL_PROFILES[r.below(6) as usize]),
            |&(occ, p)| {
                if fits(occ, p) == mock_assign(occ, p).is_some() {
                    Ok(())
                } else {
                    Err(format!("fits disagrees at occ={occ:08b} profile={p}"))
                }
            },
        );
    }
}
