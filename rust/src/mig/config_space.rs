//! Exhaustive analysis of the single- and multi-GPU configuration space
//! (§5.1).
//!
//! A *configuration* is a set of non-overlapping legal placements,
//! represented as an 18-bit mask over [`PLACEMENTS`]. Depth-first search
//! from the empty GPU enumerates all 723 configurations with 78 maximal
//! (terminal) ones; grouping configurations by their profile *multiset*
//! identifies arrangements that are suboptimal in CC, and a sweep over the
//! grouped per-profile capacities identifies configurations for which an
//! alternative arrangement of the same profiles accommodates some profile
//! better at the same or lower CC (the paper's 19% / 79% analyses).
//!
//! Paper-vs-measured note: 723 / 78 / 482 and the two-GPU pair count
//! 261,726 reproduce exactly. The paper's "248 default-policy reachable
//! configurations (172 suboptimal)" does **not** reproduce under any
//! tie-breaking of Algorithm 1 we tried (first/last/all-maximal yield
//! 179/179/297); EXPERIMENTS.md reports all variants.

use super::gpu::cc;
use super::placement::mock_assign;
use super::profiles::{ALL_PROFILES, PLACEMENTS};
use std::collections::HashMap;

/// A configuration: bit `i` set means `PLACEMENTS[i]` is allocated.
pub type Config = u32;

/// Occupancy mask of a configuration.
pub fn occupancy_of(config: Config) -> u8 {
    let mut occ = 0u8;
    for (i, pl) in PLACEMENTS.iter().enumerate() {
        if config & (1 << i) != 0 {
            occ |= pl.mask();
        }
    }
    occ
}

/// Profile multiset of a configuration, as counts per profile index.
pub fn profile_multiset(config: Config) -> [u8; 6] {
    let mut counts = [0u8; 6];
    for (i, pl) in PLACEMENTS.iter().enumerate() {
        if config & (1 << i) != 0 {
            counts[pl.profile.index()] += 1;
        }
    }
    counts
}

/// Pack a profile multiset into a compact sortable key.
fn multiset_key(counts: [u8; 6]) -> u32 {
    counts.iter().fold(0u32, |acc, &c| (acc << 4) | c as u32)
}

/// Enumerate every reachable configuration (sorted, deduplicated).
pub fn enumerate_all() -> Vec<Config> {
    let mut seen: Vec<bool> = vec![false; 1 << PLACEMENTS.len()];
    let mut out = Vec::new();
    let mut stack: Vec<(Config, u8)> = vec![(0, 0)];
    while let Some((cfg, occ)) = stack.pop() {
        if seen[cfg as usize] {
            continue;
        }
        seen[cfg as usize] = true;
        out.push(cfg);
        for (i, pl) in PLACEMENTS.iter().enumerate() {
            if occ & pl.mask() == 0 {
                stack.push((cfg | (1 << i), occ | pl.mask()));
            }
        }
    }
    out.sort_unstable();
    out
}

/// A configuration is maximal (a terminal DFS node) if no placement fits.
pub fn is_maximal(config: Config) -> bool {
    cc(occupancy_of(config)) == 0
}

/// Tie-breaking variants for the default policy's `Assign`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TieBreak {
    /// First CC-maximizing start in `startBlocks` order (our Alg. 1).
    First,
    /// Last CC-maximizing start.
    Last,
    /// Branch on every CC-maximizing start (upper bound on reachability).
    AllMaximal,
}

/// Configurations reachable from empty by repeated default-policy
/// assignment (arrivals only, no departures) under a tie-break rule.
pub fn default_policy_reachable(tie: TieBreak) -> Vec<Config> {
    let mut seen: Vec<bool> = vec![false; 1 << PLACEMENTS.len()];
    let mut out = Vec::new();
    let mut stack: Vec<(Config, u8)> = vec![(0, 0)];
    while let Some((cfg, occ)) = stack.pop() {
        if seen[cfg as usize] {
            continue;
        }
        seen[cfg as usize] = true;
        out.push(cfg);
        for profile in ALL_PROFILES {
            match tie {
                TieBreak::First => {
                    if let Some((pl, new_occ)) = mock_assign(occ, profile) {
                        let idx = placement_index(pl.profile.index(), pl.start);
                        stack.push((cfg | (1 << idx), new_occ));
                    }
                }
                TieBreak::Last | TieBreak::AllMaximal => {
                    // Recompute the maximizing set explicitly.
                    let mut best_score = 0u32;
                    let mut cands: Vec<(usize, u8)> = Vec::new();
                    for &start in profile.start_blocks() {
                        let pl = super::profiles::Placement { profile, start };
                        if occ & pl.mask() != 0 {
                            continue;
                        }
                        let score = cc(occ | pl.mask());
                        if cands.is_empty() || score > best_score {
                            best_score = score;
                            cands.clear();
                        }
                        if score == best_score {
                            cands.push((placement_index(profile.index(), start), pl.mask() as u8));
                        }
                    }
                    let chosen: Vec<(usize, u8)> = match tie {
                        TieBreak::Last => cands.last().copied().into_iter().collect(),
                        _ => cands,
                    };
                    for (idx, mask) in chosen {
                        stack.push((cfg | (1 << idx), occ | mask));
                    }
                }
            }
        }
    }
    out.sort_unstable();
    out
}

/// Index of a `(profile_index, start)` pair in `PLACEMENTS`.
fn placement_index(profile_index: usize, start: u8) -> usize {
    PLACEMENTS
        .iter()
        .position(|pl| pl.profile.index() == profile_index && pl.start == start)
        .expect("legal placement")
}

/// Group configurations by profile multiset; map key → member configs.
pub fn group_by_multiset(configs: &[Config]) -> HashMap<u32, Vec<Config>> {
    let mut groups: HashMap<u32, Vec<Config>> = HashMap::new();
    for &cfg in configs {
        groups.entry(multiset_key(profile_multiset(cfg))).or_default().push(cfg);
    }
    groups
}

/// Count configurations whose CC is strictly below the best CC achievable
/// by rearranging the same profile multiset (the paper's "suboptimal
/// arrangements": 482 of 723).
pub fn count_suboptimal(configs: &[Config], groups: &HashMap<u32, Vec<Config>>) -> usize {
    let mut best: HashMap<u32, u32> = HashMap::new();
    for (&key, members) in groups {
        let max_cc = members.iter().map(|&c| cc(occupancy_of(c))).max().unwrap();
        best.insert(key, max_cc);
    }
    configs
        .iter()
        .filter(|&&c| cc(occupancy_of(c)) < best[&multiset_key(profile_multiset(c))])
        .count()
}

/// Count configurations for which an alternative arrangement of the same
/// profiles accommodates at least one profile type better while having the
/// same or lower CC (the paper's 19%-of-723 single-GPU analysis).
pub fn count_improvable(groups: &HashMap<u32, Vec<Config>>) -> usize {
    let mut improvable = 0usize;
    for members in groups.values() {
        improvable += count_improvable_in_group(
            &members
                .iter()
                .map(|&c| {
                    let occ = occupancy_of(c);
                    (cc(occ), super::gpu::profile_capacity(occ))
                })
                .collect::<Vec<_>>(),
        );
    }
    improvable
}

/// Core sweep: items are `(cc, per-profile capacity)`. An item is
/// improvable iff some other item in the group has `cc' <= cc` and a
/// strictly larger capacity for at least one profile.
pub fn count_improvable_in_group(items: &[(u32, [u8; 6])]) -> usize {
    if items.len() < 2 {
        return 0;
    }
    let mut order: Vec<usize> = (0..items.len()).collect();
    order.sort_by_key(|&i| items[i].0);
    let mut improvable = 0usize;
    let mut max_low = [0u8; 6]; // per-profile max capacity among strictly lower CC
    let mut i = 0;
    while i < order.len() {
        // Block of equal CC.
        let cc_i = items[order[i]].0;
        let mut j = i;
        let mut block_max = [0u8; 6];
        while j < order.len() && items[order[j]].0 == cc_i {
            for p in 0..6 {
                block_max[p] = block_max[p].max(items[order[j]].1[p]);
            }
            j += 1;
        }
        for &idx in &order[i..j] {
            let cap = items[idx].1;
            let better_exists = (0..6).any(|p| max_low[p].max(block_max[p]) > cap[p]);
            if better_exists {
                improvable += 1;
            }
        }
        for p in 0..6 {
            max_low[p] = max_low[p].max(block_max[p]);
        }
        i = j;
    }
    improvable
}

/// Summary of the §5.1 configuration-space analysis.
#[derive(Debug, Clone, PartialEq)]
pub struct SpaceStats {
    /// Unique configurations of one GPU (paper: 723).
    pub total: usize,
    /// Maximal/terminal configurations (paper: 78).
    pub maximal: usize,
    /// Arrangement-suboptimal configurations (paper: 482, 67%).
    pub suboptimal: usize,
    /// Default-policy reachable (paper: 248; measured: 179 first-tie).
    pub default_reachable: usize,
    /// Suboptimal among reachable (paper: 172, 69%; measured: 59).
    pub default_reachable_suboptimal: usize,
    /// Reachable when branching all CC-ties (measured: 297).
    pub default_reachable_all_ties: usize,
    /// Single-GPU improvable configurations (paper: 138, 19%).
    pub improvable: usize,
    /// Distinct two-GPU configurations C(723+1, 2) (paper: 261,726).
    pub two_gpu_total: usize,
    /// Improvable two-GPU pairs (paper: 205,575, 79%).
    pub two_gpu_improvable: usize,
}

/// Run the complete §5.1 analysis. The two-GPU sweep is the expensive part
/// (~260k pairs grouped by combined multiset); it is skipped when
/// `with_two_gpu` is false.
pub fn analyze(with_two_gpu: bool) -> SpaceStats {
    let configs = enumerate_all();
    let groups = group_by_multiset(&configs);
    let maximal = configs.iter().filter(|&&c| is_maximal(c)).count();
    let suboptimal = count_suboptimal(&configs, &groups);
    let improvable = count_improvable(&groups);

    let reach_first = default_policy_reachable(TieBreak::First);
    let reach_groups = group_by_multiset(&configs);
    let mut best: HashMap<u32, u32> = HashMap::new();
    for (&key, members) in &reach_groups {
        best.insert(key, members.iter().map(|&c| cc(occupancy_of(c))).max().unwrap());
    }
    let reach_subopt = reach_first
        .iter()
        .filter(|&&c| cc(occupancy_of(c)) < best[&multiset_key(profile_multiset(c))])
        .count();
    let reach_all = default_policy_reachable(TieBreak::AllMaximal).len();

    let (two_total, two_improvable) = if with_two_gpu {
        two_gpu_analysis(&configs)
    } else {
        (0, 0)
    };

    SpaceStats {
        total: configs.len(),
        maximal,
        suboptimal,
        default_reachable: reach_first.len(),
        default_reachable_suboptimal: reach_subopt,
        default_reachable_all_ties: reach_all,
        improvable,
        two_gpu_total: two_total,
        two_gpu_improvable: two_improvable,
    }
}

/// Two-GPU analysis: unordered pairs of configurations grouped by their
/// *combined* profile multiset; a pair is improvable if another pair with
/// the same combined multiset accommodates some profile better at the same
/// or lower total CC.
pub fn two_gpu_analysis(configs: &[Config]) -> (usize, usize) {
    // Precompute per-config data.
    let data: Vec<(u32, [u8; 6], [u8; 6])> = configs
        .iter()
        .map(|&c| {
            let occ = occupancy_of(c);
            (cc(occ), super::gpu::profile_capacity(occ), profile_multiset(c))
        })
        .collect();

    // Group pairs by combined multiset key. Counts fit in 4 bits per
    // profile only up to 14 instances of 1g.5gb across two GPUs — max is
    // 14, which overflows a nibble, so use 5 bits per profile.
    let pack = |a: [u8; 6], b: [u8; 6]| -> u32 {
        let mut key = 0u32;
        for p in 0..6 {
            key = (key << 5) | (a[p] + b[p]) as u32;
        }
        key
    };

    let mut groups: HashMap<u32, Vec<(u32, [u8; 6])>> = HashMap::new();
    let n = configs.len();
    let mut total_pairs = 0usize;
    for i in 0..n {
        for j in i..n {
            let (cc_i, cap_i, ms_i) = data[i];
            let (cc_j, cap_j, ms_j) = data[j];
            let mut cap = [0u8; 6];
            for p in 0..6 {
                cap[p] = cap_i[p] + cap_j[p];
            }
            groups.entry(pack(ms_i, ms_j)).or_default().push((cc_i + cc_j, cap));
            total_pairs += 1;
        }
    }
    let improvable: usize = groups.values().map(|g| count_improvable_in_group(g)).sum();
    (total_pairs, improvable)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::profile_capacity;
    use crate::mig::profiles::Profile;

    #[test]
    fn paper_723_unique_configurations() {
        assert_eq!(enumerate_all().len(), 723);
    }

    #[test]
    fn paper_78_maximal_configurations() {
        let configs = enumerate_all();
        assert_eq!(configs.iter().filter(|&&c| is_maximal(c)).count(), 78);
    }

    #[test]
    fn paper_482_suboptimal_arrangements() {
        let configs = enumerate_all();
        let groups = group_by_multiset(&configs);
        assert_eq!(count_suboptimal(&configs, &groups), 482);
    }

    #[test]
    fn default_policy_reachability_measured() {
        // Paper claims 248/172; measured values under deterministic and
        // all-ties branching (documented discrepancy — see DESIGN.md §3).
        assert_eq!(default_policy_reachable(TieBreak::First).len(), 179);
        assert_eq!(default_policy_reachable(TieBreak::Last).len(), 179);
        assert_eq!(default_policy_reachable(TieBreak::AllMaximal).len(), 297);
    }

    #[test]
    fn reachable_is_subset_of_all() {
        let all: std::collections::HashSet<Config> = enumerate_all().into_iter().collect();
        for c in default_policy_reachable(TieBreak::AllMaximal) {
            assert!(all.contains(&c));
        }
    }

    #[test]
    fn two_gpu_pair_count_matches_paper() {
        // C(723 + 2 - 1, 2) = 723 * 724 / 2 = 261,726.
        let configs = enumerate_all();
        let n = configs.len();
        assert_eq!(n * (n + 1) / 2, 261_726);
    }

    /// Table 3 / Fig. 3: two arrangements of the same profiles with equal
    /// CC but different per-profile capacity exist in the space.
    #[test]
    fn table3_same_cc_different_capacity_exists() {
        let configs = enumerate_all();
        let groups = group_by_multiset(&configs);
        let mut found = false;
        'outer: for members in groups.values() {
            for (a_i, &a) in members.iter().enumerate() {
                for &b in &members[a_i + 1..] {
                    let (occ_a, occ_b) = (occupancy_of(a), occupancy_of(b));
                    if cc(occ_a) == cc(occ_b) && profile_capacity(occ_a) != profile_capacity(occ_b)
                    {
                        found = true;
                        break 'outer;
                    }
                }
            }
        }
        assert!(found, "no same-CC different-capacity pair found");
    }

    #[test]
    fn multiset_and_occupancy_consistent() {
        for &cfg in enumerate_all().iter().step_by(7) {
            let counts = profile_multiset(cfg);
            let blocks: u32 = counts
                .iter()
                .enumerate()
                .map(|(p, &c)| c as u32 * Profile::from_index(p).size() as u32)
                .sum();
            assert_eq!(occupancy_of(cfg).count_ones(), blocks);
        }
    }

    #[test]
    fn improvable_in_group_sweep_correct_bruteforce() {
        // Compare the sweep against an O(n^2) brute force on small groups.
        let configs = enumerate_all();
        let groups = group_by_multiset(&configs);
        for members in groups.values().filter(|m| m.len() >= 2).take(50) {
            let items: Vec<(u32, [u8; 6])> = members
                .iter()
                .map(|&c| {
                    let occ = occupancy_of(c);
                    (cc(occ), profile_capacity(occ))
                })
                .collect();
            let brute = items
                .iter()
                .enumerate()
                .filter(|(i, (cc_i, cap_i))| {
                    items.iter().enumerate().any(|(j, (cc_j, cap_j))| {
                        j != *i && cc_j <= cc_i && (0..6).any(|p| cap_j[p] > cap_i[p])
                    })
                })
                .count();
            assert_eq!(count_improvable_in_group(&items), brute);
        }
    }

    #[test]
    fn analyze_fast_path() {
        let stats = analyze(false);
        assert_eq!(stats.total, 723);
        assert_eq!(stats.maximal, 78);
        assert_eq!(stats.suboptimal, 482);
        assert!(stats.improvable > 0 && stats.improvable < stats.total);
    }
}
