//! GPU occupancy masks, the Configuration Capability metric (Eq. 1) and
//! live GPU state.
//!
//! A GPU configuration is a bitmask over 8 memory blocks (`1` = occupied).
//! CC and per-profile capacities are functions of the mask alone, so both
//! are precomputed for all 256 masks at first use — the native scoring
//! hot path is then a single table lookup (see EXPERIMENTS.md §Perf).

use super::profiles::{Placement, Profile, PLACEMENTS};
use std::sync::OnceLock;

/// Occupancy bitmask over the 8 memory blocks. Bit `i` set = block `i` occupied.
pub type BlockMask = u8;

/// Mask with every block occupied.
pub const FULL_GPU: BlockMask = 0xFF;

/// Number of memory blocks (re-export for convenience).
pub use super::profiles::NUM_BLOCKS;

struct CcTables {
    /// CC value per occupancy mask (Eq. 1).
    cc: [u16; 256],
    /// Per-profile feasible-start counts per occupancy mask.
    capacity: [[u8; 6]; 256],
}

fn tables() -> &'static CcTables {
    static TABLES: OnceLock<CcTables> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut cc = [0u16; 256];
        let mut capacity = [[0u8; 6]; 256];
        for occ in 0usize..256 {
            for pl in PLACEMENTS {
                if occ as u8 & pl.mask() == 0 {
                    cc[occ] += 1;
                    capacity[occ][pl.profile.index()] += 1;
                }
            }
        }
        CcTables { cc, capacity }
    })
}

/// Configuration Capability (Eq. 1): the number of legal placements that
/// still fit in configuration `occ`.
#[inline]
pub fn cc(occ: BlockMask) -> u32 {
    tables().cc[occ as usize] as u32
}

/// Feasible-start count for each profile under `occ` (indexed by
/// [`Profile::index`]). The per-profile capacity columns of Table 3.
#[inline]
pub fn profile_capacity(occ: BlockMask) -> [u8; 6] {
    tables().capacity[occ as usize]
}

/// Iterator over the start blocks where `profile` fits under `occ`.
pub fn feasible_starts(profile: Profile, occ: BlockMask) -> impl Iterator<Item = u8> {
    profile.start_blocks().iter().copied().filter(move |&s| {
        let m = Placement { profile, start: s }.mask();
        occ & m == 0
    })
}

/// Identifier of a VM owning a GPU instance.
pub type VmId = u64;

/// One allocated GPU instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instance {
    pub vm: VmId,
    pub placement: Placement,
}

/// Live state of a single MIG-enabled GPU: occupancy plus owned instances.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GpuState {
    occ: BlockMask,
    instances: Vec<Instance>,
}

impl GpuState {
    /// An empty (fully free) GPU.
    pub fn new() -> GpuState {
        GpuState::default()
    }

    /// Current occupancy mask.
    #[inline]
    pub fn occupancy(&self) -> BlockMask {
        self.occ
    }

    /// Allocated instances.
    #[inline]
    pub fn instances(&self) -> &[Instance] {
        &self.instances
    }

    /// Number of free memory blocks.
    #[inline]
    pub fn free_blocks(&self) -> u32 {
        NUM_BLOCKS as u32 - self.occ.count_ones()
    }

    /// True if nothing is allocated.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.occ == 0
    }

    /// Configuration Capability of the current state.
    #[inline]
    pub fn cc(&self) -> u32 {
        cc(self.occ)
    }

    /// `HalfFull` helper (Table 2): exactly one half (blocks 0–3 or 4–7)
    /// fully occupied, the other fully free.
    pub fn half_full(&self) -> bool {
        (self.occ == 0x0F) || (self.occ == 0xF0)
    }

    /// `SingleProfile` helper (Table 2): exactly one instance allocated.
    pub fn single_profile(&self) -> bool {
        self.instances.len() == 1
    }

    /// Place an instance at a specific placement. Panics in debug builds
    /// if the placement overlaps existing instances.
    pub fn place(&mut self, vm: VmId, placement: Placement) {
        debug_assert_eq!(
            self.occ & placement.mask(),
            0,
            "placement {placement} overlaps occupancy {:08b}",
            self.occ
        );
        self.occ |= placement.mask();
        self.instances.push(Instance { vm, placement });
    }

    /// Remove the instance owned by `vm`, returning its placement.
    pub fn remove_vm(&mut self, vm: VmId) -> Option<Placement> {
        let idx = self.instances.iter().position(|inst| inst.vm == vm)?;
        let inst = self.instances.swap_remove(idx);
        self.occ &= !inst.placement.mask();
        Some(inst.placement)
    }

    /// Find the instance owned by `vm`.
    pub fn find_vm(&self, vm: VmId) -> Option<Instance> {
        self.instances.iter().copied().find(|inst| inst.vm == vm)
    }

    /// Multiset of allocated profiles as counts indexed by profile.
    pub fn profile_counts(&self) -> [u8; 6] {
        let mut counts = [0u8; 6];
        for inst in &self.instances {
            counts[inst.placement.profile.index()] += 1;
        }
        counts
    }

    /// Total compute engines in use (for utilisation accounting).
    pub fn compute_engines_used(&self) -> u8 {
        self.instances.iter().map(|i| i.placement.profile.compute_engines()).sum()
    }

    /// Render the block map like Fig. 2 (e.g. `"115_22__"` — profile size
    /// digit per block, `_` free).
    pub fn block_map(&self) -> String {
        let mut map = ['_'; 8];
        for inst in &self.instances {
            let digit =
                char::from_digit(inst.placement.profile.compute_engines() as u32, 10).unwrap();
            for b in 0..8u8 {
                if inst.placement.mask() & (1 << b) != 0 {
                    map[b as usize] = digit;
                }
            }
        }
        map.iter().collect()
    }
}

/// Exhaustively verify an occupancy decomposition: does `occ` equal the
/// union of the instance masks with no overlap? Used by tests and the
/// simulator's integrity checks.
pub fn consistent(state: &GpuState) -> bool {
    let mut acc: BlockMask = 0;
    for inst in state.instances() {
        let m = inst.placement.mask();
        if acc & m != 0 {
            return false;
        }
        acc |= m;
    }
    acc == state.occupancy()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::profiles::ALL_PROFILES;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    /// The paper's worked example (§5): G = {1,2,4,5,6,7} free, i.e.
    /// blocks 0 and 3 occupied, has CC = 9.
    #[test]
    fn paper_example_cc_9() {
        let occ: BlockMask = 0b0000_1001; // blocks 0 and 3 occupied
        assert_eq!(cc(occ), 9);
        let cap = profile_capacity(occ);
        assert_eq!(cap[Profile::P1g5gb.index()], 5);
        assert_eq!(cap[Profile::P1g10gb.index()], 2);
        assert_eq!(cap[Profile::P2g10gb.index()], 1);
        assert_eq!(cap[Profile::P3g20gb.index()], 1);
        assert_eq!(cap[Profile::P4g20gb.index()], 0);
        assert_eq!(cap[Profile::P7g40gb.index()], 0);
    }

    #[test]
    fn empty_gpu_cc_is_18() {
        assert_eq!(cc(0), 18);
        assert_eq!(cc(FULL_GPU), 0);
    }

    /// Fig. 2(a): non-contiguous free blocks where neither 1g.10gb nor
    /// 2g.10gb fit. Occupy blocks 1,3,5,7 — free blocks 0,2,4,6 are all
    /// even, but each 2-block placement needs start and start+1.
    #[test]
    fn fig2a_fragmentation_no_two_block_fit() {
        let occ: BlockMask = 0b1010_1010;
        let cap = profile_capacity(occ);
        assert_eq!(cap[Profile::P1g10gb.index()], 0);
        assert_eq!(cap[Profile::P2g10gb.index()], 0);
        assert_eq!(cap[Profile::P1g5gb.index()], 4); // 0,2,4,6 all fit 1g.5gb
    }

    /// Fig. 2(b): contiguous free blocks that still cannot host profiles
    /// because the required *starting* blocks are unavailable. Blocks
    /// 1..=3 free (0,4,5,6,7 occupied): 2g.10gb needs start ∈ {0,2,4} and
    /// two free blocks — start 2 gives blocks 2,3: fits. But 3g.20gb
    /// (starts 0,4) cannot despite... use blocks 3..=5 free instead:
    /// starts {0,2,4}: only start 4 has 4,5 free → check a case with no
    /// valid start: free = {1,2,3}: 1g.10gb starts {0,2,4,6} → start 2
    /// fits blocks 2,3. Free = {1,3,5}: contiguity absent. True "(b)"
    /// case: free blocks {5,6,7} are contiguous but 3g.20gb/4g.20gb can't
    /// start there, and 2g.10gb only fits at one position.
    #[test]
    fn fig2b_contiguous_but_unplaceable() {
        let occ: BlockMask = 0b0001_1111; // blocks 0..=4 occupied; 5,6,7 free
        let cap = profile_capacity(occ);
        // Three contiguous free blocks, yet no 3- or 4-block profile fits
        // (3g.20gb requires start 0 or 4), and 2g.10gb has no legal start.
        assert_eq!(cap[Profile::P3g20gb.index()], 0);
        assert_eq!(cap[Profile::P4g20gb.index()], 0);
        assert_eq!(cap[Profile::P2g10gb.index()], 0);
        // 1g.10gb fits only at start 6.
        assert_eq!(cap[Profile::P1g10gb.index()], 1);
    }

    #[test]
    fn place_and_remove_roundtrip() {
        let mut g = GpuState::new();
        g.place(1, Placement { profile: Profile::P3g20gb, start: 0 });
        g.place(2, Placement { profile: Profile::P2g10gb, start: 4 });
        assert!(consistent(&g));
        assert_eq!(g.occupancy(), 0b0011_1111);
        assert_eq!(g.free_blocks(), 2);
        assert_eq!(g.remove_vm(1), Some(Placement { profile: Profile::P3g20gb, start: 0 }));
        assert_eq!(g.occupancy(), 0b0011_0000);
        assert!(consistent(&g));
        assert_eq!(g.remove_vm(99), None);
    }

    #[test]
    fn half_full_detection() {
        let mut g = GpuState::new();
        g.place(1, Placement { profile: Profile::P3g20gb, start: 4 });
        assert!(g.half_full());
        assert!(g.single_profile());
        g.place(2, Placement { profile: Profile::P1g5gb, start: 0 });
        assert!(!g.half_full());
        assert!(!g.single_profile());
    }

    #[test]
    fn block_map_rendering() {
        let mut g = GpuState::new();
        g.place(1, Placement { profile: Profile::P3g20gb, start: 0 });
        g.place(2, Placement { profile: Profile::P1g5gb, start: 5 });
        assert_eq!(g.block_map(), "3333_1__");
    }

    #[test]
    fn cc_table_matches_direct_computation() {
        for occ in 0u16..256 {
            let occ = occ as u8;
            let direct: u32 =
                PLACEMENTS.iter().filter(|pl| occ & pl.mask() == 0).count() as u32;
            assert_eq!(cc(occ), direct, "occ={occ:08b}");
            let cap = profile_capacity(occ);
            let total: u32 = cap.iter().map(|&c| c as u32).sum();
            assert_eq!(total, direct, "capacity sum mismatch at occ={occ:08b}");
        }
    }

    #[test]
    fn prop_cc_monotone_under_occupation() {
        // Occupying more blocks never increases CC.
        forall(
            "cc-monotone",
            |r: &mut Rng| {
                let occ = r.below(256) as u8;
                let extra = 1u8 << r.below(8);
                (occ, extra)
            },
            |&(occ, extra)| {
                if cc(occ | extra) <= cc(occ) {
                    Ok(())
                } else {
                    Err(format!("cc({:08b}) > cc({:08b})", occ | extra, occ))
                }
            },
        );
    }

    #[test]
    fn prop_feasible_starts_agree_with_capacity() {
        forall(
            "feasible-starts-vs-capacity",
            |r: &mut Rng| r.below(256) as u8,
            |&occ| {
                for p in ALL_PROFILES {
                    let n = feasible_starts(p, occ).count() as u8;
                    if n != profile_capacity(occ)[p.index()] {
                        return Err(format!("mismatch for {p} at occ={occ:08b}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_random_place_remove_consistency() {
        forall(
            "gpu-state-consistency",
            |r: &mut Rng| {
                // A random sequence of place/remove operations.
                let mut g = GpuState::new();
                let mut next_vm: VmId = 0;
                for _ in 0..32 {
                    if r.chance(0.6) {
                        let p = ALL_PROFILES[r.below(6) as usize];
                        if let Some(s) = feasible_starts(p, g.occupancy()).next() {
                            g.place(next_vm, Placement { profile: p, start: s });
                            next_vm += 1;
                        }
                    } else if !g.instances().is_empty() {
                        let vm = g.instances()[r.below(g.instances().len() as u64) as usize].vm;
                        g.remove_vm(vm);
                    }
                }
                g
            },
            |g| {
                if consistent(g) {
                    Ok(())
                } else {
                    Err(format!("inconsistent state: occ={:08b}", g.occupancy()))
                }
            },
        );
    }
}
