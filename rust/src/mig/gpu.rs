//! GPU occupancy masks, the Configuration Capability metric (Eq. 1) and
//! live GPU state — parameterized over the [`GpuModel`] catalog.
//!
//! A GPU configuration is a bitmask over the model's memory blocks
//! (`1` = occupied; every catalog model has ≤ 8 blocks, so a `u8` mask
//! suffices). CC and per-profile capacities are functions of the
//! `(model, mask)` pair alone, so both are precomputed per model at
//! first use — the native scoring hot path is then a single table lookup
//! (see EXPERIMENTS.md §Perf). The model-less [`cc`] /
//! [`profile_capacity`] shorthands evaluate the A100-40GB (the paper's
//! part), which the §5.1 analyses are written against.

use super::model::{GpuModel, ALL_MODELS, MAX_MODEL_PROFILES, NUM_MODELS};
use super::profiles::{placements_for, Placement, Profile};
use std::sync::OnceLock;

/// Occupancy bitmask over a model's memory blocks. Bit `i` set = block
/// `i` occupied. Masks of a model with `b` blocks use only the low `b`
/// bits.
pub type BlockMask = u8;

/// Mask with every block of an A100-40 occupied. Model-aware code uses
/// [`GpuModel::full_mask`].
pub const FULL_GPU: BlockMask = 0xFF;

/// Number of memory blocks on an A100-40 (re-export for convenience).
pub use super::profiles::NUM_BLOCKS;

struct ModelTables {
    /// CC value per occupancy mask (Eq. 1), `1 << num_blocks` entries.
    cc: Vec<u16>,
    /// Per-profile feasible-start counts per occupancy mask, indexed by
    /// the per-model [`Profile::index`].
    capacity: Vec<[u8; MAX_MODEL_PROFILES]>,
}

fn tables() -> &'static [ModelTables; NUM_MODELS] {
    static TABLES: OnceLock<[ModelTables; NUM_MODELS]> = OnceLock::new();
    TABLES.get_or_init(|| {
        ALL_MODELS.map(|model| {
            let placements = placements_for(model);
            let masks = model.num_masks();
            let mut cc = vec![0u16; masks];
            let mut capacity = vec![[0u8; MAX_MODEL_PROFILES]; masks];
            for occ in 0..masks {
                for pl in &placements {
                    if occ as u8 & pl.mask() == 0 {
                        cc[occ] += 1;
                        capacity[occ][pl.profile.index()] += 1;
                    }
                }
            }
            ModelTables { cc, capacity }
        })
    })
}

/// Configuration Capability (Eq. 1) of `occ` on `model`: the number of
/// legal placements that still fit. `occ` must only use the model's low
/// `num_blocks` bits.
#[inline]
pub fn cc_for(model: GpuModel, occ: BlockMask) -> u32 {
    tables()[model as usize].cc[occ as usize] as u32
}

/// Feasible-start count for each of `model`'s profiles under `occ`,
/// indexed by the per-model [`Profile::index`] (entries past
/// `model.num_profiles()` stay zero). The per-profile capacity columns
/// of Table 3.
#[inline]
pub fn profile_capacity_for(model: GpuModel, occ: BlockMask) -> [u8; MAX_MODEL_PROFILES] {
    tables()[model as usize].capacity[occ as usize]
}

/// [`cc_for`] on the A100-40GB (the paper's single-model analyses).
#[inline]
pub fn cc(occ: BlockMask) -> u32 {
    cc_for(GpuModel::A100_40, occ)
}

/// [`profile_capacity_for`] on the A100-40GB.
#[inline]
pub fn profile_capacity(occ: BlockMask) -> [u8; MAX_MODEL_PROFILES] {
    profile_capacity_for(GpuModel::A100_40, occ)
}

/// Iterator over the start blocks where `profile` fits under `occ`
/// (an occupancy of a GPU of the profile's model).
pub fn feasible_starts(profile: Profile, occ: BlockMask) -> impl Iterator<Item = u8> {
    profile.start_blocks().iter().copied().filter(move |&s| {
        let m = Placement { profile, start: s }.mask();
        occ & m == 0
    })
}

/// Identifier of a VM owning a GPU instance.
pub type VmId = u64;

/// One allocated GPU instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instance {
    pub vm: VmId,
    pub placement: Placement,
}

/// Live state of a single MIG-enabled GPU: the part's model, occupancy,
/// and owned instances.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GpuState {
    model: GpuModel,
    occ: BlockMask,
    instances: Vec<Instance>,
}

impl Default for GpuState {
    fn default() -> Self {
        GpuState::new()
    }
}

impl GpuState {
    /// An empty (fully free) A100-40 — the historical default part.
    pub fn new() -> GpuState {
        GpuState::with_model(GpuModel::A100_40)
    }

    /// An empty GPU of the given model.
    pub fn with_model(model: GpuModel) -> GpuState {
        GpuState { model, occ: 0, instances: Vec::new() }
    }

    /// The part's model.
    #[inline]
    pub fn model(&self) -> GpuModel {
        self.model
    }

    /// Current occupancy mask.
    #[inline]
    pub fn occupancy(&self) -> BlockMask {
        self.occ
    }

    /// Allocated instances.
    #[inline]
    pub fn instances(&self) -> &[Instance] {
        &self.instances
    }

    /// Number of free memory blocks.
    #[inline]
    pub fn free_blocks(&self) -> u32 {
        self.model.num_blocks() as u32 - self.occ.count_ones()
    }

    /// True if nothing is allocated.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.occ == 0
    }

    /// Configuration Capability of the current state.
    #[inline]
    pub fn cc(&self) -> u32 {
        cc_for(self.model, self.occ)
    }

    /// `HalfFull` helper (Table 2): exactly one half of the model's
    /// blocks fully occupied, the other fully free (blocks 0–3 / 4–7 on
    /// an 8-block part).
    pub fn half_full(&self) -> bool {
        let half = self.model.num_blocks() / 2;
        let lo = ((1u16 << half) - 1) as u8;
        let hi = lo << half;
        (self.occ == lo) || (self.occ == hi)
    }

    /// `SingleProfile` helper (Table 2): exactly one instance allocated.
    pub fn single_profile(&self) -> bool {
        self.instances.len() == 1
    }

    /// Place an instance at a specific placement. Panics in debug builds
    /// if the placement overlaps existing instances or belongs to a
    /// different model.
    pub fn place(&mut self, vm: VmId, placement: Placement) {
        debug_assert_eq!(
            placement.profile.model(),
            self.model,
            "placement {placement} on a {} GPU",
            self.model
        );
        debug_assert_eq!(
            self.occ & placement.mask(),
            0,
            "placement {placement} overlaps occupancy {:08b}",
            self.occ
        );
        self.occ |= placement.mask();
        self.instances.push(Instance { vm, placement });
    }

    /// Remove the instance owned by `vm`, returning its placement.
    pub fn remove_vm(&mut self, vm: VmId) -> Option<Placement> {
        let idx = self.instances.iter().position(|inst| inst.vm == vm)?;
        let inst = self.instances.swap_remove(idx);
        self.occ &= !inst.placement.mask();
        Some(inst.placement)
    }

    /// Find the instance owned by `vm`.
    pub fn find_vm(&self, vm: VmId) -> Option<Instance> {
        self.instances.iter().copied().find(|inst| inst.vm == vm)
    }

    /// Multiset of allocated profiles as counts indexed by the per-model
    /// [`Profile::index`].
    pub fn profile_counts(&self) -> [u8; MAX_MODEL_PROFILES] {
        let mut counts = [0u8; MAX_MODEL_PROFILES];
        for inst in &self.instances {
            counts[inst.placement.profile.index()] += 1;
        }
        counts
    }

    /// Total compute engines in use (for utilisation accounting).
    pub fn compute_engines_used(&self) -> u8 {
        self.instances.iter().map(|i| i.placement.profile.compute_engines()).sum()
    }

    /// Render the block map like Fig. 2 (e.g. `"115_22__"` — compute
    /// digit per block, `_` free); one character per model block.
    pub fn block_map(&self) -> String {
        let blocks = self.model.num_blocks();
        let mut map = vec!['_'; blocks as usize];
        for inst in &self.instances {
            let digit =
                char::from_digit(inst.placement.profile.compute_engines() as u32, 10).unwrap();
            for b in 0..blocks {
                if inst.placement.mask() & (1 << b) != 0 {
                    map[b as usize] = digit;
                }
            }
        }
        map.iter().collect()
    }
}

/// Exhaustively verify an occupancy decomposition: does `occ` equal the
/// union of the instance masks with no overlap, and does every instance
/// belong to the GPU's model? Used by tests and the simulator's
/// integrity checks.
pub fn consistent(state: &GpuState) -> bool {
    let mut acc: BlockMask = 0;
    for inst in state.instances() {
        if inst.placement.profile.model() != state.model() {
            return false;
        }
        let m = inst.placement.mask();
        if acc & m != 0 {
            return false;
        }
        acc |= m;
    }
    acc == state.occupancy() && state.occupancy() & !state.model().full_mask() == 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::profiles::{ALL_PROFILES, PLACEMENTS};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    /// The paper's worked example (§5): G = {1,2,4,5,6,7} free, i.e.
    /// blocks 0 and 3 occupied, has CC = 9.
    #[test]
    fn paper_example_cc_9() {
        let occ: BlockMask = 0b0000_1001; // blocks 0 and 3 occupied
        assert_eq!(cc(occ), 9);
        let cap = profile_capacity(occ);
        assert_eq!(cap[Profile::P1g5gb.index()], 5);
        assert_eq!(cap[Profile::P1g10gb.index()], 2);
        assert_eq!(cap[Profile::P2g10gb.index()], 1);
        assert_eq!(cap[Profile::P3g20gb.index()], 1);
        assert_eq!(cap[Profile::P4g20gb.index()], 0);
        assert_eq!(cap[Profile::P7g40gb.index()], 0);
    }

    #[test]
    fn empty_gpu_cc_is_18() {
        assert_eq!(cc(0), 18);
        assert_eq!(cc(FULL_GPU), 0);
    }

    #[test]
    fn per_model_cc_of_empty_and_full() {
        // Empty CC = the model's placement count; full CC = 0.
        for m in ALL_MODELS {
            let placements = crate::mig::profiles::placements_for(m).len() as u32;
            assert_eq!(cc_for(m, 0), placements, "{m}");
            assert_eq!(cc_for(m, m.full_mask()), 0, "{m}");
        }
        // A30: 4 + 2 + 1 legal placements.
        assert_eq!(cc_for(GpuModel::A30, 0), 7);
    }

    #[test]
    fn a30_capacity_tables() {
        let keys: Vec<Profile> = GpuModel::A30.profile_keys().collect();
        let cap = profile_capacity_for(GpuModel::A30, 0);
        assert_eq!(cap[keys[0].index()], 4); // 1g.6gb anywhere
        assert_eq!(cap[keys[1].index()], 2); // 2g.12gb at 0, 2
        assert_eq!(cap[keys[2].index()], 1); // 4g.24gb at 0
        assert_eq!(cap[3..], [0u8; 3]); // unused tail stays zero
        // Block 1 occupied: 2g.12gb@0 and 4g.24gb die, 2g.12gb@2 lives.
        let cap = profile_capacity_for(GpuModel::A30, 0b0010);
        assert_eq!(cap[keys[0].index()], 3);
        assert_eq!(cap[keys[1].index()], 1);
        assert_eq!(cap[keys[2].index()], 0);
    }

    /// Fig. 2(a): non-contiguous free blocks where neither 1g.10gb nor
    /// 2g.10gb fit. Occupy blocks 1,3,5,7 — free blocks 0,2,4,6 are all
    /// even, but each 2-block placement needs start and start+1.
    #[test]
    fn fig2a_fragmentation_no_two_block_fit() {
        let occ: BlockMask = 0b1010_1010;
        let cap = profile_capacity(occ);
        assert_eq!(cap[Profile::P1g10gb.index()], 0);
        assert_eq!(cap[Profile::P2g10gb.index()], 0);
        assert_eq!(cap[Profile::P1g5gb.index()], 4); // 0,2,4,6 all fit 1g.5gb
    }

    /// Fig. 2(b): contiguous free blocks that still cannot host profiles
    /// because the required *starting* blocks are unavailable.
    #[test]
    fn fig2b_contiguous_but_unplaceable() {
        let occ: BlockMask = 0b0001_1111; // blocks 0..=4 occupied; 5,6,7 free
        let cap = profile_capacity(occ);
        // Three contiguous free blocks, yet no 3- or 4-block profile fits
        // (3g.20gb requires start 0 or 4), and 2g.10gb has no legal start.
        assert_eq!(cap[Profile::P3g20gb.index()], 0);
        assert_eq!(cap[Profile::P4g20gb.index()], 0);
        assert_eq!(cap[Profile::P2g10gb.index()], 0);
        // 1g.10gb fits only at start 6.
        assert_eq!(cap[Profile::P1g10gb.index()], 1);
    }

    #[test]
    fn place_and_remove_roundtrip() {
        let mut g = GpuState::new();
        g.place(1, Placement { profile: Profile::P3g20gb, start: 0 });
        g.place(2, Placement { profile: Profile::P2g10gb, start: 4 });
        assert!(consistent(&g));
        assert_eq!(g.occupancy(), 0b0011_1111);
        assert_eq!(g.free_blocks(), 2);
        assert_eq!(g.remove_vm(1), Some(Placement { profile: Profile::P3g20gb, start: 0 }));
        assert_eq!(g.occupancy(), 0b0011_0000);
        assert!(consistent(&g));
        assert_eq!(g.remove_vm(99), None);
    }

    #[test]
    fn a30_state_and_halves() {
        let k2g = GpuModel::A30.profile(1); // 2g.12gb
        let mut g = GpuState::with_model(GpuModel::A30);
        assert_eq!(g.free_blocks(), 4);
        g.place(1, Placement { profile: k2g, start: 0 });
        assert!(g.half_full(), "2 of 4 blocks in the low half");
        assert!(g.single_profile());
        assert_eq!(g.free_blocks(), 2);
        assert_eq!(g.block_map(), "22__");
        assert!(consistent(&g));
        g.place(2, Placement { profile: GpuModel::A30.profile(0), start: 2 });
        assert!(!g.half_full());
        assert_eq!(g.cc(), cc_for(GpuModel::A30, 0b0111));
    }

    #[test]
    fn half_full_detection() {
        let mut g = GpuState::new();
        g.place(1, Placement { profile: Profile::P3g20gb, start: 4 });
        assert!(g.half_full());
        assert!(g.single_profile());
        g.place(2, Placement { profile: Profile::P1g5gb, start: 0 });
        assert!(!g.half_full());
        assert!(!g.single_profile());
    }

    #[test]
    fn block_map_rendering() {
        let mut g = GpuState::new();
        g.place(1, Placement { profile: Profile::P3g20gb, start: 0 });
        g.place(2, Placement { profile: Profile::P1g5gb, start: 5 });
        assert_eq!(g.block_map(), "3333_1__");
    }

    #[test]
    fn cc_table_matches_direct_computation() {
        for model in ALL_MODELS {
            let placements = crate::mig::profiles::placements_for(model);
            for occ in 0..model.num_masks() {
                let occ = occ as u8;
                let direct: u32 =
                    placements.iter().filter(|pl| occ & pl.mask() == 0).count() as u32;
                assert_eq!(cc_for(model, occ), direct, "{model} occ={occ:08b}");
                let cap = profile_capacity_for(model, occ);
                let total: u32 = cap.iter().map(|&c| c as u32).sum();
                assert_eq!(total, direct, "{model}: capacity sum mismatch at occ={occ:08b}");
            }
        }
    }

    #[test]
    fn prop_cc_monotone_under_occupation() {
        // Occupying more blocks never increases CC, on any model.
        forall(
            "cc-monotone",
            |r: &mut Rng| {
                let model = ALL_MODELS[r.below(ALL_MODELS.len() as u64) as usize];
                let occ = r.below(model.num_masks() as u64) as u8;
                let extra = 1u8 << r.below(model.num_blocks() as u64);
                (model, occ, extra)
            },
            |&(model, occ, extra)| {
                if cc_for(model, occ | extra) <= cc_for(model, occ) {
                    Ok(())
                } else {
                    Err(format!("{model}: cc({:08b}) > cc({:08b})", occ | extra, occ))
                }
            },
        );
    }

    #[test]
    fn prop_feasible_starts_agree_with_capacity() {
        forall(
            "feasible-starts-vs-capacity",
            |r: &mut Rng| {
                let model = ALL_MODELS[r.below(ALL_MODELS.len() as u64) as usize];
                (model, r.below(model.num_masks() as u64) as u8)
            },
            |&(model, occ)| {
                for p in model.profile_keys() {
                    let n = feasible_starts(p, occ).count() as u8;
                    if n != profile_capacity_for(model, occ)[p.index()] {
                        return Err(format!("mismatch for {p} at occ={occ:08b}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_random_place_remove_consistency() {
        forall(
            "gpu-state-consistency",
            |r: &mut Rng| {
                // A random sequence of place/remove operations.
                let mut g = GpuState::new();
                let mut next_vm: VmId = 0;
                for _ in 0..32 {
                    if r.chance(0.6) {
                        let p = ALL_PROFILES[r.below(6) as usize];
                        if let Some(s) = feasible_starts(p, g.occupancy()).next() {
                            g.place(next_vm, Placement { profile: p, start: s });
                            next_vm += 1;
                        }
                    } else if !g.instances().is_empty() {
                        let vm = g.instances()[r.below(g.instances().len() as u64) as usize].vm;
                        g.remove_vm(vm);
                    }
                }
                g
            },
            |g| {
                if consistent(g) {
                    Ok(())
                } else {
                    Err(format!("inconsistent state: occ={:08b}", g.occupancy()))
                }
            },
        );
    }

    #[test]
    fn placements_table_sanity() {
        // Kept from the pre-catalog suite: PLACEMENTS is the A100-40
        // table the CC tables are built from.
        assert_eq!(PLACEMENTS.len(), 18);
        assert_eq!(cc(0) as usize, PLACEMENTS.len());
    }
}
