//! The GPU-model catalog: per-model MIG geometry for heterogeneous
//! fleets.
//!
//! The paper (and this crate's original MIG layer) hardcodes one part —
//! the A100-40GB with its 8 memory blocks and six GI profiles. Real MIG
//! clouds mix parts with different block counts and legal-placement
//! tables (A30, A100-80GB, H100-80GB, ...). This module is the single
//! source of truth for that geometry:
//!
//! * [`GpuModel`] — the supported parts, each with a [`ModelSpec`]
//!   (block count, compute engines, per-profile tables).
//! * [`ProfileKey`] — a `(model, per-model index)` pair replacing the old
//!   closed six-variant `Profile` enum. `Profile` is now a type alias for
//!   `ProfileKey`; the A100-40 profiles keep their historical associated
//!   constants (`Profile::P1g5gb` .. `Profile::P7g40gb`).
//!
//! ## Dense-index determinism contract
//!
//! Catalog order puts the A100-40GB **first**, so the dense cross-model
//! index ([`ProfileKey::dense`], `0..NUM_PROFILE_KEYS`) of every A100-40
//! profile equals its historical `Profile::index()` value (0..6). All
//! cluster-wide accounting arrays (`SimResult::per_profile`, MECC
//! windows, `ClusterIndex` buckets) are keyed by the dense index, which
//! keeps A100-only runs byte-identical to the pre-catalog layout: the
//! first six slots carry exactly the old contents and every other slot
//! stays zero/empty. Per-GPU arrays (capacity tables, instance counts)
//! stay keyed by the *per-model* index `0..MAX_MODEL_PROFILES`.

use std::fmt;

/// Number of models in the catalog.
pub const NUM_MODELS: usize = 4;

/// Upper bound on profiles per model (sizes per-GPU capacity arrays).
pub const MAX_MODEL_PROFILES: usize = 6;

/// Total profile keys across the catalog (the dense index space).
pub const NUM_PROFILE_KEYS: usize = 21;

/// A MIG-capable GPU part. Catalog order (= `as usize` = dense-offset
/// order) intentionally puts the A100-40GB first — see the module docs'
/// determinism contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[allow(non_camel_case_types)] // hardware part names: A100_40, H100_80
pub enum GpuModel {
    /// NVIDIA A100 40GB: 8 × 5 GB blocks, 7 compute engines (the paper's
    /// part; Table 1 / Table 5).
    A100_40,
    /// NVIDIA A30 24GB: 4 × 6 GB blocks, 4 compute engines.
    A30,
    /// NVIDIA A100 80GB: 8 × 10 GB blocks, 7 compute engines.
    A100_80,
    /// NVIDIA H100 80GB: 8 × 10 GB blocks, 7 compute engines (A100-80
    /// geometry, distinct characteristic `h_i`).
    H100_80,
}

/// All models in catalog order.
pub const ALL_MODELS: [GpuModel; NUM_MODELS] =
    [GpuModel::A100_40, GpuModel::A30, GpuModel::A100_80, GpuModel::H100_80];

/// One GI profile row of a model's table (`Cg.Mgb`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProfileSpec {
    /// Canonical NVIDIA name, e.g. `"2g.10gb"`.
    pub name: &'static str,
    /// Size in memory blocks (`g_i`).
    pub blocks: u8,
    /// Compute engines (the `C` in `Cg.Mgb`).
    pub compute: u8,
    /// Memory in GB (the `M` in `Cg.Mgb`).
    pub memory_gb: u8,
    /// Legal starting blocks (the model's Algorithm-1 `startBlocks` row).
    pub start_blocks: &'static [u8],
    /// Maximum simultaneous instances on one GPU.
    pub max_instances: u8,
}

/// Static geometry of one GPU model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelSpec {
    /// Canonical lowercase name used by `--gpu-models` and reports.
    pub name: &'static str,
    /// Memory blocks on the part (≤ 8, so occupancy fits a `u8` mask).
    pub num_blocks: u8,
    /// Total compute engines.
    pub total_compute: u8,
    /// GB per memory block.
    pub block_gb: u8,
    /// GPU characteristic (`h_i` / `H_jk` of Eq. 17–18) — the
    /// compatibility code a request's profile must match.
    pub characteristic: u32,
    /// First dense index of this model's profiles.
    pub dense_offset: usize,
    /// The profile table, ordered smallest-to-largest (the per-model
    /// analogue of `ALL_PROFILES` order).
    pub profiles: &'static [ProfileSpec],
}

const A100_40_PROFILES: [ProfileSpec; 6] = [
    ProfileSpec {
        name: "1g.5gb",
        blocks: 1,
        compute: 1,
        memory_gb: 5,
        start_blocks: &[0, 1, 2, 3, 4, 5, 6],
        max_instances: 7,
    },
    ProfileSpec {
        name: "1g.10gb",
        blocks: 2,
        compute: 1,
        memory_gb: 10,
        start_blocks: &[0, 2, 4, 6],
        max_instances: 4,
    },
    ProfileSpec {
        name: "2g.10gb",
        blocks: 2,
        compute: 2,
        memory_gb: 10,
        start_blocks: &[0, 2, 4],
        max_instances: 3,
    },
    ProfileSpec {
        name: "3g.20gb",
        blocks: 4,
        compute: 3,
        memory_gb: 20,
        start_blocks: &[0, 4],
        max_instances: 2,
    },
    ProfileSpec {
        name: "4g.20gb",
        blocks: 4,
        compute: 4,
        memory_gb: 20,
        start_blocks: &[0],
        max_instances: 1,
    },
    ProfileSpec {
        name: "7g.40gb",
        blocks: 8,
        compute: 7,
        memory_gb: 40,
        start_blocks: &[0],
        max_instances: 1,
    },
];

const A30_PROFILES: [ProfileSpec; 3] = [
    ProfileSpec {
        name: "1g.6gb",
        blocks: 1,
        compute: 1,
        memory_gb: 6,
        start_blocks: &[0, 1, 2, 3],
        max_instances: 4,
    },
    ProfileSpec {
        name: "2g.12gb",
        blocks: 2,
        compute: 2,
        memory_gb: 12,
        start_blocks: &[0, 2],
        max_instances: 2,
    },
    ProfileSpec {
        name: "4g.24gb",
        blocks: 4,
        compute: 4,
        memory_gb: 24,
        start_blocks: &[0],
        max_instances: 1,
    },
];

const A100_80_PROFILES: [ProfileSpec; 6] = [
    ProfileSpec {
        name: "1g.10gb",
        blocks: 1,
        compute: 1,
        memory_gb: 10,
        start_blocks: &[0, 1, 2, 3, 4, 5, 6],
        max_instances: 7,
    },
    ProfileSpec {
        name: "1g.20gb",
        blocks: 2,
        compute: 1,
        memory_gb: 20,
        start_blocks: &[0, 2, 4, 6],
        max_instances: 4,
    },
    ProfileSpec {
        name: "2g.20gb",
        blocks: 2,
        compute: 2,
        memory_gb: 20,
        start_blocks: &[0, 2, 4],
        max_instances: 3,
    },
    ProfileSpec {
        name: "3g.40gb",
        blocks: 4,
        compute: 3,
        memory_gb: 40,
        start_blocks: &[0, 4],
        max_instances: 2,
    },
    ProfileSpec {
        name: "4g.40gb",
        blocks: 4,
        compute: 4,
        memory_gb: 40,
        start_blocks: &[0],
        max_instances: 1,
    },
    ProfileSpec {
        name: "7g.80gb",
        blocks: 8,
        compute: 7,
        memory_gb: 80,
        start_blocks: &[0],
        max_instances: 1,
    },
];

// The H100-80GB shares the A100-80GB MIG geometry (8 × 10 GB blocks,
// 7 engines, same profile names and placement rules); only the
// characteristic code distinguishes it for Eq. 17–18 compatibility.
const H100_80_PROFILES: [ProfileSpec; 6] = A100_80_PROFILES;

static MODEL_SPECS: [ModelSpec; NUM_MODELS] = [
    ModelSpec {
        name: "a100-40",
        num_blocks: 8,
        total_compute: 7,
        block_gb: 5,
        characteristic: 100,
        dense_offset: 0,
        profiles: &A100_40_PROFILES,
    },
    ModelSpec {
        name: "a30",
        num_blocks: 4,
        total_compute: 4,
        block_gb: 6,
        characteristic: 30,
        dense_offset: 6,
        profiles: &A30_PROFILES,
    },
    ModelSpec {
        name: "a100-80",
        num_blocks: 8,
        total_compute: 7,
        block_gb: 10,
        characteristic: 101,
        dense_offset: 9,
        profiles: &A100_80_PROFILES,
    },
    ModelSpec {
        name: "h100-80",
        num_blocks: 8,
        total_compute: 7,
        block_gb: 10,
        characteristic: 900,
        dense_offset: 15,
        profiles: &H100_80_PROFILES,
    },
];

impl GpuModel {
    /// The model's static geometry.
    #[inline]
    pub fn spec(self) -> &'static ModelSpec {
        &MODEL_SPECS[self as usize]
    }

    /// Canonical lowercase name (`"a100-40"`, `"a30"`, ...).
    #[inline]
    pub fn name(self) -> &'static str {
        self.spec().name
    }

    /// Memory blocks on the part.
    #[inline]
    pub fn num_blocks(self) -> u8 {
        self.spec().num_blocks
    }

    /// Total compute engines.
    #[inline]
    pub fn total_compute(self) -> u8 {
        self.spec().total_compute
    }

    /// GPU characteristic (`H_jk` of Eq. 17–18).
    #[inline]
    pub fn characteristic(self) -> u32 {
        self.spec().characteristic
    }

    /// Occupancy mask with every block of this model occupied.
    #[inline]
    pub fn full_mask(self) -> u8 {
        ((1u16 << self.num_blocks()) - 1) as u8
    }

    /// Number of occupancy masks (`2^num_blocks`) — per-model table size.
    #[inline]
    pub fn num_masks(self) -> usize {
        1usize << self.num_blocks()
    }

    /// Number of GI profiles this model supports.
    #[inline]
    pub fn num_profiles(self) -> usize {
        self.spec().profiles.len()
    }

    /// First dense index of this model's profile keys.
    #[inline]
    pub fn dense_offset(self) -> usize {
        self.spec().dense_offset
    }

    /// The profile key at per-model index `idx`.
    #[inline]
    pub fn profile(self, idx: usize) -> ProfileKey {
        debug_assert!(idx < self.num_profiles());
        ProfileKey { model: self, idx: idx as u8 }
    }

    /// All of this model's profile keys, smallest profile first.
    pub fn profile_keys(self) -> impl Iterator<Item = ProfileKey> {
        (0..self.num_profiles()).map(move |i| self.profile(i))
    }

    /// Parse a model name (case-insensitive; accepts the aliases `a100`
    /// for `a100-40` and `h100` for `h100-80`).
    pub fn parse(s: &str) -> Option<GpuModel> {
        let needle = s.trim().to_ascii_lowercase();
        match needle.as_str() {
            "a100" => return Some(GpuModel::A100_40),
            "h100" => return Some(GpuModel::H100_80),
            _ => {}
        }
        ALL_MODELS.iter().copied().find(|m| m.name() == needle)
    }
}

impl fmt::Display for GpuModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A GI profile of one catalog model: the open-world replacement for the
/// closed A100-only `Profile` enum. Ordering is `(model, idx)` — the
/// A100-40 subset keeps its historical order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ProfileKey {
    model: GpuModel,
    idx: u8,
}

// The historical `Profile::P1g5gb` .. `Profile::P7g40gb` spellings are
// kept verbatim (they are NVIDIA profile names, not globals).
#[allow(non_upper_case_globals)]
impl ProfileKey {
    /// MIG 1g.5gb (A100-40) — 1 block, 1 compute engine, up to 7 instances.
    pub const P1g5gb: ProfileKey = ProfileKey { model: GpuModel::A100_40, idx: 0 };
    /// MIG 1g.10gb (A100-40) — 2 blocks, 1 compute engine, up to 4 instances.
    pub const P1g10gb: ProfileKey = ProfileKey { model: GpuModel::A100_40, idx: 1 };
    /// MIG 2g.10gb (A100-40) — 2 blocks, 2 compute engines, up to 3 instances.
    pub const P2g10gb: ProfileKey = ProfileKey { model: GpuModel::A100_40, idx: 2 };
    /// MIG 3g.20gb (A100-40) — 4 blocks, 3 compute engines, up to 2 instances.
    pub const P3g20gb: ProfileKey = ProfileKey { model: GpuModel::A100_40, idx: 3 };
    /// MIG 4g.20gb (A100-40) — 4 blocks, 4 compute engines, 1 instance.
    pub const P4g20gb: ProfileKey = ProfileKey { model: GpuModel::A100_40, idx: 4 };
    /// MIG 7g.40gb (A100-40) — 8 blocks, 7 compute engines, whole GPU.
    pub const P7g40gb: ProfileKey = ProfileKey { model: GpuModel::A100_40, idx: 5 };

    /// The owning model.
    #[inline]
    pub fn model(self) -> GpuModel {
        self.model
    }

    /// Per-model index `0..model.num_profiles()` — indexes per-GPU
    /// capacity/count arrays. For A100-40 profiles this equals the
    /// historical `Profile::index()`.
    #[inline]
    pub fn index(self) -> usize {
        self.idx as usize
    }

    /// Dense cross-model index `0..NUM_PROFILE_KEYS` — indexes
    /// cluster-wide accounting (buckets, per-profile counters).
    #[inline]
    pub fn dense(self) -> usize {
        self.model.dense_offset() + self.idx as usize
    }

    /// Profile key from a dense index.
    pub fn from_dense(d: usize) -> ProfileKey {
        for m in ALL_MODELS {
            let off = m.dense_offset();
            if d < off + m.num_profiles() {
                return m.profile(d - off);
            }
        }
        panic!("dense profile index {d} out of range");
    }

    /// A100-40 profile from its historical dense index (compatibility
    /// accessor for the old `Profile::from_index`).
    #[inline]
    pub fn from_index(i: usize) -> ProfileKey {
        GpuModel::A100_40.profile(i)
    }

    /// Every catalog profile key in dense order.
    pub fn all() -> impl Iterator<Item = ProfileKey> {
        ALL_MODELS.into_iter().flat_map(|m| m.profile_keys())
    }

    #[inline]
    fn spec(self) -> &'static ProfileSpec {
        &self.model.spec().profiles[self.idx as usize]
    }

    /// Size in memory blocks (`g_i` in Table 5).
    #[inline]
    pub fn size(self) -> u8 {
        self.spec().blocks
    }

    /// Number of compute engines (the `C` in `Cg.Mgb`).
    #[inline]
    pub fn compute_engines(self) -> u8 {
        self.spec().compute
    }

    /// Memory in GB (the `M` in `Cg.Mgb`).
    #[inline]
    pub fn memory_gb(self) -> u8 {
        self.spec().memory_gb
    }

    /// Legal starting blocks (the model's Algorithm-1 `startBlocks` row).
    #[inline]
    pub fn start_blocks(self) -> &'static [u8] {
        self.spec().start_blocks
    }

    /// Last permissible starting index (`s_i` in Table 5).
    #[inline]
    pub fn last_start(self) -> u8 {
        *self.spec().start_blocks.last().expect("non-empty start table")
    }

    /// GPU characteristic required by this GI (`h_i` in Table 5; the
    /// compatibility constraint of Eq. 17–18 — a GI only lands on a GPU
    /// of the same model).
    #[inline]
    pub fn characteristic(self) -> u32 {
        self.model.characteristic()
    }

    /// Maximum simultaneous instances on one GPU (Table 1).
    #[inline]
    pub fn max_instances(self) -> u8 {
        self.spec().max_instances
    }

    /// Eq. 28: combined compute×memory value used for workload mapping,
    /// normalized within the owning model.
    #[inline]
    pub fn combined_value(self) -> f64 {
        let spec = self.model.spec();
        (self.compute_engines() as f64 / spec.total_compute as f64)
            * (self.size() as f64 / spec.num_blocks as f64)
    }

    /// Canonical NVIDIA profile name (unqualified, e.g. `"2g.10gb"`).
    #[inline]
    pub fn name(self) -> &'static str {
        self.spec().name
    }

    /// Model-qualified name, e.g. `"h100-80:3g.40gb"`. Unambiguous even
    /// where two models share profile names (A100-80 / H100-80).
    pub fn qualified_name(self) -> String {
        format!("{}:{}", self.model.name(), self.name())
    }

    /// Parse a profile name. Bare names (`"2g.10gb"`) resolve against the
    /// A100-40 table (the historical behaviour); model-qualified names
    /// (`"a30:2g.12gb"`) resolve against the named model.
    pub fn parse(s: &str) -> Option<ProfileKey> {
        match s.split_once(':') {
            Some((model, profile)) => {
                let m = GpuModel::parse(model)?;
                m.profile_keys().find(|k| k.name() == profile.trim())
            }
            None => GpuModel::A100_40.profile_keys().find(|k| k.name() == s),
        }
    }

    /// Whether this profile consumes the whole GPU (routes to the heavy
    /// basket in GRMU's dual-basket pooling). Generalizes the A100-only
    /// `== P7g40gb` check to "size equals the model's block count".
    #[inline]
    pub fn is_heavy(self) -> bool {
        self.size() == self.model.num_blocks()
    }
}

/// A100-40 profiles display bare (the historical output format); other
/// models display model-qualified to stay unambiguous.
impl fmt::Display for ProfileKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.model == GpuModel::A100_40 {
            f.write_str(self.name())
        } else {
            write!(f, "{}:{}", self.model.name(), self.name())
        }
    }
}

/// Parse a `--gpu-models` fleet mix like `"a100-40:0.7,h100-80:0.3"`.
/// A bare model name gets weight 1. Returns `(model, weight)` pairs in
/// input order; weights need not sum to 1 (samplers normalize).
pub fn parse_fleet_mix(s: &str) -> Result<Vec<(GpuModel, f64)>, String> {
    let mut out = Vec::new();
    for part in s.split(',').filter(|p| !p.trim().is_empty()) {
        let (name, weight) = match part.rsplit_once(':') {
            Some((name, w)) => {
                let weight: f64 =
                    w.trim().parse().map_err(|_| format!("bad weight in '{part}'"))?;
                (name, weight)
            }
            None => (part, 1.0),
        };
        let model = GpuModel::parse(name).ok_or_else(|| {
            let known: Vec<&str> = ALL_MODELS.iter().map(|m| m.name()).collect();
            format!("unknown GPU model '{}'; known models: {}", name.trim(), known.join(", "))
        })?;
        if !weight.is_finite() || weight <= 0.0 {
            return Err(format!("non-positive weight for '{}'", model.name()));
        }
        out.push((model, weight));
    }
    if out.is_empty() {
        return Err("empty --gpu-models list".into());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_offsets_partition_the_key_space() {
        let mut next = 0usize;
        for m in ALL_MODELS {
            assert_eq!(m.dense_offset(), next, "{m}");
            next += m.num_profiles();
        }
        assert_eq!(next, NUM_PROFILE_KEYS);
        assert!(ALL_MODELS.iter().all(|m| m.num_profiles() <= MAX_MODEL_PROFILES));
    }

    #[test]
    fn a100_40_dense_equals_historical_index() {
        // The determinism contract: A100-40 keys occupy dense 0..6 in
        // historical `Profile::index()` order.
        for (i, k) in GpuModel::A100_40.profile_keys().enumerate() {
            assert_eq!(k.dense(), i);
            assert_eq!(k.index(), i);
        }
        assert_eq!(ProfileKey::P7g40gb.dense(), 5);
    }

    #[test]
    fn dense_roundtrip() {
        for (d, k) in ProfileKey::all().enumerate() {
            assert_eq!(k.dense(), d);
            assert_eq!(ProfileKey::from_dense(d), k);
        }
    }

    #[test]
    fn start_tables_are_legal() {
        for k in ProfileKey::all() {
            let starts = k.start_blocks();
            assert!(!starts.is_empty(), "{k}");
            for w in starts.windows(2) {
                assert!(w[0] < w[1], "{k}: starts not increasing");
            }
            for &s in starts {
                assert!(s + k.size() <= k.model().num_blocks(), "{k}@{s} overflows");
                // Starts align to multiples of the size except the
                // 1-block profiles (the ILP's Eq. 14–15 invariant).
                assert_eq!(s % k.size(), 0, "{k}@{s} misaligned");
            }
            assert_eq!(*starts.last().unwrap(), k.last_start());
        }
    }

    #[test]
    fn a30_geometry() {
        let m = GpuModel::A30;
        assert_eq!(m.num_blocks(), 4);
        assert_eq!(m.total_compute(), 4);
        assert_eq!(m.full_mask(), 0b0000_1111);
        assert_eq!(m.num_profiles(), 3);
        let names: Vec<&str> = m.profile_keys().map(|k| k.name()).collect();
        assert_eq!(names, vec!["1g.6gb", "2g.12gb", "4g.24gb"]);
        // 4g.24gb is the whole part → heavy.
        assert!(m.profile(2).is_heavy());
        assert!(!m.profile(1).is_heavy());
        assert_eq!(m.profile(2).memory_gb(), 24);
        assert!((m.profile(2).combined_value() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn h100_shares_a100_80_geometry_not_identity() {
        let a = GpuModel::A100_80;
        let h = GpuModel::H100_80;
        assert_eq!(a.num_profiles(), h.num_profiles());
        for (ka, kh) in a.profile_keys().zip(h.profile_keys()) {
            assert_eq!(ka.name(), kh.name());
            assert_eq!(ka.size(), kh.size());
            assert_ne!(ka, kh, "keys must stay model-distinct");
            assert_ne!(ka.dense(), kh.dense());
        }
        assert_ne!(a.characteristic(), h.characteristic());
    }

    #[test]
    fn heavy_iff_whole_part() {
        for k in ProfileKey::all() {
            assert_eq!(k.is_heavy(), k.size() == k.model().num_blocks(), "{k}");
        }
        // Exactly one heavy profile per model.
        for m in ALL_MODELS {
            assert_eq!(m.profile_keys().filter(|k| k.is_heavy()).count(), 1, "{m}");
        }
    }

    #[test]
    fn model_parse_roundtrip_and_aliases() {
        for m in ALL_MODELS {
            assert_eq!(GpuModel::parse(m.name()), Some(m));
            assert_eq!(GpuModel::parse(&m.name().to_uppercase()), Some(m));
        }
        assert_eq!(GpuModel::parse("a100"), Some(GpuModel::A100_40));
        assert_eq!(GpuModel::parse("h100"), Some(GpuModel::H100_80));
        assert_eq!(GpuModel::parse("v100"), None);
    }

    #[test]
    fn qualified_parse_and_names() {
        assert_eq!(ProfileKey::parse("1g.5gb"), Some(ProfileKey::P1g5gb));
        assert_eq!(ProfileKey::parse("a30:2g.12gb"), Some(GpuModel::A30.profile(1)));
        let a80 = ProfileKey::parse("a100-80:1g.10gb").unwrap();
        let h80 = ProfileKey::parse("h100-80:1g.10gb").unwrap();
        assert_ne!(a80, h80);
        assert_eq!(h80.qualified_name(), "h100-80:1g.10gb");
        // Bare non-A100-40 names do not resolve (1g.6gb is A30-only).
        assert_eq!(ProfileKey::parse("1g.6gb"), None);
    }

    #[test]
    fn display_qualifies_non_default_models() {
        assert_eq!(ProfileKey::P2g10gb.to_string(), "2g.10gb");
        assert_eq!(GpuModel::A30.profile(0).to_string(), "a30:1g.6gb");
    }

    #[test]
    fn fleet_mix_parsing() {
        let mix = parse_fleet_mix("a30:0.3,a100-40:0.4,h100-80:0.3").unwrap();
        assert_eq!(
            mix,
            vec![
                (GpuModel::A30, 0.3),
                (GpuModel::A100_40, 0.4),
                (GpuModel::H100_80, 0.3)
            ]
        );
        assert_eq!(parse_fleet_mix("a100-40").unwrap(), vec![(GpuModel::A100_40, 1.0)]);
        assert!(parse_fleet_mix("v100:1.0").unwrap_err().contains("known models"));
        assert!(parse_fleet_mix("a30:0").is_err());
        assert!(parse_fleet_mix("").is_err());
    }

    #[test]
    fn combined_values_increase_within_each_model_to_one() {
        for m in ALL_MODELS {
            let mut prev = 0.0;
            for k in m.profile_keys() {
                let v = k.combined_value();
                assert!(v > prev, "{k}: combined value should increase");
                prev = v;
            }
            assert!((prev - 1.0).abs() < 1e-12, "{m}: heavy profile must normalize to 1");
        }
    }
}
