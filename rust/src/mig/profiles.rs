//! A100 GPU-instance profiles and legal placements (Table 1, Table 5, Fig. 1).
//!
//! Naming follows NVIDIA's `Cg.Mgb` convention: `C` compute engines and
//! `M` GB of memory. An A100 has 7 compute engines and 8 memory blocks of
//! 5 GB each. Only memory blocks constrain placement (the paper's
//! block-centric view); compute engines are tracked for Eq. 28's
//! `U_k = compute_k × memory_k` workload mapping.

use std::fmt;

/// Number of memory blocks on an A100.
pub const NUM_BLOCKS: u8 = 8;

/// The six GPU-instance (GI) profiles supported on an A100.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Profile {
    /// MIG 1g.5gb — 1 block, 1 compute engine, up to 7 instances.
    P1g5gb,
    /// MIG 1g.10gb — 2 blocks, 1 compute engine, up to 4 instances.
    P1g10gb,
    /// MIG 2g.10gb — 2 blocks, 2 compute engines, up to 3 instances.
    P2g10gb,
    /// MIG 3g.20gb — 4 blocks, 3 compute engines, up to 2 instances.
    P3g20gb,
    /// MIG 4g.20gb — 4 blocks, 4 compute engines, 1 instance.
    P4g20gb,
    /// MIG 7g.40gb — 8 blocks, 7 compute engines, 1 instance (whole GPU).
    P7g40gb,
}

/// All profiles in Algorithm 1's `startBlocks` table order.
pub const ALL_PROFILES: [Profile; 6] = [
    Profile::P1g5gb,
    Profile::P1g10gb,
    Profile::P2g10gb,
    Profile::P3g20gb,
    Profile::P4g20gb,
    Profile::P7g40gb,
];

impl Profile {
    /// Dense index 0..6 in `ALL_PROFILES` order.
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Profile from dense index.
    pub fn from_index(i: usize) -> Profile {
        ALL_PROFILES[i]
    }

    /// Size in memory blocks (`g_i` in Table 5).
    #[inline]
    pub const fn size(self) -> u8 {
        match self {
            Profile::P1g5gb => 1,
            Profile::P1g10gb | Profile::P2g10gb => 2,
            Profile::P3g20gb | Profile::P4g20gb => 4,
            Profile::P7g40gb => 8,
        }
    }

    /// Number of compute engines (the `C` in `Cg.Mgb`).
    #[inline]
    pub const fn compute_engines(self) -> u8 {
        match self {
            Profile::P1g5gb | Profile::P1g10gb => 1,
            Profile::P2g10gb => 2,
            Profile::P3g20gb => 3,
            Profile::P4g20gb => 4,
            Profile::P7g40gb => 7,
        }
    }

    /// Memory in GB (the `M` in `Cg.Mgb`).
    #[inline]
    pub const fn memory_gb(self) -> u8 {
        self.size() * 5
    }

    /// Legal starting blocks (Algorithm 1's `startBlocks`).
    pub const fn start_blocks(self) -> &'static [u8] {
        match self {
            Profile::P1g5gb => &[0, 1, 2, 3, 4, 5, 6],
            Profile::P1g10gb => &[0, 2, 4, 6],
            Profile::P2g10gb => &[0, 2, 4],
            Profile::P3g20gb => &[0, 4],
            Profile::P4g20gb => &[0],
            Profile::P7g40gb => &[0],
        }
    }

    /// Last permissible starting index (`s_i` in Table 5).
    #[inline]
    pub const fn last_start(self) -> u8 {
        match self {
            Profile::P1g5gb | Profile::P1g10gb => 6,
            Profile::P2g10gb | Profile::P3g20gb => 4,
            Profile::P4g20gb | Profile::P7g40gb => 0,
        }
    }

    /// GPU characteristic required by this GI (`h_i` in Table 5; 100 for
    /// every A100 profile — the compatibility constraint of Eq. 17–18).
    #[inline]
    pub const fn characteristic(self) -> u32 {
        100
    }

    /// Maximum simultaneous instances on one GPU (Table 1).
    #[inline]
    pub const fn max_instances(self) -> u8 {
        match self {
            Profile::P1g5gb => 7,
            Profile::P1g10gb => 4,
            Profile::P2g10gb => 3,
            Profile::P3g20gb => 2,
            Profile::P4g20gb | Profile::P7g40gb => 1,
        }
    }

    /// Eq. 28: combined compute×memory value used for workload mapping.
    #[inline]
    pub fn combined_value(self) -> f64 {
        (self.compute_engines() as f64 / 7.0) * (self.size() as f64 / 8.0)
    }

    /// Canonical NVIDIA profile name.
    pub const fn name(self) -> &'static str {
        match self {
            Profile::P1g5gb => "1g.5gb",
            Profile::P1g10gb => "1g.10gb",
            Profile::P2g10gb => "2g.10gb",
            Profile::P3g20gb => "3g.20gb",
            Profile::P4g20gb => "4g.20gb",
            Profile::P7g40gb => "7g.40gb",
        }
    }

    /// Parse a canonical profile name.
    pub fn parse(s: &str) -> Option<Profile> {
        ALL_PROFILES.iter().copied().find(|p| p.name() == s)
    }

    /// Whether this profile consumes the whole GPU (routes to the heavy
    /// basket in GRMU's dual-basket pooling).
    #[inline]
    pub const fn is_heavy(self) -> bool {
        matches!(self, Profile::P7g40gb)
    }
}

impl fmt::Display for Profile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One legal `(profile, start)` placement with its block mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Placement {
    pub profile: Profile,
    pub start: u8,
}

impl Placement {
    /// Bitmask over the 8 memory blocks this placement occupies.
    #[inline]
    pub const fn mask(self) -> u8 {
        (((1u16 << self.profile.size()) - 1) << self.start) as u8
    }
}

impl fmt::Display for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}", self.profile, self.start)
    }
}

/// All 18 legal placements in Algorithm 1 table order (profiles in
/// `startBlocks` order, starts ascending). Fig. 1's placement diagram.
pub const PLACEMENTS: [Placement; 18] = {
    const fn p(profile: Profile, start: u8) -> Placement {
        Placement { profile, start }
    }
    [
        p(Profile::P1g5gb, 0),
        p(Profile::P1g5gb, 1),
        p(Profile::P1g5gb, 2),
        p(Profile::P1g5gb, 3),
        p(Profile::P1g5gb, 4),
        p(Profile::P1g5gb, 5),
        p(Profile::P1g5gb, 6),
        p(Profile::P1g10gb, 0),
        p(Profile::P1g10gb, 2),
        p(Profile::P1g10gb, 4),
        p(Profile::P1g10gb, 6),
        p(Profile::P2g10gb, 0),
        p(Profile::P2g10gb, 2),
        p(Profile::P2g10gb, 4),
        p(Profile::P3g20gb, 0),
        p(Profile::P3g20gb, 4),
        p(Profile::P4g20gb, 0),
        p(Profile::P7g40gb, 0),
    ]
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_profile_parameters() {
        // (name, mem fraction numerator /8, compute /7, instances)
        let rows = [
            (Profile::P1g5gb, 1, 1, 7),
            (Profile::P1g10gb, 2, 1, 4),
            (Profile::P2g10gb, 2, 2, 3),
            (Profile::P3g20gb, 4, 3, 2),
            (Profile::P4g20gb, 4, 4, 1),
            (Profile::P7g40gb, 8, 7, 1),
        ];
        for (p, mem, ce, inst) in rows {
            assert_eq!(p.size(), mem, "{p}");
            assert_eq!(p.compute_engines(), ce, "{p}");
            assert_eq!(p.max_instances(), inst, "{p}");
        }
    }

    #[test]
    fn table5_gi_si_hi() {
        let rows = [
            (Profile::P1g5gb, 1, 6),
            (Profile::P1g10gb, 2, 6),
            (Profile::P2g10gb, 2, 4),
            (Profile::P3g20gb, 4, 4),
            (Profile::P4g20gb, 4, 0),
            (Profile::P7g40gb, 8, 0),
        ];
        for (p, g, s) in rows {
            assert_eq!(p.size(), g);
            assert_eq!(p.last_start(), s);
            assert_eq!(p.characteristic(), 100);
        }
    }

    #[test]
    fn start_blocks_match_last_start() {
        for p in ALL_PROFILES {
            let starts = p.start_blocks();
            assert_eq!(*starts.last().unwrap(), p.last_start(), "{p}");
            // Starts strictly increasing and within bounds.
            for w in starts.windows(2) {
                assert!(w[0] < w[1]);
            }
            for &s in starts {
                assert!(s + p.size() <= NUM_BLOCKS, "{p}@{s} overflows");
            }
        }
    }

    #[test]
    fn eighteen_placements() {
        assert_eq!(PLACEMENTS.len(), 18);
        // Masks are consistent with profile size/start.
        for pl in PLACEMENTS {
            assert_eq!(pl.mask().count_ones() as u8, pl.profile.size(), "{pl}");
            assert_eq!(pl.mask().trailing_zeros() as u8, pl.start, "{pl}");
        }
        // Ordered by profile then start; no duplicates.
        for w in PLACEMENTS.windows(2) {
            assert!(
                (w[0].profile.index(), w[0].start) < (w[1].profile.index(), w[1].start),
                "{} !< {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn name_roundtrip() {
        for p in ALL_PROFILES {
            assert_eq!(Profile::parse(p.name()), Some(p));
        }
        assert_eq!(Profile::parse("8g.80gb"), None);
    }

    #[test]
    fn combined_value_ordering_eq28() {
        // U_k is strictly increasing with profile "size" on A100.
        let mut prev = 0.0;
        for p in ALL_PROFILES {
            let v = p.combined_value();
            assert!(v > prev, "{p} combined value should increase");
            prev = v;
        }
        assert!((Profile::P7g40gb.combined_value() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn heavy_profile_is_only_7g() {
        for p in ALL_PROFILES {
            assert_eq!(p.is_heavy(), p == Profile::P7g40gb);
        }
    }
}
