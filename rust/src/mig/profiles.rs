//! GPU-instance profiles and legal placements (Table 1, Table 5, Fig. 1).
//!
//! Since the model-catalog redesign the profile tables live in
//! [`super::model`]: [`Profile`] is an alias for the cross-model
//! [`ProfileKey`] and the per-model geometry comes from the
//! [`GpuModel`] catalog. This module keeps the historical A100-40GB
//! surface — [`NUM_BLOCKS`], [`ALL_PROFILES`], the 18-entry
//! [`PLACEMENTS`] table and the `Profile::P1g5gb`-style constants —
//! which the paper's single-model analyses (§5.1) and the trace mapping
//! defaults are written against.
//!
//! Naming follows NVIDIA's `Cg.Mgb` convention: `C` compute engines and
//! `M` GB of memory. Only memory blocks constrain placement (the paper's
//! block-centric view); compute engines are tracked for Eq. 28's
//! `U_k = compute_k × memory_k` workload mapping.

use super::model::GpuModel;
use std::fmt;

pub use super::model::ProfileKey;

/// A GI profile: an alias for the cross-model [`ProfileKey`]. The six
/// A100-40 profiles keep their historical constants
/// (`Profile::P1g5gb` .. `Profile::P7g40gb`).
pub type Profile = ProfileKey;

/// Number of memory blocks on the paper's part (the A100-40GB). Other
/// models carry their own count — see [`GpuModel::num_blocks`].
pub const NUM_BLOCKS: u8 = 8;

/// The six A100-40 GPU-instance profiles in Algorithm 1's `startBlocks`
/// table order (the historical `Profile` enum order; their
/// [`ProfileKey::dense`] indices are 0..6 in this order).
pub const ALL_PROFILES: [Profile; 6] = [
    Profile::P1g5gb,
    Profile::P1g10gb,
    Profile::P2g10gb,
    Profile::P3g20gb,
    Profile::P4g20gb,
    Profile::P7g40gb,
];

/// One legal `(profile, start)` placement with its block mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Placement {
    pub profile: Profile,
    pub start: u8,
}

impl Placement {
    /// Bitmask over the model's memory blocks this placement occupies.
    #[inline]
    pub fn mask(self) -> u8 {
        (((1u16 << self.profile.size()) - 1) << self.start) as u8
    }
}

impl fmt::Display for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}", self.profile, self.start)
    }
}

/// All legal placements of one model in Algorithm 1 table order
/// (profiles in `startBlocks` order, starts ascending). The A100-40
/// yields the paper's 18 placements of Fig. 1.
pub fn placements_for(model: GpuModel) -> Vec<Placement> {
    model
        .profile_keys()
        .flat_map(|profile| {
            profile.start_blocks().iter().map(move |&start| Placement { profile, start })
        })
        .collect()
}

/// The A100-40's 18 legal placements (Fig. 1's placement diagram).
pub const PLACEMENTS: [Placement; 18] = {
    const fn p(profile: Profile, start: u8) -> Placement {
        Placement { profile, start }
    }
    [
        p(Profile::P1g5gb, 0),
        p(Profile::P1g5gb, 1),
        p(Profile::P1g5gb, 2),
        p(Profile::P1g5gb, 3),
        p(Profile::P1g5gb, 4),
        p(Profile::P1g5gb, 5),
        p(Profile::P1g5gb, 6),
        p(Profile::P1g10gb, 0),
        p(Profile::P1g10gb, 2),
        p(Profile::P1g10gb, 4),
        p(Profile::P1g10gb, 6),
        p(Profile::P2g10gb, 0),
        p(Profile::P2g10gb, 2),
        p(Profile::P2g10gb, 4),
        p(Profile::P3g20gb, 0),
        p(Profile::P3g20gb, 4),
        p(Profile::P4g20gb, 0),
        p(Profile::P7g40gb, 0),
    ]
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_profile_parameters() {
        // (name, mem fraction numerator /8, compute /7, instances)
        let rows = [
            (Profile::P1g5gb, 1, 1, 7),
            (Profile::P1g10gb, 2, 1, 4),
            (Profile::P2g10gb, 2, 2, 3),
            (Profile::P3g20gb, 4, 3, 2),
            (Profile::P4g20gb, 4, 4, 1),
            (Profile::P7g40gb, 8, 7, 1),
        ];
        for (p, mem, ce, inst) in rows {
            assert_eq!(p.size(), mem, "{p}");
            assert_eq!(p.compute_engines(), ce, "{p}");
            assert_eq!(p.max_instances(), inst, "{p}");
        }
    }

    #[test]
    fn table5_gi_si_hi() {
        let rows = [
            (Profile::P1g5gb, 1, 6),
            (Profile::P1g10gb, 2, 6),
            (Profile::P2g10gb, 2, 4),
            (Profile::P3g20gb, 4, 4),
            (Profile::P4g20gb, 4, 0),
            (Profile::P7g40gb, 8, 0),
        ];
        for (p, g, s) in rows {
            assert_eq!(p.size(), g);
            assert_eq!(p.last_start(), s);
            assert_eq!(p.characteristic(), 100);
        }
    }

    #[test]
    fn start_blocks_match_last_start() {
        for p in ALL_PROFILES {
            let starts = p.start_blocks();
            assert_eq!(*starts.last().unwrap(), p.last_start(), "{p}");
            // Starts strictly increasing and within bounds.
            for w in starts.windows(2) {
                assert!(w[0] < w[1]);
            }
            for &s in starts {
                assert!(s + p.size() <= NUM_BLOCKS, "{p}@{s} overflows");
            }
        }
    }

    #[test]
    fn eighteen_placements() {
        assert_eq!(PLACEMENTS.len(), 18);
        // Masks are consistent with profile size/start.
        for pl in PLACEMENTS {
            assert_eq!(pl.mask().count_ones() as u8, pl.profile.size(), "{pl}");
            assert_eq!(pl.mask().trailing_zeros() as u8, pl.start, "{pl}");
        }
        // Ordered by profile then start; no duplicates.
        for w in PLACEMENTS.windows(2) {
            assert!(
                (w[0].profile.index(), w[0].start) < (w[1].profile.index(), w[1].start),
                "{} !< {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn catalog_placements_match_the_historical_table() {
        // The generated A100-40 placement list is exactly the hardcoded
        // PLACEMENTS constant (part of the catalog's golden lock).
        assert_eq!(placements_for(GpuModel::A100_40), PLACEMENTS.to_vec());
        // Per-model placement counts: Σ per-profile start counts.
        assert_eq!(placements_for(GpuModel::A30).len(), 4 + 2 + 1);
        assert_eq!(placements_for(GpuModel::H100_80).len(), 18);
    }

    #[test]
    fn name_roundtrip() {
        for p in ALL_PROFILES {
            assert_eq!(Profile::parse(p.name()), Some(p));
        }
        assert_eq!(Profile::parse("8g.80gb"), None);
    }

    #[test]
    fn combined_value_ordering_eq28() {
        // U_k is strictly increasing with profile "size" on A100.
        let mut prev = 0.0;
        for p in ALL_PROFILES {
            let v = p.combined_value();
            assert!(v > prev, "{p} combined value should increase");
            prev = v;
        }
        assert!((Profile::P7g40gb.combined_value() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn heavy_profile_is_only_7g() {
        for p in ALL_PROFILES {
            assert_eq!(p.is_heavy(), p == Profile::P7g40gb);
        }
    }
}
