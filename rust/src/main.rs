//! `repro` — the GRMU reproduction CLI.
//!
//! Subcommands:
//!
//! * `simulate` — run one policy over a (synthetic or CSV) trace and
//!   print the §8 metrics.
//! * `figures` — regenerate the paper's figures/tables
//!   (`--fig 5|6|7|8|9|10|11|12`, `--table 6`, or `--all`).
//! * `analyze` — the §5.1 configuration-space analysis
//!   (`--two-gpu` for the 261,726-pair sweep).
//! * `sweep` — parallel multi-seed × multi-policy sweep (scoped
//!   threads), one `SimResult` per `(seed, policy)` cell plus per-policy
//!   mean ± std summaries.
//! * `trace` — emit the synthetic workload as CSV (the loader's format).
//! * `serve` — run the online placement coordinator on a trace replay,
//!   optionally scoring through the AOT-compiled XLA artifact.
//!
//! Run `repro help` for flags.

use grmu::coordinator;
use grmu::mig::config_space;
use grmu::policies::PolicyRegistry;
use grmu::report::{experiments, tables};
use grmu::trace::{loader, TraceConfig, Workload};
use grmu::util::cli::Args;
use grmu::util::json::Json;

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("figures") => cmd_figures(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("ablate") => cmd_ablate(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("trace") => cmd_trace(&args),
        Some("serve") => coordinator::cli::run(&args),
        _ => print_help(),
    }
}

fn cmd_ablate(args: &Args) {
    let cfg = experiment_config(args);
    let workload = load_workload(args, &cfg);
    let rows = experiments::grmu_ablation(&workload, &cfg);
    println!("GRMU component ablation (heavy basket {:.0}%):", 100.0 * cfg.heavy_frac);
    println!(
        "{:<36} {:>12} {:>16} {:>8} {:>8}",
        "variant", "acceptance", "avg active hw", "intra", "inter"
    );
    for (label, r) in &rows {
        println!(
            "{label:<36} {:>12.4} {:>16.4} {:>8} {:>8}",
            r.overall_acceptance(),
            r.average_active_rate(),
            r.intra_migrations(),
            r.inter_migrations()
        );
    }
}

fn print_help() {
    let registry = PolicyRegistry::standard();
    println!(
        "repro — GRMU paper reproduction\n\
         \n\
         USAGE: repro <command> [flags]\n\
         \n\
         COMMANDS:\n\
           simulate  --policy NAME [--seed N] [--hosts N] [--pods N]\n\
                     [--heavy-frac 0.3] [--consolidation HOURS] [--trace FILE.csv]\n\
                     [--gpu-models a100-40:0.7,h100-80:0.3] [--planners defrag,consolidate]\n\
                     [--migration-budget N[:per-vm]] [--shards N] [--shard-threads N]\n\
                     [--shard-rebalance HOURS] [--shard-rebalance-planner NAME]\n\
                     [--ilp-window K] [--ilp-nodes N] [--ilp-period HOURS]\n\
                     [--gap-every HOURS] [--checkpoint-every H --checkpoint-dir DIR]\n\
                     [--resume DIR] [--on-corruption MODE] [--use-index true|false]\n\
                     [ops flags] [--quick] [--json FILE]\n\
           figures   --fig 5..12 | --table 6 | --all  [--quick] [--seed N] [--json FILE]\n\
           analyze   [--two-gpu]          §5.1 configuration-space statistics
           ablate    [--heavy-frac F]     GRMU component ablation\n\
           sweep     [--seeds 1,2,3] [--policies ff,grmu,mcc+defrag] [--threads N]\n\
                     [--mix ..] [--duration-mu F] [--gpu-models a30:0.3,a100-40:0.7]\n\
                     [--planners ..] [--migration-budget N[:per-vm]] [--gap-every HOURS]\n\
                     [--quick] [--json FILE]   parallel seeds × policies sweep\n\
                     --mtbf-axis 0,500,250 [--drain-axis 0,2]   availability sweep instead\n\
           trace     [--seed N] [--out FILE.csv]      dump the synthetic trace\n\
           serve     --policy NAME [--scorer native|xla] [--quick]   online coordinator\n\
         \n\
         OPS FLAGS (fault/maintenance model + admission queue; off by default):\n\
           --mtbf HOURS|model:h,..   per-GPU mean time between failures\n\
           --mttr HOURS              GPU repair time (default 4)\n\
           --host-mtbf HOURS / --host-mttr HOURS   whole-host failures\n\
           --drain-rate R            maintenance drains per host per 1000 h\n\
           --drain-hours H           drain duration (default 2)\n\
           --ban-after N             blocklist a GPU after N failures\n\
           --blast-radius P          probability a host failure co-fails its domain\n\
           --blast-hosts N           hosts per blast domain (default: shard size)\n\
           --queue-cap N             admission retry queue capacity\n\
           --queue-ttl HOURS         queued-request time-to-live (default 24)\n\
           --preempt                 high-tier arrivals may preempt low-tier VMs\n\
           --arrival-process P       diurnal | bursty | flash-crowd\n\
           --priority-frac F         share of VMs promoted to the high tier\n\
         \n\
         RECOVERY FLAGS (crash-safe checkpoint/journal; off by default):\n\
           --checkpoint-every H      snapshot the engine state every H simulated hours\n\
           --checkpoint-dir DIR      where snapshots + interval journal are written\n\
           --resume DIR              resume from the latest valid snapshot in DIR\n\
           --on-corruption M         abort | quarantine | rebuild on integrity failure\n\
         \n\
         GPU MODELS: a100-40 (default) | a30 | a100-80 | h100-80\n\
         \n\
         POLICIES:"
    );
    for e in registry.entries() {
        println!("           {:<8} {}", e.name, e.summary);
    }
    println!(
        "\n         PLANNERS (compose as base+planner, e.g. mcc+defrag, bf+consolidate,\n\
         or via --planners; budgeted by --migration-budget):\n\
           {:<14} Algorithm 4: re-pack the most fragmented GPU on rejection\n\
           {:<14} Algorithm 5: merge half-full single-profile GPU pairs periodically\n\
           {:<14} drain the most fragmented GPUs when mean fragmentation crosses a threshold\n\
           {:<14} bounded exact repair of the most fragmented window per model\n\
           {:<14} (--ilp-window/--ilp-nodes/--ilp-period; 0 nodes or window = off)",
        "defrag", "consolidate", "frag-gradient", "ilp-repair", ""
    );
}

fn experiment_config(args: &Args) -> experiments::ExperimentConfig {
    let seed = args.num_or("seed", 42u64);
    let mut cfg = if args.flag("quick") {
        experiments::ExperimentConfig::quick(seed)
    } else {
        experiments::ExperimentConfig::default()
    };
    cfg.trace.seed = seed;
    cfg.trace.num_hosts = args.num_or("hosts", cfg.trace.num_hosts);
    cfg.trace.num_pods = args.num_or("pods", cfg.trace.num_pods);
    cfg.heavy_frac = args.num_or("heavy-frac", cfg.heavy_frac);
    cfg.trace.duration_mu = args.num_or("duration-mu", cfg.trace.duration_mu);
    cfg.trace.duration_sigma = args.num_or("duration-sigma", cfg.trace.duration_sigma);
    if let Some(w) = args.get("gpu-weights") {
        let ws: Vec<f64> = w.split(',').map(|x| x.parse().expect("gpu weight")).collect();
        assert_eq!(ws.len(), 8, "--gpu-weights needs 8 comma-separated values");
        cfg.trace.host_gpu_weights.copy_from_slice(&ws);
    }
    if let Some(m) = args.get("mix") {
        let ms: Vec<f64> = m.split(',').map(|x| x.parse().expect("mix weight")).collect();
        assert_eq!(ms.len(), 6, "--mix needs 6 comma-separated values");
        cfg.trace.profile_mix.copy_from_slice(&ms);
    }
    if let Some(h) = args.get("consolidation") {
        cfg.consolidation_hours = h.parse().ok();
    }
    if let Some(models) = args.get("gpu-models") {
        match grmu::mig::parse_fleet_mix(models) {
            Ok(mix) => cfg.trace.gpu_models = mix,
            Err(e) => {
                eprintln!("--gpu-models: {e}");
                std::process::exit(2);
            }
        }
    }
    cfg.planners = args.list_or("planners", &[]);
    if let Some(b) = args.get("migration-budget") {
        match grmu::migrate::MigrationBudget::parse(b) {
            Ok(budget) => cfg.migration_budget = budget,
            Err(e) => {
                eprintln!("--migration-budget: {e}");
                std::process::exit(2);
            }
        }
    }
    if let Some(p) = args.get("arrival-process") {
        match grmu::trace::ArrivalProcess::parse(p) {
            Some(ap) => cfg.trace.arrival_process = ap,
            None => {
                eprintln!("--arrival-process: unknown shape '{p}' (diurnal | bursty | flash-crowd)");
                std::process::exit(2);
            }
        }
    }
    cfg.trace.priority_frac = args.num_or("priority-frac", cfg.trace.priority_frac);
    // --mtbf takes a fleet-wide scalar (hours) or per-model pairs in the
    // --gpu-models syntax: `--mtbf a100-40:500,h100-80:900`.
    if let Some(m) = args.get("mtbf") {
        if let Ok(hours) = m.parse::<f64>() {
            cfg.ops = cfg.ops.clone().with_gpu_mtbf(hours);
        } else {
            match grmu::mig::parse_fleet_mix(m) {
                Ok(pairs) => {
                    for (model, hours) in pairs {
                        cfg.ops.gpu_mtbf_hours[model as usize] = hours;
                    }
                }
                Err(e) => {
                    eprintln!("--mtbf: {e}");
                    std::process::exit(2);
                }
            }
        }
    }
    cfg.ops.gpu_mttr_hours = args.num_or("mttr", cfg.ops.gpu_mttr_hours);
    cfg.ops.host_mtbf_hours = args.num_or("host-mtbf", cfg.ops.host_mtbf_hours);
    cfg.ops.host_mttr_hours = args.num_or("host-mttr", cfg.ops.host_mttr_hours);
    cfg.ops.drain_rate = args.num_or("drain-rate", cfg.ops.drain_rate);
    cfg.ops.drain_hours = args.num_or("drain-hours", cfg.ops.drain_hours);
    cfg.ops.ban_after_failures = args.num_or("ban-after", cfg.ops.ban_after_failures);
    cfg.queue.capacity = args.num_or("queue-cap", cfg.queue.capacity);
    cfg.queue.ttl_hours = args.num_or("queue-ttl", cfg.queue.ttl_hours);
    if args.flag("preempt") {
        cfg.queue.preemption = true;
    }
    cfg.shards = args.num_or("shards", cfg.shards);
    cfg.shard_threads = args.num_or("shard-threads", cfg.shard_threads);
    cfg.shard_rebalance_hours =
        args.num_or("shard-rebalance", cfg.shard_rebalance_hours);
    cfg.ilp_window = args.num_or("ilp-window", cfg.ilp_window);
    cfg.ilp_nodes = args.num_or("ilp-nodes", cfg.ilp_nodes);
    cfg.ilp_period_hours = args.num_or("ilp-period", cfg.ilp_period_hours);
    cfg.gap_check_hours = args.num_or("gap-every", cfg.gap_check_hours);
    if let Some(p) = args.get("shard-rebalance-planner") {
        // Validate through the registry: exactly the names accepted as
        // `+` suffixes are accepted here.
        if let Err(e) = PolicyRegistry::standard().build(&format!("ff+{p}"), &cfg.policy_config())
        {
            eprintln!("--shard-rebalance-planner: {e}");
            std::process::exit(2);
        }
        cfg.shard_rebalance_planner = Some(p.to_string());
    }
    cfg.ops.blast_radius = args.num_or("blast-radius", cfg.ops.blast_radius);
    cfg.ops.blast_hosts = args.num_or("blast-hosts", cfg.ops.blast_hosts);
    cfg.checkpoint_every_hours = args.num_or("checkpoint-every", cfg.checkpoint_every_hours);
    cfg.checkpoint_dir = args.get("checkpoint-dir").map(std::path::PathBuf::from);
    cfg.resume_from = args.get("resume").map(std::path::PathBuf::from);
    if let Some(mode) = args.get("on-corruption") {
        match grmu::recover::OnCorruption::parse(mode) {
            Ok(action) => cfg.on_corruption = action,
            Err(e) => {
                eprintln!("--on-corruption: {e}");
                std::process::exit(2);
            }
        }
    }
    // Diagnostic escape hatch: `--use-index false` forces the
    // brute-force scan paths the index is locked against.
    if let Some(v) = args.get("use-index") {
        match v {
            "true" | "1" | "on" => cfg.use_index = true,
            "false" | "0" | "off" => cfg.use_index = false,
            other => {
                eprintln!("--use-index: expected true|false, got '{other}'");
                std::process::exit(2);
            }
        }
    }
    cfg
}

fn load_workload(args: &Args, cfg: &experiments::ExperimentConfig) -> Workload {
    match args.get("trace") {
        Some(path) => {
            let (vms, report) =
                loader::load_trace(std::path::Path::new(path)).expect("loading trace CSV");
            // Hosts still come from the generator config (the CSV carries
            // pods only, like the Alibaba release).
            let hosts = Workload::generate(cfg.trace.clone()).hosts;
            Workload { hosts, vms, report, config: cfg.trace.clone() }
        }
        None => Workload::generate(cfg.trace.clone()),
    }
}

fn write_json(args: &Args, json: &Json) {
    if let Some(path) = args.get("json") {
        std::fs::write(path, json.to_string_pretty()).expect("writing JSON");
        eprintln!("wrote {path}");
    }
}

fn cmd_simulate(args: &Args) {
    let cfg = experiment_config(args);
    let policy = args.str_or("policy", "grmu");
    // Validate the name (and any --planners suffixes) up front so typos
    // fail with the accepted list before the (expensive) workload
    // generation.
    if let Err(e) = PolicyRegistry::standard().build(&policy, &cfg.policy_config()) {
        eprintln!("{e}");
        std::process::exit(2);
    }
    let workload = load_workload(args, &cfg);
    eprintln!(
        "workload: {} hosts / {} GPUs / {} VMs (seed {})",
        workload.hosts.len(),
        workload.num_gpus(),
        workload.vms.len(),
        cfg.trace.seed
    );
    if cfg.shards > 1 {
        eprintln!(
            "sharded engine: {} shards, {} worker threads (0 = auto)",
            cfg.shards, cfg.shard_threads
        );
    }
    let result = experiments::run_once(&workload, &policy, &cfg, true);
    println!(
        "policy={} acceptance={:.4} accepted={}/{} avg_active={:.4} auc={:.1} intra={} inter={} wall={:.2}s",
        result.policy,
        result.overall_acceptance(),
        result.accepted,
        result.requested,
        result.average_active_rate(),
        result.active_auc(),
        result.intra_migrations(),
        result.inter_migrations(),
        result.wall_seconds,
    );
    // The paper's §8.3.3 headline: migrated share of accepted VMs, plus
    // the block-weighted overhead per kind.
    println!(
        "  migration overhead: migrated_vms={} ({:.2}% of accepted) cost intra={} inter={} total={}",
        result.migrated_vms(),
        100.0 * result.migrated_vm_share(),
        result.migration_cost(grmu::policies::MigrationKind::Intra),
        result.migration_cost(grmu::policies::MigrationKind::Inter),
        result.total_migration_cost(),
    );
    let rates = result.per_profile_acceptance();
    for p in result.reported_profiles() {
        let d = p.dense();
        println!(
            "  {:<16} requested={:>5} accepted={:>5} rate={:.3}",
            p.to_string(),
            result.per_profile[d].0,
            result.per_profile[d].1,
            rates[d]
        );
    }
    let fleet_models = result.fleet_models();
    if fleet_models.len() > 1 {
        println!("  per-model breakdown:");
        let per_model = result.per_model_requests();
        for m in fleet_models {
            let (req, acc) = per_model[m as usize];
            println!(
                "  {:<9} gpus={:>5} requested={:>5} accepted={:>5} acceptance={:.3} active_gpu_rate={:.3}",
                m.name(),
                result.gpus_by_model[m as usize],
                req,
                acc,
                grmu::sim::metrics::acceptance_rate(acc, req),
                result.model_active_rate(m)
            );
        }
    }
    if result.requested > result.accepted {
        println!("  rejections: {}", grmu::policies::format_reject_counts(&result.rejections));
    }
    if result.migrations() > 0 {
        println!("{}", tables::migration_overhead(std::slice::from_ref(&result)));
    }
    // The ops table only appears when the fault/queue model is on; the
    // JSON export always carries the ops block.
    if cfg.ops.enabled() || cfg.queue.enabled() {
        println!("{}", tables::ops_summary(std::slice::from_ref(&result)));
    }
    if !result.gap_samples.is_empty() {
        println!("{}", tables::optimality_gap(std::slice::from_ref(&result)));
    }
    write_json(args, &result.to_json());
}

fn cmd_sweep(args: &Args) {
    let cfg = experiment_config(args);
    // Fault axes turn the command into the availability sweep: one GRMU
    // run per (MTBF, drain-rate) cell on the configured seed.
    if args.get("mtbf-axis").is_some() || args.get("drain-axis").is_some() {
        cmd_availability_sweep(args, &cfg);
        return;
    }
    let registry = PolicyRegistry::standard();
    let policies: Vec<String> =
        args.list_or("policies", &PolicyRegistry::COMPARISON.map(|s| s.to_string()));
    // Fail on typos (names, suffixes, --planners) before any (expensive)
    // workload generation.
    for p in &policies {
        if let Err(e) = registry.build(p, &cfg.policy_config()) {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
    let seeds: Vec<u64> = args.list_or("seeds", &[1u64, 2, 3, 4, 5]);
    let threads: usize = args.num_or("threads", 0usize);
    eprintln!(
        "sweep: {} seeds × {} policies on {} threads",
        seeds.len(),
        policies.len(),
        if threads == 0 { "auto".to_string() } else { threads.to_string() }
    );
    let t0 = std::time::Instant::now();
    let runs = experiments::sweep(&cfg, &seeds, &policies, threads);
    println!(
        "{:<8} {:<16} {:>12} {:>16} {:>8} {:>8} {:>9} {:>7} {:>7} {:>9}",
        "seed", "policy", "acceptance", "avg active hw", "intra", "inter", "mig cost", "mig%",
        "gap%", "wall"
    );
    for run in &runs {
        // `-` when the run carried no gap meter (--gap-every 0).
        let gap = match run.result.gap_mean() {
            Some(g) => format!("{g:.2}"),
            None => "-".to_string(),
        };
        println!(
            "{:<8} {:<16} {:>12.4} {:>16.4} {:>8} {:>8} {:>9} {:>6.2}% {:>7} {:>8.2}s",
            run.seed,
            run.policy,
            run.result.overall_acceptance(),
            run.result.average_active_rate(),
            run.result.intra_migrations(),
            run.result.inter_migrations(),
            run.result.total_migration_cost(),
            100.0 * run.result.migrated_vm_share(),
            gap,
            run.result.wall_seconds,
        );
    }
    println!("\nper-policy summary over {} seeds (mean ± std):", seeds.len());
    for (policy, acc_mean, acc_std, act_mean, act_std) in experiments::sweep_summary(&runs) {
        println!(
            "{policy:<8} acceptance {acc_mean:.4} ± {acc_std:.4}   \
             avg active hw {act_mean:.4} ± {act_std:.4}"
        );
    }
    if runs.iter().any(|r| !r.result.gap_samples.is_empty()) {
        let results: Vec<grmu::sim::SimResult> =
            runs.iter().map(|r| r.result.clone()).collect();
        println!("\n{}", tables::optimality_gap(&results));
    }
    eprintln!("sweep wall time: {:.2}s", t0.elapsed().as_secs_f64());
    let json = Json::arr(
        runs.iter()
            .map(|run| {
                // The fleet/workload-shape knobs are sweep-wide; the
                // per-cell seed is the sibling field.
                Json::obj(vec![
                    ("seed", run.seed.into()),
                    ("policy", run.policy.as_str().into()),
                    ("fleet", experiments::fleet_json(&cfg)),
                    ("result", run.result.to_json()),
                ])
            })
            .collect(),
    );
    write_json(args, &json);
}

fn cmd_availability_sweep(args: &Args, cfg: &experiments::ExperimentConfig) {
    use grmu::policies::RejectReason;
    let mtbfs: Vec<f64> = args.list_or("mtbf-axis", &[0.0]);
    let drains: Vec<f64> = args.list_or("drain-axis", &[0.0]);
    let workload = load_workload(args, cfg);
    eprintln!(
        "availability sweep: {} MTBF × {} drain cells on seed {}",
        mtbfs.len(),
        drains.len(),
        cfg.trace.seed
    );
    let rows = experiments::availability_sweep(&workload, &mtbfs, &drains, cfg);
    println!(
        "{:<28} {:>12} {:>12} {:>11} {:>9} {:>10} {:>8}",
        "cell", "acceptance", "availability", "interrupted", "preempted", "from queue", "expired"
    );
    for (label, r) in &rows {
        println!(
            "{label:<28} {:>12.4} {:>12.4} {:>11} {:>9} {:>10} {:>8}",
            r.overall_acceptance(),
            r.availability,
            r.interrupted,
            r.preempted,
            r.served_from_queue(),
            r.rejected(RejectReason::Expired),
        );
    }
    let json = Json::arr(
        rows.iter()
            .map(|(label, r)| {
                Json::obj(vec![("label", label.as_str().into()), ("result", r.to_json())])
            })
            .collect(),
    );
    write_json(args, &json);
}

fn cmd_figures(args: &Args) {
    let cfg = experiment_config(args);
    let workload = load_workload(args, &cfg);
    let all = args.flag("all");
    let fig = args.num_or("fig", 0u32);
    let table = args.num_or("table", 0u32);
    let caps = args.list_or("caps", &[0.05, 0.1, 0.15, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8]);
    let intervals = args.list_or("intervals", &[6u64, 12, 24, 48, 96]);

    let mut exported: Vec<(&str, Json)> = Vec::new();

    if all || fig == 5 {
        println!("{}", tables::fig5(&workload.report.profile_counts));
    }
    if all || (6..=8).contains(&fig) {
        let sweep = experiments::heavy_capacity_sweep(&workload, &caps, &cfg);
        if all || fig == 6 {
            println!("{}", tables::fig6(&sweep));
        }
        if all || fig == 7 {
            println!("{}", tables::fig7(&sweep));
        }
        if all || fig == 8 {
            println!("{}", tables::fig8(&sweep));
        }
        exported.push((
            "capacity_sweep",
            Json::arr(
                sweep
                    .iter()
                    .map(|(f, r)| {
                        Json::obj(vec![("capacity", (*f).into()), ("result", r.to_json())])
                    })
                    .collect(),
            ),
        ));
    }
    if all || fig == 9 {
        let sweep = experiments::consolidation_sweep(&workload, &intervals, &cfg);
        println!("{}", tables::fig9(&sweep));
        exported.push((
            "consolidation_sweep",
            Json::arr(
                sweep
                    .iter()
                    .map(|(l, r)| {
                        Json::obj(vec![("label", l.as_str().into()), ("result", r.to_json())])
                    })
                    .collect(),
            ),
        ));
    }
    if all || (10..=12).contains(&fig) || table == 6 {
        let results = experiments::policy_comparison(&workload, &cfg);
        if all || fig == 10 {
            println!("{}", tables::fig10(&results));
        }
        if all || fig == 11 {
            println!("{}", tables::fig11(&results));
        }
        if all || fig == 12 {
            println!("{}", tables::fig12(&results));
        }
        if all || table == 6 {
            println!("{}", tables::table6(&results));
            println!("{}", tables::migrations_summary(&results));
            println!("{}", tables::migration_overhead(&results));
            println!("{}", tables::rejections_breakdown(&results));
        }
        exported.push(("policy_comparison", tables::comparison_json(&results)));
    }
    if !exported.is_empty() {
        write_json(
            args,
            &Json::Obj(exported.into_iter().map(|(k, v)| (k.to_string(), v)).collect()),
        );
    }
}

fn cmd_analyze(args: &Args) {
    let with_two = args.flag("two-gpu");
    let stats = config_space::analyze(with_two);
    println!("§5.1 configuration-space analysis (paper values in parentheses)");
    println!("  unique configurations:          {:>7}  (723)", stats.total);
    println!("  maximal configurations:         {:>7}  (78)", stats.maximal);
    println!(
        "  suboptimal arrangements:        {:>7}  (482, 67%) — measured {:.0}%",
        stats.suboptimal,
        100.0 * stats.suboptimal as f64 / stats.total as f64
    );
    println!(
        "  default-policy reachable:       {:>7}  (paper: 248; measured, first-tie)",
        stats.default_reachable
    );
    println!(
        "    of which suboptimal:          {:>7}  (paper: 172)",
        stats.default_reachable_suboptimal
    );
    println!("    reachable (all CC ties):      {:>7}", stats.default_reachable_all_ties);
    println!(
        "  improvable single-GPU configs:  {:>7}  (paper: 138, 19%) — measured {:.0}%",
        stats.improvable,
        100.0 * stats.improvable as f64 / stats.total as f64
    );
    if with_two {
        println!("  two-GPU configurations:         {:>7}  (261,726)", stats.two_gpu_total);
        println!(
            "  improvable two-GPU configs:     {:>7}  (205,575, 79%) — measured {:.0}%",
            stats.two_gpu_improvable,
            100.0 * stats.two_gpu_improvable as f64 / stats.two_gpu_total.max(1) as f64
        );
    }
}

fn cmd_trace(args: &Args) {
    let seed = args.num_or("seed", 42u64);
    let quick = args.flag("quick");
    let config =
        if quick { TraceConfig::small(seed) } else { TraceConfig { seed, ..TraceConfig::default() } };
    let workload = Workload::generate(config);
    let mut csv = String::from("arrival,duration,num_gpus,gpu_frac,cpus,ram_gb\n");
    for vm in &workload.vms {
        // Emit the *mapped* VM back in pod format: one GPU at the
        // profile's normalized fraction (round-trips through the loader).
        let frac = vm.profile.combined_value();
        csv.push_str(&format!(
            "{},{},1,{:.6},{},{}\n",
            vm.arrival,
            vm.departure - vm.arrival,
            frac,
            vm.cpus,
            vm.ram_gb
        ));
    }
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, csv).expect("writing CSV");
            eprintln!("wrote {} VMs to {path}", workload.vms.len());
        }
        None => print!("{csv}"),
    }
}
