//! Plain-text rendering of the paper's tables and figure series.

use crate::mig::profiles::ALL_PROFILES;
use crate::sim::SimResult;
use crate::util::json::Json;

/// Fixed-width row helper.
pub fn row(cells: &[String], widths: &[usize]) -> String {
    cells
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}", w = w))
        .collect::<Vec<_>>()
        .join("  ")
}

/// Fig. 5: the workload's profile distribution.
pub fn fig5(counts: &[usize; 6]) -> String {
    let total: usize = counts.iter().sum();
    let mut out = String::from("Figure 5 — Distribution of profiles in the workload\n");
    out.push_str(&format!("{:<10} {:>8} {:>8}\n", "profile", "count", "share"));
    for (i, p) in ALL_PROFILES.iter().enumerate() {
        out.push_str(&format!(
            "{:<10} {:>8} {:>7.1}%\n",
            p.name(),
            counts[i],
            100.0 * counts[i] as f64 / total.max(1) as f64
        ));
    }
    out.push_str(&format!("{:<10} {:>8}\n", "total", total));
    out
}

/// Fig. 6: average active-hardware rate + overall acceptance per
/// heavy-basket capacity.
pub fn fig6(sweep: &[(f64, SimResult)]) -> String {
    let mut out = String::from(
        "Figure 6 — Impact of heavy basket capacity (DB only: defrag+consolidation off)\n",
    );
    out.push_str(&format!(
        "{:>8} {:>22} {:>24}\n",
        "capacity", "avg active hw rate", "overall acceptance rate"
    ));
    for (frac, r) in sweep {
        out.push_str(&format!(
            "{:>7.0}% {:>21.4} {:>23.4}\n",
            100.0 * frac,
            r.average_active_rate(),
            r.overall_acceptance()
        ));
    }
    out
}

/// Fig. 7: per-profile acceptance across heavy-basket capacities.
pub fn fig7(sweep: &[(f64, SimResult)]) -> String {
    let mut out =
        String::from("Figure 7 — Acceptance of requested profiles across heavy basket capacities\n");
    out.push_str(&format!("{:>8}", "capacity"));
    for p in ALL_PROFILES {
        out.push_str(&format!(" {:>9}", p.name()));
    }
    out.push('\n');
    for (frac, r) in sweep {
        out.push_str(&format!("{:>7.0}%", 100.0 * frac));
        for rate in r.per_profile_acceptance() {
            out.push_str(&format!(" {rate:>9.3}"));
        }
        out.push('\n');
    }
    out
}

/// Fig. 8: overall vs average acceptance rates across capacities.
pub fn fig8(sweep: &[(f64, SimResult)]) -> String {
    let mut out =
        String::from("Figure 8 — Overall vs average acceptance across heavy basket capacities\n");
    out.push_str(&format!("{:>8} {:>10} {:>10}\n", "capacity", "overall", "average"));
    for (frac, r) in sweep {
        out.push_str(&format!(
            "{:>7.0}% {:>10.4} {:>10.4}\n",
            100.0 * frac,
            r.overall_acceptance(),
            r.average_profile_acceptance()
        ));
    }
    out
}

/// Fig. 9: the three objective values per consolidation setting.
pub fn fig9(sweep: &[(String, SimResult)]) -> String {
    let mut out = String::from("Figure 9 — Objective values per consolidation interval\n");
    out.push_str(&format!(
        "{:>9} {:>12} {:>20} {:>12}\n",
        "interval", "acceptance", "avg active hw rate", "migrations"
    ));
    for (label, r) in sweep {
        out.push_str(&format!(
            "{:>9} {:>12.4} {:>20.4} {:>12}\n",
            label,
            r.overall_acceptance(),
            r.average_active_rate(),
            r.migrations()
        ));
    }
    out
}

/// Fig. 10: final acceptance rate per policy (+ hourly series length).
pub fn fig10(results: &[SimResult]) -> String {
    let mut out = String::from("Figure 10 — Acceptance rates by policy\n");
    out.push_str(&format!("{:>6} {:>12} {:>10} {:>10}\n", "policy", "acceptance", "accepted", "requested"));
    for r in results {
        out.push_str(&format!(
            "{:>6} {:>12.4} {:>10} {:>10}\n",
            r.policy,
            r.overall_acceptance(),
            r.accepted,
            r.requested
        ));
    }
    out
}

/// Fig. 11: per-profile acceptance per policy.
pub fn fig11(results: &[SimResult]) -> String {
    let mut out = String::from("Figure 11 — Acceptance rates per policy across GPU profiles\n");
    out.push_str(&format!("{:>6}", "policy"));
    for p in ALL_PROFILES {
        out.push_str(&format!(" {:>9}", p.name()));
    }
    out.push('\n');
    for r in results {
        out.push_str(&format!("{:>6}", r.policy));
        for rate in r.per_profile_acceptance() {
            out.push_str(&format!(" {rate:>9.3}"));
        }
        out.push('\n');
    }
    out
}

/// Fig. 12: average active-hardware rate per policy (the series' level).
pub fn fig12(results: &[SimResult]) -> String {
    let mut out = String::from("Figure 12 — Active hardware rates per policy\n");
    out.push_str(&format!("{:>6} {:>20} {:>14}\n", "policy", "avg active hw rate", "peak rate"));
    for r in results {
        let peak = r.samples.iter().map(|s| s.active_rate).fold(0.0, f64::max);
        out.push_str(&format!(
            "{:>6} {:>20.4} {:>14.4}\n",
            r.policy,
            r.average_active_rate(),
            peak
        ));
    }
    out
}

/// Table 6: cumulative active-resource AUC, normalized to the max.
pub fn table6(results: &[SimResult]) -> String {
    let max_auc = results.iter().map(|r| r.active_auc()).fold(0.0, f64::max);
    let mut out = String::from("Table 6 — Cumulative active resource rate\n");
    out.push_str(&format!(
        "{:>6} {:>22} {:>18}\n",
        "policy", "area under the curve", "normalized value"
    ));
    for r in results {
        out.push_str(&format!(
            "{:>6} {:>22.2} {:>18.4}\n",
            r.policy,
            r.active_auc(),
            r.active_auc() / max_auc.max(1e-12)
        ));
    }
    out
}

/// §8.3.3: migration summary (counts derived from the event log).
pub fn migrations_summary(results: &[SimResult]) -> String {
    let mut out = String::from("§8.3.3 — Migrations\n");
    out.push_str(&format!(
        "{:>6} {:>8} {:>8} {:>10} {:>18}\n",
        "policy", "intra", "inter", "total", "share of accepted"
    ));
    for r in results {
        out.push_str(&format!(
            "{:>6} {:>8} {:>8} {:>10} {:>17.2}%\n",
            r.policy,
            r.intra_migrations(),
            r.inter_migrations(),
            r.migrations(),
            100.0 * r.migration_share()
        ));
    }
    out
}

/// Per-reason rejection breakdown — the diagnostic the typed decision
/// API surfaces (CPU/RAM exhaustion vs fragmentation vs quota denial).
pub fn rejections_breakdown(results: &[SimResult]) -> String {
    use crate::policies::RejectReason;
    let mut out = String::from("Rejection breakdown by reason\n");
    out.push_str(&format!("{:>6} {:>10}", "policy", "rejected"));
    for reason in RejectReason::ALL {
        out.push_str(&format!(" {:>14}", reason.name()));
    }
    out.push('\n');
    for r in results {
        out.push_str(&format!("{:>6} {:>10}", r.policy, r.requested - r.accepted));
        for reason in RejectReason::ALL {
            out.push_str(&format!(" {:>14}", r.rejected(reason)));
        }
        out.push('\n');
    }
    out
}

/// JSON export of a policy-comparison run (used by `--json`).
pub fn comparison_json(results: &[SimResult]) -> Json {
    Json::arr(results.iter().map(|r| r.to_json()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Sample;

    fn fake(policy: &str, acc: u64) -> SimResult {
        use crate::cluster::GpuRef;
        use crate::policies::{MigrationEvent, MigrationKind};
        let g = GpuRef { host: 0, gpu: 0 };
        SimResult {
            policy: policy.into(),
            samples: vec![
                Sample { hour: 0, active_rate: 0.5, acceptance_rate: 1.0, resident: 1 },
                Sample { hour: 1, active_rate: 0.7, acceptance_rate: 0.9, resident: 2 },
            ],
            requested: 10,
            accepted: acc,
            per_profile: [(10, acc), (0, 0), (0, 0), (0, 0), (0, 0), (0, 0)],
            rejections: [0, 0, 10 - acc, 0],
            migration_events: vec![MigrationEvent {
                vm: 1,
                from: g,
                to: g,
                kind: MigrationKind::Intra,
            }],
            wall_seconds: 0.0,
        }
    }

    #[test]
    fn renders_all_tables() {
        let results = vec![fake("FF", 5), fake("GRMU", 8)];
        for text in [
            fig10(&results),
            fig11(&results),
            fig12(&results),
            table6(&results),
            migrations_summary(&results),
            rejections_breakdown(&results),
        ] {
            assert!(text.contains("FF"));
            assert!(text.contains("GRMU"));
            assert!(text.lines().count() >= 3);
        }
    }

    #[test]
    fn rejection_breakdown_names_reasons() {
        let text = rejections_breakdown(&[fake("FF", 4)]);
        assert!(text.contains("no_gpu_fit"));
        assert!(text.contains("quota_denied"));
        assert!(text.contains(" 6"), "10 requested - 4 accepted: {text}");
    }

    #[test]
    fn fig5_shares_sum_to_100() {
        let text = fig5(&[10, 0, 30, 20, 0, 40]);
        assert!(text.contains("40.0%"));
        assert!(text.contains("total"));
    }

    #[test]
    fn table6_normalizes_to_max() {
        let results = vec![fake("FF", 5), fake("GRMU", 8)];
        let text = table6(&results);
        // Equal sample curves → both normalized to 1.0.
        assert_eq!(text.matches("1.0000").count(), 2);
    }

    #[test]
    fn sweep_tables_render() {
        let sweep = vec![(0.2, fake("GRMU", 5)), (0.3, fake("GRMU", 6))];
        assert!(fig6(&sweep).contains("20%"));
        assert!(fig7(&sweep).contains("7g.40gb"));
        assert!(fig8(&sweep).contains("30%"));
        let csweep = vec![("DB".to_string(), fake("GRMU", 5))];
        assert!(fig9(&csweep).contains("DB"));
    }
}
