//! Plain-text rendering of the paper's tables and figure series.
//!
//! Heterogeneous fleets: profile-keyed tables always show the paper's
//! six A100-40 columns (bare names, the historical output), and append a
//! model-qualified column for every other catalog profile that saw
//! requests — so A100-only runs render byte-identically to the
//! pre-catalog reports.

use crate::mig::{GpuModel, ProfileKey, NUM_PROFILE_KEYS};
use crate::sim::SimResult;
use crate::util::json::Json;

/// Fixed-width row helper.
pub fn row(cells: &[String], widths: &[usize]) -> String {
    cells
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}", w = w))
        .collect::<Vec<_>>()
        .join("  ")
}

/// Column set for profile-keyed tables: the A100-40 six plus every other
/// key some result requested, as `(key, label, column width)`.
fn profile_columns<'a>(
    results: impl Iterator<Item = &'a SimResult>,
) -> Vec<(ProfileKey, String, usize)> {
    let mut requested = [false; NUM_PROFILE_KEYS];
    for r in results {
        for (d, (req, _)) in r.per_profile.iter().enumerate() {
            requested[d] |= *req > 0;
        }
    }
    ProfileKey::all()
        .filter(|k| k.model() == GpuModel::A100_40 || requested[k.dense()])
        .map(|k| {
            let label = k.to_string();
            let width = label.len().max(9);
            (k, label, width)
        })
        .collect()
}

/// Fig. 5: the workload's profile distribution (dense-keyed counts; the
/// A100-40 rows always print, other models only when present).
pub fn fig5(counts: &[usize; NUM_PROFILE_KEYS]) -> String {
    let total: usize = counts.iter().sum();
    let mut out = String::from("Figure 5 — Distribution of profiles in the workload\n");
    out.push_str(&format!("{:<10} {:>8} {:>8}\n", "profile", "count", "share"));
    for k in ProfileKey::all() {
        let count = counts[k.dense()];
        if k.model() != GpuModel::A100_40 && count == 0 {
            continue;
        }
        let label = k.to_string();
        out.push_str(&format!(
            "{label:<10} {:>8} {:>7.1}%\n",
            count,
            100.0 * count as f64 / total.max(1) as f64
        ));
    }
    out.push_str(&format!("{:<10} {:>8}\n", "total", total));
    out
}

/// Fig. 6: average active-hardware rate + overall acceptance per
/// heavy-basket capacity.
pub fn fig6(sweep: &[(f64, SimResult)]) -> String {
    let mut out = String::from(
        "Figure 6 — Impact of heavy basket capacity (DB only: defrag+consolidation off)\n",
    );
    out.push_str(&format!(
        "{:>8} {:>22} {:>24}\n",
        "capacity", "avg active hw rate", "overall acceptance rate"
    ));
    for (frac, r) in sweep {
        out.push_str(&format!(
            "{:>7.0}% {:>21.4} {:>23.4}\n",
            100.0 * frac,
            r.average_active_rate(),
            r.overall_acceptance()
        ));
    }
    out
}

/// Fig. 7: per-profile acceptance across heavy-basket capacities.
pub fn fig7(sweep: &[(f64, SimResult)]) -> String {
    let cols = profile_columns(sweep.iter().map(|(_, r)| r));
    let mut out =
        String::from("Figure 7 — Acceptance of requested profiles across heavy basket capacities\n");
    out.push_str(&format!("{:>8}", "capacity"));
    for (_, label, width) in &cols {
        let w = *width;
        out.push_str(&format!(" {label:>w$}"));
    }
    out.push('\n');
    for (frac, r) in sweep {
        out.push_str(&format!("{:>7.0}%", 100.0 * frac));
        let rates = r.per_profile_acceptance();
        for (k, _, width) in &cols {
            let w = *width;
            out.push_str(&format!(" {:>w$.3}", rates[k.dense()]));
        }
        out.push('\n');
    }
    out
}

/// Fig. 8: overall vs average acceptance rates across capacities.
pub fn fig8(sweep: &[(f64, SimResult)]) -> String {
    let mut out =
        String::from("Figure 8 — Overall vs average acceptance across heavy basket capacities\n");
    out.push_str(&format!("{:>8} {:>10} {:>10}\n", "capacity", "overall", "average"));
    for (frac, r) in sweep {
        out.push_str(&format!(
            "{:>7.0}% {:>10.4} {:>10.4}\n",
            100.0 * frac,
            r.overall_acceptance(),
            r.average_profile_acceptance()
        ));
    }
    out
}

/// Fig. 9: the three objective values per consolidation setting.
pub fn fig9(sweep: &[(String, SimResult)]) -> String {
    let mut out = String::from("Figure 9 — Objective values per consolidation interval\n");
    out.push_str(&format!(
        "{:>9} {:>12} {:>20} {:>12}\n",
        "interval", "acceptance", "avg active hw rate", "migrations"
    ));
    for (label, r) in sweep {
        out.push_str(&format!(
            "{:>9} {:>12.4} {:>20.4} {:>12}\n",
            label,
            r.overall_acceptance(),
            r.average_active_rate(),
            r.migrations()
        ));
    }
    out
}

/// Fig. 10: final acceptance rate per policy (+ hourly series length).
pub fn fig10(results: &[SimResult]) -> String {
    let mut out = String::from("Figure 10 — Acceptance rates by policy\n");
    out.push_str(&format!("{:>6} {:>12} {:>10} {:>10}\n", "policy", "acceptance", "accepted", "requested"));
    for r in results {
        out.push_str(&format!(
            "{:>6} {:>12.4} {:>10} {:>10}\n",
            r.policy,
            r.overall_acceptance(),
            r.accepted,
            r.requested
        ));
    }
    out
}

/// Fig. 11: per-profile acceptance per policy.
pub fn fig11(results: &[SimResult]) -> String {
    let cols = profile_columns(results.iter());
    let mut out = String::from("Figure 11 — Acceptance rates per policy across GPU profiles\n");
    out.push_str(&format!("{:>6}", "policy"));
    for (_, label, width) in &cols {
        let w = *width;
        out.push_str(&format!(" {label:>w$}"));
    }
    out.push('\n');
    for r in results {
        out.push_str(&format!("{:>6}", r.policy));
        let rates = r.per_profile_acceptance();
        for (k, _, width) in &cols {
            let w = *width;
            out.push_str(&format!(" {:>w$.3}", rates[k.dense()]));
        }
        out.push('\n');
    }
    out
}

/// Per-model fleet breakdown: GPU counts, acceptance and active-GPU
/// rates per catalog model present in the fleet (the heterogeneous-fleet
/// companion of Figs. 10/12; one row per policy × model).
pub fn fleet_breakdown(results: &[SimResult]) -> String {
    let mut out = String::from("Fleet breakdown — per-model acceptance and active GPUs\n");
    out.push_str(&format!(
        "{:>6} {:>9} {:>6} {:>10} {:>10} {:>12} {:>16}\n",
        "policy", "model", "gpus", "requested", "accepted", "acceptance", "active gpu rate"
    ));
    for r in results {
        let per_model = r.per_model_requests();
        for m in r.fleet_models() {
            let (req, acc) = per_model[m as usize];
            out.push_str(&format!(
                "{:>6} {:>9} {:>6} {:>10} {:>10} {:>12.4} {:>16.4}\n",
                r.policy,
                m.name(),
                r.gpus_by_model[m as usize],
                req,
                acc,
                crate::sim::metrics::acceptance_rate(acc, req),
                r.model_active_rate(m)
            ));
        }
    }
    out
}

/// Fig. 12: average active-hardware rate per policy (the series' level).
pub fn fig12(results: &[SimResult]) -> String {
    let mut out = String::from("Figure 12 — Active hardware rates per policy\n");
    out.push_str(&format!("{:>6} {:>20} {:>14}\n", "policy", "avg active hw rate", "peak rate"));
    for r in results {
        let peak = r.samples.iter().map(|s| s.active_rate).fold(0.0, f64::max);
        out.push_str(&format!(
            "{:>6} {:>20.4} {:>14.4}\n",
            r.policy,
            r.average_active_rate(),
            peak
        ));
    }
    out
}

/// Table 6: cumulative active-resource AUC, normalized to the max.
pub fn table6(results: &[SimResult]) -> String {
    let max_auc = results.iter().map(|r| r.active_auc()).fold(0.0, f64::max);
    let mut out = String::from("Table 6 — Cumulative active resource rate\n");
    out.push_str(&format!(
        "{:>6} {:>22} {:>18}\n",
        "policy", "area under the curve", "normalized value"
    ));
    for r in results {
        out.push_str(&format!(
            "{:>6} {:>22.2} {:>18.4}\n",
            r.policy,
            r.active_auc(),
            r.active_auc() / max_auc.max(1e-12)
        ));
    }
    out
}

/// §8.3.3: migration summary (counts derived from the event log), with
/// the block-weighted overhead column of the third objective.
pub fn migrations_summary(results: &[SimResult]) -> String {
    let mut out = String::from("§8.3.3 — Migrations\n");
    out.push_str(&format!(
        "{:>12} {:>8} {:>8} {:>10} {:>10} {:>18}\n",
        "policy", "intra", "inter", "total", "cost", "share of accepted"
    ));
    for r in results {
        out.push_str(&format!(
            "{:>12} {:>8} {:>8} {:>10} {:>10} {:>17.2}%\n",
            r.policy,
            r.intra_migrations(),
            r.inter_migrations(),
            r.migrations(),
            r.total_migration_cost(),
            100.0 * r.migration_share()
        ));
    }
    out
}

/// Migration overhead per [`crate::policies::MigrationKind`] and GPU
/// model: moves, blocks moved and block-weighted cost, plus the paper's
/// §8.3.3 headline — the migrated share of accepted VMs (each VM counted
/// once) — per policy. Policies without migrations render a single
/// zero-overhead row, so the table always answers "who migrated".
pub fn migration_overhead(results: &[SimResult]) -> String {
    use crate::policies::MigrationKind;
    let mut out = String::from("Migration overhead — block-weighted cost per kind and model\n");
    out.push_str(&format!(
        "{:>12} {:>9} {:>6} {:>8} {:>8} {:>8}\n",
        "policy", "model", "kind", "moves", "blocks", "cost"
    ));
    for r in results {
        let mut any = false;
        for m in r.fleet_models() {
            for kind in MigrationKind::ALL {
                let events = r
                    .migration_events
                    .iter()
                    .filter(|e| e.model == m && e.kind == kind);
                let (mut moves, mut blocks, mut cost) = (0u64, 0u64, 0u64);
                for e in events {
                    moves += 1;
                    blocks += e.blocks as u64;
                    cost += e.cost();
                }
                if moves == 0 {
                    continue;
                }
                any = true;
                out.push_str(&format!(
                    "{:>12} {:>9} {:>6} {:>8} {:>8} {:>8}\n",
                    r.policy,
                    m.name(),
                    kind.name(),
                    moves,
                    blocks,
                    cost
                ));
            }
        }
        if !any {
            out.push_str(&format!(
                "{:>12} {:>9} {:>6} {:>8} {:>8} {:>8}\n",
                r.policy, "-", "-", 0, 0, 0
            ));
        }
        out.push_str(&format!(
            "{:>12} migrated VMs: {} ({:.2}% of accepted; events {:.2}%)\n",
            r.policy,
            r.migrated_vms(),
            100.0 * r.migrated_vm_share(),
            100.0 * r.migration_share(),
        ));
    }
    out
}

/// Per-reason rejection breakdown — the diagnostic the typed decision
/// API surfaces (CPU/RAM exhaustion vs fragmentation vs quota denial).
pub fn rejections_breakdown(results: &[SimResult]) -> String {
    use crate::policies::RejectReason;
    let mut out = String::from("Rejection breakdown by reason\n");
    out.push_str(&format!("{:>6} {:>10}", "policy", "rejected"));
    for reason in RejectReason::ALL {
        out.push_str(&format!(" {:>14}", reason.name()));
    }
    out.push('\n');
    for r in results {
        out.push_str(&format!("{:>6} {:>10}", r.policy, r.requested - r.accepted));
        for reason in RejectReason::ALL {
            out.push_str(&format!(" {:>14}", r.rejected(reason)));
        }
        out.push('\n');
    }
    out
}

/// Ops summary — the fault/queue outcomes of a run: hardware
/// interruptions, preemptions, requests served from the retry queue with
/// their delay percentiles, TTL expiries, and fleet availability
/// (GPU-intervals up / GPU-intervals total). All zeros / 1.0 on a
/// fault-free run with the queue disabled.
pub fn ops_summary(results: &[SimResult]) -> String {
    use crate::policies::RejectReason;
    let mut out = String::from("Ops summary — faults, admission queue and availability\n");
    out.push_str(&format!(
        "{:>6} {:>11} {:>10} {:>12} {:>10} {:>10} {:>8} {:>12}\n",
        "policy", "interrupted", "preempted", "from queue", "delay p50", "delay p99", "expired", "availability"
    ));
    for r in results {
        out.push_str(&format!(
            "{:>6} {:>11} {:>10} {:>12} {:>9}s {:>9}s {:>8} {:>12.4}\n",
            r.policy,
            r.interrupted,
            r.preempted,
            r.served_from_queue(),
            r.queue_delay_p50(),
            r.queue_delay_p99(),
            r.rejected(RejectReason::Expired),
            r.availability
        ));
    }
    out
}

/// Optimality gap — per-policy summary of the online ILP cross-check
/// ([`crate::ilp::online::GapMeter`], `--gap-every`): how many windows
/// were sampled and how far the policy fell short of the bounded exact
/// optimum on them, in percent of the ILP's weighted acceptance.
/// Policies run without the meter render a `-` row.
pub fn optimality_gap(results: &[SimResult]) -> String {
    let mut out = String::from("Optimality gap — policy vs bounded ILP on sampled windows\n");
    out.push_str(&format!(
        "{:>12} {:>8} {:>10} {:>10}\n",
        "policy", "samples", "mean gap", "max gap"
    ));
    for r in results {
        match (r.gap_mean(), r.gap_max()) {
            (Some(mean), Some(max)) => out.push_str(&format!(
                "{:>12} {:>8} {:>9.2}% {:>9.2}%\n",
                r.policy,
                r.gap_samples.len(),
                mean,
                max
            )),
            _ => out.push_str(&format!("{:>12} {:>8} {:>10} {:>10}\n", r.policy, 0, "-", "-")),
        }
    }
    out
}

/// JSON export of a policy-comparison run (used by `--json`).
pub fn comparison_json(results: &[SimResult]) -> Json {
    Json::arr(results.iter().map(|r| r.to_json()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Sample;

    fn fake(policy: &str, acc: u64) -> SimResult {
        use crate::cluster::GpuRef;
        use crate::mig::{NUM_MODELS, NUM_PROFILE_KEYS};
        use crate::policies::{MigrationEvent, MigrationKind};
        let g = GpuRef { host: 0, gpu: 0 };
        let mut per_profile = [(0u64, 0u64); NUM_PROFILE_KEYS];
        per_profile[0] = (10, acc);
        let mut gpus_by_model = [0usize; NUM_MODELS];
        gpus_by_model[GpuModel::A100_40 as usize] = 1;
        let mut gpu_activity = [(0u64, 0u64); NUM_MODELS];
        gpu_activity[GpuModel::A100_40 as usize] = (1, 2);
        SimResult {
            policy: policy.into(),
            samples: vec![
                Sample { hour: 0, active_rate: 0.5, acceptance_rate: 1.0, resident: 1 },
                Sample { hour: 1, active_rate: 0.7, acceptance_rate: 0.9, resident: 2 },
            ],
            requested: 10,
            accepted: acc,
            per_profile,
            rejections: [0, 0, 10 - acc, 0, 0, 0],
            migration_events: vec![MigrationEvent {
                vm: 1,
                from: g,
                to: g,
                kind: MigrationKind::Intra,
                model: GpuModel::A100_40,
                blocks: 1,
            }],
            gpus_by_model,
            gpu_activity,
            interrupted: 0,
            preempted: 0,
            queue_delays: Vec::new(),
            availability: 1.0,
            gap_samples: Vec::new(),
            wall_seconds: 0.0,
        }
    }

    #[test]
    fn renders_all_tables() {
        let results = vec![fake("FF", 5), fake("GRMU", 8)];
        for text in [
            fig10(&results),
            fig11(&results),
            fig12(&results),
            table6(&results),
            migrations_summary(&results),
            rejections_breakdown(&results),
            fleet_breakdown(&results),
            migration_overhead(&results),
            ops_summary(&results),
            optimality_gap(&results),
        ] {
            assert!(text.contains("FF"));
            assert!(text.contains("GRMU"));
            assert!(text.lines().count() >= 3);
        }
    }

    #[test]
    fn optimality_gap_rows_summarize_samples() {
        let mut metered = fake("FF", 5);
        metered.gap_samples = vec![0.0, 3.0, 1.5];
        let unmetered = fake("GRMU", 8);
        let text = optimality_gap(&[metered, unmetered]);
        assert!(text.contains("1.50%"), "{text}");
        assert!(text.contains("3.00%"), "{text}");
        // A run without the meter renders a dash row, not zeros.
        let dash = text.lines().find(|l| l.contains("GRMU")).unwrap();
        assert!(dash.contains('-'), "{text}");
    }

    #[test]
    fn migration_overhead_breaks_down_kind_and_model() {
        use crate::cluster::GpuRef;
        use crate::policies::{MigrationEvent, MigrationKind};
        let mut r = fake("GRMU", 8);
        // Add an inter-GPU A30 move next to the intra A100 one.
        r.gpus_by_model[GpuModel::A30 as usize] = 1;
        r.gpu_activity[GpuModel::A30 as usize] = (1, 2);
        r.migration_events.push(MigrationEvent {
            vm: 2,
            from: GpuRef { host: 0, gpu: 0 },
            to: GpuRef { host: 0, gpu: 1 },
            kind: MigrationKind::Inter,
            model: GpuModel::A30,
            blocks: 2,
        });
        let text = migration_overhead(&[r]);
        assert!(text.contains("a100-40"), "{text}");
        assert!(text.contains("a30"), "{text}");
        assert!(text.contains("intra"), "{text}");
        assert!(text.contains("inter"), "{text}");
        assert!(text.contains("migrated VMs: 2"), "{text}");
        // A migration-free policy still renders a zero row + headline.
        let mut quiet = fake("FF", 5);
        quiet.migration_events.clear();
        let text = migration_overhead(&[quiet]);
        assert!(text.contains("migrated VMs: 0"), "{text}");
    }

    #[test]
    fn mixed_fleet_columns_append_qualified_names() {
        let mut r = fake("FF", 5);
        let k = GpuModel::A30.profile(2); // a30:4g.24gb
        r.per_profile[k.dense()] = (4, 2);
        r.gpus_by_model[GpuModel::A30 as usize] = 1;
        r.gpu_activity[GpuModel::A30 as usize] = (1, 2);
        let text = fig11(&[r.clone()]);
        // The six A100-40 columns stay; the requested A30 key appends.
        assert!(text.contains("7g.40gb"));
        assert!(text.contains("a30:4g.24gb"));
        // Unrequested foreign keys stay hidden.
        assert!(!text.contains("h100-80"));
        let fleet = fleet_breakdown(&[r]);
        assert!(fleet.contains("a30"));
        assert!(fleet.contains("a100-40"));
    }

    #[test]
    fn rejection_breakdown_names_reasons() {
        let text = rejections_breakdown(&[fake("FF", 4)]);
        assert!(text.contains("no_gpu_fit"));
        assert!(text.contains("quota_denied"));
        assert!(text.contains(" 6"), "10 requested - 4 accepted: {text}");
    }

    #[test]
    fn fig5_shares_sum_to_100() {
        let mut counts = [0usize; crate::mig::NUM_PROFILE_KEYS];
        counts[..6].copy_from_slice(&[10, 0, 30, 20, 0, 40]);
        let text = fig5(&counts);
        assert!(text.contains("40.0%"));
        assert!(text.contains("total"));
        // Mixed-fleet rows appear once a foreign model has counts.
        counts[GpuModel::A30.profile(0).dense()] = 5;
        let text = fig5(&counts);
        assert!(text.contains("a30:1g.6gb"));
    }

    #[test]
    fn table6_normalizes_to_max() {
        let results = vec![fake("FF", 5), fake("GRMU", 8)];
        let text = table6(&results);
        // Equal sample curves → both normalized to 1.0.
        assert_eq!(text.matches("1.0000").count(), 2);
    }

    #[test]
    fn sweep_tables_render() {
        let sweep = vec![(0.2, fake("GRMU", 5)), (0.3, fake("GRMU", 6))];
        assert!(fig6(&sweep).contains("20%"));
        assert!(fig7(&sweep).contains("7g.40gb"));
        assert!(fig8(&sweep).contains("30%"));
        let csweep = vec![("DB".to_string(), fake("GRMU", 5))];
        assert!(fig9(&csweep).contains("DB"));
    }
}
