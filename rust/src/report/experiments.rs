//! Parameterized experiment runners behind the figure harness, plus the
//! parallel multi-seed × multi-policy [`sweep`] runner.

use crate::cluster::vm::VmSpec;
use crate::cluster::{DataCenter, Host};
use crate::migrate::MigrationBudget;
use crate::ops::{OpsConfig, QueueConfig};
use crate::policies::{grmu, PolicyConfig, PolicyCtx, PolicyRegistry};
use crate::sim::{ShardedSimulation, SimResult, Simulation, SimulationOptions};
use crate::trace::{TraceConfig, Workload};
use crate::util::stats::{mean, std_dev};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Shared experiment parameters (CLI-controllable).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub trace: TraceConfig,
    /// GRMU heavy-basket share. The paper tunes this per workload via the
    /// Fig. 6–8 sweep and lands on 0.30 for the Alibaba trace; the same
    /// procedure on our synthetic trace lands on 0.15 (see
    /// EXPERIMENTS.md §8.2.1).
    pub heavy_frac: f64,
    /// GRMU consolidation interval in hours (`None` = disabled).
    pub consolidation_hours: Option<u64>,
    /// Cap simulated drain after the last arrival (hours, 0 = none).
    pub drain_cap_hours: u64,
    /// Extra migration planners appended to every built policy
    /// (CLI `--planners defrag,consolidate,frag-gradient`).
    pub planners: Vec<String>,
    /// Planner-stack migration budget (CLI `--migration-budget N[:M]`).
    pub migration_budget: MigrationBudget,
    /// Fault/maintenance model (CLI `--mtbf`, `--drain-rate`, …).
    /// Disabled by default; a zero `seed` inherits the trace seed so
    /// sweep cells stay deterministic per seed.
    pub ops: OpsConfig,
    /// Admission retry queue (CLI `--queue-cap`, `--queue-ttl`,
    /// `--preempt`). Disabled by default.
    pub queue: QueueConfig,
    /// Fleet shards (CLI `--shards`). `1` runs the classic single-core
    /// engine; `> 1` routes through the sharded engine, which places
    /// each interval's batch in parallel across per-shard cores.
    pub shards: usize,
    /// Worker threads for the sharded fan-out (CLI `--shard-threads`,
    /// `0` = available parallelism). Wall-clock only — results are
    /// independent of this by construction.
    pub shard_threads: usize,
    /// Cross-shard consolidation period in hours (CLI
    /// `--shard-rebalance`, `0` = off). Runs under `migration_budget`.
    pub shard_rebalance_hours: u64,
    /// Registry planner driving the cross-shard rebalancer's evacuation
    /// nominations (CLI `--shard-rebalance-planner`, `None` = the
    /// built-in sole-tenant scan). Only consulted when
    /// `shard_rebalance_hours > 0`.
    pub shard_rebalance_planner: Option<String>,
    /// `ilp-repair` extraction window: most-fragmented GPUs per model
    /// (CLI `--ilp-window`, `0` disables the planner).
    pub ilp_window: usize,
    /// Branch-and-bound node budget per ILP solver stage (CLI
    /// `--ilp-nodes`, `0` disables the planner).
    pub ilp_nodes: usize,
    /// `ilp-repair` periodic-run cadence in hours (CLI `--ilp-period`).
    pub ilp_period_hours: u64,
    /// Optimality-gap sampling cadence in hours (CLI `--gap-every`,
    /// `0` = off). Wraps every built policy in a
    /// [`crate::ilp::online::GapMeter`].
    pub gap_check_hours: u64,
    /// Snapshot cadence in hours for crash-safe checkpointing (CLI
    /// `--checkpoint-every`, `0` = snapshots off; the interval journal
    /// is still written whenever a checkpoint directory is set).
    pub checkpoint_every_hours: u64,
    /// Directory for snapshots + interval journal (CLI
    /// `--checkpoint-dir`, `None` = persistence off).
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Resume from the newest valid snapshot in this directory (CLI
    /// `--resume`); the trace and configuration must match the crashed
    /// run.
    pub resume_from: Option<std::path::PathBuf>,
    /// Reaction to a failed integrity check (CLI `--on-corruption
    /// abort|quarantine|rebuild`).
    pub on_corruption: crate::recover::OnCorruption,
    /// Answer placement queries through the incremental cluster index
    /// (CLI `--use-index true|false`, default true). `false` forces the
    /// brute-force full-scan paths — the equivalence oracle the
    /// `decision_api` locks compare against; decisions are
    /// byte-identical either way.
    pub use_index: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            trace: TraceConfig::default(),
            heavy_frac: 0.15,
            consolidation_hours: None,
            drain_cap_hours: 21 * 24,
            planners: Vec::new(),
            migration_budget: MigrationBudget::unlimited(),
            ops: OpsConfig::default(),
            queue: QueueConfig::default(),
            shards: 1,
            shard_threads: 0,
            shard_rebalance_hours: 0,
            shard_rebalance_planner: None,
            ilp_window: 8,
            ilp_nodes: 20_000,
            ilp_period_hours: 24,
            gap_check_hours: 0,
            checkpoint_every_hours: 0,
            checkpoint_dir: None,
            resume_from: None,
            on_corruption: crate::recover::OnCorruption::default(),
            use_index: true,
        }
    }
}

impl ExperimentConfig {
    /// Scaled-down config for tests / `--quick` runs.
    pub fn quick(seed: u64) -> ExperimentConfig {
        ExperimentConfig {
            trace: TraceConfig::small(seed),
            drain_cap_hours: 7 * 24,
            ..ExperimentConfig::default()
        }
    }

    /// The registry-facing policy configuration for these parameters.
    pub fn policy_config(&self) -> PolicyConfig {
        PolicyConfig::new()
            .heavy_frac(self.heavy_frac)
            .consolidation_hours(self.consolidation_hours)
            .planners(self.planners.iter().cloned())
            .migration_budget(self.migration_budget)
            .ilp_window(self.ilp_window)
            .ilp_nodes(self.ilp_nodes)
            .ilp_period_hours(self.ilp_period_hours)
            .gap_check_hours(self.gap_check_hours)
            .use_index(self.use_index)
    }
}

/// Run one policy over the workload. `policy` is a
/// [`PolicyRegistry`] name; `grmu_defrag=false` gives the paper's "DB"
/// (dual-basket only) variant.
pub fn run_once(
    workload: &Workload,
    policy: &str,
    cfg: &ExperimentConfig,
    grmu_defrag: bool,
) -> SimResult {
    run_trace(&workload.hosts, &workload.vms, policy, cfg, grmu_defrag)
}

/// Slice-based core of [`run_once`]: one policy over a trace whose hosts
/// and VM stream may be shared (e.g. `Arc`-held across [`sweep`] cells).
/// Only the data center clones the host states — it mutates them; the VM
/// stream is borrowed for the whole run.
pub fn run_trace(
    hosts: &[Host],
    vms: &[VmSpec],
    policy: &str,
    cfg: &ExperimentConfig,
    grmu_defrag: bool,
) -> SimResult {
    if cfg.shards > 1 {
        return run_sharded_trace(hosts, vms, policy, cfg, grmu_defrag);
    }
    let name = if policy == "grmu" && !grmu_defrag { "grmu-db" } else { policy };
    let policy_box = PolicyRegistry::standard()
        .build(name, &cfg.policy_config())
        .unwrap_or_else(|e| panic!("{e}"));
    let dc = DataCenter::new(hosts.to_vec());
    let mut sim = Simulation::new(dc, policy_box, vms);
    sim.ctx = PolicyCtx::new(cfg.trace.seed);
    sim.options = SimulationOptions {
        drain_cap_hours: cfg.drain_cap_hours,
        ops: resolved_ops(cfg, hosts.len()),
        queue: cfg.queue,
        checkpoint_every_hours: cfg.checkpoint_every_hours,
        checkpoint_dir: cfg.checkpoint_dir.clone(),
        resume_from: cfg.resume_from.clone(),
        on_corruption: cfg.on_corruption,
        ..SimulationOptions::default()
    };
    sim.run()
}

/// The effective fault model for a run: a zero ops seed inherits the
/// trace seed (the injector stream is already decorrelated from the
/// policy RNG by its xor constant, so sweep cells stay deterministic per
/// seed without extra plumbing), and an unset blast domain defaults to
/// the shard size — a pod/rack-sized failure domain on the sharded
/// engine, the whole fleet when unsharded.
fn resolved_ops(cfg: &ExperimentConfig, num_hosts: usize) -> OpsConfig {
    let mut ops = cfg.ops.clone();
    if ops.seed == 0 {
        ops.seed = cfg.trace.seed;
    }
    if ops.blast_radius > 0.0 && ops.blast_hosts == 0 {
        let shards = cfg.shards.clamp(1, num_hosts.max(1));
        ops.blast_hosts = num_hosts.div_ceil(shards).max(1) as u32;
    }
    ops
}

/// Sharded counterpart of [`run_trace`]: always routes through the
/// [`ShardedSimulation`] router (even at `shards == 1`, which the
/// determinism tests exploit to lock router overhead at byte-identity
/// with the classic engine). One identically configured policy instance
/// is built per shard.
pub fn run_sharded_trace(
    hosts: &[Host],
    vms: &[VmSpec],
    policy: &str,
    cfg: &ExperimentConfig,
    grmu_defrag: bool,
) -> SimResult {
    let name = if policy == "grmu" && !grmu_defrag { "grmu-db" } else { policy };
    let shards = cfg.shards.clamp(1, hosts.len().max(1));
    let registry = PolicyRegistry::standard();
    let policies = (0..shards)
        .map(|_| {
            registry.build(name, &cfg.policy_config()).unwrap_or_else(|e| panic!("{e}"))
        })
        .collect();
    let mut sim = ShardedSimulation::new(hosts, policies, vms);
    sim.options = SimulationOptions {
        drain_cap_hours: cfg.drain_cap_hours,
        ops: resolved_ops(cfg, hosts.len()),
        queue: cfg.queue,
        checkpoint_every_hours: cfg.checkpoint_every_hours,
        checkpoint_dir: cfg.checkpoint_dir.clone(),
        resume_from: cfg.resume_from.clone(),
        on_corruption: cfg.on_corruption,
        ..SimulationOptions::default()
    };
    sim.shard_options.shards = shards;
    sim.shard_options.threads = cfg.shard_threads;
    sim.shard_options.seed = cfg.trace.seed;
    sim.shard_options.rebalance_every = cfg.shard_rebalance_hours;
    sim.shard_options.budget = cfg.migration_budget;
    sim.shard_options.rebalance_planner = cfg.shard_rebalance_planner.clone();
    sim.planner_config = cfg.policy_config();
    sim.run()
}

/// [`run_once`] through the sharded router regardless of `cfg.shards`.
pub fn run_sharded(
    workload: &Workload,
    policy: &str,
    cfg: &ExperimentConfig,
    grmu_defrag: bool,
) -> SimResult {
    run_sharded_trace(&workload.hosts, &workload.vms, policy, cfg, grmu_defrag)
}

/// Figs. 6–8: sweep the heavy-basket capacity with defragmentation and
/// consolidation disabled (the paper isolates Dual-Basket Pooling).
/// Returns `(capacity_fraction, result)` pairs.
pub fn heavy_capacity_sweep(
    workload: &Workload,
    caps: &[f64],
    cfg: &ExperimentConfig,
) -> Vec<(f64, SimResult)> {
    caps.iter()
        .map(|&frac| {
            let cfg = ExperimentConfig {
                heavy_frac: frac,
                consolidation_hours: None,
                ..cfg.clone()
            };
            (frac, run_once(workload, "grmu", &cfg, false))
        })
        .collect()
}

/// Fig. 9 points: `DB` (dual-basket only), `Disabled` (defrag, no
/// consolidation) and each consolidation interval. Returns labeled runs.
pub fn consolidation_sweep(
    workload: &Workload,
    intervals_hours: &[u64],
    cfg: &ExperimentConfig,
) -> Vec<(String, SimResult)> {
    let mut out = Vec::new();
    let base =
        ExperimentConfig { consolidation_hours: None, ..cfg.clone() };
    out.push(("DB".to_string(), run_once(workload, "grmu", &base, false)));
    out.push(("Disabled".to_string(), run_once(workload, "grmu", &base, true)));
    for &h in intervals_hours {
        let c = ExperimentConfig { consolidation_hours: Some(h), ..cfg.clone() };
        out.push((format!("{h}h"), run_once(workload, "grmu", &c, true)));
    }
    out
}

/// §8.3: the five-policy comparison (Figs. 10–12, Table 6).
pub fn policy_comparison(workload: &Workload, cfg: &ExperimentConfig) -> Vec<SimResult> {
    PolicyRegistry::COMPARISON
        .iter()
        .map(|name| run_once(workload, name, cfg, true))
        .collect()
}

/// Component ablation: GRMU with each mechanism enabled incrementally,
/// plus FF as the no-mechanism reference. Quantifies what Dual-Basket
/// Pooling, defragmentation and consolidation each contribute (the §7.1
/// design-choice discussion, as an experiment).
pub fn grmu_ablation(workload: &Workload, cfg: &ExperimentConfig) -> Vec<(String, SimResult)> {
    let mut out = Vec::new();
    out.push(("FF (reference)".to_string(), run_once(workload, "ff", cfg, true)));
    let db = ExperimentConfig { consolidation_hours: None, ..cfg.clone() };
    out.push(("DB only".to_string(), run_once(workload, "grmu", &db, false)));
    out.push(("DB + defrag".to_string(), run_once(workload, "grmu", &db, true)));
    let full = ExperimentConfig { consolidation_hours: Some(24), ..cfg.clone() };
    out.push(("DB + defrag + consolidation(24h)".to_string(), run_once(workload, "grmu", &full, true)));
    out
}

/// Planner-stack ablation (EXPERIMENTS.md §Planner stacks): GRMU's
/// built-in migration machinery vs the same planners composed onto the
/// commercial baselines through the registry's `+` variants. Answers
/// "how much of GRMU's edge is the baskets vs the migrations" — the
/// question the extraction of `crate::migrate` makes askable.
pub fn planner_stack_ablation(
    workload: &Workload,
    cfg: &ExperimentConfig,
) -> Vec<(String, SimResult)> {
    ["grmu", "ff", "ff+defrag", "ff+consolidate", "mcc+defrag", "bf+consolidate"]
        .iter()
        .map(|name| (name.to_string(), run_once(workload, name, cfg, true)))
        .collect()
}

/// EXPERIMENTS.md §Availability: GRMU under an escalating fault model.
/// One labeled run per `(MTBF, drain rate)` cell, plus a fault-free
/// baseline row, so the acceptance/availability trade-off reads straight
/// off the output. `mtbf_hours` entries of `0.0` disable failures for
/// that cell (useful for a drain-only axis).
pub fn availability_sweep(
    workload: &Workload,
    mtbf_hours: &[f64],
    drain_rates: &[f64],
    cfg: &ExperimentConfig,
) -> Vec<(String, SimResult)> {
    let mut out = Vec::new();
    let base = ExperimentConfig { ops: OpsConfig::default(), ..cfg.clone() };
    out.push(("no faults".to_string(), run_once(workload, "grmu", &base, true)));
    for &mtbf in mtbf_hours {
        for &drain in drain_rates {
            let ops = OpsConfig {
                drain_rate: drain,
                ..cfg.ops.clone().with_gpu_mtbf(mtbf)
            };
            if !ops.enabled() {
                continue; // the (0, 0) cell duplicates the baseline
            }
            let cell = ExperimentConfig { ops, ..cfg.clone() };
            let label = format!("mtbf={mtbf}h drain={drain}/kh");
            out.push((label, run_once(workload, "grmu", &cell, true)));
        }
    }
    out
}

/// One `(seed, policy)` cell of a [`sweep`].
#[derive(Debug, Clone)]
pub struct SweepRun {
    pub seed: u64,
    pub policy: String,
    pub result: SimResult,
}

/// Parallel multi-seed × multi-policy sweep.
///
/// Workloads are generated once per seed (each seed reconfigures
/// `base.trace`) on the worker pool and held as `Arc<[Host]>` /
/// `Arc<[VmSpec]>` — every `(seed, policy)` cell holds a handle to its
/// seed's trace, so a cell is self-contained and never copies the VM
/// stream (only the cell's `DataCenter` clones the host *states*, which
/// it mutates). Cells run
/// as independent simulations pulled from a shared work queue by
/// `std::thread::scope` workers — no external dependencies, and the
/// per-run determinism (seeded trace + seeded `PolicyCtx`) makes the
/// output independent of thread interleaving. `threads = 0` uses the
/// machine's available parallelism. Results return in seed-major,
/// policy-minor order.
///
/// Panics (after joining all workers) if `policies` contains a name the
/// [`PolicyRegistry`] does not know.
pub fn sweep(
    base: &ExperimentConfig,
    seeds: &[u64],
    policies: &[String],
    threads: usize,
) -> Vec<SweepRun> {
    type SharedTrace = (Arc<[Host]>, Arc<[VmSpec]>);
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    };
    let seed_cfgs: Vec<ExperimentConfig> = seeds
        .iter()
        .map(|&seed| {
            let mut cfg = base.clone();
            cfg.trace.seed = seed;
            cfg
        })
        .collect();
    // Per-seed workload synthesis is the expensive part of startup and
    // every seed is independent — generate on the worker pool too.
    let generated: Vec<Mutex<Option<SharedTrace>>> =
        seeds.iter().map(|_| Mutex::new(None)).collect();
    let next_gen = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(seed_cfgs.len()).max(1) {
            scope.spawn(|| loop {
                let i = next_gen.fetch_add(1, Ordering::Relaxed);
                if i >= seed_cfgs.len() {
                    break;
                }
                let workload = Workload::generate(seed_cfgs[i].trace.clone());
                *generated[i].lock().unwrap() =
                    Some((Arc::from(workload.hosts), Arc::from(workload.vms)));
            });
        }
    });
    let workloads: Vec<SharedTrace> = generated
        .into_iter()
        .map(|cell| cell.into_inner().unwrap().expect("workload generated"))
        .collect();
    let tasks: Vec<(usize, &str)> = (0..workloads.len())
        .flat_map(|wi| policies.iter().map(move |p| (wi, p.as_str())))
        .collect();
    let next = AtomicUsize::new(0);
    let cells: Vec<Mutex<Option<SimResult>>> = tasks.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads.min(tasks.len()).max(1) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= tasks.len() {
                    break;
                }
                let (wi, policy) = tasks[i];
                // Arc handles: the cell shares its seed's generated
                // hosts and VM stream without copying either.
                let (hosts, vms) = (workloads[wi].0.clone(), workloads[wi].1.clone());
                let result = run_trace(&hosts, &vms, policy, &seed_cfgs[wi], true);
                *cells[i].lock().unwrap() = Some(result);
            });
        }
    });
    tasks
        .iter()
        .zip(cells)
        .map(|(&(wi, policy), cell)| SweepRun {
            seed: seeds[wi],
            policy: policy.to_string(),
            result: cell.into_inner().unwrap().expect("sweep cell filled"),
        })
        .collect()
}

/// Per-policy summary row of a sweep: `(policy, mean/std overall
/// acceptance, mean/std average active-hardware rate)` across seeds, in
/// first-appearance order.
pub fn sweep_summary(runs: &[SweepRun]) -> Vec<(String, f64, f64, f64, f64)> {
    let mut order: Vec<&str> = Vec::new();
    for run in runs {
        if !order.contains(&run.policy.as_str()) {
            order.push(run.policy.as_str());
        }
    }
    order
        .into_iter()
        .map(|policy| {
            let acc: Vec<f64> = runs
                .iter()
                .filter(|r| r.policy == policy)
                .map(|r| r.result.overall_acceptance())
                .collect();
            let active: Vec<f64> = runs
                .iter()
                .filter(|r| r.policy == policy)
                .map(|r| r.result.average_active_rate())
                .collect();
            (policy.to_string(), mean(&acc), std_dev(&acc), mean(&active), std_dev(&active))
        })
        .collect()
}

/// Per-cell fleet/workload metadata for sweep JSON exports: the
/// workload-shape knobs (`--hosts`, `--pods`, `--mix`, `--duration-mu`)
/// and the `--gpu-models` fleet mix that produced a cell, so a sweep
/// file is self-describing.
pub fn fleet_json(cfg: &ExperimentConfig) -> crate::util::json::Json {
    use crate::util::json::Json;
    let t = &cfg.trace;
    Json::obj(vec![
        ("hosts", (t.num_hosts as u64).into()),
        ("pods", (t.num_pods as u64).into()),
        ("horizon_hours", t.horizon_hours.into()),
        ("duration_mu", t.duration_mu.into()),
        ("duration_sigma", t.duration_sigma.into()),
        ("heavy_frac", cfg.heavy_frac.into()),
        ("shards", (cfg.shards as u64).into()),
        ("profile_mix", Json::arr(t.profile_mix.iter().map(|&m| m.into()).collect())),
        (
            "gpu_models",
            Json::Obj(
                t.gpu_models
                    .iter()
                    .map(|(m, w)| (m.name().to_string(), (*w).into()))
                    .collect(),
            ),
        ),
        (
            "planners",
            Json::arr(cfg.planners.iter().map(|p| p.as_str().into()).collect()),
        ),
        (
            "migration_budget",
            Json::obj(vec![
                ("per_interval", budget_axis(cfg.migration_budget.max_moves_per_interval)),
                ("per_vm", budget_axis(cfg.migration_budget.max_moves_per_vm)),
            ]),
        ),
    ])
}

/// One [`MigrationBudget`] axis as JSON: the `u32::MAX` sentinel renders
/// as `"unlimited"` so exported configs stay human-readable.
fn budget_axis(n: u32) -> crate::util::json::Json {
    if n == u32::MAX {
        "unlimited".into()
    } else {
        (n as u64).into()
    }
}

/// GRMU config helper mirroring [`grmu::GrmuConfig`] from experiment
/// parameters (exposed for examples).
pub fn grmu_config(cfg: &ExperimentConfig, defrag: bool) -> grmu::GrmuConfig {
    grmu::GrmuConfig {
        heavy_capacity_frac: cfg.heavy_frac,
        consolidation_interval_hours: cfg.consolidation_hours,
        defrag_enabled: defrag,
        use_index: cfg.use_index,
        migration_budget: cfg.migration_budget,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;

    fn quick_workload() -> (Workload, ExperimentConfig) {
        let cfg = ExperimentConfig::quick(11);
        let w = Workload::generate(cfg.trace.clone());
        (w, cfg)
    }

    #[test]
    fn all_policies_run_on_small_workload() {
        let (w, cfg) = quick_workload();
        let results = policy_comparison(&w, &cfg);
        assert_eq!(results.len(), 5);
        for r in &results {
            assert_eq!(r.requested, w.vms.len() as u64);
            assert!(r.accepted > 0, "{} accepted nothing", r.policy);
            assert!(r.accepted <= r.requested);
            // The typed rejection breakdown accounts for every refusal.
            assert_eq!(
                r.rejections.iter().sum::<u64>(),
                r.requested - r.accepted,
                "{}: breakdown does not sum",
                r.policy
            );
        }
        // Identical workload across policies: per-profile requested equal.
        for r in &results[1..] {
            for p in 0..6 {
                assert_eq!(r.per_profile[p].0, results[0].per_profile[p].0);
            }
        }
    }

    #[test]
    fn only_grmu_migrates() {
        let (w, cfg) = quick_workload();
        let cfg = ExperimentConfig { consolidation_hours: Some(12), ..cfg };
        for r in policy_comparison(&w, &cfg) {
            if r.policy == "GRMU" {
                continue;
            }
            assert_eq!(r.migrations(), 0, "{} migrated", r.policy);
            assert!(r.migration_events.is_empty());
        }
    }

    #[test]
    fn quota_denials_only_from_grmu() {
        use crate::policies::RejectReason;
        let (w, cfg) = quick_workload();
        for r in policy_comparison(&w, &cfg) {
            if r.policy != "GRMU" {
                assert_eq!(
                    r.rejected(RejectReason::QuotaDenied),
                    0,
                    "{} has no basket quota to deny on",
                    r.policy
                );
            }
        }
    }

    #[test]
    fn capacity_sweep_monotone_heavy_acceptance() {
        let (w, cfg) = quick_workload();
        let sweep = heavy_capacity_sweep(&w, &[0.2, 0.8], &cfg);
        let heavy_idx = Profile::P7g40gb.dense();
        let rate = |r: &SimResult| {
            let (req, acc) = r.per_profile[heavy_idx];
            if req == 0 { 0.0 } else { acc as f64 / req as f64 }
        };
        // More heavy capacity never hurts 7g.40gb acceptance.
        assert!(rate(&sweep[1].1) >= rate(&sweep[0].1));
    }

    #[test]
    fn ablation_rows_complete() {
        let (w, cfg) = quick_workload();
        let rows = grmu_ablation(&w, &cfg);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].0, "FF (reference)");
        // DB-only never migrates; the consolidation row may.
        assert_eq!(rows[1].1.migrations(), 0);
        // All rows saw the same request stream.
        for (_, r) in &rows[1..] {
            assert_eq!(r.requested, rows[0].1.requested);
        }
    }

    #[test]
    fn planner_ablation_rows_complete() {
        let (w, cfg) = quick_workload();
        let rows = planner_stack_ablation(&w, &cfg);
        assert_eq!(rows.len(), 6);
        // Plain FF never migrates; every row's breakdown must sum.
        let ff = rows.iter().find(|(l, _)| l == "ff").unwrap();
        assert_eq!(ff.1.migrations(), 0);
        for (label, r) in &rows {
            assert_eq!(r.requested, rows[0].1.requested, "{label}");
            assert_eq!(
                r.rejections.iter().sum::<u64>(),
                r.requested - r.accepted,
                "{label}: breakdown does not sum"
            );
            // Cost is consistent with the event log by construction.
            assert_eq!(
                r.total_migration_cost(),
                r.migration_events.iter().map(|e| e.cost()).sum::<u64>(),
                "{label}"
            );
        }
        // The composed names flow into the result's policy label.
        assert!(rows.iter().any(|(_, r)| r.policy == "FF+defrag"));
    }

    #[test]
    fn sweep_accepts_composed_policy_names() {
        let base = ExperimentConfig::quick(0);
        let seeds = [5u64];
        let policies: Vec<String> = vec!["ff".into(), "mcc+defrag".into()];
        let runs = sweep(&base, &seeds, &policies, 2);
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[1].policy, "mcc+defrag");
        assert_eq!(runs[1].result.policy, "MCC+defrag");
    }

    #[test]
    fn sweep_runs_every_cell_deterministically() {
        let base = ExperimentConfig::quick(0);
        let seeds = [5u64, 6];
        let policies: Vec<String> = vec!["ff".into(), "grmu".into()];
        let par = sweep(&base, &seeds, &policies, 2);
        assert_eq!(par.len(), 4);
        // Seed-major, policy-minor order.
        let keys: Vec<(u64, &str)> = par.iter().map(|r| (r.seed, r.policy.as_str())).collect();
        assert_eq!(keys, vec![(5, "ff"), (5, "grmu"), (6, "ff"), (6, "grmu")]);
        // Thread count must not affect any result.
        let seq = sweep(&base, &seeds, &policies, 1);
        for (a, b) in par.iter().zip(&seq) {
            assert_eq!(a.result.requested, b.result.requested);
            assert_eq!(a.result.accepted, b.result.accepted);
            assert_eq!(a.result.rejections, b.result.rejections);
            assert_eq!(a.result.samples, b.result.samples);
        }
        // And each cell equals a standalone run on the same seed.
        let mut cfg5 = base.clone();
        cfg5.trace.seed = 5;
        let w5 = Workload::generate(cfg5.trace.clone());
        let solo = run_once(&w5, "ff", &cfg5, true);
        assert_eq!(par[0].result.requested, solo.requested);
        assert_eq!(par[0].result.accepted, solo.accepted);
        // Summary: one row per policy, in first-appearance order.
        let summary = sweep_summary(&par);
        assert_eq!(summary.len(), 2);
        assert_eq!(summary[0].0, "ff");
        assert_eq!(summary[1].0, "grmu");
    }

    #[test]
    fn sharded_run_matches_unsharded_at_one_shard() {
        let (w, cfg) = quick_workload();
        let a = run_once(&w, "grmu", &cfg, true);
        let b = run_sharded(&w, "grmu", &cfg, true); // cfg.shards == 1
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.requested, b.requested);
        assert_eq!(a.accepted, b.accepted);
        assert_eq!(a.rejections, b.rejections);
        assert_eq!(a.per_profile, b.per_profile);
        assert_eq!(a.migration_events, b.migration_events);
    }

    #[test]
    fn sharded_run_keeps_accounting_invariant() {
        let (w, cfg) = quick_workload();
        let cfg = ExperimentConfig { shards: 4, shard_threads: 2, ..cfg };
        let r = run_once(&w, "grmu", &cfg, true); // dispatches to the router
        assert_eq!(r.requested, w.vms.len() as u64);
        assert!(r.accepted > 0);
        assert_eq!(r.rejections.iter().sum::<u64>(), r.requested - r.accepted);
        let (req, acc) = r
            .per_profile
            .iter()
            .fold((0u64, 0u64), |(a, b), (x, y)| (a + x, b + y));
        assert_eq!(req, r.requested);
        assert_eq!(acc, r.accepted);
    }

    #[test]
    fn blast_radius_defaults_to_shard_sized_domains() {
        let cfg = ExperimentConfig {
            shards: 4,
            ops: OpsConfig { blast_radius: 0.5, ..OpsConfig::default() },
            ..ExperimentConfig::quick(3)
        };
        let ops = resolved_ops(&cfg, 100);
        assert_eq!(ops.blast_hosts, 25);
        assert_eq!(ops.seed, 3, "zero ops seed inherits the trace seed");
        // Explicit domains pass through untouched.
        let cfg2 = ExperimentConfig {
            ops: OpsConfig { blast_radius: 0.5, blast_hosts: 8, ..OpsConfig::default() },
            ..cfg
        };
        assert_eq!(resolved_ops(&cfg2, 100).blast_hosts, 8);
    }

    #[test]
    fn ops_config_flows_into_runs() {
        let (w, cfg) = quick_workload();
        let faulty = ExperimentConfig {
            ops: OpsConfig { drain_rate: 1.0, ..OpsConfig::default().with_gpu_mtbf(300.0) },
            queue: QueueConfig { capacity: 16, ..QueueConfig::default() },
            ..cfg.clone()
        };
        let a = run_once(&w, "grmu", &faulty, true);
        let b = run_once(&w, "grmu", &faulty, true);
        assert_eq!(a.samples, b.samples, "faulty runs are deterministic");
        assert_eq!(a.interrupted, b.interrupted);
        assert!(a.availability < 1.0, "300 h MTBF must cost some GPU-hours");
        assert!(a.availability > 0.5);
        // The clean config still reports perfect availability.
        let clean = run_once(&w, "grmu", &cfg, true);
        assert_eq!(clean.availability, 1.0);
        assert_eq!(clean.interrupted, 0);
    }

    #[test]
    fn availability_sweep_rows() {
        let (w, cfg) = quick_workload();
        let rows = availability_sweep(&w, &[0.0, 400.0], &[0.0, 2.0], &cfg);
        let labels: Vec<&str> = rows.iter().map(|(l, _)| l.as_str()).collect();
        // (0, 0) is skipped as a duplicate of the baseline.
        assert_eq!(
            labels,
            vec![
                "no faults",
                "mtbf=0h drain=2/kh",
                "mtbf=400h drain=0/kh",
                "mtbf=400h drain=2/kh"
            ]
        );
        assert_eq!(rows[0].1.availability, 1.0);
        for (label, r) in &rows[1..] {
            assert!(r.availability <= 1.0, "{label}");
            assert_eq!(
                r.rejections.iter().sum::<u64>(),
                r.requested - r.accepted,
                "{label}: breakdown does not sum under faults"
            );
        }
    }

    #[test]
    fn gap_reporting_flows_through_runs() {
        let (w, cfg) = quick_workload();
        let cfg = ExperimentConfig { gap_check_hours: 48, ilp_nodes: 2_000, ..cfg };
        let r = run_once(&w, "ff", &cfg, true);
        assert!(!r.gap_samples.is_empty(), "the meter must sample on its cadence");
        assert!(r.gap_samples.iter().all(|g| (0.0..=100.0).contains(g)), "{:?}", r.gap_samples);
        assert!(r.gap_mean().is_some());
        // The wrapper is transparent to everything but the samples.
        let off = ExperimentConfig { gap_check_hours: 0, ..cfg.clone() };
        let plain = run_once(&w, "ff", &off, true);
        assert_eq!(plain.policy, r.policy);
        assert_eq!(plain.accepted, r.accepted);
        assert_eq!(plain.samples, r.samples);
        assert!(plain.gap_samples.is_empty());
    }

    #[test]
    fn consolidation_sweep_labels() {
        let (w, cfg) = quick_workload();
        let sweep = consolidation_sweep(&w, &[24], &cfg);
        let labels: Vec<&str> = sweep.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["DB", "Disabled", "24h"]);
        // DB performs no migrations at all.
        assert_eq!(sweep[0].1.migrations(), 0);
    }
}
