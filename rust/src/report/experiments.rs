//! Parameterized experiment runners behind the figure harness.

use crate::cluster::DataCenter;
use crate::policies::{grmu, PolicyConfig, PolicyCtx, PolicyRegistry};
use crate::sim::{SimResult, Simulation, SimulationOptions};
use crate::trace::{TraceConfig, Workload};

/// Shared experiment parameters (CLI-controllable).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub trace: TraceConfig,
    /// GRMU heavy-basket share. The paper tunes this per workload via the
    /// Fig. 6–8 sweep and lands on 0.30 for the Alibaba trace; the same
    /// procedure on our synthetic trace lands on 0.15 (see
    /// EXPERIMENTS.md §8.2.1).
    pub heavy_frac: f64,
    /// GRMU consolidation interval in hours (`None` = disabled).
    pub consolidation_hours: Option<u64>,
    /// Cap simulated drain after the last arrival (hours, 0 = none).
    pub drain_cap_hours: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            trace: TraceConfig::default(),
            heavy_frac: 0.15,
            consolidation_hours: None,
            drain_cap_hours: 21 * 24,
        }
    }
}

impl ExperimentConfig {
    /// Scaled-down config for tests / `--quick` runs.
    pub fn quick(seed: u64) -> ExperimentConfig {
        ExperimentConfig {
            trace: TraceConfig::small(seed),
            drain_cap_hours: 7 * 24,
            ..ExperimentConfig::default()
        }
    }

    /// The registry-facing policy configuration for these parameters.
    pub fn policy_config(&self) -> PolicyConfig {
        PolicyConfig::new()
            .heavy_frac(self.heavy_frac)
            .consolidation_hours(self.consolidation_hours)
    }
}

/// Run one policy over the workload. `policy` is a
/// [`PolicyRegistry`] name; `grmu_defrag=false` gives the paper's "DB"
/// (dual-basket only) variant.
pub fn run_once(
    workload: &Workload,
    policy: &str,
    cfg: &ExperimentConfig,
    grmu_defrag: bool,
) -> SimResult {
    let name = if policy == "grmu" && !grmu_defrag { "grmu-db" } else { policy };
    let policy_box = PolicyRegistry::standard()
        .build(name, &cfg.policy_config())
        .unwrap_or_else(|e| panic!("{e}"));
    let dc = DataCenter::new(workload.hosts.clone());
    let mut sim = Simulation::new(dc, policy_box, &workload.vms);
    sim.ctx = PolicyCtx::new(cfg.trace.seed);
    sim.options = SimulationOptions {
        drain_cap_hours: cfg.drain_cap_hours,
        ..SimulationOptions::default()
    };
    sim.run()
}

/// Figs. 6–8: sweep the heavy-basket capacity with defragmentation and
/// consolidation disabled (the paper isolates Dual-Basket Pooling).
/// Returns `(capacity_fraction, result)` pairs.
pub fn heavy_capacity_sweep(
    workload: &Workload,
    caps: &[f64],
    cfg: &ExperimentConfig,
) -> Vec<(f64, SimResult)> {
    caps.iter()
        .map(|&frac| {
            let cfg = ExperimentConfig {
                heavy_frac: frac,
                consolidation_hours: None,
                ..cfg.clone()
            };
            (frac, run_once(workload, "grmu", &cfg, false))
        })
        .collect()
}

/// Fig. 9 points: `DB` (dual-basket only), `Disabled` (defrag, no
/// consolidation) and each consolidation interval. Returns labeled runs.
pub fn consolidation_sweep(
    workload: &Workload,
    intervals_hours: &[u64],
    cfg: &ExperimentConfig,
) -> Vec<(String, SimResult)> {
    let mut out = Vec::new();
    let base =
        ExperimentConfig { consolidation_hours: None, ..cfg.clone() };
    out.push(("DB".to_string(), run_once(workload, "grmu", &base, false)));
    out.push(("Disabled".to_string(), run_once(workload, "grmu", &base, true)));
    for &h in intervals_hours {
        let c = ExperimentConfig { consolidation_hours: Some(h), ..cfg.clone() };
        out.push((format!("{h}h"), run_once(workload, "grmu", &c, true)));
    }
    out
}

/// §8.3: the five-policy comparison (Figs. 10–12, Table 6).
pub fn policy_comparison(workload: &Workload, cfg: &ExperimentConfig) -> Vec<SimResult> {
    PolicyRegistry::COMPARISON
        .iter()
        .map(|name| run_once(workload, name, cfg, true))
        .collect()
}

/// Component ablation: GRMU with each mechanism enabled incrementally,
/// plus FF as the no-mechanism reference. Quantifies what Dual-Basket
/// Pooling, defragmentation and consolidation each contribute (the §7.1
/// design-choice discussion, as an experiment).
pub fn grmu_ablation(workload: &Workload, cfg: &ExperimentConfig) -> Vec<(String, SimResult)> {
    let mut out = Vec::new();
    out.push(("FF (reference)".to_string(), run_once(workload, "ff", cfg, true)));
    let db = ExperimentConfig { consolidation_hours: None, ..cfg.clone() };
    out.push(("DB only".to_string(), run_once(workload, "grmu", &db, false)));
    out.push(("DB + defrag".to_string(), run_once(workload, "grmu", &db, true)));
    let full = ExperimentConfig { consolidation_hours: Some(24), ..cfg.clone() };
    out.push(("DB + defrag + consolidation(24h)".to_string(), run_once(workload, "grmu", &full, true)));
    out
}

/// GRMU config helper mirroring [`grmu::GrmuConfig`] from experiment
/// parameters (exposed for examples).
pub fn grmu_config(cfg: &ExperimentConfig, defrag: bool) -> grmu::GrmuConfig {
    grmu::GrmuConfig {
        heavy_capacity_frac: cfg.heavy_frac,
        consolidation_interval_hours: cfg.consolidation_hours,
        defrag_enabled: defrag,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;

    fn quick_workload() -> (Workload, ExperimentConfig) {
        let cfg = ExperimentConfig::quick(11);
        let w = Workload::generate(cfg.trace.clone());
        (w, cfg)
    }

    #[test]
    fn all_policies_run_on_small_workload() {
        let (w, cfg) = quick_workload();
        let results = policy_comparison(&w, &cfg);
        assert_eq!(results.len(), 5);
        for r in &results {
            assert_eq!(r.requested, w.vms.len() as u64);
            assert!(r.accepted > 0, "{} accepted nothing", r.policy);
            assert!(r.accepted <= r.requested);
            // The typed rejection breakdown accounts for every refusal.
            assert_eq!(
                r.rejections.iter().sum::<u64>(),
                r.requested - r.accepted,
                "{}: breakdown does not sum",
                r.policy
            );
        }
        // Identical workload across policies: per-profile requested equal.
        for r in &results[1..] {
            for p in 0..6 {
                assert_eq!(r.per_profile[p].0, results[0].per_profile[p].0);
            }
        }
    }

    #[test]
    fn only_grmu_migrates() {
        let (w, cfg) = quick_workload();
        let cfg = ExperimentConfig { consolidation_hours: Some(12), ..cfg };
        for r in policy_comparison(&w, &cfg) {
            if r.policy == "GRMU" {
                continue;
            }
            assert_eq!(r.migrations(), 0, "{} migrated", r.policy);
            assert!(r.migration_events.is_empty());
        }
    }

    #[test]
    fn quota_denials_only_from_grmu() {
        use crate::policies::RejectReason;
        let (w, cfg) = quick_workload();
        for r in policy_comparison(&w, &cfg) {
            if r.policy != "GRMU" {
                assert_eq!(
                    r.rejected(RejectReason::QuotaDenied),
                    0,
                    "{} has no basket quota to deny on",
                    r.policy
                );
            }
        }
    }

    #[test]
    fn capacity_sweep_monotone_heavy_acceptance() {
        let (w, cfg) = quick_workload();
        let sweep = heavy_capacity_sweep(&w, &[0.2, 0.8], &cfg);
        let heavy_idx = Profile::P7g40gb.index();
        let rate = |r: &SimResult| {
            let (req, acc) = r.per_profile[heavy_idx];
            if req == 0 { 0.0 } else { acc as f64 / req as f64 }
        };
        // More heavy capacity never hurts 7g.40gb acceptance.
        assert!(rate(&sweep[1].1) >= rate(&sweep[0].1));
    }

    #[test]
    fn ablation_rows_complete() {
        let (w, cfg) = quick_workload();
        let rows = grmu_ablation(&w, &cfg);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].0, "FF (reference)");
        // DB-only never migrates; the consolidation row may.
        assert_eq!(rows[1].1.migrations(), 0);
        // All rows saw the same request stream.
        for (_, r) in &rows[1..] {
            assert_eq!(r.requested, rows[0].1.requested);
        }
    }

    #[test]
    fn consolidation_sweep_labels() {
        let (w, cfg) = quick_workload();
        let sweep = consolidation_sweep(&w, &[24], &cfg);
        let labels: Vec<&str> = sweep.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["DB", "Disabled", "24h"]);
        // DB performs no migrations at all.
        assert_eq!(sweep[0].1.migrations(), 0);
    }
}
