//! Experiment runners and renderers for every table and figure of §8.
//!
//! * [`experiments`] — parameterized runners: one simulation, the
//!   heavy-basket capacity sweep (Figs. 6–8), the consolidation-interval
//!   sweep (Fig. 9), the five-policy comparison (Figs. 10–12, Table 6),
//!   and the parallel multi-seed × multi-policy [`experiments::sweep`]
//!   behind the `sweep` CLI subcommand.
//! * [`tables`] — plain-text table/series rendering in the paper's shape.

pub mod experiments;
pub mod tables;

pub use experiments::{
    availability_sweep, consolidation_sweep, grmu_ablation, heavy_capacity_sweep,
    planner_stack_ablation, policy_comparison, run_once, run_trace, sweep, sweep_summary,
    ExperimentConfig, SweepRun,
};
