//! The coordinator service: an online request loop around a placement
//! policy.
//!
//! Requests (VM specifications) arrive on a channel; the coordinator
//! batches them per simulated interval, releases departed VMs, asks the
//! policy for typed [`Decision`]s and answers on the response channel.
//! The event mechanics — departure heap, interval clock, maintenance
//! ticks, metric samples — are the simulator's [`EventCore`], so a
//! coordinator run yields the same [`SimResult`] a simulation of the
//! same trace would (locked by the equivalence integration test). On top
//! of the core the coordinator adds serving concerns only: batching
//! bounds, decision latency, throughput.
//!
//! Python is never involved: when the XLA scorer is selected, the
//! coordinator's [`PolicyCtx`] calls the AOT-compiled artifact through
//! the PJRT runtime.
//!
//! The offline build environment has no tokio, so concurrency uses
//! `std::thread` + `std::sync::mpsc` — the event-loop structure (bounded
//! batching, deadline-driven maintenance ticks, metrics) is the same as
//! an async implementation would have.

use crate::cluster::vm::{Time, VmId, VmSpec, HOUR};
use crate::cluster::{DataCenter, GpuRef};
use crate::ops::{FaultInjector, QueueConfig};
use crate::policies::{Policy, PolicyCtx, RejectCounts, RejectReason};
use crate::sim::metrics::acceptance_rate;
use crate::sim::{EventCore, SimResult};
use crate::util::stats::percentile;
use std::sync::mpsc::{Receiver, Sender};

/// A placement request: the VM spec (arrival acts as virtual time).
#[derive(Debug, Clone)]
pub struct Request {
    pub vm: VmSpec,
}

/// The decision for one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub vm: VmId,
    pub accepted: bool,
    /// GPU hosting the VM when accepted.
    pub gpu: Option<GpuRef>,
    /// Why the request was refused, when it was.
    pub reason: Option<RejectReason>,
    /// Wall-clock decision latency for the batch containing this VM, µs.
    pub decision_us: f64,
}

/// Coordinator knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Max requests folded into one placement batch. Splitting an
    /// interval across batches is a serving knob; simulator equivalence
    /// holds when an interval's requests fit in one batch.
    pub max_batch: usize,
    /// Virtual interval length for batching and maintenance ticks.
    pub interval: Time,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { max_batch: 256, interval: HOUR }
    }
}

/// Aggregate service statistics.
#[derive(Debug, Clone, Default)]
pub struct CoordinatorStats {
    pub requests: u64,
    pub accepted: u64,
    /// Rejections per [`RejectReason`] (indexed by `RejectReason::index`),
    /// taken from the event core's accounting.
    pub rejections: RejectCounts,
    pub batches: u64,
    /// Per-batch decision latencies (µs).
    pub batch_latencies_us: Vec<f64>,
    /// Total wall time spent deciding (s).
    pub decision_seconds: f64,
}

impl CoordinatorStats {
    /// Uses the crate-wide convention ([`acceptance_rate`]): 1.0 when no
    /// request has been seen.
    pub fn acceptance_rate(&self) -> f64 {
        acceptance_rate(self.accepted, self.requests)
    }

    pub fn latency_p50_us(&self) -> f64 {
        if self.batch_latencies_us.is_empty() {
            0.0
        } else {
            percentile(&self.batch_latencies_us, 50.0)
        }
    }

    pub fn latency_p99_us(&self) -> f64 {
        if self.batch_latencies_us.is_empty() {
            0.0
        } else {
            percentile(&self.batch_latencies_us, 99.0)
        }
    }

    /// Placement decisions per wall second.
    pub fn throughput(&self) -> f64 {
        if self.decision_seconds <= 0.0 {
            0.0
        } else {
            self.requests as f64 / self.decision_seconds
        }
    }
}

/// The coordinator: the shared event core plus serving statistics.
pub struct Coordinator {
    core: EventCore,
    config: CoordinatorConfig,
    batches: u64,
    batch_latencies_us: Vec<f64>,
    decision_seconds: f64,
    /// Reusable per-batch spec scratch (request → VmSpec staging), so
    /// the decision hot path allocates nothing per batch.
    specs: Vec<VmSpec>,
}

impl Coordinator {
    pub fn new(dc: DataCenter, policy: Box<dyn Policy>, config: CoordinatorConfig) -> Coordinator {
        Coordinator::with_ctx(dc, policy, config, PolicyCtx::default())
    }

    /// A coordinator with an explicit policy context (seeded RNG, custom
    /// scorer backend such as the XLA artifact).
    pub fn with_ctx(
        dc: DataCenter,
        policy: Box<dyn Policy>,
        config: CoordinatorConfig,
        ctx: PolicyCtx,
    ) -> Coordinator {
        let core = EventCore::with_interval(dc, policy, ctx, config.interval);
        Coordinator {
            core,
            config,
            batches: 0,
            batch_latencies_us: Vec::new(),
            decision_seconds: 0.0,
            specs: Vec::new(),
        }
    }

    /// The interval owning an arrival at `t` (see [`EventCore::window_of`]).
    pub fn window_of(&self, t: Time) -> u64 {
        self.core.window_of(t)
    }

    /// Install a fault/maintenance schedule on the underlying event core
    /// (see [`crate::ops`]). Call before serving; the coordinator then
    /// replays the same schedule at the same interval points the
    /// simulator would, preserving run equivalence.
    pub fn set_fault_schedule(&mut self, injector: FaultInjector) {
        self.core.set_fault_schedule(injector);
    }

    /// Configure admission queueing on the underlying event core.
    pub fn set_admission_queue(&mut self, cfg: QueueConfig) {
        self.core.set_admission_queue(cfg);
    }

    /// Decide one batch synchronously. Requests must be time-ordered;
    /// the batch is decided at the end of the interval owning its latest
    /// arrival (the simulator's clock — time never moves backwards).
    ///
    /// Catching up across a request-free gap costs one empty interval
    /// step (departure release, tick, sample) per elapsed interval —
    /// the price of sample-for-sample equivalence with the simulator.
    /// Feed arrivals on a contiguous virtual clock; a caller that jumps
    /// the clock by years pays for the skipped intervals.
    pub fn decide_batch(&mut self, batch: &[Request]) -> Vec<Response> {
        if batch.is_empty() {
            return Vec::new();
        }
        let t = batch.iter().map(|r| r.vm.arrival).max().unwrap();
        // Catch up on request-free intervals exactly as the simulator
        // would: per-interval departure releases, ticks and samples.
        self.core.run_until(self.core.window_of(t));
        self.core.release_due(self.core.interval_end());
        // Stage the specs in the reusable scratch and decide through the
        // buffered core path: the measured latency covers the placement
        // decisions only, with no per-batch allocation inside the timer.
        self.specs.clear();
        self.specs.extend(batch.iter().map(|r| r.vm));
        let t0 = std::time::Instant::now();
        self.core.place_buffered(&self.specs);
        let dt = t0.elapsed();
        let us = dt.as_secs_f64() * 1e6;
        self.batches += 1;
        self.batch_latencies_us.push(us);
        self.decision_seconds += dt.as_secs_f64();
        self.specs
            .iter()
            .zip(self.core.decisions())
            .map(|(vm, d)| Response {
                vm: vm.id,
                accepted: d.is_placed(),
                gpu: d.gpu(),
                reason: d.reject_reason(),
                decision_us: us,
            })
            .collect()
    }

    /// Close the open interval (fire its tick and metric sample). Called
    /// at end of service so the final interval is accounted like the
    /// simulator would.
    pub fn close_interval(&mut self) {
        self.core.step_buffered(&[]);
    }

    /// Run empty intervals until the cluster drains (or `cap_hours`
    /// intervals pass) — gives a served trace the same post-arrival
    /// lifecycle a simulation run has.
    pub fn drain(&mut self, cap_hours: u64) {
        let mut steps = 0u64;
        while self.core.pending_departures() > 0 {
            self.core.step_buffered(&[]);
            steps += 1;
            if cap_hours > 0 && steps >= cap_hours {
                break;
            }
        }
    }

    /// Serve a request channel until it closes. Requests are batched per
    /// virtual interval (the same absolute interval grid the simulator
    /// uses) and bounded by `max_batch`.
    pub fn serve(mut self, rx: Receiver<Request>, tx: Sender<Response>) -> CoordinatorStats {
        let mut pending: Vec<Request> = Vec::new();
        let mut open_window: Option<u64> = None;
        for req in rx {
            let w = self.core.window_of(req.vm.arrival);
            let flush = match open_window {
                Some(w0) => w != w0 || pending.len() >= self.config.max_batch,
                None => false,
            };
            if flush {
                for resp in self.decide_batch(&pending) {
                    let _ = tx.send(resp);
                }
                pending.clear();
                open_window = None;
            }
            if open_window.is_none() {
                open_window = Some(w);
            }
            pending.push(req);
        }
        for resp in self.decide_batch(&pending) {
            let _ = tx.send(resp);
        }
        self.close_interval();
        self.stats()
    }

    pub fn stats(&self) -> CoordinatorStats {
        CoordinatorStats {
            requests: self.core.requested(),
            accepted: self.core.accepted(),
            rejections: self.core.rejections(),
            batches: self.batches,
            batch_latencies_us: self.batch_latencies_us.clone(),
            decision_seconds: self.decision_seconds,
        }
    }

    /// Full metrics in the simulator's result type — acceptance (overall,
    /// per profile, per reject reason), samples, migration events.
    pub fn into_result(self) -> SimResult {
        self.core.into_result(self.decision_seconds)
    }

    pub fn datacenter(&self) -> &DataCenter {
        &self.core.dc
    }

    pub fn policy(&self) -> &dyn Policy {
        self.core.policy.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::Profile;
    use crate::policies::first_fit::FirstFit;
    use std::sync::mpsc;

    fn vm(id: VmId, profile: Profile, arrival: Time, departure: Time) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival, departure, weight: 1.0 }
    }

    fn coord(gpus: usize) -> Coordinator {
        Coordinator::new(
            DataCenter::new(vec![Host::new(0, 64, 256, gpus)]),
            Box::new(FirstFit::new()),
            CoordinatorConfig::default(),
        )
    }

    #[test]
    fn synchronous_decisions() {
        let mut c = coord(1);
        let r = c.decide_batch(&[Request { vm: vm(1, Profile::P7g40gb, 10, 10_000) }]);
        assert!(r[0].accepted);
        assert!(r[0].gpu.is_some());
        assert!(r[0].reason.is_none());
        let r = c.decide_batch(&[Request { vm: vm(2, Profile::P1g5gb, 20, 10_000) }]);
        assert!(!r[0].accepted);
        assert_eq!(r[0].reason, Some(RejectReason::NoGpuFit));
        assert_eq!(c.stats().requests, 2);
        assert_eq!(c.stats().accepted, 1);
    }

    #[test]
    fn departures_release_capacity() {
        let mut c = coord(1);
        c.decide_batch(&[Request { vm: vm(1, Profile::P7g40gb, 0, 100) }]);
        // Arrives in a later interval, after the departure: accepted.
        // (On the simulator clock a VM placed in interval 0 departs no
        // earlier than the start of interval 1.)
        let r = c.decide_batch(&[Request { vm: vm(2, Profile::P7g40gb, 2 * HOUR, 5 * HOUR) }]);
        assert!(r[0].accepted);
    }

    #[test]
    fn channel_service_end_to_end() {
        let c = coord(2);
        let (req_tx, req_rx) = mpsc::channel();
        let (resp_tx, resp_rx) = mpsc::channel();
        let handle = std::thread::spawn(move || c.serve(req_rx, resp_tx));
        for i in 0..5u64 {
            let spec = vm(i + 1, Profile::P2g10gb, i * 60, 1_000_000);
            req_tx.send(Request { vm: spec }).unwrap();
        }
        drop(req_tx);
        let responses: Vec<Response> = resp_rx.iter().collect();
        let stats = handle.join().unwrap();
        assert_eq!(responses.len(), 5);
        // 2 GPUs × 3 slots for 2g.10gb = 6 ≥ 5: all accepted.
        assert!(responses.iter().all(|r| r.accepted));
        assert_eq!(stats.requests, 5);
        assert!(stats.throughput() > 0.0);
        assert!(stats.latency_p99_us() >= stats.latency_p50_us());
    }

    #[test]
    fn batching_respects_interval() {
        let c = coord(8);
        let (req_tx, req_rx) = mpsc::channel();
        let (resp_tx, resp_rx) = mpsc::channel();
        let handle = std::thread::spawn(move || c.serve(req_rx, resp_tx));
        // Two requests in the same hour, one 2 hours later.
        req_tx.send(Request { vm: vm(1, Profile::P1g5gb, 0, 9_999_999) }).unwrap();
        req_tx.send(Request { vm: vm(2, Profile::P1g5gb, 60, 9_999_999) }).unwrap();
        req_tx.send(Request { vm: vm(3, Profile::P1g5gb, 2 * HOUR + 1, 9_999_999) }).unwrap();
        drop(req_tx);
        let _: Vec<Response> = resp_rx.iter().collect();
        let stats = handle.join().unwrap();
        assert_eq!(stats.batches, 2, "expected [vm1,vm2] then [vm3]");
    }

    #[test]
    fn empty_stats_acceptance_is_vacuous_one() {
        let c = coord(1);
        assert!((c.stats().acceptance_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stats_carry_core_rejection_breakdown() {
        let mut c = coord(1);
        c.decide_batch(&[
            Request { vm: vm(1, Profile::P7g40gb, 10, 10 * HOUR) },
            Request { vm: vm(2, Profile::P7g40gb, 20, 10 * HOUR) },
        ]);
        let stats = c.stats();
        assert_eq!(stats.rejections[RejectReason::NoGpuFit.index()], 1);
        assert_eq!(stats.rejections.iter().sum::<u64>(), stats.requests - stats.accepted);
    }

    #[test]
    fn drain_runs_the_post_arrival_lifecycle() {
        let mut c = coord(1);
        c.decide_batch(&[Request { vm: vm(1, Profile::P7g40gb, 10, 3 * HOUR) }]);
        c.close_interval();
        assert_eq!(c.datacenter().resident_count(), 1);
        c.drain(0);
        // The VM departed and each drained interval was sampled.
        assert_eq!(c.datacenter().resident_count(), 0);
        let result = c.into_result();
        assert_eq!(result.samples.last().unwrap().resident, 0);
        assert!(result.samples.len() >= 3);
    }

    #[test]
    fn result_carries_samples_and_reasons() {
        let mut c = coord(1);
        c.decide_batch(&[
            Request { vm: vm(1, Profile::P7g40gb, 10, 10 * HOUR) },
            Request { vm: vm(2, Profile::P7g40gb, 20, 10 * HOUR) },
        ]);
        c.close_interval();
        let result = c.into_result();
        assert_eq!(result.requested, 2);
        assert_eq!(result.accepted, 1);
        assert_eq!(result.rejected(RejectReason::NoGpuFit), 1);
        assert_eq!(result.samples.len(), 1);
        assert!((result.samples[0].acceptance_rate - 0.5).abs() < 1e-12);
    }
}
