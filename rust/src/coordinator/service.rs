//! The coordinator service: an online request loop around a placement
//! policy.
//!
//! Requests (VM specifications) arrive on a channel; the coordinator
//! batches them per simulated interval, releases departed VMs, asks the
//! policy for decisions and answers on the response channel. Python is
//! never involved: when the XLA scorer is selected, the coordinator calls
//! the AOT-compiled artifact through the PJRT runtime.
//!
//! The offline build environment has no tokio, so concurrency uses
//! `std::thread` + `std::sync::mpsc` — the event-loop structure (bounded
//! batching, deadline-driven maintenance ticks, metrics) is the same as
//! an async implementation would have.

use crate::cluster::vm::{Time, VmId, VmSpec, HOUR};
use crate::cluster::{DataCenter, GpuRef};
use crate::policies::Policy;
use crate::util::stats::percentile;
use std::collections::BinaryHeap;
use std::sync::mpsc::{Receiver, Sender};

/// A placement request: the VM spec (arrival acts as virtual time).
#[derive(Debug, Clone)]
pub struct Request {
    pub vm: VmSpec,
}

/// The decision for one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub vm: VmId,
    pub accepted: bool,
    /// GPU hosting the VM when accepted.
    pub gpu: Option<GpuRef>,
    /// Wall-clock decision latency for the batch containing this VM, µs.
    pub decision_us: f64,
}

/// Coordinator knobs.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Max requests folded into one placement batch.
    pub max_batch: usize,
    /// Virtual interval length for batching and maintenance ticks.
    pub interval: Time,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { max_batch: 256, interval: HOUR }
    }
}

/// Aggregate service statistics.
#[derive(Debug, Clone, Default)]
pub struct CoordinatorStats {
    pub requests: u64,
    pub accepted: u64,
    pub batches: u64,
    /// Per-batch decision latencies (µs).
    pub batch_latencies_us: Vec<f64>,
    /// Total wall time spent deciding (s).
    pub decision_seconds: f64,
}

impl CoordinatorStats {
    pub fn acceptance_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.accepted as f64 / self.requests as f64
        }
    }

    pub fn latency_p50_us(&self) -> f64 {
        if self.batch_latencies_us.is_empty() {
            0.0
        } else {
            percentile(&self.batch_latencies_us, 50.0)
        }
    }

    pub fn latency_p99_us(&self) -> f64 {
        if self.batch_latencies_us.is_empty() {
            0.0
        } else {
            percentile(&self.batch_latencies_us, 99.0)
        }
    }

    /// Placement decisions per wall second.
    pub fn throughput(&self) -> f64 {
        if self.decision_seconds <= 0.0 {
            0.0
        } else {
            self.requests as f64 / self.decision_seconds
        }
    }
}

/// The coordinator: data-center state + policy + virtual clock.
pub struct Coordinator {
    dc: DataCenter,
    policy: Box<dyn Policy>,
    config: CoordinatorConfig,
    departures: BinaryHeap<std::cmp::Reverse<(Time, VmId)>>,
    now: Time,
    last_tick: Time,
    stats: CoordinatorStats,
}

impl Coordinator {
    pub fn new(dc: DataCenter, policy: Box<dyn Policy>, config: CoordinatorConfig) -> Coordinator {
        Coordinator {
            dc,
            policy,
            config,
            departures: BinaryHeap::new(),
            now: 0,
            last_tick: 0,
            stats: CoordinatorStats::default(),
        }
    }

    /// Advance virtual time: release departures due by `t`, fire the
    /// policy tick at interval boundaries.
    fn advance_to(&mut self, t: Time) {
        while let Some(&std::cmp::Reverse((due, vm))) = self.departures.peek() {
            if due > t {
                break;
            }
            self.departures.pop();
            self.dc.remove(vm);
            self.policy.on_departure(&mut self.dc, vm);
        }
        if t.saturating_sub(self.last_tick) >= self.config.interval {
            self.policy.on_tick(&mut self.dc, t);
            self.last_tick = t;
        }
        self.now = self.now.max(t);
    }

    /// Decide one batch synchronously. Requests must be time-ordered.
    pub fn decide_batch(&mut self, batch: &[Request]) -> Vec<Response> {
        if batch.is_empty() {
            return Vec::new();
        }
        let t = batch.iter().map(|r| r.vm.arrival).max().unwrap();
        self.advance_to(t);
        let specs: Vec<VmSpec> = batch.iter().map(|r| r.vm).collect();
        let t0 = std::time::Instant::now();
        let decisions = self.policy.place_batch(&mut self.dc, &specs, self.now);
        let dt = t0.elapsed();
        let us = dt.as_secs_f64() * 1e6;
        self.stats.batches += 1;
        self.stats.batch_latencies_us.push(us);
        self.stats.decision_seconds += dt.as_secs_f64();
        specs
            .iter()
            .zip(&decisions)
            .map(|(vm, &accepted)| {
                self.stats.requests += 1;
                if accepted {
                    self.stats.accepted += 1;
                    self.departures
                        .push(std::cmp::Reverse((vm.departure.max(vm.arrival + 1), vm.id)));
                }
                Response {
                    vm: vm.id,
                    accepted,
                    gpu: self.dc.locate(vm.id).map(|loc| loc.gpu),
                    decision_us: us,
                }
            })
            .collect()
    }

    /// Serve a request channel until it closes. Requests are batched by
    /// virtual interval (same `interval` as maintenance) and bounded by
    /// `max_batch`.
    pub fn serve(mut self, rx: Receiver<Request>, tx: Sender<Response>) -> CoordinatorStats {
        let mut pending: Vec<Request> = Vec::new();
        let mut batch_open: Option<Time> = None;
        for req in rx {
            let t = req.vm.arrival;
            let flush = match batch_open {
                Some(t0) => {
                    t >= t0 + self.config.interval || pending.len() >= self.config.max_batch
                }
                None => false,
            };
            if flush {
                for resp in self.decide_batch(&pending) {
                    let _ = tx.send(resp);
                }
                pending.clear();
                batch_open = None;
            }
            if batch_open.is_none() {
                batch_open = Some(t);
            }
            pending.push(req);
        }
        for resp in self.decide_batch(&pending) {
            let _ = tx.send(resp);
        }
        self.stats
    }

    pub fn stats(&self) -> &CoordinatorStats {
        &self.stats
    }

    pub fn datacenter(&self) -> &DataCenter {
        &self.dc
    }

    pub fn policy(&self) -> &dyn Policy {
        self.policy.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::Profile;
    use crate::policies::first_fit::FirstFit;
    use std::sync::mpsc;

    fn vm(id: VmId, profile: Profile, arrival: Time, departure: Time) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival, departure, weight: 1.0 }
    }

    fn coord(gpus: usize) -> Coordinator {
        Coordinator::new(
            DataCenter::new(vec![Host::new(0, 64, 256, gpus)]),
            Box::new(FirstFit::new()),
            CoordinatorConfig::default(),
        )
    }

    #[test]
    fn synchronous_decisions() {
        let mut c = coord(1);
        let r = c.decide_batch(&[Request { vm: vm(1, Profile::P7g40gb, 10, 10_000) }]);
        assert!(r[0].accepted);
        assert!(r[0].gpu.is_some());
        let r = c.decide_batch(&[Request { vm: vm(2, Profile::P1g5gb, 20, 10_000) }]);
        assert!(!r[0].accepted);
        assert_eq!(c.stats().requests, 2);
        assert_eq!(c.stats().accepted, 1);
    }

    #[test]
    fn departures_release_capacity() {
        let mut c = coord(1);
        c.decide_batch(&[Request { vm: vm(1, Profile::P7g40gb, 0, 100) }]);
        // Arrives after the departure: accepted.
        let r = c.decide_batch(&[Request { vm: vm(2, Profile::P7g40gb, 200, 500) }]);
        assert!(r[0].accepted);
    }

    #[test]
    fn channel_service_end_to_end() {
        let c = coord(2);
        let (req_tx, req_rx) = mpsc::channel();
        let (resp_tx, resp_rx) = mpsc::channel();
        let handle = std::thread::spawn(move || c.serve(req_rx, resp_tx));
        for i in 0..5u64 {
            let spec = vm(i + 1, Profile::P2g10gb, i * 60, 1_000_000);
            req_tx.send(Request { vm: spec }).unwrap();
        }
        drop(req_tx);
        let responses: Vec<Response> = resp_rx.iter().collect();
        let stats = handle.join().unwrap();
        assert_eq!(responses.len(), 5);
        // 2 GPUs × 3 slots for 2g.10gb = 6 ≥ 5: all accepted.
        assert!(responses.iter().all(|r| r.accepted));
        assert_eq!(stats.requests, 5);
        assert!(stats.throughput() > 0.0);
        assert!(stats.latency_p99_us() >= stats.latency_p50_us());
    }

    #[test]
    fn batching_respects_interval() {
        let c = coord(8);
        let (req_tx, req_rx) = mpsc::channel();
        let (resp_tx, resp_rx) = mpsc::channel();
        let handle = std::thread::spawn(move || c.serve(req_rx, resp_tx));
        // Two requests in the same hour, one 2 hours later.
        req_tx.send(Request { vm: vm(1, Profile::P1g5gb, 0, 9_999_999) }).unwrap();
        req_tx.send(Request { vm: vm(2, Profile::P1g5gb, 60, 9_999_999) }).unwrap();
        req_tx.send(Request { vm: vm(3, Profile::P1g5gb, 2 * HOUR + 1, 9_999_999) }).unwrap();
        drop(req_tx);
        let _: Vec<Response> = resp_rx.iter().collect();
        let stats = handle.join().unwrap();
        assert_eq!(stats.batches, 2, "expected [vm1,vm2] then [vm3]");
    }
}
