//! The online placement coordinator (L3 service shell).
//!
//! Wraps the placement policies in a request/response service loop with
//! admission metrics, periodic maintenance ticks, and pluggable CC
//! scoring (native table lookups or the AOT-compiled XLA artifact,
//! selected through the [`crate::policies::PolicyCtx`]). The event
//! mechanics are the simulator's shared [`crate::sim::EventCore`], so a
//! coordinator run reports the same [`crate::sim::SimResult`] metrics —
//! per-reason rejections, migration events, hourly samples — as an
//! offline simulation of the same trace. See [`service`] for the event
//! loop and [`cli`] for the `repro serve` entry point.

pub mod cli;
pub mod service;

pub use service::{Coordinator, CoordinatorConfig, CoordinatorStats, Request, Response};
