//! The online placement coordinator (L3 service shell).
//!
//! Wraps the placement policies in a request/response service loop with
//! admission metrics, periodic maintenance ticks, and pluggable CC
//! scoring (native table lookups or the AOT-compiled XLA artifact).
//! See [`service`] for the event loop and [`cli`] for the `repro serve`
//! entry point.

pub mod cli;
pub mod service;

pub use service::{Coordinator, CoordinatorConfig, Request, Response};
