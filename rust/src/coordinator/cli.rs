//! `repro serve` — replay a trace through the coordinator service and
//! report serving metrics (acceptance with per-reason rejections,
//! decision latency, throughput).

use super::service::{Coordinator, CoordinatorConfig, Request, Response};
use crate::cluster::DataCenter;
use crate::policies::{format_reject_counts, PolicyConfig, PolicyCtx, PolicyRegistry};
use crate::trace::{TraceConfig, Workload};
use crate::util::cli::Args;
use std::sync::mpsc;

/// Build the policy context for the selected scorer backend. Only MCC
/// consumes the ctx scorer, so the artifact is loaded only when it
/// will actually be used — other policies serve natively even when the
/// artifact is absent (matching the pre-redesign behaviour).
#[cfg(feature = "xla")]
fn scorer_ctx(seed: u64, policy_name: &str, scorer: &str, args: &Args) -> PolicyCtx {
    if scorer == "xla" && policy_name == "mcc" {
        let artifact = args.str_or("artifact", "artifacts/cc_scorer.hlo.txt");
        let xla = crate::runtime::XlaScorer::load(std::path::Path::new(&artifact))
            .expect("loading XLA scorer artifact (run `make artifacts` first)");
        eprintln!("scoring through PJRT: {artifact}");
        PolicyCtx::with_scorer(seed, Box::new(xla))
    } else {
        if scorer == "xla" {
            eprintln!("--scorer xla only affects mcc; using the native scorer");
        }
        PolicyCtx::new(seed)
    }
}

#[cfg(not(feature = "xla"))]
fn scorer_ctx(seed: u64, _policy_name: &str, scorer: &str, _args: &Args) -> PolicyCtx {
    if scorer == "xla" {
        eprintln!("--scorer xla requires a build with `--features xla`; using the native scorer");
    }
    PolicyCtx::new(seed)
}

/// Entry point for the `serve` subcommand.
pub fn run(args: &Args) {
    let seed = args.num_or("seed", 42u64);
    let trace = if args.flag("quick") {
        TraceConfig::small(seed)
    } else {
        TraceConfig { seed, ..TraceConfig::default() }
    };
    let workload = Workload::generate(trace);
    let policy_name = args.str_or("policy", "grmu");
    let scorer = args.str_or("scorer", "native");
    let heavy_frac = args.num_or("heavy-frac", 0.30f64);
    let consolidation = args.get("consolidation").and_then(|s| s.parse().ok());

    let registry = PolicyRegistry::standard();
    let policy_cfg =
        PolicyConfig::new().heavy_frac(heavy_frac).consolidation_hours(consolidation);
    let policy = registry.build(&policy_name, &policy_cfg).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let ctx = scorer_ctx(seed, &policy_name.to_ascii_lowercase(), &scorer, args);

    eprintln!(
        "serving {} VMs over {} hosts / {} GPUs with {} (scorer: {})",
        workload.vms.len(),
        workload.hosts.len(),
        workload.num_gpus(),
        policy_name,
        scorer
    );

    let coordinator = Coordinator::with_ctx(
        DataCenter::new(workload.hosts.clone()),
        policy,
        CoordinatorConfig::default(),
        ctx,
    );

    let (req_tx, req_rx) = mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    let server = std::thread::spawn(move || coordinator.serve(req_rx, resp_tx));

    // Feeder thread: replay arrivals in virtual-time order.
    let vms = workload.vms.clone();
    let feeder = std::thread::spawn(move || {
        for vm in vms {
            if req_tx.send(Request { vm }).is_err() {
                break;
            }
        }
    });

    // Drain the response channel so the feeder/server can finish; the
    // authoritative accounting (acceptance, per-reason rejections)
    // comes back from the coordinator's event core via the stats.
    let responses: u64 = resp_rx.iter().count() as u64;
    feeder.join().unwrap();
    let stats = server.join().unwrap();
    if responses != stats.requests {
        eprintln!("warning: {responses} responses for {} requests", stats.requests);
    }

    println!(
        "served={} accepted={} ({:.1}%)  batches={}  p50={:.1}µs p99={:.1}µs  throughput={:.0} decisions/s",
        stats.requests,
        stats.accepted,
        100.0 * stats.acceptance_rate(),
        stats.batches,
        stats.latency_p50_us(),
        stats.latency_p99_us(),
        stats.throughput(),
    );
    if stats.rejections.iter().sum::<u64>() > 0 {
        println!("rejections: {}", format_reject_counts(&stats.rejections));
    }
}
