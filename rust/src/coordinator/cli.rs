//! `repro serve` — replay a trace through the coordinator service and
//! report serving metrics (acceptance, decision latency, throughput).

use super::service::{Coordinator, CoordinatorConfig, Request, Response};
use crate::cluster::DataCenter;
use crate::policies::{self, mcc::Mcc};
use crate::runtime::scorer::XlaScorer;
use crate::trace::{TraceConfig, Workload};
use crate::util::cli::Args;
use std::sync::mpsc;

/// Entry point for the `serve` subcommand.
pub fn run(args: &Args) {
    let seed = args.num_or("seed", 42u64);
    let trace = if args.flag("quick") {
        TraceConfig::small(seed)
    } else {
        TraceConfig { seed, ..TraceConfig::default() }
    };
    let workload = Workload::generate(trace);
    let policy_name = args.str_or("policy", "grmu");
    let scorer = args.str_or("scorer", "native");
    let heavy_frac = args.num_or("heavy-frac", 0.30f64);
    let consolidation = args.get("consolidation").and_then(|s| s.parse().ok());

    let policy: Box<dyn policies::Policy> = if policy_name == "mcc" && scorer == "xla" {
        let artifact = args.str_or("artifact", "artifacts/cc_scorer.hlo.txt");
        let xla = XlaScorer::load(std::path::Path::new(&artifact))
            .expect("loading XLA scorer artifact (run `make artifacts` first)");
        eprintln!("scoring through PJRT: {artifact}");
        Box::new(Mcc::with_scorer(Box::new(xla)))
    } else {
        policies::by_name(&policy_name, heavy_frac, consolidation).expect("known policy")
    };

    eprintln!(
        "serving {} VMs over {} hosts / {} GPUs with {} (scorer: {})",
        workload.vms.len(),
        workload.hosts.len(),
        workload.num_gpus(),
        policy_name,
        scorer
    );

    let coordinator = Coordinator::new(
        DataCenter::new(workload.hosts.clone()),
        policy,
        CoordinatorConfig::default(),
    );

    let (req_tx, req_rx) = mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    let server = std::thread::spawn(move || coordinator.serve(req_rx, resp_tx));

    // Feeder thread: replay arrivals in virtual-time order.
    let vms = workload.vms.clone();
    let feeder = std::thread::spawn(move || {
        for vm in vms {
            if req_tx.send(Request { vm }).is_err() {
                break;
            }
        }
    });

    let mut accepted = 0u64;
    let mut total = 0u64;
    for resp in resp_rx {
        total += 1;
        if resp.accepted {
            accepted += 1;
        }
    }
    feeder.join().unwrap();
    let stats = server.join().unwrap();

    println!(
        "served={total} accepted={accepted} ({:.1}%)  batches={}  p50={:.1}µs p99={:.1}µs  throughput={:.0} decisions/s",
        100.0 * accepted as f64 / total.max(1) as f64,
        stats.batches,
        stats.latency_p50_us(),
        stats.latency_p99_us(),
        stats.throughput(),
    );
}
