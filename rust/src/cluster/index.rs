//! The incrementally maintained cluster placement index.
//!
//! Every policy used to re-materialize `gpu_refs()` and linearly probe
//! all GPUs per request — O(cluster) per VM. The [`ClusterIndex`] turns
//! both admission questions into indexed lookups, maintained by
//! [`super::DataCenter`] on every `place`/`remove`/`migrate`/
//! `relocate_within_gpu`/`repack_gpu`:
//!
//! * **Per-profile GPU feasibility buckets**, keyed by the dense
//!   cross-model [`Profile::dense`] index: GPU `r` is in bucket `k` iff
//!   `r`'s model owns key `k` and `profile_capacity_for(model,
//!   occ)[k.index()] > 0`. A GPU therefore only ever appears in buckets
//!   of its own model's profiles, which is what confines every policy
//!   scan to model-compatible candidates. A state change moves a GPU in
//!   or out of a bucket only when that profile's feasible-start count
//!   crosses zero, so an update is a handful of table lookups plus
//!   O(log #GPUs) set operations.
//! * **Host headroom multisets** of free CPU / free RAM over
//!   GPU-equipped hosts, answering "could any host take this VM?" and
//!   the CPU-vs-RAM rejection classification from the maxima/minima in
//!   O(log #hosts).
//!
//! ## Determinism contract
//!
//! Buckets iterate in ascending [`GpuRef`] order — the paper's
//! `globalIndex` (Algorithm 2). A bucket is therefore exactly the
//! feasible *subsequence* of a full `globalIndex` scan (foreign-model
//! GPUs are infeasible by definition), which is what makes first-fit
//! and best-scoring selections over bucket candidates byte-identical to
//! the pre-index full scans (locked by the indexed-vs-scan equivalence
//! tests in `rust/tests/decision_api.rs`).
//!
//! ## Health contract
//!
//! The index covers **schedulable** capacity only: a GPU appears in
//! buckets iff it and its host are
//! [`Healthy`](crate::cluster::HealthState); an unavailable host also
//! leaves the headroom multisets and the per-model host counts.
//! [`ClusterIndex::build`] skips unhealthy capacity, and
//! [`super::DataCenter`]'s health mutators attach/detach entries on
//! availability transitions, so the "rebuild equals incremental"
//! comparison in `check_integrity` verifies the contract for free. On
//! an all-healthy fleet every skip condition is vacuous and the index
//! is bit-for-bit the pre-health one.

use super::datacenter::GpuRef;
use super::host::Host;
use crate::mig::gpu::profile_capacity_for;
use crate::mig::{BlockMask, GpuModel, Profile, NUM_MODELS, NUM_PROFILE_KEYS};
use std::collections::{BTreeMap, BTreeSet};

/// Index over the live cluster state. Owned and kept coherent by
/// [`super::DataCenter`]; consumers only read it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterIndex {
    /// `buckets[k]` = GPUs where the profile with dense index `k`
    /// currently fits, in `globalIndex` order.
    buckets: Vec<BTreeSet<GpuRef>>,
    /// Multiset of free CPU cores per GPU-equipped host.
    free_cpus: BTreeMap<u32, u32>,
    /// Multiset of free RAM (GB) per GPU-equipped host.
    free_ram: BTreeMap<u32, u32>,
    /// Number of GPU-equipped hosts (hosts without GPUs never receive a
    /// VM and are excluded from the headroom multisets).
    host_count: u32,
    /// Hosts carrying at least one GPU of each model (static per fleet:
    /// GPU models never change after construction). Drives the
    /// model-aware rejection classification fast paths.
    hosts_with_model: [u32; NUM_MODELS],
}

impl Default for ClusterIndex {
    fn default() -> Self {
        ClusterIndex {
            buckets: vec![BTreeSet::new(); NUM_PROFILE_KEYS],
            free_cpus: BTreeMap::new(),
            free_ram: BTreeMap::new(),
            host_count: 0,
            hosts_with_model: [0; NUM_MODELS],
        }
    }
}

impl ClusterIndex {
    /// Brute-force (re)construction from host/GPU states — the reference
    /// the incremental maintenance is tested against, and what
    /// [`super::DataCenter::check_integrity`] compares with.
    pub fn build(hosts: &[Host]) -> ClusterIndex {
        let mut idx = ClusterIndex::default();
        for h in hosts {
            if h.gpus().is_empty() || !h.health().allows_placement() {
                continue;
            }
            idx.attach_host(h);
        }
        idx
    }

    /// Insert an available host: headroom classes, per-model counts and
    /// the buckets of its schedulable GPUs. Called by `build` and by
    /// [`super::DataCenter`] when a host transitions back to healthy.
    pub(crate) fn attach_host(&mut self, h: &Host) {
        self.host_count += 1;
        *self.free_cpus.entry(h.free_cpus()).or_insert(0) += 1;
        *self.free_ram.entry(h.free_ram()).or_insert(0) += 1;
        let mut present = [false; NUM_MODELS];
        for gpu in h.gpus() {
            present[gpu.model() as usize] = true;
        }
        for (m, here) in present.into_iter().enumerate() {
            if here {
                self.hosts_with_model[m] += 1;
            }
        }
        for (g, gpu) in h.gpus().iter().enumerate() {
            if !h.gpu_health(g).allows_placement() {
                continue;
            }
            let r = GpuRef { host: h.id, gpu: g as u8 };
            self.attach_gpu(r, gpu.model(), gpu.occupancy());
        }
    }

    /// Remove a host that became unavailable: the exact inverse of
    /// [`ClusterIndex::attach_host`] against the same host state.
    pub(crate) fn detach_host(&mut self, h: &Host) {
        debug_assert!(self.host_count > 0);
        self.host_count -= 1;
        Self::multiset_remove(&mut self.free_cpus, h.free_cpus());
        Self::multiset_remove(&mut self.free_ram, h.free_ram());
        let mut present = [false; NUM_MODELS];
        for gpu in h.gpus() {
            present[gpu.model() as usize] = true;
        }
        for (m, here) in present.into_iter().enumerate() {
            if here {
                debug_assert!(self.hosts_with_model[m] > 0);
                self.hosts_with_model[m] -= 1;
            }
        }
        for (g, gpu) in h.gpus().iter().enumerate() {
            if !h.gpu_health(g).allows_placement() {
                continue; // was never in the buckets
            }
            let r = GpuRef { host: h.id, gpu: g as u8 };
            self.detach_gpu(r, gpu.model(), gpu.occupancy());
        }
    }

    /// Insert one schedulable GPU into the buckets its occupancy allows.
    pub(crate) fn attach_gpu(&mut self, r: GpuRef, model: GpuModel, occ: BlockMask) {
        let cap = profile_capacity_for(model, occ);
        for key in model.profile_keys() {
            if cap[key.index()] > 0 {
                self.buckets[key.dense()].insert(r);
            }
        }
    }

    /// Remove one GPU from every bucket its occupancy had it in.
    pub(crate) fn detach_gpu(&mut self, r: GpuRef, model: GpuModel, occ: BlockMask) {
        let cap = profile_capacity_for(model, occ);
        for key in model.profile_keys() {
            if cap[key.index()] > 0 {
                self.buckets[key.dense()].remove(&r);
            }
        }
    }

    /// GPUs where `profile` currently fits (all of the profile's model),
    /// in `globalIndex` order.
    #[inline]
    pub fn gpus_fitting(&self, profile: Profile) -> &BTreeSet<GpuRef> {
        &self.buckets[profile.dense()]
    }

    /// Number of GPUs with at least one feasible start for `profile`.
    pub fn fitting_count(&self, profile: Profile) -> usize {
        self.buckets[profile.dense()].len()
    }

    /// Number of GPU-equipped hosts.
    #[inline]
    pub fn num_hosts(&self) -> u32 {
        self.host_count
    }

    /// Number of hosts carrying at least one GPU of `model` — the
    /// candidate-host population for a request of that model (Eq. 17–18
    /// compatibility).
    #[inline]
    pub fn hosts_with_model(&self, model: GpuModel) -> u32 {
        self.hosts_with_model[model as usize]
    }

    /// Largest free-CPU headroom of any GPU-equipped host (0 when empty).
    #[inline]
    pub fn max_free_cpus(&self) -> u32 {
        self.free_cpus.keys().next_back().copied().unwrap_or(0)
    }

    /// Smallest free-CPU headroom of any GPU-equipped host (0 when empty).
    #[inline]
    pub fn min_free_cpus(&self) -> u32 {
        self.free_cpus.keys().next().copied().unwrap_or(0)
    }

    /// Largest free-RAM headroom of any GPU-equipped host (0 when empty).
    #[inline]
    pub fn max_free_ram(&self) -> u32 {
        self.free_ram.keys().next_back().copied().unwrap_or(0)
    }

    /// Smallest free-RAM headroom of any GPU-equipped host (0 when empty).
    #[inline]
    pub fn min_free_ram(&self) -> u32 {
        self.free_ram.keys().next().copied().unwrap_or(0)
    }

    /// Admission precheck: `false` guarantees no GPU-equipped host has
    /// both the CPU and the RAM for this request (the maxima already
    /// fail one-sidedly), so a full scan can be skipped. `true` is
    /// one-sided — the CPU and RAM maxima may live on different hosts.
    #[inline]
    pub fn host_may_fit(&self, cpus: u32, ram_gb: u32) -> bool {
        self.max_free_cpus() >= cpus && self.max_free_ram() >= ram_gb
    }

    /// Re-bucket one GPU of `model` after its occupancy changed.
    pub(crate) fn update_gpu(
        &mut self,
        r: GpuRef,
        model: GpuModel,
        old_occ: BlockMask,
        new_occ: BlockMask,
    ) {
        if old_occ == new_occ {
            return;
        }
        let old_cap = profile_capacity_for(model, old_occ);
        let new_cap = profile_capacity_for(model, new_occ);
        for key in model.profile_keys() {
            let p = key.index();
            match (old_cap[p] > 0, new_cap[p] > 0) {
                (false, true) => {
                    self.buckets[key.dense()].insert(r);
                }
                (true, false) => {
                    self.buckets[key.dense()].remove(&r);
                }
                _ => {}
            }
        }
    }

    /// Move one host between headroom classes after a reserve/release.
    pub(crate) fn update_host(&mut self, old_free: (u32, u32), new_free: (u32, u32)) {
        Self::multiset_move(&mut self.free_cpus, old_free.0, new_free.0);
        Self::multiset_move(&mut self.free_ram, old_free.1, new_free.1);
    }

    fn multiset_move(set: &mut BTreeMap<u32, u32>, old: u32, new: u32) {
        if old == new {
            return;
        }
        Self::multiset_remove(set, old);
        *set.entry(new).or_insert(0) += 1;
    }

    fn multiset_remove(set: &mut BTreeMap<u32, u32>, class: u32) {
        match set.get_mut(&class) {
            Some(n) if *n > 1 => *n -= 1,
            Some(_) => {
                set.remove(&class);
            }
            None => debug_assert!(false, "headroom multiset missing class {class}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{DataCenter, Host, VmSpec};
    use crate::mig::gpu::feasible_starts;
    use crate::mig::placement::mock_assign;
    use crate::mig::profiles::ALL_PROFILES;
    use crate::mig::{Placement, ProfileKey};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn spec(id: u64, profile: Profile, cpus: u32, ram_gb: u32) -> VmSpec {
        VmSpec { id, profile, cpus, ram_gb, arrival: 0, departure: 1_000, weight: 1.0 }
    }

    fn small_dc() -> DataCenter {
        DataCenter::new(vec![
            Host::new(0, 16, 64, 2),
            Host::new(1, 16, 64, 3),
            Host::new(2, 8, 32, 1),
        ])
    }

    /// Mixed A30 / A100-40 / H100-80 cluster for the heterogeneity tests.
    fn mixed_dc() -> DataCenter {
        DataCenter::new(vec![
            Host::with_models(0, 16, 64, &[GpuModel::A30, GpuModel::A100_40]),
            Host::with_models(1, 16, 64, &[GpuModel::H100_80, GpuModel::A30, GpuModel::A100_40]),
            Host::with_models(2, 8, 32, &[GpuModel::H100_80]),
        ])
    }

    #[test]
    fn build_on_empty_cluster_buckets_every_gpu() {
        let dc = small_dc();
        for p in ALL_PROFILES {
            assert_eq!(dc.index().fitting_count(p), 6, "{p}");
        }
        assert_eq!(dc.index().num_hosts(), 3);
        assert_eq!(dc.index().max_free_cpus(), 16);
        assert_eq!(dc.index().min_free_cpus(), 8);
        assert_eq!(dc.index().max_free_ram(), 64);
        assert_eq!(dc.index().min_free_ram(), 32);
    }

    #[test]
    fn buckets_are_model_segregated() {
        let dc = mixed_dc();
        // Two A100-40 GPUs, two A30s, two H100-80s.
        for p in ALL_PROFILES {
            assert_eq!(dc.index().fitting_count(p), 2, "{p}");
        }
        for k in GpuModel::A30.profile_keys() {
            assert_eq!(dc.index().fitting_count(k), 2, "{k}");
            for r in dc.index().gpus_fitting(k) {
                assert_eq!(dc.gpu(*r).model(), GpuModel::A30, "{k}");
            }
        }
        // No A100-80s in this fleet: buckets empty.
        for k in GpuModel::A100_80.profile_keys() {
            assert_eq!(dc.index().fitting_count(k), 0, "{k}");
        }
    }

    #[test]
    fn full_gpu_leaves_every_bucket() {
        let mut dc = small_dc();
        let r = GpuRef { host: 0, gpu: 0 };
        let pl = Placement { profile: Profile::P7g40gb, start: 0 };
        dc.place(&spec(1, Profile::P7g40gb, 4, 8), r, pl);
        for p in ALL_PROFILES {
            assert!(!dc.index().gpus_fitting(p).contains(&r), "{p}");
        }
        dc.remove(1);
        for p in ALL_PROFILES {
            assert!(dc.index().gpus_fitting(p).contains(&r), "{p}");
        }
    }

    #[test]
    fn headroom_tracks_reservations() {
        let mut dc = small_dc();
        let r = GpuRef { host: 0, gpu: 0 };
        let pl = Placement { profile: Profile::P1g5gb, start: 6 };
        dc.place(&spec(1, Profile::P1g5gb, 10, 40), r, pl);
        assert_eq!(dc.index().max_free_cpus(), 16); // host 1 untouched
        assert!(dc.index().host_may_fit(16, 64));
        assert!(!dc.index().host_may_fit(17, 1));
        assert!(!dc.index().host_may_fit(1, 65));
        assert_eq!(dc.index().min_free_cpus(), 6); // host 0: 16 - 10
        dc.remove(1);
        assert_eq!(dc.index().min_free_cpus(), 8); // back to host 2's 8
    }

    #[test]
    fn partial_occupancy_tracks_capacity_zero_crossings() {
        let mut dc = small_dc();
        let r = GpuRef { host: 1, gpu: 2 };
        // 3g.20gb at start 0: blocks 0-3 occupied. 4g.20gb (start 0 only)
        // no longer fits; 3g.20gb still fits at start 4.
        let pl = Placement { profile: Profile::P3g20gb, start: 0 };
        dc.place(&spec(1, Profile::P3g20gb, 1, 1), r, pl);
        assert!(!dc.index().gpus_fitting(Profile::P4g20gb).contains(&r));
        assert!(!dc.index().gpus_fitting(Profile::P7g40gb).contains(&r));
        assert!(dc.index().gpus_fitting(Profile::P3g20gb).contains(&r));
        assert!(dc.index().gpus_fitting(Profile::P1g5gb).contains(&r));
    }

    #[test]
    fn a30_occupancy_tracks_its_own_buckets() {
        let mut dc = mixed_dc();
        let r = GpuRef { host: 0, gpu: 0 }; // the A30
        let k2g = GpuModel::A30.profile(1);
        let k4g = GpuModel::A30.profile(2);
        dc.place(&spec(1, k2g, 1, 1), r, Placement { profile: k2g, start: 0 });
        assert!(!dc.index().gpus_fitting(k4g).contains(&r));
        assert!(dc.index().gpus_fitting(k2g).contains(&r)); // start 2 free
        // The A100 buckets are untouched by A30 occupancy changes.
        for p in ALL_PROFILES {
            assert_eq!(dc.index().fitting_count(p), 2, "{p}");
        }
        dc.check_integrity().unwrap();
    }

    /// Satellite acceptance: after random place/remove/migrate/relocate
    /// sequences — on a single-model *or* mixed-model cluster — every
    /// bucket and headroom class equals a brute-force recomputation from
    /// the GPU/host states, and `check_integrity` (which embeds the same
    /// comparison) passes.
    #[test]
    fn prop_incremental_index_matches_brute_force() {
        forall(
            "cluster-index-vs-brute-force",
            |r: &mut Rng| {
                let mut dc = if r.chance(0.5) { small_dc() } else { mixed_dc() };
                let mut next_vm: u64 = 1;
                let mut resident: Vec<u64> = Vec::new();
                let refs: Vec<GpuRef> = dc.gpu_refs();
                for _ in 0..48 {
                    match r.below(4) {
                        0 | 1 => {
                            // Place on a random feasible GPU (a profile of
                            // that GPU's own model).
                            let gr = refs[r.below(refs.len() as u64) as usize];
                            let model = dc.gpu(gr).model();
                            let profile =
                                model.profile(r.below(model.num_profiles() as u64) as usize);
                            let (cpus, ram) = (1 + r.below(3) as u32, 1 + r.below(4) as u32);
                            let vm = spec(next_vm, profile, cpus, ram);
                            let host_ok = dc.host(gr.host).fits_resources(vm.cpus, vm.ram_gb);
                            if let (true, Some((pl, _))) =
                                (host_ok, mock_assign(dc.gpu(gr).occupancy(), profile))
                            {
                                dc.place(&vm, gr, pl);
                                resident.push(next_vm);
                                next_vm += 1;
                            }
                        }
                        2 => {
                            // Remove a random resident VM.
                            if !resident.is_empty() {
                                let i = r.below(resident.len() as u64) as usize;
                                let vm = resident.swap_remove(i);
                                dc.remove(vm);
                            }
                        }
                        _ => {
                            if resident.is_empty() {
                                continue;
                            }
                            let vm = resident[r.below(resident.len() as u64) as usize];
                            let loc = dc.locate(vm).unwrap();
                            if r.chance(0.5) {
                                // Intra-GPU relocation to another legal start.
                                let occ = dc.gpu(loc.gpu).occupancy() & !loc.placement.mask();
                                let starts: Vec<u8> =
                                    feasible_starts(loc.placement.profile, occ).collect();
                                let s = starts[r.below(starts.len() as u64) as usize];
                                dc.relocate_within_gpu(
                                    vm,
                                    Placement { profile: loc.placement.profile, start: s },
                                );
                            } else {
                                // Inter-GPU migration to a random feasible
                                // GPU of the same model.
                                let dst = refs[r.below(refs.len() as u64) as usize];
                                if dst == loc.gpu
                                    || dc.gpu(dst).model() != loc.placement.profile.model()
                                {
                                    continue;
                                }
                                let (cpus, ram) = dc.vm_demands(vm).unwrap();
                                if dst.host != loc.gpu.host
                                    && !dc.host(dst.host).fits_resources(cpus, ram)
                                {
                                    continue;
                                }
                                if let Some((pl, _)) =
                                    mock_assign(dc.gpu(dst).occupancy(), loc.placement.profile)
                                {
                                    dc.migrate(vm, dst, pl);
                                }
                            }
                        }
                    }
                }
                dc
            },
            |dc| {
                let rebuilt = ClusterIndex::build(dc.hosts());
                if &rebuilt != dc.index() {
                    return Err("incremental index diverged from brute-force rebuild".into());
                }
                // The O(1) activity counters must match a brute-force
                // recount after the same mutation sequence.
                if dc.active_hardware() != dc.active_hardware_scan() {
                    return Err("activity counters diverged from fleet recount".into());
                }
                if dc.active_gpus_by_model() != dc.active_gpus_by_model_scan() {
                    return Err("per-model activity diverged from fleet recount".into());
                }
                // GPUs only ever sit in buckets of their own model.
                for key in ProfileKey::all() {
                    for r in dc.index().gpus_fitting(key) {
                        if dc.gpu(*r).model() != key.model() {
                            return Err(format!("{key}: foreign-model GPU in bucket"));
                        }
                    }
                }
                dc.check_integrity().map_err(|e| format!("integrity: {e}"))
            },
        );
    }
}
