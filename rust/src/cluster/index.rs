//! The incrementally maintained cluster placement index.
//!
//! Every policy used to re-materialize `gpu_refs()` and linearly probe
//! all GPUs per request — O(cluster) per VM. The [`ClusterIndex`] turns
//! both admission questions into indexed lookups, maintained by
//! [`super::DataCenter`] on every `place`/`remove`/`migrate`/
//! `relocate_within_gpu`/`repack_gpu`:
//!
//! * **Per-profile GPU feasibility buckets**, keyed by the dense
//!   cross-model [`Profile::dense`] index: GPU `r` is in bucket `k` iff
//!   `r`'s model owns key `k` and `profile_capacity_for(model,
//!   occ)[k.index()] > 0`. A GPU therefore only ever appears in buckets
//!   of its own model's profiles, which is what confines every policy
//!   scan to model-compatible candidates. A state change moves a GPU in
//!   or out of a bucket only when that profile's feasible-start count
//!   crosses zero, so an update is a handful of table lookups plus O(1)
//!   bit operations.
//! * **Per-model schedulable sets** ([`ClusterIndex::schedulable`]):
//!   every healthy GPU of a model on a healthy host, independent of
//!   occupancy. These back whole-fleet walks that previously scanned
//!   `hosts()` — the ILP window extraction and the sharded router's
//!   rebalance receiver probe.
//! * **Host headroom histograms** of free CPU / free RAM over
//!   GPU-equipped hosts, answering "could any host take this VM?" and
//!   the CPU-vs-RAM rejection classification from cached maxima/minima
//!   in O(1).
//!
//! ## Index v2 layout (PR 10)
//!
//! The buckets were `BTreeSet<GpuRef>` through PR 9; at fleet scale the
//! innermost placement loop was dominated by B-tree pointer chasing.
//! They are now a **two-level hierarchical bitset** per profile key:
//!
//! * A static [`SlotMap`] numbers every GPU of the fleet (healthy or
//!   not) with a dense *slot* in ascending `GpuRef` order. The map is
//!   derived purely from fleet topology (host ids and GPU counts never
//!   change after construction), so it is identical across health
//!   transitions and across `build` vs incremental maintenance.
//! * Each bucket is a leaf `Vec<u64>` (bit per slot) plus a summary
//!   layer (bit per nonzero leaf word). Set/clear is O(1);
//!   find-first/next-set is one or two `trailing_zeros` per step; a
//!   word of 64 candidates occupies 8 contiguous bytes instead of 64
//!   B-tree entries.
//!
//! Consumers read buckets through the [`GpuSetView`] facade
//! (`iter`/`contains`/`len` in `GpuRef` terms), and set algebra against
//! an external GPU set — GRMU's basket ∩ bucket intersection — is a
//! word-wise AND via [`GpuBits`] + [`GpuSetView::and_iter`].
//!
//! The headroom multisets were `BTreeMap<u32, u32>`; free-CPU/free-RAM
//! classes are small integers, so they are now flat histograms
//! ([`Hist`]) with cached max/min. Increments update the cache
//! directly; removing the last host of an extreme class rescans — a
//! bounded walk over the (tiny) class range, amortized O(1).
//!
//! ## Determinism contract
//!
//! Buckets iterate in ascending [`GpuRef`] order — the paper's
//! `globalIndex` (Algorithm 2). With the bitset layout this holds *by
//! construction*: slots ascend with `GpuRef`, and `trailing_zeros`
//! iteration visits slots in ascending order. A bucket is therefore
//! exactly the feasible *subsequence* of a full `globalIndex` scan
//! (foreign-model GPUs are infeasible by definition), which is what
//! makes first-fit and best-scoring selections over bucket candidates
//! byte-identical to the pre-index full scans (locked by the
//! indexed-vs-scan equivalence tests in `rust/tests/decision_api.rs`).
//!
//! ## Health contract
//!
//! The index covers **schedulable** capacity only: a GPU appears in
//! buckets (and its model's [`ClusterIndex::schedulable`] set) iff it
//! and its host are [`Healthy`](crate::cluster::HealthState); an
//! unavailable host also leaves the headroom histograms and the
//! per-model host counts. [`ClusterIndex::build`] skips unhealthy
//! capacity, and [`super::DataCenter`]'s health mutators attach/detach
//! entries on availability transitions, so the "rebuild equals
//! incremental" comparison in `check_integrity` verifies the contract
//! for free (plus the structural [`ClusterIndex::check_invariants`]:
//! summary/leaf coherence, cached lengths and histogram extremes). On
//! an all-healthy fleet every skip condition is vacuous and the index
//! is bit-for-bit the pre-health one.

use super::datacenter::GpuRef;
use super::host::Host;
use crate::mig::gpu::profile_capacity_for;
use crate::mig::{BlockMask, GpuModel, Profile, NUM_MODELS, NUM_PROFILE_KEYS};

/// Dense GPU numbering in ascending [`GpuRef`] order, shared by every
/// bucket of one [`ClusterIndex`]. Built once from fleet topology
/// (which is immutable after construction) and never touched by
/// occupancy or health changes.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
struct SlotMap {
    /// Host position → first slot of that host's GPUs.
    base: Vec<u32>,
    /// Slot → the `GpuRef` it denotes (ascending).
    refs: Vec<GpuRef>,
}

impl SlotMap {
    fn build(hosts: &[Host]) -> SlotMap {
        let mut base = Vec::with_capacity(hosts.len());
        let mut refs = Vec::new();
        for (pos, h) in hosts.iter().enumerate() {
            debug_assert_eq!(h.id as usize, pos, "host id must equal its position");
            base.push(refs.len() as u32);
            for g in 0..h.gpus().len() {
                refs.push(GpuRef { host: h.id, gpu: g as u8 });
            }
        }
        SlotMap { base, refs }
    }

    #[inline]
    fn slot_of(&self, r: GpuRef) -> usize {
        self.base[r.host as usize] as usize + r.gpu as usize
    }

    #[inline]
    fn num_slots(&self) -> usize {
        self.refs.len()
    }
}

/// One two-level bitset over the fleet's slots: `words` holds a bit per
/// slot, `summary` a bit per nonzero leaf word, `len` the popcount.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
struct BitBucket {
    words: Vec<u64>,
    summary: Vec<u64>,
    len: u32,
}

fn words_for(bits: usize) -> usize {
    (bits + 63) / 64
}

impl BitBucket {
    fn with_slots(slots: usize) -> BitBucket {
        let leaves = words_for(slots);
        BitBucket { words: vec![0; leaves], summary: vec![0; words_for(leaves)], len: 0 }
    }

    /// Idempotent insert.
    #[inline]
    fn set(&mut self, slot: usize) {
        let (w, bit) = (slot / 64, 1u64 << (slot % 64));
        if self.words[w] & bit == 0 {
            self.words[w] |= bit;
            self.summary[w / 64] |= 1 << (w % 64);
            self.len += 1;
        }
    }

    /// Idempotent remove.
    #[inline]
    fn clear(&mut self, slot: usize) {
        let (w, bit) = (slot / 64, 1u64 << (slot % 64));
        if self.words[w] & bit != 0 {
            self.words[w] &= !bit;
            if self.words[w] == 0 {
                self.summary[w / 64] &= !(1 << (w % 64));
            }
            self.len -= 1;
        }
    }

    #[inline]
    fn contains(&self, slot: usize) -> bool {
        self.words[slot / 64] & (1 << (slot % 64)) != 0
    }

    fn check(&self, what: &str, slots: usize) -> Result<(), String> {
        if self.words.len() != words_for(slots) || self.summary.len() != words_for(self.words.len())
        {
            return Err(format!("{what}: bitset sized for a different fleet"));
        }
        let pop: u32 = self.words.iter().map(|w| w.count_ones()).sum();
        if pop != self.len {
            return Err(format!("{what}: cached len {} != popcount {pop}", self.len));
        }
        for (w, &word) in self.words.iter().enumerate() {
            let summarized = self.summary[w / 64] & (1 << (w % 64)) != 0;
            if summarized != (word != 0) {
                return Err(format!("{what}: summary bit {w} out of sync with leaf word"));
            }
        }
        if slots % 64 != 0 {
            if let Some(&last) = self.words.last() {
                if last >> (slots % 64) != 0 {
                    return Err(format!("{what}: bits set past the last slot"));
                }
            }
        }
        Ok(())
    }
}

/// Borrowed read view of one feasibility bucket (or schedulable set):
/// the bitset plus the slot map that translates slots back to
/// [`GpuRef`]s. `Copy`, so it can be passed around like the old
/// `&BTreeSet<GpuRef>` handle.
#[derive(Clone, Copy)]
pub struct GpuSetView<'a> {
    bucket: &'a BitBucket,
    slots: &'a SlotMap,
}

impl<'a> GpuSetView<'a> {
    /// Number of GPUs in the set.
    #[inline]
    pub fn len(&self) -> usize {
        self.bucket.len as usize
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.bucket.len == 0
    }

    /// Membership test in O(1).
    #[inline]
    pub fn contains(&self, r: GpuRef) -> bool {
        self.bucket.contains(self.slots.slot_of(r))
    }

    /// Iterate members in ascending [`GpuRef`] order (the `globalIndex`
    /// contract), yielding `GpuRef` by value.
    #[inline]
    pub fn iter(&self) -> GpuSetIter<'a> {
        GpuSetIter {
            bucket: self.bucket,
            slots: self.slots,
            word: 0,
            bits: 0,
            sum_word: 0,
            sum_bits: self.bucket.summary.first().copied().unwrap_or(0),
        }
    }

    /// Iterate `self ∩ mask` in ascending [`GpuRef`] order via a
    /// word-wise AND — GRMU's basket-intersection hot path. The mask
    /// must have been created against the same index topology.
    #[inline]
    pub fn and_iter(&self, mask: &'a GpuBits) -> GpuAndIter<'a> {
        GpuAndIter {
            bucket: self.bucket,
            mask: &mask.words,
            slots: self.slots,
            word: 0,
            bits: 0,
            sum_word: 0,
            sum_bits: self.bucket.summary.first().copied().unwrap_or(0),
        }
    }
}

impl<'a> IntoIterator for GpuSetView<'a> {
    type Item = GpuRef;
    type IntoIter = GpuSetIter<'a>;
    fn into_iter(self) -> GpuSetIter<'a> {
        self.iter()
    }
}

/// Ascending-`GpuRef` iterator over one [`GpuSetView`]. The summary
/// layer skips runs of 64 empty words; within a word, members pop out
/// via `trailing_zeros` / clear-lowest-set-bit.
pub struct GpuSetIter<'a> {
    bucket: &'a BitBucket,
    slots: &'a SlotMap,
    word: usize,
    bits: u64,
    sum_word: usize,
    sum_bits: u64,
}

impl Iterator for GpuSetIter<'_> {
    type Item = GpuRef;

    #[inline]
    fn next(&mut self) -> Option<GpuRef> {
        loop {
            if self.bits != 0 {
                let b = self.bits.trailing_zeros() as usize;
                self.bits &= self.bits - 1;
                return Some(self.slots.refs[self.word * 64 + b]);
            }
            loop {
                if self.sum_bits != 0 {
                    let w = self.sum_bits.trailing_zeros() as usize;
                    self.sum_bits &= self.sum_bits - 1;
                    self.word = self.sum_word * 64 + w;
                    self.bits = self.bucket.words[self.word];
                    break;
                }
                self.sum_word += 1;
                if self.sum_word >= self.bucket.summary.len() {
                    return None;
                }
                self.sum_bits = self.bucket.summary[self.sum_word];
            }
        }
    }
}

/// Ascending-`GpuRef` iterator over `bucket ∩ mask`
/// ([`GpuSetView::and_iter`]). Driven by the bucket's summary layer;
/// each candidate word costs one AND.
pub struct GpuAndIter<'a> {
    bucket: &'a BitBucket,
    mask: &'a [u64],
    slots: &'a SlotMap,
    word: usize,
    bits: u64,
    sum_word: usize,
    sum_bits: u64,
}

impl Iterator for GpuAndIter<'_> {
    type Item = GpuRef;

    #[inline]
    fn next(&mut self) -> Option<GpuRef> {
        loop {
            if self.bits != 0 {
                let b = self.bits.trailing_zeros() as usize;
                self.bits &= self.bits - 1;
                return Some(self.slots.refs[self.word * 64 + b]);
            }
            loop {
                if self.sum_bits != 0 {
                    let w = self.sum_bits.trailing_zeros() as usize;
                    self.sum_bits &= self.sum_bits - 1;
                    self.word = self.sum_word * 64 + w;
                    self.bits = self.bucket.words[self.word]
                        & self.mask.get(self.word).copied().unwrap_or(0);
                    if self.bits != 0 {
                        break;
                    }
                } else {
                    self.sum_word += 1;
                    if self.sum_word >= self.bucket.summary.len() {
                        return None;
                    }
                    self.sum_bits = self.bucket.summary[self.sum_word];
                }
            }
        }
    }
}

/// An external GPU set in the index's slot space — the mask side of
/// [`GpuSetView::and_iter`]. Policies that keep their own GPU
/// groupings (GRMU's heavy/light baskets) mirror them into a `GpuBits`
/// so the per-request basket ∩ bucket intersection is a word-wise AND
/// instead of an ordered-set merge.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GpuBits {
    words: Vec<u64>,
}

impl GpuBits {
    /// An empty set sized for `index`'s fleet.
    pub fn for_index(index: &ClusterIndex) -> GpuBits {
        GpuBits { words: vec![0; words_for(index.slots.num_slots())] }
    }

    /// Idempotent insert (`index` supplies the slot mapping).
    #[inline]
    pub fn insert(&mut self, index: &ClusterIndex, r: GpuRef) {
        let slot = index.slots.slot_of(r);
        self.words[slot / 64] |= 1 << (slot % 64);
    }

    /// Idempotent remove.
    #[inline]
    pub fn remove(&mut self, index: &ClusterIndex, r: GpuRef) {
        let slot = index.slots.slot_of(r);
        self.words[slot / 64] &= !(1 << (slot % 64));
    }

    #[inline]
    pub fn contains(&self, index: &ClusterIndex, r: GpuRef) -> bool {
        let slot = index.slots.slot_of(r);
        self.words[slot / 64] & (1 << (slot % 64)) != 0
    }
}

/// Flat headroom histogram with cached extremes: `counts[c]` = number
/// of GPU-equipped hosts whose free CPU (or RAM) equals `c`. Classes
/// are small integers (bounded by the largest host), so the backing
/// vector stays tiny and max/min maintenance on removal is a bounded
/// scan toward the surviving population.
#[derive(Debug, Clone, Default)]
struct Hist {
    counts: Vec<u32>,
    /// Total number of entries across all classes.
    present: u32,
    /// Largest / smallest populated class; both 0 when `present == 0`
    /// (mirroring the old `BTreeMap` readers' `unwrap_or(0)`).
    max: u32,
    min: u32,
}

impl Hist {
    fn insert(&mut self, class: u32) {
        let i = class as usize;
        if i >= self.counts.len() {
            self.counts.resize(i + 1, 0);
        }
        self.counts[i] += 1;
        if self.present == 0 {
            self.max = class;
            self.min = class;
        } else {
            self.max = self.max.max(class);
            self.min = self.min.min(class);
        }
        self.present += 1;
    }

    fn remove(&mut self, class: u32) {
        let i = class as usize;
        match self.counts.get_mut(i) {
            Some(n) if *n > 0 => {
                *n -= 1;
                self.present -= 1;
                if self.present == 0 {
                    self.max = 0;
                    self.min = 0;
                    return;
                }
                if self.counts[i] == 0 {
                    // `present > 0` guarantees a populated class on the
                    // far side of each rescan.
                    if class == self.max {
                        let mut c = i;
                        while self.counts[c] == 0 {
                            c -= 1;
                        }
                        self.max = c as u32;
                    }
                    if class == self.min {
                        let mut c = i;
                        while self.counts[c] == 0 {
                            c += 1;
                        }
                        self.min = c as u32;
                    }
                }
            }
            _ => debug_assert!(false, "headroom histogram missing class {class}"),
        }
    }

    fn shift(&mut self, old: u32, new: u32) {
        if old == new {
            return;
        }
        self.remove(old);
        self.insert(new);
    }

    fn check(&self, what: &str) -> Result<(), String> {
        let total: u32 = self.counts.iter().sum();
        if total != self.present {
            return Err(format!("{what}: cached total {} != recount {total}", self.present));
        }
        if self.present == 0 {
            if self.max != 0 || self.min != 0 {
                return Err(format!("{what}: empty histogram with nonzero extremes"));
            }
            return Ok(());
        }
        let lo = self.counts.iter().position(|&n| n > 0).unwrap() as u32;
        let hi = self.counts.iter().rposition(|&n| n > 0).unwrap() as u32;
        if self.min != lo || self.max != hi {
            return Err(format!(
                "{what}: cached extremes {}..{} != populated range {lo}..{hi}",
                self.min, self.max
            ));
        }
        Ok(())
    }
}

/// The incremental histogram may carry trailing zero classes that a
/// fresh rebuild never allocates; compare logical content.
impl PartialEq for Hist {
    fn eq(&self, other: &Hist) -> bool {
        if self.present != other.present || self.max != other.max || self.min != other.min {
            return false;
        }
        let classes = self.counts.len().max(other.counts.len());
        (0..classes).all(|c| {
            self.counts.get(c).copied().unwrap_or(0) == other.counts.get(c).copied().unwrap_or(0)
        })
    }
}

impl Eq for Hist {}

/// Index over the live cluster state. Owned and kept coherent by
/// [`super::DataCenter`]; consumers only read it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterIndex {
    /// Static GpuRef ↔ slot numbering shared by all bitsets below.
    slots: SlotMap,
    /// `buckets[k]` = GPUs where the profile with dense index `k`
    /// currently fits, in `globalIndex` order.
    buckets: Vec<BitBucket>,
    /// `sched[m]` = schedulable GPUs of model `m`, occupancy-blind.
    sched: Vec<BitBucket>,
    /// Histogram of free CPU cores per GPU-equipped host.
    free_cpus: Hist,
    /// Histogram of free RAM (GB) per GPU-equipped host.
    free_ram: Hist,
    /// Number of GPU-equipped hosts (hosts without GPUs never receive a
    /// VM and are excluded from the headroom histograms).
    host_count: u32,
    /// Hosts carrying at least one GPU of each model (static per fleet:
    /// GPU models never change after construction). Drives the
    /// model-aware rejection classification fast paths.
    hosts_with_model: [u32; NUM_MODELS],
}

impl ClusterIndex {
    /// Brute-force (re)construction from host/GPU states — the reference
    /// the incremental maintenance is tested against, and what
    /// [`super::DataCenter::check_integrity`] compares with.
    pub fn build(hosts: &[Host]) -> ClusterIndex {
        let slots = SlotMap::build(hosts);
        let n = slots.num_slots();
        let mut idx = ClusterIndex {
            slots,
            buckets: (0..NUM_PROFILE_KEYS).map(|_| BitBucket::with_slots(n)).collect(),
            sched: (0..NUM_MODELS).map(|_| BitBucket::with_slots(n)).collect(),
            free_cpus: Hist::default(),
            free_ram: Hist::default(),
            host_count: 0,
            hosts_with_model: [0; NUM_MODELS],
        };
        for h in hosts {
            if h.gpus().is_empty() || !h.health().allows_placement() {
                continue;
            }
            idx.attach_host(h);
        }
        idx
    }

    /// Insert an available host: headroom classes, per-model counts and
    /// the buckets of its schedulable GPUs. Called by `build` and by
    /// [`super::DataCenter`] when a host transitions back to healthy.
    pub(crate) fn attach_host(&mut self, h: &Host) {
        self.host_count += 1;
        self.free_cpus.insert(h.free_cpus());
        self.free_ram.insert(h.free_ram());
        let mut present = [false; NUM_MODELS];
        for gpu in h.gpus() {
            present[gpu.model() as usize] = true;
        }
        for (m, here) in present.into_iter().enumerate() {
            if here {
                self.hosts_with_model[m] += 1;
            }
        }
        for (g, gpu) in h.gpus().iter().enumerate() {
            if !h.gpu_health(g).allows_placement() {
                continue;
            }
            let r = GpuRef { host: h.id, gpu: g as u8 };
            self.attach_gpu(r, gpu.model(), gpu.occupancy());
        }
    }

    /// Remove a host that became unavailable: the exact inverse of
    /// [`ClusterIndex::attach_host`] against the same host state.
    pub(crate) fn detach_host(&mut self, h: &Host) {
        debug_assert!(self.host_count > 0);
        self.host_count -= 1;
        self.free_cpus.remove(h.free_cpus());
        self.free_ram.remove(h.free_ram());
        let mut present = [false; NUM_MODELS];
        for gpu in h.gpus() {
            present[gpu.model() as usize] = true;
        }
        for (m, here) in present.into_iter().enumerate() {
            if here {
                debug_assert!(self.hosts_with_model[m] > 0);
                self.hosts_with_model[m] -= 1;
            }
        }
        for (g, gpu) in h.gpus().iter().enumerate() {
            if !h.gpu_health(g).allows_placement() {
                continue; // was never in the buckets
            }
            let r = GpuRef { host: h.id, gpu: g as u8 };
            self.detach_gpu(r, gpu.model(), gpu.occupancy());
        }
    }

    /// Insert one schedulable GPU into its model's schedulable set and
    /// the buckets its occupancy allows.
    pub(crate) fn attach_gpu(&mut self, r: GpuRef, model: GpuModel, occ: BlockMask) {
        let slot = self.slots.slot_of(r);
        self.sched[model as usize].set(slot);
        let cap = profile_capacity_for(model, occ);
        for key in model.profile_keys() {
            if cap[key.index()] > 0 {
                self.buckets[key.dense()].set(slot);
            }
        }
    }

    /// Remove one GPU from its model's schedulable set and every bucket
    /// its occupancy had it in.
    pub(crate) fn detach_gpu(&mut self, r: GpuRef, model: GpuModel, occ: BlockMask) {
        let slot = self.slots.slot_of(r);
        self.sched[model as usize].clear(slot);
        let cap = profile_capacity_for(model, occ);
        for key in model.profile_keys() {
            if cap[key.index()] > 0 {
                self.buckets[key.dense()].clear(slot);
            }
        }
    }

    /// GPUs where `profile` currently fits (all of the profile's model),
    /// in `globalIndex` order.
    #[inline]
    pub fn gpus_fitting(&self, profile: Profile) -> GpuSetView<'_> {
        GpuSetView { bucket: &self.buckets[profile.dense()], slots: &self.slots }
    }

    /// Schedulable GPUs of `model` (healthy device on healthy host),
    /// regardless of occupancy, in `globalIndex` order.
    #[inline]
    pub fn schedulable(&self, model: GpuModel) -> GpuSetView<'_> {
        GpuSetView { bucket: &self.sched[model as usize], slots: &self.slots }
    }

    /// Number of GPUs with at least one feasible start for `profile`.
    pub fn fitting_count(&self, profile: Profile) -> usize {
        self.buckets[profile.dense()].len as usize
    }

    /// Number of GPU-equipped hosts.
    #[inline]
    pub fn num_hosts(&self) -> u32 {
        self.host_count
    }

    /// Number of hosts carrying at least one GPU of `model` — the
    /// candidate-host population for a request of that model (Eq. 17–18
    /// compatibility).
    #[inline]
    pub fn hosts_with_model(&self, model: GpuModel) -> u32 {
        self.hosts_with_model[model as usize]
    }

    /// Largest free-CPU headroom of any GPU-equipped host (0 when empty).
    #[inline]
    pub fn max_free_cpus(&self) -> u32 {
        self.free_cpus.max
    }

    /// Smallest free-CPU headroom of any GPU-equipped host (0 when empty).
    #[inline]
    pub fn min_free_cpus(&self) -> u32 {
        self.free_cpus.min
    }

    /// Largest free-RAM headroom of any GPU-equipped host (0 when empty).
    #[inline]
    pub fn max_free_ram(&self) -> u32 {
        self.free_ram.max
    }

    /// Smallest free-RAM headroom of any GPU-equipped host (0 when empty).
    #[inline]
    pub fn min_free_ram(&self) -> u32 {
        self.free_ram.min
    }

    /// Admission precheck: `false` guarantees no GPU-equipped host has
    /// both the CPU and the RAM for this request (the maxima already
    /// fail one-sidedly), so a full scan can be skipped. `true` is
    /// one-sided — the CPU and RAM maxima may live on different hosts.
    #[inline]
    pub fn host_may_fit(&self, cpus: u32, ram_gb: u32) -> bool {
        self.max_free_cpus() >= cpus && self.max_free_ram() >= ram_gb
    }

    /// Re-bucket one GPU of `model` after its occupancy changed.
    pub(crate) fn update_gpu(
        &mut self,
        r: GpuRef,
        model: GpuModel,
        old_occ: BlockMask,
        new_occ: BlockMask,
    ) {
        if old_occ == new_occ {
            return;
        }
        let slot = self.slots.slot_of(r);
        let old_cap = profile_capacity_for(model, old_occ);
        let new_cap = profile_capacity_for(model, new_occ);
        for key in model.profile_keys() {
            let p = key.index();
            match (old_cap[p] > 0, new_cap[p] > 0) {
                (false, true) => self.buckets[key.dense()].set(slot),
                (true, false) => self.buckets[key.dense()].clear(slot),
                _ => {}
            }
        }
    }

    /// Move one host between headroom classes after a reserve/release.
    pub(crate) fn update_host(&mut self, old_free: (u32, u32), new_free: (u32, u32)) {
        self.free_cpus.shift(old_free.0, new_free.0);
        self.free_ram.shift(old_free.1, new_free.1);
    }

    /// Structural self-check of the v2 layout, run by
    /// [`super::DataCenter::check_integrity`] *in addition to* the
    /// rebuild-equality comparison: every summary bit mirrors its leaf
    /// word, cached lengths equal popcounts, no bits sit past the last
    /// slot, and the histogram caches match a recount.
    pub fn check_invariants(&self) -> Result<(), String> {
        let n = self.slots.num_slots();
        for (k, b) in self.buckets.iter().enumerate() {
            b.check(&format!("bucket {k}"), n)?;
        }
        for (m, b) in self.sched.iter().enumerate() {
            b.check(&format!("sched set {m}"), n)?;
        }
        self.free_cpus.check("free-CPU histogram")?;
        self.free_ram.check("free-RAM histogram")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{DataCenter, HealthState, Host, VmSpec};
    use crate::mig::gpu::feasible_starts;
    use crate::mig::placement::mock_assign;
    use crate::mig::profiles::ALL_PROFILES;
    use crate::mig::{Placement, ProfileKey, ALL_MODELS};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn spec(id: u64, profile: Profile, cpus: u32, ram_gb: u32) -> VmSpec {
        VmSpec { id, profile, cpus, ram_gb, arrival: 0, departure: 1_000, weight: 1.0 }
    }

    fn small_dc() -> DataCenter {
        DataCenter::new(vec![
            Host::new(0, 16, 64, 2),
            Host::new(1, 16, 64, 3),
            Host::new(2, 8, 32, 1),
        ])
    }

    /// Mixed A30 / A100-40 / H100-80 cluster for the heterogeneity tests.
    fn mixed_dc() -> DataCenter {
        DataCenter::new(vec![
            Host::with_models(0, 16, 64, &[GpuModel::A30, GpuModel::A100_40]),
            Host::with_models(1, 16, 64, &[GpuModel::H100_80, GpuModel::A30, GpuModel::A100_40]),
            Host::with_models(2, 8, 32, &[GpuModel::H100_80]),
        ])
    }

    /// Brute-force bucket recomputation: the schedulable GPUs of the
    /// profile's model where `mock_assign` finds a start, in scan order.
    fn scan_bucket(dc: &DataCenter, key: ProfileKey) -> Vec<GpuRef> {
        let mut out = Vec::new();
        for h in dc.hosts() {
            for (g, gpu) in h.gpus().iter().enumerate() {
                if gpu.model() == key.model()
                    && h.gpu_available(g)
                    && mock_assign(gpu.occupancy(), key).is_some()
                {
                    out.push(GpuRef { host: h.id, gpu: g as u8 });
                }
            }
        }
        out
    }

    #[test]
    fn build_on_empty_cluster_buckets_every_gpu() {
        let dc = small_dc();
        for p in ALL_PROFILES {
            assert_eq!(dc.index().fitting_count(p), 6, "{p}");
        }
        assert_eq!(dc.index().num_hosts(), 3);
        assert_eq!(dc.index().max_free_cpus(), 16);
        assert_eq!(dc.index().min_free_cpus(), 8);
        assert_eq!(dc.index().max_free_ram(), 64);
        assert_eq!(dc.index().min_free_ram(), 32);
    }

    #[test]
    fn buckets_are_model_segregated() {
        let dc = mixed_dc();
        // Two A100-40 GPUs, two A30s, two H100-80s.
        for p in ALL_PROFILES {
            assert_eq!(dc.index().fitting_count(p), 2, "{p}");
        }
        for k in GpuModel::A30.profile_keys() {
            assert_eq!(dc.index().fitting_count(k), 2, "{k}");
            for r in dc.index().gpus_fitting(k) {
                assert_eq!(dc.gpu(r).model(), GpuModel::A30, "{k}");
            }
        }
        // No A100-80s in this fleet: buckets empty.
        for k in GpuModel::A100_80.profile_keys() {
            assert_eq!(dc.index().fitting_count(k), 0, "{k}");
        }
    }

    #[test]
    fn view_iterates_ascending_and_agrees_with_contains() {
        let dc = mixed_dc();
        for key in ProfileKey::all() {
            let got: Vec<GpuRef> = dc.index().gpus_fitting(key).iter().collect();
            let mut sorted = got.clone();
            sorted.sort();
            assert_eq!(got, sorted, "{key}: iteration not ascending");
            assert_eq!(got.len(), dc.index().gpus_fitting(key).len(), "{key}");
            for r in &got {
                assert!(dc.index().gpus_fitting(key).contains(*r), "{key}");
            }
            assert_eq!(got, scan_bucket(&dc, key), "{key}");
        }
    }

    #[test]
    fn word_and_intersection_matches_filtered_iteration() {
        let dc = small_dc();
        let idx = dc.index();
        // Mask covering every other GPU of the fleet.
        let mut mask = GpuBits::for_index(idx);
        let all: Vec<GpuRef> = dc.gpu_refs();
        for (i, &r) in all.iter().enumerate() {
            if i % 2 == 0 {
                mask.insert(idx, r);
            }
        }
        for p in ALL_PROFILES {
            let anded: Vec<GpuRef> = idx.gpus_fitting(p).and_iter(&mask).collect();
            let filtered: Vec<GpuRef> =
                idx.gpus_fitting(p).iter().filter(|&r| mask.contains(idx, r)).collect();
            assert_eq!(anded, filtered, "{p}");
        }
        // Removal empties the intersection again.
        for &r in &all {
            mask.remove(idx, r);
        }
        assert_eq!(idx.gpus_fitting(Profile::P1g5gb).and_iter(&mask).count(), 0);
    }

    #[test]
    fn full_gpu_leaves_every_bucket() {
        let mut dc = small_dc();
        let r = GpuRef { host: 0, gpu: 0 };
        let pl = Placement { profile: Profile::P7g40gb, start: 0 };
        dc.place(&spec(1, Profile::P7g40gb, 4, 8), r, pl);
        for p in ALL_PROFILES {
            assert!(!dc.index().gpus_fitting(p).contains(r), "{p}");
        }
        dc.remove(1);
        for p in ALL_PROFILES {
            assert!(dc.index().gpus_fitting(p).contains(r), "{p}");
        }
    }

    #[test]
    fn headroom_tracks_reservations() {
        let mut dc = small_dc();
        let r = GpuRef { host: 0, gpu: 0 };
        let pl = Placement { profile: Profile::P1g5gb, start: 6 };
        dc.place(&spec(1, Profile::P1g5gb, 10, 40), r, pl);
        assert_eq!(dc.index().max_free_cpus(), 16); // host 1 untouched
        assert!(dc.index().host_may_fit(16, 64));
        assert!(!dc.index().host_may_fit(17, 1));
        assert!(!dc.index().host_may_fit(1, 65));
        assert_eq!(dc.index().min_free_cpus(), 6); // host 0: 16 - 10
        dc.remove(1);
        assert_eq!(dc.index().min_free_cpus(), 8); // back to host 2's 8
    }

    #[test]
    fn histogram_extremes_survive_class_exhaustion() {
        let mut h = Hist::default();
        for class in [8, 16, 16, 4, 32] {
            h.insert(class);
        }
        assert_eq!((h.min, h.max, h.present), (4, 32, 5));
        h.remove(32); // exhausts the max class: rescan lands on 16
        assert_eq!((h.min, h.max), (4, 16));
        h.remove(4); // exhausts the min class: rescan lands on 8
        assert_eq!((h.min, h.max), (8, 16));
        h.remove(16); // one of two: no rescan needed
        assert_eq!((h.min, h.max), (8, 16));
        h.remove(16);
        h.remove(8);
        assert_eq!((h.min, h.max, h.present), (0, 0, 0));
        h.check("unit").unwrap();
        // Logical equality ignores trailing zero classes.
        let mut tall = Hist::default();
        tall.insert(40);
        tall.remove(40);
        assert_eq!(tall, Hist::default());
    }

    #[test]
    fn schedulable_sets_track_health_transitions() {
        let mut dc = mixed_dc();
        let a30 = GpuRef { host: 0, gpu: 0 };
        assert!(dc.index().schedulable(GpuModel::A30).contains(a30));
        assert_eq!(dc.index().schedulable(GpuModel::A30).len(), 2);
        dc.set_gpu_health(a30, HealthState::Failed { until: 100 });
        assert!(!dc.index().schedulable(GpuModel::A30).contains(a30));
        assert_eq!(dc.index().schedulable(GpuModel::A30).len(), 1);
        dc.set_host_health(1, HealthState::Draining); // takes the other A30 down too
        assert!(dc.index().schedulable(GpuModel::A30).is_empty());
        dc.set_gpu_health(a30, HealthState::Healthy);
        dc.set_host_health(1, HealthState::Healthy);
        assert_eq!(dc.index().schedulable(GpuModel::A30).len(), 2);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn partial_occupancy_tracks_capacity_zero_crossings() {
        let mut dc = small_dc();
        let r = GpuRef { host: 1, gpu: 2 };
        // 3g.20gb at start 0: blocks 0-3 occupied. 4g.20gb (start 0 only)
        // no longer fits; 3g.20gb still fits at start 4.
        let pl = Placement { profile: Profile::P3g20gb, start: 0 };
        dc.place(&spec(1, Profile::P3g20gb, 1, 1), r, pl);
        assert!(!dc.index().gpus_fitting(Profile::P4g20gb).contains(r));
        assert!(!dc.index().gpus_fitting(Profile::P7g40gb).contains(r));
        assert!(dc.index().gpus_fitting(Profile::P3g20gb).contains(r));
        assert!(dc.index().gpus_fitting(Profile::P1g5gb).contains(r));
    }

    #[test]
    fn a30_occupancy_tracks_its_own_buckets() {
        let mut dc = mixed_dc();
        let r = GpuRef { host: 0, gpu: 0 }; // the A30
        let k2g = GpuModel::A30.profile(1);
        let k4g = GpuModel::A30.profile(2);
        dc.place(&spec(1, k2g, 1, 1), r, Placement { profile: k2g, start: 0 });
        assert!(!dc.index().gpus_fitting(k4g).contains(r));
        assert!(dc.index().gpus_fitting(k2g).contains(r)); // start 2 free
        // The A100 buckets are untouched by A30 occupancy changes.
        for p in ALL_PROFILES {
            assert_eq!(dc.index().fitting_count(p), 2, "{p}");
        }
        dc.check_integrity().unwrap();
    }

    /// Satellite acceptance: after random place/remove/migrate/relocate
    /// /health-transition sequences — on a single-model *or* mixed-model
    /// cluster — the bitset index equals a brute-force rebuild, every
    /// bucket equals an independent availability-masked scan (the
    /// `use_index(false)` oracle), the structural invariants hold, and
    /// `check_integrity` passes.
    #[test]
    fn prop_incremental_index_matches_brute_force() {
        forall(
            "cluster-index-vs-brute-force",
            |r: &mut Rng| {
                let mut dc = if r.chance(0.5) { small_dc() } else { mixed_dc() };
                let mut next_vm: u64 = 1;
                let mut resident: Vec<u64> = Vec::new();
                let refs: Vec<GpuRef> = dc.gpu_refs();
                for _ in 0..48 {
                    match r.below(6) {
                        0 | 1 => {
                            // Place on a random feasible GPU (a profile of
                            // that GPU's own model).
                            let gr = refs[r.below(refs.len() as u64) as usize];
                            if !dc.gpu_available(gr) {
                                continue;
                            }
                            let model = dc.gpu(gr).model();
                            let profile =
                                model.profile(r.below(model.num_profiles() as u64) as usize);
                            let (cpus, ram) = (1 + r.below(3) as u32, 1 + r.below(4) as u32);
                            let vm = spec(next_vm, profile, cpus, ram);
                            let host_ok = dc.host(gr.host).fits_resources(vm.cpus, vm.ram_gb);
                            if let (true, Some((pl, _))) =
                                (host_ok, mock_assign(dc.gpu(gr).occupancy(), profile))
                            {
                                dc.place(&vm, gr, pl);
                                resident.push(next_vm);
                                next_vm += 1;
                            }
                        }
                        2 => {
                            // Remove a random resident VM.
                            if !resident.is_empty() {
                                let i = r.below(resident.len() as u64) as usize;
                                let vm = resident.swap_remove(i);
                                dc.remove(vm);
                            }
                        }
                        3 => {
                            // GPU health flip. Failing hardware requires
                            // emptiness (the eviction-first contract);
                            // draining tolerates residents.
                            let gr = refs[r.below(refs.len() as u64) as usize];
                            let cur = dc.host(gr.host).gpu_health(gr.gpu as usize);
                            let next = if !cur.allows_placement() {
                                HealthState::Healthy
                            } else if dc.gpu(gr).instances().is_empty() && r.chance(0.5) {
                                HealthState::Failed { until: 10_000 }
                            } else {
                                HealthState::Draining
                            };
                            dc.set_gpu_health(gr, next);
                        }
                        4 => {
                            // Host health flip (always via Draining, which
                            // keeps any residents legal).
                            let id = r.below(3) as u32;
                            let next = if dc.host(id).health().allows_placement() {
                                HealthState::Draining
                            } else {
                                HealthState::Healthy
                            };
                            dc.set_host_health(id, next);
                        }
                        _ => {
                            if resident.is_empty() {
                                continue;
                            }
                            let vm = resident[r.below(resident.len() as u64) as usize];
                            let loc = dc.locate(vm).unwrap();
                            if r.chance(0.5) {
                                // Intra-GPU relocation to another legal start.
                                let occ = dc.gpu(loc.gpu).occupancy() & !loc.placement.mask();
                                let starts: Vec<u8> =
                                    feasible_starts(loc.placement.profile, occ).collect();
                                let s = starts[r.below(starts.len() as u64) as usize];
                                dc.relocate_within_gpu(
                                    vm,
                                    Placement { profile: loc.placement.profile, start: s },
                                );
                            } else {
                                // Inter-GPU migration to a random feasible
                                // (and schedulable) GPU of the same model.
                                let dst = refs[r.below(refs.len() as u64) as usize];
                                if dst == loc.gpu
                                    || !dc.gpu_available(dst)
                                    || dc.gpu(dst).model() != loc.placement.profile.model()
                                {
                                    continue;
                                }
                                let (cpus, ram) = dc.vm_demands(vm).unwrap();
                                if dst.host != loc.gpu.host
                                    && !dc.host(dst.host).fits_resources(cpus, ram)
                                {
                                    continue;
                                }
                                if let Some((pl, _)) =
                                    mock_assign(dc.gpu(dst).occupancy(), loc.placement.profile)
                                {
                                    dc.migrate(vm, dst, pl);
                                }
                            }
                        }
                    }
                }
                dc
            },
            |dc| {
                let rebuilt = ClusterIndex::build(dc.hosts());
                if &rebuilt != dc.index() {
                    return Err("incremental index diverged from brute-force rebuild".into());
                }
                dc.index().check_invariants().map_err(|e| format!("invariants: {e}"))?;
                // The O(1) activity counters must match a brute-force
                // recount after the same mutation sequence.
                if dc.active_hardware() != dc.active_hardware_scan() {
                    return Err("activity counters diverged from fleet recount".into());
                }
                if dc.active_gpus_by_model() != dc.active_gpus_by_model_scan() {
                    return Err("per-model activity diverged from fleet recount".into());
                }
                // Every bucket equals the scan oracle (the walk the
                // `use_index(false)` policy variants perform), GPUs only
                // ever sit in buckets of their own model, and the
                // schedulable sets match an availability recount.
                for key in ProfileKey::all() {
                    let indexed: Vec<GpuRef> = dc.index().gpus_fitting(key).iter().collect();
                    if indexed != scan_bucket(dc, key) {
                        return Err(format!("{key}: bitset bucket != brute-force scan"));
                    }
                    for r in dc.index().gpus_fitting(key) {
                        if dc.gpu(r).model() != key.model() {
                            return Err(format!("{key}: foreign-model GPU in bucket"));
                        }
                    }
                }
                for model in ALL_MODELS {
                    let scan: Vec<GpuRef> = dc
                        .gpu_refs()
                        .into_iter()
                        .filter(|&r| dc.gpu_available(r) && dc.gpu(r).model() == model)
                        .collect();
                    let indexed: Vec<GpuRef> = dc.index().schedulable(model).iter().collect();
                    if scan != indexed {
                        return Err(format!("{model:?}: schedulable set != availability scan"));
                    }
                }
                dc.check_integrity().map_err(|e| format!("integrity: {e}"))
            },
        );
    }
}
