//! Physical machines (`PM_j` of §6) with CPU/RAM capacities and GPUs.

use crate::cluster::health::HealthState;
use crate::mig::{GpuModel, GpuState};

/// A physical machine: CPU/RAM capacities (`C_j`, `R_j` of Eq. 6–7) and a
/// collection of MIG-enabled GPUs (`P_j`), each tagged with its catalog
/// model. The GPU characteristic (`H_jk` of Eq. 17–18) is per GPU now:
/// `gpu.model().characteristic()`.
#[derive(Debug, Clone)]
pub struct Host {
    pub id: u32,
    /// CPU capacity in cores (`C_j`).
    pub cpus: u32,
    /// RAM capacity in GB (`R_j`).
    pub ram_gb: u32,
    /// Power/priority weight (`b_j` of Eq. 4).
    pub weight: f64,
    pub(crate) used_cpus: u32,
    pub(crate) used_ram: u32,
    pub(crate) gpus: Vec<GpuState>,
    /// Number of VMs currently resident (for active-hardware accounting).
    pub(crate) resident_vms: u32,
    /// Operational health of the whole machine.
    pub(crate) health: HealthState,
    /// Operational health per GPU, parallel to `gpus`.
    pub(crate) gpu_health: Vec<HealthState>,
}

impl Host {
    /// Create a host with `num_gpus` empty A100-40s (the historical
    /// single-model constructor).
    pub fn new(id: u32, cpus: u32, ram_gb: u32, num_gpus: usize) -> Host {
        Host::with_models(id, cpus, ram_gb, &vec![GpuModel::A100_40; num_gpus])
    }

    /// Create a host with one empty GPU per entry of `models`.
    pub fn with_models(id: u32, cpus: u32, ram_gb: u32, models: &[GpuModel]) -> Host {
        Host {
            id,
            cpus,
            ram_gb,
            weight: 1.0,
            used_cpus: 0,
            used_ram: 0,
            gpus: models.iter().map(|&m| GpuState::with_model(m)).collect(),
            resident_vms: 0,
            health: HealthState::Healthy,
            gpu_health: vec![HealthState::Healthy; models.len()],
        }
    }

    /// CPU cores still free.
    pub fn free_cpus(&self) -> u32 {
        self.cpus - self.used_cpus
    }

    /// RAM (GB) still free.
    pub fn free_ram(&self) -> u32 {
        self.ram_gb - self.used_ram
    }

    /// Would a VM with these demands fit CPU/RAM-wise (Eq. 6–7)?
    pub fn fits_resources(&self, cpus: u32, ram_gb: u32) -> bool {
        self.free_cpus() >= cpus && self.free_ram() >= ram_gb
    }

    /// GPUs on this host.
    pub fn gpus(&self) -> &[GpuState] {
        &self.gpus
    }

    /// Mutable access to one GPU.
    pub fn gpu_mut(&mut self, idx: usize) -> &mut GpuState {
        &mut self.gpus[idx]
    }

    /// Active = hosts at least one VM (`φ_j` of Eq. 19).
    pub fn is_active(&self) -> bool {
        self.resident_vms > 0
    }

    /// Operational health of the machine.
    pub fn health(&self) -> HealthState {
        self.health
    }

    /// Operational health of one GPU.
    pub fn gpu_health(&self, idx: usize) -> HealthState {
        self.gpu_health[idx]
    }

    /// Is the GPU at `idx` schedulable — both the device and the
    /// machine must [`allow placement`](HealthState::allows_placement)?
    #[inline]
    pub fn gpu_available(&self, idx: usize) -> bool {
        self.health.allows_placement() && self.gpu_health[idx].allows_placement()
    }

    /// Number of resident VMs.
    pub fn resident_vms(&self) -> u32 {
        self.resident_vms
    }

    /// Reserve CPU/RAM for a VM. Panics in debug builds on over-commit.
    pub(crate) fn reserve(&mut self, cpus: u32, ram_gb: u32) {
        debug_assert!(self.fits_resources(cpus, ram_gb));
        self.used_cpus += cpus;
        self.used_ram += ram_gb;
        self.resident_vms += 1;
    }

    /// Release CPU/RAM previously reserved.
    pub(crate) fn release(&mut self, cpus: u32, ram_gb: u32) {
        debug_assert!(self.used_cpus >= cpus && self.used_ram >= ram_gb);
        self.used_cpus -= cpus;
        self.used_ram -= ram_gb;
        debug_assert!(self.resident_vms > 0);
        self.resident_vms -= 1;
    }
}

/// GPU count per catalog model over a host slice, indexed by
/// `GpuModel as usize` — the fleet composition. Used by the trace
/// generator's workload summary; [`super::DataCenter::gpus_by_model`]
/// answers from its O(1) activity counters instead, whose coherence
/// with the host states `check_integrity` verifies by recount.
pub fn gpus_by_model(hosts: &[Host]) -> [usize; crate::mig::NUM_MODELS] {
    let mut out = [0usize; crate::mig::NUM_MODELS];
    for h in hosts {
        for g in h.gpus() {
            out[g.model() as usize] += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resource_accounting() {
        let mut h = Host::new(0, 64, 256, 4);
        assert!(h.fits_resources(64, 256));
        assert!(!h.fits_resources(65, 1));
        h.reserve(32, 100);
        assert_eq!(h.free_cpus(), 32);
        assert_eq!(h.free_ram(), 156);
        assert!(h.is_active());
        h.release(32, 100);
        assert!(!h.is_active());
        assert_eq!(h.free_cpus(), 64);
    }

    #[test]
    fn gpus_initialized_empty() {
        let h = Host::new(1, 8, 32, 8);
        assert_eq!(h.gpus().len(), 8);
        assert!(h.gpus().iter().all(|g| g.is_empty()));
        assert!(h.gpus().iter().all(|g| g.model() == GpuModel::A100_40));
    }

    #[test]
    fn mixed_model_host() {
        let h = Host::with_models(
            2,
            64,
            256,
            &[GpuModel::A30, GpuModel::A100_40, GpuModel::H100_80],
        );
        let models: Vec<GpuModel> = h.gpus().iter().map(|g| g.model()).collect();
        assert_eq!(models, vec![GpuModel::A30, GpuModel::A100_40, GpuModel::H100_80]);
        assert_eq!(h.gpus()[0].free_blocks(), 4);
        assert_eq!(h.gpus()[2].free_blocks(), 8);
    }
}
