//! Health state for GPUs and hosts — the operational-reality layer.
//!
//! Production MIG fleets never run on uniformly healthy hardware:
//! devices fail and come back (MTBF/MTTR), hosts get drained for
//! maintenance, and repeatedly flapping parts are taken out of rotation
//! (LumosCore tracks exactly this as `banned_gpu_status`). The
//! [`HealthState`] here is that scheduler input, attached to every GPU
//! and host of a [`super::DataCenter`].
//!
//! The contract with the [`super::ClusterIndex`] is strict: a GPU is
//! *schedulable* iff the GPU **and** its host both
//! [`allow placement`](HealthState::allows_placement), and the index
//! holds entries for schedulable capacity only. `DataCenter` enforces
//! the contract in its health mutators (`set_gpu_health` /
//! `set_host_health` attach/detach index entries on availability
//! transitions) and `check_integrity` re-verifies it on every call —
//! the existing "rebuild equals incremental" comparison is the anchor,
//! because [`super::ClusterIndex::build`] itself skips unhealthy
//! capacity.

use crate::cluster::vm::Time;
use std::fmt;

/// Operational health of one GPU or one host.
///
/// The default is [`HealthState::Healthy`]; a fleet that never sees a
/// fault event stays in the default state everywhere, and every health
/// check collapses to a branch that is always true — which is what
/// keeps the ops layer strictly additive (zero-fault runs are
/// byte-identical to the pre-ops decision stream).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HealthState {
    /// In service; capacity is schedulable.
    #[default]
    Healthy,
    /// Hard failure; resident VMs were evicted. `until` is the repair
    /// completion time (MTTR draw) recorded for reporting — the actual
    /// repair is a separate event, so the state machine stays
    /// event-driven.
    Failed {
        /// Expected repair time (informational; the repair event is
        /// authoritative).
        until: Time,
    },
    /// Maintenance drain in progress: existing VMs may stay resident
    /// (until evacuation moves them), but no new placements land here.
    Draining,
    /// Permanently out of rotation after repeated failures.
    Banned,
}

impl HealthState {
    /// May new VMs be placed on capacity in this state?
    ///
    /// Only [`HealthState::Healthy`] capacity is schedulable; a
    /// draining host keeps its residents but accepts nothing new.
    #[inline]
    pub fn allows_placement(&self) -> bool {
        matches!(self, HealthState::Healthy)
    }

    /// May VMs *remain* resident in this state? Draining capacity keeps
    /// its VMs until the evacuation plan moves them; failed or banned
    /// capacity must be empty (the failure evicted everything).
    #[inline]
    pub fn allows_residency(&self) -> bool {
        matches!(self, HealthState::Healthy | HealthState::Draining)
    }

    /// Short lowercase label for tables and logs.
    pub fn name(&self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Failed { .. } => "failed",
            HealthState::Draining => "draining",
            HealthState::Banned => "banned",
        }
    }
}

impl fmt::Display for HealthState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_healthy() {
        assert_eq!(HealthState::default(), HealthState::Healthy);
        assert!(HealthState::default().allows_placement());
        assert!(HealthState::default().allows_residency());
    }

    #[test]
    fn placement_and_residency_matrix() {
        let failed = HealthState::Failed { until: 100 };
        assert!(!failed.allows_placement());
        assert!(!failed.allows_residency());
        assert!(!HealthState::Draining.allows_placement());
        assert!(HealthState::Draining.allows_residency());
        assert!(!HealthState::Banned.allows_placement());
        assert!(!HealthState::Banned.allows_residency());
    }

    #[test]
    fn names_render() {
        assert_eq!(HealthState::Healthy.to_string(), "healthy");
        assert_eq!(HealthState::Failed { until: 5 }.to_string(), "failed");
        assert_eq!(HealthState::Draining.to_string(), "draining");
        assert_eq!(HealthState::Banned.to_string(), "banned");
    }
}
