//! The data-center model: physical machines, GPUs, VMs (§6's `M`, `P_j`,
//! `N`).
//!
//! * [`vm`] — VM specifications (`c_i`, `r_i`, `g_i` via the MIG profile,
//!   arrival/departure times).
//! * [`host`] — physical machines with CPU/RAM capacities (`C_j`, `R_j`)
//!   and one to eight MIG-enabled GPUs.
//! * [`datacenter`] — the cluster state: placement/removal of VMs with a
//!   VM→location index, GPU addressing by global index, and the paper's
//!   strict active-hardware accounting.
//! * [`index`] — the [`index::ClusterIndex`]: per-profile GPU feasibility
//!   buckets (two-level hierarchical bitsets read through
//!   [`index::GpuSetView`]), per-model schedulable sets, and host
//!   headroom histograms, maintained incrementally by every `DataCenter`
//!   mutation so policies answer placement queries without scanning the
//!   cluster.
//! * [`health`] — operational [`health::HealthState`] of GPUs and hosts
//!   (failed / draining / banned); the index covers schedulable
//!   capacity only, a contract `check_integrity` verifies.
//! * [`shard`] — contiguous fleet partitions ([`shard::ShardMap`]) for
//!   the sharded engine ([`crate::sim::ShardedCore`]): per-shard
//!   `DataCenter`s over renumbered host clones, with local↔global
//!   reference translation and VM-id-pure request routing.

pub mod datacenter;
pub mod health;
pub mod host;
pub mod index;
pub mod shard;
pub mod vm;

pub use datacenter::{DataCenter, GpuRef, IntegrityReport, VmLocation};
pub use health::HealthState;
pub use host::Host;
pub use index::{ClusterIndex, GpuBits, GpuSetView};
pub use shard::ShardMap;
pub use vm::{Time, VmId, VmSpec, HOUR};
