//! The data-center model: physical machines, GPUs, VMs (§6's `M`, `P_j`,
//! `N`).
//!
//! * [`vm`] — VM specifications (`c_i`, `r_i`, `g_i` via the MIG profile,
//!   arrival/departure times).
//! * [`host`] — physical machines with CPU/RAM capacities (`C_j`, `R_j`)
//!   and one to eight MIG-enabled GPUs.
//! * [`datacenter`] — the cluster state: placement/removal of VMs with a
//!   VM→location index, GPU addressing by global index, and the paper's
//!   strict active-hardware accounting.

pub mod datacenter;
pub mod host;
pub mod vm;

pub use datacenter::{DataCenter, GpuRef, VmLocation};
pub use host::Host;
pub use vm::{Time, VmId, VmSpec, HOUR};
