//! Virtual machine specifications (the set `N` of §6).

use crate::mig::Profile;

/// VM identifier (also tags GPU instances in [`crate::mig::GpuState`]).
pub type VmId = u64;

/// Simulation time in seconds.
pub type Time = u64;

/// One VM request: a MIG GI profile plus host-level CPU/RAM demands and
/// its lifetime.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VmSpec {
    pub id: VmId,
    /// Requested GI profile (`g_i`, `h_i` derive from it).
    pub profile: Profile,
    /// CPU cores requested (`c_i`).
    pub cpus: u32,
    /// RAM in GB requested (`r_i`).
    pub ram_gb: u32,
    /// Arrival time (seconds).
    pub arrival: Time,
    /// Departure time (seconds); `departure > arrival`.
    pub departure: Time,
    /// Acceptance weight (`a_i` of Eq. 3); provider-defined priority.
    pub weight: f64,
}

impl VmSpec {
    /// Lifetime in seconds.
    pub fn duration(&self) -> Time {
        self.departure.saturating_sub(self.arrival)
    }

    /// Serialize for crash-safe snapshots ([`crate::recover`]).
    pub(crate) fn encode(&self, e: &mut crate::util::codec::Enc) {
        e.u64(self.id);
        e.u8(self.profile.dense() as u8);
        e.u32(self.cpus);
        e.u32(self.ram_gb);
        e.u64(self.arrival);
        e.u64(self.departure);
        e.f64(self.weight);
    }

    /// Inverse of [`VmSpec::encode`].
    pub(crate) fn decode(d: &mut crate::util::codec::Dec) -> Result<VmSpec, String> {
        let id = d.u64()?;
        let dense = d.u8()? as usize;
        if dense >= crate::mig::NUM_PROFILE_KEYS {
            return Err(format!("VM spec has out-of-range profile key {dense}"));
        }
        let profile = crate::mig::ProfileKey::from_dense(dense);
        let cpus = d.u32()?;
        let ram_gb = d.u32()?;
        let arrival = d.u64()?;
        let departure = d.u64()?;
        let weight = d.f64()?;
        Ok(VmSpec { id, profile, cpus, ram_gb, arrival, departure, weight })
    }
}

/// Seconds per simulated hour (metric sampling granularity).
pub const HOUR: Time = 3_600;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_computed() {
        let vm = VmSpec {
            id: 1,
            profile: Profile::P2g10gb,
            cpus: 8,
            ram_gb: 32,
            arrival: 100,
            departure: 4_100,
            weight: 1.0,
        };
        assert_eq!(vm.duration(), 4_000);
    }

    #[test]
    fn duration_saturates() {
        let vm = VmSpec {
            id: 1,
            profile: Profile::P1g5gb,
            cpus: 1,
            ram_gb: 1,
            arrival: 10,
            departure: 5,
            weight: 1.0,
        };
        assert_eq!(vm.duration(), 0);
    }
}
