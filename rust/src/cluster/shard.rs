//! Fleet sharding: contiguous host partitions (pods/zones) with
//! local↔global reference translation.
//!
//! A [`ShardMap`] splits a host list into `S` contiguous, near-equal
//! ranges. Each shard owns an independent [`super::DataCenter`] — its own
//! [`super::ClusterIndex`], activity counters and health state — built
//! over *renumbered* clones of its hosts (local ids `0..len`, preserving
//! the `host.id == position` integrity invariant). The map translates
//! [`GpuRef`]s and host ids between the global namespace the router and
//! reports speak and each shard's local namespace.
//!
//! Request routing is by VM id (`vm.id % S`), independent of fleet size
//! and shard boundaries, so a request's *home* shard — and therefore the
//! merged decision stream — is a pure function of the trace and the
//! shard count, never of worker threads or timing.

use crate::cluster::{GpuRef, Host, VmId};

/// Contiguous host partition of a fleet into `S` shards. The first
/// `num_hosts % S` shards are one host larger, so sizes differ by at
/// most one.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMap {
    /// Global host-id boundaries: shard `s` owns hosts
    /// `bounds[s]..bounds[s + 1]`. Length `shards + 1`.
    bounds: Vec<u32>,
}

impl ShardMap {
    /// Partition `num_hosts` hosts into `shards` contiguous ranges.
    /// The shard count is clamped to `[1, num_hosts]` (an empty fleet
    /// keeps one empty shard), so every shard is non-empty.
    pub fn new(num_hosts: usize, shards: usize) -> ShardMap {
        let s = shards.clamp(1, num_hosts.max(1));
        let base = num_hosts / s;
        let extra = num_hosts % s;
        let mut bounds = Vec::with_capacity(s + 1);
        let mut at = 0usize;
        bounds.push(0);
        for i in 0..s {
            at += base + usize::from(i < extra);
            bounds.push(at as u32);
        }
        ShardMap { bounds }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Total hosts across all shards.
    pub fn num_hosts(&self) -> usize {
        *self.bounds.last().unwrap() as usize
    }

    /// First global host id of shard `s`.
    pub fn base(&self, s: usize) -> u32 {
        self.bounds[s]
    }

    /// Hosts owned by shard `s`.
    pub fn shard_len(&self, s: usize) -> usize {
        (self.bounds[s + 1] - self.bounds[s]) as usize
    }

    /// The shard owning global host id `host`.
    pub fn shard_of_host(&self, host: u32) -> usize {
        debug_assert!((host as usize) < self.num_hosts());
        self.bounds.partition_point(|&b| b <= host) - 1
    }

    /// The *home* shard of a request: `vm % S`. Pure in the VM id, so
    /// routing is reproducible across runs and thread counts.
    pub fn home_shard(&self, vm: VmId) -> usize {
        (vm % self.shards() as u64) as usize
    }

    /// Translate a global GPU reference into shard `s`'s namespace.
    pub fn to_local(&self, s: usize, r: GpuRef) -> GpuRef {
        debug_assert_eq!(self.shard_of_host(r.host), s);
        GpuRef { host: r.host - self.bounds[s], gpu: r.gpu }
    }

    /// Translate shard `s`'s local GPU reference back to the global
    /// namespace.
    pub fn to_global(&self, s: usize, r: GpuRef) -> GpuRef {
        debug_assert!((r.host as usize) < self.shard_len(s));
        GpuRef { host: r.host + self.bounds[s], gpu: r.gpu }
    }

    /// Clone and renumber the fleet into per-shard host lists: shard
    /// `s`'s hosts get local ids `0..shard_len(s)` so each shard's
    /// `DataCenter` keeps the `host.id == position` invariant. With one
    /// shard this is an identity copy.
    pub fn split_hosts(&self, hosts: &[Host]) -> Vec<Vec<Host>> {
        assert_eq!(hosts.len(), self.num_hosts(), "fleet size matches the map");
        (0..self.shards())
            .map(|s| {
                hosts[self.bounds[s] as usize..self.bounds[s + 1] as usize]
                    .iter()
                    .enumerate()
                    .map(|(local, h)| {
                        let mut h = h.clone();
                        h.id = local as u32;
                        h
                    })
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_are_contiguous_and_near_equal() {
        let map = ShardMap::new(10, 4);
        assert_eq!(map.shards(), 4);
        assert_eq!(map.num_hosts(), 10);
        let sizes: Vec<usize> = (0..4).map(|s| map.shard_len(s)).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        // Every host belongs to exactly the shard whose range holds it.
        for h in 0..10u32 {
            let s = map.shard_of_host(h);
            assert!(map.base(s) <= h && h < map.base(s) + map.shard_len(s) as u32);
        }
    }

    #[test]
    fn shard_count_is_clamped() {
        assert_eq!(ShardMap::new(3, 8).shards(), 3);
        assert_eq!(ShardMap::new(3, 0).shards(), 1);
        assert_eq!(ShardMap::new(0, 4).shards(), 1);
        assert_eq!(ShardMap::new(0, 4).num_hosts(), 0);
    }

    #[test]
    fn ref_translation_round_trips() {
        let map = ShardMap::new(7, 3);
        for host in 0..7u32 {
            for gpu in 0..4u8 {
                let g = GpuRef { host, gpu };
                let s = map.shard_of_host(host);
                let l = map.to_local(s, g);
                assert!((l.host as usize) < map.shard_len(s));
                assert_eq!(map.to_global(s, l), g);
            }
        }
    }

    #[test]
    fn home_shard_depends_only_on_vm_id() {
        let map = ShardMap::new(100, 4);
        for vm in 0..32u64 {
            assert_eq!(map.home_shard(vm), (vm % 4) as usize);
            assert_eq!(map.home_shard(vm), ShardMap::new(8, 4).home_shard(vm));
        }
    }

    #[test]
    fn split_hosts_renumbers_locally() {
        let hosts: Vec<Host> = (0..5).map(|i| Host::new(i, 64, 256, 2)).collect();
        let map = ShardMap::new(5, 2);
        let split = map.split_hosts(&hosts);
        assert_eq!(split.len(), 2);
        assert_eq!(split[0].len(), 3);
        assert_eq!(split[1].len(), 2);
        for part in &split {
            for (i, h) in part.iter().enumerate() {
                assert_eq!(h.id as usize, i, "local ids match positions");
            }
        }
        // Single shard: identity copy (same ids, same order).
        let one = ShardMap::new(5, 1).split_hosts(&hosts);
        assert_eq!(one.len(), 1);
        for (a, b) in one[0].iter().zip(&hosts) {
            assert_eq!(a.id, b.id);
        }
    }
}
