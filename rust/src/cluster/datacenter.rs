//! The cluster state: hosts, GPU addressing, VM placement bookkeeping and
//! active-hardware accounting.
//!
//! Every mutation (`place`, `remove`, `migrate`, `relocate_within_gpu`,
//! `repack_gpu`) also maintains the [`ClusterIndex`] incrementally, so
//! policies query per-profile feasibility buckets and host headroom
//! instead of scanning the cluster.

use super::host::Host;
use super::index::ClusterIndex;
use super::vm::{VmId, VmSpec};
use crate::mig::{GpuState, Instance, Placement};
use std::collections::HashMap;

/// Address of one GPU: `(host index, GPU index within host)`. Ordering is
/// the paper's `globalIndex` (Algorithm 2) — lexicographic, so first-fit
/// scans are deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GpuRef {
    pub host: u32,
    pub gpu: u8,
}

/// Where a VM currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VmLocation {
    pub gpu: GpuRef,
    pub placement: Placement,
}

/// The data center: all hosts plus a VM→location index and the
/// incrementally maintained [`ClusterIndex`].
#[derive(Debug, Clone, Default)]
pub struct DataCenter {
    hosts: Vec<Host>,
    locations: HashMap<VmId, VmLocation>,
    /// CPU/RAM demands of resident VMs (needed on departure).
    demands: HashMap<VmId, (u32, u32)>,
    /// Placement index, kept coherent by every mutation below.
    index: ClusterIndex,
}

impl DataCenter {
    pub fn new(hosts: Vec<Host>) -> DataCenter {
        let index = ClusterIndex::build(&hosts);
        DataCenter { hosts, locations: HashMap::new(), demands: HashMap::new(), index }
    }

    /// The placement index (per-profile feasibility buckets + host
    /// headroom). Read-only: coherence is this type's responsibility.
    #[inline]
    pub fn index(&self) -> &ClusterIndex {
        &self.index
    }

    pub fn hosts(&self) -> &[Host] {
        &self.hosts
    }

    pub fn host(&self, id: u32) -> &Host {
        &self.hosts[id as usize]
    }

    pub fn host_mut(&mut self, id: u32) -> &mut Host {
        &mut self.hosts[id as usize]
    }

    /// Total number of GPUs in the data center.
    pub fn num_gpus(&self) -> usize {
        self.hosts.iter().map(|h| h.gpus().len()).sum()
    }

    /// All GPU references in `globalIndex` order.
    pub fn gpu_refs(&self) -> Vec<GpuRef> {
        let mut refs = Vec::with_capacity(self.num_gpus());
        for h in &self.hosts {
            for g in 0..h.gpus().len() {
                refs.push(GpuRef { host: h.id, gpu: g as u8 });
            }
        }
        refs
    }

    pub fn gpu(&self, r: GpuRef) -> &GpuState {
        &self.hosts[r.host as usize].gpus()[r.gpu as usize]
    }

    /// Raw mutable GPU access. **Bypasses the [`ClusterIndex`]** — only
    /// for tests that deliberately corrupt state; production mutation
    /// goes through `place`/`remove`/`migrate`/`relocate_within_gpu`/
    /// [`DataCenter::repack_gpu`], which keep the index coherent.
    pub fn gpu_mut(&mut self, r: GpuRef) -> &mut GpuState {
        self.hosts[r.host as usize].gpu_mut(r.gpu as usize)
    }

    /// Location of a resident VM.
    pub fn locate(&self, vm: VmId) -> Option<VmLocation> {
        self.locations.get(&vm).copied()
    }

    /// CPU/RAM demands of a resident VM.
    pub fn vm_demands(&self, vm: VmId) -> Option<(u32, u32)> {
        self.demands.get(&vm).copied()
    }

    /// Number of resident VMs.
    pub fn resident_count(&self) -> usize {
        self.locations.len()
    }

    /// Place `vm` on the given GPU at the given placement, reserving host
    /// CPU/RAM. Caller must have validated feasibility (CPU/RAM and block
    /// availability); debug builds assert it.
    pub fn place(&mut self, vm: &VmSpec, gpu_ref: GpuRef, placement: Placement) {
        debug_assert!(self.locations.get(&vm.id).is_none(), "VM {} already placed", vm.id);
        let host = &mut self.hosts[gpu_ref.host as usize];
        let old_free = (host.free_cpus(), host.free_ram());
        host.reserve(vm.cpus, vm.ram_gb);
        let new_free = (host.free_cpus(), host.free_ram());
        let gpu = host.gpu_mut(gpu_ref.gpu as usize);
        let model = gpu.model();
        let old_occ = gpu.occupancy();
        gpu.place(vm.id, placement);
        let new_occ = gpu.occupancy();
        self.index.update_host(old_free, new_free);
        self.index.update_gpu(gpu_ref, model, old_occ, new_occ);
        self.locations.insert(vm.id, VmLocation { gpu: gpu_ref, placement });
        self.demands.insert(vm.id, (vm.cpus, vm.ram_gb));
    }

    /// Remove a resident VM entirely (departure), releasing all resources.
    /// Returns its former location.
    pub fn remove(&mut self, vm: VmId) -> Option<VmLocation> {
        let loc = self.locations.remove(&vm)?;
        let (cpus, ram) = self.demands.remove(&vm).unwrap_or((0, 0));
        let host = &mut self.hosts[loc.gpu.host as usize];
        let old_free = (host.free_cpus(), host.free_ram());
        let gpu = host.gpu_mut(loc.gpu.gpu as usize);
        let model = gpu.model();
        let old_occ = gpu.occupancy();
        gpu.remove_vm(vm);
        let new_occ = gpu.occupancy();
        host.release(cpus, ram);
        let new_free = (host.free_cpus(), host.free_ram());
        self.index.update_host(old_free, new_free);
        self.index.update_gpu(loc.gpu, model, old_occ, new_occ);
        Some(loc)
    }

    /// Move a VM's GI to a different placement on the *same* GPU
    /// (intra-GPU migration; the `ω_ijk`-only case of Eq. 24–25).
    pub fn relocate_within_gpu(&mut self, vm: VmId, new_placement: Placement) {
        let loc = self.locations.get_mut(&vm).expect("VM resident");
        let gpu_ref = loc.gpu;
        loc.placement = new_placement;
        let gpu = self.hosts[gpu_ref.host as usize].gpu_mut(gpu_ref.gpu as usize);
        let model = gpu.model();
        let old_occ = gpu.occupancy();
        gpu.remove_vm(vm).expect("instance present");
        gpu.place(vm, new_placement);
        let new_occ = gpu.occupancy();
        self.index.update_gpu(gpu_ref, model, old_occ, new_occ);
    }

    /// Apply an intra-GPU re-pack plan (the defragmentation path): all
    /// moving instances are removed first, then placed at their new
    /// positions — avoiding transient overlaps when instances swap.
    /// Host resources are untouched; the location and cluster indices
    /// stay coherent.
    pub fn repack_gpu(&mut self, gpu_ref: GpuRef, moves: &[(Instance, Placement)]) {
        let gpu = self.hosts[gpu_ref.host as usize].gpu_mut(gpu_ref.gpu as usize);
        let model = gpu.model();
        let old_occ = gpu.occupancy();
        for (inst, _) in moves {
            gpu.remove_vm(inst.vm).expect("moving instance present");
        }
        for (inst, new_placement) in moves {
            gpu.place(inst.vm, *new_placement);
        }
        let new_occ = gpu.occupancy();
        for (inst, new_placement) in moves {
            self.locations
                .insert(inst.vm, VmLocation { gpu: gpu_ref, placement: *new_placement });
        }
        self.index.update_gpu(gpu_ref, model, old_occ, new_occ);
    }

    /// Move a VM's GI to a different GPU (inter-GPU migration). Host
    /// CPU/RAM moves with it when the hosts differ. Caller validated the
    /// destination placement is free.
    pub fn migrate(&mut self, vm: VmId, dst: GpuRef, placement: Placement) {
        let loc = *self.locations.get(&vm).expect("VM resident");
        let (cpus, ram) = *self.demands.get(&vm).expect("VM demands known");
        let src = loc.gpu;
        let src_gpu = self.hosts[src.host as usize].gpu_mut(src.gpu as usize);
        let src_model = src_gpu.model();
        let src_old_occ = src_gpu.occupancy();
        src_gpu.remove_vm(vm);
        let src_new_occ = src_gpu.occupancy();
        self.index.update_gpu(src, src_model, src_old_occ, src_new_occ);
        if src.host != dst.host {
            let src_host = &mut self.hosts[src.host as usize];
            let old_free = (src_host.free_cpus(), src_host.free_ram());
            src_host.release(cpus, ram);
            self.index.update_host(old_free, (src_host.free_cpus(), src_host.free_ram()));
            let dst_host = &mut self.hosts[dst.host as usize];
            let old_free = (dst_host.free_cpus(), dst_host.free_ram());
            dst_host.reserve(cpus, ram);
            self.index.update_host(old_free, (dst_host.free_cpus(), dst_host.free_ram()));
        }
        let dst_gpu = self.hosts[dst.host as usize].gpu_mut(dst.gpu as usize);
        let dst_model = dst_gpu.model();
        let dst_old_occ = dst_gpu.occupancy();
        dst_gpu.place(vm, placement);
        let dst_new_occ = dst_gpu.occupancy();
        self.index.update_gpu(dst, dst_model, dst_old_occ, dst_new_occ);
        self.locations.insert(vm, VmLocation { gpu: dst, placement });
    }

    /// Active-hardware count under the paper's *strict* definition (§2):
    /// a PM is active if it hosts any VM; every GPU on an active PM counts
    /// as active even when idle (idle GPUs count as inactive only when the
    /// whole machine is idle). Returns `(active units, total units)` where
    /// a unit is one PM or one GPU, matching Eq. 4's `φ_j + Σ_k γ_jk`.
    pub fn active_hardware(&self) -> (usize, usize) {
        let mut active = 0usize;
        let mut total = 0usize;
        for h in &self.hosts {
            total += 1 + h.gpus().len();
            if h.is_active() {
                active += 1 + h.gpus().len();
            }
        }
        (active, total)
    }

    /// Active-hardware rate in `[0, 1]`.
    pub fn active_hardware_rate(&self) -> f64 {
        let (active, total) = self.active_hardware();
        if total == 0 {
            0.0
        } else {
            active as f64 / total as f64
        }
    }

    /// GPU count per catalog model, indexed by `GpuModel as usize`
    /// (the fleet composition).
    pub fn gpus_by_model(&self) -> [usize; crate::mig::NUM_MODELS] {
        super::host::gpus_by_model(&self.hosts)
    }

    /// Per-model `(active, total)` GPU counts under the strict §2 rule
    /// (every GPU of an active PM counts as active), indexed by
    /// `GpuModel as usize`. The per-model breakdown of Eq. 4's
    /// `Σ_k γ_jk` term.
    pub fn active_gpus_by_model(&self) -> [(usize, usize); crate::mig::NUM_MODELS] {
        let mut out = [(0usize, 0usize); crate::mig::NUM_MODELS];
        for h in &self.hosts {
            let active = h.is_active();
            for g in h.gpus() {
                let slot = &mut out[g.model() as usize];
                slot.1 += 1;
                if active {
                    slot.0 += 1;
                }
            }
        }
        out
    }

    /// Looser accounting for ablation: GPUs count individually (`γ_jk`
    /// set only when hosting a GI, Eq. 21).
    pub fn active_hardware_loose(&self) -> (usize, usize) {
        let mut active = 0usize;
        let mut total = 0usize;
        for h in &self.hosts {
            total += 1 + h.gpus().len();
            if h.is_active() {
                active += 1;
            }
            active += h.gpus().iter().filter(|g| !g.is_empty()).count();
        }
        (active, total)
    }

    /// Integrity check: every location index entry matches the GPU state,
    /// host ids equal their positions (the `globalIndex` addressing
    /// invariant the [`ClusterIndex`] ordering relies on), and the
    /// incrementally maintained index equals a brute-force rebuild.
    pub fn check_integrity(&self) -> Result<(), String> {
        for (i, h) in self.hosts.iter().enumerate() {
            if h.id as usize != i {
                return Err(format!("host id {} at position {i}", h.id));
            }
        }
        for (vm, loc) in &self.locations {
            let gpu = self.gpu(loc.gpu);
            match gpu.find_vm(*vm) {
                None => return Err(format!("VM {vm} indexed but absent from {:?}", loc.gpu)),
                Some(inst) if inst.placement != loc.placement => {
                    return Err(format!("VM {vm} placement mismatch"))
                }
                _ => {}
            }
        }
        for h in &self.hosts {
            for (g_idx, g) in h.gpus().iter().enumerate() {
                if !crate::mig::gpu::consistent(g) {
                    return Err(format!("host {} GPU {g_idx} inconsistent", h.id));
                }
                for inst in g.instances() {
                    let loc = self
                        .locations
                        .get(&inst.vm)
                        .ok_or_else(|| format!("VM {} on GPU but not indexed", inst.vm))?;
                    if loc.gpu != (GpuRef { host: h.id, gpu: g_idx as u8 }) {
                        return Err(format!("VM {} location index stale", inst.vm));
                    }
                }
            }
        }
        if ClusterIndex::build(&self.hosts) != self.index {
            return Err("cluster index out of sync with GPU/host state".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;

    fn spec(id: VmId, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 4, ram_gb: 16, arrival: 0, departure: 100, weight: 1.0 }
    }

    fn small_dc() -> DataCenter {
        DataCenter::new(vec![Host::new(0, 64, 256, 2), Host::new(1, 64, 256, 1)])
    }

    #[test]
    fn place_and_remove() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P3g20gb);
        let r = GpuRef { host: 0, gpu: 1 };
        dc.place(&vm, r, Placement { profile: Profile::P3g20gb, start: 0 });
        assert_eq!(dc.locate(1).unwrap().gpu, r);
        assert_eq!(dc.host(0).free_cpus(), 60);
        dc.check_integrity().unwrap();
        let loc = dc.remove(1).unwrap();
        assert_eq!(loc.gpu, r);
        assert!(dc.locate(1).is_none());
        assert_eq!(dc.host(0).free_cpus(), 64);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn strict_active_hardware() {
        let mut dc = small_dc();
        assert_eq!(dc.active_hardware(), (0, 5)); // 2 hosts + 3 GPUs
        let vm = spec(1, Profile::P1g5gb);
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P1g5gb, start: 6 });
        // Host 0 active: counts itself + BOTH its GPUs (strict rule).
        assert_eq!(dc.active_hardware(), (3, 5));
        assert_eq!(dc.active_hardware_loose(), (2, 5));
    }

    #[test]
    fn migrate_between_hosts_moves_resources() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P4g20gb);
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P4g20gb, start: 0 });
        dc.migrate(1, GpuRef { host: 1, gpu: 0 }, Placement { profile: Profile::P4g20gb, start: 0 });
        assert_eq!(dc.host(0).free_cpus(), 64);
        assert_eq!(dc.host(1).free_cpus(), 60);
        assert_eq!(dc.locate(1).unwrap().gpu, GpuRef { host: 1, gpu: 0 });
        dc.check_integrity().unwrap();
    }

    #[test]
    fn relocate_within_gpu() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P1g5gb);
        let r = GpuRef { host: 0, gpu: 0 };
        dc.place(&vm, r, Placement { profile: Profile::P1g5gb, start: 4 });
        dc.relocate_within_gpu(1, Placement { profile: Profile::P1g5gb, start: 6 });
        assert_eq!(dc.locate(1).unwrap().placement.start, 6);
        assert_eq!(dc.host(0).free_cpus(), 60); // CPU unchanged
        dc.check_integrity().unwrap();
    }

    #[test]
    fn gpu_refs_global_index_order() {
        let dc = small_dc();
        let refs = dc.gpu_refs();
        assert_eq!(
            refs,
            vec![
                GpuRef { host: 0, gpu: 0 },
                GpuRef { host: 0, gpu: 1 },
                GpuRef { host: 1, gpu: 0 }
            ]
        );
        let mut sorted = refs.clone();
        sorted.sort();
        assert_eq!(refs, sorted);
    }

    #[test]
    fn index_maintained_across_lifecycle() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P7g40gb);
        let r = GpuRef { host: 0, gpu: 0 };
        dc.place(&vm, r, Placement { profile: Profile::P7g40gb, start: 0 });
        assert!(!dc.index().gpus_fitting(Profile::P1g5gb).contains(&r));
        dc.check_integrity().unwrap();
        let dst = GpuRef { host: 1, gpu: 0 };
        dc.migrate(1, dst, Placement { profile: Profile::P7g40gb, start: 0 });
        assert!(dc.index().gpus_fitting(Profile::P1g5gb).contains(&r));
        assert!(!dc.index().gpus_fitting(Profile::P1g5gb).contains(&dst));
        dc.check_integrity().unwrap();
        dc.remove(1);
        assert!(dc.index().gpus_fitting(Profile::P7g40gb).contains(&dst));
        dc.check_integrity().unwrap();
    }

    #[test]
    fn repack_gpu_keeps_indices_coherent() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P1g5gb);
        let r = GpuRef { host: 0, gpu: 0 };
        dc.place(&vm, r, Placement { profile: Profile::P1g5gb, start: 4 });
        let inst = dc.gpu(r).find_vm(1).unwrap();
        dc.repack_gpu(r, &[(inst, Placement { profile: Profile::P1g5gb, start: 6 })]);
        assert_eq!(dc.locate(1).unwrap().placement.start, 6);
        assert_eq!(dc.gpu(r).instances()[0].placement.start, 6);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn per_model_hardware_accounting() {
        use crate::mig::GpuModel;
        let mut dc = DataCenter::new(vec![
            Host::with_models(0, 64, 256, &[GpuModel::A30, GpuModel::A100_40]),
            Host::with_models(1, 64, 256, &[GpuModel::H100_80]),
        ]);
        let total = dc.gpus_by_model();
        assert_eq!(total[GpuModel::A100_40 as usize], 1);
        assert_eq!(total[GpuModel::A30 as usize], 1);
        assert_eq!(total[GpuModel::H100_80 as usize], 1);
        assert_eq!(total[GpuModel::A100_80 as usize], 0);
        // Place on the A30: host 0 activates, so BOTH its GPUs (A30 and
        // A100) count active under the strict rule; host 1's H100 idles.
        let k = GpuModel::A30.profile(0);
        let vm = spec(1, k);
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile: k, start: 3 });
        let by_model = dc.active_gpus_by_model();
        assert_eq!(by_model[GpuModel::A30 as usize], (1, 1));
        assert_eq!(by_model[GpuModel::A100_40 as usize], (1, 1));
        assert_eq!(by_model[GpuModel::H100_80 as usize], (0, 1));
        dc.check_integrity().unwrap();
    }

    #[test]
    fn integrity_detects_corruption() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P1g5gb);
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P1g5gb, start: 6 });
        // Corrupt: remove from GPU behind the index's back.
        dc.host_mut(0).gpu_mut(0).remove_vm(1);
        assert!(dc.check_integrity().is_err());
    }
}
