//! The cluster state: hosts, GPU addressing, VM placement bookkeeping and
//! active-hardware accounting.
//!
//! Every mutation (`place`, `remove`, `migrate`, `relocate_within_gpu`,
//! `repack_gpu`) also maintains the [`ClusterIndex`] incrementally, so
//! policies query per-profile feasibility buckets and host headroom
//! instead of scanning the cluster.
//!
//! Cluster-wide *activity* aggregates — resident VM count, active hosts,
//! active GPUs per model — are likewise maintained incrementally (the
//! [`ActivityCounters`] below), so the per-interval metric sample reads
//! them in O(1) instead of scanning every host and GPU. The counters are
//! pure observers: no policy reads them when deciding a placement, which
//! is what keeps the indexed-vs-scan determinism contract untouched.
//! `check_integrity` verifies them against a brute-force recount, and the
//! `_scan` variants of the readers survive as that reference (and as the
//! "before" side of `benches/engine.rs`).

use super::health::HealthState;
use super::host::Host;
use super::index::ClusterIndex;
use super::vm::{Time, VmId, VmSpec};
use crate::mig::{GpuState, Instance, Placement, ProfileKey, ALL_MODELS, NUM_MODELS, NUM_PROFILE_KEYS};
use crate::util::codec::{Dec, Enc};
use std::collections::HashMap;

/// One integrity violation, attributed to a host when the failing check
/// is host-local (`None` for cluster-wide index/counter divergence).
/// Returned by [`DataCenter::try_check_integrity`] so the engine can
/// quarantine or rebuild instead of panicking.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IntegrityReport {
    /// The offending host, when one is identifiable.
    pub host: Option<u32>,
    /// Human-readable description of the violation.
    pub detail: String,
}

impl std::fmt::Display for IntegrityReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.host {
            Some(h) => write!(f, "host {h}: {}", self.detail),
            None => write!(f, "{}", self.detail),
        }
    }
}

impl IntegrityReport {
    fn cluster(detail: impl Into<String>) -> IntegrityReport {
        IntegrityReport { host: None, detail: detail.into() }
    }

    fn on_host(host: u32, detail: impl Into<String>) -> IntegrityReport {
        IntegrityReport { host: Some(host), detail: detail.into() }
    }
}

/// Address of one GPU: `(host index, GPU index within host)`. Ordering is
/// the paper's `globalIndex` (Algorithm 2) — lexicographic, so first-fit
/// scans are deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GpuRef {
    pub host: u32,
    pub gpu: u8,
}

/// Where a VM currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VmLocation {
    pub gpu: GpuRef,
    pub placement: Placement,
}

/// Incrementally maintained cluster-wide activity aggregates (§Perf
/// iteration 6): everything [`DataCenter::active_hardware`],
/// [`DataCenter::active_gpus_by_model`] and [`DataCenter::gpus_by_model`]
/// report, updated in O(1) whenever a host crosses the active/idle
/// boundary. The fleet composition (`total_*`, `host_gpus`) is static
/// after construction — GPUs are never added or removed.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
struct ActivityCounters {
    /// Hosts currently hosting at least one VM (`φ_j` summed).
    active_hosts: usize,
    /// Active units under the strict §2 rule: each active host counts
    /// itself plus *all* its GPUs (Eq. 4's `φ_j + Σ_k γ_jk`).
    active_units: usize,
    /// Total units: hosts + GPUs.
    total_units: usize,
    /// Active GPUs per catalog model (strict rule), by `GpuModel as usize`.
    active_gpus_by_model: [usize; NUM_MODELS],
    /// Fleet composition: GPU count per model.
    total_gpus_by_model: [usize; NUM_MODELS],
    /// Per-host GPU composition `(gpu count, per-model counts)`, indexed
    /// by host id. What makes an activation flip O(1).
    host_gpus: Vec<(usize, [usize; NUM_MODELS])>,
}

impl ActivityCounters {
    /// Brute-force (re)construction — the reference the incremental
    /// maintenance is verified against by `check_integrity` and the
    /// counter property tests.
    fn build(hosts: &[Host]) -> ActivityCounters {
        let mut a = ActivityCounters::default();
        for h in hosts {
            let mut by_model = [0usize; NUM_MODELS];
            for g in h.gpus() {
                by_model[g.model() as usize] += 1;
            }
            a.total_units += 1 + h.gpus().len();
            for (t, &n) in a.total_gpus_by_model.iter_mut().zip(&by_model) {
                *t += n;
            }
            if h.is_active() {
                a.active_hosts += 1;
                a.active_units += 1 + h.gpus().len();
                for (t, &n) in a.active_gpus_by_model.iter_mut().zip(&by_model) {
                    *t += n;
                }
            }
            a.host_gpus.push((h.gpus().len(), by_model));
        }
        a
    }
}

/// The data center: all hosts plus a VM→location index, the incrementally
/// maintained [`ClusterIndex`] and the O(1) activity counters.
#[derive(Debug, Clone, Default)]
pub struct DataCenter {
    hosts: Vec<Host>,
    locations: HashMap<VmId, VmLocation>,
    /// CPU/RAM demands of resident VMs (needed on departure).
    demands: HashMap<VmId, (u32, u32)>,
    /// Placement index, kept coherent by every mutation below.
    index: ClusterIndex,
    /// Activity aggregates, kept coherent by every mutation below.
    activity: ActivityCounters,
    /// GPUs currently unschedulable (own health or their host's), kept
    /// coherent by the health mutators; read per interval for the
    /// availability metric.
    offline_gpus: usize,
}

impl DataCenter {
    pub fn new(hosts: Vec<Host>) -> DataCenter {
        let index = ClusterIndex::build(&hosts);
        let activity = ActivityCounters::build(&hosts);
        DataCenter {
            hosts,
            locations: HashMap::new(),
            demands: HashMap::new(),
            index,
            activity,
            offline_gpus: 0,
        }
    }

    /// Apply a host's active↔idle flip to the activity counters. Called
    /// after every reserve/release with the host's prior state; O(1) via
    /// the precomputed per-host GPU composition.
    fn note_host_transition(&mut self, host: u32, was_active: bool) {
        let is_active = self.hosts[host as usize].is_active();
        if was_active == is_active {
            return;
        }
        let (gpus, by_model) = self.activity.host_gpus[host as usize];
        let units = 1 + gpus;
        if is_active {
            self.activity.active_hosts += 1;
            self.activity.active_units += units;
            for (t, &n) in self.activity.active_gpus_by_model.iter_mut().zip(&by_model) {
                *t += n;
            }
        } else {
            self.activity.active_hosts -= 1;
            self.activity.active_units -= units;
            for (t, &n) in self.activity.active_gpus_by_model.iter_mut().zip(&by_model) {
                *t -= n;
            }
        }
    }

    /// The placement index (per-profile feasibility buckets + host
    /// headroom). Read-only: coherence is this type's responsibility.
    #[inline]
    pub fn index(&self) -> &ClusterIndex {
        &self.index
    }

    pub fn hosts(&self) -> &[Host] {
        &self.hosts
    }

    pub fn host(&self, id: u32) -> &Host {
        &self.hosts[id as usize]
    }

    pub fn host_mut(&mut self, id: u32) -> &mut Host {
        &mut self.hosts[id as usize]
    }

    /// Total number of GPUs in the data center.
    pub fn num_gpus(&self) -> usize {
        self.hosts.iter().map(|h| h.gpus().len()).sum()
    }

    /// All GPU references in `globalIndex` order.
    pub fn gpu_refs(&self) -> Vec<GpuRef> {
        let mut refs = Vec::with_capacity(self.num_gpus());
        for h in &self.hosts {
            for g in 0..h.gpus().len() {
                refs.push(GpuRef { host: h.id, gpu: g as u8 });
            }
        }
        refs
    }

    pub fn gpu(&self, r: GpuRef) -> &GpuState {
        &self.hosts[r.host as usize].gpus()[r.gpu as usize]
    }

    /// Raw mutable GPU access. **Bypasses the [`ClusterIndex`]** — only
    /// for tests that deliberately corrupt state; production mutation
    /// goes through `place`/`remove`/`migrate`/`relocate_within_gpu`/
    /// [`DataCenter::repack_gpu`], which keep the index coherent.
    pub fn gpu_mut(&mut self, r: GpuRef) -> &mut GpuState {
        self.hosts[r.host as usize].gpu_mut(r.gpu as usize)
    }

    /// Location of a resident VM.
    pub fn locate(&self, vm: VmId) -> Option<VmLocation> {
        self.locations.get(&vm).copied()
    }

    /// CPU/RAM demands of a resident VM.
    pub fn vm_demands(&self, vm: VmId) -> Option<(u32, u32)> {
        self.demands.get(&vm).copied()
    }

    /// Number of resident VMs (O(1); `check_integrity` verifies it
    /// against the instances actually sitting on GPUs).
    #[inline]
    pub fn resident_count(&self) -> usize {
        self.locations.len()
    }

    /// Operational health of one GPU (the device's own state; its host
    /// may be unhealthy independently).
    #[inline]
    pub fn gpu_health(&self, r: GpuRef) -> HealthState {
        self.hosts[r.host as usize].gpu_health(r.gpu as usize)
    }

    /// Operational health of one host.
    #[inline]
    pub fn host_health(&self, id: u32) -> HealthState {
        self.hosts[id as usize].health()
    }

    /// Is the GPU schedulable (device *and* host healthy)?
    #[inline]
    pub fn gpu_available(&self, r: GpuRef) -> bool {
        self.hosts[r.host as usize].gpu_available(r.gpu as usize)
    }

    /// Is the host schedulable?
    #[inline]
    pub fn host_available(&self, id: u32) -> bool {
        self.hosts[id as usize].health().allows_placement()
    }

    /// GPUs currently unschedulable (own health or their host's) — the
    /// numerator of the per-interval availability metric. O(1).
    #[inline]
    pub fn offline_gpus(&self) -> usize {
        self.offline_gpus
    }

    /// VMs resident on one GPU, in ascending id order (the deterministic
    /// eviction order on a device failure).
    pub fn vms_on_gpu(&self, r: GpuRef) -> Vec<VmId> {
        let mut vms: Vec<VmId> = self.gpu(r).instances().iter().map(|i| i.vm).collect();
        vms.sort_unstable();
        vms
    }

    /// VMs resident on one host, GPU-major then ascending id (the
    /// deterministic eviction/evacuation order on a host event).
    pub fn vms_on_host(&self, id: u32) -> Vec<VmId> {
        let mut out = Vec::new();
        for g in 0..self.hosts[id as usize].gpus().len() {
            out.extend(self.vms_on_gpu(GpuRef { host: id, gpu: g as u8 }));
        }
        out
    }

    /// Unschedulable-GPU count of one host under a hypothetical host
    /// health (used by the health mutators to keep `offline_gpus` O(#GPUs
    /// of the touched host)).
    fn host_offline_gpus(&self, id: u32, health: HealthState) -> usize {
        let h = &self.hosts[id as usize];
        if !health.allows_placement() {
            return h.gpus().len();
        }
        (0..h.gpus().len()).filter(|&g| !h.gpu_health(g).allows_placement()).count()
    }

    /// Set the health of one GPU, keeping the [`ClusterIndex`] contract:
    /// the device's bucket entries are detached when it stops being
    /// schedulable and re-attached when it recovers. The caller must
    /// have evicted resident VMs *before* marking a device failed/banned
    /// (while the index still covers it); `check_integrity` enforces the
    /// resulting emptiness.
    pub fn set_gpu_health(&mut self, r: GpuRef, health: HealthState) {
        let host = &self.hosts[r.host as usize];
        let was = host.gpu_available(r.gpu as usize);
        let host_ok = host.health().allows_placement();
        let now = host_ok && health.allows_placement();
        self.hosts[r.host as usize].gpu_health[r.gpu as usize] = health;
        if was == now {
            return; // host down, or no schedulability flip: index untouched
        }
        let gpu = self.gpu(r);
        let (model, occ) = (gpu.model(), gpu.occupancy());
        if now {
            self.index.attach_gpu(r, model, occ);
            self.offline_gpus -= 1;
        } else {
            self.index.detach_gpu(r, model, occ);
            self.offline_gpus += 1;
        }
    }

    /// Set the health of one host, attaching/detaching its headroom
    /// classes, model counts and every schedulable GPU of the machine on
    /// availability transitions. As with [`DataCenter::set_gpu_health`],
    /// evictions must happen before the transition to failed/banned.
    pub fn set_host_health(&mut self, id: u32, health: HealthState) {
        let old = self.hosts[id as usize].health();
        if old == health {
            return;
        }
        let offline_before = self.host_offline_gpus(id, old);
        let offline_after = self.host_offline_gpus(id, health);
        self.hosts[id as usize].health = health;
        self.offline_gpus = self.offline_gpus + offline_after - offline_before;
        match (old.allows_placement(), health.allows_placement()) {
            (true, false) => self.index.detach_host(&self.hosts[id as usize]),
            (false, true) => self.index.attach_host(&self.hosts[id as usize]),
            _ => {} // still attached or still detached
        }
    }

    /// Place `vm` on the given GPU at the given placement, reserving host
    /// CPU/RAM. Caller must have validated feasibility (CPU/RAM and block
    /// availability); debug builds assert it.
    pub fn place(&mut self, vm: &VmSpec, gpu_ref: GpuRef, placement: Placement) {
        debug_assert!(self.locations.get(&vm.id).is_none(), "VM {} already placed", vm.id);
        let host = &mut self.hosts[gpu_ref.host as usize];
        let was_active = host.is_active();
        let host_avail = host.health.allows_placement();
        let gpu_avail = host.gpu_available(gpu_ref.gpu as usize);
        let old_free = (host.free_cpus(), host.free_ram());
        host.reserve(vm.cpus, vm.ram_gb);
        let new_free = (host.free_cpus(), host.free_ram());
        let gpu = host.gpu_mut(gpu_ref.gpu as usize);
        let model = gpu.model();
        let old_occ = gpu.occupancy();
        gpu.place(vm.id, placement);
        let new_occ = gpu.occupancy();
        // Unavailable capacity has no index entries to maintain (the
        // health contract); same gate in every mutator below.
        if host_avail {
            self.index.update_host(old_free, new_free);
        }
        if gpu_avail {
            self.index.update_gpu(gpu_ref, model, old_occ, new_occ);
        }
        self.note_host_transition(gpu_ref.host, was_active);
        self.locations.insert(vm.id, VmLocation { gpu: gpu_ref, placement });
        self.demands.insert(vm.id, (vm.cpus, vm.ram_gb));
    }

    /// Remove a resident VM entirely (departure), releasing all resources.
    /// Returns its former location.
    pub fn remove(&mut self, vm: VmId) -> Option<VmLocation> {
        let loc = self.locations.remove(&vm)?;
        let (cpus, ram) = self.demands.remove(&vm).unwrap_or((0, 0));
        let host = &mut self.hosts[loc.gpu.host as usize];
        let was_active = host.is_active();
        let host_avail = host.health.allows_placement();
        let gpu_avail = host.gpu_available(loc.gpu.gpu as usize);
        let old_free = (host.free_cpus(), host.free_ram());
        let gpu = host.gpu_mut(loc.gpu.gpu as usize);
        let model = gpu.model();
        let old_occ = gpu.occupancy();
        gpu.remove_vm(vm);
        let new_occ = gpu.occupancy();
        host.release(cpus, ram);
        let new_free = (host.free_cpus(), host.free_ram());
        if host_avail {
            self.index.update_host(old_free, new_free);
        }
        if gpu_avail {
            self.index.update_gpu(loc.gpu, model, old_occ, new_occ);
        }
        self.note_host_transition(loc.gpu.host, was_active);
        Some(loc)
    }

    /// Move a VM's GI to a different placement on the *same* GPU
    /// (intra-GPU migration; the `ω_ijk`-only case of Eq. 24–25).
    pub fn relocate_within_gpu(&mut self, vm: VmId, new_placement: Placement) {
        let loc = self.locations.get_mut(&vm).expect("VM resident");
        let gpu_ref = loc.gpu;
        loc.placement = new_placement;
        let gpu = self.hosts[gpu_ref.host as usize].gpu_mut(gpu_ref.gpu as usize);
        let model = gpu.model();
        let old_occ = gpu.occupancy();
        gpu.remove_vm(vm).expect("instance present");
        gpu.place(vm, new_placement);
        let new_occ = gpu.occupancy();
        if self.gpu_available(gpu_ref) {
            self.index.update_gpu(gpu_ref, model, old_occ, new_occ);
        }
    }

    /// Apply an intra-GPU re-pack plan (the defragmentation path): all
    /// moving instances are removed first, then placed at their new
    /// positions — avoiding transient overlaps when instances swap.
    /// Host resources are untouched; the location and cluster indices
    /// stay coherent.
    pub fn repack_gpu(&mut self, gpu_ref: GpuRef, moves: &[(Instance, Placement)]) {
        let gpu = self.hosts[gpu_ref.host as usize].gpu_mut(gpu_ref.gpu as usize);
        let model = gpu.model();
        let old_occ = gpu.occupancy();
        for (inst, _) in moves {
            gpu.remove_vm(inst.vm).expect("moving instance present");
        }
        for (inst, new_placement) in moves {
            gpu.place(inst.vm, *new_placement);
        }
        let new_occ = gpu.occupancy();
        for (inst, new_placement) in moves {
            self.locations
                .insert(inst.vm, VmLocation { gpu: gpu_ref, placement: *new_placement });
        }
        if self.gpu_available(gpu_ref) {
            self.index.update_gpu(gpu_ref, model, old_occ, new_occ);
        }
    }

    /// Move a VM's GI to a different GPU (inter-GPU migration). Host
    /// CPU/RAM moves with it when the hosts differ. Caller validated the
    /// destination placement is free.
    pub fn migrate(&mut self, vm: VmId, dst: GpuRef, placement: Placement) {
        let loc = *self.locations.get(&vm).expect("VM resident");
        let (cpus, ram) = *self.demands.get(&vm).expect("VM demands known");
        let src = loc.gpu;
        let src_avail = self.gpu_available(src);
        let src_gpu = self.hosts[src.host as usize].gpu_mut(src.gpu as usize);
        let src_model = src_gpu.model();
        let src_old_occ = src_gpu.occupancy();
        src_gpu.remove_vm(vm);
        let src_new_occ = src_gpu.occupancy();
        if src_avail {
            self.index.update_gpu(src, src_model, src_old_occ, src_new_occ);
        }
        if src.host != dst.host {
            let src_host = &mut self.hosts[src.host as usize];
            let src_was_active = src_host.is_active();
            let src_host_avail = src_host.health.allows_placement();
            let old_free = (src_host.free_cpus(), src_host.free_ram());
            src_host.release(cpus, ram);
            if src_host_avail {
                self.index.update_host(old_free, (src_host.free_cpus(), src_host.free_ram()));
            }
            self.note_host_transition(src.host, src_was_active);
            let dst_host = &mut self.hosts[dst.host as usize];
            let dst_was_active = dst_host.is_active();
            let dst_host_avail = dst_host.health.allows_placement();
            let old_free = (dst_host.free_cpus(), dst_host.free_ram());
            dst_host.reserve(cpus, ram);
            if dst_host_avail {
                self.index.update_host(old_free, (dst_host.free_cpus(), dst_host.free_ram()));
            }
            self.note_host_transition(dst.host, dst_was_active);
        }
        let dst_avail = self.gpu_available(dst);
        let dst_gpu = self.hosts[dst.host as usize].gpu_mut(dst.gpu as usize);
        let dst_model = dst_gpu.model();
        let dst_old_occ = dst_gpu.occupancy();
        dst_gpu.place(vm, placement);
        let dst_new_occ = dst_gpu.occupancy();
        if dst_avail {
            self.index.update_gpu(dst, dst_model, dst_old_occ, dst_new_occ);
        }
        self.locations.insert(vm, VmLocation { gpu: dst, placement });
    }

    /// Active-hardware count under the paper's *strict* definition (§2):
    /// a PM is active if it hosts any VM; every GPU on an active PM counts
    /// as active even when idle (idle GPUs count as inactive only when the
    /// whole machine is idle). Returns `(active units, total units)` where
    /// a unit is one PM or one GPU, matching Eq. 4's `φ_j + Σ_k γ_jk`.
    ///
    /// An O(1) counter read since §Perf iteration 6; the fleet scan it
    /// replaced survives as [`DataCenter::active_hardware_scan`].
    #[inline]
    pub fn active_hardware(&self) -> (usize, usize) {
        (self.activity.active_units, self.activity.total_units)
    }

    /// Hosts currently hosting at least one VM (O(1) counter read).
    #[inline]
    pub fn active_host_count(&self) -> usize {
        self.activity.active_hosts
    }

    /// Brute-force fleet scan behind [`DataCenter::active_hardware`] —
    /// the pre-iteration-6 per-interval cost, retained as the
    /// `check_integrity` reference and the "before" side of
    /// `benches/engine.rs`.
    pub fn active_hardware_scan(&self) -> (usize, usize) {
        let mut active = 0usize;
        let mut total = 0usize;
        for h in &self.hosts {
            total += 1 + h.gpus().len();
            if h.is_active() {
                active += 1 + h.gpus().len();
            }
        }
        (active, total)
    }

    /// Active-hardware rate in `[0, 1]`.
    pub fn active_hardware_rate(&self) -> f64 {
        let (active, total) = self.active_hardware();
        if total == 0 {
            0.0
        } else {
            active as f64 / total as f64
        }
    }

    /// GPU count per catalog model, indexed by `GpuModel as usize`
    /// (the fleet composition; static, O(1) counter read).
    #[inline]
    pub fn gpus_by_model(&self) -> [usize; NUM_MODELS] {
        self.activity.total_gpus_by_model
    }

    /// Per-model `(active, total)` GPU counts under the strict §2 rule
    /// (every GPU of an active PM counts as active), indexed by
    /// `GpuModel as usize`. The per-model breakdown of Eq. 4's
    /// `Σ_k γ_jk` term.
    ///
    /// An O(1) counter read since §Perf iteration 6; the fleet scan it
    /// replaced survives as [`DataCenter::active_gpus_by_model_scan`].
    #[inline]
    pub fn active_gpus_by_model(&self) -> [(usize, usize); NUM_MODELS] {
        let mut out = [(0usize, 0usize); NUM_MODELS];
        for ((o, &active), &total) in out
            .iter_mut()
            .zip(&self.activity.active_gpus_by_model)
            .zip(&self.activity.total_gpus_by_model)
        {
            *o = (active, total);
        }
        out
    }

    /// Brute-force fleet scan behind [`DataCenter::active_gpus_by_model`]
    /// (see [`DataCenter::active_hardware_scan`]).
    pub fn active_gpus_by_model_scan(&self) -> [(usize, usize); NUM_MODELS] {
        let mut out = [(0usize, 0usize); NUM_MODELS];
        for h in &self.hosts {
            let active = h.is_active();
            for g in h.gpus() {
                let slot = &mut out[g.model() as usize];
                slot.1 += 1;
                if active {
                    slot.0 += 1;
                }
            }
        }
        out
    }

    /// Looser accounting for ablation: GPUs count individually (`γ_jk`
    /// set only when hosting a GI, Eq. 21).
    pub fn active_hardware_loose(&self) -> (usize, usize) {
        let mut active = 0usize;
        let mut total = 0usize;
        for h in &self.hosts {
            total += 1 + h.gpus().len();
            if h.is_active() {
                active += 1;
            }
            active += h.gpus().iter().filter(|g| !g.is_empty()).count();
        }
        (active, total)
    }

    /// Integrity check: every location index entry matches the GPU state,
    /// host ids equal their positions (the `globalIndex` addressing
    /// invariant the [`ClusterIndex`] ordering relies on), and the
    /// incrementally maintained index equals a brute-force rebuild.
    ///
    /// Compat wrapper over [`DataCenter::try_check_integrity`] — same
    /// checks, stringly-typed error.
    pub fn check_integrity(&self) -> Result<(), String> {
        self.try_check_integrity().map_err(|r| r.to_string())
    }

    /// Non-panicking integrity check returning a structured
    /// [`IntegrityReport`] that attributes host-local violations, so
    /// the engine's `--on-corruption quarantine` mode knows *what* to
    /// quarantine. The historical behaviour (callers `.expect(..)` on
    /// [`DataCenter::check_integrity`]) is untouched.
    pub fn try_check_integrity(&self) -> Result<(), IntegrityReport> {
        for (i, h) in self.hosts.iter().enumerate() {
            if h.id as usize != i {
                return Err(IntegrityReport::cluster(format!("host id {} at position {i}", h.id)));
            }
        }
        for (vm, loc) in &self.locations {
            let gpu = self.gpu(loc.gpu);
            match gpu.find_vm(*vm) {
                None => {
                    return Err(IntegrityReport::on_host(
                        loc.gpu.host,
                        format!("VM {vm} indexed but absent from {:?}", loc.gpu),
                    ))
                }
                Some(inst) if inst.placement != loc.placement => {
                    return Err(IntegrityReport::on_host(
                        loc.gpu.host,
                        format!("VM {vm} placement mismatch"),
                    ))
                }
                _ => {}
            }
        }
        for h in &self.hosts {
            for (g_idx, g) in h.gpus().iter().enumerate() {
                if !crate::mig::gpu::consistent(g) {
                    return Err(IntegrityReport::on_host(
                        h.id,
                        format!("host {} GPU {g_idx} inconsistent", h.id),
                    ));
                }
                for inst in g.instances() {
                    let loc = self.locations.get(&inst.vm).ok_or_else(|| {
                        IntegrityReport::on_host(
                            h.id,
                            format!("VM {} on GPU but not indexed", inst.vm),
                        )
                    })?;
                    if loc.gpu != (GpuRef { host: h.id, gpu: g_idx as u8 }) {
                        return Err(IntegrityReport::on_host(
                            h.id,
                            format!("VM {} location index stale", inst.vm),
                        ));
                    }
                }
            }
        }
        // Health contract: failed/banned capacity holds no VMs (draining
        // may — evacuation is best-effort), the index covers schedulable
        // capacity only (the rebuild below skips unhealthy capacity, so
        // the equality comparison verifies it), and the offline-GPU
        // counter matches a fleet recount.
        let mut offline = 0usize;
        for h in &self.hosts {
            let host_resident_ok = h.health().allows_residency();
            for (g_idx, g) in h.gpus().iter().enumerate() {
                if !h.gpu_available(g_idx) {
                    offline += 1;
                }
                if !(host_resident_ok && h.gpu_health(g_idx).allows_residency())
                    && !g.instances().is_empty()
                {
                    return Err(IntegrityReport::on_host(
                        h.id,
                        format!(
                            "host {} GPU {g_idx} is {}/{} but holds {} VMs",
                            h.id,
                            h.health(),
                            h.gpu_health(g_idx),
                            g.instances().len()
                        ),
                    ));
                }
            }
        }
        if offline != self.offline_gpus {
            return Err(IntegrityReport::cluster(format!(
                "offline-GPU counter {} != {offline} per recount",
                self.offline_gpus
            )));
        }
        if ClusterIndex::build(&self.hosts) != self.index {
            return Err(IntegrityReport::cluster("cluster index out of sync with GPU/host state"));
        }
        if let Err(e) = self.index.check_invariants() {
            return Err(IntegrityReport::cluster(format!("cluster index invariant broken: {e}")));
        }
        if ActivityCounters::build(&self.hosts) != self.activity {
            return Err(IntegrityReport::cluster("activity counters out of sync with host state"));
        }
        let resident: usize =
            self.hosts.iter().flat_map(|h| h.gpus()).map(|g| g.instances().len()).sum();
        if resident != self.locations.len() {
            return Err(IntegrityReport::cluster(format!(
                "resident count {} != {} instances on GPUs",
                self.locations.len(),
                resident
            )));
        }
        Ok(())
    }

    /// Rebuild every piece of *derived* state — VM locations, the
    /// [`ClusterIndex`], activity counters and the offline-GPU counter —
    /// from the ground truth sitting on the hosts' GPUs. The
    /// `--on-corruption quarantine|rebuild` repair path.
    ///
    /// Limits: per-VM CPU/RAM `demands` are not fully recoverable (hosts
    /// store only aggregate reservations), so existing entries are kept
    /// for VMs still resident and entries of departed VMs are dropped; a
    /// VM whose demand entry was lost releases `(0, 0)` on departure.
    pub fn rebuild_derived(&mut self) {
        let mut locations = HashMap::with_capacity(self.locations.len());
        for h in &self.hosts {
            for (g_idx, g) in h.gpus().iter().enumerate() {
                for inst in g.instances() {
                    locations.insert(
                        inst.vm,
                        VmLocation {
                            gpu: GpuRef { host: h.id, gpu: g_idx as u8 },
                            placement: inst.placement,
                        },
                    );
                }
            }
        }
        self.demands.retain(|vm, _| locations.contains_key(vm));
        self.locations = locations;
        self.index = ClusterIndex::build(&self.hosts);
        self.activity = ActivityCounters::build(&self.hosts);
        self.offline_gpus = self
            .hosts
            .iter()
            .map(|h| (0..h.gpus().len()).filter(|&g| !h.gpu_available(g)).count())
            .sum();
    }

    fn encode_health(e: &mut Enc, h: HealthState) {
        match h {
            HealthState::Healthy => e.u8(0),
            HealthState::Failed { until } => {
                e.u8(1);
                e.u64(until);
            }
            HealthState::Draining => e.u8(2),
            HealthState::Banned => e.u8(3),
        }
    }

    fn decode_health(d: &mut Dec) -> Result<HealthState, String> {
        Ok(match d.u8()? {
            0 => HealthState::Healthy,
            1 => HealthState::Failed { until: d.u64()? as Time },
            2 => HealthState::Draining,
            3 => HealthState::Banned,
            t => return Err(format!("unknown health tag {t}")),
        })
    }

    /// Serialize the ground truth for the crash-safe snapshot layer:
    /// per host — id, CPU/RAM capacity, weight, health, per-GPU model +
    /// health + resident instances (with each VM's CPU/RAM demand
    /// inline). Derived state (index, activity counters, locations,
    /// offline-GPU counter) is deliberately **not** written — the
    /// restore path re-derives it by replaying placements and then
    /// cross-checks with [`DataCenter::try_check_integrity`], so a
    /// snapshot can never resurrect stale derived state.
    pub fn snapshot_into(&self, e: &mut Enc) {
        e.usize(self.hosts.len());
        for h in &self.hosts {
            e.u32(h.id);
            e.u32(h.cpus);
            e.u32(h.ram_gb);
            e.f64(h.weight);
            Self::encode_health(e, h.health());
            e.usize(h.gpus().len());
            for (g_idx, g) in h.gpus().iter().enumerate() {
                e.u8(g.model() as u8);
                Self::encode_health(e, h.gpu_health(g_idx));
                // Ascending start order: a deterministic replay order
                // that is also a valid placement order (no overlaps).
                let mut insts: Vec<Instance> = g.instances().to_vec();
                insts.sort_by_key(|i| i.placement.start);
                e.usize(insts.len());
                for inst in &insts {
                    e.u64(inst.vm);
                    e.u8(inst.placement.profile.dense() as u8);
                    e.u8(inst.placement.start);
                    let (cpus, ram) = self.demands.get(&inst.vm).copied().unwrap_or((0, 0));
                    e.u32(cpus);
                    e.u32(ram);
                }
            }
        }
    }

    /// Rebuild a data center from a [`DataCenter::snapshot_into`]
    /// stream: construct pristine hosts, replay every resident instance
    /// through [`DataCenter::place`] (host/GPU/start ascending, the
    /// writer's order), then apply GPU and host health exactly as the
    /// live run did (device transitions before host transitions), and
    /// finally verify the result with the integrity checker. Failed or
    /// banned capacity holds no VMs per the health contract, so the
    /// place-before-health order is always feasible.
    pub fn restore_from(d: &mut Dec) -> Result<DataCenter, String> {
        struct PendingInst {
            gpu: GpuRef,
            vm: VmId,
            profile: ProfileKey,
            start: u8,
            cpus: u32,
            ram: u32,
        }
        let num_hosts = d.count(14)?;
        let mut hosts = Vec::with_capacity(num_hosts);
        let mut pending: Vec<PendingInst> = Vec::new();
        let mut host_health: Vec<(u32, HealthState)> = Vec::new();
        let mut gpu_health: Vec<(GpuRef, HealthState)> = Vec::new();
        for _ in 0..num_hosts {
            let id = d.u32()?;
            let cpus = d.u32()?;
            let ram_gb = d.u32()?;
            let weight = d.f64()?;
            let health = Self::decode_health(d)?;
            if health != HealthState::Healthy {
                host_health.push((id, health));
            }
            let num_gpus = d.count(2)?;
            let mut models = Vec::with_capacity(num_gpus);
            for g_idx in 0..num_gpus {
                let model_tag = d.u8()? as usize;
                if model_tag >= NUM_MODELS {
                    return Err(format!("unknown GPU model tag {model_tag}"));
                }
                models.push(ALL_MODELS[model_tag]);
                let gh = Self::decode_health(d)?;
                let r = GpuRef { host: id, gpu: g_idx as u8 };
                if gh != HealthState::Healthy {
                    gpu_health.push((r, gh));
                }
                let num_insts = d.count(22)?;
                for _ in 0..num_insts {
                    let vm = d.u64()?;
                    let dense = d.u8()? as usize;
                    if dense >= NUM_PROFILE_KEYS {
                        return Err(format!("profile dense index {dense} out of range"));
                    }
                    let profile = ProfileKey::from_dense(dense);
                    let start = d.u8()?;
                    let cpus = d.u32()?;
                    let ram = d.u32()?;
                    pending.push(PendingInst { gpu: r, vm, profile, start, cpus, ram });
                }
            }
            let mut h = Host::with_models(id, cpus, ram_gb, &models);
            h.weight = weight;
            hosts.push(h);
        }
        let mut dc = DataCenter::new(hosts);
        for p in &pending {
            if p.gpu.host as usize >= dc.hosts.len() {
                return Err(format!("instance on unknown host {}", p.gpu.host));
            }
            let spec = VmSpec {
                id: p.vm,
                profile: p.profile,
                cpus: p.cpus,
                ram_gb: p.ram,
                arrival: 0,
                departure: 0,
                weight: 1.0,
            };
            dc.place(&spec, p.gpu, Placement { profile: p.profile, start: p.start });
        }
        for (r, h) in gpu_health {
            dc.set_gpu_health(r, h);
        }
        for (id, h) in host_health {
            dc.set_host_health(id, h);
        }
        dc.check_integrity().map_err(|e| format!("restored state fails integrity: {e}"))?;
        Ok(dc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;

    fn spec(id: VmId, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 4, ram_gb: 16, arrival: 0, departure: 100, weight: 1.0 }
    }

    fn small_dc() -> DataCenter {
        DataCenter::new(vec![Host::new(0, 64, 256, 2), Host::new(1, 64, 256, 1)])
    }

    #[test]
    fn place_and_remove() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P3g20gb);
        let r = GpuRef { host: 0, gpu: 1 };
        dc.place(&vm, r, Placement { profile: Profile::P3g20gb, start: 0 });
        assert_eq!(dc.locate(1).unwrap().gpu, r);
        assert_eq!(dc.host(0).free_cpus(), 60);
        dc.check_integrity().unwrap();
        let loc = dc.remove(1).unwrap();
        assert_eq!(loc.gpu, r);
        assert!(dc.locate(1).is_none());
        assert_eq!(dc.host(0).free_cpus(), 64);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn strict_active_hardware() {
        let mut dc = small_dc();
        assert_eq!(dc.active_hardware(), (0, 5)); // 2 hosts + 3 GPUs
        let vm = spec(1, Profile::P1g5gb);
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P1g5gb, start: 6 });
        // Host 0 active: counts itself + BOTH its GPUs (strict rule).
        assert_eq!(dc.active_hardware(), (3, 5));
        assert_eq!(dc.active_hardware_loose(), (2, 5));
    }

    #[test]
    fn migrate_between_hosts_moves_resources() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P4g20gb);
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P4g20gb, start: 0 });
        dc.migrate(1, GpuRef { host: 1, gpu: 0 }, Placement { profile: Profile::P4g20gb, start: 0 });
        assert_eq!(dc.host(0).free_cpus(), 64);
        assert_eq!(dc.host(1).free_cpus(), 60);
        assert_eq!(dc.locate(1).unwrap().gpu, GpuRef { host: 1, gpu: 0 });
        dc.check_integrity().unwrap();
    }

    #[test]
    fn relocate_within_gpu() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P1g5gb);
        let r = GpuRef { host: 0, gpu: 0 };
        dc.place(&vm, r, Placement { profile: Profile::P1g5gb, start: 4 });
        dc.relocate_within_gpu(1, Placement { profile: Profile::P1g5gb, start: 6 });
        assert_eq!(dc.locate(1).unwrap().placement.start, 6);
        assert_eq!(dc.host(0).free_cpus(), 60); // CPU unchanged
        dc.check_integrity().unwrap();
    }

    #[test]
    fn gpu_refs_global_index_order() {
        let dc = small_dc();
        let refs = dc.gpu_refs();
        assert_eq!(
            refs,
            vec![
                GpuRef { host: 0, gpu: 0 },
                GpuRef { host: 0, gpu: 1 },
                GpuRef { host: 1, gpu: 0 }
            ]
        );
        let mut sorted = refs.clone();
        sorted.sort();
        assert_eq!(refs, sorted);
    }

    #[test]
    fn index_maintained_across_lifecycle() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P7g40gb);
        let r = GpuRef { host: 0, gpu: 0 };
        dc.place(&vm, r, Placement { profile: Profile::P7g40gb, start: 0 });
        assert!(!dc.index().gpus_fitting(Profile::P1g5gb).contains(r));
        dc.check_integrity().unwrap();
        let dst = GpuRef { host: 1, gpu: 0 };
        dc.migrate(1, dst, Placement { profile: Profile::P7g40gb, start: 0 });
        assert!(dc.index().gpus_fitting(Profile::P1g5gb).contains(r));
        assert!(!dc.index().gpus_fitting(Profile::P1g5gb).contains(dst));
        dc.check_integrity().unwrap();
        dc.remove(1);
        assert!(dc.index().gpus_fitting(Profile::P7g40gb).contains(dst));
        dc.check_integrity().unwrap();
    }

    #[test]
    fn repack_gpu_keeps_indices_coherent() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P1g5gb);
        let r = GpuRef { host: 0, gpu: 0 };
        dc.place(&vm, r, Placement { profile: Profile::P1g5gb, start: 4 });
        let inst = dc.gpu(r).find_vm(1).unwrap();
        dc.repack_gpu(r, &[(inst, Placement { profile: Profile::P1g5gb, start: 6 })]);
        assert_eq!(dc.locate(1).unwrap().placement.start, 6);
        assert_eq!(dc.gpu(r).instances()[0].placement.start, 6);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn per_model_hardware_accounting() {
        use crate::mig::GpuModel;
        let mut dc = DataCenter::new(vec![
            Host::with_models(0, 64, 256, &[GpuModel::A30, GpuModel::A100_40]),
            Host::with_models(1, 64, 256, &[GpuModel::H100_80]),
        ]);
        let total = dc.gpus_by_model();
        assert_eq!(total[GpuModel::A100_40 as usize], 1);
        assert_eq!(total[GpuModel::A30 as usize], 1);
        assert_eq!(total[GpuModel::H100_80 as usize], 1);
        assert_eq!(total[GpuModel::A100_80 as usize], 0);
        // Place on the A30: host 0 activates, so BOTH its GPUs (A30 and
        // A100) count active under the strict rule; host 1's H100 idles.
        let k = GpuModel::A30.profile(0);
        let vm = spec(1, k);
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile: k, start: 3 });
        let by_model = dc.active_gpus_by_model();
        assert_eq!(by_model[GpuModel::A30 as usize], (1, 1));
        assert_eq!(by_model[GpuModel::A100_40 as usize], (1, 1));
        assert_eq!(by_model[GpuModel::H100_80 as usize], (0, 1));
        dc.check_integrity().unwrap();
    }

    #[test]
    fn activity_counters_match_scan_readers() {
        let mut dc = small_dc();
        assert_eq!(dc.active_hardware(), dc.active_hardware_scan());
        assert_eq!(dc.active_gpus_by_model(), dc.active_gpus_by_model_scan());
        assert_eq!(dc.active_host_count(), 0);
        let vm = spec(1, Profile::P2g10gb);
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P2g10gb, start: 0 });
        assert_eq!(dc.active_hardware(), dc.active_hardware_scan());
        assert_eq!(dc.active_gpus_by_model(), dc.active_gpus_by_model_scan());
        assert_eq!(dc.active_host_count(), 1);
        // A second VM on the same host crosses no boundary.
        let vm2 = spec(2, Profile::P2g10gb);
        dc.place(&vm2, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P2g10gb, start: 0 });
        assert_eq!(dc.active_host_count(), 1);
        assert_eq!(dc.active_hardware(), (3, 5));
        // Cross-host migration flips both hosts.
        dc.migrate(1, GpuRef { host: 1, gpu: 0 }, Placement { profile: Profile::P2g10gb, start: 0 });
        assert_eq!(dc.active_host_count(), 2);
        assert_eq!(dc.active_hardware(), dc.active_hardware_scan());
        dc.remove(1);
        dc.remove(2);
        assert_eq!(dc.active_hardware(), (0, 5));
        assert_eq!(dc.active_hardware(), dc.active_hardware_scan());
        dc.check_integrity().unwrap();
    }

    /// Satellite acceptance: after *every* mutation — place, remove,
    /// migrate, relocate, repack — on single-model or mixed fleets, the
    /// incremental activity counters equal a brute-force recount of the
    /// host/GPU states.
    #[test]
    fn prop_activity_counters_match_recount_after_every_mutation() {
        use crate::mig::gpu::feasible_starts;
        use crate::mig::placement::mock_assign;
        use crate::mig::{GpuModel, ALL_MODELS};
        use crate::migrate::defrag::repack_plan;
        use crate::util::prop::forall;
        use crate::util::rng::Rng;

        fn recount_ok(dc: &DataCenter) -> Result<(), String> {
            if dc.active_hardware() != dc.active_hardware_scan() {
                return Err(format!(
                    "active_hardware {:?} != recount {:?}",
                    dc.active_hardware(),
                    dc.active_hardware_scan()
                ));
            }
            if dc.active_gpus_by_model() != dc.active_gpus_by_model_scan() {
                return Err("active_gpus_by_model diverged from recount".into());
            }
            let resident: usize =
                dc.hosts().iter().flat_map(|h| h.gpus()).map(|g| g.instances().len()).sum();
            if dc.resident_count() != resident {
                return Err(format!("resident_count {} != {resident}", dc.resident_count()));
            }
            Ok(())
        }

        forall(
            "activity-counters-vs-recount",
            |r: &mut Rng| {
                let hosts: Vec<Host> = (0..2 + r.below(4))
                    .map(|i| {
                        let models: Vec<GpuModel> = (0..1 + r.below(3))
                            .map(|_| ALL_MODELS[r.below(ALL_MODELS.len() as u64) as usize])
                            .collect();
                        Host::with_models(i as u32, 32, 128, &models)
                    })
                    .collect();
                let mut dc = DataCenter::new(hosts);
                let refs = dc.gpu_refs();
                let mut next_vm: u64 = 1;
                let mut resident: Vec<u64> = Vec::new();
                let mut trace: Vec<&'static str> = Vec::new();
                for _ in 0..40 {
                    match r.below(5) {
                        0 | 1 => {
                            let gr = refs[r.below(refs.len() as u64) as usize];
                            let model = dc.gpu(gr).model();
                            let profile =
                                model.profile(r.below(model.num_profiles() as u64) as usize);
                            let vm = spec(next_vm, profile);
                            if dc.host(gr.host).fits_resources(vm.cpus, vm.ram_gb) {
                                if let Some((pl, _)) =
                                    mock_assign(dc.gpu(gr).occupancy(), profile)
                                {
                                    dc.place(&vm, gr, pl);
                                    resident.push(next_vm);
                                    next_vm += 1;
                                    trace.push("place");
                                }
                            }
                        }
                        2 => {
                            if let Some(i) =
                                (!resident.is_empty()).then(|| r.below(resident.len() as u64))
                            {
                                dc.remove(resident.swap_remove(i as usize));
                                trace.push("remove");
                            }
                        }
                        3 => {
                            if resident.is_empty() {
                                continue;
                            }
                            let vm = resident[r.below(resident.len() as u64) as usize];
                            let loc = dc.locate(vm).unwrap();
                            if r.chance(0.5) {
                                let occ = dc.gpu(loc.gpu).occupancy() & !loc.placement.mask();
                                let starts: Vec<u8> =
                                    feasible_starts(loc.placement.profile, occ).collect();
                                let s = starts[r.below(starts.len() as u64) as usize];
                                dc.relocate_within_gpu(
                                    vm,
                                    Placement { profile: loc.placement.profile, start: s },
                                );
                                trace.push("relocate");
                            } else {
                                let dst = refs[r.below(refs.len() as u64) as usize];
                                if dst == loc.gpu
                                    || dc.gpu(dst).model() != loc.placement.profile.model()
                                {
                                    continue;
                                }
                                let (cpus, ram) = dc.vm_demands(vm).unwrap();
                                if dst.host != loc.gpu.host
                                    && !dc.host(dst.host).fits_resources(cpus, ram)
                                {
                                    continue;
                                }
                                if let Some((pl, _)) =
                                    mock_assign(dc.gpu(dst).occupancy(), loc.placement.profile)
                                {
                                    dc.migrate(vm, dst, pl);
                                    trace.push("migrate");
                                }
                            }
                        }
                        _ => {
                            // Re-pack a random occupied GPU (the defrag path).
                            let gr = refs[r.below(refs.len() as u64) as usize];
                            if let Some(moves) = repack_plan(dc.gpu(gr)) {
                                if !moves.is_empty() {
                                    dc.repack_gpu(gr, &moves);
                                    trace.push("repack");
                                }
                            }
                        }
                    }
                    // The satellite's "after every mutation": recount now,
                    // not just at the end of the walk.
                    if let Err(e) = recount_ok(&dc) {
                        panic!("counters diverged after {:?}: {e}", trace);
                    }
                }
                dc
            },
            |dc| {
                recount_ok(dc)?;
                dc.check_integrity().map_err(|e| format!("integrity: {e}"))
            },
        );
    }

    #[test]
    fn integrity_detects_corruption() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P1g5gb);
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P1g5gb, start: 6 });
        // Corrupt: remove from GPU behind the index's back.
        dc.host_mut(0).gpu_mut(0).remove_vm(1);
        assert!(dc.check_integrity().is_err());
    }

    #[test]
    fn gpu_failure_leaves_and_reenters_the_index() {
        use crate::cluster::HealthState;
        let mut dc = small_dc();
        let r = GpuRef { host: 0, gpu: 0 };
        dc.set_gpu_health(r, HealthState::Failed { until: 100 });
        assert!(!dc.gpu_available(r));
        assert_eq!(dc.offline_gpus(), 1);
        assert!(!dc.index().gpus_fitting(Profile::P1g5gb).contains(r));
        dc.check_integrity().unwrap();
        // Occupancy changes while offline leave the index untouched; the
        // re-attach picks up the live occupancy.
        let vm = spec(1, Profile::P7g40gb);
        dc.place(&vm, r, Placement { profile: Profile::P7g40gb, start: 0 });
        dc.check_integrity().unwrap();
        dc.remove(1);
        dc.set_gpu_health(r, HealthState::Healthy);
        assert_eq!(dc.offline_gpus(), 0);
        assert!(dc.index().gpus_fitting(Profile::P1g5gb).contains(r));
        dc.check_integrity().unwrap();
    }

    #[test]
    fn draining_host_keeps_residents_but_leaves_the_index() {
        use crate::cluster::HealthState;
        let mut dc = small_dc();
        let vm = spec(1, Profile::P2g10gb);
        let r = GpuRef { host: 0, gpu: 0 };
        dc.place(&vm, r, Placement { profile: Profile::P2g10gb, start: 0 });
        dc.set_host_health(0, HealthState::Draining);
        assert!(!dc.host_available(0));
        assert_eq!(dc.offline_gpus(), 2); // both GPUs of host 0
        assert!(!dc.index().gpus_fitting(Profile::P1g5gb).contains(r));
        assert_eq!(dc.index().num_hosts(), 1);
        assert_eq!(dc.vms_on_host(0), vec![1]);
        dc.check_integrity().unwrap();
        // Departures on a drained host keep every structure coherent.
        dc.remove(1);
        dc.check_integrity().unwrap();
        dc.set_host_health(0, HealthState::Healthy);
        assert_eq!(dc.offline_gpus(), 0);
        assert_eq!(dc.index().num_hosts(), 2);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn failed_gpu_holding_a_vm_fails_integrity() {
        use crate::cluster::HealthState;
        let mut dc = small_dc();
        let vm = spec(1, Profile::P1g5gb);
        let r = GpuRef { host: 0, gpu: 0 };
        dc.place(&vm, r, Placement { profile: Profile::P1g5gb, start: 6 });
        dc.set_gpu_health(r, HealthState::Banned);
        assert!(dc.check_integrity().is_err(), "banned GPU still holds a VM");
        dc.remove(1);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn snapshot_restore_round_trips_mixed_fleet_with_health() {
        use crate::cluster::HealthState;
        use crate::mig::GpuModel;
        use crate::util::codec::{Dec, Enc};
        let mut dc = DataCenter::new(vec![
            Host::with_models(0, 64, 256, &[GpuModel::A100_40, GpuModel::A30]),
            Host::with_models(1, 32, 128, &[GpuModel::H100_80]),
            Host::with_models(2, 64, 256, &[GpuModel::A100_40]),
        ]);
        dc.host_mut(2).weight = 2.5;
        let vm1 = spec(1, Profile::P2g10gb);
        dc.place(&vm1, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P2g10gb, start: 0 });
        let k = GpuModel::A30.profile(0);
        let vm2 = VmSpec { id: 2, profile: k, cpus: 2, ram_gb: 8, arrival: 0, departure: 50, weight: 1.0 };
        dc.place(&vm2, GpuRef { host: 0, gpu: 1 }, Placement { profile: k, start: 0 });
        // Degrade some capacity: a failed empty GPU and a draining host
        // that keeps its resident.
        dc.set_gpu_health(GpuRef { host: 2, gpu: 0 }, HealthState::Failed { until: 999 });
        dc.set_host_health(0, HealthState::Draining);
        dc.check_integrity().unwrap();

        let mut e = Enc::new();
        dc.snapshot_into(&mut e);
        let bytes = e.into_bytes();
        let got = DataCenter::restore_from(&mut Dec::new(&bytes)).unwrap();
        assert_eq!(got.hosts().len(), 3);
        assert_eq!(got.host(2).weight, 2.5);
        assert_eq!(got.locate(1), dc.locate(1));
        assert_eq!(got.locate(2), dc.locate(2));
        assert_eq!(got.vm_demands(1), Some((4, 16)));
        assert_eq!(got.vm_demands(2), Some((2, 8)));
        assert_eq!(got.host_health(0), HealthState::Draining);
        assert_eq!(got.gpu_health(GpuRef { host: 2, gpu: 0 }), HealthState::Failed { until: 999 });
        assert_eq!(got.offline_gpus(), dc.offline_gpus());
        assert_eq!(got.active_hardware(), dc.active_hardware());
        assert_eq!(got.gpus_by_model(), dc.gpus_by_model());
        assert_eq!(got.host(0).free_cpus(), dc.host(0).free_cpus());
        assert_eq!(got.host(0).free_ram(), dc.host(0).free_ram());
        got.check_integrity().unwrap();
        // A truncated snapshot is an error, not a panic.
        assert!(DataCenter::restore_from(&mut Dec::new(&bytes[..bytes.len() / 2])).is_err());
    }

    #[test]
    fn try_check_integrity_attributes_the_offending_host() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P1g5gb);
        dc.place(&vm, GpuRef { host: 1, gpu: 0 }, Placement { profile: Profile::P1g5gb, start: 6 });
        // Corrupt host 1's GPU behind the index's back.
        dc.host_mut(1).gpu_mut(0).remove_vm(1);
        let report = dc.try_check_integrity().unwrap_err();
        assert_eq!(report.host, Some(1));
        assert!(!report.detail.is_empty());
    }

    #[test]
    fn rebuild_derived_repairs_corrupted_indices() {
        let mut dc = small_dc();
        let vm = spec(1, Profile::P1g5gb);
        let vm2 = spec(2, Profile::P2g10gb);
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P1g5gb, start: 6 });
        dc.place(&vm2, GpuRef { host: 1, gpu: 0 }, Placement { profile: Profile::P2g10gb, start: 0 });
        // Corrupt: drop VM 1 from its GPU behind the index's back — the
        // location map, cluster index and activity counters all go stale.
        dc.host_mut(0).gpu_mut(0).remove_vm(1);
        assert!(dc.try_check_integrity().is_err());
        dc.rebuild_derived();
        // Ground truth wins: VM 1 is gone, VM 2 intact, indices rebuilt.
        // (Host 0's CPU/RAM reservation leak is ground-truth state, not
        // derived — rebuild does not unreserve it, matching the
        // documented limits.)
        assert!(dc.locate(1).is_none());
        assert!(dc.vm_demands(1).is_none());
        assert_eq!(dc.locate(2).unwrap().gpu, GpuRef { host: 1, gpu: 0 });
        assert_eq!(dc.resident_count(), 1);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn migrate_off_a_draining_host_restores_health_when_done() {
        use crate::cluster::HealthState;
        let mut dc = small_dc();
        let vm = spec(1, Profile::P3g20gb);
        let src = GpuRef { host: 0, gpu: 0 };
        let dst = GpuRef { host: 1, gpu: 0 };
        dc.place(&vm, src, Placement { profile: Profile::P3g20gb, start: 0 });
        dc.set_host_health(0, HealthState::Draining);
        dc.check_integrity().unwrap();
        dc.migrate(1, dst, Placement { profile: Profile::P3g20gb, start: 0 });
        assert_eq!(dc.locate(1).unwrap().gpu, dst);
        assert!(dc.vms_on_host(0).is_empty());
        dc.check_integrity().unwrap();
        dc.set_host_health(0, HealthState::Healthy);
        dc.check_integrity().unwrap();
    }
}
