//! Synthetic Alibaba-2023-like workload generation.
//!
//! The paper's workload: 1,213 GPU-equipped hosts (1–8 GPUs each) and
//! 8,063 MIG-enabled VMs with a 7g.40gb-heavy profile mix (Fig. 5),
//! arrival-time outliers removed by the IQR rule. We synthesize raw pod
//! records whose *fractional GPU requirements* land on the paper's
//! profile mix after the Eq. 27–30 mapping, with:
//!
//! * diurnal Poisson arrivals over a configurable horizon, plus a small
//!   share of extreme arrival outliers for the IQR stage to remove
//!   (mimicking trace artifacts);
//! * heavy-tailed (lognormal) service times — GPU workloads in the
//!   Alibaba trace are long-lived, which is what makes the placement
//!   problem capacity-constrained;
//! * host shapes biased to 2- and 8-GPU nodes like the trace.
//!
//! Everything is keyed by a single seed: the five policies compared in §8
//! replay byte-identical workloads.

use super::mapping::{
    map_pods_to_profiles_fleet, normalized_profile_values, MappingReport, PodRecord,
};
use crate::cluster::host::Host;
use crate::cluster::vm::{Time, VmSpec, HOUR};
use crate::mig::{GpuModel, NUM_PROFILE_KEYS};
use crate::ops::{generate_schedule, OpsConfig, OpsEvent};
use crate::util::rng::Rng;

/// Shape of the arrival process. All three share the same
/// rejection-sampling loop (identical RNG draws per iteration — two
/// `f64`s); only the deterministic intensity function of the candidate
/// time differs, so [`ArrivalProcess::Diurnal`] reproduces the
/// historical stream byte-for-byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ArrivalProcess {
    /// Sinusoidal day/night cycle (the historical default).
    #[default]
    Diurnal,
    /// Short high-intensity bursts every 8 hours over a low baseline.
    Bursty,
    /// A single flash crowd in the middle decile of the horizon.
    FlashCrowd,
}

impl ArrivalProcess {
    /// Parse a CLI name (`diurnal` | `bursty` | `flash-crowd`).
    pub fn parse(s: &str) -> Option<ArrivalProcess> {
        match s {
            "diurnal" => Some(ArrivalProcess::Diurnal),
            "bursty" => Some(ArrivalProcess::Bursty),
            "flash-crowd" | "flash" => Some(ArrivalProcess::FlashCrowd),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Diurnal => "diurnal",
            ArrivalProcess::Bursty => "bursty",
            ArrivalProcess::FlashCrowd => "flash-crowd",
        }
    }

    /// Acceptance probability of a candidate arrival at `t` (in `(0, 1]`
    /// everywhere, so the rejection loop always terminates).
    fn intensity(&self, t: Time, horizon_secs: Time) -> f64 {
        match self {
            ArrivalProcess::Diurnal => {
                let hour_of_day = (t / HOUR) % 24;
                0.75 + 0.25 * (2.0 * std::f64::consts::PI * hour_of_day as f64 / 24.0).sin()
            }
            ArrivalProcess::Bursty => {
                if (t / HOUR) % 8 < 2 {
                    1.0
                } else {
                    0.25
                }
            }
            ArrivalProcess::FlashCrowd => {
                let frac = t as f64 / horizon_secs.max(1) as f64;
                if (0.45..0.55).contains(&frac) {
                    1.0
                } else {
                    0.3
                }
            }
        }
    }
}

/// Configuration of the synthetic trace.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub seed: u64,
    /// Number of GPU-equipped hosts (paper: 1,213).
    pub num_hosts: usize,
    /// Raw pod count before cleaning (paper ends at 8,063 VMs).
    pub num_pods: usize,
    /// Horizon in hours (arrivals span this window).
    pub horizon_hours: u64,
    /// Target per-profile mix (Fig. 5), in `ALL_PROFILES` order.
    pub profile_mix: [f64; 6],
    /// Lognormal duration parameters (of hours).
    pub duration_mu: f64,
    pub duration_sigma: f64,
    /// Fraction of pods given extreme arrival times (IQR fodder).
    pub outlier_frac: f64,
    /// Fraction of pods requesting more than one full GPU (dropped by the
    /// pipeline, <1% in the paper).
    pub multi_gpu_frac: f64,
    /// Host GPU-count weights for 1..=8 GPUs per host.
    pub host_gpu_weights: [f64; 8],
    /// Fleet mix: `(model, weight)` pairs. Every GPU's model is drawn
    /// from this distribution and every pod's requirement maps onto its
    /// assigned model's ladder. A single-entry mix (the default,
    /// A100-40-only) consumes no randomness, keeping the historical
    /// byte-identical streams.
    pub gpu_models: Vec<(GpuModel, f64)>,
    /// Shape of the arrival intensity. [`ArrivalProcess::Diurnal`] (the
    /// default) reproduces the historical stream exactly.
    pub arrival_process: ArrivalProcess,
    /// Fraction of VMs promoted to the high-priority tier (weight 2.0,
    /// eligible to preempt under `--preempt`). The promotion pass draws
    /// from its own RNG stream and is skipped entirely at 0.0, so
    /// default configs stay byte-identical.
    pub priority_frac: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 42,
            num_hosts: 1_213,
            num_pods: 8_230,
            horizon_hours: 30 * 24,
            // Fig. 5: 7g.40gb dominates; 2g.10gb and 3g.20gb follow.
            profile_mix: [0.07, 0.05, 0.22, 0.17, 0.11, 0.38],
            // Long-lived services: median ≈ e^7.5 ≈ 1808 h, heavy tail.
            duration_mu: 7.5,
            duration_sigma: 1.3,
            outlier_frac: 0.01,
            multi_gpu_frac: 0.008,
            // mostly single-GPU nodes: ~1,450 GPUs total, the scarcity regime
            // that produces the paper's ~30-40% acceptance rates.
            host_gpu_weights: [0.90, 0.07, 0.01, 0.01, 0.005, 0.002, 0.002, 0.001],
            gpu_models: vec![(GpuModel::A100_40, 1.0)],
            arrival_process: ArrivalProcess::Diurnal,
            priority_frac: 0.0,
        }
    }
}

impl TraceConfig {
    /// A scaled-down config for tests and quick sweeps.
    pub fn small(seed: u64) -> TraceConfig {
        TraceConfig {
            seed,
            num_hosts: 40,
            num_pods: 400,
            horizon_hours: 7 * 24,
            ..TraceConfig::default()
        }
    }
}

/// A generated workload: the cluster plus the cleaned VM stream.
#[derive(Debug, Clone)]
pub struct Workload {
    pub hosts: Vec<Host>,
    pub vms: Vec<VmSpec>,
    pub report: MappingReport,
    pub config: TraceConfig,
}

impl Workload {
    /// Generate a workload from a config (deterministic per seed).
    pub fn generate(config: TraceConfig) -> Workload {
        let mut rng = Rng::new(config.seed);
        let hosts = generate_hosts(&config, &mut rng.split());
        let pods = generate_pods(&config, &mut rng.split());
        let (mut vms, report) =
            map_pods_to_profiles_fleet(&pods, &config.gpu_models, &mut rng.split());
        if config.priority_frac > 0.0 {
            // Gated split: zero-frac configs draw nothing and keep the
            // historical byte-identical streams.
            let mut prng = rng.split();
            for vm in &mut vms {
                if prng.chance(config.priority_frac) {
                    vm.weight = 2.0;
                }
            }
        }
        Workload { hosts, vms, report, config }
    }

    /// A fault/drain schedule for this workload's fleet. When the ops
    /// config leaves `horizon_hours` at 0 it inherits the trace horizon
    /// (plus slack so repairs land inside the run).
    pub fn fault_schedule(&self, ops: &OpsConfig) -> Vec<(Time, OpsEvent)> {
        let mut ops = ops.clone();
        if ops.horizon_hours == 0 {
            ops.horizon_hours = self.config.horizon_hours + 24;
        }
        generate_schedule(&ops, &self.hosts)
    }

    /// Total GPUs across hosts.
    pub fn num_gpus(&self) -> usize {
        self.hosts.iter().map(|h| h.gpus().len()).sum()
    }

    /// Per-model GPU counts of the generated fleet.
    pub fn gpus_by_model(&self) -> [usize; crate::mig::NUM_MODELS] {
        crate::cluster::host::gpus_by_model(&self.hosts)
    }

    /// Fig. 5 data: per-profile share of the cleaned workload, by dense
    /// key (the first six slots are the A100-40 distribution).
    pub fn profile_distribution(&self) -> [f64; NUM_PROFILE_KEYS] {
        let total: usize = self.report.profile_counts.iter().sum();
        let mut out = [0.0; NUM_PROFILE_KEYS];
        if total > 0 {
            for i in 0..NUM_PROFILE_KEYS {
                out[i] = self.report.profile_counts[i] as f64 / total as f64;
            }
        }
        out
    }
}

fn generate_hosts(config: &TraceConfig, rng: &mut Rng) -> Vec<Host> {
    let model_weights: Vec<f64> = config.gpu_models.iter().map(|(_, w)| *w).collect();
    (0..config.num_hosts)
        .map(|i| {
            let gpus = rng.weighted_index(&config.host_gpu_weights) + 1;
            // CPU/RAM scale with GPU count (DGX-like shapes) and are
            // generous enough that GPU blocks are the binding resource,
            // matching the paper's focus.
            let cpus = 32 * gpus as u32 + 16;
            let ram = 128 * gpus as u32 + 64;
            if config.gpu_models.len() == 1 {
                // Single-model fleets draw nothing extra: the historical
                // RNG stream (and thus the whole workload) is preserved.
                Host::with_models(i as u32, cpus, ram, &vec![config.gpu_models[0].0; gpus])
            } else {
                let models: Vec<GpuModel> = (0..gpus)
                    .map(|_| config.gpu_models[rng.weighted_index(&model_weights)].0)
                    .collect();
                Host::with_models(i as u32, cpus, ram, &models)
            }
        })
        .collect()
}

fn generate_pods(config: &TraceConfig, rng: &mut Rng) -> Vec<PodRecord> {
    let values = normalized_profile_values();
    let horizon_secs = config.horizon_hours * HOUR;
    let mut pods = Vec::with_capacity(config.num_pods);
    for _ in 0..config.num_pods {
        // Arrival: rejection-sample against the configured intensity
        // curve. Each iteration draws exactly two f64s regardless of the
        // process, so Diurnal reproduces the historical stream.
        let arrival = if rng.chance(config.outlier_frac) {
            // Outlier: far beyond the horizon (trace artifact).
            horizon_secs + rng.range_inclusive(100, 1_000) * HOUR
        } else {
            loop {
                let t = (rng.f64() * horizon_secs as f64) as Time;
                let intensity = config.arrival_process.intensity(t, horizon_secs);
                if rng.f64() < intensity {
                    break t;
                }
            }
        };

        // Duration: lognormal hours, clamped to [0.25 h, 4× horizon].
        let hours = rng
            .lognormal(config.duration_mu, config.duration_sigma)
            .clamp(0.25, 4.0 * config.horizon_hours as f64);
        let duration = (hours * HOUR as f64) as Time;

        // GPU requirement: pick the *intended* profile from the target
        // mix, then synthesize a fractional requirement that Eq. 27–30
        // maps back to it (uniform in the profile's nearest-value cell).
        let (num_gpus, gpu_frac) = if rng.chance(config.multi_gpu_frac) {
            (1.0 + rng.range_inclusive(1, 7) as f64, 1.0)
        } else {
            let k = rng.weighted_index(&config.profile_mix);
            let lo = if k == 0 { 0.0 } else { (values[k - 1] + values[k]) / 2.0 };
            let hi = if k == 5 { 1.0 } else { (values[k] + values[k + 1]) / 2.0 };
            // Sample strictly inside the cell to avoid boundary ties.
            let width = hi - lo;
            let u = lo + width * (0.05 + 0.9 * rng.f64());
            // Express as (gpus, frac): whole-GPU requests use frac 1.0.
            if u >= 0.999 {
                (1.0, 1.0)
            } else {
                (1.0, u)
            }
        };

        // CPU/RAM roughly proportional to the GPU slice.
        let slice = (num_gpus * gpu_frac).min(1.0);
        let cpus = (2.0 + 14.0 * slice + rng.f64() * 4.0) as u32;
        let ram_gb = (8.0 + 56.0 * slice + rng.f64() * 16.0) as u32;

        pods.push(PodRecord { arrival, duration, num_gpus, gpu_frac, cpus, ram_gb });
    }
    pods
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;

    #[test]
    fn deterministic_per_seed() {
        let a = Workload::generate(TraceConfig::small(7));
        let b = Workload::generate(TraceConfig::small(7));
        assert_eq!(a.vms, b.vms);
        assert_eq!(a.hosts.len(), b.hosts.len());
    }

    #[test]
    fn different_seeds_differ() {
        let a = Workload::generate(TraceConfig::small(1));
        let b = Workload::generate(TraceConfig::small(2));
        assert_ne!(a.vms, b.vms);
    }

    #[test]
    fn profile_mix_close_to_target() {
        let config = TraceConfig { num_pods: 8_000, ..TraceConfig::default() };
        let target = config.profile_mix;
        let w = Workload::generate(config);
        let dist = w.profile_distribution();
        for i in 0..6 {
            assert!(
                (dist[i] - target[i]).abs() < 0.03,
                "profile {} share {:.3} vs target {:.3}",
                Profile::from_index(i),
                dist[i],
                target[i]
            );
        }
    }

    #[test]
    fn paper_scale_defaults() {
        let c = TraceConfig::default();
        assert_eq!(c.num_hosts, 1_213);
        // Raw pod count exceeds 8,063 so cleaning lands near the paper's VM count.
        assert!(c.num_pods > 8_063);
    }

    #[test]
    fn outliers_are_removed_by_pipeline() {
        let w = Workload::generate(TraceConfig::small(3));
        assert!(w.report.outliers_removed > 0, "IQR stage should have work to do");
        assert!(w.report.multi_gpu_removed > 0);
        let horizon = w.config.horizon_hours * HOUR;
        assert!(w.vms.iter().all(|v| v.arrival <= horizon + 200 * HOUR));
    }

    #[test]
    fn vms_sorted_with_sane_lifetimes() {
        let w = Workload::generate(TraceConfig::small(5));
        assert!(w.vms.windows(2).all(|p| p[0].arrival <= p[1].arrival));
        assert!(w.vms.iter().all(|v| v.departure > v.arrival));
        assert!(w.vms.iter().all(|v| v.cpus >= 2 && v.ram_gb >= 8));
    }

    #[test]
    fn mixed_fleet_generation_is_deterministic_and_segregated() {
        let config = TraceConfig {
            gpu_models: vec![
                (GpuModel::A30, 0.3),
                (GpuModel::A100_40, 0.4),
                (GpuModel::H100_80, 0.3),
            ],
            ..TraceConfig::small(17)
        };
        let a = Workload::generate(config.clone());
        let b = Workload::generate(config);
        assert_eq!(a.vms, b.vms);
        let by_model = a.gpus_by_model();
        assert!(by_model[GpuModel::A30 as usize] > 0);
        assert!(by_model[GpuModel::A100_40 as usize] > 0);
        assert!(by_model[GpuModel::H100_80 as usize] > 0);
        assert_eq!(by_model[GpuModel::A100_80 as usize], 0);
        // Every VM's profile belongs to a fleet model.
        for vm in &a.vms {
            assert_ne!(vm.profile.model(), GpuModel::A100_80);
        }
        // All three models receive requests.
        let dist = a.profile_distribution();
        for m in [GpuModel::A30, GpuModel::A100_40, GpuModel::H100_80] {
            let share: f64 = m.profile_keys().map(|k| dist[k.dense()]).sum();
            assert!(share > 0.1, "{m} share {share}");
        }
    }

    #[test]
    fn single_model_fleet_unchanged_by_catalog_plumbing() {
        // The default config must generate the exact same hosts and VM
        // stream the pre-catalog generator produced: model sampling and
        // fleet mapping consume no randomness for single-model fleets.
        let w = Workload::generate(TraceConfig::small(42));
        assert!(w.hosts.iter().all(|h| h
            .gpus()
            .iter()
            .all(|g| g.model() == GpuModel::A100_40)));
        assert!(w.vms.iter().all(|v| v.profile.model() == GpuModel::A100_40));
    }

    #[test]
    fn arrival_process_parse_round_trips() {
        for p in [ArrivalProcess::Diurnal, ArrivalProcess::Bursty, ArrivalProcess::FlashCrowd] {
            assert_eq!(ArrivalProcess::parse(p.name()), Some(p));
        }
        assert_eq!(ArrivalProcess::parse("flash"), Some(ArrivalProcess::FlashCrowd));
        assert_eq!(ArrivalProcess::parse("poisson"), None);
    }

    #[test]
    fn alternate_arrival_processes_are_deterministic_and_distinct() {
        let bursty = TraceConfig {
            arrival_process: ArrivalProcess::Bursty,
            ..TraceConfig::small(7)
        };
        let flash = TraceConfig {
            arrival_process: ArrivalProcess::FlashCrowd,
            ..TraceConfig::small(7)
        };
        let b1 = Workload::generate(bursty.clone());
        let b2 = Workload::generate(bursty);
        assert_eq!(b1.vms, b2.vms);
        let f = Workload::generate(flash);
        let d = Workload::generate(TraceConfig::small(7));
        assert_ne!(b1.vms, d.vms);
        assert_ne!(f.vms, d.vms);

        // The flash crowd concentrates arrivals in the middle decile far
        // beyond its 10% share of the horizon.
        let horizon = f.config.horizon_hours * HOUR;
        let in_window = f
            .vms
            .iter()
            .filter(|v| {
                let frac = v.arrival as f64 / horizon as f64;
                (0.45..0.55).contains(&frac)
            })
            .count();
        assert!(
            in_window as f64 > 0.15 * f.vms.len() as f64,
            "flash window holds {in_window}/{}",
            f.vms.len()
        );
    }

    #[test]
    fn priority_frac_promotes_without_disturbing_the_stream() {
        let base = Workload::generate(TraceConfig::small(11));
        assert!(base.vms.iter().all(|v| v.weight == 1.0));

        let pri =
            Workload::generate(TraceConfig { priority_frac: 0.3, ..TraceConfig::small(11) });
        let high = pri.vms.iter().filter(|v| v.weight == 2.0).count();
        assert!(high > 0 && high < pri.vms.len(), "promoted {high}/{}", pri.vms.len());
        // The promotion pass only touches weights: every other field of
        // the VM stream is byte-identical to the zero-frac run.
        assert_eq!(base.vms.len(), pri.vms.len());
        for (a, b) in base.vms.iter().zip(&pri.vms) {
            assert_eq!((a.arrival, a.departure, a.profile, a.cpus, a.ram_gb),
                       (b.arrival, b.departure, b.profile, b.cpus, b.ram_gb));
        }
    }

    #[test]
    fn fault_schedule_inherits_the_trace_horizon() {
        let w = Workload::generate(TraceConfig::small(13));
        let ops = OpsConfig { drain_rate: 0.02, ..OpsConfig::default().with_gpu_mtbf(500.0) };
        let a = w.fault_schedule(&ops);
        let b = w.fault_schedule(&ops);
        assert_eq!(a, b, "schedule is deterministic");
        assert!(!a.is_empty(), "a 500 h MTBF over a week-long trace must fire");
        let bound = (w.config.horizon_hours + 24) * HOUR;
        assert!(a.iter().all(|(t, _)| *t <= bound));
    }

    #[test]
    fn host_shapes_in_range() {
        let w = Workload::generate(TraceConfig::small(9));
        for h in &w.hosts {
            let n = h.gpus().len();
            assert!((1..=8).contains(&n));
            assert!(h.cpus >= 48);
        }
        assert!(w.num_gpus() >= w.hosts.len());
    }
}
