//! CSV trace ingestion — runs the *same* cleaning pipeline as the
//! generator, so a real Alibaba-derived CSV can replace synthesis without
//! touching the rest of the stack.
//!
//! Expected header (column order free, extra columns ignored):
//! `arrival,duration,num_gpus,gpu_frac,cpus,ram_gb` — times in seconds.

use super::mapping::{map_pods_to_profiles, MappingReport, PodRecord};
use crate::cluster::vm::VmSpec;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Parse a trace CSV into raw pod records.
pub fn parse_pods_csv(text: &str) -> Result<Vec<PodRecord>> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().ok_or_else(|| anyhow!("empty CSV"))?;
    let cols: Vec<&str> = header.split(',').map(|c| c.trim()).collect();
    let col = |name: &str| -> Result<usize> {
        cols.iter().position(|&c| c == name).ok_or_else(|| anyhow!("missing column '{name}'"))
    };
    let (i_arr, i_dur, i_num, i_frac, i_cpu, i_ram) = (
        col("arrival")?,
        col("duration")?,
        col("num_gpus")?,
        col("gpu_frac")?,
        col("cpus")?,
        col("ram_gb")?,
    );
    let mut pods = Vec::new();
    for (lineno, line) in lines.enumerate() {
        let fields: Vec<&str> = line.split(',').map(|f| f.trim()).collect();
        let get = |i: usize| -> Result<&str> {
            fields.get(i).copied().ok_or_else(|| anyhow!("line {}: too few fields", lineno + 2))
        };
        let parse_f = |s: &str| -> Result<f64> {
            s.parse().with_context(|| format!("line {}: bad number '{s}'", lineno + 2))
        };
        pods.push(PodRecord {
            arrival: parse_f(get(i_arr)?)? as u64,
            duration: parse_f(get(i_dur)?)? as u64,
            num_gpus: parse_f(get(i_num)?)?,
            gpu_frac: parse_f(get(i_frac)?)?,
            cpus: parse_f(get(i_cpu)?)? as u32,
            ram_gb: parse_f(get(i_ram)?)? as u32,
        });
    }
    Ok(pods)
}

/// Load a CSV file and run the §8.1 pipeline.
pub fn load_trace(path: &Path) -> Result<(Vec<VmSpec>, MappingReport)> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    let pods = parse_pods_csv(&text)?;
    Ok(map_pods_to_profiles(&pods))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::Profile;

    const SAMPLE: &str = "\
arrival,duration,num_gpus,gpu_frac,cpus,ram_gb
0,3600,1,1.0,8,32
60,7200,1,0.5,4,16
120,1800,1,0.02,2,8
180,3600,2,1.0,16,64
";

    #[test]
    fn parses_and_maps() {
        let pods = parse_pods_csv(SAMPLE).unwrap();
        assert_eq!(pods.len(), 4);
        let (vms, report) = map_pods_to_profiles(&pods);
        // The 2-GPU pod is dropped.
        assert_eq!(report.multi_gpu_removed, 1);
        assert_eq!(vms.len(), 3);
        assert_eq!(vms[0].profile, Profile::P7g40gb);
        // 0.02 ≈ 1/56 → 1g.5gb.
        assert_eq!(vms[2].profile, Profile::P1g5gb);
    }

    #[test]
    fn header_order_free() {
        let reordered = "\
cpus,ram_gb,arrival,duration,gpu_frac,num_gpus
8,32,0,3600,1.0,1
";
        let pods = parse_pods_csv(reordered).unwrap();
        assert_eq!(pods[0].cpus, 8);
        assert_eq!(pods[0].gpu_frac, 1.0);
    }

    #[test]
    fn missing_column_rejected() {
        assert!(parse_pods_csv("arrival,duration\n1,2\n").is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let bad = "arrival,duration,num_gpus,gpu_frac,cpus,ram_gb\nx,1,1,1,1,1\n";
        assert!(parse_pods_csv(bad).is_err());
    }

    #[test]
    fn roundtrip_via_tempfile() {
        let dir = std::env::temp_dir().join("grmu_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pods.csv");
        std::fs::write(&path, SAMPLE).unwrap();
        let (vms, _) = load_trace(&path).unwrap();
        assert_eq!(vms.len(), 3);
    }
}
