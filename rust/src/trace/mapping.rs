//! Pod→MIG-profile mapping (Eq. 27–30) and the trace-cleaning pipeline.
//!
//! Heterogeneous fleets: [`map_pods_to_profiles_fleet`] additionally
//! assigns each retained pod a GPU model drawn from the fleet mix, then
//! maps its normalized requirement onto *that model's* profile ladder
//! (each model's Eq. 28–29 values are normalized within the model, so
//! `û ∈ [0, 1]` lands on every ladder). The single-model path — the
//! historical [`map_pods_to_profiles`] — consumes no randomness and is
//! byte-identical to the pre-catalog pipeline.

use crate::cluster::vm::{Time, VmSpec};
use crate::mig::model::{GpuModel, NUM_PROFILE_KEYS};
use crate::mig::profiles::{Profile, ALL_PROFILES};
use crate::util::rng::Rng;
use crate::util::stats::iqr_bounds;

/// A raw pod record before mapping (one row of the cleaned trace).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PodRecord {
    /// Arrival time in seconds.
    pub arrival: Time,
    /// Duration in seconds.
    pub duration: Time,
    /// Number of GPUs requested (may be fractional per GPU, e.g. 2 × 0.5).
    pub num_gpus: f64,
    /// Fraction of each GPU requested, in `(0, 1]`.
    pub gpu_frac: f64,
    /// CPU cores requested.
    pub cpus: u32,
    /// RAM in GB requested.
    pub ram_gb: u32,
}

impl PodRecord {
    /// Total GPU requirement `u`: GPUs × fraction each (§8.1).
    pub fn total_gpu_requirement(&self) -> f64 {
        self.num_gpus * self.gpu_frac
    }
}

/// Eq. 28–29 on the A100-40: normalized combined compute×memory value
/// per profile. `max(U_k)` is 7g.40gb's value, so Û_(7g.40gb) = 1.
pub fn normalized_profile_values() -> [f64; 6] {
    let max = Profile::P7g40gb.combined_value();
    let mut out = [0.0; 6];
    for p in ALL_PROFILES {
        out[p.index()] = p.combined_value() / max;
    }
    out
}

/// Eq. 28–29 for any model: normalized combined values of its profiles
/// in per-model index order ([`Profile::combined_value`] is already
/// normalized within the model, so the heavy profile maps to 1).
pub fn normalized_values_for(model: GpuModel) -> Vec<f64> {
    model.profile_keys().map(|k| k.combined_value()).collect()
}

/// Eq. 30 on `model`: the profile whose normalized value is closest to
/// `u_hat`. Ties resolve to the smaller profile (first in table order).
pub fn nearest_profile_for(model: GpuModel, u_hat: f64) -> Profile {
    let mut best = model.profile(0);
    let mut best_d = f64::INFINITY;
    for k in model.profile_keys() {
        let d = (k.combined_value() - u_hat).abs();
        if d < best_d {
            best_d = d;
            best = k;
        }
    }
    best
}

/// Eq. 30 on the A100-40 (the historical mapping).
pub fn nearest_profile(u_hat: f64) -> Profile {
    nearest_profile_for(GpuModel::A100_40, u_hat)
}

/// Outcome of the full §8.1 cleaning pipeline.
#[derive(Debug, Clone)]
pub struct MappingReport {
    /// Pods dropped by the IQR arrival filter.
    pub outliers_removed: usize,
    /// Pods dropped for requiring more than one full GPU.
    pub multi_gpu_removed: usize,
    /// Final per-profile counts by dense [`Profile::dense`] key (the
    /// first six slots are Fig. 5's A100-40 distribution).
    pub profile_counts: [usize; NUM_PROFILE_KEYS],
}

/// Run the paper's pipeline over raw pods against an A100-40-only fleet:
/// IQR-filter arrivals, drop pods needing more than one full GPU (<1% in
/// the paper), normalize the requirement by the post-filter maximum
/// (Eq. 27) and map each pod to the nearest profile (Eq. 30). Returns VM
/// specs sorted by arrival.
pub fn map_pods_to_profiles(pods: &[PodRecord]) -> (Vec<VmSpec>, MappingReport) {
    // The single-model path never touches the RNG; any seed works.
    map_pods_to_profiles_fleet(pods, &[(GpuModel::A100_40, 1.0)], &mut Rng::new(0))
}

/// [`map_pods_to_profiles`] over a heterogeneous fleet mix: each
/// retained pod is assigned a model drawn from `fleet` (weights need not
/// sum to 1), then mapped onto that model's ladder. With a single-entry
/// fleet the RNG is never consumed and the pipeline is byte-identical to
/// the historical single-model mapping.
pub fn map_pods_to_profiles_fleet(
    pods: &[PodRecord],
    fleet: &[(GpuModel, f64)],
    rng: &mut Rng,
) -> (Vec<VmSpec>, MappingReport) {
    assert!(!fleet.is_empty(), "fleet mix must name at least one model");
    // IQR filter on arrival times (§8.1).
    let arrivals: Vec<f64> = pods.iter().map(|p| p.arrival as f64).collect();
    let (lo, hi) = if arrivals.is_empty() { (0.0, 0.0) } else { iqr_bounds(&arrivals) };
    let kept: Vec<&PodRecord> =
        pods.iter().filter(|p| (p.arrival as f64) >= lo && (p.arrival as f64) <= hi).collect();
    let outliers_removed = pods.len() - kept.len();

    // Drop pods requiring more than one full GPU.
    let single: Vec<&PodRecord> =
        kept.iter().copied().filter(|p| p.total_gpu_requirement() <= 1.0).collect();
    let multi_gpu_removed = kept.len() - single.len();

    // Eq. 27: normalize by the maximum requirement across retained pods.
    let max_u = single.iter().map(|p| p.total_gpu_requirement()).fold(0.0f64, f64::max);

    let weights: Vec<f64> = fleet.iter().map(|(_, w)| *w).collect();
    let mut vms: Vec<VmSpec> = Vec::with_capacity(single.len());
    let mut profile_counts = [0usize; NUM_PROFILE_KEYS];
    for pod in &single {
        let u_hat = if max_u > 0.0 { pod.total_gpu_requirement() / max_u } else { 0.0 };
        let model =
            if fleet.len() == 1 { fleet[0].0 } else { fleet[rng.weighted_index(&weights)].0 };
        let profile = nearest_profile_for(model, u_hat);
        profile_counts[profile.dense()] += 1;
        vms.push(VmSpec {
            id: 0, // assigned after sorting
            profile,
            cpus: pod.cpus,
            ram_gb: pod.ram_gb,
            arrival: pod.arrival,
            departure: pod.arrival + pod.duration.max(1),
            weight: 1.0,
        });
    }
    vms.sort_by_key(|v| (v.arrival, v.departure));
    for (i, vm) in vms.iter_mut().enumerate() {
        vm.id = i as u64 + 1;
    }
    (vms, MappingReport { outliers_removed, multi_gpu_removed, profile_counts })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pod(arrival: Time, u: f64) -> PodRecord {
        PodRecord { arrival, duration: 3_600, num_gpus: 1.0, gpu_frac: u, cpus: 4, ram_gb: 16 }
    }

    #[test]
    fn normalized_values_increasing_to_one() {
        let v = normalized_profile_values();
        for w in v.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!((v[5] - 1.0).abs() < 1e-12);
        // Spot values: 1g.5gb = (1/7)(1/8) = 1/56 of 1.0.
        assert!((v[0] - 1.0 / 56.0).abs() < 1e-12);
        // 2g.10gb = (2/7)(2/8) = 4/56.
        assert!((v[2] - 4.0 / 56.0).abs() < 1e-12);
    }

    #[test]
    fn per_model_ladders_normalized() {
        for m in crate::mig::ALL_MODELS {
            let v = normalized_values_for(m);
            for w in v.windows(2) {
                assert!(w[0] < w[1], "{m}");
            }
            assert!((v.last().unwrap() - 1.0).abs() < 1e-12, "{m}");
        }
        // A30: 1g.6gb = (1/4)(1/4) = 1/16; 2g.12gb = (2/4)(2/4) = 1/4.
        let a30 = normalized_values_for(GpuModel::A30);
        assert!((a30[0] - 1.0 / 16.0).abs() < 1e-12);
        assert!((a30[1] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn nearest_profile_extremes() {
        assert_eq!(nearest_profile(0.0), Profile::P1g5gb);
        assert_eq!(nearest_profile(1.0), Profile::P7g40gb);
        assert_eq!(nearest_profile(0.99), Profile::P7g40gb);
        // Per-model extremes land on the model's own ladder.
        assert_eq!(nearest_profile_for(GpuModel::A30, 1.0), GpuModel::A30.profile(2));
        assert_eq!(nearest_profile_for(GpuModel::A30, 0.0), GpuModel::A30.profile(0));
        assert_eq!(
            nearest_profile_for(GpuModel::H100_80, 1.0),
            GpuModel::H100_80.profile(5)
        );
    }

    #[test]
    fn nearest_profile_midpoints() {
        let v = normalized_profile_values();
        // Just above the 4g.20gb value → still 4g.20gb.
        assert_eq!(nearest_profile(v[4] + 1e-6), Profile::P4g20gb);
        // Midpoint between 4g.20gb and 7g.40gb, slightly above → 7g.40gb.
        let mid = (v[4] + v[5]) / 2.0;
        assert_eq!(nearest_profile(mid + 1e-6), Profile::P7g40gb);
        assert_eq!(nearest_profile(mid - 1e-6), Profile::P4g20gb);
    }

    #[test]
    fn pipeline_filters_outliers_and_multigpu() {
        let mut pods: Vec<PodRecord> = (0..100).map(|i| pod(i * 60, 1.0)).collect();
        pods.push(pod(10_000_000, 1.0)); // arrival outlier
        pods.push(PodRecord {
            arrival: 300,
            duration: 60,
            num_gpus: 4.0,
            gpu_frac: 1.0,
            cpus: 4,
            ram_gb: 16,
        }); // multi-GPU
        let (vms, report) = map_pods_to_profiles(&pods);
        assert_eq!(report.outliers_removed, 1);
        assert_eq!(report.multi_gpu_removed, 1);
        assert_eq!(vms.len(), 100);
    }

    #[test]
    fn ids_sequential_by_arrival() {
        let pods = vec![pod(500, 0.5), pod(100, 0.2), pod(300, 1.0)];
        let (vms, _) = map_pods_to_profiles(&pods);
        assert_eq!(vms.len(), 3);
        assert!(vms.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert_eq!(vms.iter().map(|v| v.id).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn departure_strictly_after_arrival() {
        let pods = vec![PodRecord {
            arrival: 100,
            duration: 0,
            num_gpus: 1.0,
            gpu_frac: 0.3,
            cpus: 1,
            ram_gb: 1,
        }];
        let (vms, _) = map_pods_to_profiles(&pods);
        assert!(vms[0].departure > vms[0].arrival);
    }

    #[test]
    fn fractional_pods_map_to_small_profiles() {
        // u = 0.02 ≈ 1/56 → 1g.5gb when max u is 1.0.
        let pods = vec![pod(0, 1.0), pod(1, 1.0 / 56.0)];
        let (vms, report) = map_pods_to_profiles(&pods);
        assert_eq!(vms[1].profile, Profile::P1g5gb);
        assert_eq!(report.profile_counts[Profile::P7g40gb.dense()], 1);
        assert_eq!(report.profile_counts[Profile::P1g5gb.dense()], 1);
    }

    #[test]
    fn fleet_mapping_spreads_models_deterministically() {
        let pods: Vec<PodRecord> = (0..300).map(|i| pod(i * 60, 1.0)).collect();
        let fleet = [(GpuModel::A30, 0.5), (GpuModel::H100_80, 0.5)];
        let (vms_a, report_a) = map_pods_to_profiles_fleet(&pods, &fleet, &mut Rng::new(7));
        let (vms_b, _) = map_pods_to_profiles_fleet(&pods, &fleet, &mut Rng::new(7));
        assert_eq!(vms_a, vms_b, "fleet mapping must be seed-deterministic");
        // Both models' heavy profiles appear; counts cover the stream.
        let a30_heavy = GpuModel::A30.profile(2).dense();
        let h100_heavy = GpuModel::H100_80.profile(5).dense();
        assert!(report_a.profile_counts[a30_heavy] > 50);
        assert!(report_a.profile_counts[h100_heavy] > 50);
        assert_eq!(
            report_a.profile_counts.iter().sum::<usize>(),
            vms_a.len(),
            "every VM counted once"
        );
        // No A100-40 keys in a fleet without A100-40s.
        assert!(report_a.profile_counts[..6].iter().all(|&c| c == 0));
    }

    #[test]
    fn single_model_fleet_matches_historical_path() {
        let pods: Vec<PodRecord> = (0..50).map(|i| pod(i * 60, 0.1 + (i as f64) * 0.015)).collect();
        let (vms_old, report_old) = map_pods_to_profiles(&pods);
        let (vms_new, report_new) = map_pods_to_profiles_fleet(
            &pods,
            &[(GpuModel::A100_40, 1.0)],
            &mut Rng::new(999), // consumed by neither path
        );
        assert_eq!(vms_old, vms_new);
        assert_eq!(report_old.profile_counts, report_new.profile_counts);
    }
}
