//! Workload construction (§8.1).
//!
//! The paper builds its workload from the Alibaba GPU cluster trace 2023:
//! nodes become hosts, pods become VMs, arrival-time outliers are removed
//! with the IQR rule, pods needing more than one full GPU are dropped, and
//! each pod's fractional GPU requirement is mapped to the nearest MIG
//! profile by normalized compute×memory value (Eq. 27–30).
//!
//! The proprietary trace is not available in this environment, so
//! [`generator`] synthesizes a statistically equivalent workload (same
//! host/VM counts, 7g.40gb-heavy profile mix, heavy-tailed durations,
//! diurnal arrivals, injected arrival outliers for the IQR stage to
//! remove). [`loader`] ingests a real trace CSV with the same pipeline
//! when one is available, so the substitution is contained to record
//! *synthesis*, not processing.

pub mod generator;
pub mod loader;
pub mod mapping;

pub use generator::{ArrivalProcess, TraceConfig, Workload};
pub use mapping::{map_pods_to_profiles, map_pods_to_profiles_fleet, PodRecord};
