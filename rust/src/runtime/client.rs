//! Thin wrapper over the `xla` crate's PJRT client.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Artifacts are HLO *text*: jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client (CPU plugin).
pub struct Runtime {
    client: xla::PjRtClient,
}

// SAFETY: the `xla` crate wraps raw PJRT pointers without declaring Send,
// but PJRT objects are not tied to their creating thread (the C API is
// thread-compatible). We only ever *move* these values into the
// coordinator thread — single ownership, no concurrent sharing — which is
// exactly the Send contract.
unsafe impl Send for Runtime {}
unsafe impl Send for Executable {}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform string (e.g. "cpu") for diagnostics.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&computation)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

/// A compiled executable with tuple-output convention
/// (`return_tuple=True` on the python side).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the elements of the output
    /// tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs).context("executing")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let tuple = result.to_tuple().context("decomposing output tuple")?;
        Ok(tuple)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact() -> Option<std::path::PathBuf> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/cc_scorer.hlo.txt");
        p.exists().then_some(p)
    }

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn loads_and_runs_artifact_when_present() {
        // Gated on `make artifacts` having run (CI runs it first).
        let Some(path) = artifact() else {
            eprintln!("skipping: artifacts/cc_scorer.hlo.txt not built");
            return;
        };
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_hlo_text(&path).unwrap();
        // Batch of 1024 empty GPUs: CC = 18 everywhere.
        let occ = xla::Literal::vec1(&vec![0f32; 1024 * 8]).reshape(&[1024, 8]).unwrap();
        let out = exe.run(&[occ]).unwrap();
        assert_eq!(out.len(), 2);
        let cc = out[0].to_vec::<f32>().unwrap();
        assert_eq!(cc.len(), 1024);
        assert!(cc.iter().all(|&v| v == 18.0));
    }
}
