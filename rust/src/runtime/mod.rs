//! The PJRT/XLA runtime: load AOT-compiled artifacts and run them from
//! the Rust hot path (python never runs at request time). Built only
//! with the `xla` cargo feature, which pulls the external `xla`/`anyhow`
//! crates; the default build uses the native table scorer.
//!
//! * [`client`] — thin wrapper over the `xla` crate: CPU PJRT client,
//!   HLO-text loading (the id-safe interchange format — see
//!   `python/compile/aot.py`), compilation, tuple-output execution.
//! * [`scorer`] — the batched CC scorer backed by
//!   `artifacts/cc_scorer.hlo.txt`; implements
//!   [`crate::policies::CcScorer`] so MCC can score through XLA (via
//!   `PolicyCtx::with_scorer`) interchangeably with the native table
//!   (bit-identical results, verified by integration tests).

pub mod client;
pub mod scorer;

pub use client::{Executable, Runtime};
pub use scorer::XlaScorer;
