//! The PJRT/XLA runtime: load AOT-compiled artifacts and run them from
//! the Rust hot path (python never runs at request time).
//!
//! * [`client`] — thin wrapper over the `xla` crate: CPU PJRT client,
//!   HLO-text loading (the id-safe interchange format — see
//!   `python/compile/aot.py`), compilation, tuple-output execution.
//! * [`scorer`] — the batched CC scorer backed by
//!   `artifacts/cc_scorer.hlo.txt`; implements
//!   [`crate::policies::mcc::CcScorer`] so MCC/MECC can score through
//!   XLA interchangeably with the native table (bit-identical results,
//!   verified by integration tests).

pub mod client;
pub mod scorer;

pub use client::{Executable, Runtime};
pub use scorer::XlaScorer;
