//! The XLA-backed batched CC scorer.
//!
//! Loads `artifacts/cc_scorer.hlo.txt` (the AOT-lowered L2 graph wrapping
//! the L1 Pallas kernel) and exposes it as a
//! [`crate::policies::CcScorer`]: occupancy bitmasks in, CC values
//! out. The artifact's batch dimension is fixed at export time; inputs
//! are padded to the batch and results truncated. Scores are bit-identical
//! to the native table (`mig::gpu::cc`) — asserted by tests.

use super::client::{Executable, Runtime};
use crate::mig::GpuModel;
use crate::policies::CcScorer;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// The XLA scorer: compiled executable + fixed batch size.
pub struct XlaScorer {
    exe: Executable,
    batch: usize,
    /// Reusable host-side staging buffer.
    staging: Vec<f32>,
    /// Calls and configs scored (perf accounting).
    pub calls: u64,
    pub configs_scored: u64,
}

impl XlaScorer {
    /// Load an artifact (and its `.meta.json` sidecar for the batch size).
    pub fn load(hlo_path: &Path) -> Result<XlaScorer> {
        let meta_path = hlo_path
            .to_str()
            .context("path not UTF-8")?
            .replace(".hlo.txt", ".meta.json");
        let meta_text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path} (run `make artifacts`)"))?;
        let meta = Json::parse(&meta_text).map_err(|e| anyhow!("bad meta JSON: {e}"))?;
        let batch = meta
            .get("batch")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("meta missing 'batch'"))? as usize;
        let rt = Runtime::cpu()?;
        let exe = rt.load_hlo_text(hlo_path)?;
        Ok(XlaScorer { exe, batch, staging: Vec::new(), calls: 0, configs_scored: 0 })
    }

    /// Batch size the artifact was exported with.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Score occupancy masks, returning `(cc, per-profile capacities)`.
    pub fn score_full(&mut self, occs: &[u8]) -> Result<(Vec<u32>, Vec<[u8; 6]>)> {
        let mut cc_out = Vec::with_capacity(occs.len());
        let mut cap_out = Vec::with_capacity(occs.len());
        for chunk in occs.chunks(self.batch) {
            // Stage the chunk into a padded [batch, 8] 0/1 buffer.
            self.staging.clear();
            self.staging.resize(self.batch * 8, 0.0);
            for (i, &occ) in chunk.iter().enumerate() {
                for b in 0..8 {
                    if occ & (1u8 << b) != 0 {
                        self.staging[i * 8 + b] = 1.0;
                    }
                }
            }
            let input = xla::Literal::vec1(&self.staging)
                .reshape(&[self.batch as i64, 8])
                .context("reshaping input")?;
            let out = self.exe.run(&[input])?;
            let cc = out[0].to_vec::<f32>().context("cc output")?;
            let cap = out[1].to_vec::<f32>().context("capacity output")?;
            for i in 0..chunk.len() {
                cc_out.push(cc[i] as u32);
                let mut caps = [0u8; 6];
                for p in 0..6 {
                    caps[p] = cap[i * 6 + p] as u8;
                }
                cap_out.push(caps);
            }
            self.calls += 1;
            self.configs_scored += chunk.len() as u64;
        }
        Ok((cc_out, cap_out))
    }
}

impl CcScorer for XlaScorer {
    fn score(&mut self, model: GpuModel, occs: &[u8]) -> Vec<u32> {
        // The AOT artifact bakes in the A100-40 placement table; other
        // catalog models score through the native per-model tables
        // (bit-identical semantics, no artifact available for them yet).
        if model != GpuModel::A100_40 {
            return occs.iter().map(|&o| crate::mig::cc_for(model, o)).collect();
        }
        self.score_full(occs).expect("XLA scorer execution").0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::gpu::{cc, profile_capacity};

    fn load_scorer() -> Option<XlaScorer> {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/cc_scorer.hlo.txt");
        if !p.exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(XlaScorer::load(&p).unwrap())
    }

    #[test]
    fn bit_identical_to_native_table_all_masks() {
        let Some(mut scorer) = load_scorer() else { return };
        let masks: Vec<u8> = (0..=255).collect();
        let (ccs, caps) = scorer.score_full(&masks).unwrap();
        for (i, &m) in masks.iter().enumerate() {
            assert_eq!(ccs[i], cc(m), "cc mismatch at {m:08b}");
            assert_eq!(caps[i], profile_capacity(m), "capacity mismatch at {m:08b}");
        }
    }

    #[test]
    fn padding_and_chunking() {
        let Some(mut scorer) = load_scorer() else { return };
        // More masks than one batch → two executions; odd remainder padded.
        let n = scorer.batch() + 37;
        let masks: Vec<u8> = (0..n).map(|i| (i % 256) as u8).collect();
        let (ccs, _) = scorer.score_full(&masks).unwrap();
        assert_eq!(ccs.len(), n);
        assert_eq!(scorer.calls, 2);
        for (i, &m) in masks.iter().enumerate() {
            assert_eq!(ccs[i], cc(m));
        }
    }

    #[test]
    fn usable_as_mcc_backend() {
        let Some(scorer) = load_scorer() else { return };
        use crate::cluster::{DataCenter, Host, VmSpec};
        use crate::mig::Profile;
        use crate::policies::{mcc::Mcc, Policy, PolicyCtx};
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let mut policy = Mcc::new();
        let mut ctx = PolicyCtx::with_scorer(0, Box::new(scorer));
        let vm = VmSpec {
            id: 1,
            profile: Profile::P3g20gb,
            cpus: 2,
            ram_gb: 4,
            arrival: 0,
            departure: 100,
            weight: 1.0,
        };
        let out = policy.place_batch(&mut dc, &[vm], &mut ctx);
        assert!(out[0].is_placed());
    }
}
