//! Atomic on-disk snapshot store.
//!
//! One file per checkpointed interval boundary, named
//! `snap-{hour:010}.grmu`, each a complete framed engine image (see
//! [`super::encode_frame`]). Writes are crash-atomic: payload → temp
//! file in the same directory → fsync → rename over the final name →
//! fsync the directory. Readers scan newest-first and skip any file the
//! frame codec rejects, so a torn write degrades recovery to the
//! previous valid snapshot instead of corrupt state.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use super::{decode_frame, encode_frame, SnapshotKind};

/// Directory of framed engine snapshots, newest wins.
#[derive(Debug, Clone)]
pub struct SnapshotStore {
    dir: PathBuf,
}

impl SnapshotStore {
    /// Open (creating if needed) a snapshot directory.
    pub fn open(dir: &Path) -> std::io::Result<SnapshotStore> {
        fs::create_dir_all(dir)?;
        Ok(SnapshotStore { dir: dir.to_path_buf() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of the snapshot for a given closed-interval hour.
    pub fn path_for(&self, hour: u64) -> PathBuf {
        self.dir.join(format!("snap-{hour:010}.grmu"))
    }

    /// Atomically persist a snapshot of `kind` taken at interval
    /// boundary `hour`. On return the file is durable: a crash at any
    /// point leaves either no `snap-{hour}` file or a complete one.
    pub fn write(&self, hour: u64, kind: SnapshotKind, payload: &[u8]) -> std::io::Result<PathBuf> {
        let frame = encode_frame(kind, payload);
        let final_path = self.path_for(hour);
        let tmp_path = self.dir.join(format!(".snap-{hour:010}.grmu.tmp"));
        {
            let mut f = fs::File::create(&tmp_path)?;
            f.write_all(&frame)?;
            f.sync_all()?;
        }
        fs::rename(&tmp_path, &final_path)?;
        // Durability of the rename itself requires fsyncing the
        // directory; best-effort on filesystems that refuse it.
        if let Ok(d) = fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
        Ok(final_path)
    }

    /// Hours that have a snapshot file present (valid or not),
    /// ascending.
    pub fn hours(&self) -> Vec<u64> {
        let mut hours = Vec::new();
        let Ok(entries) = fs::read_dir(&self.dir) else {
            return hours;
        };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(h) = name
                .strip_prefix("snap-")
                .and_then(|rest| rest.strip_suffix(".grmu"))
                .and_then(|digits| digits.parse::<u64>().ok())
            {
                hours.push(h);
            }
        }
        hours.sort_unstable();
        hours
    }

    /// Load the newest snapshot that passes frame validation, returning
    /// its hour, kind and decoded payload. Torn or corrupt files are
    /// skipped (that is the crash-recovery contract); `None` means no
    /// valid snapshot exists at all.
    pub fn latest_valid(&self) -> Option<(u64, SnapshotKind, Vec<u8>)> {
        for &hour in self.hours().iter().rev() {
            let Ok(bytes) = fs::read(self.path_for(hour)) else {
                continue;
            };
            if let Ok((kind, payload)) = decode_frame(&bytes) {
                return Some((hour, kind, payload.to_vec()));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

    fn scratch_dir(tag: &str) -> PathBuf {
        let n = DIR_SEQ.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!(
            "grmu-snap-test-{}-{tag}-{n}",
            std::process::id()
        ))
    }

    #[test]
    fn newest_valid_snapshot_wins() {
        let dir = scratch_dir("latest");
        let store = SnapshotStore::open(&dir).unwrap();
        store.write(8, SnapshotKind::Core, b"at-8").unwrap();
        store.write(16, SnapshotKind::Core, b"at-16").unwrap();
        let (hour, kind, payload) = store.latest_valid().unwrap();
        assert_eq!((hour, kind), (16, SnapshotKind::Core));
        assert_eq!(payload, b"at-16");
        assert_eq!(store.hours(), vec![8, 16]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_snapshot_falls_back_to_previous() {
        let dir = scratch_dir("torn");
        let store = SnapshotStore::open(&dir).unwrap();
        store.write(8, SnapshotKind::Core, b"good").unwrap();
        store.write(16, SnapshotKind::Core, b"newer").unwrap();
        // Tear the newer file in half, as a crash mid-write would
        // without the atomic rename.
        let newest = store.path_for(16);
        let bytes = fs::read(&newest).unwrap();
        fs::write(&newest, &bytes[..bytes.len() / 2]).unwrap();
        let (hour, _, payload) = store.latest_valid().unwrap();
        assert_eq!(hour, 8);
        assert_eq!(payload, b"good");
        // Corrupt the survivor too: now nothing is loadable.
        let older = store.path_for(8);
        let mut bytes = fs::read(&older).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        fs::write(&older, &bytes).unwrap();
        assert!(store.latest_valid().is_none());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_or_missing_dir_is_no_snapshot() {
        let dir = scratch_dir("empty");
        let store = SnapshotStore::open(&dir).unwrap();
        assert!(store.latest_valid().is_none());
        assert!(store.hours().is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }
}
