//! Crash-consistent persistence for the simulation engine.
//!
//! A long online run must survive a controller crash without losing the
//! placement state it has accumulated. This module provides the three
//! pieces the engine needs:
//!
//! * **Framed snapshots** ([`encode_frame`]/[`decode_frame`] +
//!   [`SnapshotStore`]) — a full engine image (`DataCenter` +
//!   `EventCore` run state, or every shard of a `ShardedCore`) encoded
//!   with the [`crate::util::codec`] byte codec, wrapped in a versioned
//!   frame with an FNV-1a checksum, and written **atomically**: the
//!   payload goes to a temp file, is fsynced, and is renamed into place
//!   (then the directory is fsynced), so a crash mid-write leaves either
//!   the old snapshot set or the old set plus one new complete file —
//!   never a half-written "latest".
//! * **Interval journal** ([`Journal`] + [`IntervalRecord`]) — a tiny
//!   write-ahead record appended at every interval boundary with the
//!   run's cumulative counters. On recovery the engine loads the newest
//!   *valid* snapshot (torn files are skipped by checksum), re-drives
//!   the deterministic trace from the snapshot clock, and cross-checks
//!   each re-closed interval against the journal suffix — a mismatch
//!   means the trace or configuration differs from the crashed run and
//!   recovery aborts loudly instead of silently diverging.
//! * **Graceful degradation** ([`OnCorruption`]) — what the engine does
//!   when `DataCenter::try_check_integrity` reports a violation at a
//!   maintenance tick: abort (the historical panic), quarantine the
//!   offending host (rebuild derived state, evict its residents, ban
//!   it), or rebuild derived state in place. Repairs surface as
//!   `OpsEvent::StateRepair` entries in the engine's repair log.
//!
//! Determinism is what makes recovery *byte-identical* rather than
//! merely plausible: the snapshot captures every bit of engine state
//! that influences future decisions (RNG cursors, policy state, queue
//! contents, fault-schedule cursor), and the determinism contracts from
//! the cluster/sim layers guarantee the resumed run replays the exact
//! decision stream of an uninterrupted twin. `rust/tests/crash_recovery.rs`
//! locks this across policies × shard counts × ops schedules × kill
//! points.

mod journal;
mod snapshot;

pub use journal::{IntervalRecord, Journal};
pub use snapshot::SnapshotStore;

use crate::util::codec::fnv1a;
use std::collections::VecDeque;
use std::path::Path;

/// Magic prefix of every snapshot frame.
pub const SNAPSHOT_MAGIC: [u8; 4] = *b"GRMU";

/// Snapshot format version. Bump on any change to the payload field
/// sequence; readers refuse versions they do not know (recovery then
/// falls back to an older snapshot or a fresh run — never a guess).
pub const SNAPSHOT_VERSION: u16 = 1;

/// What kind of engine image a snapshot frame carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnapshotKind {
    /// A single `EventCore` (classic engine).
    Core,
    /// A `ShardedCore` (router state + one core image per shard).
    Sharded,
}

impl SnapshotKind {
    fn tag(self) -> u8 {
        match self {
            SnapshotKind::Core => 1,
            SnapshotKind::Sharded => 2,
        }
    }

    fn from_tag(tag: u8) -> Result<SnapshotKind, String> {
        match tag {
            1 => Ok(SnapshotKind::Core),
            2 => Ok(SnapshotKind::Sharded),
            t => Err(format!("unknown snapshot kind tag {t}")),
        }
    }
}

/// Policy for integrity violations detected at a maintenance tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OnCorruption {
    /// Panic, as the engine always has (default).
    #[default]
    Abort,
    /// Rebuild derived state from ground truth, then evict and ban the
    /// offending host (when one is identifiable) and keep serving.
    Quarantine,
    /// Rebuild derived state (index, activity counters, locations) from
    /// ground truth in place and keep serving.
    Rebuild,
}

impl OnCorruption {
    /// Parse a CLI value. Accepts `abort`, `quarantine`, `rebuild`.
    pub fn parse(s: &str) -> Result<OnCorruption, String> {
        match s {
            "abort" => Ok(OnCorruption::Abort),
            "quarantine" => Ok(OnCorruption::Quarantine),
            "rebuild" => Ok(OnCorruption::Rebuild),
            other => Err(format!(
                "unknown --on-corruption mode '{other}' (expected abort|quarantine|rebuild)"
            )),
        }
    }
}

/// Wrap an encoded payload in the versioned, checksummed snapshot frame:
/// `magic ++ version(u16) ++ kind(u8) ++ len(u64) ++ payload ++ fnv1a(payload)`.
pub fn encode_frame(kind: SnapshotKind, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 23);
    out.extend_from_slice(&SNAPSHOT_MAGIC);
    out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
    out.push(kind.tag());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    out.extend_from_slice(&fnv1a(payload).to_le_bytes());
    out
}

/// Validate a snapshot frame and return its kind and payload slice.
/// Any damage — wrong magic, unknown version, truncation, checksum
/// mismatch, trailing garbage — is an `Err`, so callers can treat a torn
/// file as "not a snapshot" and fall back.
pub fn decode_frame(bytes: &[u8]) -> Result<(SnapshotKind, &[u8]), String> {
    if bytes.len() < 23 {
        return Err(format!("frame too short ({} bytes)", bytes.len()));
    }
    if bytes[..4] != SNAPSHOT_MAGIC {
        return Err("bad snapshot magic".into());
    }
    let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
    if version != SNAPSHOT_VERSION {
        return Err(format!(
            "unsupported snapshot version {version} (this build reads {SNAPSHOT_VERSION})"
        ));
    }
    let kind = SnapshotKind::from_tag(bytes[6])?;
    let len = u64::from_le_bytes(bytes[7..15].try_into().unwrap());
    let len = usize::try_from(len).map_err(|_| "payload length overflows".to_string())?;
    let expected_total = 15usize
        .checked_add(len)
        .and_then(|n| n.checked_add(8))
        .ok_or_else(|| "payload length overflows".to_string())?;
    if bytes.len() != expected_total {
        return Err(format!(
            "frame length mismatch: header says {expected_total} bytes, file has {}",
            bytes.len()
        ));
    }
    let payload = &bytes[15..15 + len];
    let sum = u64::from_le_bytes(bytes[15 + len..].try_into().unwrap());
    if fnv1a(payload) != sum {
        return Err("snapshot checksum mismatch (torn or corrupt write)".into());
    }
    Ok((kind, payload))
}

/// Engine-side checkpoint driver: owns one checkpoint directory's
/// [`SnapshotStore`] and [`Journal`] and implements the per-interval
/// protocol shared by the single-core and sharded engines:
///
/// * On a **fresh** run, stale `snap-*.grmu` files and the journal from
///   any earlier run in the same directory are removed first — leftover
///   state drawn from a different trace would poison a later resume.
/// * On a **resume**, the journal suffix from the crashed run (records
///   at or past the snapshot hour) is held for cross-checking: each
///   re-closed interval must reproduce the crashed run's cumulative
///   counters exactly, or recovery aborts loudly instead of silently
///   diverging. Intervals past the crash frontier append fresh records.
/// * Full engine images are written on the `every`-interval cadence
///   (0 = journal only).
pub struct Checkpointer {
    store: SnapshotStore,
    journal: Journal,
    every: u64,
    kind: SnapshotKind,
    /// Crashed-run journal records still awaiting cross-check,
    /// ascending by hour; drained front-to-back as intervals re-close.
    pending_check: VecDeque<IntervalRecord>,
}

impl Checkpointer {
    /// Open `dir` for checkpointing. `resume_hour` is the hour of the
    /// snapshot the run was restored from (`None` = fresh run).
    pub fn new(
        dir: &Path,
        every: u64,
        kind: SnapshotKind,
        resume_hour: Option<u64>,
    ) -> std::io::Result<Checkpointer> {
        let store = SnapshotStore::open(dir)?;
        let journal = Journal::in_dir(dir);
        let pending_check = match resume_hour {
            Some(h) => journal.read_all().into_iter().filter(|r| r.hour >= h).collect(),
            None => {
                for hour in store.hours() {
                    let _ = std::fs::remove_file(store.path_for(hour));
                }
                let _ = std::fs::remove_file(journal.path());
                VecDeque::new()
            }
        };
        Ok(Checkpointer { store, journal, every, kind, pending_check })
    }

    /// Journal records from the crashed run not yet cross-checked.
    pub fn pending_checks(&self) -> usize {
        self.pending_check.len()
    }

    /// Record one closed interval: cross-check it against the crashed
    /// run's journal if it falls inside the re-drive window, append it
    /// otherwise, and write a full snapshot on the cadence (`snapshot`
    /// is only invoked when an image is actually due).
    ///
    /// Panics on a cross-check mismatch: the resumed run is not
    /// reproducing the crashed run, which means the trace or the
    /// configuration differs — continuing would be silent divergence.
    pub fn interval_closed(&mut self, rec: &IntervalRecord, snapshot: impl FnOnce() -> Vec<u8>) {
        match self.pending_check.front() {
            Some(prior) if prior.hour <= rec.hour => {
                assert_eq!(
                    prior, rec,
                    "journal cross-check failed at interval {}: the resumed run diverged \
                     from the crashed run (trace or configuration mismatch)",
                    rec.hour
                );
                self.pending_check.pop_front();
            }
            _ => {
                self.journal.append(rec).expect("journal append failed");
            }
        }
        if self.every > 0 && (rec.hour + 1) % self.every == 0 {
            self.store
                .write(rec.hour + 1, self.kind, &snapshot())
                .expect("snapshot write failed");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_round_trip() {
        let payload = b"engine state bytes".to_vec();
        let frame = encode_frame(SnapshotKind::Sharded, &payload);
        let (kind, got) = decode_frame(&frame).unwrap();
        assert_eq!(kind, SnapshotKind::Sharded);
        assert_eq!(got, &payload[..]);
    }

    #[test]
    fn torn_and_tampered_frames_are_rejected() {
        let frame = encode_frame(SnapshotKind::Core, b"payload");
        // Truncation at every prefix length fails cleanly.
        for cut in 0..frame.len() {
            assert!(decode_frame(&frame[..cut]).is_err(), "cut={cut}");
        }
        // A single flipped payload bit fails the checksum.
        let mut bad = frame.clone();
        bad[16] ^= 0x40;
        assert!(decode_frame(&bad).is_err());
        // Trailing garbage is not ignored.
        let mut long = frame.clone();
        long.push(0);
        assert!(decode_frame(&long).is_err());
        // A future version is refused rather than misread.
        let mut vers = frame;
        vers[4] = 0xFF;
        assert!(decode_frame(&vers).is_err());
    }

    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

    fn scratch_dir(tag: &str) -> PathBuf {
        let n = DIR_SEQ.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("grmu-cp-test-{}-{tag}-{n}", std::process::id()))
    }

    fn rec(hour: u64) -> IntervalRecord {
        IntervalRecord {
            hour,
            requested: 2 * hour + 3,
            accepted: 2 * hour,
            rejections: [3, 0, 0, 0, 0, 0],
            migrations: hour / 2,
            interrupted: 0,
            queue_len: 1,
        }
    }

    #[test]
    fn checkpointer_cross_checks_then_rolls_forward() {
        let dir = scratch_dir("protocol");
        let mut cp = Checkpointer::new(&dir, 2, SnapshotKind::Core, None).unwrap();
        for h in 0..=2 {
            cp.interval_closed(&rec(h), || b"image".to_vec());
        }
        // Cadence 2 → snapshots named for hours 2 (after closing 1).
        let store = SnapshotStore::open(&dir).unwrap();
        assert_eq!(store.hours(), vec![2]);
        // "Crash", then resume from the hour-2 snapshot: the journaled
        // interval 2 is re-driven and cross-checked, not re-appended.
        let mut cp = Checkpointer::new(&dir, 2, SnapshotKind::Core, Some(2)).unwrap();
        assert_eq!(cp.pending_checks(), 1);
        cp.interval_closed(&rec(2), || b"image".to_vec());
        assert_eq!(cp.pending_checks(), 0);
        cp.interval_closed(&rec(3), || b"image".to_vec());
        let journaled: Vec<u64> =
            Journal::in_dir(&dir).read_all().iter().map(|r| r.hour).collect();
        assert_eq!(journaled, vec![0, 1, 2, 3], "no duplicate record for hour 2");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[should_panic(expected = "journal cross-check failed")]
    fn checkpointer_panics_on_divergent_redrive() {
        let dir = scratch_dir("diverge");
        let mut cp = Checkpointer::new(&dir, 0, SnapshotKind::Core, None).unwrap();
        cp.interval_closed(&rec(0), Vec::new);
        let mut cp = Checkpointer::new(&dir, 0, SnapshotKind::Core, Some(0)).unwrap();
        let mut wrong = rec(0);
        wrong.accepted += 1;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cp.interval_closed(&wrong, Vec::new);
        }));
        std::fs::remove_dir_all(&dir).unwrap();
        match result {
            Ok(()) => panic!("divergent re-drive was accepted"),
            // Re-raise with the original payload after cleanup so the
            // `should_panic(expected)` filter still sees the message.
            Err(e) => std::panic::resume_unwind(e),
        }
    }

    #[test]
    fn checkpointer_fresh_run_clears_stale_state() {
        let dir = scratch_dir("stale");
        let mut cp = Checkpointer::new(&dir, 1, SnapshotKind::Core, None).unwrap();
        cp.interval_closed(&rec(0), || b"old".to_vec());
        let _ = Checkpointer::new(&dir, 1, SnapshotKind::Core, None).unwrap();
        assert!(SnapshotStore::open(&dir).unwrap().hours().is_empty());
        assert!(Journal::in_dir(&dir).read_all().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn on_corruption_parses() {
        assert_eq!(OnCorruption::parse("abort").unwrap(), OnCorruption::Abort);
        assert_eq!(
            OnCorruption::parse("quarantine").unwrap(),
            OnCorruption::Quarantine
        );
        assert_eq!(OnCorruption::parse("rebuild").unwrap(), OnCorruption::Rebuild);
        assert!(OnCorruption::parse("retry").is_err());
        assert_eq!(OnCorruption::default(), OnCorruption::Abort);
    }
}
