//! Append-only interval journal (write-ahead log).
//!
//! One [`IntervalRecord`] is appended per closed interval with the
//! run's *cumulative* counters. Each record is individually framed as
//! `len(u32) ++ payload ++ fnv1a(payload)(u64)`, so a crash mid-append
//! tears at most the final record: sequential reads stop at the first
//! record that fails its length or checksum.
//!
//! The journal is not replayed to mutate state — snapshots carry the
//! full engine image, and the trace re-drive is deterministic. Its job
//! is *cross-checking*: a resumed run re-closes intervals the crashed
//! run already journaled and verifies it reproduces the exact same
//! counters, turning a trace/config mismatch into a loud error instead
//! of a silently divergent "recovery".

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::policies::RejectCounts;
use crate::util::codec::{fnv1a, Dec, Enc};

/// Cumulative run counters at one closed interval boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IntervalRecord {
    /// The interval index that just closed.
    pub hour: u64,
    /// Cumulative requests offered so far.
    pub requested: u64,
    /// Cumulative acceptances so far.
    pub accepted: u64,
    /// Cumulative per-reason rejection counts.
    pub rejections: RejectCounts,
    /// Cumulative migration events performed.
    pub migrations: u64,
    /// Cumulative VM interruptions from faults.
    pub interrupted: u64,
    /// Admission-queue length at the boundary.
    pub queue_len: u64,
}

impl IntervalRecord {
    fn encode(&self) -> Vec<u8> {
        let mut e = Enc::with_capacity(96);
        e.u64(self.hour);
        e.u64(self.requested);
        e.u64(self.accepted);
        for &r in &self.rejections {
            e.u64(r);
        }
        e.u64(self.migrations);
        e.u64(self.interrupted);
        e.u64(self.queue_len);
        e.into_bytes()
    }

    fn decode(bytes: &[u8]) -> Result<IntervalRecord, String> {
        let mut d = Dec::new(bytes);
        let hour = d.u64()?;
        let requested = d.u64()?;
        let accepted = d.u64()?;
        let mut rejections = RejectCounts::default();
        for r in rejections.iter_mut() {
            *r = d.u64()?;
        }
        let rec = IntervalRecord {
            hour,
            requested,
            accepted,
            rejections,
            migrations: d.u64()?,
            interrupted: d.u64()?,
            queue_len: d.u64()?,
        };
        if !d.is_empty() {
            return Err("journal record has trailing bytes".into());
        }
        Ok(rec)
    }
}

/// Append-only journal file (`journal.grmuj` inside the checkpoint
/// directory).
#[derive(Debug, Clone)]
pub struct Journal {
    path: PathBuf,
}

impl Journal {
    /// Conventional journal path inside a checkpoint directory.
    pub fn in_dir(dir: &Path) -> Journal {
        Journal { path: dir.join("journal.grmuj") }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record and fsync. The record is framed individually,
    /// so a crash during the append tears only this record.
    pub fn append(&self, rec: &IntervalRecord) -> std::io::Result<()> {
        let payload = rec.encode();
        let mut framed = Vec::with_capacity(payload.len() + 12);
        framed.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        framed.extend_from_slice(&payload);
        framed.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        let mut f = fs::OpenOptions::new().create(true).append(true).open(&self.path)?;
        f.write_all(&framed)?;
        f.sync_all()
    }

    /// Read every intact record in order, stopping at the first torn or
    /// corrupt one (the crash frontier). A missing file is an empty
    /// journal.
    pub fn read_all(&self) -> Vec<IntervalRecord> {
        let Ok(bytes) = fs::read(&self.path) else {
            return Vec::new();
        };
        let mut out = Vec::new();
        let mut at = 0usize;
        while bytes.len() - at >= 4 {
            let len = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) as usize;
            let Some(end) = at.checked_add(4 + len + 8) else { break };
            if end > bytes.len() {
                break;
            }
            let payload = &bytes[at + 4..at + 4 + len];
            let sum = u64::from_le_bytes(bytes[at + 4 + len..end].try_into().unwrap());
            if fnv1a(payload) != sum {
                break;
            }
            match IntervalRecord::decode(payload) {
                Ok(rec) => out.push(rec),
                Err(_) => break,
            }
            at = end;
        }
        out
    }

    /// Hour of the last intact record, if any.
    pub fn last_hour(&self) -> Option<u64> {
        self.read_all().last().map(|r| r.hour)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

    fn scratch_dir(tag: &str) -> PathBuf {
        let n = DIR_SEQ.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "grmu-journal-test-{}-{tag}-{n}",
            std::process::id()
        ));
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn record(hour: u64) -> IntervalRecord {
        IntervalRecord {
            hour,
            requested: hour * 10,
            accepted: hour * 9,
            rejections: [hour, 0, 1, 0, 2, 0],
            migrations: hour / 2,
            interrupted: 0,
            queue_len: 3,
        }
    }

    #[test]
    fn appends_and_reads_back_in_order() {
        let dir = scratch_dir("rw");
        let j = Journal::in_dir(&dir);
        for h in 1..=5 {
            j.append(&record(h)).unwrap();
        }
        let got = j.read_all();
        assert_eq!(got.len(), 5);
        assert_eq!(got[0], record(1));
        assert_eq!(got[4], record(5));
        assert_eq!(j.last_hour(), Some(5));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_tail_record_is_dropped() {
        let dir = scratch_dir("torn");
        let j = Journal::in_dir(&dir);
        for h in 1..=3 {
            j.append(&record(h)).unwrap();
        }
        // Tear the last record: drop the final 5 bytes of the file.
        let bytes = fs::read(j.path()).unwrap();
        fs::write(j.path(), &bytes[..bytes.len() - 5]).unwrap();
        let got = j.read_all();
        assert_eq!(got.len(), 2);
        assert_eq!(j.last_hour(), Some(2));
        // A corrupt middle record hides everything after it.
        let mut bytes = fs::read(j.path()).unwrap();
        bytes[6] ^= 0x01;
        fs::write(j.path(), &bytes).unwrap();
        assert!(j.read_all().is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_journal_is_empty() {
        let dir = scratch_dir("missing");
        let j = Journal::in_dir(&dir);
        assert!(j.read_all().is_empty());
        assert_eq!(j.last_hour(), None);
        fs::remove_dir_all(&dir).unwrap();
    }
}
