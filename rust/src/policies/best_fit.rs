//! Best-Fit (BF, §8.3): among all GPUs that can host the request, pick
//! the one minimizing the blocks left unallocated after placement. The
//! candidate set comes from the cluster index (decision-identical to the
//! historical full scan; see [`super::visit_candidates`]).

use super::{reject_cluster, visit_candidates, Decision, Policy, PolicyCtx};
use crate::cluster::vm::VmSpec;
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::placement::mock_assign;
use crate::mig::Placement;

/// Best-Fit placement.
#[derive(Debug)]
pub struct BestFit {
    use_index: bool,
}

impl BestFit {
    pub fn new() -> BestFit {
        BestFit::with_index(true)
    }

    /// `use_index = false` restores the brute-force full scan.
    pub fn with_index(use_index: bool) -> BestFit {
        BestFit { use_index }
    }
}

impl Default for BestFit {
    fn default() -> Self {
        BestFit::new()
    }
}

impl Policy for BestFit {
    fn name(&self) -> &str {
        "BF"
    }

    fn place_batch_into(&mut self, dc: &mut DataCenter, vms: &[VmSpec], ctx: &mut PolicyCtx) {
        ctx.decisions.begin(vms.len());
        for vm in vms {
            if self.use_index && !dc.index().host_may_fit(vm.cpus, vm.ram_gb) {
                ctx.decisions.push(reject_cluster(dc, vm, self.use_index));
                continue;
            }
            let num_blocks = vm.profile.model().num_blocks() as u32;
            let mut best: Option<(u32, GpuRef, Placement)> = None;
            let mut skip_host: Option<u32> = None;
            visit_candidates(dc, vm.profile, self.use_index, |r| {
                if skip_host == Some(r.host) {
                    return true;
                }
                if !dc.host(r.host).fits_resources(vm.cpus, vm.ram_gb) {
                    skip_host = Some(r.host);
                    return true;
                }
                if let Some((pl, new_occ)) = mock_assign(dc.gpu(r).occupancy(), vm.profile) {
                    let remaining = num_blocks - new_occ.count_ones();
                    // Strictly-less keeps the first (lowest index) on ties.
                    if best.map(|(b, _, _)| remaining < b).unwrap_or(true) {
                        best = Some((remaining, r, pl));
                        if remaining == 0 {
                            return false; // perfect fit
                        }
                    }
                }
                true
            });
            let d = match best {
                Some((_, r, pl)) => {
                    dc.place(vm, r, pl);
                    Decision::Placed { gpu: r, placement: pl }
                }
                None => reject_cluster(dc, vm, self.use_index),
            };
            ctx.decisions.push(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::{Placement, Profile};

    fn vm(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 100, weight: 1.0 }
    }

    #[test]
    fn prefers_tighter_gpu() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        // Pre-occupy GPU 1 with a 4-block instance: it becomes the
        // tighter fit for a 3g.20gb request (0 remaining vs 4 on GPU 0).
        let filler = vm(99, Profile::P4g20gb);
        dc.place(&filler, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P4g20gb, start: 0 });
        let mut p = BestFit::new();
        let mut ctx = PolicyCtx::default();
        let out = p.place_batch(&mut dc, &[vm(1, Profile::P3g20gb)], &mut ctx);
        assert!(out[0].is_placed());
        assert_eq!(out[0].gpu(), Some(GpuRef { host: 0, gpu: 1 }));
        assert_eq!(dc.locate(1).unwrap().gpu, GpuRef { host: 0, gpu: 1 });
    }

    #[test]
    fn falls_back_when_tight_gpu_cannot_host() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        // GPU 1 has 6 blocks taken: a 3g.20gb no longer fits there.
        let f1 = vm(98, Profile::P4g20gb);
        let f2 = vm(99, Profile::P2g10gb);
        dc.place(&f1, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P4g20gb, start: 0 });
        dc.place(&f2, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P2g10gb, start: 4 });
        let mut p = BestFit::new();
        let mut ctx = PolicyCtx::default();
        let out = p.place_batch(&mut dc, &[vm(1, Profile::P3g20gb)], &mut ctx);
        assert!(out[0].is_placed());
        assert_eq!(dc.locate(1).unwrap().gpu, GpuRef { host: 0, gpu: 0 });
    }

    #[test]
    fn ties_resolve_to_lowest_global_index() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 3)]);
        let mut p = BestFit::new();
        let mut ctx = PolicyCtx::default();
        p.place_batch(&mut dc, &[vm(1, Profile::P1g5gb)], &mut ctx);
        assert_eq!(dc.locate(1).unwrap().gpu, GpuRef { host: 0, gpu: 0 });
    }
}
