//! Max Expected Configuration Capacity (MECC, Algorithm 7): like MCC but
//! each profile's feasible-start count is weighted by the probability of
//! that profile appearing, estimated from an `n`-hour trailing window of
//! requested profiles (the paper picks n = 24 h, the lowest-error
//! look-back among {1, 12, 24, 48, 96}).
//!
//! The window spans the whole catalog: requests are counted per dense
//! [`Profile::dense`] key, and a candidate GPU's expected capacity sums
//! only over its own model's profiles (foreign-model profiles can never
//! land there, so they contribute zero capacity by construction). On an
//! A100-only fleet this reduces exactly to the historical six-profile
//! window — the uniform empty-window prior scales every candidate's
//! score by the same constant, leaving every argmax unchanged.

use super::{reject_cluster, visit_candidates, Decision, Policy, PolicyCtx};
use crate::cluster::vm::{Time, VmSpec};
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::gpu::profile_capacity_for;
use crate::mig::placement::mock_assign;
use crate::mig::{GpuModel, Profile, ProfileKey, ALL_MODELS, NUM_MODELS, NUM_PROFILE_KEYS};
use std::collections::VecDeque;

/// MECC placement.
pub struct Mecc {
    use_index: bool,
    /// Look-back window (hours).
    window_hours: u64,
    /// Requested profiles (dense keys) with timestamps, pruned to the
    /// window.
    history: VecDeque<(Time, usize)>,
    /// Current per-profile counts within the window, by dense key.
    counts: [u64; NUM_PROFILE_KEYS],
    /// Per-model ECC tables, recomputed in place at the start of every
    /// batch (allocated once; §Perf iterations 4 and 6).
    ecc_tables: Vec<[f64; 256]>,
}

impl Mecc {
    pub fn new(window_hours: u64) -> Mecc {
        Mecc::with_index(window_hours, true)
    }

    /// `use_index = false` restores the brute-force full scan.
    pub fn with_index(window_hours: u64, use_index: bool) -> Mecc {
        Mecc {
            use_index,
            window_hours,
            history: VecDeque::new(),
            counts: [0; NUM_PROFILE_KEYS],
            ecc_tables: vec![[0.0; 256]; NUM_MODELS],
        }
    }

    /// Profile probabilities from the window (by dense key); uniform
    /// when empty.
    pub fn probabilities(&self) -> [f64; NUM_PROFILE_KEYS] {
        let total: u64 = self.counts.iter().sum();
        if total == 0 {
            return [1.0 / NUM_PROFILE_KEYS as f64; NUM_PROFILE_KEYS];
        }
        let mut p = [0.0; NUM_PROFILE_KEYS];
        for i in 0..NUM_PROFILE_KEYS {
            p[i] = self.counts[i] as f64 / total as f64;
        }
        p
    }

    /// GetECC (Algorithm 7): probability-weighted feasible-start count
    /// of `occ` on a GPU of `model`.
    pub fn ecc(&self, model: GpuModel, occ: u8, probs: &[f64; NUM_PROFILE_KEYS]) -> f64 {
        let cap = profile_capacity_for(model, occ);
        let mut e = 0.0;
        for key in model.profile_keys() {
            e += probs[key.dense()] * cap[key.index()] as f64;
        }
        e
    }

    fn observe(&mut self, vms: &[VmSpec], now: Time) {
        for vm in vms {
            let idx = vm.profile.dense();
            self.history.push_back((now, idx));
            self.counts[idx] += 1;
        }
        let horizon = now.saturating_sub(self.window_hours * crate::cluster::vm::HOUR);
        while let Some(&(t, idx)) = self.history.front() {
            if t >= horizon {
                break;
            }
            self.history.pop_front();
            self.counts[idx] -= 1;
        }
    }

    /// Most probable profile in the current window (used by the paper's
    /// look-back error analysis).
    pub fn predicted_profile(&self) -> Profile {
        let probs = self.probabilities();
        let mut best = 0usize;
        for i in 1..NUM_PROFILE_KEYS {
            if probs[i] > probs[best] {
                best = i;
            }
        }
        ProfileKey::from_dense(best)
    }
}

impl Policy for Mecc {
    fn name(&self) -> &str {
        "MECC"
    }

    fn place_batch_into(&mut self, dc: &mut DataCenter, vms: &[VmSpec], ctx: &mut PolicyCtx) {
        // The window reflects requests seen up to and including this batch.
        self.observe(vms, ctx.now);
        let probs = self.probabilities();
        // The probabilities are fixed for the whole batch, so ECC is a
        // pure function of the (model, occupancy) pair — recompute every
        // model's table once per batch, in the tables allocated at
        // construction (EXPERIMENTS.md §Perf iterations 4 and 6;
        // ≤ 4 × 256 sums, amortized over the whole batch).
        for model in ALL_MODELS {
            for occ in 0..model.num_masks() {
                let e = self.ecc(model, occ as u8, &probs);
                self.ecc_tables[model as usize][occ] = e;
            }
        }
        let use_index = self.use_index;
        ctx.decisions.begin(vms.len());
        for vm in vms {
            if use_index && !dc.index().host_may_fit(vm.cpus, vm.ram_gb) {
                ctx.decisions.push(reject_cluster(dc, vm, use_index));
                continue;
            }
            let ecc_table = &self.ecc_tables[vm.profile.model() as usize];
            let mut best: Option<(f64, GpuRef, crate::mig::Placement)> = None;
            let mut skip_host: Option<u32> = None;
            visit_candidates(dc, vm.profile, use_index, |r| {
                if skip_host == Some(r.host) {
                    return true;
                }
                if !dc.host(r.host).fits_resources(vm.cpus, vm.ram_gb) {
                    skip_host = Some(r.host);
                    return true;
                }
                if let Some((pl, new_occ)) = mock_assign(dc.gpu(r).occupancy(), vm.profile) {
                    let score = ecc_table[new_occ as usize];
                    if best.map(|(b, _, _)| score > b).unwrap_or(true) {
                        best = Some((score, r, pl));
                    }
                }
                true
            });
            let d = match best {
                Some((_, r, pl)) => {
                    dc.place(vm, r, pl);
                    Decision::Placed { gpu: r, placement: pl }
                }
                None => reject_cluster(dc, vm, use_index),
            };
            ctx.decisions.push(d);
        }
    }

    fn snapshot_state(&self, out: &mut Vec<u8>) {
        // `counts` is a pure function of `history`, and the ECC tables
        // are recomputed per batch — the window is the whole state.
        let mut e = crate::util::codec::Enc::new();
        e.usize(self.history.len());
        for &(t, idx) in &self.history {
            e.u64(t);
            e.usize(idx);
        }
        out.extend_from_slice(e.bytes());
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut d = crate::util::codec::Dec::new(bytes);
        let n = d.count(16)?;
        self.history = VecDeque::with_capacity(n);
        self.counts = [0; NUM_PROFILE_KEYS];
        for _ in 0..n {
            let t = d.u64()?;
            let idx = d.usize()?;
            if idx >= NUM_PROFILE_KEYS {
                return Err(format!("MECC history has out-of-range profile key {idx}"));
            }
            self.history.push_back((t, idx));
            self.counts[idx] += 1;
        }
        if !d.is_empty() {
            return Err("trailing bytes in MECC state".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::vm::HOUR;
    use crate::cluster::Host;

    fn vm(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 100, weight: 1.0 }
    }

    fn batch_at(m: &mut Mecc, dc: &mut DataCenter, vms: &[VmSpec], now: Time) -> Vec<Decision> {
        let mut ctx = PolicyCtx::default();
        ctx.now = now;
        m.place_batch(dc, vms, &mut ctx)
    }

    #[test]
    fn window_prunes_old_history() {
        let mut m = Mecc::new(24);
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 8)]);
        batch_at(&mut m, &mut dc, &[vm(1, Profile::P1g5gb)], HOUR);
        batch_at(&mut m, &mut dc, &[vm(2, Profile::P7g40gb)], 30 * HOUR);
        // After 30h, the 1g.5gb observation (at 1h) left the 24h window.
        assert_eq!(m.counts[Profile::P1g5gb.dense()], 0);
        assert_eq!(m.counts[Profile::P7g40gb.dense()], 1);
        assert_eq!(m.predicted_profile(), Profile::P7g40gb);
    }

    #[test]
    fn uniform_prior_when_no_history() {
        let m = Mecc::new(24);
        let p = m.probabilities();
        assert!(p.iter().all(|&x| (x - 1.0 / NUM_PROFILE_KEYS as f64).abs() < 1e-12));
    }

    #[test]
    fn ecc_weighted_by_probabilities() {
        let m = Mecc::new(24);
        let a100 = GpuModel::A100_40;
        // All mass on 7g.40gb: ECC of the empty GPU = cap(7g) = 1.
        let mut probs = [0.0; NUM_PROFILE_KEYS];
        probs[Profile::P7g40gb.dense()] = 1.0;
        assert!((m.ecc(a100, 0, &probs) - 1.0).abs() < 1e-12);
        // All mass on 1g.5gb: ECC of the empty GPU = 7.
        let mut probs = [0.0; NUM_PROFILE_KEYS];
        probs[Profile::P1g5gb.dense()] = 1.0;
        assert!((m.ecc(a100, 0, &probs) - 7.0).abs() < 1e-12);
        // Foreign-model mass contributes nothing on an A100.
        let mut probs = [0.0; NUM_PROFILE_KEYS];
        probs[GpuModel::A30.profile(0).dense()] = 1.0;
        assert_eq!(m.ecc(a100, 0, &probs), 0.0);
        // ... and everything on an A30 (cap(1g.6gb) of the empty part = 4).
        assert!((m.ecc(GpuModel::A30, 0, &probs) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn mixed_window_counts_per_model() {
        let mut m = Mecc::new(24);
        let mut dc = DataCenter::new(vec![
            Host::with_models(0, 64, 256, &[GpuModel::A100_40, GpuModel::A30]),
        ]);
        let k_a30 = GpuModel::A30.profile(1); // 2g.12gb
        let out = batch_at(
            &mut m,
            &mut dc,
            &[vm(1, Profile::P2g10gb), vm(2, k_a30)],
            HOUR,
        );
        assert!(out.iter().all(|d| d.is_placed()));
        assert_eq!(m.counts[Profile::P2g10gb.dense()], 1);
        assert_eq!(m.counts[k_a30.dense()], 1);
        // The A30 VM landed on the A30, the A100 VM on the A100.
        assert_eq!(dc.locate(2).unwrap().gpu.gpu, 1);
        assert_eq!(dc.locate(1).unwrap().gpu.gpu, 0);
    }

    #[test]
    fn scoring_is_local_to_the_chosen_gpu() {
        // GetECC (like GetCC) scores only the GPU that receives the GI, so
        // even a 7g-heavy prior cannot make MECC "protect" other GPUs:
        // the second small VM lands on the fresh GPU whose post-allocation
        // expected capacity is higher. This locality is exactly why MECC
        // tracks MCC so closely in §8.3.1.
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let mut m = Mecc::new(24);
        // Seed a 7g-dominated window (placements may be rejected; the
        // observation still counts).
        let heavy: Vec<VmSpec> = (10..30).map(|i| vm(i, Profile::P7g40gb)).collect();
        batch_at(&mut m, &mut dc, &heavy, HOUR);
        let placed: Vec<u64> = (10..30).filter(|i| dc.locate(*i).is_some()).collect();
        for id in placed {
            dc.remove(id);
        }
        assert!((m.probabilities()[Profile::P7g40gb.dense()]) > 0.9);
        let out = batch_at(
            &mut m,
            &mut dc,
            &[vm(1, Profile::P1g5gb), vm(2, Profile::P1g5gb)],
            2 * HOUR,
        );
        assert!(out.iter().all(|d| d.is_placed()));
        assert_ne!(dc.locate(1).unwrap().gpu, dc.locate(2).unwrap().gpu);
    }

    #[test]
    fn behaves_like_mcc_under_uniform_prior_for_acceptance() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let mut m = Mecc::new(24);
        let out =
            batch_at(&mut m, &mut dc, &[vm(1, Profile::P7g40gb), vm(2, Profile::P1g5gb)], 0);
        assert!(out[0].is_placed());
        assert!(!out[1].is_placed());
    }
}
