//! Max Expected Configuration Capacity (MECC, Algorithm 7): like MCC but
//! each profile's feasible-start count is weighted by the probability of
//! that profile appearing, estimated from an `n`-hour trailing window of
//! requested profiles (the paper picks n = 24 h, the lowest-error
//! look-back among {1, 12, 24, 48, 96}).

use super::{reject_cluster, visit_candidates, Decision, Policy, PolicyCtx};
use crate::cluster::vm::{Time, VmSpec};
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::gpu::profile_capacity;
use crate::mig::placement::mock_assign;
use crate::mig::profiles::ALL_PROFILES;
use std::collections::VecDeque;

/// MECC placement.
pub struct Mecc {
    use_index: bool,
    /// Look-back window (hours).
    window_hours: u64,
    /// Requested profiles with timestamps, pruned to the window.
    history: VecDeque<(Time, usize)>,
    /// Current per-profile counts within the window.
    counts: [u64; 6],
}

impl Mecc {
    pub fn new(window_hours: u64) -> Mecc {
        Mecc::with_index(window_hours, true)
    }

    /// `use_index = false` restores the brute-force full scan.
    pub fn with_index(window_hours: u64, use_index: bool) -> Mecc {
        Mecc { use_index, window_hours, history: VecDeque::new(), counts: [0; 6] }
    }

    /// Profile probabilities from the window; uniform when empty.
    pub fn probabilities(&self) -> [f64; 6] {
        let total: u64 = self.counts.iter().sum();
        if total == 0 {
            return [1.0 / 6.0; 6];
        }
        let mut p = [0.0; 6];
        for i in 0..6 {
            p[i] = self.counts[i] as f64 / total as f64;
        }
        p
    }

    /// GetECC (Algorithm 7): probability-weighted feasible-start count.
    pub fn ecc(&self, occ: u8, probs: &[f64; 6]) -> f64 {
        let cap = profile_capacity(occ);
        let mut e = 0.0;
        for i in 0..6 {
            e += probs[i] * cap[i] as f64;
        }
        e
    }

    fn observe(&mut self, vms: &[VmSpec], now: Time) {
        for vm in vms {
            let idx = vm.profile.index();
            self.history.push_back((now, idx));
            self.counts[idx] += 1;
        }
        let horizon = now.saturating_sub(self.window_hours * crate::cluster::vm::HOUR);
        while let Some(&(t, idx)) = self.history.front() {
            if t >= horizon {
                break;
            }
            self.history.pop_front();
            self.counts[idx] -= 1;
        }
    }

    /// Most probable profile in the current window (used by the paper's
    /// look-back error analysis).
    pub fn predicted_profile(&self) -> crate::mig::Profile {
        let probs = self.probabilities();
        let mut best = 0usize;
        for i in 1..6 {
            if probs[i] > probs[best] {
                best = i;
            }
        }
        ALL_PROFILES[best]
    }
}

impl Policy for Mecc {
    fn name(&self) -> &str {
        "MECC"
    }

    fn place_batch(
        &mut self,
        dc: &mut DataCenter,
        vms: &[VmSpec],
        ctx: &mut PolicyCtx,
    ) -> Vec<Decision> {
        // The window reflects requests seen up to and including this batch.
        self.observe(vms, ctx.now);
        let probs = self.probabilities();
        // The probabilities are fixed for the whole batch, so ECC is a
        // pure function of the 8-bit occupancy — precompute all 256
        // values once per batch (EXPERIMENTS.md §Perf iteration 4).
        let mut ecc_table = [0.0f64; 256];
        for (occ, slot) in ecc_table.iter_mut().enumerate() {
            *slot = self.ecc(occ as u8, &probs);
        }
        let use_index = self.use_index;
        vms.iter()
            .map(|vm| {
                if use_index && !dc.index().host_may_fit(vm.cpus, vm.ram_gb) {
                    return reject_cluster(dc, vm, use_index);
                }
                let mut best: Option<(f64, GpuRef, crate::mig::Placement)> = None;
                let mut skip_host: Option<u32> = None;
                visit_candidates(dc, vm.profile, use_index, |r| {
                    if skip_host == Some(r.host) {
                        return true;
                    }
                    if !dc.host(r.host).fits_resources(vm.cpus, vm.ram_gb) {
                        skip_host = Some(r.host);
                        return true;
                    }
                    if let Some((pl, new_occ)) = mock_assign(dc.gpu(r).occupancy(), vm.profile) {
                        let score = ecc_table[new_occ as usize];
                        if best.map(|(b, _, _)| score > b).unwrap_or(true) {
                            best = Some((score, r, pl));
                        }
                    }
                    true
                });
                match best {
                    Some((_, r, pl)) => {
                        dc.place(vm, r, pl);
                        Decision::Placed { gpu: r, placement: pl }
                    }
                    None => reject_cluster(dc, vm, use_index),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::vm::HOUR;
    use crate::cluster::Host;
    use crate::mig::Profile;

    fn vm(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 100, weight: 1.0 }
    }

    fn batch_at(m: &mut Mecc, dc: &mut DataCenter, vms: &[VmSpec], now: Time) -> Vec<Decision> {
        let mut ctx = PolicyCtx::default();
        ctx.now = now;
        m.place_batch(dc, vms, &mut ctx)
    }

    #[test]
    fn window_prunes_old_history() {
        let mut m = Mecc::new(24);
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 8)]);
        batch_at(&mut m, &mut dc, &[vm(1, Profile::P1g5gb)], HOUR);
        batch_at(&mut m, &mut dc, &[vm(2, Profile::P7g40gb)], 30 * HOUR);
        // After 30h, the 1g.5gb observation (at 1h) left the 24h window.
        assert_eq!(m.counts[Profile::P1g5gb.index()], 0);
        assert_eq!(m.counts[Profile::P7g40gb.index()], 1);
        assert_eq!(m.predicted_profile(), Profile::P7g40gb);
    }

    #[test]
    fn uniform_prior_when_no_history() {
        let m = Mecc::new(24);
        let p = m.probabilities();
        assert!(p.iter().all(|&x| (x - 1.0 / 6.0).abs() < 1e-12));
    }

    #[test]
    fn ecc_weighted_by_probabilities() {
        let m = Mecc::new(24);
        // All mass on 7g.40gb: ECC of the empty GPU = cap(7g) = 1.
        let mut probs = [0.0; 6];
        probs[Profile::P7g40gb.index()] = 1.0;
        assert!((m.ecc(0, &probs) - 1.0).abs() < 1e-12);
        // All mass on 1g.5gb: ECC of the empty GPU = 7.
        let mut probs = [0.0; 6];
        probs[Profile::P1g5gb.index()] = 1.0;
        assert!((m.ecc(0, &probs) - 7.0).abs() < 1e-12);
    }

    #[test]
    fn scoring_is_local_to_the_chosen_gpu() {
        // GetECC (like GetCC) scores only the GPU that receives the GI, so
        // even a 7g-heavy prior cannot make MECC "protect" other GPUs:
        // the second small VM lands on the fresh GPU whose post-allocation
        // expected capacity is higher. This locality is exactly why MECC
        // tracks MCC so closely in §8.3.1.
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let mut m = Mecc::new(24);
        // Seed a 7g-dominated window (placements may be rejected; the
        // observation still counts).
        let heavy: Vec<VmSpec> = (10..30).map(|i| vm(i, Profile::P7g40gb)).collect();
        batch_at(&mut m, &mut dc, &heavy, HOUR);
        let placed: Vec<u64> = (10..30).filter(|i| dc.locate(*i).is_some()).collect();
        for id in placed {
            dc.remove(id);
        }
        assert!((m.probabilities()[Profile::P7g40gb.index()]) > 0.9);
        let out = batch_at(
            &mut m,
            &mut dc,
            &[vm(1, Profile::P1g5gb), vm(2, Profile::P1g5gb)],
            2 * HOUR,
        );
        assert!(out.iter().all(|d| d.is_placed()));
        assert_ne!(dc.locate(1).unwrap().gpu, dc.locate(2).unwrap().gpu);
    }

    #[test]
    fn behaves_like_mcc_under_uniform_prior_for_acceptance() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let mut m = Mecc::new(24);
        let out =
            batch_at(&mut m, &mut dc, &[vm(1, Profile::P7g40gb), vm(2, Profile::P1g5gb)], 0);
        assert!(out[0].is_placed());
        assert!(!out[1].is_placed());
    }
}
