//! VM placement policies (§8.3).
//!
//! All policies operate at the paper's *upper* placement level: they pick
//! the host/GPU for each VM. The *lower* level — which blocks a GI lands
//! on within the chosen GPU — is always NVIDIA's fixed default policy
//! ([`crate::mig::placement::assign`]), which cannot be overridden on real
//! hardware.
//!
//! * [`first_fit`] — FF: first GPU in `globalIndex` order that fits.
//! * [`best_fit`] — BF: GPU minimizing remaining free blocks.
//! * [`mcc`] — Max Configuration Capacity (Algorithm 6).
//! * [`mecc`] — Max *Expected* CC (Algorithm 7) with an n-hour
//!   profile-frequency window.
//! * [`grmu`] — the paper's contribution: dual-basket pooling,
//!   defragmentation and consolidation (Algorithms 2–5).

pub mod best_fit;
pub mod first_fit;
pub mod grmu;
pub mod mcc;
pub mod mecc;

use crate::cluster::vm::{Time, VmId, VmSpec};
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::placement::mock_assign;

/// A VM placement policy driven by the simulation engine. `Send` so the
/// coordinator can own a policy on its service thread.
pub trait Policy: Send {
    /// Short name used in reports ("FF", "GRMU", ...).
    fn name(&self) -> &str;

    /// Decide placement for a batch of VMs that arrived in the current
    /// interval. Returns one accept/reject decision per VM, in order.
    /// Accepted VMs must have been placed into `dc`.
    fn place_batch(&mut self, dc: &mut DataCenter, vms: &[VmSpec], now: Time) -> Vec<bool>;

    /// Called after a VM departed (its resources are already released).
    fn on_departure(&mut self, _dc: &mut DataCenter, _vm: VmId) {}

    /// Periodic maintenance hook (once per simulated hour).
    fn on_tick(&mut self, _dc: &mut DataCenter, _now: Time) {}

    /// Intra-GPU relocations performed so far (defragmentation).
    fn intra_migrations(&self) -> u64 {
        0
    }

    /// Inter-GPU migrations performed so far (consolidation).
    fn inter_migrations(&self) -> u64 {
        0
    }
}

/// Try to place `vm` on the specific GPU: host CPU/RAM must fit (Eq. 6–7)
/// and the GI must fit under the default block placement. Returns success.
pub fn try_place_on_gpu(dc: &mut DataCenter, vm: &VmSpec, r: GpuRef) -> bool {
    if !dc.host(r.host).fits_resources(vm.cpus, vm.ram_gb) {
        return false;
    }
    match mock_assign(dc.gpu(r).occupancy(), vm.profile) {
        Some((placement, _)) => {
            dc.place(vm, r, placement);
            true
        }
        None => false,
    }
}

/// Construct a policy by name (CLI / figure harness entry point).
/// `heavy_frac` and `consolidation_hours` configure GRMU only.
pub fn by_name(
    name: &str,
    heavy_frac: f64,
    consolidation_hours: Option<u64>,
) -> Option<Box<dyn Policy>> {
    match name.to_ascii_lowercase().as_str() {
        "ff" | "first-fit" => Some(Box::new(first_fit::FirstFit::new())),
        "bf" | "best-fit" => Some(Box::new(best_fit::BestFit::new())),
        "mcc" => Some(Box::new(mcc::Mcc::new())),
        "mecc" => Some(Box::new(mecc::Mecc::new(24))),
        "grmu" => Some(Box::new(grmu::Grmu::new(grmu::GrmuConfig {
            heavy_capacity_frac: heavy_frac,
            consolidation_interval_hours: consolidation_hours,
            ..grmu::GrmuConfig::default()
        }))),
        "grmu-db" => Some(Box::new(grmu::Grmu::new(grmu::GrmuConfig {
            heavy_capacity_frac: heavy_frac,
            consolidation_interval_hours: None,
            defrag_enabled: false,
        }))),
        _ => None,
    }
}

/// Names accepted by [`by_name`], for CLI help and sweeps.
pub const POLICY_NAMES: [&str; 5] = ["ff", "bf", "mcc", "mecc", "grmu"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::Profile;

    fn vm(id: VmId, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 4, ram_gb: 8, arrival: 0, departure: 1000, weight: 1.0 }
    }

    #[test]
    fn try_place_respects_cpu() {
        let mut dc = DataCenter::new(vec![Host::new(0, 3, 256, 1)]);
        assert!(!try_place_on_gpu(&mut dc, &vm(1, Profile::P1g5gb), GpuRef { host: 0, gpu: 0 }));
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        assert!(try_place_on_gpu(&mut dc, &vm(1, Profile::P1g5gb), GpuRef { host: 0, gpu: 0 }));
    }

    #[test]
    fn by_name_constructs_all() {
        for n in POLICY_NAMES {
            assert!(by_name(n, 0.3, None).is_some(), "{n}");
        }
        assert!(by_name("nope", 0.3, None).is_none());
    }
}
