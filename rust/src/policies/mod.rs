//! VM placement policies (§8.3) and the typed decision API they speak.
//!
//! All policies operate at the paper's *upper* placement level: they pick
//! the host/GPU for each VM. The *lower* level — which blocks a GI lands
//! on within the chosen GPU — is always NVIDIA's fixed default policy
//! ([`crate::mig::placement::assign`]), which cannot be overridden on real
//! hardware.
//!
//! ## The decision API
//!
//! A policy answers every request with a [`Decision`]: either
//! [`Decision::Placed`] carrying the chosen [`GpuRef`] and the exact
//! [`Placement`] the GI received, or [`Decision::Rejected`] carrying a
//! [`RejectReason`] that distinguishes CPU exhaustion, RAM exhaustion,
//! fragmentation (no fitting GI anywhere) and GRMU's basket-quota denial.
//! Migrations performed by a policy (defragmentation, consolidation) are
//! planned and applied through the policy-agnostic [`crate::migrate`]
//! layer, recorded as first-class [`MigrationEvent`]s and drained by the
//! engine via [`Policy::drain_migrations_into`] — the evaluation's
//! per-reason rejection breakdown and block-weighted migration-cost
//! accounting (Eq. 3–26) fall out of these records instead of opaque
//! booleans and counters.
//!
//! Policies receive a [`PolicyCtx`] with the batch: the virtual decision
//! time, a per-run seeded RNG for randomized policies, the shared
//! [`CcScorer`] backend (native table lookups or the AOT-compiled XLA
//! artifact), and the reusable [`DecisionBuffer`] that the
//! allocation-free [`Policy::place_batch_into`] entry point writes into
//! (the `Vec`-returning [`Policy::place_batch`] is a compat wrapper
//! around it).
//!
//! ## The policies
//!
//! * [`first_fit`] — FF: first GPU in `globalIndex` order that fits.
//! * [`best_fit`] — BF: GPU minimizing remaining free blocks.
//! * [`mcc`] — Max Configuration Capacity (Algorithm 6).
//! * [`mecc`] — Max *Expected* CC (Algorithm 7) with an n-hour
//!   profile-frequency window.
//! * [`grmu`] — the paper's contribution: dual-basket pooling,
//!   defragmentation and consolidation (Algorithms 2–5).
//!
//! Construction goes through the [`PolicyRegistry`], which advertises
//! every variant (including `grmu-db`, the dual-basket-only ablation,
//! and the composed `base+planner` migration variants — `mcc+defrag`,
//! `bf+consolidate`, ... — built on [`Planned`]) and reports unknown
//! names with the accepted list.
//!
//! ## Candidate iteration and the cluster index
//!
//! Policies no longer scan `gpu_refs()` vectors: candidates come from
//! the [`crate::cluster::ClusterIndex`] feasibility buckets (via
//! [`visit_candidates`]), and cluster-wide rejection classification from
//! the host headroom index ([`classify_rejection_cluster`]). Bucket
//! iteration follows ascending [`GpuRef`] order — the paper's
//! `globalIndex` — so indexed decisions are byte-identical to the
//! pre-index full scans; `PolicyConfig::use_index(false)` rebuilds the
//! full-scan variants as the brute-force reference.

pub mod best_fit;
pub mod first_fit;
pub mod grmu;
pub mod mcc;
pub mod mecc;
pub mod planned;

use crate::cluster::vm::{Time, VmId, VmSpec};
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::gpu::cc_for;
use crate::mig::placement::mock_assign;
use crate::mig::{GpuModel, Placement, Profile};
use crate::migrate::MigrationBudget;
use crate::util::rng::Rng;
use std::fmt;

// Migration events moved to the policy-agnostic `migrate` layer; the
// historical import path stays valid.
pub use crate::migrate::{MigrationEvent, MigrationKind};
pub use planned::{Planned, PLANNER_NAMES};

/// Why a request was rejected. The taxonomy mirrors the admission
/// constraints of the model: host resources (Eq. 6–7), GI feasibility
/// under the default placement (Alg. 1), and GRMU's basket quotas
/// (Alg. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RejectReason {
    /// No host had enough free CPU cores (Eq. 6).
    CpuExhausted,
    /// No host had enough free RAM (Eq. 7).
    RamExhausted,
    /// Some host had CPU/RAM headroom but no GPU could fit the GI —
    /// the fragmentation case the paper's defragmentation targets.
    NoGpuFit,
    /// GRMU only: the responsible basket is at its quota and may not
    /// grow, although the pool could otherwise serve the request.
    QuotaDenied,
    /// Not placeable right now; parked in the bounded admission retry
    /// queue ([`crate::ops::AdmissionQueue`]). A queued request that
    /// later lands flips this count back into an acceptance; one whose
    /// TTL lapses becomes [`RejectReason::Expired`].
    Queued,
    /// Spent its retry-queue TTL without ever fitting — the terminal
    /// fate of a queued request.
    Expired,
}

impl RejectReason {
    /// All reasons, in [`RejectReason::index`] order.
    pub const ALL: [RejectReason; 6] = [
        RejectReason::CpuExhausted,
        RejectReason::RamExhausted,
        RejectReason::NoGpuFit,
        RejectReason::QuotaDenied,
        RejectReason::Queued,
        RejectReason::Expired,
    ];

    /// Dense index for per-reason accounting arrays.
    pub fn index(self) -> usize {
        match self {
            RejectReason::CpuExhausted => 0,
            RejectReason::RamExhausted => 1,
            RejectReason::NoGpuFit => 2,
            RejectReason::QuotaDenied => 3,
            RejectReason::Queued => 4,
            RejectReason::Expired => 5,
        }
    }

    /// Stable name used in reports and JSON exports.
    pub fn name(self) -> &'static str {
        match self {
            RejectReason::CpuExhausted => "cpu_exhausted",
            RejectReason::RamExhausted => "ram_exhausted",
            RejectReason::NoGpuFit => "no_gpu_fit",
            RejectReason::QuotaDenied => "quota_denied",
            RejectReason::Queued => "queued",
            RejectReason::Expired => "expired",
        }
    }

    /// Would the admission queue retry this rejection? Resource and
    /// fragmentation shortages are transient (departures free capacity);
    /// a basket-quota denial is a policy decision the queue must not
    /// overturn, and the queue's own outcomes never re-enter it.
    pub fn retryable(self) -> bool {
        matches!(
            self,
            RejectReason::CpuExhausted | RejectReason::RamExhausted | RejectReason::NoGpuFit
        )
    }
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-reason rejection counters, indexed by [`RejectReason::index`].
pub type RejectCounts = [u64; 6];

/// Compact `name=count` summary of the non-zero rejection counters
/// (shared by the `simulate` and `serve` CLI outputs). Empty string
/// when nothing was rejected.
pub fn format_reject_counts(counts: &RejectCounts) -> String {
    RejectReason::ALL
        .iter()
        .filter(|r| counts[r.index()] > 0)
        .map(|r| format!("{}={}", r.name(), counts[r.index()]))
        .collect::<Vec<_>>()
        .join(" ")
}

/// One placement decision. `Placed` VMs have already been inserted into
/// the data center by the policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Accepted: the GI landed on `gpu` at `placement`.
    Placed { gpu: GpuRef, placement: Placement },
    /// Refused, with the binding constraint.
    Rejected(RejectReason),
}

impl Decision {
    pub fn is_placed(&self) -> bool {
        matches!(self, Decision::Placed { .. })
    }

    /// The hosting GPU when accepted.
    pub fn gpu(&self) -> Option<GpuRef> {
        match self {
            Decision::Placed { gpu, .. } => Some(*gpu),
            Decision::Rejected(_) => None,
        }
    }

    /// The rejection cause when refused.
    pub fn reject_reason(&self) -> Option<RejectReason> {
        match self {
            Decision::Placed { .. } => None,
            Decision::Rejected(r) => Some(*r),
        }
    }
}

/// Scoring backend for post-allocation CC evaluation (used by MCC). The
/// XLA backend ([`crate::runtime`], behind the `xla` feature) computes
/// the same scores via the AOT-compiled batched kernel; results are
/// bit-identical.
pub trait CcScorer: Send {
    /// CC of each candidate occupancy in `occs`, all of GPUs of `model`.
    /// (Candidates of one request always share a model: a GI only lands
    /// on GPUs of its own model, Eq. 17–18.)
    fn score(&mut self, model: GpuModel, occs: &[u8]) -> Vec<u32>;

    /// Allocation-free variant: append the scores to a caller-owned
    /// buffer (the policies' reusable scratch). Backends without a
    /// native append path fall back to [`CcScorer::score`].
    fn score_into(&mut self, model: GpuModel, occs: &[u8], out: &mut Vec<u32>) {
        out.extend(self.score(model, occs));
    }
}

/// Native table-lookup scorer (the default).
#[derive(Debug, Default)]
pub struct NativeScorer;

impl CcScorer for NativeScorer {
    fn score(&mut self, model: GpuModel, occs: &[u8]) -> Vec<u32> {
        let mut out = Vec::with_capacity(occs.len());
        self.score_into(model, occs, &mut out);
        out
    }

    fn score_into(&mut self, model: GpuModel, occs: &[u8], out: &mut Vec<u32>) {
        out.extend(occs.iter().map(|&o| cc_for(model, o)));
    }
}

/// Reusable [`Decision`] output buffer, owned by the [`PolicyCtx`] and
/// written by [`Policy::place_batch_into`]. One allocation per run
/// (amortized) instead of one `Vec<Decision>` per batch: the buffer is
/// cleared at the start of every batch and holds that batch's decisions
/// — in request order, one per VM — until the next batch. Dereferences
/// to `[Decision]` for reading.
#[derive(Debug, Default)]
pub struct DecisionBuffer {
    buf: Vec<Decision>,
}

impl DecisionBuffer {
    pub fn new() -> DecisionBuffer {
        DecisionBuffer::default()
    }

    /// Start a batch of `n` decisions: clear and pre-size.
    pub fn begin(&mut self, n: usize) {
        self.buf.clear();
        self.buf.reserve(n);
    }

    /// Append the decision for the batch's next VM.
    #[inline]
    pub fn push(&mut self, d: Decision) {
        self.buf.push(d);
    }

    /// The current batch's decisions, in request order.
    #[inline]
    pub fn as_slice(&self) -> &[Decision] {
        &self.buf
    }

    /// Copy out as an owned `Vec` (the compat path).
    pub fn to_vec(&self) -> Vec<Decision> {
        self.buf.clone()
    }

    /// Rewrite the decision at `i` in the current batch. Used by the
    /// admission queue: a retryable rejection is parked and its buffered
    /// decision overwritten with [`Decision::Rejected`]
    /// ([`RejectReason::Queued`]) so the stream the caller sees matches
    /// the accounting.
    #[inline]
    pub fn replace(&mut self, i: usize, d: Decision) {
        self.buf[i] = d;
    }
}

impl std::ops::Deref for DecisionBuffer {
    type Target = [Decision];

    fn deref(&self) -> &[Decision] {
        &self.buf
    }
}

/// Per-run context handed to every policy hook: the virtual clock, a
/// deterministic RNG split from the experiment seed, and the shared CC
/// scoring backend. Owned by the event core ([`crate::sim::EventCore`]),
/// which advances `now` to the end of the interval being decided.
pub struct PolicyCtx {
    /// Virtual decision time (end of the current interval).
    pub now: Time,
    /// Seeded per-run generator for randomized policies.
    pub rng: Rng,
    /// CC scoring backend (native table or AOT/XLA artifact).
    pub scorer: Box<dyn CcScorer>,
    /// Reusable decision output buffer written by
    /// [`Policy::place_batch_into`]; holds the latest batch's decisions.
    pub decisions: DecisionBuffer,
}

impl PolicyCtx {
    pub fn new(seed: u64) -> PolicyCtx {
        PolicyCtx::with_scorer(seed, Box::new(NativeScorer))
    }

    /// Context scoring through a custom backend (e.g. the XLA artifact).
    pub fn with_scorer(seed: u64, scorer: Box<dyn CcScorer>) -> PolicyCtx {
        PolicyCtx { now: 0, rng: Rng::new(seed), scorer, decisions: DecisionBuffer::new() }
    }
}

impl Default for PolicyCtx {
    fn default() -> Self {
        PolicyCtx::new(0)
    }
}

/// A VM placement policy driven by the event core. `Send` so the
/// coordinator can own a policy on its service thread.
///
/// The required entry point is the allocation-free
/// [`Policy::place_batch_into`], which writes one [`Decision`] per VM
/// into the [`PolicyCtx`]'s [`DecisionBuffer`]; the `Vec`-returning
/// [`Policy::place_batch`] is a provided compat wrapper around it.
///
/// Migration note: before the decision API, `place_batch` returned
/// `Vec<bool>` and migrations were exposed as two cumulative counters
/// (`intra_migrations`/`inter_migrations`). Decisions now carry the
/// chosen GPU or the [`RejectReason`], and migrations are drained as
/// [`MigrationEvent`] records via [`Policy::drain_migrations_into`] /
/// [`Policy::take_migrations`].
pub trait Policy: Send {
    /// Short name used in reports ("FF", "GRMU", ...).
    fn name(&self) -> &str;

    /// Decide placement for a batch of VMs that arrived in the current
    /// interval. Returns one [`Decision`] per VM, in order. Placed VMs
    /// must have been inserted into `dc`.
    fn place_batch(
        &mut self,
        dc: &mut DataCenter,
        vms: &[VmSpec],
        ctx: &mut PolicyCtx,
    ) -> Vec<Decision> {
        self.place_batch_into(dc, vms, ctx);
        ctx.decisions.to_vec()
    }

    /// Allocation-free [`Policy::place_batch`]: write one [`Decision`]
    /// per VM, in order, into `ctx.decisions` (calling
    /// [`DecisionBuffer::begin`] first). The buffer's contents stay
    /// valid until the next batch. This is the required method —
    /// keeping it abstract (rather than defaulting it to `place_batch`
    /// and vice versa) makes "implemented neither" a compile error
    /// instead of runtime infinite recursion.
    fn place_batch_into(&mut self, dc: &mut DataCenter, vms: &[VmSpec], ctx: &mut PolicyCtx);

    /// Called after a VM departed (its resources are already released).
    fn on_departure(&mut self, _dc: &mut DataCenter, _vm: VmId, _ctx: &mut PolicyCtx) {}

    /// Periodic maintenance hook, fired once per interval at `ctx.now`.
    fn on_tick(&mut self, _dc: &mut DataCenter, _ctx: &mut PolicyCtx) {}

    /// Drain the migrations performed since the last call, appending to
    /// a caller-owned buffer. The event core collects these after every
    /// batch and tick; this is the required shape of the drain — the
    /// default no-op serves the policies that never migrate without
    /// allocating, and migrating policies override it with
    /// `out.append(..)` so their internal buffer's capacity is retained
    /// across drains.
    fn drain_migrations_into(&mut self, _out: &mut Vec<MigrationEvent>) {}

    /// Compat wrapper over [`Policy::drain_migrations_into`] returning an
    /// owned `Vec` (one allocation per call; the buffered drain is the
    /// hot path). The delegation used to run the other way — `take` was
    /// the primitive and the buffered drain copied through it, costing a
    /// `Vec` per interval even for migration-free policies.
    ///
    /// **Migration note:** overriding `take_migrations` no longer feeds
    /// the engine — [`crate::sim::EventCore`] drains exclusively through
    /// [`Policy::drain_migrations_into`]. A policy written against the
    /// pre-inversion contract must move its override to the buffered
    /// drain (`out.append(&mut self.events)`).
    fn take_migrations(&mut self) -> Vec<MigrationEvent> {
        let mut out = Vec::new();
        self.drain_migrations_into(&mut out);
        out
    }

    /// Drain the optimality-gap samples (percent, one per sampled
    /// interval) recorded since the last call. Only the
    /// [`crate::ilp::online::GapMeter`] wrapper produces any; the
    /// default no-op serves everyone else. Wrappers ([`Planned`])
    /// forward so the meter is reachable wherever it sits in the
    /// composition.
    fn drain_gap_samples_into(&mut self, _out: &mut Vec<f64>) {}

    /// Serialize this policy's internal decision-relevant state for the
    /// crash-safe snapshot layer (`crate::recover`), appending
    /// [`crate::util::codec`]-encoded bytes to `out`. Stateless
    /// policies (FF/BF/MCC) keep the default no-op — an empty state.
    /// Stateful policies (MECC windows, GRMU baskets, planner wrappers)
    /// must write everything that influences future decisions;
    /// recomputable caches are elided and rebuilt on the next batch.
    fn snapshot_state(&self, _out: &mut Vec<u8>) {}

    /// Restore state captured by [`Policy::snapshot_state`] into a
    /// freshly built policy of the same registry name and
    /// configuration. The default accepts only an empty state (what the
    /// default `snapshot_state` produces) — a non-empty payload landing
    /// on a stateless policy means a name/config mismatch and is an
    /// error, never a silent drop.
    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        if bytes.is_empty() {
            Ok(())
        } else {
            Err(format!("policy {} carries no restorable state", self.name()))
        }
    }
}

/// Visit placement candidates for `profile` in `globalIndex` order,
/// until the visitor returns `false`. Only GPUs of the profile's model
/// are candidates (the Eq. 17–18 compatibility constraint).
///
/// With `use_index` the walk covers only the
/// [`crate::cluster::ClusterIndex`] bucket — exactly the GPUs where the
/// profile currently fits; the full scan covers every model-compatible
/// GPU. Both orders are ascending
/// [`GpuRef`], and the bucket is the feasible subsequence of the full
/// scan, so any first-match or best-scoring selection over the
/// candidates is byte-identical between the two modes (the
/// indexed-vs-scan equivalence tests in `rust/tests/decision_api.rs`
/// lock this). The scan mode is retained as the brute-force reference
/// for those tests and the `benches/cluster_index.rs` comparison.
pub fn visit_candidates(
    dc: &DataCenter,
    profile: Profile,
    use_index: bool,
    mut visit: impl FnMut(GpuRef) -> bool,
) {
    if use_index {
        for r in dc.index().gpus_fitting(profile) {
            if !visit(r) {
                return;
            }
        }
    } else {
        let model = profile.model();
        for h in dc.hosts() {
            for (g, gpu) in h.gpus().iter().enumerate() {
                if gpu.model() != model || !h.gpu_available(g) {
                    continue;
                }
                if !visit(GpuRef { host: h.id, gpu: g as u8 }) {
                    return;
                }
            }
        }
    }
}

/// Probe one GPU without mutating anything: the GPU must be of the
/// request's model (Eq. 17–18), the host must have the CPU/RAM
/// (Eq. 6–7) and the GI must fit under the default block placement. The
/// non-committing core of [`try_place_on_gpu`], shared by the first-fit
/// scan paths (FF and GRMU's basket/pool walks).
pub fn probe_gpu(dc: &DataCenter, vm: &VmSpec, r: GpuRef) -> Option<Placement> {
    if !dc.gpu_available(r) {
        return None;
    }
    let gpu = dc.gpu(r);
    if gpu.model() != vm.profile.model() || !dc.host(r.host).fits_resources(vm.cpus, vm.ram_gb) {
        return None;
    }
    mock_assign(gpu.occupancy(), vm.profile).map(|(placement, _)| placement)
}

/// [`probe_gpu`], then commit: on success the VM is inserted into `dc`
/// and the chosen placement returned.
pub fn try_place_on_gpu(dc: &mut DataCenter, vm: &VmSpec, r: GpuRef) -> Option<Placement> {
    let placement = probe_gpu(dc, vm, r)?;
    dc.place(vm, r, placement);
    Some(placement)
}

/// Classify why `vm` fit on none of `refs` (called by policies after an
/// unsuccessful scan). Only GPUs of the request's model count as
/// candidates (Eq. 17–18) — a host whose only headroom sits next to
/// foreign-model GPUs cannot serve the VM, so it must not steer the
/// reason. Precedence: if any compatible candidate's host has CPU *and*
/// RAM headroom the blocker was GI fragmentation ([`RejectReason::
/// NoGpuFit`]); otherwise CPU shortage wins over RAM shortage, matching
/// the constraint order of the model (Eq. 6 before Eq. 7); an all-
/// foreign (or empty) candidate set is a no-compatible-GPU case, i.e.
/// [`RejectReason::NoGpuFit`].
pub fn classify_rejection<'a, I>(dc: &DataCenter, vm: &VmSpec, refs: I) -> RejectReason
where
    I: IntoIterator<Item = &'a GpuRef>,
{
    let model = vm.profile.model();
    let mut cpu_short = false;
    let mut ram_short = false;
    let mut resource_fit = false;
    for &r in refs {
        if dc.gpu(r).model() != model || !dc.gpu_available(r) {
            continue;
        }
        let host = dc.host(r.host);
        let cpu_ok = host.free_cpus() >= vm.cpus;
        let ram_ok = host.free_ram() >= vm.ram_gb;
        if cpu_ok && ram_ok {
            // Resources fit here, yet the scan failed — the GI was the
            // binding constraint somewhere, i.e. fragmentation.
            resource_fit = true;
        } else {
            cpu_short |= !cpu_ok;
            ram_short |= !ram_ok;
        }
    }
    if resource_fit {
        RejectReason::NoGpuFit
    } else if cpu_short {
        RejectReason::CpuExhausted
    } else if ram_short {
        RejectReason::RamExhausted
    } else {
        // No compatible candidate GPU at all (empty basket/cluster, or
        // a fleet without the request's model).
        RejectReason::NoGpuFit
    }
}

/// Cluster-wide [`classify_rejection`] over every GPU-equipped host,
/// answered from the host headroom index when the maxima alone decide
/// (no host anywhere has the CPU, or the RAM) and by a single host scan
/// otherwise.
///
/// Byte-identical to `classify_rejection(dc, vm, &dc.gpu_refs())`: that
/// scan evaluated the same three per-host existentials, just once per
/// GPU instead of once per host, and hosts without GPUs appear in
/// neither walk.
pub fn classify_rejection_cluster(dc: &DataCenter, vm: &VmSpec) -> RejectReason {
    let idx = dc.index();
    let model = vm.profile.model();
    // The index-answered fast paths hold only on a fully healthy fleet:
    // with capacity offline, `hosts_with_model` counts hosts whose last
    // model-compatible GPU may be down (the count tracks host
    // availability only), so the reference walk over schedulable GPUs
    // could see an empty candidate set where the maxima-based shortcuts
    // still claim a resource verdict. Degraded fleets take the (already
    // rare, rejection-only) host scan directly.
    if dc.offline_gpus() == 0 {
        let compat_hosts = idx.hosts_with_model(model);
        if compat_hosts == 0 {
            // Empty cluster, or a fleet without the request's model — same
            // no-compatible-GPU convention as an empty candidate set.
            return RejectReason::NoGpuFit;
        }
        if idx.max_free_cpus() < vm.cpus {
            // Every host (compatible ones included) is CPU-short, so nothing
            // can have joint headroom.
            return RejectReason::CpuExhausted;
        }
        if compat_hosts == idx.num_hosts() && idx.max_free_ram() < vm.ram_gb {
            // Homogeneous-for-this-model fleet and no host has the RAM; a
            // CPU shortage anywhere still takes precedence (Eq. 6 before
            // Eq. 7). (On a mixed fleet the cluster-wide minima may belong
            // to foreign-model hosts, so fall through to the host scan.)
            return if idx.min_free_cpus() < vm.cpus {
                RejectReason::CpuExhausted
            } else {
                RejectReason::RamExhausted
            };
        }
    }
    // Some host has the CPU and some host has the RAM — whether one
    // *compatible* host has both takes a scan (hosts, not GPUs). Only
    // schedulable GPUs make a host compatible; on an all-healthy fleet
    // the availability checks are vacuous, keeping this byte-identical
    // to the pre-health scan.
    let mut cpu_short = false;
    let mut ram_short = false;
    for host in dc.hosts() {
        if !host.gpus().iter().enumerate().any(|(g, gpu)| {
            gpu.model() == model && host.gpu_available(g)
        }) {
            continue;
        }
        let cpu_ok = host.free_cpus() >= vm.cpus;
        let ram_ok = host.free_ram() >= vm.ram_gb;
        if cpu_ok && ram_ok {
            return RejectReason::NoGpuFit;
        }
        cpu_short |= !cpu_ok;
        ram_short |= !ram_ok;
    }
    if cpu_short {
        RejectReason::CpuExhausted
    } else if ram_short {
        RejectReason::RamExhausted
    } else {
        RejectReason::NoGpuFit
    }
}

/// Shared rejection path for the cluster-scanning policies. In indexed
/// mode the reason comes from [`classify_rejection_cluster`]; in scan
/// mode from the original full-GPU-ref walk, so the brute-force
/// reference stays fully index-free.
pub(crate) fn reject_cluster(dc: &DataCenter, vm: &VmSpec, use_index: bool) -> Decision {
    let reason = if use_index {
        classify_rejection_cluster(dc, vm)
    } else {
        let refs = dc.gpu_refs();
        classify_rejection(dc, vm, &refs)
    };
    Decision::Rejected(reason)
}

/// Builder-style configuration consumed by the [`PolicyRegistry`].
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// GRMU heavy-basket share of all GPUs (paper knee: 0.30).
    pub heavy_frac: f64,
    /// GRMU consolidation period; `None` disables it.
    pub consolidation_hours: Option<u64>,
    /// MECC profile-frequency look-back window (paper pick: 24 h).
    pub mecc_window_hours: u64,
    /// Query the [`crate::cluster::ClusterIndex`] for placement
    /// candidates (the default). `false` restores the brute-force full
    /// scan — decision-identical, kept as the equivalence-test and
    /// benchmark reference.
    pub use_index: bool,
    /// Extra migration planners appended to whatever the policy name
    /// selects (CLI `--planners defrag,consolidate`); see
    /// [`PLANNER_NAMES`]. Empty by default.
    pub planners: Vec<String>,
    /// Migration budget for planner stacks — composed `base+planner`
    /// variants *and* GRMU's internal stack. Unlimited by default (the
    /// paper's configuration).
    pub migration_budget: MigrationBudget,
    /// Mean-fragmentation trigger for the `frag-gradient` planner.
    pub frag_threshold: f64,
    /// `ilp-repair` planner: most-fragmented GPUs per model in the
    /// extraction window ([`crate::ilp::online::RollingIlp`]). `0`
    /// disables the planner (byte-identical to not composing it).
    pub ilp_window: usize,
    /// `ilp-repair` planner: branch-and-bound node budget per solver
    /// stage. `0` disables the planner.
    pub ilp_nodes: usize,
    /// `ilp-repair` planner: tick cadence in hours (rejection bursts
    /// plan regardless of the cadence).
    pub ilp_period_hours: u64,
    /// Optimality-gap sampling cadence in hours
    /// ([`crate::ilp::online::GapMeter`]); `0` (the default) disables
    /// gap metering entirely — the built policy is the unwrapped one.
    pub gap_check_hours: u64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            heavy_frac: 0.30,
            consolidation_hours: None,
            mecc_window_hours: 24,
            use_index: true,
            planners: Vec::new(),
            migration_budget: MigrationBudget::unlimited(),
            frag_threshold: 1.0,
            ilp_window: 8,
            ilp_nodes: 20_000,
            ilp_period_hours: 24,
            gap_check_hours: 0,
        }
    }
}

impl PolicyConfig {
    pub fn new() -> PolicyConfig {
        PolicyConfig::default()
    }

    pub fn heavy_frac(mut self, frac: f64) -> PolicyConfig {
        self.heavy_frac = frac;
        self
    }

    pub fn consolidation_hours(mut self, hours: Option<u64>) -> PolicyConfig {
        self.consolidation_hours = hours;
        self
    }

    pub fn mecc_window_hours(mut self, hours: u64) -> PolicyConfig {
        self.mecc_window_hours = hours;
        self
    }

    pub fn use_index(mut self, use_index: bool) -> PolicyConfig {
        self.use_index = use_index;
        self
    }

    /// Append migration planners (by [`PLANNER_NAMES`] name) to any
    /// policy this config builds.
    pub fn planners<I, S>(mut self, names: I) -> PolicyConfig
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.planners = names.into_iter().map(Into::into).collect();
        self
    }

    pub fn migration_budget(mut self, budget: MigrationBudget) -> PolicyConfig {
        self.migration_budget = budget;
        self
    }

    pub fn frag_threshold(mut self, threshold: f64) -> PolicyConfig {
        self.frag_threshold = threshold;
        self
    }

    pub fn ilp_window(mut self, window: usize) -> PolicyConfig {
        self.ilp_window = window;
        self
    }

    pub fn ilp_nodes(mut self, nodes: usize) -> PolicyConfig {
        self.ilp_nodes = nodes;
        self
    }

    pub fn ilp_period_hours(mut self, hours: u64) -> PolicyConfig {
        self.ilp_period_hours = hours;
        self
    }

    pub fn gap_check_hours(mut self, hours: u64) -> PolicyConfig {
        self.gap_check_hours = hours;
        self
    }
}

/// One registry row: canonical name, accepted aliases, one-line summary
/// and the constructor.
pub struct PolicyEntry {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub summary: &'static str,
    build: fn(&PolicyConfig) -> Box<dyn Policy>,
}

/// Error for a name the registry does not know; its `Display` lists the
/// accepted base names and the planner suffixes that compose with them.
/// When the base policy was valid but a `+suffix`/`--planners` entry was
/// not, `planner` names the actual offender.
#[derive(Debug, Clone)]
pub struct UnknownPolicy {
    pub requested: String,
    pub known: Vec<String>,
    /// The unknown planner name, when the base policy resolved fine.
    pub planner: Option<String>,
}

impl fmt::Display for UnknownPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.planner {
            Some(p) => write!(
                f,
                "unknown planner '{p}' in policy '{}'; known planners: {}",
                self.requested,
                PLANNER_NAMES.join(", "),
            ),
            None => write!(
                f,
                "unknown policy '{}'; known policies: {} (any base composes with +{})",
                self.requested,
                self.known.join(", "),
                PLANNER_NAMES.join(", +"),
            ),
        }
    }
}

impl std::error::Error for UnknownPolicy {}

/// The policy registry: every constructible variant, including `grmu-db`
/// (dual-basket only), with builder-style configuration. CLI, figure
/// harness, benches and examples all construct policies through it.
pub struct PolicyRegistry {
    entries: Vec<PolicyEntry>,
}

impl PolicyRegistry {
    /// The §8.3 five-policy comparison set (Figs. 10–12, Table 6).
    pub const COMPARISON: [&'static str; 5] = ["ff", "bf", "mcc", "mecc", "grmu"];

    /// The standard registry with all six variants.
    pub fn standard() -> PolicyRegistry {
        fn ff(cfg: &PolicyConfig) -> Box<dyn Policy> {
            Box::new(first_fit::FirstFit::with_index(cfg.use_index))
        }
        fn bf(cfg: &PolicyConfig) -> Box<dyn Policy> {
            Box::new(best_fit::BestFit::with_index(cfg.use_index))
        }
        fn build_mcc(cfg: &PolicyConfig) -> Box<dyn Policy> {
            Box::new(mcc::Mcc::with_index(cfg.use_index))
        }
        fn build_mecc(cfg: &PolicyConfig) -> Box<dyn Policy> {
            Box::new(mecc::Mecc::with_index(cfg.mecc_window_hours, cfg.use_index))
        }
        fn build_grmu(cfg: &PolicyConfig) -> Box<dyn Policy> {
            Box::new(grmu::Grmu::new(grmu::GrmuConfig {
                heavy_capacity_frac: cfg.heavy_frac,
                consolidation_interval_hours: cfg.consolidation_hours,
                defrag_enabled: true,
                use_index: cfg.use_index,
                migration_budget: cfg.migration_budget,
            }))
        }
        fn build_grmu_db(cfg: &PolicyConfig) -> Box<dyn Policy> {
            Box::new(grmu::Grmu::new(grmu::GrmuConfig {
                heavy_capacity_frac: cfg.heavy_frac,
                consolidation_interval_hours: None,
                defrag_enabled: false,
                use_index: cfg.use_index,
                migration_budget: cfg.migration_budget,
            }))
        }
        PolicyRegistry {
            entries: vec![
                PolicyEntry {
                    name: "ff",
                    aliases: &["first-fit"],
                    summary: "First-Fit: first GPU in globalIndex order that fits",
                    build: ff,
                },
                PolicyEntry {
                    name: "bf",
                    aliases: &["best-fit"],
                    summary: "Best-Fit: GPU minimizing remaining free blocks",
                    build: bf,
                },
                PolicyEntry {
                    name: "mcc",
                    aliases: &[],
                    summary: "Max Configuration Capacity (Algorithm 6)",
                    build: build_mcc,
                },
                PolicyEntry {
                    name: "mecc",
                    aliases: &[],
                    summary: "Max Expected CC with a trailing profile window (Algorithm 7)",
                    build: build_mecc,
                },
                PolicyEntry {
                    name: "grmu",
                    aliases: &[],
                    summary: "GRMU: dual-basket pooling + defrag + consolidation (Alg. 2-5)",
                    build: build_grmu,
                },
                PolicyEntry {
                    name: "grmu-db",
                    aliases: &[],
                    summary: "GRMU ablation: dual-basket pooling only (no defrag/consolidation)",
                    build: build_grmu_db,
                },
            ],
        }
    }

    /// All advertised canonical names: the base entries plus the
    /// composed `base+planner` migration variants of the non-GRMU §8.3
    /// comparison policies — every one of them constructible by
    /// [`PolicyRegistry::build`] (as is any other
    /// `base+planner[+planner..]` combination). GRMU is not advertised
    /// with suffixes: it already runs defrag/consolidation internally
    /// (light-basket scope), and stacking a second cluster-scoped copy —
    /// with its own independent budget — is rarely what a sweep means by
    /// `grmu+defrag`. It can still be built explicitly.
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.entries.iter().map(|e| e.name.to_string()).collect();
        for base in PolicyRegistry::COMPARISON.iter().filter(|&&b| b != "grmu") {
            for planner in ["defrag", "consolidate"] {
                names.push(format!("{base}+{planner}"));
            }
        }
        names
    }

    /// Registry rows (for CLI help listings).
    pub fn entries(&self) -> &[PolicyEntry] {
        &self.entries
    }

    /// Construct a policy by (case-insensitive) name or alias. Names may
    /// carry `+planner` suffixes (`mcc+defrag`, `bf+consolidate`,
    /// `ff+defrag+frag-gradient`, ...): the base policy is wrapped in a
    /// [`Planned`] composition running the named planners — in suffix
    /// order, followed by any `cfg.planners` — over the whole cluster
    /// under `cfg.migration_budget`.
    pub fn build(&self, name: &str, cfg: &PolicyConfig) -> Result<Box<dyn Policy>, UnknownPolicy> {
        let needle = name.to_ascii_lowercase();
        let mut parts = needle.split('+').map(str::trim);
        let base = parts.next().unwrap_or("");
        let entry = self
            .entries
            .iter()
            .find(|e| e.name == base || e.aliases.contains(&base))
            .ok_or_else(|| UnknownPolicy {
                requested: name.to_string(),
                known: self.names(),
                planner: None,
            })?;
        let mut policy = (entry.build)(cfg);
        let mut planner_names: Vec<String> = parts.map(str::to_string).collect();
        planner_names.extend(cfg.planners.iter().map(|p| p.trim().to_ascii_lowercase()));
        if !planner_names.is_empty() {
            let mut stack = crate::migrate::PlannerStack::new(cfg.migration_budget);
            for pn in &planner_names {
                let planner = planned::planner_from_name(pn, cfg).ok_or_else(|| UnknownPolicy {
                    requested: name.to_string(),
                    known: self.names(),
                    planner: Some(pn.clone()),
                })?;
                stack.push(planner);
            }
            policy = Box::new(Planned::new(policy, stack));
        }
        if cfg.gap_check_hours > 0 {
            policy = Box::new(crate::ilp::online::GapMeter::new(
                policy,
                cfg.gap_check_hours,
                cfg.ilp_window,
                cfg.ilp_nodes,
            ));
        }
        Ok(policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::Profile;

    fn vm(id: VmId, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 4, ram_gb: 8, arrival: 0, departure: 1000, weight: 1.0 }
    }

    #[test]
    fn try_place_respects_cpu() {
        let mut dc = DataCenter::new(vec![Host::new(0, 3, 256, 1)]);
        assert!(try_place_on_gpu(&mut dc, &vm(1, Profile::P1g5gb), GpuRef { host: 0, gpu: 0 })
            .is_none());
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        assert!(try_place_on_gpu(&mut dc, &vm(1, Profile::P1g5gb), GpuRef { host: 0, gpu: 0 })
            .is_some());
    }

    #[test]
    fn registry_constructs_all_advertised_names() {
        let registry = PolicyRegistry::standard();
        let cfg = PolicyConfig::new().heavy_frac(0.3);
        for n in registry.names() {
            assert!(registry.build(&n, &cfg).is_ok(), "{n}");
        }
        // Aliases and case-insensitivity.
        assert!(registry.build("First-Fit", &cfg).is_ok());
        assert!(registry.build("GRMU", &cfg).is_ok());
        assert!(registry.build("MCC+Defrag", &cfg).is_ok());
    }

    #[test]
    fn registry_advertises_grmu_db_and_composed_variants() {
        let registry = PolicyRegistry::standard();
        let names = registry.names();
        let has = |n: &str| names.iter().any(|x| x == n);
        assert!(has("grmu-db"));
        assert!(PolicyRegistry::COMPARISON.iter().all(|n| has(n)));
        // Acceptance criterion: the composed migration variants are
        // advertised for every non-GRMU §8.3 policy (GRMU migrates
        // through its own internal stack and is not double-advertised,
        // though explicit composition still builds).
        for base in ["ff", "bf", "mcc", "mecc"] {
            assert!(has(&format!("{base}+defrag")), "{base}+defrag");
            assert!(has(&format!("{base}+consolidate")), "{base}+consolidate");
        }
        assert!(!has("grmu+defrag"));
        assert!(PolicyRegistry::standard()
            .build("grmu+frag-gradient", &PolicyConfig::new())
            .is_ok());
    }

    #[test]
    fn composed_names_report_the_stack() {
        let registry = PolicyRegistry::standard();
        let cfg = PolicyConfig::new();
        let p = registry.build("mcc+defrag", &cfg).unwrap();
        assert_eq!(p.name(), "MCC+defrag");
        let p = registry.build("ff+defrag+consolidate", &cfg).unwrap();
        assert_eq!(p.name(), "FF+defrag+consolidate");
        // cfg.planners composes the same wrapper without a name suffix.
        let p = registry.build("bf", &cfg.clone().planners(["frag-gradient"])).unwrap();
        assert_eq!(p.name(), "BF+frag-gradient");
        // Unknown planner suffixes are rejected naming the offender (not
        // the perfectly valid base policy).
        let err = registry.build("mcc+nope", &cfg).unwrap_err();
        assert_eq!(err.planner.as_deref(), Some("nope"));
        assert!(err.to_string().contains("unknown planner 'nope'"), "{err}");
        let err = registry.build("ff", &cfg.clone().planners(["nope"])).unwrap_err();
        assert_eq!(err.planner.as_deref(), Some("nope"));
    }

    #[test]
    fn unknown_policy_error_lists_names() {
        let registry = PolicyRegistry::standard();
        let err = registry.build("nope", &PolicyConfig::new()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("nope"));
        for n in registry.names() {
            assert!(msg.contains(&n), "error should list {n}: {msg}");
        }
        // The planner suffixes are advertised too.
        for p in PLANNER_NAMES {
            assert!(msg.contains(p), "error should list planner {p}: {msg}");
        }
    }

    #[test]
    fn classify_cpu_vs_ram_vs_fragmentation() {
        // CPU short, RAM fine.
        let mut dc = DataCenter::new(vec![Host::new(0, 2, 256, 1)]);
        let refs = dc.gpu_refs();
        let v = vm(1, Profile::P1g5gb);
        assert_eq!(classify_rejection(&dc, &v, &refs), RejectReason::CpuExhausted);
        // RAM short, CPU fine.
        let dc2 = DataCenter::new(vec![Host::new(0, 64, 4, 1)]);
        assert_eq!(classify_rejection(&dc2, &v, &dc2.gpu_refs()), RejectReason::RamExhausted);
        // Resources fine but GPU full → fragmentation.
        let full = vm(9, Profile::P7g40gb);
        let r = GpuRef { host: 0, gpu: 0 };
        dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        assert!(try_place_on_gpu(&mut dc, &full, r).is_some());
        assert_eq!(classify_rejection(&dc, &v, &dc.gpu_refs()), RejectReason::NoGpuFit);
    }

    #[test]
    fn prop_cluster_classification_matches_full_ref_walk() {
        use crate::mig::ALL_MODELS;
        use crate::util::prop::forall;
        use crate::util::rng::Rng;
        // classify_rejection_cluster (headroom fast paths + host scan)
        // must agree with the original classify_rejection over every GPU
        // ref, for arbitrary host loads, fleet mixes and demands — and
        // for requests whose model may or may not exist in the fleet.
        forall(
            "classify-cluster-vs-refs",
            |r: &mut Rng| {
                let hosts = (0..1 + r.below(5))
                    .map(|i| {
                        let models: Vec<crate::mig::GpuModel> = (0..1 + r.below(3))
                            .map(|_| ALL_MODELS[r.below(ALL_MODELS.len() as u64) as usize])
                            .collect();
                        Host::with_models(
                            i as u32,
                            r.below(16) as u32,
                            r.below(64) as u32,
                            &models,
                        )
                    })
                    .collect();
                let dc = DataCenter::new(hosts);
                let model = ALL_MODELS[r.below(ALL_MODELS.len() as u64) as usize];
                let profile = model.profile(r.below(model.num_profiles() as u64) as usize);
                let demand = (r.below(16) as u32, r.below(64) as u32);
                (dc, profile, demand)
            },
            |(dc, profile, (cpus, ram_gb))| {
                let v = VmSpec {
                    id: 1,
                    profile: *profile,
                    cpus: *cpus,
                    ram_gb: *ram_gb,
                    arrival: 0,
                    departure: 10,
                    weight: 1.0,
                };
                let refs = dc.gpu_refs();
                let expected = classify_rejection(dc, &v, &refs);
                let got = classify_rejection_cluster(dc, &v);
                if got == expected {
                    Ok(())
                } else {
                    Err(format!("{profile}: cluster={got:?} refs={expected:?}"))
                }
            },
        );
    }

    #[test]
    fn classification_ignores_foreign_model_headroom() {
        use crate::mig::GpuModel;
        // A30 host with zero free CPU + roomy H100 host: an A30 request
        // is CPU-bound (the H100 host's headroom is irrelevant to it).
        let mut dc = DataCenter::new(vec![
            Host::with_models(0, 2, 256, &[GpuModel::A30]),
            Host::with_models(1, 64, 256, &[GpuModel::H100_80]),
        ]);
        let a30_vm = vm(1, GpuModel::A30.profile(0));
        assert_eq!(classify_rejection_cluster(&dc, &a30_vm), RejectReason::CpuExhausted);
        assert_eq!(
            classify_rejection(&dc, &a30_vm, &dc.gpu_refs()),
            RejectReason::CpuExhausted
        );
        // A request for a model absent from the fleet is a
        // no-compatible-GPU case, whatever the headroom.
        let a100_vm = vm(2, Profile::P1g5gb);
        assert_eq!(classify_rejection_cluster(&dc, &a100_vm), RejectReason::NoGpuFit);
        // Fill the H100 completely: its *host* still has headroom, but an
        // H100 request is blocked by the GI — fragmentation, not CPU.
        let h100_heavy = GpuModel::H100_80.profile(5);
        let filler = vm(3, h100_heavy);
        assert!(try_place_on_gpu(&mut dc, &filler, GpuRef { host: 1, gpu: 0 }).is_some());
        let h100_vm = vm(4, GpuModel::H100_80.profile(0));
        assert_eq!(classify_rejection_cluster(&dc, &h100_vm), RejectReason::NoGpuFit);
    }

    #[test]
    fn reject_reason_indices_dense() {
        for (i, r) in RejectReason::ALL.iter().enumerate() {
            assert_eq!(r.index(), i);
        }
    }

    #[test]
    fn reject_counts_format_skips_zeroes() {
        let counts: RejectCounts = [0, 2, 1, 0, 3, 0];
        assert_eq!(format_reject_counts(&counts), "ram_exhausted=2 no_gpu_fit=1 queued=3");
        assert_eq!(format_reject_counts(&[0; 6]), "");
    }

    #[test]
    fn scan_paths_skip_unhealthy_capacity() {
        use crate::cluster::HealthState;
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1), Host::new(1, 64, 256, 1)]);
        let down = GpuRef { host: 0, gpu: 0 };
        dc.set_gpu_health(down, HealthState::Failed { until: 10 });
        // The brute-force walk must agree with the health-aware bucket.
        let mut seen = Vec::new();
        visit_candidates(&dc, Profile::P1g5gb, false, |r| {
            seen.push(r);
            true
        });
        assert_eq!(seen, vec![GpuRef { host: 1, gpu: 0 }]);
        let bucket: Vec<GpuRef> = dc.index().gpus_fitting(Profile::P1g5gb).iter().collect();
        assert_eq!(seen, bucket);
        assert!(probe_gpu(&dc, &vm(1, Profile::P1g5gb), down).is_none());
        // With every compatible GPU down, both classifiers report
        // no-compatible-GPU even though the hosts keep CPU/RAM headroom.
        dc.set_gpu_health(GpuRef { host: 1, gpu: 0 }, HealthState::Banned);
        let v = vm(2, Profile::P1g5gb);
        assert_eq!(classify_rejection_cluster(&dc, &v), RejectReason::NoGpuFit);
        assert_eq!(classify_rejection(&dc, &v, &dc.gpu_refs()), RejectReason::NoGpuFit);
    }
}
