//! GRMU — the GPU Resource Management Unit (§7).
//!
//! A multi-stage placement framework combining:
//!
//! * **Dual-Basket Pooling** (Algorithms 2–3): GPUs live in a pool ordered
//!   by `globalIndex`; a *heavy* basket (capped at a configurable share of
//!   all GPUs) serves whole-GPU requests (7g.40gb on the A100-40 and its
//!   per-model analogues — [`crate::mig::Profile::is_heavy`]), a *light*
//!   basket serves everything else. Baskets span all fleet models; a
//!   request only probes model-compatible GPUs within its basket. Baskets
//!   grow on demand by drawing the lowest-index GPU from the pool;
//!   first-fit within a basket promotes consolidation. A request the
//!   quota locks out of an otherwise-serviceable pool is rejected with
//!   [`RejectReason::QuotaDenied`].
//! * **Migration**, delegated to the policy-agnostic planner layer
//!   ([`crate::migrate`]): GRMU is now a thin composition of the baskets
//!   above and a [`PlannerStack`] scoped to the light basket —
//!   [`crate::migrate::DefragOnReject`] (Algorithm 4, fired when a batch
//!   sees any rejection) and [`crate::migrate::PairwiseConsolidate`]
//!   (Algorithm 5, fired on the periodic tick). Plans apply through the
//!   transactional `DataCenter::apply_plan`; performed moves surface as
//!   [`MigrationEvent`]s, and consolidation sources that emptied return
//!   from the light basket to the pool. Default-config decisions and
//!   events are byte-identical to the pre-extraction inline
//!   implementation (locked in `rust/tests/decision_api.rs`).
//!
//! Implementation note on Algorithm 3 line 13: the pseudocode's
//! `|basket| ≤ basketCapacity` would let a basket reach capacity+1; we
//! use strict `<` so the heavy basket never exceeds its quota.

use super::{
    classify_rejection, probe_gpu, Decision, MigrationEvent, MigrationKind, Policy, PolicyCtx,
    RejectReason,
};
use crate::cluster::vm::{VmId, VmSpec};
use crate::cluster::{DataCenter, GpuBits, GpuRef};
use crate::migrate::{
    DefragOnReject, MigrationBudget, PairwiseConsolidate, PlanScope, PlanTrigger, PlannerStack,
};
use std::collections::BTreeSet;

/// GRMU tuning knobs (§8.2's sweep parameters).
#[derive(Debug, Clone)]
pub struct GrmuConfig {
    /// Share of all GPUs reserved for the heavy basket (paper knee: 0.30).
    pub heavy_capacity_frac: f64,
    /// Consolidation period; `None` disables it (the paper's pick for the
    /// evaluated workload).
    pub consolidation_interval_hours: Option<u64>,
    /// Defragmentation on rejection (Algorithm 4).
    pub defrag_enabled: bool,
    /// Probe only basket GPUs where the profile currently fits (the
    /// cluster-index intersection; decision-identical to the plain
    /// basket walk, which `false` restores as the brute-force reference).
    pub use_index: bool,
    /// Budget for the internal planner stack. Unlimited by default — the
    /// paper's configuration, and what the byte-identity lock assumes.
    pub migration_budget: MigrationBudget,
}

impl Default for GrmuConfig {
    fn default() -> Self {
        GrmuConfig {
            heavy_capacity_frac: 0.30,
            consolidation_interval_hours: None,
            defrag_enabled: true,
            use_index: true,
            migration_budget: MigrationBudget::unlimited(),
        }
    }
}

/// The GRMU policy state.
pub struct Grmu {
    config: GrmuConfig,
    /// Unused GPUs, ordered by `globalIndex` (`Get` pops the first).
    pool: BTreeSet<GpuRef>,
    /// Heavy basket (7g.40gb), ordered by `globalIndex`.
    heavy: BTreeSet<GpuRef>,
    /// Light basket (all other profiles), ordered by `globalIndex`.
    light: BTreeSet<GpuRef>,
    /// Bitset mirrors of the baskets in the cluster index's slot space,
    /// so the indexed placement walk is a word-wise AND against the
    /// profile's feasibility bucket ([`GpuSetView::and_iter`]
    /// (crate::cluster::GpuSetView::and_iter)). Derived state: the
    /// `BTreeSet`s above stay authoritative (they feed `PlanScope::Set`,
    /// the snapshot codec and the public accessors); the mirrors are
    /// rebuilt lazily after a snapshot restore (`bits_ready`).
    heavy_bits: GpuBits,
    light_bits: GpuBits,
    bits_ready: bool,
    heavy_capacity: usize,
    light_capacity: usize,
    /// Migration planners (defrag/consolidation), scoped to the light
    /// basket at every run.
    stack: PlannerStack,
    /// Migrations performed and not yet drained by the event core.
    events: Vec<MigrationEvent>,
    initialized: bool,
}

impl Grmu {
    pub fn new(config: GrmuConfig) -> Grmu {
        let stack = Grmu::default_stack(&config);
        Grmu::with_stack(config, stack)
    }

    /// The planner stack [`Grmu::new`] composes from a config: defrag on
    /// rejection (Algorithm 4) when enabled, then periodic pairwise
    /// consolidation (Algorithm 5) when an interval is set.
    pub fn default_stack(config: &GrmuConfig) -> PlannerStack {
        let mut stack = PlannerStack::new(config.migration_budget);
        if config.defrag_enabled {
            stack.push(Box::new(DefragOnReject::new(config.use_index)));
        }
        if let Some(hours) = config.consolidation_interval_hours {
            stack.push(Box::new(PairwiseConsolidate::every(hours)));
        }
        stack
    }

    /// GRMU over an explicit planner stack (the thin-composition seam:
    /// `Grmu::new(cfg)` ≡ `Grmu::with_stack(cfg, Grmu::default_stack(&cfg))`,
    /// locked in `rust/tests/decision_api.rs`). The stack always runs
    /// scoped to the light basket.
    pub fn with_stack(config: GrmuConfig, stack: PlannerStack) -> Grmu {
        Grmu {
            config,
            pool: BTreeSet::new(),
            heavy: BTreeSet::new(),
            light: BTreeSet::new(),
            heavy_bits: GpuBits::default(),
            light_bits: GpuBits::default(),
            bits_ready: false,
            heavy_capacity: 0,
            light_capacity: 0,
            stack,
            events: Vec::new(),
            initialized: false,
        }
    }

    /// Algorithm 2: pool every GPU by global index, fix basket capacities,
    /// seed each basket with one GPU.
    fn initialize(&mut self, dc: &DataCenter) {
        let refs = dc.gpu_refs();
        let num_gpus = refs.len();
        self.pool = refs.into_iter().collect();
        self.heavy_capacity =
            ((num_gpus as f64 * self.config.heavy_capacity_frac).round() as usize).max(1);
        self.light_capacity = num_gpus - self.heavy_capacity;
        if let Some(g) = self.pop_pool() {
            self.heavy.insert(g);
        }
        if let Some(g) = self.pop_pool() {
            self.light.insert(g);
        }
        self.initialized = true;
        self.rebuild_bits(dc);
    }

    /// (Re)derive the basket bitset mirrors from the authoritative
    /// `BTreeSet`s — at initialization and lazily after a snapshot
    /// restore (which carries the sets but has no `DataCenter` to size
    /// the bitsets against).
    fn rebuild_bits(&mut self, dc: &DataCenter) {
        self.heavy_bits = GpuBits::for_index(dc.index());
        self.light_bits = GpuBits::for_index(dc.index());
        for &r in &self.heavy {
            self.heavy_bits.insert(dc.index(), r);
        }
        for &r in &self.light {
            self.light_bits.insert(dc.index(), r);
        }
        self.bits_ready = true;
    }

    fn pop_pool(&mut self) -> Option<GpuRef> {
        let first = *self.pool.iter().next()?;
        self.pool.remove(&first);
        Some(first)
    }

    /// Algorithm 5's pool return, applied after every stack run: an
    /// inter-GPU move (from `self.events[start..]`) that emptied its
    /// source GPU drains that GPU from the light basket back into the
    /// pool (by `globalIndex` order, so it is the first to be reused).
    /// Checked after rejection rounds too, not just ticks — a custom
    /// stack ([`Grmu::with_stack`]) may run inter-capable planners (e.g.
    /// `FragGradient`) on rejections; the default defrag-only rejection
    /// round emits only intra moves and is untouched.
    fn return_emptied_sources(&mut self, dc: &DataCenter, start: usize) {
        for i in start..self.events.len() {
            let ev = self.events[i];
            if ev.kind == MigrationKind::Inter
                && dc.gpu(ev.from).is_empty()
                && self.light.remove(&ev.from)
            {
                if self.bits_ready {
                    self.light_bits.remove(dc.index(), ev.from);
                }
                self.pool.insert(ev.from);
            }
        }
    }

    /// Algorithm 3 for one VM: scan the basket first-fit, then grow it
    /// from the pool if allowed. Rejections distinguish a binding basket
    /// quota from genuine resource/fragmentation shortage.
    ///
    /// With the cluster index the basket walk is intersected with the
    /// profile's feasibility bucket — a word-wise AND of the basket's
    /// bitset mirror against the bucket — so only GPUs that can actually
    /// host the GI are probed; both walks are ascending `globalIndex`,
    /// so the first fit — and every decision — is identical.
    fn place_one(&mut self, dc: &mut DataCenter, vm: &VmSpec) -> Decision {
        let heavy = vm.profile.is_heavy();
        let capacity = if heavy { self.heavy_capacity } else { self.light_capacity };
        let basket = if heavy { &self.heavy } else { &self.light };

        let probe = |dc: &DataCenter, r: GpuRef| probe_gpu(dc, vm, r).map(|pl| (r, pl));
        let found = if self.config.use_index {
            let bits = if heavy { &self.heavy_bits } else { &self.light_bits };
            dc.index().gpus_fitting(vm.profile).and_iter(bits).find_map(|r| probe(dc, r))
        } else {
            basket.iter().find_map(|&r| probe(dc, r))
        };
        if let Some((r, placement)) = found {
            dc.place(vm, r, placement);
            return Decision::Placed { gpu: r, placement };
        }
        let at_quota = basket.len() >= capacity;
        if !at_quota {
            // Grow the basket from the pool (strict capacity check; see
            // module docs). Pool GPUs are empty, but their host may be
            // unable to take the VM's CPU/RAM — skip such GPUs without
            // consuming them (and without materializing a candidate Vec).
            if let Some((r, placement)) = self.pool.iter().find_map(|&r| probe(dc, r)) {
                self.pool.remove(&r);
                if heavy {
                    self.heavy.insert(r);
                    self.heavy_bits.insert(dc.index(), r);
                } else {
                    self.light.insert(r);
                    self.light_bits.insert(dc.index(), r);
                }
                dc.place(vm, r, placement);
                return Decision::Placed { gpu: r, placement };
            }
        } else if self.pool.iter().any(|&r| {
            dc.gpu_available(r)
                && dc.gpu(r).model() == vm.profile.model()
                && dc.host(r.host).fits_resources(vm.cpus, vm.ram_gb)
        }) {
            // A pool GPU of the request's model (empty, so any of its GIs
            // fits) could serve this VM; only the basket quota stands in
            // the way.
            return Decision::Rejected(RejectReason::QuotaDenied);
        }
        let basket = if heavy { &self.heavy } else { &self.light };
        let reason = if at_quota {
            classify_rejection(dc, vm, basket)
        } else {
            classify_rejection(dc, vm, basket.iter().chain(self.pool.iter()))
        };
        Decision::Rejected(reason)
    }
}

impl Policy for Grmu {
    fn name(&self) -> &str {
        "GRMU"
    }

    fn place_batch_into(&mut self, dc: &mut DataCenter, vms: &[VmSpec], ctx: &mut PolicyCtx) {
        if !self.initialized {
            self.initialize(dc);
        }
        if !self.bits_ready {
            // Restored from a snapshot: the baskets traveled in the
            // image, the bitset mirrors did not (derived state).
            self.rebuild_bits(dc);
        }
        ctx.decisions.begin(vms.len());
        let mut any_rejected = false;
        for vm in vms {
            let d = self.place_one(dc, vm);
            any_rejected |= !d.is_placed();
            ctx.decisions.push(d);
        }
        // Any rejection triggers light-basket defragmentation (§7.1) via
        // the rejection-triggered planners of the stack.
        if any_rejected {
            let start = self.events.len();
            self.stack.run(
                dc,
                ctx.now,
                PlanTrigger::Rejection,
                PlanScope::Set(&self.light),
                &mut self.events,
            );
            self.return_emptied_sources(dc, start);
        }
    }

    fn on_departure(&mut self, _dc: &mut DataCenter, _vm: VmId, _ctx: &mut PolicyCtx) {
        // Basket membership is sticky: emptied GPUs return to the pool
        // only through consolidation (Algorithm 5).
    }

    fn on_tick(&mut self, dc: &mut DataCenter, ctx: &mut PolicyCtx) {
        let start = self.events.len();
        self.stack.run(
            dc,
            ctx.now,
            PlanTrigger::Tick,
            PlanScope::Set(&self.light),
            &mut self.events,
        );
        self.return_emptied_sources(dc, start);
    }

    fn drain_migrations_into(&mut self, out: &mut Vec<MigrationEvent>) {
        // `append` keeps the event buffer's capacity across drains — no
        // per-interval reallocation in steady state.
        out.append(&mut self.events);
    }

    fn snapshot_state(&self, out: &mut Vec<u8>) {
        let mut e = crate::util::codec::Enc::new();
        e.bool(self.initialized);
        e.usize(self.heavy_capacity);
        e.usize(self.light_capacity);
        let basket = |e: &mut crate::util::codec::Enc, set: &BTreeSet<GpuRef>| {
            e.usize(set.len());
            for r in set {
                e.u32(r.host);
                e.u8(r.gpu);
            }
        };
        basket(&mut e, &self.pool);
        basket(&mut e, &self.heavy);
        basket(&mut e, &self.light);
        let mut stack = Vec::new();
        self.stack.snapshot_state(&mut stack);
        e.blob(&stack);
        e.usize(self.events.len());
        for ev in &self.events {
            ev.encode(&mut e);
        }
        out.extend_from_slice(e.bytes());
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut d = crate::util::codec::Dec::new(bytes);
        self.initialized = d.bool()?;
        self.heavy_capacity = d.usize()?;
        self.light_capacity = d.usize()?;
        let mut basket = |d: &mut crate::util::codec::Dec| -> Result<BTreeSet<GpuRef>, String> {
            let n = d.count(5)?;
            let mut set = BTreeSet::new();
            for _ in 0..n {
                let host = d.u32()?;
                let gpu = d.u8()?;
                set.insert(GpuRef { host, gpu });
            }
            Ok(set)
        };
        self.pool = basket(&mut d)?;
        self.heavy = basket(&mut d)?;
        self.light = basket(&mut d)?;
        self.bits_ready = false; // mirrors are rebuilt on the next batch
        let stack = d.blob()?.to_vec();
        self.stack.restore_state(&stack)?;
        let n = d.count(21)?;
        self.events = Vec::with_capacity(n);
        for _ in 0..n {
            self.events.push(MigrationEvent::decode(&mut d)?);
        }
        if !d.is_empty() {
            return Err("trailing bytes in GRMU state".into());
        }
        Ok(())
    }
}

/// Test-support accessors (used by integration tests and examples).
impl Grmu {
    pub fn heavy_basket(&self) -> &BTreeSet<GpuRef> {
        &self.heavy
    }
    pub fn light_basket(&self) -> &BTreeSet<GpuRef> {
        &self.light
    }
    pub fn pool_size(&self) -> usize {
        self.pool.len()
    }
    pub fn heavy_capacity(&self) -> usize {
        self.heavy_capacity
    }
    /// Migrations recorded and not yet drained via `take_migrations`.
    pub fn pending_migrations(&self) -> &[MigrationEvent] {
        &self.events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::vm::HOUR;
    use crate::cluster::Host;
    use crate::mig::Profile;
    use crate::policies::MigrationKind;

    fn vm(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 100_000, weight: 1.0 }
    }

    fn dc(gpus_per_host: usize, hosts: u32) -> DataCenter {
        DataCenter::new(
            (0..hosts).map(|i| Host::new(i, 256, 1024, gpus_per_host)).collect(),
        )
    }

    fn batch(g: &mut Grmu, dcx: &mut DataCenter, vms: &[VmSpec]) -> Vec<Decision> {
        let mut ctx = PolicyCtx::default();
        g.place_batch(dcx, vms, &mut ctx)
    }

    fn accepted(out: &[Decision]) -> usize {
        out.iter().filter(|d| d.is_placed()).count()
    }

    #[test]
    fn initialization_seeds_baskets() {
        let mut dc = dc(2, 5); // 10 GPUs
        let mut g = Grmu::new(GrmuConfig { heavy_capacity_frac: 0.3, ..Default::default() });
        batch(&mut g, &mut dc, &[vm(1, Profile::P1g5gb)]);
        assert_eq!(g.heavy_capacity(), 3);
        assert_eq!(g.heavy_basket().len(), 1);
        assert_eq!(g.light_basket().len(), 1);
        assert_eq!(g.pool_size(), 8);
    }

    #[test]
    fn heavy_quota_enforced_with_quota_reason() {
        let mut dcx = dc(1, 10); // 10 GPUs, heavy capacity = 3
        let mut g = Grmu::new(GrmuConfig { heavy_capacity_frac: 0.3, ..Default::default() });
        let heavy: Vec<VmSpec> = (1..=5).map(|i| vm(i, Profile::P7g40gb)).collect();
        let out = batch(&mut g, &mut dcx, &heavy);
        // Only 3 GPUs may serve 7g.40gb; the overflow is a quota denial,
        // not a capacity shortage (the pool still has empty GPUs).
        assert_eq!(accepted(&out), 3);
        assert_eq!(g.heavy_basket().len(), 3);
        for d in &out[3..] {
            assert_eq!(d.reject_reason(), Some(RejectReason::QuotaDenied));
        }
        // Light profiles still have the remaining GPUs.
        let out = batch(&mut g, &mut dcx, &[vm(10, Profile::P3g20gb)]);
        assert_eq!(accepted(&out), 1);
    }

    #[test]
    fn light_profiles_never_use_heavy_basket() {
        let mut dcx = dc(1, 4);
        let mut g = Grmu::new(GrmuConfig { heavy_capacity_frac: 0.5, ..Default::default() });
        batch(&mut g, &mut dcx, &[vm(1, Profile::P7g40gb)]);
        let heavy_gpu = *g.heavy_basket().iter().next().unwrap();
        // Fill the light basket to capacity with small VMs; none may land
        // on the heavy GPU even after the 7g departs.
        dcx.remove(1);
        let small: Vec<VmSpec> = (2..30).map(|i| vm(i, Profile::P3g20gb)).collect();
        batch(&mut g, &mut dcx, &small);
        assert!(dcx.gpu(heavy_gpu).is_empty(), "light VM placed on heavy-basket GPU");
    }

    #[test]
    fn first_fit_within_basket_consolidates() {
        let mut dcx = dc(2, 3);
        let mut g = Grmu::new(GrmuConfig::default());
        let out = batch(
            &mut g,
            &mut dcx,
            &[vm(1, Profile::P3g20gb), vm(2, Profile::P3g20gb), vm(3, Profile::P1g5gb)],
        );
        assert_eq!(accepted(&out), 3);
        // Both 3g VMs share the first light GPU; light basket grew for the
        // third VM only if needed.
        assert_eq!(dcx.locate(1).unwrap().gpu, dcx.locate(2).unwrap().gpu);
    }

    #[test]
    fn defrag_triggered_on_rejection() {
        // Build fragmentation on the single light GPU: place 1g.5gb VMs,
        // remove some to leave a suboptimal layout, then send a request
        // that must be rejected — defrag should relocate instances.
        let mut dcx = dc(1, 2); // 2 GPUs: 1 heavy + 1 light, pool empty
        let mut g = Grmu::new(GrmuConfig { heavy_capacity_frac: 0.5, ..Default::default() });
        let b: Vec<VmSpec> = (1..=3).map(|i| vm(i, Profile::P1g5gb)).collect();
        batch(&mut g, &mut dcx, &b);
        // Placed at 6, 4, 5 (default policy). Remove VM at block 6 and 5:
        dcx.remove(1);
        dcx.remove(3);
        // Now a lone 1g.5gb sits at block 4 — fragmented. A 4g.20gb fits
        // at blocks 0–3. A 2g.10gb then needs start 0, 2 or 4 — all
        // blocked → rejection → defrag relocates the stray 1g to block 6.
        let out = batch(&mut g, &mut dcx, &[vm(10, Profile::P4g20gb)]);
        assert_eq!(accepted(&out), 1);
        let out = batch(&mut g, &mut dcx, &[vm(11, Profile::P2g10gb)]);
        assert_eq!(accepted(&out), 0);
        let intra = g
            .pending_migrations()
            .iter()
            .filter(|e| e.kind == MigrationKind::Intra)
            .count();
        assert!(intra > 0, "defrag should have relocated the stray instance");
        // Draining hands the events to the caller exactly once.
        assert_eq!(g.take_migrations().len(), intra);
        assert!(g.pending_migrations().is_empty());
        // After defrag the 2g.10gb fits at start 4.
        let out = batch(&mut g, &mut dcx, &[vm(12, Profile::P2g10gb)]);
        assert_eq!(accepted(&out), 1);
        assert_eq!(dcx.locate(12).unwrap().placement.start, 4);
    }

    #[test]
    fn consolidation_returns_gpus_to_pool() {
        let mut dcx = dc(1, 6);
        let mut g = Grmu::new(GrmuConfig {
            heavy_capacity_frac: 0.17, // 1 GPU heavy, 5 light
            consolidation_interval_hours: Some(1),
            ..Default::default()
        });
        // Two 3g.20gb VMs forced onto two different GPUs: fill first GPU's
        // other half with a temporary 3g, then remove it.
        let out = batch(
            &mut g,
            &mut dcx,
            &[vm(1, Profile::P3g20gb), vm(2, Profile::P3g20gb), vm(3, Profile::P3g20gb)],
        );
        assert_eq!(accepted(&out), 3);
        // VMs 1,2 share GPU A; VM 3 on GPU B. Remove VM 1: A half-full.
        dcx.remove(1);
        let pool_before = g.pool_size();
        let mut ctx = PolicyCtx::default();
        ctx.now = 2 * HOUR;
        g.on_tick(&mut dcx, &mut ctx);
        // VM 3 (or 2) migrated so one GPU drained back to the pool.
        let inter: Vec<_> = g
            .pending_migrations()
            .iter()
            .filter(|e| e.kind == MigrationKind::Inter)
            .collect();
        assert_eq!(inter.len(), 1);
        assert_ne!(inter[0].from, inter[0].to);
        assert_eq!(g.pool_size(), pool_before + 1);
        dcx.check_integrity().unwrap();
    }

    #[test]
    fn no_consolidation_when_disabled() {
        let mut dcx = dc(1, 6);
        let mut g = Grmu::new(GrmuConfig {
            heavy_capacity_frac: 0.17,
            consolidation_interval_hours: None,
            ..Default::default()
        });
        batch(&mut g, &mut dcx, &[vm(1, Profile::P3g20gb), vm(2, Profile::P4g20gb)]);
        let mut ctx = PolicyCtx::default();
        ctx.now = 100 * HOUR;
        g.on_tick(&mut dcx, &mut ctx);
        assert!(g.pending_migrations().is_empty());
    }

    #[test]
    fn failed_capacity_is_skipped_in_baskets_and_pool() {
        use crate::cluster::HealthState;
        let mut dcx = dc(1, 2); // 2 GPUs: 1 heavy + 1 light, pool empty
        let mut g = Grmu::new(GrmuConfig { heavy_capacity_frac: 0.5, ..Default::default() });
        batch(&mut g, &mut dcx, &[vm(1, Profile::P1g5gb)]);
        let light_gpu = *g.light_basket().iter().next().unwrap();
        dcx.remove(1);
        dcx.set_gpu_health(light_gpu, HealthState::Failed { until: 99 });
        // The light basket's only GPU is down: the request must bounce
        // rather than land on failed capacity.
        let out = batch(&mut g, &mut dcx, &[vm(2, Profile::P1g5gb)]);
        assert_eq!(accepted(&out), 0);
        assert_eq!(out[0].reject_reason(), Some(RejectReason::NoGpuFit));
        // Repair restores service.
        dcx.set_gpu_health(light_gpu, HealthState::Healthy);
        let out = batch(&mut g, &mut dcx, &[vm(3, Profile::P1g5gb)]);
        assert_eq!(accepted(&out), 1);
        dcx.check_integrity().unwrap();
    }

    #[test]
    fn budgeted_grmu_suppresses_defrag() {
        // Same scenario as defrag_triggered_on_rejection, but a zero
        // interval budget starves the stack: no migration happens and the
        // stray instance stays put.
        let mut dcx = dc(1, 2);
        let mut g = Grmu::new(GrmuConfig {
            heavy_capacity_frac: 0.5,
            migration_budget: MigrationBudget::unlimited().per_interval(0),
            ..Default::default()
        });
        let b: Vec<VmSpec> = (1..=3).map(|i| vm(i, Profile::P1g5gb)).collect();
        batch(&mut g, &mut dcx, &b);
        dcx.remove(1);
        dcx.remove(3);
        batch(&mut g, &mut dcx, &[vm(10, Profile::P4g20gb)]);
        let out = batch(&mut g, &mut dcx, &[vm(11, Profile::P2g10gb)]);
        assert_eq!(accepted(&out), 0);
        assert!(g.pending_migrations().is_empty());
        assert_eq!(dcx.locate(2).unwrap().placement.start, 4);
    }
}
