//! Light-basket consolidation via inter-GPU migration (Algorithm 5).
//!
//! Periodically, GRMU looks for half-full single-profile GPUs in the
//! light basket — GPUs holding exactly one 3g.20gb or 4g.20gb instance
//! that occupies one half of the device. Pairs of such GPUs are merged:
//! the guest of the source migrates into the free half of the target, the
//! source empties and returns to the pool (by `globalIndex` order, so it
//! is the first to be reused). Every move is recorded as a
//! [`MigrationEvent`] of kind [`MigrationKind::Inter`].
//!
//! Placement-rule subtlety the pseudocode glosses over: a 4g.20gb can
//! only start at block 0, so two 4g.20gb-bearing GPUs can never merge —
//! the fit check below (via the default placement) rejects such pairs.

use crate::cluster::{DataCenter, GpuRef};
use crate::mig::placement::mock_assign;
use crate::policies::{MigrationEvent, MigrationKind};
use std::collections::BTreeSet;

/// One consolidation round. Returns the GPUs drained back to the pool;
/// each migrated VM is appended to `events`.
pub fn consolidate_light_basket(
    dc: &mut DataCenter,
    light: &mut BTreeSet<GpuRef>,
    events: &mut Vec<MigrationEvent>,
) -> Vec<GpuRef> {
    // Candidates: half-full, single-profile GPUs (Algorithm 5 line 1).
    let mut candidates: Vec<GpuRef> = light
        .iter()
        .copied()
        .filter(|&r| {
            let g = dc.gpu(r);
            g.half_full() && g.single_profile()
        })
        .collect();

    let mut freed = Vec::new();
    // Greedy pairing: take each source in order, find any compatible
    // target among the remaining candidates.
    let mut i = 0;
    while i < candidates.len() {
        let source = candidates[i];
        let Some(inst) = dc.gpu(source).instances().first().copied() else {
            i += 1;
            continue;
        };
        // Find a target whose free half accepts the source's profile.
        // (Feasibility is a single `mock_assign` table lookup per target,
        // so this path deliberately stays index-free: it behaves the same
        // under both candidate-iteration modes of the policies.)
        let mut chosen: Option<(usize, crate::mig::Placement)> = None;
        for (j, &target) in candidates.iter().enumerate() {
            if j == i {
                continue;
            }
            // Only GPUs of the instance's model can receive it
            // (Eq. 17–18): a mixed light basket pairs per model.
            if dc.gpu(target).model() != inst.placement.profile.model() {
                continue;
            }
            // CPU/RAM must also follow the VM when hosts differ; the
            // paper's model migrates the whole VM.
            if source.host != target.host {
                let (cpus, ram) = dc.vm_demands(inst.vm).unwrap_or((0, 0));
                if !dc.host(target.host).fits_resources(cpus, ram) {
                    continue;
                }
            }
            if let Some((placement, _)) =
                mock_assign(dc.gpu(target).occupancy(), inst.placement.profile)
            {
                chosen = Some((j, placement));
                break;
            }
        }
        if let Some((j, placement)) = chosen {
            let target = candidates[j];
            dc.migrate(inst.vm, target, placement);
            events.push(MigrationEvent {
                vm: inst.vm,
                from: source,
                to: target,
                kind: MigrationKind::Inter,
            });
            light.remove(&source);
            freed.push(source);
            // Source leaves the candidate list; target is now full and
            // leaves as well.
            let (lo, hi) = if i < j { (i, j) } else { (j, i) };
            candidates.remove(hi);
            candidates.remove(lo);
            // Restart scan from the beginning of the shrunk list.
            i = 0;
        } else {
            i += 1;
        }
    }
    freed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Host, VmSpec};
    use crate::mig::{Placement, Profile};

    fn place(dc: &mut DataCenter, id: u64, profile: Profile, r: GpuRef, start: u8) {
        let vm = VmSpec {
            id,
            profile,
            cpus: 4,
            ram_gb: 8,
            arrival: 0,
            departure: 10,
            weight: 1.0,
        };
        dc.place(&vm, r, Placement { profile, start });
    }

    fn refs(n: u8) -> Vec<GpuRef> {
        (0..n).map(|g| GpuRef { host: 0, gpu: g }).collect()
    }

    #[test]
    fn merges_two_half_full_3g_gpus() {
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        place(&mut dc, 1, Profile::P3g20gb, refs(2)[0], 0);
        place(&mut dc, 2, Profile::P3g20gb, refs(2)[1], 0);
        let mut light: BTreeSet<GpuRef> = refs(2).into_iter().collect();
        let mut events = Vec::new();
        let freed = consolidate_light_basket(&mut dc, &mut light, &mut events);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind, MigrationKind::Inter);
        assert_ne!(events[0].from, events[0].to);
        assert_eq!(freed.len(), 1);
        assert_eq!(light.len(), 1);
        // One GPU holds both instances, the other is empty.
        let full = *light.iter().next().unwrap();
        assert_eq!(dc.gpu(full).instances().len(), 2);
        assert_eq!(dc.gpu(freed[0]).instances().len(), 0);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn two_4g_gpus_cannot_merge() {
        // 4g.20gb must start at block 0 — both GPUs have block 0 taken.
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        place(&mut dc, 1, Profile::P4g20gb, refs(2)[0], 0);
        place(&mut dc, 2, Profile::P4g20gb, refs(2)[1], 0);
        let mut light: BTreeSet<GpuRef> = refs(2).into_iter().collect();
        let mut events = Vec::new();
        let freed = consolidate_light_basket(&mut dc, &mut light, &mut events);
        assert!(events.is_empty());
        assert!(freed.is_empty());
        assert_eq!(light.len(), 2);
    }

    #[test]
    fn mixed_3g_4g_merge_in_the_feasible_direction() {
        // 4g@0 on GPU 0, 3g@0 on GPU 1: only the 3g can move (to start 4
        // of GPU 0) — the 4g cannot start at 4.
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        place(&mut dc, 1, Profile::P4g20gb, refs(2)[0], 0);
        place(&mut dc, 2, Profile::P3g20gb, refs(2)[1], 0);
        let mut light: BTreeSet<GpuRef> = refs(2).into_iter().collect();
        let mut events = Vec::new();
        let freed = consolidate_light_basket(&mut dc, &mut light, &mut events);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].vm, 2);
        assert_eq!(freed, vec![GpuRef { host: 0, gpu: 1 }]);
        let loc = dc.locate(2).unwrap();
        assert_eq!(loc.gpu, GpuRef { host: 0, gpu: 0 });
        assert_eq!(loc.placement.start, 4);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn multi_instance_gpus_not_candidates() {
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        // Half-full but with two instances (2×2g) — not single-profile.
        place(&mut dc, 1, Profile::P2g10gb, refs(2)[0], 0);
        place(&mut dc, 2, Profile::P2g10gb, refs(2)[0], 2);
        place(&mut dc, 3, Profile::P3g20gb, refs(2)[1], 0);
        let mut light: BTreeSet<GpuRef> = refs(2).into_iter().collect();
        let mut events = Vec::new();
        consolidate_light_basket(&mut dc, &mut light, &mut events);
        assert!(events.is_empty());
    }

    #[test]
    fn cross_host_migration_checks_resources() {
        // Target host has no CPU headroom → no migration.
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 1), Host::new(1, 4, 8, 1)]);
        place(&mut dc, 1, Profile::P3g20gb, GpuRef { host: 0, gpu: 0 }, 0);
        // Fill host 1's CPU with its own VM.
        place(&mut dc, 2, Profile::P3g20gb, GpuRef { host: 1, gpu: 0 }, 0);
        // Migrating VM 1 → host 1 impossible (CPU), VM 2 → host 0 fine.
        let mut light: BTreeSet<GpuRef> =
            [GpuRef { host: 0, gpu: 0 }, GpuRef { host: 1, gpu: 0 }].into_iter().collect();
        let mut events = Vec::new();
        let freed = consolidate_light_basket(&mut dc, &mut light, &mut events);
        assert_eq!(events.len(), 1);
        assert_eq!(freed, vec![GpuRef { host: 1, gpu: 0 }]);
        assert_eq!(dc.locate(2).unwrap().gpu.host, 0);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn four_gpus_pair_into_two_merges() {
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 4)]);
        for (i, r) in refs(4).into_iter().enumerate() {
            place(&mut dc, i as u64 + 1, Profile::P3g20gb, r, 0);
        }
        let mut light: BTreeSet<GpuRef> = refs(4).into_iter().collect();
        let mut events = Vec::new();
        let freed = consolidate_light_basket(&mut dc, &mut light, &mut events);
        assert_eq!(events.len(), 2);
        assert_eq!(freed.len(), 2);
        assert_eq!(light.len(), 2);
        dc.check_integrity().unwrap();
    }
}
