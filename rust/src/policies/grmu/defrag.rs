//! Defragmentation via intra-GPU migration (Algorithm 4).
//!
//! When an allocation round rejects any VM, GRMU selects the most
//! fragmented GPU in the light basket and re-packs it: the GPU's current
//! instances are replayed onto an empty *mock* GPU using the default
//! NVIDIA placement (largest profiles first, so the replay reproduces a
//! fresh-arrival packing), and every instance whose mock position differs
//! from its live position is relocated (`Relocated` + `IntraMigrate` of
//! Table 2). The replay is simulation-only — the data center is mutated
//! only if the complete re-pack is feasible. Every relocation is reported
//! as a [`MigrationEvent`] of kind [`MigrationKind::Intra`].

use crate::cluster::{DataCenter, GpuRef};
use crate::mig::fragmentation::fragmentation_value;
use crate::mig::placement::mock_assign;
use crate::mig::{GpuState, Instance, Placement};
use crate::policies::{MigrationEvent, MigrationKind};
use std::collections::BTreeSet;

/// Pick the most fragmented GPU (Algorithm 4's `Max(lightBasket,
/// Fragmentation)`); ties resolve to the lowest global index. GPUs with
/// zero fragmentation are skipped entirely.
pub fn most_fragmented(dc: &DataCenter, basket: &BTreeSet<GpuRef>) -> Option<GpuRef> {
    let mut best: Option<(f64, GpuRef)> = None;
    for &r in basket {
        let gpu = dc.gpu(r);
        let frag = fragmentation_value(gpu.model(), gpu.occupancy());
        if frag <= 0.0 {
            continue;
        }
        if best.map(|(b, _)| frag > b).unwrap_or(true) {
            best = Some((frag, r));
        }
    }
    best.map(|(_, r)| r)
}

/// Compute the re-pack plan for one GPU: replay instances onto a mock GPU
/// with the default placement and return the instances that move, paired
/// with their new placements. Returns `None` if the replay cannot fit
/// every instance (the greedy default policy is not guaranteed to re-pack
/// arbitrary multisets) — in that case no migration is performed.
pub fn repack_plan(gpu: &GpuState) -> Option<Vec<(Instance, Placement)>> {
    let mut instances: Vec<Instance> = gpu.instances().to_vec();
    // Replay order: largest profile first, then current start — a
    // fresh-arrival order that the default policy packs tightly.
    instances.sort_by_key(|inst| {
        (std::cmp::Reverse(inst.placement.profile.size()), inst.placement.start)
    });
    let mut mock: u8 = 0;
    let mut moves = Vec::new();
    for inst in &instances {
        let (placement, new_occ) = mock_assign(mock, inst.placement.profile)?;
        mock = new_occ;
        if placement != inst.placement {
            moves.push((*inst, placement));
        }
    }
    // Migrations are costly (Eq. 5): only relocate when the re-pack
    // *strictly improves* the configuration's CC — a same-CC shuffle
    // would burn migrations for nothing.
    if crate::mig::gpu::cc_for(gpu.model(), mock) <= gpu.cc() {
        return Some(Vec::new());
    }
    Some(moves)
}

/// Algorithm 4's `Defragmentation`: re-pack the most fragmented GPU of
/// the light basket. Returns one intra-GPU [`MigrationEvent`] per
/// relocated instance.
pub fn defragment_light_basket(dc: &mut DataCenter, basket: &BTreeSet<GpuRef>) -> Vec<MigrationEvent> {
    let Some(target) = most_fragmented(dc, basket) else {
        return Vec::new();
    };
    let Some(moves) = repack_plan(dc.gpu(target)) else {
        return Vec::new();
    };
    if moves.is_empty() {
        return Vec::new();
    }
    apply_repack(dc, target, &moves)
}

/// Apply a re-pack plan through [`DataCenter::repack_gpu`] (which keeps
/// the location and cluster indices coherent while avoiding transient
/// overlaps). Returns the performed relocations as migration events.
pub fn apply_repack(
    dc: &mut DataCenter,
    gpu_ref: GpuRef,
    moves: &[(Instance, Placement)],
) -> Vec<MigrationEvent> {
    dc.repack_gpu(gpu_ref, moves);
    moves
        .iter()
        .map(|(inst, _)| MigrationEvent {
            vm: inst.vm,
            from: gpu_ref,
            to: gpu_ref,
            kind: MigrationKind::Intra,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Host, VmSpec};
    use crate::mig::Profile;

    fn dc_one_gpu() -> DataCenter {
        DataCenter::new(vec![Host::new(0, 256, 1024, 1)])
    }

    fn place(dc: &mut DataCenter, id: u64, profile: Profile, start: u8) {
        let vm = VmSpec { id, profile, cpus: 1, ram_gb: 1, arrival: 0, departure: 10, weight: 1.0 };
        dc.place(&vm, GpuRef { host: 0, gpu: 0 }, Placement { profile, start });
    }

    #[test]
    fn paper_stray_1g_relocated_to_block_6() {
        // §7.1: a 1g.5gb left at block 4 after its block-6 neighbour
        // departed should move to block 6.
        let mut dc = dc_one_gpu();
        place(&mut dc, 1, Profile::P1g5gb, 4);
        let r = GpuRef { host: 0, gpu: 0 };
        let basket: BTreeSet<GpuRef> = [r].into_iter().collect();
        let events = defragment_light_basket(&mut dc, &basket);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0], MigrationEvent { vm: 1, from: r, to: r, kind: MigrationKind::Intra });
        assert_eq!(dc.gpu(r).instances()[0].placement.start, 6);
        assert_eq!(dc.locate(1).unwrap().placement.start, 6);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn repack_improves_or_preserves_cc() {
        let mut dc = dc_one_gpu();
        // Fragmented layout: 1g.5gb at 0 and 3 (the CC=9 example).
        place(&mut dc, 1, Profile::P1g5gb, 0);
        place(&mut dc, 2, Profile::P1g5gb, 3);
        let r = GpuRef { host: 0, gpu: 0 };
        let cc_before = dc.gpu(r).cc();
        let basket: BTreeSet<GpuRef> = [r].into_iter().collect();
        defragment_light_basket(&mut dc, &basket);
        assert!(dc.gpu(r).cc() > cc_before);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn already_optimal_gpu_untouched() {
        let mut dc = dc_one_gpu();
        place(&mut dc, 1, Profile::P1g5gb, 6); // where the default puts it
        let r = GpuRef { host: 0, gpu: 0 };
        let basket: BTreeSet<GpuRef> = [r].into_iter().collect();
        // Fragmentation of this state may be zero or the replay may be a
        // no-op; either way no migration happens.
        let events = defragment_light_basket(&mut dc, &basket);
        assert!(events.is_empty());
        assert_eq!(dc.gpu(r).instances()[0].placement.start, 6);
    }

    #[test]
    fn empty_basket_no_op() {
        let mut dc = dc_one_gpu();
        assert!(defragment_light_basket(&mut dc, &BTreeSet::new()).is_empty());
    }

    #[test]
    fn most_fragmented_picks_worst() {
        let mut dc = DataCenter::new(vec![Host::new(0, 256, 1024, 2)]);
        // GPU 0: tight (3g at 0). GPU 1: stray 1g at 4.
        let a = VmSpec {
            id: 1,
            profile: Profile::P3g20gb,
            cpus: 1,
            ram_gb: 1,
            arrival: 0,
            departure: 10,
            weight: 1.0,
        };
        dc.place(&a, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P3g20gb, start: 0 });
        let b = VmSpec { id: 2, profile: Profile::P1g5gb, ..a };
        dc.place(&b, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P1g5gb, start: 4 });
        let basket: BTreeSet<GpuRef> = dc.gpu_refs().into_iter().collect();
        let worst = most_fragmented(&dc, &basket).unwrap();
        assert_eq!(worst, GpuRef { host: 0, gpu: 1 });
    }

    #[test]
    fn repack_plan_handles_full_multiset() {
        // 7 × 1g.5gb: replay fills blocks 0..=6 — all must fit.
        let mut g = GpuState::new();
        for (i, s) in [0u8, 1, 2, 3, 4, 5, 6].iter().enumerate() {
            g.place(i as u64, Placement { profile: Profile::P1g5gb, start: *s });
        }
        let plan = repack_plan(&g).expect("full multiset re-packs");
        // Already at every legal start; the plan may shuffle but count ≤ 7.
        assert!(plan.len() <= 7);
    }
}
