//! First-Fit (FF): the commercial-solution baseline of §8.3.
//!
//! Sequentially scans hosts and their GPUs in `globalIndex` order and
//! places the request on the first compatible resource.

use super::{classify_rejection, try_place_on_gpu, Decision, Policy, PolicyCtx};
use crate::cluster::vm::VmSpec;
use crate::cluster::{DataCenter, GpuRef};

/// First-Fit placement.
#[derive(Debug, Default)]
pub struct FirstFit {
    refs: Vec<GpuRef>,
}

impl FirstFit {
    pub fn new() -> FirstFit {
        FirstFit::default()
    }
}

impl Policy for FirstFit {
    fn name(&self) -> &str {
        "FF"
    }

    fn place_batch(
        &mut self,
        dc: &mut DataCenter,
        vms: &[VmSpec],
        _ctx: &mut PolicyCtx,
    ) -> Vec<Decision> {
        if self.refs.is_empty() {
            self.refs = dc.gpu_refs();
        }
        vms.iter()
            .map(|vm| {
                // Skip hosts that cannot fit CPU/RAM without probing
                // every GPU on them.
                let mut skip_host: Option<u32> = None;
                for &r in &self.refs {
                    if skip_host == Some(r.host) {
                        continue;
                    }
                    if !dc.host(r.host).fits_resources(vm.cpus, vm.ram_gb) {
                        skip_host = Some(r.host);
                        continue;
                    }
                    if let Some(placement) = try_place_on_gpu(dc, vm, r) {
                        return Decision::Placed { gpu: r, placement };
                    }
                }
                Decision::Rejected(classify_rejection(dc, vm, &self.refs))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::Profile;
    use crate::policies::RejectReason;

    fn vm(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 100, weight: 1.0 }
    }

    fn placed(out: &[Decision]) -> Vec<bool> {
        out.iter().map(|d| d.is_placed()).collect()
    }

    #[test]
    fn fills_first_gpu_first() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2), Host::new(1, 64, 256, 2)]);
        let mut p = FirstFit::new();
        let mut ctx = PolicyCtx::default();
        let out = p.place_batch(
            &mut dc,
            &[vm(1, Profile::P3g20gb), vm(2, Profile::P3g20gb), vm(3, Profile::P3g20gb)],
            &mut ctx,
        );
        assert_eq!(placed(&out), vec![true, true, true]);
        // First two on GPU (0,0); third on GPU (0,1) — and the decisions
        // carry the same addresses as the location index.
        assert_eq!(dc.locate(1).unwrap().gpu, GpuRef { host: 0, gpu: 0 });
        assert_eq!(dc.locate(2).unwrap().gpu, GpuRef { host: 0, gpu: 0 });
        assert_eq!(dc.locate(3).unwrap().gpu, GpuRef { host: 0, gpu: 1 });
        for (v, d) in [1u64, 2, 3].iter().zip(&out) {
            assert_eq!(d.gpu(), Some(dc.locate(*v).unwrap().gpu));
        }
    }

    #[test]
    fn rejects_with_fragmentation_reason_when_no_fit() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let mut p = FirstFit::new();
        let mut ctx = PolicyCtx::default();
        let out =
            p.place_batch(&mut dc, &[vm(1, Profile::P7g40gb), vm(2, Profile::P1g5gb)], &mut ctx);
        assert!(out[0].is_placed());
        assert_eq!(out[1], Decision::Rejected(RejectReason::NoGpuFit));
    }

    #[test]
    fn skips_cpu_exhausted_host() {
        let mut dc = DataCenter::new(vec![Host::new(0, 1, 256, 1), Host::new(1, 64, 256, 1)]);
        let mut p = FirstFit::new();
        let mut ctx = PolicyCtx::default();
        let out = p.place_batch(&mut dc, &[vm(1, Profile::P1g5gb)], &mut ctx);
        assert!(out[0].is_placed());
        assert_eq!(dc.locate(1).unwrap().gpu.host, 1);
    }

    #[test]
    fn cpu_exhaustion_reason_surfaces() {
        let mut dc = DataCenter::new(vec![Host::new(0, 1, 256, 1)]);
        let mut p = FirstFit::new();
        let mut ctx = PolicyCtx::default();
        let out = p.place_batch(&mut dc, &[vm(1, Profile::P1g5gb)], &mut ctx);
        assert_eq!(out[0], Decision::Rejected(RejectReason::CpuExhausted));
    }
}
