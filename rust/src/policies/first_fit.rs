//! First-Fit (FF): the commercial-solution baseline of §8.3.
//!
//! Walks the candidate GPUs in `globalIndex` order and places the
//! request on the first compatible resource. With the cluster index the
//! walk covers only the GPUs where the profile currently fits, which is
//! decision-identical to the historical full scan (see
//! [`super::visit_candidates`]).

use super::{probe_gpu, reject_cluster, visit_candidates, Decision, Policy, PolicyCtx};
use crate::cluster::vm::VmSpec;
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::Placement;

/// First-Fit placement.
#[derive(Debug)]
pub struct FirstFit {
    use_index: bool,
}

impl FirstFit {
    pub fn new() -> FirstFit {
        FirstFit::with_index(true)
    }

    /// `use_index = false` restores the brute-force full scan (the
    /// equivalence-test / benchmark reference).
    pub fn with_index(use_index: bool) -> FirstFit {
        FirstFit { use_index }
    }
}

impl Default for FirstFit {
    fn default() -> Self {
        FirstFit::new()
    }
}

impl Policy for FirstFit {
    fn name(&self) -> &str {
        "FF"
    }

    fn place_batch_into(&mut self, dc: &mut DataCenter, vms: &[VmSpec], ctx: &mut PolicyCtx) {
        ctx.decisions.begin(vms.len());
        for vm in vms {
            if self.use_index && !dc.index().host_may_fit(vm.cpus, vm.ram_gb) {
                // No host anywhere has the CPU (or the RAM): the scan
                // below cannot succeed, skip straight to the reason.
                ctx.decisions.push(reject_cluster(dc, vm, self.use_index));
                continue;
            }
            let mut found: Option<(GpuRef, Placement)> = None;
            visit_candidates(dc, vm.profile, self.use_index, |r| {
                if let Some(pl) = probe_gpu(dc, vm, r) {
                    found = Some((r, pl));
                    return false;
                }
                true
            });
            let d = match found {
                Some((r, pl)) => {
                    dc.place(vm, r, pl);
                    Decision::Placed { gpu: r, placement: pl }
                }
                None => reject_cluster(dc, vm, self.use_index),
            };
            ctx.decisions.push(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::Profile;
    use crate::policies::RejectReason;

    fn vm(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 100, weight: 1.0 }
    }

    fn placed(out: &[Decision]) -> Vec<bool> {
        out.iter().map(|d| d.is_placed()).collect()
    }

    #[test]
    fn fills_first_gpu_first() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2), Host::new(1, 64, 256, 2)]);
        let mut p = FirstFit::new();
        let mut ctx = PolicyCtx::default();
        let out = p.place_batch(
            &mut dc,
            &[vm(1, Profile::P3g20gb), vm(2, Profile::P3g20gb), vm(3, Profile::P3g20gb)],
            &mut ctx,
        );
        assert_eq!(placed(&out), vec![true, true, true]);
        // First two on GPU (0,0); third on GPU (0,1) — and the decisions
        // carry the same addresses as the location index.
        assert_eq!(dc.locate(1).unwrap().gpu, GpuRef { host: 0, gpu: 0 });
        assert_eq!(dc.locate(2).unwrap().gpu, GpuRef { host: 0, gpu: 0 });
        assert_eq!(dc.locate(3).unwrap().gpu, GpuRef { host: 0, gpu: 1 });
        for (v, d) in [1u64, 2, 3].iter().zip(&out) {
            assert_eq!(d.gpu(), Some(dc.locate(*v).unwrap().gpu));
        }
    }

    #[test]
    fn rejects_with_fragmentation_reason_when_no_fit() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let mut p = FirstFit::new();
        let mut ctx = PolicyCtx::default();
        let out =
            p.place_batch(&mut dc, &[vm(1, Profile::P7g40gb), vm(2, Profile::P1g5gb)], &mut ctx);
        assert!(out[0].is_placed());
        assert_eq!(out[1], Decision::Rejected(RejectReason::NoGpuFit));
    }

    #[test]
    fn skips_cpu_exhausted_host() {
        let mut dc = DataCenter::new(vec![Host::new(0, 1, 256, 1), Host::new(1, 64, 256, 1)]);
        let mut p = FirstFit::new();
        let mut ctx = PolicyCtx::default();
        let out = p.place_batch(&mut dc, &[vm(1, Profile::P1g5gb)], &mut ctx);
        assert!(out[0].is_placed());
        assert_eq!(dc.locate(1).unwrap().gpu.host, 1);
    }

    #[test]
    fn cpu_exhaustion_reason_surfaces() {
        let mut dc = DataCenter::new(vec![Host::new(0, 1, 256, 1)]);
        let mut p = FirstFit::new();
        let mut ctx = PolicyCtx::default();
        let out = p.place_batch(&mut dc, &[vm(1, Profile::P1g5gb)], &mut ctx);
        assert_eq!(out[0], Decision::Rejected(RejectReason::CpuExhausted));
    }
}
