//! [`Planned`] — any placement policy composed with a migration
//! [`PlannerStack`].
//!
//! The bridge between the policy layer and the policy-agnostic
//! [`crate::migrate`] mechanism: the wrapped policy decides placements
//! untouched, and the stack runs over the **whole cluster** after every
//! batch that saw a rejection ([`PlanTrigger::Rejection`]) and on every
//! maintenance tick ([`PlanTrigger::Tick`]). This is what the registry
//! builds for the `base+planner` composed names (`mcc+defrag`,
//! `bf+consolidate`, `ff+defrag+frag-gradient`, ...) and the CLI's
//! `--planners` flag — every §8.3 policy can now defragment and
//! consolidate, not just GRMU. (GRMU itself keeps its own internal
//! stack, scoped to the light basket, per Algorithms 4–5.)

use super::{Policy, PolicyConfig, PolicyCtx};
use crate::cluster::vm::{VmId, VmSpec};
use crate::cluster::DataCenter;
use crate::migrate::{
    DefragOnReject, FragGradient, MigrationEvent, MigrationPlanner, PairwiseConsolidate,
    PlanScope, PlanTrigger, PlannerStack,
};

/// Planner names accepted as `+` suffixes on registry policy names and
/// in `--planners` lists, in documentation order.
pub const PLANNER_NAMES: [&str; 4] = ["defrag", "consolidate", "frag-gradient", "ilp-repair"];

/// Build a planner by [`PLANNER_NAMES`] name from the shared policy
/// configuration. `None` for unknown names.
///
/// A standalone `consolidate` planner (outside GRMU) defaults to the
/// paper's 24 h period when `cfg.consolidation_hours` is unset — a
/// composed `bf+consolidate` that never fired would be pointless.
pub(crate) fn planner_from_name(
    name: &str,
    cfg: &PolicyConfig,
) -> Option<Box<dyn MigrationPlanner>> {
    match name {
        "defrag" => Some(Box::new(DefragOnReject::new(cfg.use_index))),
        "consolidate" => {
            Some(Box::new(PairwiseConsolidate::every(cfg.consolidation_hours.unwrap_or(24))))
        }
        "frag-gradient" => Some(Box::new(FragGradient::new(cfg.frag_threshold, cfg.use_index))),
        "ilp-repair" => Some(Box::new(crate::ilp::online::RollingIlp::new(
            cfg.ilp_window,
            cfg.ilp_nodes,
            cfg.ilp_period_hours,
        ))),
        _ => None,
    }
}

/// A base policy + a cluster-scoped planner stack.
pub struct Planned {
    inner: Box<dyn Policy>,
    stack: PlannerStack,
    /// `"<BASE>+<planner>+..."`, e.g. `"MCC+defrag"`.
    name: String,
    /// Migrations performed by the stack, pending drain.
    events: Vec<MigrationEvent>,
}

impl Planned {
    pub fn new(inner: Box<dyn Policy>, stack: PlannerStack) -> Planned {
        let mut name = inner.name().to_string();
        for planner in stack.names() {
            name.push('+');
            name.push_str(planner);
        }
        Planned { inner, stack, name, events: Vec::new() }
    }

    /// The wrapped base policy.
    pub fn inner(&self) -> &dyn Policy {
        self.inner.as_ref()
    }
}

impl Policy for Planned {
    fn name(&self) -> &str {
        &self.name
    }

    fn place_batch_into(&mut self, dc: &mut DataCenter, vms: &[VmSpec], ctx: &mut PolicyCtx) {
        self.inner.place_batch_into(dc, vms, ctx);
        // Any rejection in the batch fires the rejection-triggered
        // planners (Algorithm 4's defragmentation condition), over the
        // whole cluster — composed policies have no baskets. The
        // rejected specs ride along as demand hints so planners that
        // understand them (`ilp-repair`) can lay the cluster out for
        // exactly the shapes that just bounced.
        let rejected: Vec<VmSpec> = vms
            .iter()
            .zip(ctx.decisions.iter())
            .filter(|(_, d)| !d.is_placed())
            .map(|(v, _)| *v)
            .collect();
        if !rejected.is_empty() {
            self.stack.run_with_pending(
                dc,
                ctx.now,
                PlanTrigger::Rejection,
                PlanScope::Cluster,
                &rejected,
                &mut self.events,
            );
        }
    }

    fn on_departure(&mut self, dc: &mut DataCenter, vm: VmId, ctx: &mut PolicyCtx) {
        self.inner.on_departure(dc, vm, ctx);
    }

    fn on_tick(&mut self, dc: &mut DataCenter, ctx: &mut PolicyCtx) {
        self.inner.on_tick(dc, ctx);
        self.stack.run(dc, ctx.now, PlanTrigger::Tick, PlanScope::Cluster, &mut self.events);
    }

    fn drain_migrations_into(&mut self, out: &mut Vec<MigrationEvent>) {
        self.inner.drain_migrations_into(out);
        out.append(&mut self.events);
    }

    fn drain_gap_samples_into(&mut self, out: &mut Vec<f64>) {
        self.inner.drain_gap_samples_into(out);
    }

    fn snapshot_state(&self, out: &mut Vec<u8>) {
        let mut e = crate::util::codec::Enc::new();
        let mut inner = Vec::new();
        self.inner.snapshot_state(&mut inner);
        e.blob(&inner);
        let mut stack = Vec::new();
        self.stack.snapshot_state(&mut stack);
        e.blob(&stack);
        e.usize(self.events.len());
        for ev in &self.events {
            ev.encode(&mut e);
        }
        out.extend_from_slice(e.bytes());
    }

    fn restore_state(&mut self, bytes: &[u8]) -> Result<(), String> {
        let mut d = crate::util::codec::Dec::new(bytes);
        let inner = d.blob()?.to_vec();
        self.inner.restore_state(&inner)?;
        let stack = d.blob()?.to_vec();
        self.stack.restore_state(&stack)?;
        let n = d.count(21)?;
        self.events = Vec::with_capacity(n);
        for _ in 0..n {
            self.events.push(MigrationEvent::decode(&mut d)?);
        }
        if !d.is_empty() {
            return Err("trailing bytes in composed-policy state".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::Profile;
    use crate::migrate::{MigrationBudget, MigrationKind};
    use crate::policies::{Decision, PolicyRegistry};

    fn vm(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 100_000, weight: 1.0 }
    }

    /// Rebuild GRMU's §7.1 defragmentation scenario with a *composed*
    /// policy: ff+defrag must relocate the stray 1g.5gb exactly like
    /// GRMU's internal defragmentation does.
    #[test]
    fn ff_plus_defrag_defragments_on_rejection() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let mut p = PolicyRegistry::standard()
            .build("ff+defrag", &PolicyConfig::new())
            .unwrap();
        let mut ctx = PolicyCtx::default();
        let b: Vec<VmSpec> = (1..=3).map(|i| vm(i, Profile::P1g5gb)).collect();
        p.place_batch(&mut dc, &b, &mut ctx);
        dc.remove(1);
        dc.remove(3);
        // Stray 1g at block 4. The 4g.20gb fits at 0–3; the 2g.10gb then
        // has no legal start → rejection → defrag moves the stray to 6.
        let out = p.place_batch(&mut dc, &[vm(10, Profile::P4g20gb)], &mut ctx);
        assert!(out[0].is_placed());
        let out = p.place_batch(&mut dc, &[vm(11, Profile::P2g10gb)], &mut ctx);
        assert!(out[0].reject_reason().is_some());
        let events = p.take_migrations();
        assert!(
            events.iter().any(|e| e.kind == MigrationKind::Intra),
            "composed defrag should have relocated the stray instance: {events:?}"
        );
        assert_eq!(dc.locate(2).unwrap().placement.start, 6);
        // After defrag the 2g.10gb fits.
        let out = p.place_batch(&mut dc, &[vm(12, Profile::P2g10gb)], &mut ctx);
        assert!(out[0].is_placed());
        dc.check_integrity().unwrap();
    }

    #[test]
    fn bf_plus_consolidate_merges_on_tick() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 4)]);
        let cfg = PolicyConfig::new().consolidation_hours(Some(1));
        let mut p = PolicyRegistry::standard().build("bf+consolidate", &cfg).unwrap();
        let mut ctx = PolicyCtx::default();
        // BF packs 3g pairs tightly; force two half-full GPUs by placing
        // four and removing the second of each pair.
        let b: Vec<VmSpec> = (1..=4).map(|i| vm(i, Profile::P3g20gb)).collect();
        let out = p.place_batch(&mut dc, &b, &mut ctx);
        assert!(out.iter().all(Decision::is_placed));
        dc.remove(2);
        dc.remove(4);
        ctx.now = 2 * crate::cluster::vm::HOUR;
        p.on_tick(&mut dc, &mut ctx);
        let events = p.take_migrations();
        assert_eq!(events.len(), 1, "{events:?}");
        assert_eq!(events[0].kind, MigrationKind::Inter);
        dc.check_integrity().unwrap();
    }

    #[test]
    fn zero_budget_suppresses_all_migrations() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let cfg = PolicyConfig::new()
            .migration_budget(MigrationBudget::unlimited().per_interval(0));
        let mut p = PolicyRegistry::standard().build("ff+defrag", &cfg).unwrap();
        let mut ctx = PolicyCtx::default();
        let b: Vec<VmSpec> = (1..=3).map(|i| vm(i, Profile::P1g5gb)).collect();
        p.place_batch(&mut dc, &b, &mut ctx);
        dc.remove(1);
        dc.remove(3);
        p.place_batch(&mut dc, &[vm(10, Profile::P4g20gb)], &mut ctx);
        p.place_batch(&mut dc, &[vm(11, Profile::P2g10gb)], &mut ctx);
        assert!(p.take_migrations().is_empty(), "budget 0 must suppress defrag");
        // The stray stayed where it was.
        assert_eq!(dc.locate(2).unwrap().placement.start, 4);
    }

    #[test]
    fn base_policy_decisions_untouched_by_wrapper() {
        // The wrapper may migrate *after* the batch, but decisions come
        // verbatim from the base policy.
        let mut dc1 = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let mut dc2 = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let registry = PolicyRegistry::standard();
        let mut plain = registry.build("mcc", &PolicyConfig::new()).unwrap();
        let mut composed = registry.build("mcc+defrag", &PolicyConfig::new()).unwrap();
        let batch: Vec<VmSpec> = (1..=3).map(|i| vm(i, Profile::P3g20gb)).collect();
        let mut ctx1 = PolicyCtx::default();
        let mut ctx2 = PolicyCtx::default();
        let a = plain.place_batch(&mut dc1, &batch, &mut ctx1);
        let b = composed.place_batch(&mut dc2, &batch, &mut ctx2);
        assert_eq!(a, b);
    }
}
