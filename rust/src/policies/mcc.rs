//! Max Configuration Capacity (MCC, Algorithm 6): evaluate every GPU in
//! the data center and keep the one whose *post-allocation* CC is
//! highest. Ties resolve to the lowest `globalIndex`.
//!
//! Because an empty GPU retains a high CC after hosting a small profile,
//! MCC tends to spread load across many GPUs — the behaviour §8.3.2
//! observes as higher active-hardware usage.
//!
//! Scoring goes through the [`CcScorer`] handle of the [`PolicyCtx`], so
//! the same policy instance can score natively or through the
//! AOT-compiled XLA artifact with bit-identical results.

use super::{reject_cluster, visit_candidates, Decision, Policy, PolicyCtx};
use crate::cluster::vm::VmSpec;
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::placement::mock_assign;

// Scorer types live in the crate's policy root since the decision-API
// redesign; re-exported here for the historical import path.
pub use super::{CcScorer, NativeScorer};

/// MCC placement. The scoring backend comes from the [`PolicyCtx`].
#[derive(Debug)]
pub struct Mcc {
    use_index: bool,
    /// Scratch buffers reused across decisions (hot-path allocation-free).
    cand_refs: Vec<(GpuRef, crate::mig::Placement)>,
    cand_occs: Vec<u8>,
    scores: Vec<u32>,
}

impl Mcc {
    pub fn new() -> Mcc {
        Mcc::with_index(true)
    }

    /// `use_index = false` restores the brute-force full scan.
    pub fn with_index(use_index: bool) -> Mcc {
        Mcc { use_index, cand_refs: Vec::new(), cand_occs: Vec::new(), scores: Vec::new() }
    }
}

impl Default for Mcc {
    fn default() -> Self {
        Mcc::new()
    }
}

impl Policy for Mcc {
    fn name(&self) -> &str {
        "MCC"
    }

    fn place_batch_into(&mut self, dc: &mut DataCenter, vms: &[VmSpec], ctx: &mut PolicyCtx) {
        let use_index = self.use_index;
        ctx.decisions.begin(vms.len());
        for vm in vms {
            if use_index && !dc.index().host_may_fit(vm.cpus, vm.ram_gb) {
                ctx.decisions.push(reject_cluster(dc, vm, use_index));
                continue;
            }
            // Gather candidates: (gpu, default placement, resulting occ).
            self.cand_refs.clear();
            self.cand_occs.clear();
            let mut skip_host: Option<u32> = None;
            let (cand_refs, cand_occs) = (&mut self.cand_refs, &mut self.cand_occs);
            visit_candidates(dc, vm.profile, use_index, |r| {
                if skip_host == Some(r.host) {
                    return true;
                }
                if !dc.host(r.host).fits_resources(vm.cpus, vm.ram_gb) {
                    skip_host = Some(r.host);
                    return true;
                }
                if let Some((pl, new_occ)) = mock_assign(dc.gpu(r).occupancy(), vm.profile) {
                    cand_refs.push((r, pl));
                    cand_occs.push(new_occ);
                }
                true
            });
            if self.cand_refs.is_empty() {
                ctx.decisions.push(reject_cluster(dc, vm, use_index));
                continue;
            }
            // All candidates share the request's model (Eq. 17–18), so
            // one scorer call covers the candidate set; the score buffer
            // is reused across decisions.
            self.scores.clear();
            ctx.scorer.score_into(vm.profile.model(), &self.cand_occs, &mut self.scores);
            let mut best = 0usize;
            for (i, &s) in self.scores.iter().enumerate() {
                if s > self.scores[best] {
                    best = i;
                }
            }
            let (r, pl) = self.cand_refs[best];
            dc.place(vm, r, pl);
            ctx.decisions.push(Decision::Placed { gpu: r, placement: pl });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::{Placement, Profile};
    use crate::policies::RejectReason;

    fn vm(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 100, weight: 1.0 }
    }

    #[test]
    fn spreads_across_empty_gpus() {
        // Unlike BF, MCC places the second small VM on a *fresh* GPU:
        // an empty GPU's post-allocation CC beats packing.
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let mut p = Mcc::new();
        let mut ctx = PolicyCtx::default();
        let out =
            p.place_batch(&mut dc, &[vm(1, Profile::P3g20gb), vm(2, Profile::P3g20gb)], &mut ctx);
        assert!(out.iter().all(|d| d.is_placed()));
        assert_ne!(dc.locate(1).unwrap().gpu, dc.locate(2).unwrap().gpu);
    }

    #[test]
    fn picks_cc_maximal_gpu() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        // GPU 0: blocks 0 and 3 occupied (the CC=9 example); GPU 1: 7 blocks
        // occupied. A 1g.5gb lands where post-CC is higher (GPU 0).
        let a = vm(90, Profile::P1g5gb);
        let b = vm(91, Profile::P1g5gb);
        dc.place(&a, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P1g5gb, start: 0 });
        dc.place(&b, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P1g5gb, start: 3 });
        let c = vm(92, Profile::P7g40gb);
        // Can't place 7g on partially full GPU — occupy GPU 1 with 4g+2g+1g.
        let d = vm(93, Profile::P4g20gb);
        let e = vm(94, Profile::P2g10gb);
        let f = vm(95, Profile::P1g5gb);
        let _ = c;
        dc.place(&d, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P4g20gb, start: 0 });
        dc.place(&e, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P2g10gb, start: 4 });
        dc.place(&f, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P1g5gb, start: 6 });
        let mut p = Mcc::new();
        let mut ctx = PolicyCtx::default();
        let out = p.place_batch(&mut dc, &[vm(1, Profile::P1g5gb)], &mut ctx);
        assert!(out[0].is_placed());
        assert_eq!(dc.locate(1).unwrap().gpu, GpuRef { host: 0, gpu: 0 });
    }

    #[test]
    fn rejects_when_nothing_fits() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let mut p = Mcc::new();
        let mut ctx = PolicyCtx::default();
        let out =
            p.place_batch(&mut dc, &[vm(1, Profile::P7g40gb), vm(2, Profile::P7g40gb)], &mut ctx);
        assert!(out[0].is_placed());
        assert_eq!(out[1], Decision::Rejected(RejectReason::NoGpuFit));
    }
}
