//! Max Configuration Capacity (MCC, Algorithm 6): evaluate every GPU in
//! the data center and keep the one whose *post-allocation* CC is
//! highest. Ties resolve to the lowest `globalIndex`.
//!
//! Because an empty GPU retains a high CC after hosting a small profile,
//! MCC tends to spread load across many GPUs — the behaviour §8.3.2
//! observes as higher active-hardware usage.
//!
//! Scoring goes through the [`CcScorer`] handle of the [`PolicyCtx`], so
//! the same policy instance can score natively or through the
//! AOT-compiled XLA artifact with bit-identical results.

use super::{classify_rejection, Decision, Policy, PolicyCtx};
use crate::cluster::vm::VmSpec;
use crate::cluster::{DataCenter, GpuRef};
use crate::mig::placement::mock_assign;

// Scorer types live in the crate's policy root since the decision-API
// redesign; re-exported here for the historical import path.
pub use super::{CcScorer, NativeScorer};

/// MCC placement. The scoring backend comes from the [`PolicyCtx`].
#[derive(Debug, Default)]
pub struct Mcc {
    refs: Vec<GpuRef>,
    /// Scratch buffers reused across decisions (hot-path allocation-free).
    cand_refs: Vec<(GpuRef, crate::mig::Placement)>,
    cand_occs: Vec<u8>,
}

impl Mcc {
    pub fn new() -> Mcc {
        Mcc::default()
    }
}

impl Policy for Mcc {
    fn name(&self) -> &str {
        "MCC"
    }

    fn place_batch(
        &mut self,
        dc: &mut DataCenter,
        vms: &[VmSpec],
        ctx: &mut PolicyCtx,
    ) -> Vec<Decision> {
        if self.refs.is_empty() {
            self.refs = dc.gpu_refs();
        }
        vms.iter()
            .map(|vm| {
                // Gather candidates: (gpu, default placement, resulting occ).
                self.cand_refs.clear();
                self.cand_occs.clear();
                let mut skip_host: Option<u32> = None;
                for &r in &self.refs {
                    if skip_host == Some(r.host) {
                        continue;
                    }
                    if !dc.host(r.host).fits_resources(vm.cpus, vm.ram_gb) {
                        skip_host = Some(r.host);
                        continue;
                    }
                    if let Some((pl, new_occ)) = mock_assign(dc.gpu(r).occupancy(), vm.profile) {
                        self.cand_refs.push((r, pl));
                        self.cand_occs.push(new_occ);
                    }
                }
                if self.cand_refs.is_empty() {
                    return Decision::Rejected(classify_rejection(dc, vm, &self.refs));
                }
                let scores = ctx.scorer.score(&self.cand_occs);
                let mut best = 0usize;
                for (i, &s) in scores.iter().enumerate() {
                    if s > scores[best] {
                        best = i;
                    }
                }
                let (r, pl) = self.cand_refs[best];
                dc.place(vm, r, pl);
                Decision::Placed { gpu: r, placement: pl }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Host;
    use crate::mig::{Placement, Profile};
    use crate::policies::RejectReason;

    fn vm(id: u64, profile: Profile) -> VmSpec {
        VmSpec { id, profile, cpus: 2, ram_gb: 4, arrival: 0, departure: 100, weight: 1.0 }
    }

    #[test]
    fn spreads_across_empty_gpus() {
        // Unlike BF, MCC places the second small VM on a *fresh* GPU:
        // an empty GPU's post-allocation CC beats packing.
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        let mut p = Mcc::new();
        let mut ctx = PolicyCtx::default();
        let out =
            p.place_batch(&mut dc, &[vm(1, Profile::P3g20gb), vm(2, Profile::P3g20gb)], &mut ctx);
        assert!(out.iter().all(|d| d.is_placed()));
        assert_ne!(dc.locate(1).unwrap().gpu, dc.locate(2).unwrap().gpu);
    }

    #[test]
    fn picks_cc_maximal_gpu() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 2)]);
        // GPU 0: blocks 0 and 3 occupied (the CC=9 example); GPU 1: 7 blocks
        // occupied. A 1g.5gb lands where post-CC is higher (GPU 0).
        let a = vm(90, Profile::P1g5gb);
        let b = vm(91, Profile::P1g5gb);
        dc.place(&a, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P1g5gb, start: 0 });
        dc.place(&b, GpuRef { host: 0, gpu: 0 }, Placement { profile: Profile::P1g5gb, start: 3 });
        let c = vm(92, Profile::P7g40gb);
        // Can't place 7g on partially full GPU — occupy GPU 1 with 4g+2g+1g.
        let d = vm(93, Profile::P4g20gb);
        let e = vm(94, Profile::P2g10gb);
        let f = vm(95, Profile::P1g5gb);
        let _ = c;
        dc.place(&d, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P4g20gb, start: 0 });
        dc.place(&e, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P2g10gb, start: 4 });
        dc.place(&f, GpuRef { host: 0, gpu: 1 }, Placement { profile: Profile::P1g5gb, start: 6 });
        let mut p = Mcc::new();
        let mut ctx = PolicyCtx::default();
        let out = p.place_batch(&mut dc, &[vm(1, Profile::P1g5gb)], &mut ctx);
        assert!(out[0].is_placed());
        assert_eq!(dc.locate(1).unwrap().gpu, GpuRef { host: 0, gpu: 0 });
    }

    #[test]
    fn rejects_when_nothing_fits() {
        let mut dc = DataCenter::new(vec![Host::new(0, 64, 256, 1)]);
        let mut p = Mcc::new();
        let mut ctx = PolicyCtx::default();
        let out =
            p.place_batch(&mut dc, &[vm(1, Profile::P7g40gb), vm(2, Profile::P7g40gb)], &mut ctx);
        assert!(out[0].is_placed());
        assert_eq!(out[1], Decision::Rejected(RejectReason::NoGpuFit));
    }
}
