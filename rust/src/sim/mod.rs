//! Discrete-event simulation (replaces the paper's "Cloudy" simulator,
//! ref. [30]).
//!
//! The paper models placement as an online stochastic process on a
//! discrete clock (§6): each interval evaluates the requests that arrived
//! during it and makes placement decisions. [`event_core`] implements
//! that loop once — departures before arrivals, typed placement
//! decisions, maintenance ticks, hourly metric samples — and is shared
//! with the online coordinator, so offline simulations and live serving
//! produce the same [`SimResult`]. [`engine`] wraps the core in a
//! trace-replay driver; [`metrics`] accumulates the quantities behind
//! every figure of §8: acceptance rates (overall, hourly, per profile,
//! and per [`crate::policies::RejectReason`]), the strict active-hardware
//! rate, migration events and Table 6's area under the curve.
//! [`sharded`] scales the same interval loop to very large fleets: a
//! deterministic router fans each interval out to per-shard cores placed
//! in parallel, with `--shards 1` byte-identical to the single-core
//! engine and results independent of the worker-thread count.

pub mod engine;
pub mod event_core;
pub mod metrics;
pub mod sharded;

pub use engine::{Simulation, SimulationOptions};
pub use event_core::EventCore;
pub use metrics::{acceptance_rate, Sample, SimResult};
pub use sharded::{ShardOptions, ShardedCore, ShardedSimulation};
