//! Discrete-event simulation (replaces the paper's "Cloudy" simulator,
//! ref. [30]).
//!
//! The paper models placement as an online stochastic process on a
//! discrete clock (§6): each interval evaluates the requests that arrived
//! during it and makes placement decisions. [`engine`] implements that
//! loop — hourly arrival batches, exact-time departures, periodic
//! maintenance ticks for policies that migrate, and hourly metric
//! sampling. [`metrics`] accumulates the quantities behind every figure
//! of §8: acceptance rates (overall, hourly, per profile), the strict
//! active-hardware rate, migrations and Table 6's area under the curve.

pub mod engine;
pub mod metrics;

pub use engine::{Simulation, SimulationOptions};
pub use metrics::{Sample, SimResult};
